#!/usr/bin/env python
"""Docs link checker: fail on dangling intra-repo references.

Checks, over README.md and every markdown file under docs/:

  * markdown links `[text](target)` whose target is a relative path —
    the file must exist (anchors `#...` are stripped; pure-anchor and
    external http(s)/mailto links are skipped);
  * `docs/DESIGN.md` prose references anywhere in README.md, docs/,
    src/, benchmarks/, examples/ and tests/ — the file must exist, and
    a `§N` / `§Name` section reference must match a heading in it.

Run from the repo root:  python tools/check_docs.py
Shares the tools/ convention: violations print as ``FAIL ...`` lines,
the last line is ``# check_docs: ok`` / ``# check_docs: N
failure(s)``, exit 0 iff clean.
"""
from __future__ import annotations

import os
import pathlib
import re
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from _ci import finish  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECREF_RE = re.compile(r"docs/DESIGN\.md\s+§([\w-]+)")


def md_files():
    out = [ROOT / "README.md"]
    docs = ROOT / "docs"
    if docs.is_dir():
        out.extend(sorted(docs.rglob("*.md")))
    return [p for p in out if p.exists()]


def check_md_links(errors):
    for md in md_files():
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                                  f"dangling link -> {target}")


def design_headings():
    design = ROOT / "docs" / "DESIGN.md"
    if not design.exists():
        return None
    heads = []
    for line in design.read_text().splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if m:
            heads.append(m.group(1).lower())
    return heads


def check_section_refs(errors):
    heads = design_headings()
    if heads is None:
        errors.append("docs/DESIGN.md does not exist but is referenced")
        return
    scan_roots = ["README.md", "docs", "src", "benchmarks", "examples",
                  "tests"]
    files = []
    for r in scan_roots:
        p = ROOT / r
        if p.is_file():
            files.append(p)
        elif p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
            files.extend(sorted(p.rglob("*.md")))
    for f in files:
        try:
            text = f.read_text()
        except UnicodeDecodeError:
            continue
        for lineno, line in enumerate(text.splitlines(), 1):
            for sec in SECREF_RE.findall(line):
                sl = sec.lower()
                # "§3" matches a "## 3. ..." heading; "§Arch-..."
                # matches by prefix
                ok = any(h.startswith(f"{sl}.") or h.startswith(f"{sl} ")
                         or sl in h for h in heads)
                if not ok:
                    errors.append(
                        f"{f.relative_to(ROOT)}:{lineno}: "
                        f"docs/DESIGN.md §{sec} matches no heading")


def main() -> int:
    errors = []
    check_md_links(errors)
    check_section_refs(errors)
    if not errors:
        n = len(md_files())
        print(f"docs OK: {n} markdown file(s), all intra-repo links "
              "and DESIGN.md section references resolve")
    return finish("check_docs", errors)


if __name__ == "__main__":
    sys.exit(main())
