#!/usr/bin/env python
"""Static-analysis gate: the five `repro.analysis` engines over the
repo (docs/DESIGN.md §Analysis).

  * source — AST rules over ``src/``: bare ``PRNGKey(<const>)`` under
    ``launch/``, kernel-oracle / ``REPRO_REF_BWD``-hatch completeness,
    README env-knob-table completeness, the materializing-call
    allowlist.
  * stream — mask-stream coverage over the registry config zoo: every
    `MaskedLeaf`'s intervals tile its flat hash stream exactly (zero
    overlaps / zero gaps, grouped (E, K, N) expert slices included)
    and no two (leaf, shard, cohort) streams share a seed.
  * jaxpr  — the rule-based walker on the MXU-aligned whole-model
    check configs AND the kernel-level fused fwd/bwd: zero
    weight-shaped f32 temporaries outside pallas_call, zero
    materialized masks, no f64 / weight-sized bf16→f32 promotion, no
    use-after-donate.
  * collective — wire purity of every (arch x algorithm) round cell's
    collectives on the debug pod mesh: only packed uint32 words, the
    float-sidecar pmean, and scalar metrics may cross
    (`repro.analysis.collective_lint`); the static cost tables the
    same traces yield are committed as ``BENCH_comm.json`` by
    ``benchmarks/comm_bench.py`` and diffed by ``tools/check_comm.py``.
  * shard — `launch/sharding.py` annotations vs reality: big leaves
    the divisibility heuristic silently replicated across the registry
    param trees, plus declared-vs-lowered input shardings on the
    reference arch's compiled round step.

Usage:
    PYTHONPATH=src python tools/repro_lint.py \
        [--engines source,stream,jaxpr,collective,shard] \
        [--archs all|a,b,...] \
        [--devices 8] [--cohorts 2] [--seed 17]

Shares the tools/ convention: ``FAIL ...`` lines, then a final
``# repro_lint: ok`` / ``# repro_lint: N failure(s)``; exit 0 iff ok.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))
sys.path.insert(0, str(ROOT / "src"))

from _ci import finish  # noqa: E402


def run_source(errors) -> None:
    from repro.analysis import source_lint
    found = source_lint.run_all(ROOT)
    errors.extend(f"source {f}" for f in found)
    print(f"# repro_lint[source]: {len(found)} finding(s)")


def run_stream(errors, archs, devices, cohorts, seed) -> None:
    from repro.analysis import stream_cover
    for arch in archs:
        rep = stream_cover.arch_stream_report(
            arch, smoke=True, C=cohorts, devs=range(devices),
            run_seed=seed)
        errors.extend(f"stream[{arch}] {f}" for f in rep["findings"])
        print(f"# repro_lint[stream] {arch}: {rep['n_leaves']} leaves, "
              f"{rep['n_intervals']} intervals, {rep['n_streams']} "
              f"streams, {len(rep['findings'])} finding(s)")


def run_jaxpr(errors) -> None:
    import jax
    import jax.numpy as jnp
    from repro.analysis import jaxpr_lint, model_check
    from repro.kernels import ops
    from repro.launch import steps as steplib

    # kernel level: the fused dense fwd+bwd jaxprs stay clean under
    # EVERY rule (aligned shape -> no pad/slice equations)
    M, K, N = 256, 512, 512
    x = jnp.zeros((M, K), jnp.bfloat16)
    w = jnp.zeros((K, N), jnp.bfloat16)
    s = jnp.zeros((K, N), jnp.float32)
    g = jnp.zeros((M, N), jnp.bfloat16)

    def fwd_bwd(x, w, s, g):
        y, vjp = jax.vjp(lambda x_, s_: ops.masked_dense(x_, w, s_, 0),
                         x, s)
        return y, vjp(g)

    jx = jax.make_jaxpr(fwd_bwd)(x, w, s, g)
    rules = [jaxpr_lint.weight_f32_temporaries((K, N)),
             jaxpr_lint.mask_materialization((K, N)),
             jaxpr_lint.DtypePromotionRule([(K, N)]),
             jaxpr_lint.DonationAliasRule()]
    found = jaxpr_lint.lint_jaxpr(jx, rules)
    errors.extend(f"jaxpr[kernel] {f}" for f in found)
    print(f"# repro_lint[jaxpr] kernel fwd+bwd: {len(found)} "
          "finding(s)")

    # whole-model level: fused train step of each aligned family; the
    # bf16→f32 shape check stays off here (a (128, 128) activation can
    # legitimately share a block shape at model scale — the kernel-
    # level pass above is the precise home for that rule)
    for fam, (cfg, S) in model_check.MODEL_CHECK_CFGS.items():
        api, state, batch = model_check.model_step_setup(cfg, S=S)
        scfg = steplib.StepConfig(lam=0.1, lr=0.5)
        jx, _ = model_check.trace_model_step(api, state, batch, scfg,
                                             eff_path=False)
        shapes = model_check.masked_block_shapes(state)
        rules = [jaxpr_lint.weight_f32_temporaries(sh)
                 for sh in shapes]
        rules += [jaxpr_lint.mask_materialization(sh)
                  for sh in shapes]
        rules.append(jaxpr_lint.DtypePromotionRule())
        rules.append(jaxpr_lint.DonationAliasRule())
        found = jaxpr_lint.lint_jaxpr(jx, rules)
        errors.extend(f"jaxpr[{fam}] {f}" for f in found)
        print(f"# repro_lint[jaxpr] {fam}: {len(shapes)} block "
              f"shapes, {len(found)} finding(s)")


def run_collective(errors, archs, cohorts) -> None:
    from repro.analysis import collective_lint
    from repro.launch import mesh as meshlib
    from repro.launch import plans

    mesh = meshlib.make_debug_pod_mesh()
    ref = "internlm2-1.8b"
    cells = [(a, "fedpm_reg") for a in archs]
    cells += [(ref, algo) for algo in sorted(plans.MASK_ALGOS)
              if algo != "fedpm_reg" or ref not in archs]
    for arch, algo in cells:
        rep = collective_lint.arch_collective_report(
            arch, algo, mesh=mesh, C=cohorts)
        errors.extend(f"collective[{arch}|{algo}] {f}"
                      for f in rep["findings"])
        m = rep["model"]
        print(f"# repro_lint[collective] {arch}|{algo}: "
              f"{rep['n_sites']} sites, bpp_wire={m['bpp_wire']}, "
              f"{len(rep['findings'])} finding(s)")
    # liveness: the bf16-psum baseline MUST trip the float rule — a
    # rule that stops firing on the known-impure path is a dead gate
    rep = collective_lint.arch_collective_report(
        ref, "fedpm_reg", mesh=mesh, C=cohorts, packed=False)
    if not rep["findings"]:
        errors.append("collective[liveness] unpacked bf16-psum round "
                      "produced zero purity findings (rule went dead)")
    print(f"# repro_lint[collective] liveness(unpacked): "
          f"{len(rep['findings'])} finding(s) (expected > 0)")


def run_shard(errors, archs, cohorts) -> None:
    from repro.analysis import shard_lint
    from repro.launch import mesh as meshlib

    mesh = meshlib.make_debug_pod_mesh()
    for arch in archs:
        rep = shard_lint.arch_shard_report(arch, mesh=mesh)
        errors.extend(f"shard[{arch}] {f}" for f in rep["findings"])
        print(f"# repro_lint[shard] {arch}: "
              f"{len(rep['explanations'])} leaves explained, "
              f"{len(rep['findings'])} finding(s)")
    # declared-vs-lowered on the reference arch's compiled round step
    rep = shard_lint.arch_shard_report("internlm2-1.8b", mesh=mesh,
                                       C=cohorts, compile_step=True)
    errors.extend(f"shard[round-step] {f}" for f in rep["findings"])
    print(f"# repro_lint[shard] round-step(internlm2-1.8b): "
          f"{rep['n_leaves']} leaves, {len(rep['findings'])} "
          "finding(s)")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--engines",
                   default="source,stream,jaxpr,collective,shard",
                   help="comma-separated subset of "
                        "source,stream,jaxpr,collective,shard")
    p.add_argument("--archs", default="all",
                   help="'all' (full registry zoo) or comma-separated "
                        "names, for the stream engine")
    p.add_argument("--devices", type=int, default=8,
                   help="simulated shard ids swept by the stream "
                        "engine (mask_stream_seed is pure: no real "
                        "devices needed)")
    p.add_argument("--cohorts", type=int, default=2)
    p.add_argument("--seed", type=int, default=17)
    args = p.parse_args(argv)

    engines = {e.strip() for e in args.engines.split(",") if e.strip()}
    unknown = engines - {"source", "stream", "jaxpr", "collective",
                         "shard"}
    if unknown:
        print(f"unknown engine(s): {sorted(unknown)}", file=sys.stderr)
        return 2

    if args.archs == "all":
        from repro.configs import ARCH_NAMES
        archs = list(ARCH_NAMES)
    else:
        archs = [a.strip() for a in args.archs.split(",") if a.strip()]

    errors: list = []
    if "source" in engines:
        run_source(errors)
    if "stream" in engines:
        run_stream(errors, archs, args.devices, args.cohorts,
                   args.seed)
    if "jaxpr" in engines:
        run_jaxpr(errors)
    if "collective" in engines:
        run_collective(errors, archs, args.cohorts)
    if "shard" in engines:
        run_shard(errors, archs, args.cohorts)
    return finish("repro_lint", errors)


if __name__ == "__main__":
    sys.exit(main())
