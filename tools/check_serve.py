#!/usr/bin/env python3
"""Serving-benchmark regression gate: compare a freshly written
``BENCH_serve.json`` against the committed baseline.

Two kinds of check (same convention as ``check_bench.py``):

  * STRUCTURAL (always asserted): the sweep must prove the
    one-shared-`w` HBM claim — ``weight_bytes`` identical across every
    row while the tenant count grows, at least one row with
    ``tenants > capacity``, freeze-cache occupancy never above
    capacity, evictions observed once tenants exceed capacity, and the
    resident-bytes ledger arithmetically consistent
    (``weight + occupancy * delta``).

  * TIMING (asserted only on real hardware): per-row
    ``decode_tok_s`` must not regress below ``1 / --max-ratio`` of the
    baseline row.  Under Pallas interpret mode (CPU CI) the engine
    runs emulated kernels, so throughput is printed informationally
    and never fails.

Usage:
    python tools/check_serve.py --fresh BENCH_serve.json \
        --baseline /tmp/BENCH_serve_baseline.json [--max-ratio 2.0]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from _ci import finish  # noqa: E402


def structural_errors(fresh: dict):
    rows = fresh.get("rows") or []
    if not rows:
        yield "no rows in fresh BENCH_serve.json"
        return
    weights = {r["weight_bytes"] for r in rows}
    if len(weights) != 1:
        yield (f"weight_bytes varies across rows ({sorted(weights)}): "
               "resident weight HBM must be ONE shared w regardless of "
               "tenant count")
    if not any(r["tenants"] > r["capacity"] for r in rows):
        yield ("no row exercises tenants > cache capacity; the sweep "
               "must cross the freeze-cache bound")
    for r in rows:
        t = r["tenants"]
        if r["occupancy"] > r["capacity"]:
            yield (f"tenants={t}: occupancy {r['occupancy']} exceeds "
                   f"cache capacity {r['capacity']}")
        if t > r["capacity"] and r["evictions"] < 1:
            yield (f"tenants={t} > capacity {r['capacity']} but no "
                   "evictions: LRU bound not exercised")
        want = r["weight_bytes"] + r["occupancy"] * \
            r["delta_bytes_per_tree"]
        if r["resident_bytes"] != want:
            yield (f"tenants={t}: resident_bytes {r['resident_bytes']} "
                   f"!= weight + occupancy*delta ({want})")
        if r["decode_tokens"] <= 0 or r["decode_tok_s"] <= 0:
            yield f"tenants={t}: no decode throughput recorded"
        if r["misses"] + r["hits"] < t:
            yield (f"tenants={t}: cache saw fewer lookups "
                   f"({r['hits']}+{r['misses']}) than tenants")


def timing_errors(fresh: dict, base: dict, max_ratio: float):
    base_rows = {r["tenants"]: r for r in base.get("rows", [])}
    for r in fresh.get("rows", []):
        b = base_rows.get(r["tenants"])
        if not b or not b.get("decode_tok_s"):
            continue
        ratio = b["decode_tok_s"] / max(r["decode_tok_s"], 1e-9)
        if ratio > max_ratio:
            yield (f"tenants={r['tenants']}: decode {r['decode_tok_s']:.1f}"
                   f" tok/s is {ratio:.2f}x slower than baseline "
                   f"{b['decode_tok_s']:.1f} tok/s (limit {max_ratio}x)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--max-ratio", type=float, default=2.0)
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    errors = list(structural_errors(fresh))

    interpret = bool(fresh.get("interpret")) or bool(base.get("interpret"))
    t_errs = list(timing_errors(fresh, base, args.max_ratio))
    if interpret:
        for e in t_errs:
            print(f"# (informational, interpret mode) {e}")
        print(f"# interpret mode: {len(t_errs)} timing deviation(s) "
              "not asserted (emulated kernels)")
    else:
        errors.extend(t_errs)

    return finish("check_serve", errors)


if __name__ == "__main__":
    sys.exit(main())
