#!/usr/bin/env python3
"""Kernel-benchmark regression gate: compare a freshly written
``BENCH_kernels.json`` against the committed baseline.

Two kinds of check:

  * STRUCTURAL (always asserted): the fused kernels must define zero
    weight-shaped f32 temporaries (``weight_f32_defs``) and the
    whole-model gate (``model_step``) must report fused == 0 on every
    masked block shape of every checked family — these are jaxpr
    counts, valid on any backend.

  * TIMING (asserted only on real hardware): the fused-vs-reference
    ratio ``fused_us / ref_us`` per (shape, op) must not regress by
    more than ``--max-ratio-regression`` (default 2x) against the
    baseline's ratio.  Under Pallas interpret mode (CPU CI) the fused
    kernels are EMULATED, so absolute timings — and their ratios — are
    meaningless; the timing comparison then prints informationally and
    never fails (the structural jaxpr counts are the gate there).

Usage:
    python tools/check_bench.py --fresh BENCH_kernels.json \
        --baseline /tmp/BENCH_baseline.json [--max-ratio-regression 2.0]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from _ci import finish  # noqa: E402


def _ratios(results: dict) -> dict:
    """{(kind, name, op): fused_us / reference_us} for every timed
    shape AND the whole-model train steps (fused vs the materialized
    REPRO_EFF_PATH baseline — the headline fused-vs-eff ratio)."""
    out = {}
    for kind, ops in (("shapes", ("fwd", "bwd", "sample_pack")),
                      ("grouped_shapes", ("fwd", "bwd"))):
        for row in results.get(kind, []):
            for op in ops:
                fused = row.get(f"{op}_us")
                refus = row.get(f"{op}_ref_us")
                if fused and refus:
                    out[(kind, row["name"], op)] = fused / refus
    model = results.get("model_step") or {}
    fams = (model.items() if "block_shapes" not in model
            else [("dense", model)])
    for fam, m in fams:
        fused = m.get("train_step_us")
        eff = m.get("train_step_eff_us")
        if fused and eff:
            out[("model_step", f"model_step[{fam}]", "train_step")] = \
                fused / eff
    return out


def check_structural(results: dict, label: str) -> list:
    """Missing keys are hard failures: the structural gate must never
    pass vacuously on a truncated or schema-drifted JSON."""
    errs = []
    wd = results.get("weight_f32_defs")
    if not isinstance(wd, dict):
        errs.append(f"{label}: missing weight_f32_defs section")
        wd = {}
    for key in ("fwd_fused", "bwd_fused"):
        if key not in wd:
            errs.append(f"{label}: weight_f32_defs[{key}] missing")
        elif wd[key] != 0:
            errs.append(f"{label}: weight_f32_defs[{key}] = {wd[key]} "
                        "(must be 0)")
    for key in ("fwd_naive", "bwd_naive"):
        if key not in wd:
            errs.append(f"{label}: weight_f32_defs[{key}] missing")
        elif wd[key] <= 0:
            errs.append(f"{label}: weight_f32_defs[{key}] lost its "
                        "temporaries")
    model = results.get("model_step")
    if not isinstance(model, dict) or not model:
        errs.append(f"{label}: missing model_step section")
        model = {}
    # pre-grouped JSONs had a flat model_step; current ones are
    # keyed by family
    fams = (model.items() if "block_shapes" not in model
            else [("dense", model)])
    for fam, m in fams:
        if not m.get("block_shapes") or not m.get("leaf_shapes"):
            errs.append(f"{label}: model_step[{fam}] has no "
                        "block/leaf shape counts")
        for sh, cts in m.get("block_shapes", {}).items():
            if cts.get("fused", 1) != 0:
                errs.append(f"{label}: model_step[{fam}] block {sh} "
                            f"fused = {cts.get('fused')} (must be 0)")
        for sh, cts in m.get("leaf_shapes", {}).items():
            if cts.get("eff", 0) <= cts.get("fused", 0):
                errs.append(f"{label}: model_step[{fam}] leaf {sh} "
                            f"eff {cts.get('eff')} <= fused "
                            f"{cts.get('fused')}")
    return errs


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fresh", default="BENCH_kernels.json",
                   help="freshly generated results JSON")
    p.add_argument("--baseline", required=True,
                   help="committed baseline JSON to compare against")
    p.add_argument("--max-ratio-regression", type=float, default=2.0,
                   help="fail if fresh fused/ref ratio exceeds this "
                        "multiple of the baseline ratio")
    args = p.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    errs = check_structural(fresh, "fresh")

    fresh_interp = bool(fresh.get("interpret"))
    base_interp = bool(base.get("interpret"))
    interpret = fresh_interp or base_interp
    if interpret:
        # say so up front, not only when a regression happens to exist
        src = ("fresh run" if fresh_interp and base_interp else
               "fresh run" if fresh_interp else "committed baseline")
        print(f"# timing gate DISARMED: {src} was recorded under Pallas "
              "interpret mode (emulated kernels; ratios not "
              "comparable)" + ("" if fresh_interp else
                               " — commit a hardware BENCH_kernels.json "
                               "to arm the 2x gate"))
    fr, br = _ratios(fresh), _ratios(base)
    timing_errs = []
    for key in sorted(fr.keys() & br.keys()):
        kind, name, op = key
        ratio, base_ratio = fr[key], br[key]
        verdict = "ok"
        if base_ratio > 0 and ratio > args.max_ratio_regression * base_ratio:
            verdict = "REGRESSED"
            timing_errs.append(
                f"{name}:{op} fused/ref ratio {ratio:.2f} > "
                f"{args.max_ratio_regression:.1f}x baseline "
                f"{base_ratio:.2f}")
        print(f"{name}:{op},ratio={ratio:.3f},baseline={base_ratio:.3f},"
              f"{verdict}")
    missing = br.keys() - fr.keys()
    if missing:
        errs.append(f"fresh JSON lost timed shapes: {sorted(missing)}")

    if timing_errs:
        if interpret:
            print(f"# interpret mode: {len(timing_errs)} timing "
                  "regression(s) reported informationally only "
                  "(emulated kernels; structural jaxpr counts are the "
                  "gate)")
        else:
            errs.extend(timing_errs)

    return finish("check_bench", errs)


if __name__ == "__main__":
    sys.exit(main())
