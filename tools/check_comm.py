#!/usr/bin/env python
"""Diff a freshly generated BENCH_comm.json against the committed
baseline.

The static communication model is DETERMINISTIC given the registry,
the round-step code, and the mesh shape — so the static fields must
match the baseline EXACTLY (no tolerance band like the kernel
latency diff).  A drift means a collective was added, removed, or
re-shaped in the round step; if intentional, regenerate the baseline:

    PYTHONPATH=src python benchmarks/comm_bench.py \
        --validate --json BENCH_comm.json

Also enforced on the FRESH run: the measured-vs-static validation
block (when present) must be ok, and the unpacked contrast row must
still trip the purity rule (liveness).

Usage:
    python tools/check_comm.py --fresh /tmp/BENCH_comm.json \
        [--baseline BENCH_comm.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

from _ci import finish  # noqa: E402

# per-algorithm scalars that must not drift
STATIC_KEYS = ("uplink_bits", "downlink_bits", "bpp_wire", "n_sites",
               "cohorts", "mask_params", "ring_bytes_per_axis",
               "ring_bytes_per_prim")


def _site_set(tab: dict):
    return sorted(
        (r["prim"], tuple(r["axes"]), r["dtype"], tuple(r["shape"]),
         r["role"], r["payload_bits_per_shard"])
        for r in tab["sites"])


def diff(fresh: dict, base: dict) -> list:
    errors = []
    if fresh["meta"].get("mesh") != base["meta"].get("mesh"):
        errors.append(
            f"mesh drift: baseline {base['meta'].get('mesh')} vs "
            f"fresh {fresh['meta'].get('mesh')} — comm model is only "
            "comparable on the same mesh")
    for algo, btab in sorted(base["algos"].items()):
        ftab = fresh["algos"].get(algo)
        if ftab is None:
            errors.append(f"{algo}: missing from fresh run")
            continue
        for k in STATIC_KEYS:
            if ftab.get(k) != btab.get(k):
                errors.append(f"{algo}.{k}: baseline {btab.get(k)} "
                              f"vs fresh {ftab.get(k)}")
        if _site_set(ftab) != _site_set(btab):
            errors.append(f"{algo}: collective site set drifted "
                          f"({btab['n_sites']} baseline vs "
                          f"{ftab['n_sites']} fresh sites)")
    for algo in sorted(fresh["algos"]):
        if algo not in base["algos"]:
            errors.append(f"{algo}: new algorithm not in baseline — "
                          "regenerate and commit BENCH_comm.json")
    v = fresh.get("validation")
    if v is not None and not v.get("ok"):
        errors.append(f"static-vs-measured validation failed: "
                      f"rel_err={v.get('rel_err')} "
                      f"(tol {v.get('tolerance')})")
    contrast = fresh.get("unpacked_contrast", {})
    if contrast.get("purity_findings", 0) <= 0:
        errors.append("unpacked contrast fired zero purity findings "
                      "(rule went dead)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="BENCH_comm.json from this run")
    ap.add_argument("--baseline", default=str(ROOT / "BENCH_comm.json"))
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    errors = diff(fresh, base)
    print(f"# check_comm: {len(base['algos'])} algo table(s) compared")
    return finish("check_comm", errors)


if __name__ == "__main__":
    sys.exit(main())
