"""Shared reporting convention for the tools/ CI gates.

Every gate (``check_bench.py``, ``check_docs.py``, ``repro_lint.py``)
reports the same way, so job logs are scannable:

  * each violation prints as a line starting with ``FAIL ``;
  * the LAST line is ``# <tool>: ok`` or ``# <tool>: N failure(s)``;
  * the process exits 0 iff there are no failures.
"""
from __future__ import annotations


def finish(tool: str, errors) -> int:
    """Print the FAIL lines and the summary line; return the exit
    code for ``sys.exit``."""
    errors = list(errors)
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        print(f"# {tool}: {len(errors)} failure(s)")
        return 1
    print(f"# {tool}: ok")
    return 0
