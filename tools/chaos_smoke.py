#!/usr/bin/env python
"""Crash-restart chaos smoke: kill the trainer mid-run, resume, and
assert crash consistency (the CI twin of docs/DESIGN.md §5).

    PYTHONPATH=src python tools/chaos_smoke.py

Drives `repro.launch.train` as a real subprocess with checkpointing and
fault injection on, then:

  1. waits for the FIRST round commit (checkpoint + ledger sidecar on
     disk) and SIGKILLs the process — no atexit, no flush, exactly a
     coordinator crash;
  2. relaunches the identical command and lets it run to completion;
  3. asserts STEP CONTINUITY (the resumed run starts from the
     checkpointed step, never from 0) and a MONOTONE CommLedger (the
     cumulative byte ledger resumes from the sidecar and only grows —
     a crash must never under-report communication).

Exit code 0 = pass; any assertion prints FAIL and exits 1 (the same
convention as tools/check_bench.py / check_docs.py).

``--tree`` runs the AGGREGATOR-TREE chaos gate instead: it drives
`repro.runtime.agg_tree`'s CLI (a `TreeRoundEngine` with live edge
crash/partition faults, per-tick crash-consistent saves), SIGKILLs the
coordinator mid-round after the first commit is durable, resumes, and
asserts EXACTLY-ONCE commits: every version printed by the killed run
was durably saved before it was announced, the resumed run continues
strictly after the restored version with monotone event ``seq``, the
union of committed versions equals an uninterrupted reference run's,
and the final theta digest matches the reference bit-for-bit.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import signal
import subprocess
import sys
import tempfile
import time

STEPS = 12
ROUND_EVERY = 4


def _cmd(ckpt_dir: str) -> list:
    return [
        sys.executable, "-m", "repro.launch.train", "--smoke",
        "--steps", str(STEPS), "--round-every", str(ROUND_EVERY),
        "--cohorts", "4", "--fail-prob", "0.3", "--quorum-frac", "0.8",
        "--ckpt-dir", ckpt_dir,
    ]


def _read_ledger(ckpt_dir: str) -> dict:
    with open(os.path.join(ckpt_dir, "comm_ledger.json")) as f:
        return json.load(f)


def _fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def _tree_cmd(ckpt_dir: str, marker: str = "",
              tick_sleep: float = 0.0) -> list:
    cmd = [
        sys.executable, "-m", "repro.runtime.agg_tree",
        "--ticks", "8", "--clients", "8", "--fanout", "2",
        "--agg-fault-prob", "0.3", "--quorum-frac", "0.75",
        "--deadline", "2", "--seed", "0", "--ckpt-dir", ckpt_dir,
    ]
    if marker:
        cmd += ["--marker", marker]
    if tick_sleep:
        cmd += ["--tick-sleep", str(tick_sleep)]
    return cmd


def _commits(text: str) -> list:
    """[(version, seq)] in print order."""
    return [(int(v), int(s)) for v, s in
            re.findall(r"commit v=(\d+) seq=(\d+)", text)]


def _digest(text: str) -> str:
    m = re.search(r"theta digest ([0-9a-f]{8}) version (\d+)", text)
    return m and (m.group(1), int(m.group(2)))


def tree_main(args) -> None:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")

    # -- reference: one uninterrupted run -------------------------------
    print("[1/3] uninterrupted reference run")
    ref_dir = tempfile.mkdtemp(prefix="chaos_tree_ref_")
    ref = subprocess.run(_tree_cmd(ref_dir), env=env,
                         capture_output=True, text=True,
                         timeout=args.timeout)
    if ref.returncode != 0:
        _fail(f"reference run failed (rc={ref.returncode}):\n"
              + ref.stdout[-2000:] + ref.stderr[-2000:])
    ref_commits = _commits(ref.stdout)
    ref_digest = _digest(ref.stdout)
    if not ref_commits or ref_digest is None:
        _fail("reference run produced no commits/digest:\n" + ref.stdout)
    print(f"      reference: versions "
          f"{[v for v, _ in ref_commits]}, digest {ref_digest[0]}")

    # -- phase 2: run with per-tick saves, SIGKILL an edge mid-round ----
    print("[2/3] launch + SIGKILL after first durable commit")
    ckpt_dir = tempfile.mkdtemp(prefix="chaos_tree_")
    marker = os.path.join(ckpt_dir, "COMMITTED")
    p = subprocess.Popen(_tree_cmd(ckpt_dir, marker, tick_sleep=0.4),
                         env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT)
    deadline = time.time() + args.timeout
    try:
        while time.time() < deadline:
            if p.poll() is not None:
                _fail(f"tree driver exited (rc={p.returncode}) before "
                      "the kill; output:\n" + p.stdout.read().decode())
            if os.path.exists(marker):
                break
            time.sleep(0.1)
        else:
            _fail("no durable commit within the timeout")
        # let it get ~mid-tick so the kill lands between save points
        time.sleep(0.2)
        os.kill(p.pid, signal.SIGKILL)
        p.wait(timeout=30)
    finally:
        if p.poll() is None:
            p.kill()
    out1 = p.stdout.read().decode()
    v1 = _commits(out1)
    print(f"      killed; announced versions {[v for v, _ in v1]}")
    if not v1:
        _fail("marker existed but no commit line was printed")

    # -- phase 3: resume + exactly-once assertions ----------------------
    print("[3/3] resume + assert exactly-once commits")
    out = subprocess.run(_tree_cmd(ckpt_dir), env=env,
                         capture_output=True, text=True,
                         timeout=args.timeout)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        _fail(f"resumed run failed (rc={out.returncode}):\n"
              + out.stdout[-2000:] + out.stderr[-2000:])
    m = re.search(r"resumed at tick (\d+) \(version (\d+), seq (\d+)\)",
                  out.stdout)
    if not m:
        _fail("resumed run did not restore the bundle "
              "(no 'resumed at tick' line)")
    v_r, seq_r = int(m.group(2)), int(m.group(3))
    v2 = _commits(out.stdout)
    digest2 = _digest(out.stdout)
    # every commit the killed run ANNOUNCED was saved first, so the
    # restored version is at least the last announced one ...
    if v_r < max(v for v, _ in v1):
        _fail(f"announced commit v{max(v for v, _ in v1)} was not "
              f"durable (resumed at v{v_r}) — the driver printed "
              "before saving")
    # ... and the resumed run must never re-commit an announced version
    if any(v <= v_r for v, _ in v2):
        _fail(f"version replayed after restore: resumed at v{v_r}, "
              f"recommitted {[v for v, _ in v2 if v <= v_r]}")
    seqs = [s for _, s in v2]
    if seqs != sorted(seqs) or (seqs and seqs[0] <= seq_r):
        _fail(f"event seq not monotone across the crash: restored "
              f"seq {seq_r}, then {seqs}")
    # exactly-once over the whole history: durable prefix + resumed
    # tail == the uninterrupted reference, and the final theta matches
    got = sorted({v for v, _ in v1 if v <= v_r} | {v for v, _ in v2})
    want = sorted({v for v, _ in ref_commits})
    if got != want:
        _fail(f"committed versions diverged: {got} vs reference {want}")
    if digest2 is None:
        _fail("resumed run printed no theta digest")
    if digest2 != ref_digest:
        _fail(f"theta digest diverged across the crash: {digest2} vs "
              f"reference {ref_digest}")
    print(f"OK: killed at v{max(v for v, _ in v1)}, resumed at v{v_r}, "
          f"versions {got} == reference, digest {digest2[0]} matches")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-phase wall clock limit (s)")
    ap.add_argument("--tree", action="store_true",
                    help="run the aggregator-tree exactly-once gate "
                         "instead of the trainer gate")
    args = ap.parse_args(argv)
    if args.tree:
        tree_main(args)
        return

    ckpt_dir = tempfile.mkdtemp(prefix="chaos_smoke_")
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    ledger_path = os.path.join(ckpt_dir, "comm_ledger.json")

    # -- phase 1: run until the first round lands on disk, then KILL ----
    print(f"[1/3] launch + kill after first commit  (ckpt={ckpt_dir})")
    p = subprocess.Popen(_cmd(ckpt_dir), env=env,
                         stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT)
    deadline = time.time() + args.timeout
    try:
        while time.time() < deadline:
            if p.poll() is not None:
                _fail(f"trainer exited (rc={p.returncode}) before the "
                      "kill — round too fast or crashed; output:\n"
                      + p.stdout.read().decode())
            if (os.path.exists(os.path.join(ckpt_dir, "LATEST"))
                    and os.path.exists(ledger_path)):
                break
            time.sleep(0.2)
        else:
            _fail("no checkpoint appeared within the timeout")
        os.kill(p.pid, signal.SIGKILL)   # a real coordinator crash
        p.wait(timeout=30)
    finally:
        if p.poll() is None:
            p.kill()
    with open(os.path.join(ckpt_dir, "LATEST")) as f:
        killed_at = int(f.read().strip())
    pre = _read_ledger(ckpt_dir)
    print(f"      killed after step {killed_at}; "
          f"ledger rounds={pre['rounds']} "
          f"uplink_bits={pre['uplink_bits']:.0f}")
    if killed_at < ROUND_EVERY:
        _fail(f"checkpoint step {killed_at} before the first round")

    # -- phase 2: resume the identical command to completion ------------
    print("[2/3] resume to completion")
    out = subprocess.run(_cmd(ckpt_dir), env=env, capture_output=True,
                         text=True, timeout=args.timeout)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        _fail(f"resumed run failed (rc={out.returncode}):\n"
              + out.stderr[-2000:])

    # -- phase 3: continuity + monotone ledger --------------------------
    print("[3/3] assert step continuity + monotone ledger")
    m = re.search(r"resumed at step (\d+)", out.stdout)
    if not m:
        _fail("resumed run did not restore the checkpoint "
              "(no 'resumed at step' line)")
    resumed = int(m.group(1))
    if resumed != killed_at:
        _fail(f"step discontinuity: killed at {killed_at}, "
              f"resumed at {resumed}")
    if not re.search(r"resumed ledger:", out.stdout):
        _fail("CommLedger sidecar was not resumed")
    if "done" not in out.stdout:
        _fail("resumed run did not reach 'done'")
    post = _read_ledger(ckpt_dir)
    for k in ("uplink_bits", "downlink_bits", "rounds"):
        if post[k] < pre[k]:
            _fail(f"ledger went BACKWARD across the crash: "
                  f"{k} {pre[k]} -> {post[k]}")
    if post["rounds"] <= pre["rounds"]:
        _fail(f"no rounds after resume ({pre['rounds']} -> "
              f"{post['rounds']})")
    expect_rounds = STEPS // ROUND_EVERY
    if post["rounds"] != expect_rounds:
        _fail(f"resumed run re-counted rounds: total {post['rounds']} "
              f"!= {expect_rounds} (double-counting a replayed round?)")
    print(f"OK: killed at step {killed_at}, resumed at {resumed}, "
          f"ledger {pre['rounds']} -> {post['rounds']} rounds "
          f"monotone ({pre['uplink_bits']:.0f} -> "
          f"{post['uplink_bits']:.0f} uplink bits)")


if __name__ == "__main__":
    main()
