#!/usr/bin/env python
"""Crash-restart chaos smoke: kill the trainer mid-run, resume, and
assert crash consistency (the CI twin of docs/DESIGN.md §5).

    PYTHONPATH=src python tools/chaos_smoke.py

Drives `repro.launch.train` as a real subprocess with checkpointing and
fault injection on, then:

  1. waits for the FIRST round commit (checkpoint + ledger sidecar on
     disk) and SIGKILLs the process — no atexit, no flush, exactly a
     coordinator crash;
  2. relaunches the identical command and lets it run to completion;
  3. asserts STEP CONTINUITY (the resumed run starts from the
     checkpointed step, never from 0) and a MONOTONE CommLedger (the
     cumulative byte ledger resumes from the sidecar and only grows —
     a crash must never under-report communication).

Exit code 0 = pass; any assertion prints FAIL and exits 1 (the same
convention as tools/check_bench.py / check_docs.py).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import signal
import subprocess
import sys
import tempfile
import time

STEPS = 12
ROUND_EVERY = 4


def _cmd(ckpt_dir: str) -> list:
    return [
        sys.executable, "-m", "repro.launch.train", "--smoke",
        "--steps", str(STEPS), "--round-every", str(ROUND_EVERY),
        "--cohorts", "4", "--fail-prob", "0.3", "--quorum-frac", "0.8",
        "--ckpt-dir", ckpt_dir,
    ]


def _read_ledger(ckpt_dir: str) -> dict:
    with open(os.path.join(ckpt_dir, "comm_ledger.json")) as f:
        return json.load(f)


def _fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-phase wall clock limit (s)")
    args = ap.parse_args(argv)

    ckpt_dir = tempfile.mkdtemp(prefix="chaos_smoke_")
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    ledger_path = os.path.join(ckpt_dir, "comm_ledger.json")

    # -- phase 1: run until the first round lands on disk, then KILL ----
    print(f"[1/3] launch + kill after first commit  (ckpt={ckpt_dir})")
    p = subprocess.Popen(_cmd(ckpt_dir), env=env,
                         stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT)
    deadline = time.time() + args.timeout
    try:
        while time.time() < deadline:
            if p.poll() is not None:
                _fail(f"trainer exited (rc={p.returncode}) before the "
                      "kill — round too fast or crashed; output:\n"
                      + p.stdout.read().decode())
            if (os.path.exists(os.path.join(ckpt_dir, "LATEST"))
                    and os.path.exists(ledger_path)):
                break
            time.sleep(0.2)
        else:
            _fail("no checkpoint appeared within the timeout")
        os.kill(p.pid, signal.SIGKILL)   # a real coordinator crash
        p.wait(timeout=30)
    finally:
        if p.poll() is None:
            p.kill()
    with open(os.path.join(ckpt_dir, "LATEST")) as f:
        killed_at = int(f.read().strip())
    pre = _read_ledger(ckpt_dir)
    print(f"      killed after step {killed_at}; "
          f"ledger rounds={pre['rounds']} "
          f"uplink_bits={pre['uplink_bits']:.0f}")
    if killed_at < ROUND_EVERY:
        _fail(f"checkpoint step {killed_at} before the first round")

    # -- phase 2: resume the identical command to completion ------------
    print("[2/3] resume to completion")
    out = subprocess.run(_cmd(ckpt_dir), env=env, capture_output=True,
                         text=True, timeout=args.timeout)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        _fail(f"resumed run failed (rc={out.returncode}):\n"
              + out.stderr[-2000:])

    # -- phase 3: continuity + monotone ledger --------------------------
    print("[3/3] assert step continuity + monotone ledger")
    m = re.search(r"resumed at step (\d+)", out.stdout)
    if not m:
        _fail("resumed run did not restore the checkpoint "
              "(no 'resumed at step' line)")
    resumed = int(m.group(1))
    if resumed != killed_at:
        _fail(f"step discontinuity: killed at {killed_at}, "
              f"resumed at {resumed}")
    if not re.search(r"resumed ledger:", out.stdout):
        _fail("CommLedger sidecar was not resumed")
    if "done" not in out.stdout:
        _fail("resumed run did not reach 'done'")
    post = _read_ledger(ckpt_dir)
    for k in ("uplink_bits", "downlink_bits", "rounds"):
        if post[k] < pre[k]:
            _fail(f"ledger went BACKWARD across the crash: "
                  f"{k} {pre[k]} -> {post[k]}")
    if post["rounds"] <= pre["rounds"]:
        _fail(f"no rounds after resume ({pre['rounds']} -> "
              f"{post['rounds']})")
    expect_rounds = STEPS // ROUND_EVERY
    if post["rounds"] != expect_rounds:
        _fail(f"resumed run re-counted rounds: total {post['rounds']} "
              f"!= {expect_rounds} (double-counting a replayed round?)")
    print(f"OK: killed at step {killed_at}, resumed at {resumed}, "
          f"ledger {pre['rounds']} -> {post['rounds']} rounds "
          f"monotone ({pre['uplink_bits']:.0f} -> "
          f"{post['uplink_bits']:.0f} uplink bits)")


if __name__ == "__main__":
    main()
