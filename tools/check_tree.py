#!/usr/bin/env python
"""Validate the committed BENCH_tree.json against the static model.

The aggregator tree's headline claim — per-commit root traffic is
O(params), independent of client count — is checked without re-running
the 10^6-client fold: the static `analysis.comm_model` table is
DETERMINISTIC given (n_params, n_edges, acc_bits), so this gate
recomputes it from the baseline's own meta and requires:

  * every row's measured ledger bits == the recomputed static bits,
    EXACTLY (the bench already asserted measured == static at
    generation time; this catches a drifted cost model or a hand-edited
    baseline);
  * root bits are IDENTICAL across every client count (the O(params)
    invariant), while the flat column grows as clients x params;
  * the sweep actually spans the claim (>= 10^4 through >= 10^6
    clients) and no row's per-edge cohort overflows the packed count
    field width.

Regenerate after an intentional wire-format change:

    PYTHONPATH=src python benchmarks/tree_bench.py --json BENCH_tree.json

Usage:
    PYTHONPATH=src python tools/check_tree.py [--baseline BENCH_tree.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))
sys.path.insert(0, str(ROOT / "src"))

from _ci import finish                    # noqa: E402
from repro.analysis import comm_model     # noqa: E402


def check(doc: dict) -> list:
    errors = []
    meta = doc.get("meta", {})
    n_params = int(meta.get("n_params", 0))
    n_edges = int(meta.get("n_edges", 0))
    acc_bits = int(meta.get("acc_bits", 0))
    rows = doc.get("rows", [])
    if not (n_params and n_edges and acc_bits and rows):
        return [f"baseline incomplete: meta={meta}, {len(rows)} row(s)"]

    static_rec = comm_model.tree_root_record_bits(
        [n_params], acc_bits=acc_bits, n_classes=1, float_elems=0,
        n_metrics=0)
    if doc.get("static_record") != static_rec:
        errors.append(f"static record drift: baseline "
                      f"{doc.get('static_record')} vs recomputed "
                      f"{static_rec} — regenerate BENCH_tree.json")
    static = comm_model.tree_root_round_bits(
        [n_params], n_edges, acc_bits=acc_bits, n_classes=1,
        float_elems=0, n_metrics=0)

    roots = set()
    for r in rows:
        n = r.get("clients")
        if r.get("root_bits_measured") != static["root_bits"]:
            errors.append(
                f"clients={n}: measured {r.get('root_bits_measured')}b "
                f"!= static {static['root_bits']}b")
        if r.get("static_root_bits") != static["root_bits"]:
            errors.append(f"clients={n}: baseline static column "
                          f"{r.get('static_root_bits')} drifted from "
                          f"recomputed {static['root_bits']}")
        if r.get("flat_root_bits") != n * n_params:
            errors.append(f"clients={n}: flat column "
                          f"{r.get('flat_root_bits')} != clients x "
                          f"params = {n * n_params}")
        if r.get("clients_per_edge", 0) >= (1 << acc_bits):
            errors.append(f"clients={n}: {r['clients_per_edge']} "
                          f"clients/edge overflows acc_bits={acc_bits}")
        roots.add(r.get("root_bits_measured"))
    if len(roots) != 1:
        errors.append(f"root bits vary with client count: "
                      f"{sorted(roots)} — the O(params) claim broke")
    counts = [r.get("clients", 0) for r in rows]
    if min(counts) > 10_000 or max(counts) < 1_000_000:
        errors.append(f"sweep {sorted(counts)} does not span "
                      "10^4..10^6 clients")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(ROOT / "BENCH_tree.json"))
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        doc = json.load(f)
    errors = check(doc)
    print(f"# check_tree: {len(doc.get('rows', []))} row(s) validated "
          f"against the static model")
    return finish("check_tree", errors)


if __name__ == "__main__":
    sys.exit(main())
