"""Optimizer library tests (built from scratch — no optax offline).

Fixed seeds only; randomized sweeps live in test_optim_property.py
(skipped when hypothesis is absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as optlib


def _quad_loss(p):
    return 0.5 * jnp.sum(p["x"] ** 2) + 0.5 * jnp.sum(p["y"] ** 2)


def _run(opt, steps=200, lr_note=""):
    params = {"x": jnp.asarray([1.0, -2.0]), "y": jnp.asarray([[3.0]])}
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(_quad_loss)(params)
        upd, state = opt.update(g, state, params)
        params = optlib.apply_updates(params, upd)
    return float(_quad_loss(params))


@pytest.mark.parametrize("opt", [
    optlib.sgd(0.1),
    optlib.momentum(0.05),
    optlib.momentum(0.05, nesterov=True),
    optlib.adam(0.1),
    optlib.adamw(0.1, weight_decay=0.0),
])
def test_optimizers_converge_on_quadratic(opt):
    assert _run(opt) < 1e-3


def test_none_leaf_tolerance():
    opt = optlib.adam(0.1)
    params = {"a": jnp.ones((3,)), "b": None}
    state = opt.init(params)
    g = {"a": jnp.ones((3,)), "b": None}
    upd, state = opt.update(g, state, params)
    assert upd["b"] is None
    out = optlib.apply_updates(params, upd)
    assert out["b"] is None


def test_clip_by_global_norm():
    clip = optlib.clip_by_global_norm(1.0)
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    upd, _ = clip.update(g, (), None)
    assert abs(float(jnp.linalg.norm(upd["a"])) - 1.0) < 1e-5
    # below threshold: unchanged
    g2 = {"a": jnp.asarray([0.3, 0.4])}
    upd2, _ = clip.update(g2, (), None)
    np.testing.assert_allclose(np.asarray(upd2["a"]),
                               np.asarray(g2["a"]), rtol=1e-6)


def test_chain_composition():
    opt = optlib.chain(optlib.clip_by_global_norm(0.5), optlib.sgd(1.0))
    g = {"a": jnp.asarray([30.0, 40.0])}
    state = opt.init(g)
    upd, _ = opt.update(g, state, g)
    assert abs(float(jnp.linalg.norm(upd["a"])) - 0.5) < 1e-5


@pytest.mark.parametrize("total", [1, 10, 50, 250, 500])
def test_warmup_cosine_schedule_monotone_warmup(total):
    sched = optlib.warmup_cosine(1.0, warmup=10, total_steps=total + 10)
    vals = [float(sched(jnp.asarray(s))) for s in range(10)]
    assert all(vals[i] <= vals[i + 1] + 1e-6 for i in range(9))
    assert abs(vals[-1] - 1.0) < 0.12
    end = float(sched(jnp.asarray(total + 9)))
    assert end <= 1.0


def test_scale_by_schedule_steps_counter():
    sched = lambda step: jnp.where(step < 1, 1.0, 0.0)
    opt = optlib.scale_by_schedule(optlib.sgd, sched)
    p = {"a": jnp.ones(2)}
    st_ = opt.init(p)
    g = {"a": jnp.ones(2)}
    u1, st_ = opt.update(g, st_, p)
    u2, st_ = opt.update(g, st_, p)
    assert float(jnp.abs(u1["a"]).max()) == 1.0
    assert float(jnp.abs(u2["a"]).max()) == 0.0
