"""Fixed-seed tests for the wire codec layer: lossless round trips,
measured-vs-encoded honesty, the sub-1-Bpp acceptance criterion, and
the round engine's full two-way communication metrics.  Randomized
sweeps of the same properties live in test_codecs_property.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import codecs
from repro.core import masking, regularizer
from repro.models import cnn
from repro.data import synthetic, partition

KEY = jax.random.PRNGKey(0)

PACKED = ("bitpack", "golomb", "arithmetic")
EXACT_MEASURE = ("bitpack", "golomb", "signpack", "float32")


def _mask_payload(p=0.12, sizes=((5, 37), (501,), (64,)), floats=True,
                  seed=0):
    key = jax.random.PRNGKey(seed)
    mask, fl = {}, {}
    for i, sh in enumerate(sizes):
        mask[f"m{i}"] = (jax.random.uniform(
            jax.random.fold_in(key, i), sh) < p).astype(jnp.uint8)
        fl[f"m{i}"] = None
    mask["skip"] = None
    fl["skip"] = jnp.linspace(0.0, 1.0, 7) if floats else None
    return api.BitpackedMasks.from_masks(mask, fl)


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a, is_leaf=lambda x: x is None)
    lb = jax.tree_util.tree_leaves(b, is_leaf=lambda x: x is None)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if x is None:
            assert y is None
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("name", PACKED)
def test_mask_roundtrip_exact(name):
    payload = _mask_payload()
    codec = codecs.get_codec(name)
    msg = codec.encode(payload)
    back = codec.decode(msg)
    assert type(back) is api.BitpackedMasks
    _tree_equal(back.to_masks(), payload.to_masks())
    _tree_equal(back.floats, payload.floats)
    assert back.shapes == payload.shapes
    # the serialized words really carry everything: exact accounting
    assert msg.wire_bits == sum(w.size for w in msg.words) * 32
    assert msg.sidecar_bits == sum(w.size for w in msg.sidecar) * 32


@pytest.mark.parametrize("name", PACKED + ("signpack",))
def test_sign_roundtrip_exact(name):
    signs = {"w": jnp.where(
        jax.random.uniform(KEY, (130,)) < 0.5, 1.0, -1.0), "b": None}
    payload = api.SignVotes.from_signs(signs)
    codec = codecs.get_codec(name)
    back = codec.decode(codec.encode(payload))
    assert type(back) is api.SignVotes
    _tree_equal(back.to_signs(), payload.to_signs())


def test_float_roundtrip_exact():
    vals = {"x": jax.random.normal(KEY, (33, 3)), "y": None,
            "z": jnp.asarray([1.5], jnp.float32)}
    payload = api.FloatDeltas.from_tree(vals)
    codec = codecs.get_codec("float32")
    back = codec.decode(codec.encode(payload))
    _tree_equal(back.values, payload.values)
    assert back.bits == payload.bits


@pytest.mark.parametrize("name", PACKED)
def test_measure_matches_encode(name):
    """measure_bits is the traced twin of the real encoder's output
    size: exact for the integer-math codecs, within one word for the
    arithmetic coder (float-ulp in the log2)."""
    for p in (0.02, 0.12, 0.5, 0.9):
        payload = _mask_payload(p=p, seed=int(p * 100))
        codec = codecs.get_codec(name)
        measured = int(codec.measure_bits(payload))
        wire = codec.encode(payload).wire_bits
        if name in EXACT_MEASURE:
            assert measured == wire, (name, p)
        else:
            assert abs(measured - wire) <= 32, (name, p)


def test_codec_registry_and_defaults():
    assert set(codecs.available()) == {"bitpack", "golomb", "arithmetic",
                                       "signpack", "float32"}
    with pytest.raises(KeyError, match="bitpack"):
        codecs.get_codec("nope")
    # float codec refuses mask payloads (and vice versa) at resolve time
    from repro.api.protocol import PayloadSpec
    spec = PayloadSpec(api.BitpackedMasks, None)
    with pytest.raises(ValueError, match="float32"):
        codecs.resolve("float32", spec)
    assert codecs.resolve(None, spec).name == "arithmetic"
    fspec = PayloadSpec(api.FloatDeltas, 32.0)
    assert codecs.resolve(None, fspec).name == "float32"


def test_arithmetic_sub_1bpp_at_low_probability():
    """The acceptance criterion on a raw payload: mean mask probability
    ~0.12 -> the arithmetic coder is strictly below 1 Bpp and within
    10% of the eq. 13 entropy bound; Bitpack32 reports exactly the
    word-aligned 1 Bpp."""
    payload = _mask_payload(p=0.12, sizes=((128, 64), (96, 96), (777,)))
    n = payload.num_params()
    bound = float(payload.bpp())           # eq. 13, <= 1
    assert bound < 1.0

    arith = codecs.get_codec("arithmetic")
    meas = int(arith.measure_bits(payload))
    assert meas / n < 1.0
    assert meas / n <= 1.10 * bound
    assert meas / n >= bound               # a bound is a bound
    # the REAL encoder pays the measured size (to within one word:
    # host np.log2 vs traced jnp.log2 may differ by an ulp at a ceil
    # boundary)
    assert abs(arith.encode(payload).wire_bits - meas) <= 32

    bp = codecs.get_codec("bitpack")
    assert int(bp.measure_bits(payload)) == ((n + 31) // 32) * 32

    # golomb also wins at this sparsity
    assert int(codecs.get_codec("golomb").measure_bits(payload)) < n


# ---------------------------------------------------------------------------
# Round-engine integration: fedpm_reg at low theta really goes sub-1-Bpp
# ---------------------------------------------------------------------------


CFG = cnn.ConvConfig("c", (16, 16), (64,), n_classes=4, img_size=8)
K, H = 2, 1


@pytest.fixture(scope="module")
def setup():
    task = synthetic.make_image_task(KEY, n=96, img=8, n_classes=4,
                                     noise=0.3)
    params = cnn.init_params(KEY, CFG)
    apply_fn = lambda p, b: cnn.forward(p, CFG, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    cidx = partition.partition_iid(np.random.default_rng(0),
                                   np.asarray(task.y), K)
    data = synthetic.federated_batches(KEY, task, cidx, K, H, 8)
    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    return dict(params=params, apply_fn=apply_fn, loss_fn=loss_fn,
                data=data, sizes=sizes)


def _low_theta_state(algo, params, p=0.12):
    st = algo.init(KEY, params)
    theta = jax.tree_util.tree_map(
        lambda t: None if t is None else jnp.full_like(t, p),
        st.theta, is_leaf=lambda x: x is None)
    return st._replace(theta=theta)


def test_fedpm_reg_round_sub_1bpp_measured(setup):
    """A fedpm_reg round whose mean mask probability is ~0.12: the
    arithmetic uplink measures strictly below 1 Bpp and within 10% of
    the entropy bound; the bitpack codec on the same round reports the
    word-aligned 1 Bpp."""
    part = jnp.ones((K,), bool)
    # lr=0 keeps client scores at logit(theta): masks sample ~Bern(0.12)
    common = dict(spec=masking.MaskSpec(), local_steps=H, lr=0.0,
                  float_lr=0.0, optimizer="sgd", lam=1.0)
    algo = api.get_algorithm("fedpm_reg", setup["apply_fn"],
                             setup["loss_fn"], **common)
    st = _low_theta_state(algo, setup["params"])
    _, m = algo.round(st, setup["data"], part, setup["sizes"], KEY)
    bound = float(m["uplink_bpp"])
    meas = float(m["uplink_bpp_measured"])
    assert bound < 1.0
    assert meas < 1.0
    assert meas <= 1.10 * bound
    assert meas >= 0.90 * bound

    algo_bp = api.get_algorithm("fedpm_reg", setup["apply_fn"],
                                setup["loss_fn"], codec="bitpack",
                                **common)
    st = _low_theta_state(algo_bp, setup["params"])
    n = sum(l.size for l in jax.tree_util.tree_leaves(
        st.theta, is_leaf=lambda x: x is None) if l is not None)
    _, mb = algo_bp.round(st, setup["data"], part, setup["sizes"], KEY)
    assert float(mb["uplink_bpp_measured"]) == pytest.approx(
        (((n + 31) // 32) * 32) / n)


@pytest.mark.parametrize("name", ["fedpm_reg", "fedpm", "fedmask",
                                  "topk", "mv_signsgd", "fedavg"])
def test_round_metrics_complete_for_every_algorithm(setup, name):
    """run_round must report uplink_bpp, uplink_bpp_measured,
    uplink_bits_measured, downlink_bpp and downlink_bits for every
    registered algorithm."""
    algo = api.get_algorithm(name, setup["apply_fn"], setup["loss_fn"],
                             spec=masking.MaskSpec(), local_steps=H)
    st = algo.init(KEY, setup["params"])
    _, m = algo.round(st, setup["data"], jnp.ones((K,), bool),
                      setup["sizes"], KEY)
    for k in ("uplink_bpp", "uplink_bpp_measured",
              "uplink_bits_measured", "downlink_bpp", "downlink_bits"):
        assert k in m, (name, k)
        assert np.isfinite(float(m[k])), (name, k)
    assert float(m["uplink_bits_measured"]) > 0
    assert float(m["downlink_bits"]) > 0
    if name in ("fedpm_reg", "fedpm"):
        # the k-bit ProbBroadcast downlink (8 bits/param, word-aligned)
        assert 8.0 <= float(m["downlink_bpp"]) < 8.1
    if name == "fedavg":
        assert float(m["uplink_bpp_measured"]) == 32.0


def test_prob_broadcast_wire_and_dequantize():
    theta = {"a": jnp.asarray([[0.1, 0.5], [0.9, 0.0]]), "b": None}
    floats = {"a": None, "b": jnp.ones((3,), jnp.float32)}
    pay = api.ProbBroadcast.from_theta(theta, KEY, bits=8, floats=floats)
    assert pay.num_params() == 4
    assert pay.wire_bits() == 32            # 4 params x 8 bits
    assert pay.sidecar_bits() == 96
    back = pay.to_theta()["a"]
    assert float(jnp.max(jnp.abs(back - theta["a"]))) <= 1.0 / 255 + 1e-6
    assert float(pay.bpp()) == pytest.approx(8.0)


def test_comm_ledger_accumulates_both_directions():
    led = api.CommLedger()
    led.update({"uplink_bits_measured": 8e6, "downlink_bits": 16e6})
    led.update({"uplink_bits_measured": 8e6})
    assert led.rounds == 2
    assert led.uplink_mb == pytest.approx(2.0)
    assert led.downlink_mb == pytest.approx(2.0)
    d = led.as_dict()
    assert d["cumulative_total_mb"] == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# Degenerate-payload edge cases: zero-length streams and all-zeros /
# all-ones rows must round-trip losslessly, and the measured cost must
# match the serialized cost even at the theta extremes.
# ---------------------------------------------------------------------------


def _degenerate_payload(kind):
    n = 677  # odd on purpose: exercises the sub-word tail
    if kind == "empty":
        vals = jnp.zeros((0,), jnp.uint8)
    elif kind == "zeros":
        vals = jnp.zeros((n,), jnp.uint8)
    else:  # "ones"
        vals = jnp.ones((n,), jnp.uint8)
    return api.BitpackedMasks.from_masks({"m0": vals}, {"m0": None})


@pytest.mark.parametrize("name", ("golomb", "arithmetic"))
@pytest.mark.parametrize("kind", ("empty", "zeros", "ones"))
def test_degenerate_mask_rows_roundtrip(name, kind):
    payload = _degenerate_payload(kind)
    codec = codecs.get_codec(name)
    msg = codec.encode(payload)
    back = codec.decode(msg)
    assert type(back) is api.BitpackedMasks
    assert back.shapes == payload.shapes
    if kind != "empty":
        _tree_equal(back.to_masks(), payload.to_masks())
    else:
        assert back.num_params() == 0


@pytest.mark.parametrize("name", ("golomb", "arithmetic"))
@pytest.mark.parametrize("kind", ("empty", "zeros", "ones"))
def test_degenerate_measure_matches_wire(name, kind):
    """measure_bits (the dryrun/ledger estimate) and the serialized
    wire_bits must agree at the degenerate theta extremes: an
    optimistic estimate here would fake sub-1-Bpp results."""
    payload = _degenerate_payload(kind)
    codec = codecs.get_codec(name)
    msg = codec.encode(payload)
    measured = int(codec.measure_bits(payload))
    if name in EXACT_MEASURE:
        assert msg.wire_bits == measured
    else:
        # arithmetic: np-vs-jnp log2 may differ by an ulp near p=0/1;
        # same tolerance as test_measure_matches_encode above
        assert abs(msg.wire_bits - measured) <= 32
    # constant rows are where entropy coding wins hardest — except
    # golomb on all-ones, whose unary quotients are the worst case
    # (bounded blowup, never silent corruption)
    if kind == "empty":
        return
    if name == "arithmetic" or kind == "zeros":
        assert msg.wire_bits < 677
    else:
        assert msg.wire_bits <= 2 * 677


# ---------------------------------------------------------------------------
# packed-domain meters: measure_pooled_words == measure_pooled_bits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ("bitpack", "golomb"))
@pytest.mark.parametrize("n,p", ((1000, 0.03), (1024, 0.5), (64, 0.0),
                                 (33, 1.0), (7, 0.3), (4096, 0.001)))
def test_measure_pooled_words_matches_unpacked_meter(name, n, p):
    """The packed-domain meter the round step uses (no unpack_bits on
    the hot path) must agree bit-for-bit with the unpacked meter AND
    with the serialized wire size."""
    from repro.core import aggregation
    codec = codecs.get_codec(name)
    bits = (jax.random.uniform(jax.random.PRNGKey(n), (n,))
            < p).astype(jnp.uint8)
    pad = (-n) % 32                      # zero padding, as packed
    words = aggregation.pack_bits(
        jnp.concatenate([bits, jnp.zeros((pad,), jnp.uint8)]))
    via_words = int(codec.measure_pooled_words(words, n))
    via_bits = int(codec.measure_pooled_bits(bits))
    assert via_words == via_bits
    payload = api.BitpackedMasks.from_masks({"m": bits}, {"m": None})
    assert via_words == codec.encode(payload).wire_bits


@pytest.mark.parametrize("name", ("bitpack", "golomb"))
def test_measure_pooled_words_empty_and_vmap(name):
    from repro.core import aggregation
    codec = codecs.get_codec(name)
    assert int(codec.measure_pooled_words(
        jnp.zeros((0,), jnp.uint32), 0)) == \
        int(codec.measure_pooled_bits(jnp.zeros((0,), jnp.uint8)))
    # cohort-batched, jit-traced — the shape the round step vmaps
    n = 96
    bits = (jax.random.uniform(KEY, (4, n)) < 0.2).astype(jnp.uint8)
    words = jax.vmap(aggregation.pack_bits)(bits)
    batched = jax.jit(jax.vmap(
        lambda w: codec.measure_pooled_words(w, n)))(words)
    expect = [int(codec.measure_pooled_bits(b)) for b in bits]
    assert [int(x) for x in batched] == expect
