"""Robustness-layer tests: checkpoint atomicity/bf16/gc/error paths,
restart-deterministic fault draws, elastic partial restore, and
survivor-renormalized round aggregation (docs/DESIGN.md §5)."""
import inspect
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt
from repro.ckpt import checkpoint as ckptmod
from repro.configs import get_config
from repro.core import masking
from repro.launch import steps as steplib
from repro.models import build_model
from repro.runtime import elastic, fault

KEY = jax.random.PRNGKey(0)
SPEC = masking.MaskSpec()


# ---------------------------------------------------------------------------
# ckpt/checkpoint.py
# ---------------------------------------------------------------------------


def test_leftover_tmp_files_never_shadow_a_checkpoint(tmp_path):
    """Crash mid-write simulation: stray .tmp_* files (the atomic-write
    staging names) must not be visible as checkpoints — LATEST, restore
    and the gc all ignore them."""
    d = str(tmp_path)
    tree = {"a": jnp.arange(6.0), "b": None}
    ckpt.save_checkpoint(d, 2, tree)
    # a later save died before os.replace: garbage under the tmp names
    for name in (".tmp_step_3.npz", ".tmp_manifest.json", ".tmp_latest"):
        with open(os.path.join(d, name), "wb") as f:
            f.write(b"\x00garbage")
    assert ckpt.latest_step(d) == 2
    restored, step = ckpt.restore_checkpoint(d, tree)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(6.0))


def test_checkpoint_bf16_roundtrip(tmp_path):
    """npz can't store bf16 — the uint16-view detour must round-trip
    bit-exactly through save/restore AND load_raw."""
    import ml_dtypes
    d = str(tmp_path)
    x = jnp.asarray(np.linspace(-3, 3, 16), jnp.bfloat16)
    tree = {"w": x, "f32": jnp.ones((2,), jnp.float32)}
    ckpt.save_checkpoint(d, 1, tree)
    restored, _ = ckpt.restore_checkpoint(d, tree)
    assert np.asarray(restored["w"]).dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"]).view(np.uint16),
        np.asarray(x).view(np.uint16))
    raw, manifest = ckpt.load_raw(d)
    assert manifest["dtypes"] == {"w": "bfloat16"}
    np.testing.assert_array_equal(raw["w"].view(np.uint16),
                                  np.asarray(x).view(np.uint16))


def test_async_checkpointer_surfaces_worker_errors(tmp_path):
    """A background save that fails must raise on the NEXT save()/wait(),
    not vanish in the worker thread."""
    blocker = str(tmp_path / "not_a_dir")
    with open(blocker, "w") as f:
        f.write("file where a directory must go")
    ac = ckpt.AsyncCheckpointer(blocker, keep=2)
    ac.save(0, {"a": jnp.ones((2,))})
    with pytest.raises(OSError):
        ac.wait()
    with pytest.raises(OSError):
        ac.save(1, {"a": jnp.ones((2,))})


def test_async_checkpointer_gc_removes_manifests_too(tmp_path):
    d = str(tmp_path)
    ac = ckpt.AsyncCheckpointer(d, keep=2)
    for s in range(5):
        ac.save(s, {"a": jnp.full((3,), s)})
    ac.close()
    steps = sorted(int(f[5:-4]) for f in os.listdir(d)
                   if f.startswith("step_"))
    manifests = sorted(int(f[9:-5]) for f in os.listdir(d)
                       if f.startswith("manifest_"))
    assert steps == manifests == [3, 4]
    assert ckpt.latest_step(d) == 4
    restored, step = ckpt.restore_checkpoint(d, {"a": jnp.zeros((3,))})
    assert step == 4 and float(restored["a"][0]) == 4.0


def test_restore_raises_on_missing_and_mismatched_leaves(tmp_path):
    """The full-restore path must REFUSE structure drift loudly —
    that's the trigger for the theta-only fallback in launch/train.py."""
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, {"scores": {"w": jnp.ones((4, 3))}})
    with pytest.raises(KeyError, match="missing leaf"):
        ckpt.restore_checkpoint(d, {"scores": {"w": jnp.ones((4, 3)),
                                               "extra": jnp.ones(2)}})
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore_checkpoint(d, {"scores": {"w": jnp.ones((2, 3))}})


def test_bundle_roundtrip_atomic_and_typed(tmp_path):
    """save_bundle/load_bundle (the async engine's persistence): None
    sentinels, bf16 leaves, '/'-keys, and the JSON extra all survive;
    a bundle is only visible once its manifest landed."""
    import ml_dtypes
    p = str(tmp_path / "sub" / "bundle")
    arrays = {"state/0": np.arange(5, dtype=np.uint32),
              "state/1": None,
              "buf0/w": jnp.asarray([1.5, -2.5], jnp.bfloat16)}
    extra = {"tick": 7, "totals": {"commits": 2, "bits": 123.5}}
    assert not ckpt.bundle_exists(p)
    ckpt.save_bundle(p, arrays, extra)
    assert ckpt.bundle_exists(p)
    got, gextra = ckpt.load_bundle(p)
    assert gextra == extra
    np.testing.assert_array_equal(got["state/0"], arrays["state/0"])
    assert got["state/1"] is None
    assert got["buf0/w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        got["buf0/w"].view(np.uint16),
        np.asarray(arrays["buf0/w"]).view(np.uint16))
    # no staging files left behind
    assert not [f for f in os.listdir(tmp_path / "sub") if ".tmp" in f]


# ---------------------------------------------------------------------------
# runtime/fault.py — restart determinism
# ---------------------------------------------------------------------------


def test_fault_draws_are_pure_functions_of_seed_and_round():
    """No mutable generator: two simulators (or the same one twice)
    produce identical draws for the same (seed, round) — the property a
    coordinator restart relies on."""
    a = fault.FaultSimulator(n_clients=50, fail_prob=0.3, seed=9)
    b = fault.FaultSimulator(n_clients=50, fail_prob=0.3, seed=9)
    for r in (0, 3, 17):
        np.testing.assert_array_equal(a.sample_round(round_idx=r),
                                      b.sample_round(round_idx=r))
        np.testing.assert_array_equal(a.sample_round(round_idx=r),
                                      a.sample_round(round_idx=r))
    # cursor mode is just a default round index: resuming a fresh sim
    # at cursor=r continues the identical sequence
    seq = [a.sample_round() for _ in range(5)]
    c = fault.FaultSimulator(n_clients=50, fail_prob=0.3, seed=9,
                             cursor=3)
    np.testing.assert_array_equal(c.sample_round(), seq[3])
    np.testing.assert_array_equal(c.sample_round(), seq[4])
    # different seeds decorrelate
    d = fault.FaultSimulator(n_clients=50, fail_prob=0.3, seed=10)
    assert not np.array_equal(d.sample_round(round_idx=0),
                              b.sample_round(round_idx=0))


def test_straggler_cut_takes_only_latencies():
    """The cut is a pure deadline sort — the legacy rng parameter is
    gone (it was never used and poisoned restart determinism)."""
    params = inspect.signature(fault.StragglerPolicy.cut).parameters
    assert list(params) == ["self", "latencies"]
    pol = fault.StragglerPolicy(quorum_frac=0.5)
    lat = np.asarray([3.0, 1.0, 2.0, 4.0])
    keep = pol.cut(lat)
    np.testing.assert_array_equal(keep, [False, True, True, False])


def test_quorum_bounds_and_all_dead_rescue():
    sim = fault.FaultSimulator(n_clients=100, fail_prob=0.2, seed=1)
    pol = fault.StragglerPolicy(quorum_frac=0.7)
    alive = sim.sample_round(pol, round_idx=0)
    assert 1 <= alive.sum() <= 70
    # fail_prob=1: the server never stalls — exactly one rescue survivor
    dead = fault.FaultSimulator(n_clients=40, fail_prob=1.0, seed=2)
    for r in range(4):
        assert dead.sample_round(round_idx=r).sum() == 1


def test_pod_outages_are_correlated():
    """With per-client failures off, aliveness is constant WITHIN each
    pod (whole failure domains drop together)."""
    sim = fault.FaultSimulator(n_clients=40, fail_prob=0.0, pod_size=8,
                               pod_outage_prob=0.5, seed=3)
    saw_down = False
    for r in range(6):
        alive = sim.sample_round(round_idx=r)
        if alive.sum() == 1:
            continue  # all-dead rescue breaks within-pod uniformity
        for p in range(5):
            pod = alive[p * 8:(p + 1) * 8]
            assert pod.all() or not pod.any()
            saw_down |= not pod.any()
    assert saw_down


def test_injector_corruption_is_deterministic_and_single_bit():
    inj = fault.FaultInjector(8, seed=4, crash_prob=0.25,
                              straggler_prob=0.5, corrupt_prob=0.5)
    inj2 = fault.FaultInjector(8, seed=4, crash_prob=0.25,
                               straggler_prob=0.5, corrupt_prob=0.5)
    for r in (0, 2):
        np.testing.assert_array_equal(inj.dropped(r), inj2.dropped(r))
        np.testing.assert_array_equal(inj.delay_rounds(r),
                                      inj2.delay_rounds(r))
        for c in range(8):
            for a in range(2):
                assert inj.corrupt_attempt(r, c, a) == \
                    inj2.corrupt_attempt(r, c, a)
    words = [np.arange(10, dtype=np.uint32), np.zeros(3, np.uint32)]
    out = inj.corrupt_words(words, 0, 1, 0)
    out2 = inj2.corrupt_words(words, 0, 1, 0)
    flat = np.concatenate(words)
    oflat = np.concatenate([np.asarray(w) for w in out])
    diff = flat ^ oflat
    assert np.count_nonzero(diff) == 1
    assert bin(int(diff[diff != 0][0])).count("1") == 1
    np.testing.assert_array_equal(oflat,
                                  np.concatenate([np.asarray(w)
                                                  for w in out2]))


# ---------------------------------------------------------------------------
# runtime/elastic.py
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,s", [(32, 8), (32, 4), (7, 3), (5, 5),
                                 (100, 7)])
def test_cohort_plan_exactly_covers_all_clients(k, s):
    plan = elastic.cohort_plan(k, s)
    assert len(plan) == s
    allc = np.concatenate(plan)
    assert sorted(allc.tolist()) == list(range(k))


def test_restore_theta_only_refits_cohorts_and_resets_optimizer(
        tmp_path):
    """The structure-mismatch fallback: scores carry over (cohort axis
    refit by averaging), optimizer moments restart at zero, weights stay
    the template's (seed-regenerated), step comes from the manifest."""
    d = str(tmp_path)
    old = {"scores": {"w": np.asarray([[0., 2.], [4., 6.], [2., 4.],
                                       [2., 0.]], np.float32)},
           "floats": {"b": np.full((4, 3), 5.0, np.float32)},
           "opt_m": {"w": np.ones((4, 2), np.float32)},
           "weights": {"w": np.asarray([1.5], np.float32)},
           "step": np.asarray(40, np.int32)}
    ckpt.save_checkpoint(d, 40, old)
    like = {"scores": {"w": jnp.zeros((2, 2))},
            "floats": {"b": jnp.zeros((2, 3))},
            "opt_m": {"w": jnp.full((2, 2), 9.0)},
            "weights": {"w": jnp.asarray([7.5])},
            "step": jnp.asarray(0, jnp.int32)}
    state, step = elastic.restore_theta_only(d, like)
    assert step == 40
    # cohort mean of the old scores, broadcast onto C=2
    np.testing.assert_allclose(np.asarray(state["scores"]["w"]),
                               [[2., 3.], [2., 3.]])
    np.testing.assert_allclose(np.asarray(state["floats"]["b"]),
                               np.full((2, 3), 5.0))
    np.testing.assert_array_equal(np.asarray(state["opt_m"]["w"]),
                                  np.zeros((2, 2)))
    # weights are NOT taken from the checkpoint
    np.testing.assert_array_equal(np.asarray(state["weights"]["w"]),
                                  [7.5])
    assert int(state["step"]) == 40
    # same-shape leaves pass through bit-identically
    state2, _ = elastic.restore_theta_only(d, old)
    np.testing.assert_array_equal(state2["scores"]["w"],
                                  old["scores"]["w"])


def test_fit_cohort_rejects_incompatible_trailing_shape():
    with pytest.raises(ValueError, match="cannot fit"):
        elastic._fit_cohort(np.ones((4, 3)), np.ones((2, 5)))


# ---------------------------------------------------------------------------
# launch/steps.py — survivor-renormalized round aggregation
# ---------------------------------------------------------------------------


def _round_setup(C=4):
    cfg = get_config("internlm2-1.8b", smoke=True)
    api = build_model(cfg)
    state = steplib.init_fed_state(jax.random.PRNGKey(5), api, SPEC,
                                   C=C)
    state["scores"] = jax.tree_util.tree_map(
        lambda s: None if s is None else s
        + jax.random.normal(jax.random.PRNGKey(6), s.shape),
        state["scores"], is_leaf=lambda x: x is None)
    rs = jax.jit(steplib.make_round_step(api, steplib.StepConfig()))
    return state, rs


def test_round_step_participation_renormalizes_over_survivors():
    """The --fail-prob wire: a participation vector gates which cohorts
    the round folds. All-alive matches the legacy no-vector path; half
    participation halves the measured uplink bits (dead cohorts never
    touch the wire)."""
    state, rs = _round_setup(C=4)
    s_none, m_none = rs(state)
    s_ones, m_ones = rs(state, jnp.ones((4,), bool))
    for (_, a), (_, b) in zip(
            masking.leaves_with_paths(s_none["scores"]),
            masking.leaves_with_paths(s_ones["scores"])):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-2)  # bf16 psum rounding
    assert float(m_ones["bits_measured"]) == \
        float(m_none["bits_measured"])
    s_half, m_half = rs(state, jnp.asarray([True, True, False, False]))
    assert float(m_half["bits_measured"]) == pytest.approx(
        0.5 * float(m_none["bits_measured"]))
    assert 0.0 <= float(m_half["bpp"]) <= 1.0
    # survivors' masks only: aggregating {0,1} vs all four differs
    diff = any(
        a is not None and not np.allclose(np.asarray(a), np.asarray(b),
                                          atol=1e-4)
        for (_, a), (_, b) in zip(
            masking.leaves_with_paths(s_half["scores"]),
            masking.leaves_with_paths(s_ones["scores"])))
    assert diff


def test_round_step_single_survivor_equals_its_own_mask():
    """With one survivor the weighted mean is that cohort's mask alone —
    the all-dead rescue path must stay numerically sane."""
    state, rs = _round_setup(C=3)
    s1, m1 = rs(state, jnp.asarray([False, True, False]))
    for _, leaf in masking.leaves_with_paths(s1["scores"]):
        if leaf is None:
            continue
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert float(m1["bits_measured"]) == pytest.approx(
        float(rs(state, jnp.ones((3,), bool))[1]["bits_measured"]) / 3)


# ---------------------------------------------------------------------------
# launch/train.py — the ledger sidecar format the chaos smoke relies on
# ---------------------------------------------------------------------------


def test_comm_ledger_sidecar_roundtrip(tmp_path):
    from repro import api as fedapi
    ledger = fedapi.CommLedger()
    ledger.update({"uplink_bits_measured": 1000.0,
                   "downlink_bits": 2000.0})
    p = str(tmp_path / "comm_ledger.json")
    with open(p, "w") as f:
        json.dump({"uplink_bits": ledger.uplink_bits,
                   "downlink_bits": ledger.downlink_bits,
                   "rounds": ledger.rounds}, f)
    with open(p) as f:
        back = fedapi.CommLedger(**json.load(f))
    assert back.rounds == 1
    assert back.total_mb == ledger.total_mb
