"""Randomized property sweeps for the mask-training core.

Requires `hypothesis` (the `test` extra); the module skips cleanly when
it is absent — fixed-seed versions of the same properties live in
test_masking.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import masking, regularizer, aggregation


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 0.95))
@settings(max_examples=20, deadline=None)
def test_final_mask_rate_matches_theta(seed, p):
    key = jax.random.PRNGKey(seed % 1000)
    n = 20000
    s = jnp.full((n, 2), masking.logit(jnp.float32(p)))
    mp = masking.MaskedParams({"w_x": jnp.ones((n, 2))}, {"w_x": s},
                              {"w_x": None})
    m = masking.final_mask(mp, key)["w_x"]
    rate = float(jnp.mean(m.astype(jnp.float32)))
    assert abs(rate - p) < 0.02


@given(st.floats(0.01, 0.99))
@settings(max_examples=20, deadline=None)
def test_binary_entropy_concave_max_at_half(p):
    hp = float(regularizer.binary_entropy(jnp.float32(p)))
    hhalf = float(regularizer.binary_entropy(jnp.float32(0.5)))
    assert hp <= hhalf + 1e-6


@given(st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip(seed):
    key = jax.random.PRNGKey(seed % 997)
    m = jax.random.bernoulli(key, 0.37, (32 * 17,)).astype(jnp.uint8)
    words = aggregation.pack_bits(m)
    back = aggregation.unpack_bits(words, m.size)
    assert bool(jnp.all(back == m))


@given(st.integers(0, 10 ** 6), st.sampled_from([4, 8]))
@settings(max_examples=15, deadline=None)
def test_theta_quantization_unbiased(seed, bits):
    """Stochastic DL quantization must be unbiased and bounded."""
    key = jax.random.PRNGKey(seed % 99991)
    theta = {"w": jax.random.uniform(key, (4000,))}
    q = aggregation.quantize_theta(theta, key, bits=bits)
    dq = aggregation.dequantize_theta(q, bits=bits)["w"]
    step = 1.0 / ((1 << bits) - 1)
    assert float(jnp.max(jnp.abs(dq - theta["w"]))) <= step + 1e-6
    errs = []
    for i in range(8):
        qi = aggregation.quantize_theta(
            theta, jax.random.fold_in(key, i), bits=bits)
        errs.append(aggregation.dequantize_theta(qi, bits=bits)["w"]
                    - theta["w"])
    mean_err = float(jnp.mean(jnp.stack(errs)))
    assert abs(mean_err) < step / 4
