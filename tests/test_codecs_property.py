"""Randomized property sweeps for the wire codec layer.

For every codec x payload pair: `decode(encode(p)) == p` losslessly,
and the serialized accounting is exact —
`wire_bits == len(serialized words) * word_bits`.

Requires `hypothesis` (the `test` extra); the module skips cleanly when
it is absent — fixed-seed versions of the same properties live in
test_codecs.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import api
from repro.api import codecs

PACKED = ["bitpack", "golomb", "arithmetic"]


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a, is_leaf=lambda x: x is None)
    lb = jax.tree_util.tree_leaves(b, is_leaf=lambda x: x is None)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if x is None:
            assert y is None
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(0, 10 ** 6), st.floats(0.01, 0.99),
       st.integers(1, 400), st.sampled_from(PACKED))
@settings(max_examples=25, deadline=None)
def test_mask_codec_roundtrip_and_exact_accounting(seed, p, n1, name):
    key = jax.random.PRNGKey(seed % 99991)
    mask = {"a": (jax.random.uniform(key, (n1,)) < p).astype(jnp.uint8),
            "b": None,
            "c": (jax.random.uniform(jax.random.fold_in(key, 1),
                                     (3, 17)) < p).astype(jnp.uint8)}
    floats = {"a": None, "b": jax.random.normal(key, (5,)), "c": None}
    payload = api.BitpackedMasks.from_masks(mask, floats)
    codec = codecs.get_codec(name)

    msg = codec.encode(payload)
    back = codec.decode(msg)
    _assert_tree_equal(back.to_masks(), payload.to_masks())
    _assert_tree_equal(back.floats, payload.floats)
    assert back.shapes == payload.shapes
    assert msg.wire_bits == sum(w.size for w in msg.words) * msg.word_bits
    assert msg.sidecar_bits == sum(w.size
                                   for w in msg.sidecar) * msg.word_bits
    # traced measurement mirrors the real encoder (exactly for the
    # integer-math codecs, within one word for arithmetic)
    measured = int(codec.measure_bits(payload))
    tol = 32 if name == "arithmetic" else 0
    assert abs(measured - msg.wire_bits) <= tol


@given(st.integers(0, 10 ** 6), st.floats(0.05, 0.95),
       st.integers(1, 300), st.sampled_from(PACKED + ["signpack"]))
@settings(max_examples=20, deadline=None)
def test_sign_codec_roundtrip(seed, p, n, name):
    key = jax.random.PRNGKey(seed % 997)
    signs = {"w": jnp.where(jax.random.uniform(key, (n,)) < p,
                            1.0, -1.0)}
    payload = api.SignVotes.from_signs(signs)
    codec = codecs.get_codec(name)
    msg = codec.encode(payload)
    back = codec.decode(msg)
    assert type(back) is api.SignVotes
    _assert_tree_equal(back.to_signs(), payload.to_signs())
    assert msg.wire_bits == sum(w.size for w in msg.words) * msg.word_bits


@given(st.integers(0, 10 ** 6), st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_float_codec_roundtrip(seed, n):
    key = jax.random.PRNGKey(seed % 7919)
    vals = {"x": jax.random.normal(key, (n,)),
            "y": None,
            "z": jax.random.normal(key, (2, 3)).astype(jnp.float32)}
    payload = api.FloatDeltas.from_tree(vals)
    codec = codecs.get_codec("float32")
    msg = codec.encode(payload)
    back = codec.decode(msg)
    _assert_tree_equal(back.values, payload.values)
    assert msg.wire_bits == sum(w.size for w in msg.words) * msg.word_bits
    assert int(codec.measure_bits(payload)) == msg.wire_bits


@given(st.integers(0, 10 ** 6), st.floats(0.02, 0.3))
@settings(max_examples=10, deadline=None)
def test_entropy_coders_beat_bitpack_when_sparse(seed, p):
    """At low mask probability the entropy coders' measured rate drops
    below the bitpack 1 Bpp — the paper's operating regime."""
    key = jax.random.PRNGKey(seed % 99991)
    mask = {"m": (jax.random.uniform(key, (4096,)) < p).astype(
        jnp.uint8)}
    payload = api.BitpackedMasks.from_masks(mask)
    bp = int(codecs.get_codec("bitpack").measure_bits(payload))
    ar = int(codecs.get_codec("arithmetic").measure_bits(payload))
    go = int(codecs.get_codec("golomb").measure_bits(payload))
    assert ar < bp
    assert go < bp
