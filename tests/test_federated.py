"""Integration tests: federated rounds, baselines, fault tolerance,
checkpointing, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masking, federated, baselines, regularizer
from repro.models import cnn
from repro.data import synthetic, partition
from repro.runtime import fault, elastic
from repro import ckpt


KEY = jax.random.PRNGKey(0)
CFG = cnn.ConvConfig("t", (8, 8), (32,), n_classes=4, img_size=8)
SPEC = masking.MaskSpec()


def _setup(K=4, H=2, B=8):
    task = synthetic.make_image_task(KEY, n=256, img=8, n_classes=4,
                                     noise=0.3)
    params = cnn.init_params(KEY, CFG)
    apply_fn = lambda p, b: cnn.forward(p, CFG, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    rng = np.random.default_rng(0)
    cidx = partition.partition_iid(rng, np.asarray(task.y), K)
    data = synthetic.federated_batches(KEY, task, cidx, K, H, B)
    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    return task, params, apply_fn, loss_fn, data, sizes


def test_round_improves_loss_and_reports_bpp():
    K = 4
    task, params, apply_fn, loss_fn, data, sizes = _setup(K)
    server = federated.init_server(KEY, params, SPEC)
    cfg = federated.FedConfig(lam=1.0, local_steps=2, lr=0.1,
                              optimizer="adam")
    rf = federated.make_round_fn(apply_fn, loss_fn, cfg, K)
    part = jnp.ones((K,), bool)
    losses = []
    for r in range(4):
        kr = jax.random.PRNGKey(r)
        server, m = rf(server, data, part, sizes, kr)
        losses.append(float(m["loss"]))
        assert 0.0 <= float(m["uplink_bpp"]) <= 1.0
    assert losses[-1] < losses[0]
    assert int(server.round) == 4


def test_partial_participation_renormalizes():
    """Dropping clients must not crash or NaN the aggregate (the node-
    failure path)."""
    K = 4
    task, params, apply_fn, loss_fn, data, sizes = _setup(K)
    server = federated.init_server(KEY, params, SPEC)
    cfg = federated.FedConfig(lam=0.5, local_steps=2)
    rf = federated.make_round_fn(apply_fn, loss_fn, cfg, K)
    part = jnp.asarray([True, False, False, True])
    server, m = rf(server, data, part, sizes, KEY)
    for leaf in jax.tree_util.tree_leaves(server.theta):
        if leaf is None:
            continue
        assert bool(jnp.all(jnp.isfinite(leaf)))
        assert float(jnp.min(leaf)) >= 0 and float(jnp.max(leaf)) <= 1


def test_fault_simulator_and_straggler_policy():
    sim = fault.FaultSimulator(n_clients=100, fail_prob=0.2, seed=1)
    pol = fault.StragglerPolicy(quorum_frac=0.7)
    alive = sim.sample_round(pol)
    assert alive.dtype == bool and alive.shape == (100,)
    assert 1 <= alive.sum() <= 70
    # pod-correlated outage
    sim2 = fault.FaultSimulator(n_clients=100, fail_prob=0.0,
                                pod_size=10, pod_outage_prob=1.0, seed=2)
    assert sim2.sample_round().sum() == 1  # keeps one survivor


def test_all_baselines_run_one_round():
    K = 4
    task, params, apply_fn, loss_fn, data, sizes = _setup(K)
    part = jnp.ones((K,), bool)
    algos = [
        baselines.fedavg(apply_fn, loss_fn),
        baselines.mv_signsgd(apply_fn, loss_fn),
        baselines.topk_mask(apply_fn, loss_fn, SPEC, k_frac=0.3),
        baselines.fedmask(apply_fn, loss_fn, SPEC),
    ]
    for algo in algos:
        st = algo.init(KEY, params)
        st, m = algo.round(st, data, part, sizes, KEY)
        assert np.isfinite(float(m["loss"])), algo.name
        assert "uplink_bpp" in m
        eff = algo.eval_params(st, KEY)
        out = apply_fn(eff, {"images": task.x[:8], "labels": task.y[:8]})
        assert not bool(jnp.any(jnp.isnan(out))), algo.name
    # uplink cost ordering: fedavg (32) > binary methods (<=1)
    assert float(algos[0].round(algos[0].init(KEY, params), data, part,
                                sizes, KEY)[1]["uplink_bpp"]) == 32.0


def test_final_artifact_roundtrip(tmp_path):
    K = 2
    task, params, apply_fn, loss_fn, data, sizes = _setup(K)
    server = federated.init_server(KEY, params, SPEC)
    art = federated.final_artifact(server, KEY)
    n_mask_params = sum(int(np.prod(sh)) for _, (w, sh)
                        in art["masks"].items())
    packed_bytes = sum(w.size * 4 for _, (w, sh) in art["masks"].items())
    # the paper's claim: ~n/8 bytes instead of 4n
    assert packed_bytes <= n_mask_params // 8 + 64 * len(art["masks"])
    path = os.path.join(tmp_path, "artifact.npz")
    size = ckpt.save_artifact(path, art)
    assert size < n_mask_params  # far below 1 byte/param total
    loaded = ckpt.load_artifact(path)
    for k, (w, sh) in art["masks"].items():
        assert np.array_equal(np.asarray(w), loaded["masks"][k][0])


def test_checkpoint_save_restore_and_atomicity(tmp_path):
    task, params, apply_fn, loss_fn, data, sizes = _setup(2)
    server = federated.init_server(KEY, params, SPEC)
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 3, server._asdict())
    assert ckpt.latest_step(d) == 3
    like = jax.eval_shape(lambda: server)._asdict() if False else \
        server._asdict()
    restored, step = ckpt.restore_checkpoint(d, like)
    assert step == 3
    for (p1, l1), (p2, l2) in zip(
            masking.leaves_with_paths(server._asdict()),
            masking.leaves_with_paths(restored)):
        if l1 is None:
            assert l2 is None
            continue
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ac = ckpt.AsyncCheckpointer(d, keep=2)
    tree = {"a": jnp.arange(10), "b": None}
    for s in range(4):
        ac.save(s, tree)
    ac.close()
    assert ckpt.latest_step(d) == 3
    files = [f for f in os.listdir(d) if f.startswith("step_")]
    assert len(files) == 2  # gc kept last 2


def test_elastic_cohort_replan_and_reshard():
    plan8 = elastic.cohort_plan(32, 8)
    plan4 = elastic.cohort_plan(32, 4)
    assert sum(len(p) for p in plan8) == 32
    assert sum(len(p) for p in plan4) == 32
    # resharding: host -> single-device placement
    tree = {"x": np.ones((4, 4), np.float32), "y": None}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"x": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()), "y": None}
    out = elastic.reshard_server(tree, sh)
    assert isinstance(out["x"], jax.Array)


def test_partition_by_class_heterogeneity():
    rng = np.random.default_rng(0)
    labels = np.repeat(np.arange(10), 100)
    parts = partition.partition_by_class(rng, labels, k=30, c=2)
    assert sum(len(p) for p in parts) == len(labels)
    for p in parts[:5]:
        if len(p):
            assert len(np.unique(labels[p])) <= 2


def test_partition_dirichlet_covers_all():
    rng = np.random.default_rng(1)
    labels = np.repeat(np.arange(10), 50)
    parts = partition.partition_dirichlet(rng, labels, k=10, alpha=0.5)
    assert sum(len(p) for p in parts) == len(labels)
