"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting shapes + no NaNs; decode matches forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, ARCH_NAMES
from repro.core import masking
from repro.models import build_model
from repro.optim import optimizers as optlib


def _batch_for(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_embeds"] = 0.1 * jax.random.normal(
            key, (B, 4, cfg.d_model)).astype(jnp.bfloat16)
        batch["tokens"] = batch["tokens"][:, : S - 4]
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_shapes_no_nan(name):
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key)
    batch = _batch_for(cfg, key)
    logits, aux = api.forward(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss = api.loss((logits, aux), batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_one_train_step_reduces_grad(name):
    """One float-SGD step on the smoke config must produce finite grads
    and change the loss."""
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init_params(key)
    batch = _batch_for(cfg, key)

    def loss_fn(p):
        return api.loss(api.forward(p, batch), batch)

    l0, grads = jax.value_and_grad(loss_fn)(params)
    flat = [g for g in jax.tree_util.tree_leaves(grads)]
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    params2 = jax.tree_util.tree_map(
        lambda p, g: (p - 0.3 * g.astype(p.dtype)).astype(p.dtype),
        params, grads)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l1)) and float(l1) != float(l0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_masked_train_step(name):
    """The paper's technique applies to every arch: one STE score update."""
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = api.init_params(key)
    spec = masking.MaskSpec()
    mp = masking.init_masked(key, params, spec)
    n_masked = masking.count_params(mp.scores)
    assert n_masked > 0, "every arch must have maskable tensors"
    batch = _batch_for(cfg, key)

    def loss_fn(scores):
        eff = masking.sample_effective(
            masking.MaskedParams(mp.weights, scores, mp.floats), key)
        return api.loss(api.forward(eff, batch), batch)

    l0, g = jax.value_and_grad(loss_fn)(mp.scores)
    gl = [x for x in jax.tree_util.tree_leaves(g) if x is not None]
    assert gl and all(bool(jnp.all(jnp.isfinite(x))) for x in gl)
    assert any(float(jnp.max(jnp.abs(x))) > 0 for x in gl)


# one config per model family (dense / moe / vlm / ssm / hybrid /
# encdec) for the fused-vs-reference path equivalence sweep
FAMILY_REPS = ("internlm2-1.8b", "deepseek-v2-lite-16b", "qwen2-vl-2b",
               "mamba2-370m", "recurrentgemma-9b", "whisper-medium")


@pytest.mark.parametrize("name", FAMILY_REPS)
def test_masked_execution_matches_reference_path(name):
    """The tentpole invariant: the fused masked-execution forward
    (MaskedLeaf -> ops.masked_dense) and the materialized reference
    path (masking.hash_effective -> plain forward) sample bit-identical
    masks under the shared seed convention, so logits are bit-identical
    and score grads agree."""
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    key = jax.random.PRNGKey(5)
    params = api.init_params(key)
    mp = masking.init_masked(key, params, masking.MaskSpec())
    seed_fn = lambda i: masking.mask_stream_seed(3, 0, i, 1, run_seed=17)
    batch = _batch_for(cfg, key)

    fused = api.forward(masking.masked_forward_tree(mp, seed_fn), batch)
    eff = api.forward(masking.hash_effective(mp, seed_fn), batch)
    assert np.array_equal(np.asarray(fused[0], np.float32),
                          np.asarray(eff[0], np.float32)), \
        "fused and materialized logits diverge"

    def loss_fused(scores):
        t = masking.masked_forward_tree(
            masking.MaskedParams(mp.weights, scores, mp.floats), seed_fn)
        return api.loss(api.forward(t, batch), batch)

    def loss_eff(scores):
        e = masking.hash_effective(
            masking.MaskedParams(mp.weights, scores, mp.floats), seed_fn)
        return api.loss(api.forward(e, batch), batch)

    l1, g1 = jax.value_and_grad(loss_fused)(mp.scores)
    l2, g2 = jax.value_and_grad(loss_eff)(mp.scores)
    assert float(l1) == float(l2)
    for (path, a), (_, b) in zip(masking.leaves_with_paths(g1),
                                 masking.leaves_with_paths(g2)):
        if a is None:
            continue
        # grads differ only by bf16 rounding of the reference's x^T@g
        d = float(jnp.max(jnp.abs(a - b)))
        scale = float(jnp.max(jnp.abs(b))) + 1e-9
        assert d <= 0.05 * scale + 1e-5, (path, d, scale)


def test_masked_execution_matches_reference_path_cnn():
    """The cnn family (the paper's own Conv models): conv kernels ride
    the materializing fallback, denses the fused kernels — same stream,
    same outputs."""
    from repro.models import cnn
    cfg = cnn.ConvConfig("quick", (8, 8), (32,), n_classes=4, img_size=8)
    key = jax.random.PRNGKey(6)
    params = cnn.init_params(key, cfg)
    mp = masking.init_masked(key, params, masking.MaskSpec())
    seed_fn = lambda i: masking.mask_stream_seed(0, 0, i, 0, run_seed=9)
    images = jax.random.normal(key, (4, 8, 8, 3), jnp.float32)
    labels = jnp.asarray([0, 1, 2, 3], jnp.int32)

    y1 = cnn.forward(masking.masked_forward_tree(mp, seed_fn), cfg,
                     images)
    y2 = cnn.forward(masking.hash_effective(mp, seed_fn), cfg, images)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)

    def loss_of(build):
        def f(scores):
            t = build(masking.MaskedParams(mp.weights, scores,
                                           mp.floats), seed_fn)
            return cnn.ce_loss(cnn.forward(t, cfg, images),
                               {"labels": labels})
        return f

    l1, g1 = jax.value_and_grad(
        loss_of(masking.masked_forward_tree))(mp.scores)
    l2, g2 = jax.value_and_grad(
        loss_of(masking.hash_effective))(mp.scores)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for (path, a), (_, b) in zip(masking.leaves_with_paths(g1),
                                 masking.leaves_with_paths(g2)):
        if a is None:
            continue
        # the reference path rounds x^T@g through bf16; the fused
        # kernel keeps it f32 — bf16-level agreement is the bound
        d = float(jnp.max(jnp.abs(a - b)))
        scale = float(jnp.max(jnp.abs(b))) + 1e-9
        assert d <= 0.05 * scale + 1e-5, (path, d, scale)


@pytest.mark.parametrize("name", ["internlm2-1.8b", "mamba2-370m"])
def test_masked_execution_threshold_mode(name):
    """FedMask threshold mode through the fused kernels equals the
    materialized threshold reference."""
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    key = jax.random.PRNGKey(8)
    params = api.init_params(key)
    mp = masking.init_masked(key, params, masking.MaskSpec())
    seed_fn = lambda i: masking.mask_stream_seed(0, 0, i, 0)
    batch = _batch_for(cfg, key)
    fused = api.forward(masking.masked_forward_tree(
        mp, seed_fn, mode="threshold", tau=0.45), batch)
    eff = api.forward(masking.hash_effective(
        mp, seed_fn, mode="threshold", tau=0.45), batch)
    assert np.array_equal(np.asarray(fused[0], np.float32),
                          np.asarray(eff[0], np.float32))


def test_dynamics_params_stay_float():
    """A_log / D (ssm) and a_param (hybrid) must NOT be masked —
    Bernoulli-masking a decay rate destroys stability (docs/DESIGN.md
    §Arch-applicability)."""
    for name, frags in (("mamba2-370m", ("A_log", "/D")),
                        ("recurrentgemma-9b", ("a_param",))):
        cfg = get_config(name, smoke=True)
        api = build_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        mp = masking.init_masked(jax.random.PRNGKey(0), params,
                                 masking.MaskSpec())
        for path, leaf in masking.leaves_with_paths(mp.scores):
            for frag in frags:
                if frag.strip("/") in path.split("/")[-1]:
                    assert leaf is None, f"{name}: {path} got masked"


@pytest.mark.parametrize("name", [n for n in ARCH_NAMES
                                  if n != "qwen2-vl-2b"])
def test_decode_matches_forward(name):
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = api.init_params(key)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
        api = build_model(cfg)
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)).astype(jnp.bfloat16)
    ref_logits = api.forward(params, batch)[0]
    cache = api.init_cache(B, S)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc_out = encdec.encode(params, cfg, batch["frames"])

        def fill(lp):
            kk = (enc_out @ lp["cross"]["w_k"]).reshape(
                B, cfg.enc_seq, cfg.n_kv_heads, cfg.hd)
            vv = (enc_out @ lp["cross"]["w_v"]).reshape(
                B, cfg.enc_seq, cfg.n_kv_heads, cfg.hd)
            return kk, vv

        ck, cv = jax.vmap(fill)(params["dec_layers"])
        cache = dict(cache, ck=ck.astype(cache["ck"].dtype),
                     cv=cv.astype(cache["cv"].dtype))
    dec = jax.jit(api.decode_step)
    errs = []
    for t in range(S):
        logits, cache = dec(params, cache, tokens[:, t],
                            jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(logits - ref_logits[:, t]))))
    tol = 0.05 if cfg.family in ("hybrid",) else 0.02
    assert max(errs) < max(tol, 0.02), f"{name}: {errs}"


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    c = get_config("gemma3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab) == (34, 2560, 8, 4, 10240, 262144)
    assert c.global_every == 5 and c.sliding_window
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == \
        (60, 5120, 128, 102400)
    assert (c.n_experts, c.top_k, c.n_shared_experts,
            c.kv_lora_rank, c.moe_d_ff) == (160, 6, 2, 512, 1536)
    c = get_config("qwen2-7b")
    assert c.qkv_bias and (c.n_layers, c.d_model, c.n_heads,
                           c.n_kv_heads, c.d_ff, c.vocab) == \
        (28, 3584, 28, 4, 18944, 152064)
    c = get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == \
        (48, 1024, 128, 50280)
    c = get_config("recurrentgemma-9b")
    assert c.block_pattern == ("rec", "rec", "attn")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == \
        (38, 4096, 12288, 256000)
    c = get_config("whisper-medium")
    assert (c.enc_layers, c.n_layers, c.d_model, c.vocab) == \
        (24, 24, 1024, 51865)
    c = get_config("internlm2-1.8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == \
        (24, 2048, 16, 8)
    c = get_config("deepseek-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (30, 4096, 32, 32, 11008)
    c = get_config("deepseek-v2-lite-16b")
    assert (c.n_experts, c.top_k, c.kv_lora_rank, c.q_lora_rank) == \
        (64, 6, 512, 0)
    c = get_config("qwen2-vl-2b")
    assert c.mrope_sections == (16, 24, 24) and c.d_model == 1536
