"""Lowered-step tests on a tiny debug mesh (1 device): the production
train/round/serve steps must run end-to-end on CPU with real values."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import masking
from repro.models import build_model
from repro.launch import steps as steplib
from repro.launch import sharding as shd
from repro.launch import mesh as meshlib


SPEC = masking.MaskSpec()


def _mini(name="internlm2-1.8b"):
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    return cfg, api


def test_train_step_runs_and_reduces_loss():
    cfg, api = _mini()
    key = jax.random.PRNGKey(0)
    state = steplib.init_fed_state(key, api, SPEC, C=2)
    scfg = steplib.StepConfig(lam=0.1, lr=1.0)
    step = jax.jit(steplib.make_train_step(api, scfg))
    # learnable data: deterministic repeating sequence (uniform-random
    # tokens are at the CE optimum already). Score-SGD on a tiny signed-
    # constant net learns slowly; assert a clear but modest improvement.
    seq = (jnp.arange(16) * 3) % 7
    batch = {"tokens": jnp.broadcast_to(seq, (2, 2, 16)).astype(
        jnp.int32)}
    losses = []
    for i in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert min(losses[-5:]) < losses[0] - 0.05, losses
    assert int(state["step"]) == 30


def test_round_step_no_mesh_packed_equals_unpacked_theta():
    cfg, api = _mini()
    key = jax.random.PRNGKey(1)
    state = steplib.init_fed_state(key, api, SPEC, C=2)
    # make scores asymmetric so theta is non-trivial
    state["scores"] = jax.tree_util.tree_map(
        lambda s: None if s is None else s
        + jax.random.normal(key, s.shape),
        state["scores"], is_leaf=lambda x: x is None)
    rp = steplib.make_round_step(api, steplib.StepConfig(
        packed_masks=True))
    ru = steplib.make_round_step(api, steplib.StepConfig(
        packed_masks=False))
    sp_, mp_ = jax.jit(rp)(state)
    su_, mu_ = jax.jit(ru)(state)
    # identical mask sampling -> identical theta (packed path is lossless)
    for (pa, a), (pb, b) in zip(
            masking.leaves_with_paths(sp_["scores"]),
            masking.leaves_with_paths(su_["scores"])):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-2)  # bf16 psum rounding
    assert 0.0 <= float(mp_["bpp"]) <= 1.0


def test_round_step_resets_cohort_scores_identically():
    cfg, api = _mini()
    key = jax.random.PRNGKey(2)
    state = steplib.init_fed_state(key, api, SPEC, C=3)
    state["scores"] = jax.tree_util.tree_map(
        lambda s: None if s is None else s + jax.random.normal(
            jax.random.PRNGKey(9), s.shape),
        state["scores"], is_leaf=lambda x: x is None)
    rs = jax.jit(steplib.make_round_step(api, steplib.StepConfig()))
    s2, _ = rs(state)
    for _, leaf in masking.leaves_with_paths(s2["scores"]):
        if leaf is None:
            continue
        a = np.asarray(leaf)
        assert np.allclose(a[0], a[1]) and np.allclose(a[0], a[2])


def test_round_step_deterministic_and_step_dependent():
    """The counter-based mask streams are a pure function of
    (step, shard, leaf, cohort): re-running the round on the same state
    gives bit-identical theta; a later step samples different masks."""
    cfg, api = _mini()
    key = jax.random.PRNGKey(6)
    state = steplib.init_fed_state(key, api, SPEC, C=2)
    state["scores"] = jax.tree_util.tree_map(
        lambda s: None if s is None else s
        + jax.random.normal(key, s.shape),
        state["scores"], is_leaf=lambda x: x is None)
    rs = jax.jit(steplib.make_round_step(api, steplib.StepConfig()))
    s1, m1 = rs(state)
    s2, m2 = rs(state)
    for (_, a), (_, b) in zip(masking.leaves_with_paths(s1["scores"]),
                              masking.leaves_with_paths(s2["scores"])):
        if a is None:
            continue
        assert np.array_equal(np.asarray(a), np.asarray(b))
    later = dict(state, step=state["step"] + 5)
    s3, m3 = rs(later)
    diff = any(
        a is not None and not np.array_equal(np.asarray(a),
                                             np.asarray(b))
        for (_, a), (_, b) in zip(
            masking.leaves_with_paths(s1["scores"]),
            masking.leaves_with_paths(s3["scores"])))
    assert diff


def test_sample_and_pack_rows_kernel_matches_reference():
    """aggregation.sample_and_pack_rows: the fused-kernel and pure-jnp
    dispatches produce identical packed words (the round_step transport
    invariant)."""
    from repro.core import aggregation
    key = jax.random.PRNGKey(8)
    flat = jax.random.normal(key, (3, 500), jnp.float32)
    seeds = jnp.asarray([1, 2, 3], jnp.uint32)
    wk = aggregation.sample_and_pack_rows(flat, seeds, use_kernel=True)
    wr = aggregation.sample_and_pack_rows(flat, seeds, use_kernel=False)
    assert wk.shape == (3, (500 + 31) // 32)
    assert bool(jnp.all(wk == wr))
    # rows draw from distinct streams
    assert not bool(jnp.all(wk[0] == wk[1]))


def _scores_equal(a, b):
    return all(
        x is None or np.array_equal(np.asarray(x), np.asarray(y))
        for (_, x), (_, y) in zip(masking.leaves_with_paths(a),
                                  masking.leaves_with_paths(b)))


def test_train_step_seed_plumbed_and_deterministic():
    """StepConfig.seed feeds every mask stream (no hard-coded PRNGKey):
    equal seeds reproduce the step bit-for-bit, different seeds sample
    different masks and so take a different step."""
    cfg, api = _mini()
    key = jax.random.PRNGKey(11)
    state = steplib.init_fed_state(key, api, SPEC, C=2)
    batch = {"tokens": jnp.broadcast_to((jnp.arange(16) * 3) % 7,
                                        (2, 2, 16)).astype(jnp.int32)}
    s_a, _ = jax.jit(steplib.make_train_step(
        api, steplib.StepConfig(seed=1)))(state, batch)
    s_a2, _ = jax.jit(steplib.make_train_step(
        api, steplib.StepConfig(seed=1)))(state, batch)
    s_b, _ = jax.jit(steplib.make_train_step(
        api, steplib.StepConfig(seed=2)))(state, batch)
    assert _scores_equal(s_a["scores"], s_a2["scores"])
    assert not _scores_equal(s_a["scores"], s_b["scores"])


def test_train_step_eff_path_matches_fused(monkeypatch):
    """REPRO_EFF_PATH=1 (materialized effective params) draws the SAME
    hash-stream masks as the fused kernels: identical loss, score
    updates equal to bf16 rounding."""
    cfg, api = _mini()
    key = jax.random.PRNGKey(12)
    state = steplib.init_fed_state(key, api, SPEC, C=2)
    scfg = steplib.StepConfig(lam=0.1, lr=0.5)
    batch = {"tokens": jnp.broadcast_to((jnp.arange(16) * 5) % 11,
                                        (2, 2, 16)).astype(jnp.int32)}
    s_f, m_f = jax.jit(steplib.make_train_step(api, scfg))(state, batch)
    monkeypatch.setenv("REPRO_EFF_PATH", "1")
    s_e, m_e = jax.jit(steplib.make_train_step(api, scfg))(state, batch)
    assert float(m_f["loss"]) == float(m_e["loss"])
    for (p, a), (_, b) in zip(masking.leaves_with_paths(s_f["scores"]),
                              masking.leaves_with_paths(s_e["scores"])):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-2, err_msg=p)


def test_round_step_threshold_mode():
    """mask_mode="threshold" (the fedmask plan): the uplink packs the
    deterministic mask, so with shared scores theta IS the thresholded
    mask — and re-running is bit-identical (no sampling)."""
    cfg, api = _mini()
    key = jax.random.PRNGKey(13)
    state = steplib.init_fed_state(key, api, SPEC, C=2)
    state["scores"] = jax.tree_util.tree_map(
        lambda s: None if s is None else s
        + jax.random.normal(key, s.shape),
        state["scores"], is_leaf=lambda x: x is None)
    rs = jax.jit(steplib.make_round_step(api, steplib.StepConfig(
        mask_mode="threshold", tau=0.5)))
    s1, m1 = rs(state)
    s2, _ = rs(state)
    assert _scores_equal(s1["scores"], s2["scores"])
    # theta = mean over cohorts of the deterministic thresholded masks
    # (no sampling); new scores are logit(theta), clipped at 1e-6
    for (p, leaf), (_, s0) in zip(
            masking.leaves_with_paths(s1["scores"]),
            masking.leaves_with_paths(state["scores"])):
        if leaf is None:
            continue
        theta = jax.nn.sigmoid(np.asarray(leaf, np.float32))
        want = np.mean(
            (jax.nn.sigmoid(np.asarray(s0, np.float32)) > 0.5)
            .astype(np.float32), axis=0)
        assert np.allclose(theta, want, atol=2e-5), p
    assert 0.0 <= float(m1["bpp"]) <= 1.0


def test_fedmask_launch_plan_runs():
    """--algo fedmask resolves to a launch plan whose train step
    differentiates through the fused threshold kernels."""
    from repro import api as fedapi
    from repro.launch import plans  # noqa: F401 (registers)
    cfg, api = _mini()
    plan = fedapi.get_launch_plan("fedmask")(
        api, steplib.StepConfig(lr=0.5), key=jax.random.PRNGKey(0),
        cohorts=2)
    toks = jnp.arange(512, dtype=jnp.int32) % 7
    batch = plan.make_batch(jax.random.PRNGKey(1), toks, 2, 16)
    state, m = plan.step_fn(plan.state, batch)
    assert np.isfinite(float(m["loss"]))
    state, rm = plan.round_fn(state)
    assert 0.0 <= float(rm["bpp"]) <= 1.0


@pytest.mark.parametrize("family", ["dense", "moe", "hybrid"])
def test_train_step_jaxpr_zero_weight_temporaries(family):
    """Acceptance invariant (tier-1 twin of the benchmark gate): the
    jaxpr of a jitted make_train_step for an MXU-aligned config of
    each family — dense transformer, deepseek-style MoE (stacked
    (E, K, N) expert leaves through the GROUPED kernel), and
    recurrentgemma-style hybrid ((W, C) conv leaves through the fused
    conv kernel) — defines ZERO weight-shaped f32 values outside
    pallas_call, forward AND backward, for every masked block shape,
    while the materialized REPRO_EFF_PATH reference defines strictly
    more at every leaf shape.  Twin and bench import the SAME
    traversal from repro.analysis (no duplicated walker)."""
    from repro.analysis import model_check
    cfg, S = model_check.MODEL_CHECK_CFGS[family]
    model = model_check.model_step_weight_defs(cfg, S=S)
    assert model["block_shapes"], "no masked blocks found"
    for sh, cts in model["block_shapes"].items():
        assert cts["fused"] == 0, (family, sh, cts)
    for sh, cts in model["leaf_shapes"].items():
        assert cts["eff"] > cts["fused"], (family, sh, cts)


def test_serve_step_runs():
    cfg, api = _mini("gemma3-4b")
    key = jax.random.PRNGKey(3)
    params = api.init_params(key)
    cache = api.init_cache(2, 32)
    serve = jax.jit(steplib.make_serve_step(api))
    logits, cache2 = serve(params, cache, jnp.zeros((2,), jnp.int32),
                           jnp.asarray(5, jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_fedavg_step_runs():
    cfg, api = _mini()
    key = jax.random.PRNGKey(4)
    state = steplib.init_fedavg_state(key, api)
    scfg = steplib.StepConfig(lr=0.05)
    step = jax.jit(steplib.make_fedavg_step(api, scfg))
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    l0 = None
    for i in range(5):
        state, m = step(state, batch)
        if l0 is None:
            l0 = float(m["loss"])
    assert float(m["loss"]) < l0


def test_sharding_rules_divisibility():
    """Every assigned arch x both meshes: every param leaf gets a spec
    whose sharded dims divide evenly (the dry-run precondition)."""
    import os
    from repro.configs import ARCH_NAMES
    mesh = meshlib.make_debug_mesh(1, 1)
    for name in ARCH_NAMES:
        cfg = get_config(name, smoke=True)
        api = build_model(cfg)
        shapes = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        sh = shd.tree_param_shardings(shapes, mesh)
        leaves = jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: x is None)
        assert leaves


def test_train_step_adam_scores():
    """Adam-on-scores (the FedPM reference optimizer) in the production
    step: runs, reduces loss, round resets both moments."""
    cfg, api = _mini()
    key = jax.random.PRNGKey(7)
    state = steplib.init_fed_state(key, api, SPEC, C=2,
                                   optimizer="adam")
    assert "opt_v" in state
    scfg = steplib.StepConfig(lam=0.5, lr=0.05, optimizer="adam")
    step = jax.jit(steplib.make_train_step(api, scfg))
    rnd = jax.jit(steplib.make_round_step(api, scfg))
    seq = (jnp.arange(16) * 5) % 11
    batch = {"tokens": jnp.broadcast_to(seq, (2, 2, 16)).astype(
        jnp.int32)}
    losses = []
    for i in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    state, rm = rnd(state)
    assert 0.0 <= float(rm["bpp"]) <= 1.0
    for v in jax.tree_util.tree_leaves(state["opt_v"]):
        assert float(jnp.max(jnp.abs(v))) == 0.0  # reset at round


# ---------------------------------------------------------------------------
# the _shard_map compat shim: both homes, both kwarg spellings
# ---------------------------------------------------------------------------


def test_shard_map_shim_prefers_jax_namespace(monkeypatch):
    """When jax.shard_map exists (jax >= 0.6) the shim must use it and
    probe the kwarg name from ITS signature — here the new check_vma
    spelling."""
    seen = {}

    def fake_sm(fn, mesh=None, in_specs=None, out_specs=None,
                check_vma=True):
        seen.update(mesh=mesh, check_vma=check_vma)
        return fn

    monkeypatch.setattr(jax, "shard_map", fake_sm, raising=False)
    mesh = meshlib.make_debug_pod_mesh()
    P = jax.sharding.PartitionSpec
    out = steplib._shard_map(lambda x: x, mesh, (P(),), P())
    assert seen == {"mesh": mesh, "check_vma": False}
    assert out(3) == 3


def test_shard_map_shim_old_kwarg_spelling(monkeypatch):
    """A jax.shard_map that still spells the kwarg check_rep must get
    check_rep=False, not an unexpected-kwarg TypeError."""
    seen = {}

    def fake_sm(fn, mesh=None, in_specs=None, out_specs=None,
                check_rep=True):
        seen.update(check_rep=check_rep)
        return fn

    monkeypatch.setattr(jax, "shard_map", fake_sm, raising=False)
    mesh = meshlib.make_debug_pod_mesh()
    P = jax.sharding.PartitionSpec
    steplib._shard_map(lambda x: x, mesh, (P(),), P())
    assert seen == {"check_rep": False}


def test_shard_map_shim_experimental_home_executes():
    """Without jax.shard_map the shim resolves the experimental home —
    and the result is a REAL shard_map: collectives over the pod axis
    execute."""
    assert not hasattr(jax, "shard_map") or True  # either home is fine
    mesh = meshlib.make_debug_pod_mesh()
    P = jax.sharding.PartitionSpec
    fn = steplib._shard_map(
        lambda x: jax.lax.psum(x, "pod"), mesh, (P(),), P())
    x = jnp.arange(4.0)
    np.testing.assert_allclose(
        jax.jit(fn)(x), x * mesh.shape["pod"])
