"""Correctness tests for the §Perf hillclimb features: they must be
exact (or bf16-tolerant) drop-ins for the baselines they replace."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, layers as L
from repro.launch.dryrun import collective_bytes


def test_windowed_decode_matches_regular():
    cfg = get_config("gemma3-4b", smoke=True)
    api_ref = build_model(cfg)
    api_w = build_model(dataclasses.replace(cfg, window_kv_cache=True))
    key = jax.random.PRNGKey(5)
    params = api_ref.init_params(key)
    B, S = 2, 24  # > window(8): exercises ring wraparound
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    c1, c2 = api_ref.init_cache(B, S), api_w.init_cache(B, S)
    d1, d2 = jax.jit(api_ref.decode_step), jax.jit(api_w.decode_step)
    for t in range(S):
        l1, c1 = d1(params, c1, tokens[:, t], jnp.asarray(t, jnp.int32))
        l2, c2 = d2(params, c2, tokens[:, t], jnp.asarray(t, jnp.int32))
        assert float(jnp.max(jnp.abs(l2 - l1))) < 0.05, t


def test_windowed_cache_is_smaller():
    cfg = dataclasses.replace(get_config("gemma3-4b", smoke=True),
                              window_kv_cache=True)
    api_w = build_model(cfg)
    api_r = build_model(get_config("gemma3-4b", smoke=True))
    S = 512
    sz = lambda c: sum(x.size * x.dtype.itemsize
                       for x in jax.tree_util.tree_leaves(c))
    full = sz(jax.eval_shape(lambda: api_r.init_cache(1, S)))
    ring = sz(jax.eval_shape(lambda: api_w.init_cache(1, S)))
    assert ring < full / 3  # 5:1 local:global with window << S


def test_remat_preserves_forward_and_grads():
    cfg = get_config("internlm2-1.8b", smoke=True)
    api = build_model(cfg)
    api_r = build_model(dataclasses.replace(cfg, remat=True))
    key = jax.random.PRNGKey(0)
    params = api.init_params(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}

    def loss(p, a):
        return a.loss(a.forward(p, batch), batch)

    l1, g1 = jax.value_and_grad(lambda p: loss(p, api))(params)
    l2, g2 = jax.value_and_grad(lambda p: loss(p, api_r))(params)
    assert abs(float(l1) - float(l2)) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=1e-3)


def test_moe_block_dispatch_matches_global_when_capacity_ample():
    key = jax.random.PRNGKey(1)
    D, E, F = 32, 8, 16
    p = L.moe_init(key, D, F, E, n_shared=0)
    x = jax.random.normal(key, (4, 64, D), jnp.float32)
    y0, _ = L.moe_apply(p, x, E, 2, capacity_factor=8.0)
    yb, _ = L.moe_apply(p, x, E, 2, capacity_factor=8.0,
                        block_dispatch=8)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yb),
                               rtol=1e-4, atol=1e-4)


def test_moe_block_dispatch_smoke_grad():
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b",
                                         smoke=True),
                              moe_block_dispatch=4)
    api = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = api.init_params(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    loss, g = jax.value_and_grad(
        lambda p: api.loss(api.forward(p, batch), batch))(params)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(g))


def test_chunked_attention_matches_dense():
    key = jax.random.PRNGKey(3)
    B, S, H, Kv, Hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, Hd), jnp.float32)
    k = jax.random.normal(key, (B, S, Kv, Hd), jnp.float32)
    v = jax.random.normal(key, (B, S, Kv, Hd), jnp.float32)
    pos = jnp.arange(S)
    dense = L.attention_core(q, k, v, pos, pos, causal=True)
    chunked = L.attention_core(q, k, v, pos, pos, causal=True,
                               chunk_kv=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-4, atol=1e-4)
    # sliding window variant
    dw = L.attention_core(q, k, v, pos, pos, causal=True, window=8)
    cw = L.attention_core(q, k, v, pos, pos, causal=True, window=8,
                          chunk_kv=16)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(cw),
                               rtol=1e-4, atol=1e-4)


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[4,128]{1,0} all-gather(%x), replica_groups=[2,16]<=[32], dimensions={0}
  %ar-start = f32[256]{0} all-reduce-start(%y), replica_groups=[1,32]<=[32]
  %ar-done = f32[256]{0} all-reduce-done(%ar-start)
  %rs = u32[8]{0} reduce-scatter(%z), replica_groups=[4,8]<=[32]
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 4 * 128 * 2 // 16
    assert cb["all-reduce"] == 256 * 4          # start counted once
    assert cb["reduce-scatter"] == 8 * 4 * 8
    assert cb["total"] == sum(v for k, v in cb.items() if k != "total")
