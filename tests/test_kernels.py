"""Pallas kernel allclose sweeps vs ref.py oracles (interpret mode).

Runs without `hypothesis`: the randomized property sweep lives in
test_kernels_property.py (skipped when hypothesis is absent); the
fixed-seed cases below cover the same pack/unpack round trip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, ops
from repro.kernels.masked_matmul import (masked_matmul, masked_matmul_dx,
                                         masked_matmul_ds,
                                         sample_and_pack)
from repro.kernels.bitpack import pack_bits, unpack_bits


SHAPES = [
    (128, 512, 512),
    (256, 512, 1024),
    (128, 1024, 512),
    (384, 512, 512),    # M not multiple of block -> smaller bm
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_allclose(shape, dtype):
    M, K, N = shape
    key = jax.random.PRNGKey(M + K + N)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(dtype)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    y_kernel = masked_matmul(x, w, s, 42, bm=128, bn=512, bk=512,
                             interpret=True)
    y_ref = ref.masked_matmul(x, w, s, 42)
    np.testing.assert_allclose(
        np.asarray(y_kernel, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("seed", [0, 1, 12345])
def test_masked_matmul_seed_changes_mask(seed):
    M, K, N = 128, 512, 512
    key = jax.random.PRNGKey(0)
    x = jnp.ones((M, K), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    s = jnp.zeros((K, N), jnp.float32)  # theta = 0.5 everywhere
    y1 = masked_matmul(x, w, s, seed, interpret=True)
    y2 = masked_matmul(x, w, s, seed + 1, interpret=True)
    assert not np.allclose(np.asarray(y1), np.asarray(y2))
    # theta=0.5: each output ~ sum of K/2 ones
    assert abs(float(jnp.mean(y1)) - K / 2) < K * 0.05


def test_masked_matmul_extreme_scores():
    M, K, N = 128, 512, 512
    x = jnp.ones((M, K), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    s_on = jnp.full((K, N), 40.0)
    s_off = jnp.full((K, N), -40.0)
    y_on = masked_matmul(x, w, s_on, 7, interpret=True)
    y_off = masked_matmul(x, w, s_off, 7, interpret=True)
    assert np.allclose(np.asarray(y_on), K)
    assert np.allclose(np.asarray(y_off), 0.0)


@pytest.mark.parametrize("seed,words", [
    (0, 1), (7, 3), (123, 17), (9972, 64), (2 ** 20, 33),
])
def test_bitpack_roundtrip_fixed_seeds(seed, words):
    """Fixed-seed fallback for the hypothesis property sweep."""
    key = jax.random.PRNGKey(seed % 9973)
    n = 32 * words
    m = jax.random.bernoulli(key, 0.5, (n,)).astype(jnp.uint8)
    pk = pack_bits(m, interpret=True)
    assert bool(jnp.all(pk == ref.pack_bits(m)))
    un = unpack_bits(pk, n, interpret=True)
    assert bool(jnp.all(un == m))


@pytest.mark.parametrize("fill", [0, 1])
def test_bitpack_roundtrip_constant_masks(fill):
    n = 32 * 5
    m = jnp.full((n,), fill, jnp.uint8)
    pk = pack_bits(m, interpret=True)
    expect = jnp.uint32(0xFFFFFFFF if fill else 0)
    assert bool(jnp.all(pk == expect))
    assert bool(jnp.all(unpack_bits(pk, n, interpret=True) == m))


def test_bitpack_compression_ratio():
    m = jnp.ones((32 * 1024,), jnp.uint8)
    pk = pack_bits(m, interpret=True)
    assert pk.size * 32 == m.size
    assert pk.dtype == jnp.uint32


def test_ops_masked_dense_ste_gradients():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (32, 64), jnp.float32)
    w = jax.random.normal(key, (64, 16), jnp.float32)
    s = jnp.zeros((64, 16), jnp.float32)

    def loss(s, x):
        return jnp.sum(ops.masked_dense(x, w, s, 5) ** 2)

    gs = jax.grad(loss, argnums=0)(s, x)
    gx = jax.grad(loss, argnums=1)(s, x)
    assert gs.shape == s.shape and gx.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(gs)))
    # STE: ds includes sigmoid'(s)=0.25 factor at s=0
    assert float(jnp.max(jnp.abs(gs))) > 0


def test_ops_masked_dense_matches_ref_forward():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (8, 4, 64), jnp.float32)  # batched
    w = jax.random.normal(key, (64, 32), jnp.float32)
    s = jax.random.normal(key, (64, 32), jnp.float32)
    y = ops.masked_dense(x, w, s, 9)
    y_ref = ref.masked_matmul(x.reshape(-1, 64), w, s, 9).reshape(
        8, 4, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(128, 512, 512), (256, 512, 1024)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_dx_allclose(shape, dtype):
    M, K, N = shape
    key = jax.random.PRNGKey(M + K + N + 1)
    kg, kw, ks = jax.random.split(key, 3)
    g = jax.random.normal(kg, (M, N), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(dtype)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    dx = masked_matmul_dx(g, w, s, 42, interpret=True)
    dx_ref = ref.masked_matmul_dx(g, w, s, 42)
    np.testing.assert_allclose(
        np.asarray(dx, np.float32), np.asarray(dx_ref, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("shape", [(128, 512, 512), (256, 1024, 512)])
def test_masked_matmul_ds_allclose(shape):
    M, K, N = shape
    key = jax.random.PRNGKey(M + K + N + 2)
    kx, kg, kw, ks = jax.random.split(key, 4)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    g = jax.random.normal(kg, (M, N), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(jnp.bfloat16)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    ds = masked_matmul_ds(x, g, w, s, interpret=True)
    ds_ref = ref.masked_matmul_ds(x, g, w, s)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blocks", [(128, 128), (256, 128), (128, 256),
                                    (256, 256)])
def test_fwd_bwd_ref_masks_bit_identical_across_tilings(blocks):
    """Fixed-seed fallback for the hypothesis sweep: the forward-kernel
    mask, the dx-kernel regenerated mask, and ref.sample_mask must agree
    BIT-EXACTLY regardless of block shape.  With w = 1 and an identity
    input, the forward returns m and dx returns m^T, both exactly."""
    bk, bn = blocks
    K = N = 256
    s = jax.random.normal(jax.random.PRNGKey(11), (K, N), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    eye = jnp.eye(K, dtype=jnp.float32)
    m_fwd = masked_matmul(eye, w, s, 99, bm=128, bn=bn, bk=bk,
                          interpret=True)
    m_dx = masked_matmul_dx(jnp.eye(N, dtype=jnp.float32), w, s, 99,
                            bm=128, bn=bn, bk=bk, interpret=True)
    m_ref = ref.sample_mask(s, 99).astype(jnp.float32)
    assert np.array_equal(np.asarray(m_fwd), np.asarray(m_ref))
    assert np.array_equal(np.asarray(m_dx).T, np.asarray(m_ref))


def test_padded_launch_mask_matches_ref_bit_exact():
    """ops.masked_dense zero-pads MXU-unaligned shapes but hashes the
    LOGICAL index (n_logical), so the sampled mask must still equal
    ref.sample_mask on the original shape bit-for-bit."""
    K, N = 100, 60
    s = jax.random.normal(jax.random.PRNGKey(5), (K, N), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    m = ops.masked_dense(jnp.eye(K, dtype=jnp.float32), w, s, 31)
    m_ref = ref.sample_mask(s, 31).astype(jnp.float32)
    assert np.array_equal(np.asarray(m), np.asarray(m_ref))


@pytest.mark.parametrize("seed,C,n", [
    (0, 1, 32), (3, 2, 1000), (17, 3, 4096), (101, 2, 33),
])
def test_sample_and_pack_matches_ref(seed, C, n):
    """Fixed-seed fallback for the hypothesis sweep: the fused kernel's
    words equal the two-pass sample-then-pack oracle exactly."""
    key = jax.random.PRNGKey(seed)
    s = jax.random.normal(key, (C, n), jnp.float32)
    seeds = jnp.arange(C, dtype=jnp.uint32) * 7919 + seed
    words = sample_and_pack(s, seeds, interpret=True)
    words_ref = ref.sample_and_pack(s, seeds)
    assert words.shape == (C, (n + 31) // 32)
    assert bool(jnp.all(words == words_ref))
    # lossless round trip back to the jnp-sampled mask
    m = jax.vmap(lambda wd: ref.unpack_bits(wd, n))(words)
    assert bool(jnp.all(m == ref.sample_rows(s, seeds)))


def test_sample_and_pack_extreme_scores():
    n = 96
    s_on = jnp.full((1, n), 40.0)
    s_off = jnp.full((1, n), -40.0)
    seeds = jnp.asarray([5], jnp.uint32)
    assert bool(jnp.all(sample_and_pack(s_on, seeds, interpret=True)
                        == jnp.uint32(0xFFFFFFFF)))
    assert bool(jnp.all(sample_and_pack(s_off, seeds, interpret=True)
                        == 0))


@pytest.mark.parametrize("shape", [(32, 64, 16), (40, 100, 60),
                                   (128, 512, 512)])
def test_masked_dense_grads_match_ref_oracle(shape):
    """Fixed-seed fallback for the hypothesis sweep: jax.grad through
    the fused custom-vjp must match the naive jnp STE backward (same
    mask, same math) — including MXU-unaligned shapes via padding."""
    M, K, N = shape
    key = jax.random.PRNGKey(M + N)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)

    def loss(x, s):
        return jnp.sum(ops.masked_dense(x, w, s, 13) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_matmul(x, w, s, 13)
    dx_ref, ds_ref = ref.masked_dense_bwd(x, w, s, 13, 2.0 * y_ref)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


def test_masked_dense_offset_matches_ref_bit_exact():
    """The `off` operand shifts the flat hash index: identity-probing
    the kernel recovers ref.sample_mask(s, seed, off) bit-for-bit, on
    aligned and padded launches."""
    K, N = 100, 60
    s = jax.random.normal(jax.random.PRNGKey(5), (K, N), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    for off in (0, 12345, 3 * K * N):
        m = ops.masked_dense(jnp.eye(K, dtype=jnp.float32), w, s, 31,
                             off)
        m_ref = ref.sample_mask(s, 31, off).astype(jnp.float32)
        assert np.array_equal(np.asarray(m), np.asarray(m_ref)), off


def test_stacked_leaf_offsets_equal_uplink_stream():
    """THE shared-stream identity behind the model zoo's MaskedLeaf
    convention: per-block masks at off = l*K*N are exactly the bits
    `sample_and_pack` packs for the flat stacked leaf under one seed."""
    L, K, N = 3, 24, 56
    ss = jax.random.normal(jax.random.PRNGKey(3), (L, K, N), jnp.float32)
    words = ref.sample_and_pack(ss.reshape(1, -1),
                                jnp.asarray([31], jnp.uint32))
    flat = ref.unpack_bits(words[0], L * K * N).reshape(L, K, N)
    per = jnp.stack([ref.sample_mask(ss[l], 31, l * K * N)
                     for l in range(L)])
    assert np.array_equal(np.asarray(flat), np.asarray(per))
    # and the kernel agrees with the per-block oracle
    w = jnp.ones((K, N), jnp.float32)
    for l in range(L):
        m = ops.masked_dense(jnp.eye(K, dtype=jnp.float32), w, ss[l],
                             31, l * K * N)
        assert np.array_equal(np.asarray(m),
                              np.asarray(per[l], np.float32))


def test_masked_dense_offset_grads_match_ref():
    M, K, N = 40, 100, 60
    key = jax.random.PRNGKey(7)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)

    def loss(x, s):
        return jnp.sum(ops.masked_dense(x, w, s, 13, 777) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_matmul(x, w, s, 13, 777)
    dx_ref, ds_ref = ref.masked_dense_bwd(x, w, s, 13, 2.0 * y_ref, 777)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


def test_masked_dense_threshold_forward_and_grads():
    """FedMask mode: m = 1[sigmoid(s) > tau] through the fused kernels,
    STE backward identical in form to the Bernoulli mode's."""
    M, K, N = 40, 96, 72
    key = jax.random.PRNGKey(11)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    tau = 0.4
    eff = ref.threshold_mask(s, tau).astype(jnp.float32) * w
    y = ops.masked_dense_threshold(x, w, s, tau)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ eff),
                               rtol=1e-5, atol=1e-5)

    def loss(x, s):
        return jnp.sum(ops.masked_dense_threshold(x, w, s, tau) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    g = 2.0 * np.asarray(y)
    sig = np.asarray(jax.nn.sigmoid(s))
    np.testing.assert_allclose(np.asarray(gx), g @ np.asarray(eff).T,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(gs),
        (np.asarray(x).T @ g) * np.asarray(w) * sig * (1 - sig),
        rtol=1e-4, atol=1e-4)


def test_sample_and_pack_threshold_mode():
    s2 = jax.random.normal(jax.random.PRNGKey(5), (2, 500), jnp.float32)
    seeds = jnp.asarray([1, 2], jnp.uint32)
    wt = sample_and_pack(s2, seeds, interpret=True, mode="threshold",
                         tau=0.3)
    wr = ref.sample_and_pack(s2, seeds, mode="threshold", tau=0.3)
    assert np.array_equal(np.asarray(wt), np.asarray(wr))
    m = jax.vmap(lambda wd: ref.unpack_bits(wd, 500))(wt)
    assert np.array_equal(np.asarray(m),
                          np.asarray(ref.threshold_rows(s2, 0.3)))


def test_use_interpret_cached_and_forceable(monkeypatch):
    ops._use_interpret.cache_clear()
    try:
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        assert ops._use_interpret() is True
        # cached: changing the env after the first call has no effect
        monkeypatch.delenv("REPRO_FORCE_INTERPRET")
        assert ops._use_interpret() is True
        assert ops._use_interpret.cache_info().hits >= 1
    finally:
        ops._use_interpret.cache_clear()


def test_hash_uniform_distribution():
    idx = jnp.arange(1 << 16, dtype=jnp.uint32)
    u = ref.hash_uniform(idx, 3)
    assert 0.49 < float(jnp.mean(u)) < 0.51
    assert float(jnp.min(u)) >= 0.0 and float(jnp.max(u)) < 1.0
    # uniformity: chi-square-ish bucket check
    hist, _ = np.histogram(np.asarray(u), bins=16, range=(0, 1))
    assert hist.min() > (1 << 16) / 16 * 0.9
