"""Pallas kernel allclose sweeps vs ref.py oracles (interpret mode).

Runs without `hypothesis`: the randomized property sweep lives in
test_kernels_property.py (skipped when hypothesis is absent); the
fixed-seed cases below cover the same pack/unpack round trip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, ops
from repro.kernels.masked_matmul import (masked_matmul, masked_matmul_dx,
                                         masked_matmul_ds,
                                         masked_matmul_grouped,
                                         masked_matmul_grouped_dx,
                                         masked_matmul_grouped_ds,
                                         sample_and_pack)
from repro.kernels.bitpack import pack_bits, unpack_bits


SHAPES = [
    (128, 512, 512),
    (256, 512, 1024),
    (128, 1024, 512),
    (384, 512, 512),    # M not multiple of block -> smaller bm
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_allclose(shape, dtype):
    M, K, N = shape
    key = jax.random.PRNGKey(M + K + N)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(dtype)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    y_kernel = masked_matmul(x, w, s, 42, bm=128, bn=512, bk=512,
                             interpret=True)
    y_ref = ref.masked_matmul(x, w, s, 42)
    np.testing.assert_allclose(
        np.asarray(y_kernel, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("seed", [0, 1, 12345])
def test_masked_matmul_seed_changes_mask(seed):
    M, K, N = 128, 512, 512
    key = jax.random.PRNGKey(0)
    x = jnp.ones((M, K), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    s = jnp.zeros((K, N), jnp.float32)  # theta = 0.5 everywhere
    y1 = masked_matmul(x, w, s, seed, interpret=True)
    y2 = masked_matmul(x, w, s, seed + 1, interpret=True)
    assert not np.allclose(np.asarray(y1), np.asarray(y2))
    # theta=0.5: each output ~ sum of K/2 ones
    assert abs(float(jnp.mean(y1)) - K / 2) < K * 0.05


def test_masked_matmul_extreme_scores():
    M, K, N = 128, 512, 512
    x = jnp.ones((M, K), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    s_on = jnp.full((K, N), 40.0)
    s_off = jnp.full((K, N), -40.0)
    y_on = masked_matmul(x, w, s_on, 7, interpret=True)
    y_off = masked_matmul(x, w, s_off, 7, interpret=True)
    assert np.allclose(np.asarray(y_on), K)
    assert np.allclose(np.asarray(y_off), 0.0)


@pytest.mark.parametrize("seed,words", [
    (0, 1), (7, 3), (123, 17), (9972, 64), (2 ** 20, 33),
])
def test_bitpack_roundtrip_fixed_seeds(seed, words):
    """Fixed-seed fallback for the hypothesis property sweep."""
    key = jax.random.PRNGKey(seed % 9973)
    n = 32 * words
    m = jax.random.bernoulli(key, 0.5, (n,)).astype(jnp.uint8)
    pk = pack_bits(m, interpret=True)
    assert bool(jnp.all(pk == ref.pack_bits(m)))
    un = unpack_bits(pk, n, interpret=True)
    assert bool(jnp.all(un == m))


@pytest.mark.parametrize("fill", [0, 1])
def test_bitpack_roundtrip_constant_masks(fill):
    n = 32 * 5
    m = jnp.full((n,), fill, jnp.uint8)
    pk = pack_bits(m, interpret=True)
    expect = jnp.uint32(0xFFFFFFFF if fill else 0)
    assert bool(jnp.all(pk == expect))
    assert bool(jnp.all(unpack_bits(pk, n, interpret=True) == m))


def test_bitpack_compression_ratio():
    m = jnp.ones((32 * 1024,), jnp.uint8)
    pk = pack_bits(m, interpret=True)
    assert pk.size * 32 == m.size
    assert pk.dtype == jnp.uint32


def test_ops_masked_dense_ste_gradients():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (32, 64), jnp.float32)
    w = jax.random.normal(key, (64, 16), jnp.float32)
    s = jnp.zeros((64, 16), jnp.float32)

    def loss(s, x):
        return jnp.sum(ops.masked_dense(x, w, s, 5) ** 2)

    gs = jax.grad(loss, argnums=0)(s, x)
    gx = jax.grad(loss, argnums=1)(s, x)
    assert gs.shape == s.shape and gx.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(gs)))
    # STE: ds includes sigmoid'(s)=0.25 factor at s=0
    assert float(jnp.max(jnp.abs(gs))) > 0


def test_ops_masked_dense_matches_ref_forward():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (8, 4, 64), jnp.float32)  # batched
    w = jax.random.normal(key, (64, 32), jnp.float32)
    s = jax.random.normal(key, (64, 32), jnp.float32)
    y = ops.masked_dense(x, w, s, 9)
    y_ref = ref.masked_matmul(x.reshape(-1, 64), w, s, 9).reshape(
        8, 4, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(128, 512, 512), (256, 512, 1024)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_dx_allclose(shape, dtype):
    M, K, N = shape
    key = jax.random.PRNGKey(M + K + N + 1)
    kg, kw, ks = jax.random.split(key, 3)
    g = jax.random.normal(kg, (M, N), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(dtype)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    dx = masked_matmul_dx(g, w, s, 42, interpret=True)
    dx_ref = ref.masked_matmul_dx(g, w, s, 42)
    np.testing.assert_allclose(
        np.asarray(dx, np.float32), np.asarray(dx_ref, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("shape", [(128, 512, 512), (256, 1024, 512)])
def test_masked_matmul_ds_allclose(shape):
    M, K, N = shape
    key = jax.random.PRNGKey(M + K + N + 2)
    kx, kg, kw, ks = jax.random.split(key, 4)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    g = jax.random.normal(kg, (M, N), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(jnp.bfloat16)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    ds = masked_matmul_ds(x, g, w, s, interpret=True)
    ds_ref = ref.masked_matmul_ds(x, g, w, s)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blocks", [(128, 128), (256, 128), (128, 256),
                                    (256, 256)])
def test_fwd_bwd_ref_masks_bit_identical_across_tilings(blocks):
    """Fixed-seed fallback for the hypothesis sweep: the forward-kernel
    mask, the dx-kernel regenerated mask, and ref.sample_mask must agree
    BIT-EXACTLY regardless of block shape.  With w = 1 and an identity
    input, the forward returns m and dx returns m^T, both exactly."""
    bk, bn = blocks
    K = N = 256
    s = jax.random.normal(jax.random.PRNGKey(11), (K, N), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    eye = jnp.eye(K, dtype=jnp.float32)
    m_fwd = masked_matmul(eye, w, s, 99, bm=128, bn=bn, bk=bk,
                          interpret=True)
    m_dx = masked_matmul_dx(jnp.eye(N, dtype=jnp.float32), w, s, 99,
                            bm=128, bn=bn, bk=bk, interpret=True)
    m_ref = ref.sample_mask(s, 99).astype(jnp.float32)
    assert np.array_equal(np.asarray(m_fwd), np.asarray(m_ref))
    assert np.array_equal(np.asarray(m_dx).T, np.asarray(m_ref))


def test_padded_launch_mask_matches_ref_bit_exact():
    """ops.masked_dense zero-pads MXU-unaligned shapes but hashes the
    LOGICAL index (n_logical), so the sampled mask must still equal
    ref.sample_mask on the original shape bit-for-bit."""
    K, N = 100, 60
    s = jax.random.normal(jax.random.PRNGKey(5), (K, N), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    m = ops.masked_dense(jnp.eye(K, dtype=jnp.float32), w, s, 31)
    m_ref = ref.sample_mask(s, 31).astype(jnp.float32)
    assert np.array_equal(np.asarray(m), np.asarray(m_ref))


@pytest.mark.parametrize("seed,C,n", [
    (0, 1, 32), (3, 2, 1000), (17, 3, 4096), (101, 2, 33),
])
def test_sample_and_pack_matches_ref(seed, C, n):
    """Fixed-seed fallback for the hypothesis sweep: the fused kernel's
    words equal the two-pass sample-then-pack oracle exactly."""
    key = jax.random.PRNGKey(seed)
    s = jax.random.normal(key, (C, n), jnp.float32)
    seeds = jnp.arange(C, dtype=jnp.uint32) * 7919 + seed
    words = sample_and_pack(s, seeds, interpret=True)
    words_ref = ref.sample_and_pack(s, seeds)
    assert words.shape == (C, (n + 31) // 32)
    assert bool(jnp.all(words == words_ref))
    # lossless round trip back to the jnp-sampled mask
    m = jax.vmap(lambda wd: ref.unpack_bits(wd, n))(words)
    assert bool(jnp.all(m == ref.sample_rows(s, seeds)))


def test_sample_and_pack_extreme_scores():
    n = 96
    s_on = jnp.full((1, n), 40.0)
    s_off = jnp.full((1, n), -40.0)
    seeds = jnp.asarray([5], jnp.uint32)
    assert bool(jnp.all(sample_and_pack(s_on, seeds, interpret=True)
                        == jnp.uint32(0xFFFFFFFF)))
    assert bool(jnp.all(sample_and_pack(s_off, seeds, interpret=True)
                        == 0))


@pytest.mark.parametrize("shape", [(32, 64, 16), (40, 100, 60),
                                   (128, 512, 512)])
def test_masked_dense_grads_match_ref_oracle(shape):
    """Fixed-seed fallback for the hypothesis sweep: jax.grad through
    the fused custom-vjp must match the naive jnp STE backward (same
    mask, same math) — including MXU-unaligned shapes via padding."""
    M, K, N = shape
    key = jax.random.PRNGKey(M + N)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)

    def loss(x, s):
        return jnp.sum(ops.masked_dense(x, w, s, 13) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_matmul(x, w, s, 13)
    dx_ref, ds_ref = ref.masked_dense_bwd(x, w, s, 13, 2.0 * y_ref)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


def test_masked_dense_offset_matches_ref_bit_exact():
    """The `off` operand shifts the flat hash index: identity-probing
    the kernel recovers ref.sample_mask(s, seed, off) bit-for-bit, on
    aligned and padded launches."""
    K, N = 100, 60
    s = jax.random.normal(jax.random.PRNGKey(5), (K, N), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    for off in (0, 12345, 3 * K * N):
        m = ops.masked_dense(jnp.eye(K, dtype=jnp.float32), w, s, 31,
                             off)
        m_ref = ref.sample_mask(s, 31, off).astype(jnp.float32)
        assert np.array_equal(np.asarray(m), np.asarray(m_ref)), off


def test_stacked_leaf_offsets_equal_uplink_stream():
    """THE shared-stream identity behind the model zoo's MaskedLeaf
    convention: per-block masks at off = l*K*N are exactly the bits
    `sample_and_pack` packs for the flat stacked leaf under one seed."""
    L, K, N = 3, 24, 56
    ss = jax.random.normal(jax.random.PRNGKey(3), (L, K, N), jnp.float32)
    words = ref.sample_and_pack(ss.reshape(1, -1),
                                jnp.asarray([31], jnp.uint32))
    flat = ref.unpack_bits(words[0], L * K * N).reshape(L, K, N)
    per = jnp.stack([ref.sample_mask(ss[l], 31, l * K * N)
                     for l in range(L)])
    assert np.array_equal(np.asarray(flat), np.asarray(per))
    # and the kernel agrees with the per-block oracle
    w = jnp.ones((K, N), jnp.float32)
    for l in range(L):
        m = ops.masked_dense(jnp.eye(K, dtype=jnp.float32), w, ss[l],
                             31, l * K * N)
        assert np.array_equal(np.asarray(m),
                              np.asarray(per[l], np.float32))


def test_masked_dense_offset_grads_match_ref():
    M, K, N = 40, 100, 60
    key = jax.random.PRNGKey(7)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)

    def loss(x, s):
        return jnp.sum(ops.masked_dense(x, w, s, 13, 777) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_matmul(x, w, s, 13, 777)
    dx_ref, ds_ref = ref.masked_dense_bwd(x, w, s, 13, 2.0 * y_ref, 777)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


def test_masked_dense_threshold_forward_and_grads():
    """FedMask mode: m = 1[sigmoid(s) > tau] through the fused kernels,
    STE backward identical in form to the Bernoulli mode's."""
    M, K, N = 40, 96, 72
    key = jax.random.PRNGKey(11)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    tau = 0.4
    eff = ref.threshold_mask(s, tau).astype(jnp.float32) * w
    y = ops.masked_dense_threshold(x, w, s, tau)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ eff),
                               rtol=1e-5, atol=1e-5)

    def loss(x, s):
        return jnp.sum(ops.masked_dense_threshold(x, w, s, tau) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    g = 2.0 * np.asarray(y)
    sig = np.asarray(jax.nn.sigmoid(s))
    np.testing.assert_allclose(np.asarray(gx), g @ np.asarray(eff).T,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(gs),
        (np.asarray(x).T @ g) * np.asarray(w) * sig * (1 - sig),
        rtol=1e-4, atol=1e-4)


def test_sample_and_pack_threshold_mode():
    s2 = jax.random.normal(jax.random.PRNGKey(5), (2, 500), jnp.float32)
    seeds = jnp.asarray([1, 2], jnp.uint32)
    wt = sample_and_pack(s2, seeds, interpret=True, mode="threshold",
                         tau=0.3)
    wr = ref.sample_and_pack(s2, seeds, mode="threshold", tau=0.3)
    assert np.array_equal(np.asarray(wt), np.asarray(wr))
    m = jax.vmap(lambda wd: ref.unpack_bits(wd, 500))(wt)
    assert np.array_equal(np.asarray(m),
                          np.asarray(ref.threshold_rows(s2, 0.3)))


# ---------------------------------------------------------------------------
# Grouped kernels: stacked (E, K, N) expert leaves
# ---------------------------------------------------------------------------


def _grouped_operands(E, M, K, N, seed=7, dtype=jnp.float32):
    key = jax.random.PRNGKey(E + M + K + N)
    kx, kw, ks, kg = jax.random.split(key, 4)
    x = jax.random.normal(kx, (E, M, K), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (E, K, N), jnp.float32).astype(dtype)
    s = jax.random.normal(ks, (E, K, N), jnp.float32)
    g = jax.random.normal(kg, (E, M, N), jnp.float32).astype(dtype)
    seeds = jnp.full((E,), seed, jnp.uint32)
    offs = jnp.arange(E, dtype=jnp.uint32) * jnp.uint32(K * N)
    return x, w, s, g, seeds, offs


@pytest.mark.parametrize("shape", [(2, 128, 256, 128), (3, 128, 128, 256)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_grouped_allclose(shape, dtype):
    E, M, K, N = shape
    x, w, s, g, seeds, offs = _grouped_operands(E, M, K, N, dtype=dtype)
    y = masked_matmul_grouped(x, w, s, seeds, offs, interpret=True)
    y_ref = ref.masked_matmul_grouped(x, w, s, seeds, offs)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)
    dx = masked_matmul_grouped_dx(g, w, s, seeds, offs, interpret=True)
    dx_ref = ref.masked_matmul_grouped_dx(g, w, s, seeds, offs)
    np.testing.assert_allclose(
        np.asarray(dx, np.float32), np.asarray(dx_ref, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)
    ds = masked_matmul_grouped_ds(x, g, w, s, interpret=True)
    ds_ref = ref.masked_matmul_grouped_ds(x, g, w, s)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("blocks", [(128, 128), (256, 128), (128, 256)])
def test_grouped_masks_bit_identical_across_tilings(blocks):
    """Grouped twin of the tiling-invariance property: the forward and
    dx kernels regenerate every group's mask bit-identically to
    ref.sample_mask at that group's offset, for any block shape."""
    bk, bn = blocks
    E, K, N = 3, 256, 256
    _, _, s, _, seeds, offs = _grouped_operands(E, K, K, N)
    w1 = jnp.ones((E, K, N), jnp.float32)
    eye = jnp.broadcast_to(jnp.eye(K, dtype=jnp.float32), (E, K, K))
    m_fwd = masked_matmul_grouped(eye, w1, s, seeds, offs, bm=128,
                                  bn=bn, bk=bk, interpret=True)
    eyeN = jnp.broadcast_to(jnp.eye(N, dtype=jnp.float32), (E, N, N))
    m_dx = masked_matmul_grouped_dx(eyeN, w1, s, seeds, offs, bm=128,
                                    bn=bn, bk=bk, interpret=True)
    for e in range(E):
        m_ref = ref.sample_mask(s[e], 7, e * K * N).astype(np.float32)
        assert np.array_equal(np.asarray(m_fwd[e]), m_ref), (e, blocks)
        assert np.array_equal(np.asarray(m_dx[e]).T, m_ref), (e, blocks)


def test_grouped_offsets_equal_uplink_stream():
    """THE stacked-leaf identity for experts: under offs[e] = e*K*N and
    one seed, the E per-expert kernel masks are exactly the bits
    `sample_and_pack` packs for the flat (E*K*N,) leaf stream."""
    E, K, N = 4, 24, 56
    ss = jax.random.normal(jax.random.PRNGKey(3), (E, K, N), jnp.float32)
    words = ref.sample_and_pack(ss.reshape(1, -1),
                                jnp.asarray([31], jnp.uint32))
    flat = ref.unpack_bits(words[0], E * K * N).reshape(E, K, N)
    eye = jnp.broadcast_to(jnp.eye(K, dtype=jnp.float32), (E, K, K))
    m = ops.masked_dense_grouped(eye, jnp.ones((E, K, N), jnp.float32),
                                 ss, 31)
    assert np.array_equal(np.asarray(m), np.asarray(flat, np.float32))


@pytest.mark.parametrize("shape", [(2, 16, 64, 32), (3, 20, 100, 60)])
def test_masked_dense_grouped_grads_match_ref(shape):
    """jax.grad through the grouped custom-vjp matches the naive jnp
    grouped STE backward — including MXU-unaligned shapes via
    padding."""
    E, M, K, N = shape
    x, w, s, g, seeds, offs = _grouped_operands(E, M, K, N, seed=13)

    def loss(x, s):
        return jnp.sum(ops.masked_dense_grouped(x, w, s, 13, offs) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_matmul_grouped(x, w, s, seeds, offs)
    dx_ref, ds_ref = ref.masked_dense_grouped_bwd(x, w, s, seeds, offs,
                                                  2.0 * y_ref)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


def test_masked_dense_grouped_threshold_matches_eff():
    """Grouped FedMask mode: threshold masks through the grouped
    kernel equal the materialized threshold reference."""
    E, M, K, N = 2, 12, 40, 24
    x, w, s, _, _, _ = _grouped_operands(E, M, K, N)
    tau = 0.4
    y = ops.masked_dense_grouped_threshold(x, w, s, tau)
    eff = jax.vmap(lambda se, we: ref.threshold_mask(se, tau).astype(
        jnp.float32) * we)(s, w)
    y_ref = jnp.einsum("emk,ekn->emn", x, eff)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)

    def loss(s):
        return jnp.sum(ops.masked_dense_grouped_threshold(x, w, s, tau)
                       ** 2)

    gs = jax.grad(loss)(s)
    assert gs.shape == s.shape and bool(jnp.all(jnp.isfinite(gs)))


# ---------------------------------------------------------------------------
# Fused depthwise causal conv: the (W, C) kernel leaf
# ---------------------------------------------------------------------------


def _conv_operands(B, S, C, Wt=4, dtype=jnp.float32):
    key = jax.random.PRNGKey(B + S + C)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (B, S, C), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (Wt, C), jnp.float32).astype(dtype)
    s = jax.random.normal(ks, (Wt, C), jnp.float32)
    return x, w, s


@pytest.mark.parametrize("C", [128, 96, 70])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_conv1d_matches_ref(C, dtype):
    """The fused conv kernel equals the jnp tap-loop oracle (aligned
    and channel-padded launches; the hash stays indexed by the logical
    channel count).  Tolerance-level only: XLA may fuse the oracle's
    mul-add chain into FMAs — the BIT-level invariant of the model
    paths is kernel-vs-kernel (next test)."""
    x, w, s = _conv_operands(2, 16, C, dtype=dtype)
    y = ops.masked_conv1d(x, w, s, 31, 5)
    y_ref = ref.masked_conv1d(x, w, s, 31, 5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_masked_conv1d_equals_plain_on_materialized_weight():
    """Fused masked conv == the mask-free plain-conv kernel fed the
    materialized m⊙w — the instruction-identity that makes the fused
    and reference model paths bit-equal."""
    for dtype in DTYPES:
        x, w, s = _conv_operands(2, 12, 96, dtype=dtype)
        m = ref.sample_mask(s, 9, 77)
        weff = m.astype(w.dtype) * w
        y_fused = ops.masked_conv1d(x, w, s, 9, 77)
        y_plain = ops.conv1d_plain(x, weff)
        assert np.array_equal(np.asarray(y_fused), np.asarray(y_plain))


def test_masked_conv1d_grads_match_ref():
    x, w, s = _conv_operands(3, 10, 70)

    def loss(x, s):
        return jnp.sum(ops.masked_conv1d(x, w, s, 31, 5) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_conv1d(x, w, s, 31, 5)
    dx_ref, ds_ref = ref.masked_conv1d_bwd(x, w, s, 31, 2.0 * y_ref, 5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


def test_masked_conv1d_stream_matches_sample_and_pack():
    """The conv leaf's kernel mask is its uplink stream: identity-probe
    the (W, C) mask via extreme weights and compare against the packed
    flat stream."""
    Wt, C = 4, 56
    s = jax.random.normal(jax.random.PRNGKey(2), (Wt, C), jnp.float32)
    words = ref.sample_and_pack(s.reshape(1, -1),
                                jnp.asarray([19], jnp.uint32))
    flat = ref.unpack_bits(words[0], Wt * C).reshape(Wt, C)
    # an impulse at position t makes y[·, W-1, c] = (m ⊙ 1)[t, c]:
    # at output position W-1 the window covers x[0..W-1] tap-aligned
    x = jnp.zeros((Wt, Wt, C), jnp.float32)
    for t in range(Wt):
        x = x.at[t, t].set(1.0)
    y = ops.masked_conv1d(x, jnp.ones((Wt, C), jnp.float32), s, 19, 0)
    got = np.stack([np.asarray(y[t, Wt - 1]) for t in range(Wt)])
    assert np.array_equal(got, np.asarray(flat, np.float32))


def test_conv1d_plain_grads_match_views_einsum():
    """The plain-conv custom-vjp (float baselines) matches autodiff
    through the old stacked-views einsum formulation."""
    B, S, C, Wt = 2, 12, 40, 4
    x, w, _ = _conv_operands(B, S, C, Wt)

    def loss_k(x, w):
        return jnp.sum(ops.conv1d_plain(x, w) ** 2)

    def loss_ref(x, w):
        xp = jnp.pad(x, ((0, 0), (Wt - 1, 0), (0, 0)))
        views = jnp.stack([xp[:, i:i + S] for i in range(Wt)], axis=2)
        out = jnp.einsum("bswc,wc->bsc", views.astype(jnp.float32),
                         w.astype(jnp.float32))
        return jnp.sum(out ** 2)

    g1 = jax.grad(loss_k, argnums=(0, 1))(x, w)
    g2 = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_masked_conv1d_threshold_mode():
    x, w, s = _conv_operands(2, 8, 64)
    tau = 0.35
    y = ops.masked_conv1d_threshold(x, w, s, tau)
    weff = ref.threshold_mask(s, tau).astype(jnp.float32) * w
    y_ref = ops.conv1d_plain(x, weff)
    assert np.array_equal(np.asarray(y), np.asarray(y_ref))


def test_use_interpret_cached_and_forceable(monkeypatch,
                                            kernel_backend_reset):
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert ops._use_interpret() is True
    # cached: changing the env after the first call has no effect...
    monkeypatch.delenv("REPRO_FORCE_INTERPRET")
    assert ops._use_interpret() is True
    assert ops._use_interpret.cache_info().hits >= 1
    # ...until the public reset makes the flip take effect (on any
    # non-TPU test backend the uncached answer is interpret=True, so
    # flip via the backend probe instead)
    monkeypatch.setattr(ops, "repro_backend", lambda: "tpu")
    assert ops._use_interpret() is True      # still the stale cache
    ops.reset_backend_cache()
    assert ops._use_interpret() is False     # fresh decision


def test_hash_uniform_distribution():
    idx = jnp.arange(1 << 16, dtype=jnp.uint32)
    u = ref.hash_uniform(idx, 3)
    assert 0.49 < float(jnp.mean(u)) < 0.51
    assert float(jnp.min(u)) >= 0.0 and float(jnp.max(u)) < 1.0
    # uniformity: chi-square-ish bucket check
    hist, _ = np.histogram(np.asarray(u), bins=16, range=(0, 1))
    assert hist.min() > (1 << 16) / 16 * 0.9
