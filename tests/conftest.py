import os
import sys

import pytest

# src-layout import path (tests run without install)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def kernel_backend_reset():
    """Reset the kernels' memoized backend decision around a test that
    toggles REPRO_FORCE_INTERPRET or monkeypatches the backend probe
    (`kernels/ops.py` caches `_use_interpret` per process — a stale
    entry would leak the toggle into every later test)."""
    from repro.kernels import ops
    ops.reset_backend_cache()
    yield
    ops.reset_backend_cache()
