"""Randomized property sweeps for the serving freeze-cache.

Requires `hypothesis` (the `test` extra); the module skips cleanly
when it is absent — fixed-seed versions of the same properties live in
test_serving.py (`test_freeze_cache_exact_lru`).
"""
import collections

import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import masking


def _tiny_mp():
    key = jax.random.PRNGKey(0)
    params_like = {"w_x": jnp.zeros((16, 8)), "bias": jnp.zeros((8,))}
    return masking.init_masked(key, params_like, masking.MaskSpec())


_MP = _tiny_mp()


@given(st.integers(1, 4),
       st.lists(st.integers(0, 5), min_size=1, max_size=24))
@settings(max_examples=25, deadline=None)
def test_freeze_cache_is_exact_lru(capacity, accesses):
    """Under ARBITRARY access sequences: occupancy never exceeds
    capacity, the resident set and its recency order match an exact
    LRU oracle, hit/miss/eviction counters are exact, and a cache hit
    returns a tree bit-identical to a fresh `freeze_identity` of the
    same identity."""
    cache = masking.FreezeCache(
        lambda ident: masking.freeze_identity(_MP, ident), capacity)
    oracle = collections.OrderedDict()
    hits = misses = evictions = 0

    for seed in accesses:
        ident = masking.MaskIdentity(seed=seed)
        was_hit = ident in oracle
        tree = cache.get(ident)

        if was_hit:
            hits += 1
            oracle.move_to_end(ident)
        else:
            misses += 1
            oracle[ident] = True
            if len(oracle) > capacity:
                oracle.popitem(last=False)
                evictions += 1

        assert len(cache) <= capacity
        assert cache.keys() == list(oracle.keys()), \
            "resident set / recency order diverged from the LRU oracle"
        assert (cache.hits, cache.misses, cache.evictions) == \
            (hits, misses, evictions)

        if was_hit:
            fresh = masking.freeze_identity(_MP, ident)
            for a, b in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(fresh)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    "cache hit is not bit-identical to a fresh freeze"


@given(st.integers(0, 2 ** 16), st.sampled_from(["sample", "threshold"]))
@settings(max_examples=15, deadline=None)
def test_freeze_identity_deterministic(seed, mode):
    """freeze_identity is a pure function of (mp, identity): two
    independent builds are bit-identical (the property the cache's
    hit-equals-fresh guarantee rests on)."""
    ident = masking.MaskIdentity(seed=seed, mode=mode)
    a = masking.freeze_identity(_MP, ident)
    b = masking.freeze_identity(_MP, ident)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))
