"""Randomized property sweeps for the bitpack kernels.

Requires `hypothesis` (the `test` extra); the whole module skips
cleanly when it is absent — tier-1 coverage of the same round trip
lives in test_kernels.py as fixed-seed cases.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bitpack import pack_bits, unpack_bits


@given(st.integers(0, 2 ** 20), st.integers(1, 64))
@settings(max_examples=15, deadline=None)
def test_bitpack_roundtrip_property(seed, words):
    key = jax.random.PRNGKey(seed % 9973)
    n = 32 * words
    m = jax.random.bernoulli(key, 0.5, (n,)).astype(jnp.uint8)
    pk = pack_bits(m, interpret=True)
    assert bool(jnp.all(pk == ref.pack_bits(m)))
    un = unpack_bits(pk, n, interpret=True)
    assert bool(jnp.all(un == m))
