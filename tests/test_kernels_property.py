"""Randomized property sweeps for the Pallas kernels.

Requires `hypothesis` (the `test` extra); the whole module skips
cleanly when it is absent — tier-1 coverage of the same properties
lives in test_kernels.py as fixed-seed cases.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bitpack import pack_bits, unpack_bits
from repro.kernels.masked_matmul import (masked_matmul, masked_matmul_dx,
                                         sample_and_pack)


@given(st.integers(0, 2 ** 20), st.integers(1, 64))
@settings(max_examples=15, deadline=None)
def test_bitpack_roundtrip_property(seed, words):
    key = jax.random.PRNGKey(seed % 9973)
    n = 32 * words
    m = jax.random.bernoulli(key, 0.5, (n,)).astype(jnp.uint8)
    pk = pack_bits(m, interpret=True)
    assert bool(jnp.all(pk == ref.pack_bits(m)))
    un = unpack_bits(pk, n, interpret=True)
    assert bool(jnp.all(un == m))


@given(st.integers(0, 2 ** 20),
       st.sampled_from([128, 256]), st.sampled_from([128, 256]),
       st.sampled_from([128, 256]), st.sampled_from([128, 256]))
@settings(max_examples=10, deadline=None)
def test_masks_bit_identical_across_tilings_property(
        seed, bk_f, bn_f, bk_b, bn_b):
    """Forward-kernel mask, dx-kernel regenerated mask, and
    ref.sample_mask agree bit-exactly for ANY (seed, tiling) pair —
    the invariant the STE backward correctness rests on."""
    K = N = 256
    s = jax.random.normal(jax.random.PRNGKey(seed % 9973), (K, N),
                          jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    m_fwd = masked_matmul(jnp.eye(K, dtype=jnp.float32), w, s, seed,
                          bm=128, bn=bn_f, bk=bk_f, interpret=True)
    m_dx = masked_matmul_dx(jnp.eye(N, dtype=jnp.float32), w, s, seed,
                            bm=128, bn=bn_b, bk=bk_b, interpret=True)
    m_ref = ref.sample_mask(s, seed).astype(jnp.float32)
    assert np.array_equal(np.asarray(m_fwd), np.asarray(m_ref))
    assert np.array_equal(np.asarray(m_dx).T, np.asarray(m_ref))


@given(st.integers(0, 2 ** 16), st.integers(1, 3),
       st.integers(1, 3000))
@settings(max_examples=15, deadline=None)
def test_sample_and_pack_matches_two_pass_property(seed, C, n):
    """The fused sample+pack kernel equals sample-then-pack_bits
    exactly for any row count / length (incl. non-multiples of 32)."""
    key = jax.random.PRNGKey(seed % 9973)
    s = jax.random.normal(key, (C, n), jnp.float32)
    seeds = jnp.arange(C, dtype=jnp.uint32) * 104729 + seed
    words = sample_and_pack(s, seeds, interpret=True)
    assert bool(jnp.all(words == ref.sample_and_pack(s, seeds)))


@given(st.integers(0, 2 ** 16),
       st.sampled_from([(8, 32, 16), (40, 100, 60), (16, 130, 70)]))
@settings(max_examples=10, deadline=None)
def test_masked_dense_grad_matches_ref_property(seed, shape):
    """jax.grad through the fused custom-vjp matches the pure-jnp STE
    oracle to tolerance for arbitrary (incl. unaligned) shapes."""
    M, K, N = shape
    key = jax.random.PRNGKey(seed % 9973)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)

    def loss(x, s):
        return jnp.sum(ops.masked_dense(x, w, s, seed) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_matmul(x, w, s, seed)
    dx_ref, ds_ref = ref.masked_dense_bwd(x, w, s, seed, 2.0 * y_ref)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 16),
       st.sampled_from([128, 256]), st.sampled_from([128, 256]))
@settings(max_examples=8, deadline=None)
def test_grouped_masks_bit_identical_across_tilings_property(
        seed, bk, bn):
    """Grouped twin of the tiling-invariance property: every group's
    forward/dx kernel mask equals ref.sample_mask at that group's flat
    offset for ANY (seed, tiling) pair."""
    from repro.kernels.masked_matmul import (masked_matmul_grouped,
                                             masked_matmul_grouped_dx)
    E, K, N = 2, 256, 256
    s = jax.random.normal(jax.random.PRNGKey(seed % 9973), (E, K, N),
                          jnp.float32)
    seeds = jnp.full((E,), seed, jnp.uint32)
    offs = jnp.arange(E, dtype=jnp.uint32) * jnp.uint32(K * N)
    w1 = jnp.ones((E, K, N), jnp.float32)
    eye = jnp.broadcast_to(jnp.eye(K, dtype=jnp.float32), (E, K, K))
    m_fwd = masked_matmul_grouped(eye, w1, s, seeds, offs, bm=128,
                                  bn=bn, bk=bk, interpret=True)
    m_dx = masked_matmul_grouped_dx(eye, w1, s, seeds, offs, bm=128,
                                    bn=bn, bk=bk, interpret=True)
    for e in range(E):
        m_ref = ref.sample_mask(s[e], seed, e * K * N).astype(
            np.float32)
        assert np.array_equal(np.asarray(m_fwd[e]), m_ref)
        assert np.array_equal(np.asarray(m_dx[e]).T, m_ref)


@given(st.integers(0, 2 ** 16), st.integers(1, 4),
       st.sampled_from([(8, 24), (24, 56), (16, 130)]))
@settings(max_examples=10, deadline=None)
def test_grouped_offsets_equal_uplink_stream_property(seed, E, kn):
    """Per-expert offset identity: the E grouped-kernel masks under
    offs[e] = e*K*N are exactly the stacked leaf's flat
    `sample_and_pack` stream, for any (seed, E, K, N)."""
    K, N = kn
    s = jax.random.normal(jax.random.PRNGKey(seed % 9973), (E, K, N),
                          jnp.float32)
    words = ref.sample_and_pack(s.reshape(1, -1),
                                jnp.asarray([seed], jnp.uint32))
    flat = ref.unpack_bits(words[0], E * K * N).reshape(E, K, N)
    eye = jnp.broadcast_to(jnp.eye(K, dtype=jnp.float32), (E, K, K))
    m = ops.masked_dense_grouped(eye, jnp.ones((E, K, N), jnp.float32),
                                 s, seed)
    assert np.array_equal(np.asarray(m), np.asarray(flat, np.float32))


@given(st.integers(0, 2 ** 16), st.sampled_from([40, 70, 128]))
@settings(max_examples=10, deadline=None)
def test_masked_conv1d_equals_plain_property(seed, C):
    """The fused masked conv equals the plain-conv kernel fed the
    materialized m⊙w bit-exactly (the model-path identity), and its
    mask is the leaf's flat uplink stream."""
    Wt = 4
    key = jax.random.PRNGKey(seed % 9973)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (2, 9, C), jnp.float32)
    w = jax.random.normal(kw, (Wt, C), jnp.float32)
    s = jax.random.normal(ks, (Wt, C), jnp.float32)
    m = ref.sample_mask(s, seed, 0)
    y_fused = ops.masked_conv1d(x, w, s, seed, 0)
    y_plain = ops.conv1d_plain(x, m.astype(w.dtype) * w)
    assert np.array_equal(np.asarray(y_fused), np.asarray(y_plain))
    words = ref.sample_and_pack(s.reshape(1, -1),
                                jnp.asarray([seed], jnp.uint32))
    flat = ref.unpack_bits(words[0], Wt * C).reshape(Wt, C)
    assert np.array_equal(np.asarray(m), np.asarray(flat))
