"""Randomized property sweeps for the Pallas kernels.

Requires `hypothesis` (the `test` extra); the whole module skips
cleanly when it is absent — tier-1 coverage of the same properties
lives in test_kernels.py as fixed-seed cases.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bitpack import pack_bits, unpack_bits
from repro.kernels.masked_matmul import (masked_matmul, masked_matmul_dx,
                                         sample_and_pack)


@given(st.integers(0, 2 ** 20), st.integers(1, 64))
@settings(max_examples=15, deadline=None)
def test_bitpack_roundtrip_property(seed, words):
    key = jax.random.PRNGKey(seed % 9973)
    n = 32 * words
    m = jax.random.bernoulli(key, 0.5, (n,)).astype(jnp.uint8)
    pk = pack_bits(m, interpret=True)
    assert bool(jnp.all(pk == ref.pack_bits(m)))
    un = unpack_bits(pk, n, interpret=True)
    assert bool(jnp.all(un == m))


@given(st.integers(0, 2 ** 20),
       st.sampled_from([128, 256]), st.sampled_from([128, 256]),
       st.sampled_from([128, 256]), st.sampled_from([128, 256]))
@settings(max_examples=10, deadline=None)
def test_masks_bit_identical_across_tilings_property(
        seed, bk_f, bn_f, bk_b, bn_b):
    """Forward-kernel mask, dx-kernel regenerated mask, and
    ref.sample_mask agree bit-exactly for ANY (seed, tiling) pair —
    the invariant the STE backward correctness rests on."""
    K = N = 256
    s = jax.random.normal(jax.random.PRNGKey(seed % 9973), (K, N),
                          jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    m_fwd = masked_matmul(jnp.eye(K, dtype=jnp.float32), w, s, seed,
                          bm=128, bn=bn_f, bk=bk_f, interpret=True)
    m_dx = masked_matmul_dx(jnp.eye(N, dtype=jnp.float32), w, s, seed,
                            bm=128, bn=bn_b, bk=bk_b, interpret=True)
    m_ref = ref.sample_mask(s, seed).astype(jnp.float32)
    assert np.array_equal(np.asarray(m_fwd), np.asarray(m_ref))
    assert np.array_equal(np.asarray(m_dx).T, np.asarray(m_ref))


@given(st.integers(0, 2 ** 16), st.integers(1, 3),
       st.integers(1, 3000))
@settings(max_examples=15, deadline=None)
def test_sample_and_pack_matches_two_pass_property(seed, C, n):
    """The fused sample+pack kernel equals sample-then-pack_bits
    exactly for any row count / length (incl. non-multiples of 32)."""
    key = jax.random.PRNGKey(seed % 9973)
    s = jax.random.normal(key, (C, n), jnp.float32)
    seeds = jnp.arange(C, dtype=jnp.uint32) * 104729 + seed
    words = sample_and_pack(s, seeds, interpret=True)
    assert bool(jnp.all(words == ref.sample_and_pack(s, seeds)))


@given(st.integers(0, 2 ** 16),
       st.sampled_from([(8, 32, 16), (40, 100, 60), (16, 130, 70)]))
@settings(max_examples=10, deadline=None)
def test_masked_dense_grad_matches_ref_property(seed, shape):
    """jax.grad through the fused custom-vjp matches the pure-jnp STE
    oracle to tolerance for arbitrary (incl. unaligned) shapes."""
    M, K, N = shape
    key = jax.random.PRNGKey(seed % 9973)
    kx, kw, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    s = jax.random.normal(ks, (K, N), jnp.float32)

    def loss(x, s):
        return jnp.sum(ops.masked_dense(x, w, s, seed) ** 2)

    gx, gs = jax.grad(loss, argnums=(0, 1))(x, s)
    y_ref = ref.masked_matmul(x, w, s, seed)
    dx_ref, ds_ref = ref.masked_dense_bwd(x, w, s, seed, 2.0 * y_ref)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ds_ref),
                               rtol=1e-4, atol=1e-4)
