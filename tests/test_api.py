"""Tests for the unified `repro.api` surface: registry resolution, the
FedAlgorithm round trip for EVERY registered algorithm, and the typed
payload layer's serialized-size accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import masking, regularizer
from repro.models import cnn
from repro.data import synthetic, partition

KEY = jax.random.PRNGKey(0)
CFG = cnn.ConvConfig("t", (8, 8), (16,), n_classes=4, img_size=8)
SPEC = masking.MaskSpec()
K, H, B = 3, 2, 8


@pytest.fixture(scope="module")
def setup():
    task = synthetic.make_image_task(KEY, n=192, img=8, n_classes=4,
                                     noise=0.3)
    params = cnn.init_params(KEY, CFG)
    apply_fn = lambda p, b: cnn.forward(p, CFG, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    rng = np.random.default_rng(0)
    cidx = partition.partition_iid(rng, np.asarray(task.y), K)
    data = synthetic.federated_batches(KEY, task, cidx, K, H, B)
    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    return dict(task=task, params=params, apply_fn=apply_fn,
                loss_fn=loss_fn, data=data, sizes=sizes)


def _get(setup, name):
    return api.get_algorithm(name, setup["apply_fn"], setup["loss_fn"],
                             spec=SPEC, local_steps=H)


def test_registry_lists_all_algorithms():
    assert set(api.available()) >= {"fedpm_reg", "fedpm", "fedmask",
                                    "topk", "mv_signsgd", "fedavg"}


def test_registry_unknown_name_is_helpful():
    with pytest.raises(KeyError, match="fedpm_reg"):
        api.get_algorithm("nope", lambda *a: None, lambda *a: None)


def test_payload_specs_match_registry():
    for name in api.available():
        entry = api.get_entry(name)
        assert issubclass(entry.payload_spec.cls, api.UplinkPayload)


@pytest.mark.parametrize("name", ["fedpm_reg", "fedpm", "fedmask",
                                  "topk", "mv_signsgd", "fedavg"])
def test_full_protocol_roundtrip(setup, name):
    """init -> client_update -> aggregate -> eval_params on a tiny
    model, driven by the shared round engine."""
    algo = _get(setup, name)
    assert isinstance(algo, api.SupportsFedAlgorithm)
    st = algo.init(KEY, setup["params"])
    part = jnp.ones((K,), bool)
    st, m = algo.round(st, setup["data"], part, setup["sizes"], KEY)
    assert np.isfinite(float(m["loss"]))
    assert "uplink_bpp" in m and "sparsity" in m
    eff = algo.eval_params(st, KEY)
    out = setup["apply_fn"](eff, {"images": setup["task"].x[:8],
                                  "labels": setup["task"].y[:8]})
    assert bool(jnp.all(jnp.isfinite(out)))
    # payload type matches the spec the registry advertises
    payload, _ = algo.client_update(
        st, jax.tree_util.tree_map(lambda x: x[0], setup["data"]), KEY)
    assert type(payload) is algo.payload_spec.cls


@pytest.mark.parametrize("name", ["fedpm_reg", "fedpm", "fedmask",
                                  "topk", "mv_signsgd", "fedavg"])
def test_uplink_bpp_derives_from_payload_bits(setup, name):
    """The engine's reported uplink_bpp must equal the |D_i|-weighted
    mean of the clients' payload.bpp(), which in turn is tied to the
    payload's actual serialized bits."""
    algo = _get(setup, name)
    st = algo.init(KEY, setup["params"])
    part = jnp.ones((K,), bool)

    # replicate the engine: clients see the state AFTER the downlink
    # broadcast (quantized theta for the fedpm family).  Compute the
    # payloads BEFORE calling round — round donates `st`.
    dl, cst = api.client_view(algo, st, KEY)
    keys = jax.random.split(KEY, K)
    payloads, _ = jax.vmap(algo.client_update, in_axes=(None, 0, 0))(
        cst, setup["data"], keys)
    st2, m = algo.round(st, setup["data"], part, setup["sizes"], KEY)

    wn = setup["sizes"] / jnp.sum(setup["sizes"])
    bpps = jax.vmap(lambda p: p.bpp())(payloads)
    np.testing.assert_allclose(float(m["uplink_bpp"]),
                               float(jnp.sum(bpps * wn)), rtol=1e-5)

    # measured metrics: the codec's traced size over the same payloads
    bits = jax.vmap(algo.codec.measure_bits)(payloads)
    n = payloads.num_params()
    np.testing.assert_allclose(
        float(m["uplink_bpp_measured"]),
        float(jnp.sum(bits.astype(jnp.float32) * wn)) / n, rtol=1e-5)
    assert float(m["downlink_bpp"]) > 0.0
    assert float(m["downlink_bits"]) > 0.0

    # per-client: bpp is consistent with the serialized representation
    one = jax.tree_util.tree_map(lambda x: x[0], payloads)
    n = one.num_params()
    assert n > 0
    wire = one.wire_bits()
    if isinstance(one, api.FloatDeltas):
        assert wire == 32 * n
        assert float(one.bpp()) == 32.0
    elif isinstance(one, api.SignVotes):
        assert n <= wire < n + 32 * len(one.shapes)  # word padding only
        assert float(one.bpp()) == 1.0
    else:
        assert isinstance(one, api.BitpackedMasks)
        assert n <= wire < n + 32 * len(one.shapes)
        # entropy-coded rate of the packed bits, <= 1 and == eq. 13 on
        # the unpacked masks
        got = float(one.bpp())
        assert 0.0 <= got <= 1.0
        expect = float(regularizer.empirical_entropy(one.to_masks()))
        np.testing.assert_allclose(got, expect, rtol=1e-5)
        # serialized words really carry the mask bits
        back = one.to_masks()
        leaves = [l for l in jax.tree_util.tree_leaves(
            back, is_leaf=lambda x: x is None) if l is not None]
        assert all(l.dtype == jnp.uint8 for l in leaves)


def test_bitpacked_masks_roundtrip_exact():
    mask = {"a": (jax.random.uniform(KEY, (5, 37)) < 0.3
                  ).astype(jnp.uint8),
            "b": None,
            "c": jnp.ones((64,), jnp.uint8)}
    p = api.BitpackedMasks.from_masks(mask)
    back = p.to_masks()
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(mask["a"]))
    np.testing.assert_array_equal(np.asarray(back["c"]),
                                  np.asarray(mask["c"]))
    assert back["b"] is None
    assert p.num_params() == 5 * 37 + 64
    # wire size: word-aligned bits per leaf
    assert p.wire_bits() == 32 * ((5 * 37 + 31) // 32) + 64


def test_sign_votes_roundtrip_sign_values():
    signs = {"w": jnp.asarray([1.0, -1.0, -1.0, 1.0] * 16)}
    p = api.SignVotes.from_signs(signs)
    back = p.to_signs()
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(signs["w"]))
    assert float(p.bpp()) == 1.0


def test_mean_from_words_matches_unpacked_mean():
    key = jax.random.PRNGKey(3)
    bits = (jax.random.uniform(key, (4, 96)) < 0.4).astype(jnp.uint8)
    from repro.core import aggregation
    words = jax.vmap(aggregation.pack_bits)(bits)
    got = api.mean_from_words(words, 96)
    np.testing.assert_allclose(np.asarray(got),
                               np.mean(np.asarray(bits, np.float32), 0))
    w = jnp.asarray([0.5, 0.25, 0.25, 0.0])
    got_w = api.mean_from_words(words, 96, w)
    expect = np.tensordot(np.asarray(w),
                          np.asarray(bits, np.float32), axes=(0, 0))
    np.testing.assert_allclose(np.asarray(got_w), expect, rtol=1e-6)


def test_partial_participation_zeroes_dropped_clients(setup):
    algo = _get(setup, "fedpm_reg")
    st = algo.init(KEY, setup["params"])
    part = jnp.asarray([True, False, True])
    st, m = algo.round(st, setup["data"], part, setup["sizes"], KEY)
    for leaf in jax.tree_util.tree_leaves(st.theta):
        if leaf is None:
            continue
        assert bool(jnp.all(jnp.isfinite(leaf)))
        assert float(jnp.min(leaf)) >= 0 and float(jnp.max(leaf)) <= 1


def test_launch_plans_registered():
    from repro.launch import plans  # noqa: F401 (registers)
    assert set(api.launchable()) >= {"fedpm_reg", "fedpm", "fedmask",
                                     "fedavg"}
    with pytest.raises(KeyError, match="launch plan"):
        api.get_launch_plan("topk")
