"""Buffered-async round engine gates (repro.runtime.async_engine).

Two hard invariants from docs/DESIGN.md §5:

  * EQUIVALENCE: with zero faults and quorum_frac=1 every committed
    round is bit-identical to the synchronous barrier path
    (`protocol.run_round`) — theta AND the measured wire bits.
  * CHAOS: under crash + straggler + corrupt injection plus a
    mid-buffer coordinator kill/restore, training completes, the
    restored engine replays the identical fault sequence, and
    corrupted uplinks are excluded without aborting the round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import masking
from repro.models import cnn
from repro.data import synthetic, partition
from repro.runtime.async_engine import AsyncConfig, AsyncRoundEngine
from repro.runtime.fault import FaultInjector

KEY = jax.random.PRNGKey(0)
CFG = cnn.ConvConfig("t", (8, 8), (16,), n_classes=4, img_size=8)
SPEC = masking.MaskSpec()
K, H, B = 3, 2, 8


@pytest.fixture(scope="module")
def setup():
    task = synthetic.make_image_task(KEY, n=192, img=8, n_classes=4,
                                     noise=0.3)
    params = cnn.init_params(KEY, CFG)
    apply_fn = lambda p, b: cnn.forward(p, CFG, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    rng = np.random.default_rng(0)
    cidx = partition.partition_iid(rng, np.asarray(task.y), K)
    data = synthetic.federated_batches(KEY, task, cidx, K, H, B)
    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    algo = api.get_algorithm("fedpm_reg", apply_fn, loss_fn, spec=SPEC,
                             local_steps=H)
    return dict(algo=algo, params=params, data=data, sizes=sizes)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree, is_leaf=lambda x: x is None)


def _assert_states_equal(a, b):
    for la, lb in zip(_leaves(a), _leaves(b)):
        if la is None:
            assert lb is None
            continue
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_quorum_count_bounds():
    assert AsyncConfig(quorum_frac=0.0).quorum_count(5) == 1
    assert AsyncConfig(quorum_frac=1.0).quorum_count(5) == 5
    assert AsyncConfig(quorum_frac=0.5).quorum_count(5) == 3
    assert AsyncConfig(quorum_frac=2.0).quorum_count(5) == 5


def test_zero_faults_bit_identical_to_sync_barrier(setup):
    """The equivalence gate: no injector, quorum=1.0 — every engine
    commit must reproduce `algo.round` EXACTLY (theta, the weighted
    loss, the entropy-bound Bpp, and the measured wire bits)."""
    algo, data, sizes = setup["algo"], setup["data"], setup["sizes"]
    st_sync = algo.init(KEY, setup["params"])
    eng = AsyncRoundEngine(algo, algo.init(KEY, setup["params"]),
                           data, sizes, KEY,
                           config=AsyncConfig(quorum_frac=1.0))
    part = jnp.ones((K,), bool)
    for t in range(3):
        kt = jax.random.fold_in(KEY, t)
        st_sync, m = algo.round(st_sync, data, part, sizes, kt)
        commits = eng.tick(data)
        assert len(commits) == 1, "full buffer must commit every tick"
        c = commits[0]
        assert c["n_folded"] == K and not c["forced"]
        assert c["staleness_max"] == 0
        assert float(c["uplink_bpp"]) == float(m["uplink_bpp"])
        assert float(c["loss"]) == float(m["loss"])
        # measured WIRE bits: codec stream + sidecar, per delivery
        assert float(c["uplink_bits_measured"]) == float(
            m["uplink_bits_measured"])
        _assert_states_equal(eng.state, st_sync)
    # zero faults: no drops/cuts/corruptions ever surfaced
    kinds = {e["kind"] for e in eng.events}
    assert kinds == {"fold", "commit"}


def test_header_bits_metered_separately(setup):
    """The CRC32 header rides outside the mask stream: commits meter it
    as uplink_header_bits (32 bits per delivered message), never inside
    uplink_bits_measured — Bpp accounting keeps its codec meaning."""
    algo, data, sizes = setup["algo"], setup["data"], setup["sizes"]
    eng = AsyncRoundEngine(algo, algo.init(KEY, setup["params"]),
                           data, sizes, KEY)
    (c,) = eng.tick(data)
    assert float(c["uplink_header_bits"]) == 32.0 * K
    assert float(c["uplink_bits_measured"]) > 0


def test_stragglers_deadline_and_flush(setup):
    """All uplinks 1-2 rounds late: the deadline force-commits rather
    than starving, and flush() drains the tail without new launches."""
    algo, data, sizes = setup["algo"], setup["data"], setup["sizes"]
    inj = FaultInjector(K, seed=3, straggler_prob=1.0,
                        straggler_rounds_max=2)
    eng = AsyncRoundEngine(algo, algo.init(KEY, setup["params"]),
                           data, sizes, KEY,
                           config=AsyncConfig(quorum_frac=1.0,
                                              deadline_rounds=2),
                           injector=inj)
    commits = []
    for t in range(4):
        commits += eng.tick(data)
    commits += eng.flush()
    assert not eng.pending and not eng.buffer
    folded = sum(c["n_folded"] for c in commits)
    launched = 4 * K
    stale = sum(1 for e in eng.events if e["kind"] == "stale_drop")
    assert folded + stale == launched
    assert any(e["kind"] == "straggle" for e in eng.events)


def test_corrupt_uplinks_rejected_then_cut_without_abort(setup):
    """corrupt_prob=1: every attempt fails the checksum; after
    max_retries the client is cut. No exception, no commit from
    garbage — and the wasted attempts still count as wire bits."""
    algo, data, sizes = setup["algo"], setup["data"], setup["sizes"]
    inj = FaultInjector(K, seed=5, corrupt_prob=1.0, max_retries=1)
    eng = AsyncRoundEngine(algo, algo.init(KEY, setup["params"]),
                           data, sizes, KEY, injector=inj)
    commits = eng.tick(data) + eng.flush()
    assert commits == []
    cuts = [e for e in eng.events if e["kind"] == "cut"]
    rejects = [e for e in eng.events if e["kind"] == "corrupt_reject"]
    assert {e["client"] for e in cuts} == set(range(K))
    assert len(rejects) == K            # one retry each before the cut
    assert all(e["attempts"] == 2 for e in cuts)
    # both failed attempts consumed the wire
    assert eng.totals["uplink_bits_measured"] > 0
    assert eng.totals["commits"] == 0


def _chaos_engine(setup, state):
    inj = FaultInjector(K, seed=7, crash_prob=0.3, straggler_prob=0.3,
                        corrupt_prob=0.4, max_retries=1)
    return AsyncRoundEngine(
        setup["algo"], state, setup["data"], setup["sizes"], KEY,
        config=AsyncConfig(quorum_frac=0.8, deadline_rounds=2,
                           max_staleness=3),
        injector=inj)


def test_chaos_crash_restore_replays_identical_run(setup, tmp_path):
    """The chaos gate: crash+straggler+corrupt injection, coordinator
    killed MID-BUFFER and restored into a fresh engine — the continued
    run must match an unkilled twin event-for-event and bit-for-bit
    (fault draws are counter hashes; the bundle carries the cursor)."""
    data = setup["data"]
    ref = _chaos_engine(setup, setup["algo"].init(KEY, setup["params"]))
    eng = _chaos_engine(setup, setup["algo"].init(KEY, setup["params"]))
    for t in range(3):
        ref.tick(data)
        eng.tick(data)
    # kill mid-buffer: persist, throw the engine away, restore fresh
    assert eng.buffer or eng.pending, "chaos seed must leave work"
    path = str(tmp_path / "engine")
    eng.save(path)
    eng2 = _chaos_engine(setup,
                         setup["algo"].init(KEY, setup["params"]))
    eng2.restore(path)
    assert eng2.tick_idx == ref.tick_idx
    _assert_states_equal(eng2.state, ref.state)
    ref_commits, new_commits = [], []
    for t in range(3):
        ref_commits += ref.tick(data)
        new_commits += eng2.tick(data)
    ref_commits += ref.flush()
    new_commits += eng2.flush()
    # identical replayed fault sequence and commit schedule
    assert eng2.events == ref.events
    assert len(new_commits) == len(ref_commits) >= 1
    for a, b in zip(new_commits, ref_commits):
        assert a["clients"] == b["clients"]
        assert a["tick"] == b["tick"]
        assert float(a["uplink_bpp"]) == float(b["uplink_bpp"])
    _assert_states_equal(eng2.state, ref.state)
    assert eng2.totals == ref.totals
    # the run actually saw chaos, and survived it
    kinds = {e["kind"] for e in eng2.events}
    assert "drop" in kinds and "corrupt_reject" in kinds
    assert eng2.totals["commits"] >= 1


def test_save_restore_roundtrip_is_byte_identical(setup, tmp_path):
    """restore() must rebuild EVERY field save() wrote: state leaves,
    buffered payloads, in-flight WireMessages (words, sidecar, stamped
    checksum), counters and totals."""
    data = setup["data"]
    eng = _chaos_engine(setup, setup["algo"].init(KEY, setup["params"]))
    for t in range(3):
        eng.tick(data)
    path = str(tmp_path / "rt")
    eng.save(path)
    eng2 = _chaos_engine(setup,
                         setup["algo"].init(KEY, setup["params"]))
    eng2.restore(path)
    _assert_states_equal(eng2.state, eng.state)
    assert eng2.buffer_ones == eng.buffer_ones
    assert eng2.totals == eng.totals
    assert eng2._since_commit == eng._since_commit
    assert len(eng2.buffer) == len(eng.buffer)
    for a, b in zip(eng2.buffer, eng.buffer):
        assert (a.client, a.version, a.round, a.size) == \
            (b.client, b.version, b.round, b.size)
        _assert_states_equal(a.payload, b.payload)
    assert len(eng2.pending) == len(eng.pending)
    for a, b in zip(eng2.pending, eng.pending):
        assert (a.client, a.deliver, a.attempt) == \
            (b.client, b.deliver, b.attempt)
        assert a.msg.checksum == b.msg.checksum
        for wa, wb in zip(a.msg.words, b.msg.words):
            np.testing.assert_array_equal(np.asarray(wa),
                                          np.asarray(wb))


def test_stale_arrivals_discarded(setup):
    """max_staleness=0 with multi-round stragglers: anything trained
    against an old theta is dropped, never folded."""
    algo, data, sizes = setup["algo"], setup["data"], setup["sizes"]
    inj = FaultInjector(K, seed=11, straggler_prob=0.7,
                        straggler_rounds_max=2)
    eng = AsyncRoundEngine(algo, algo.init(KEY, setup["params"]),
                           data, sizes, KEY,
                           config=AsyncConfig(quorum_frac=0.5,
                                              deadline_rounds=1,
                                              max_staleness=0),
                           injector=inj)
    for t in range(5):
        eng.tick(data)
    eng.flush()
    folds = [e for e in eng.events if e["kind"] == "fold"]
    assert all(e["staleness"] == 0 for e in folds)
    assert any(e["kind"] == "stale_drop" for e in eng.events)
