"""Unit tests for the mask-training core (fixed seeds; the randomized
hypothesis sweeps live in test_masking_property.py and skip cleanly
when hypothesis is absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masking, regularizer, aggregation


def test_signed_constant_init_values():
    key = jax.random.PRNGKey(0)
    w = masking.signed_constant_init(key, (64, 64), fan_in=64)
    c = float(jnp.sqrt(2.0 / 64))
    vals = np.unique(np.asarray(jnp.abs(w)))
    assert np.allclose(vals, c, rtol=1e-5)


def test_score_init_uniform_theta():
    key = jax.random.PRNGKey(1)
    s = masking.score_init(key, (10000,), p0=0.5, jitter=0.5)
    theta = jax.nn.sigmoid(s)
    assert 0.45 < float(jnp.mean(theta)) < 0.55
    assert float(jnp.min(theta)) < 0.05 and float(jnp.max(theta)) > 0.95


def test_ste_bernoulli_forward_and_grad():
    theta = jnp.asarray([0.0, 0.3, 0.9, 1.0])
    u = jnp.asarray([0.5, 0.5, 0.5, 0.5])
    m = masking.ste_bernoulli(theta, u)
    assert list(np.asarray(m)) == [0.0, 0.0, 1.0, 1.0]
    g = jax.grad(lambda t: jnp.sum(masking.ste_bernoulli(t, u) * 2.0))(
        theta)
    assert np.allclose(np.asarray(g), 2.0)  # straight-through


def test_mask_spec_classification():
    spec = masking.MaskSpec()
    assert spec.is_masked("layers/attn/w_q", jnp.zeros((4, 4)))
    assert not spec.is_masked("layers/attn_norm/scale", jnp.zeros((4, 4)))
    assert not spec.is_masked("moe/router_w", jnp.zeros((4, 4)))
    assert not spec.is_masked("embed/table", jnp.zeros((4, 4)))
    assert not spec.is_masked("layers/w_q/bias_q", jnp.zeros((4, 4)))
    assert not spec.is_masked("w_small", jnp.zeros((4,)))  # 1D


def test_sample_effective_modes():
    key = jax.random.PRNGKey(2)
    params = {"w_a": jnp.zeros((8, 8)), "norm_scale": jnp.ones((8,))}
    mp = masking.init_masked(key, params, masking.MaskSpec())
    eff_s = masking.sample_effective(mp, key, "sample")
    eff_t = masking.sample_effective(mp, key, "threshold")
    eff_e = masking.sample_effective(mp, key, "expected")
    w = mp.weights["w_a"]
    # sampled/thresholded entries are either 0 or +-c
    for eff in (eff_s, eff_t):
        vals = np.unique(np.round(np.abs(np.asarray(
            eff["w_a"], dtype=np.float32)), 5))
        assert len(vals) <= 2
    # expected-mode magnitudes lie strictly inside [0, |c|]
    assert float(jnp.max(jnp.abs(eff_e["w_a"]))) <= float(
        jnp.max(jnp.abs(w))) + 1e-6
    # float leaf passes through
    assert np.allclose(np.asarray(eff_s["norm_scale"]), 1.0)


@pytest.mark.parametrize("seed,p", [
    (0, 0.05), (123, 0.25), (777, 0.5), (42, 0.75), (999, 0.95),
])
def test_final_mask_rate_matches_theta(seed, p):
    key = jax.random.PRNGKey(seed % 1000)
    n = 20000
    s = jnp.full((n, 2), masking.logit(jnp.float32(p)))
    mp = masking.MaskedParams({"w_x": jnp.ones((n, 2))}, {"w_x": s},
                              {"w_x": None})
    m = masking.final_mask(mp, key)["w_x"]
    rate = float(jnp.mean(m.astype(jnp.float32)))
    assert abs(rate - p) < 0.02


def test_scores_from_theta_roundtrip():
    theta = {"a": jnp.asarray([0.1, 0.5, 0.9]), "b": None}
    s = masking.scores_from_theta(theta)
    back = jax.nn.sigmoid(s["a"])
    assert np.allclose(np.asarray(back), [0.1, 0.5, 0.9], atol=1e-5)
    assert s["b"] is None


# ---------------------------------------------------------------------------
# regularizer
# ---------------------------------------------------------------------------


def test_entropy_proxy_matches_mean_sigmoid():
    s = {"w": jnp.asarray([[0.0, 2.0], [-2.0, 0.0]]), "skip": None}
    got = float(regularizer.entropy_proxy(s))
    want = float(jnp.mean(jax.nn.sigmoid(s["w"])))
    assert abs(got - want) < 1e-6


def test_empirical_entropy_bounds():
    all_ones = {"w": jnp.ones((100,), jnp.uint8)}
    half = {"w": jnp.asarray([0, 1] * 50, jnp.uint8)}
    assert float(regularizer.empirical_entropy(all_ones)) < 1e-5
    assert abs(float(regularizer.empirical_entropy(half)) - 1.0) < 1e-6


@pytest.mark.parametrize("p", [0.01, 0.2, 0.5, 0.77, 0.99])
def test_binary_entropy_concave_max_at_half(p):
    hp = float(regularizer.binary_entropy(jnp.float32(p)))
    hhalf = float(regularizer.binary_entropy(jnp.float32(0.5)))
    assert hp <= hhalf + 1e-6


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 42, 996, 123456])
def test_pack_unpack_roundtrip(seed):
    key = jax.random.PRNGKey(seed % 997)
    m = jax.random.bernoulli(key, 0.37, (32 * 17,)).astype(jnp.uint8)
    words = aggregation.pack_bits(m)
    back = aggregation.unpack_bits(words, m.size)
    assert bool(jnp.all(back == m))


def test_aggregate_masks_weighted_mean():
    m1 = {"w": jnp.asarray([1, 1, 0, 0], jnp.uint8)}
    m2 = {"w": jnp.asarray([1, 0, 1, 0], jnp.uint8)}
    theta = aggregation.aggregate_masks([m1, m2], weights=[3.0, 1.0])
    assert np.allclose(np.asarray(theta["w"]), [1.0, 0.75, 0.25, 0.0])


def test_aggregate_bayesian_shrinks_to_half():
    m = {"w": jnp.ones((4,), jnp.uint8)}
    theta = aggregation.aggregate_bayesian([m], alpha0=1, beta0=1)
    assert np.allclose(np.asarray(theta["w"]), 2.0 / 3.0)


def test_uplink_bits_accounting():
    mask = {"w": jnp.ones((100,), jnp.uint8)}
    assert aggregation.uplink_bits(mask, packed=True) == 128  # pad to 32
    assert aggregation.uplink_bits(mask, packed=False) == 1600


@pytest.mark.parametrize("seed,bits", [
    (0, 4), (1, 8), (42, 8), (99990, 4),
])
def test_theta_quantization_unbiased(seed, bits):
    """Stochastic DL quantization must be unbiased and bounded."""
    key = jax.random.PRNGKey(seed % 99991)
    theta = {"w": jax.random.uniform(key, (4000,))}
    q = aggregation.quantize_theta(theta, key, bits=bits)
    dq = aggregation.dequantize_theta(q, bits=bits)["w"]
    step = 1.0 / ((1 << bits) - 1)
    assert float(jnp.max(jnp.abs(dq - theta["w"]))) <= step + 1e-6
    # unbiasedness: average reconstruction error ~ 0
    errs = []
    for i in range(8):
        qi = aggregation.quantize_theta(
            theta, jax.random.fold_in(key, i), bits=bits)
        errs.append(aggregation.dequantize_theta(qi, bits=bits)["w"]
                    - theta["w"])
    mean_err = float(jnp.mean(jnp.stack(errs)))
    assert abs(mean_err) < step / 4


def test_freeze_for_decode_materializes_once_and_exactly():
    """freeze_for_decode turns every MaskedLeaf of a forward tree into
    the SAME effective weights the fused kernels execute (bit-identical
    hash-stream masks), leaves floats untouched, and contains no
    MaskedLeaf afterwards — so per-token decode (conv1d_step etc.) does
    zero mask resampling."""
    key = jax.random.PRNGKey(4)
    params = {"proj": {"w_a": jax.random.normal(key, (12, 8)),
                       "bias": jnp.zeros((8,), jnp.float32)},
              "conv": {"w_conv": jax.random.normal(key, (4, 8)),
                       "bias_conv": jnp.zeros((8,), jnp.float32)}}
    mp = masking.init_masked(key, params, masking.MaskSpec())
    seed_fn = lambda i: masking.mask_stream_seed(0, 0, i, 0, run_seed=3)
    tree = masking.masked_forward_tree(mp, seed_fn)
    frozen = masking.freeze_for_decode(tree)
    leaves = jax.tree_util.tree_leaves(
        frozen, is_leaf=lambda x: isinstance(x, masking.MaskedLeaf))
    assert not any(isinstance(l, masking.MaskedLeaf) for l in leaves)
    eff = masking.hash_effective(mp, seed_fn)
    for (p, a), (_, b) in zip(masking.leaves_with_paths(frozen),
                              masking.leaves_with_paths(eff)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32)), p
