"""Tier-1 tests for the collective-comm static analysis: the wire-
purity rules, the static cost model (`repro.analysis.comm_model`), and
the sharding lint — every rule demonstrated by a committed failing
fixture AND shown clean at HEAD, plus the forced-8-device acceptance
check that the static uplink prediction matches the CommLedger's
measured bits within 2%."""
import copy
import json
import math
import pathlib
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import collective_lint, comm_model, shard_lint
from repro.launch import mesh as meshlib
from repro.launch import plans
from repro.launch import sharding as shd
from tests.analysis_fixtures import bad_collective, bad_sharding

REPO = pathlib.Path(__file__).resolve().parents[1]
P = jax.sharding.PartitionSpec


class _StubMesh:
    """Duck-typed mesh for spec arithmetic: explain_spec and the
    replication lint only read .shape / .axis_names, so tests can use
    axis sizes > 1 without devices."""

    def __init__(self, pod=2, data=2, model=2):
        self.shape = {"pod": pod, "data": data, "model": model}
        self.axis_names = ("pod", "data", "model")
        self.size = pod * data * model


# ---------------------------------------------------------------------------
# comm_model units
# ---------------------------------------------------------------------------


def test_ring_send_bytes_formulas():
    S, A = 1024.0, 8
    f = comm_model._ring_send_bytes
    assert f("all_gather", S, A) == S * 7
    assert f("psum", S, A) == 2 * S * 7 / 8
    assert f("reduce_scatter", S, A) == S * 7 / 8
    assert f("all_to_all", S, A) == S * 7 / 8
    assert f("ppermute", S, A) == S
    assert f("psum", S, 1) == 0.0          # single-member group: free


def test_shard_shape_divides_by_spec_axes():
    mesh = _StubMesh(pod=2, data=4, model=2)
    assert comm_model.shard_shape((16, 64), P(None, "model"),
                                  mesh) == (16, 32)
    assert comm_model.shard_shape((16, 64), P("data", "model"),
                                  mesh) == (4, 32)
    assert comm_model.shard_shape(
        (8, 16, 64), P(None, ("pod", "data"), "model"),
        mesh) == (8, 2, 32)


def test_classify_site_roles():
    mk = lambda prim, shape, dt: comm_model.CollectiveSite(
        prim, ("pod",), shape, dt,
        int(math.prod(shape) or 1) * jnp.dtype(dt).itemsize * 8)
    floats = frozenset({(1, 128, 32)})
    masks = frozenset({4096})
    cl = lambda s: comm_model.classify_site(
        s, float_shapes=floats, mask_sizes=masks)
    assert cl(mk("all_gather", (2, 130), "uint32")) == "uplink"
    assert cl(mk("psum", (), "float32")) == "metric"
    assert cl(mk("psum", (1, 128, 32), "float32")) == "sidecar"
    # same element count as the float sidecar, but mask-stream shaped
    assert cl(mk("psum", (2, 2048), "bfloat16")) == "mask-unpacked"
    assert cl(mk("all_gather", (64, 65), "float32")) == "other"


# ---------------------------------------------------------------------------
# purity rule: fixtures fire, HEAD round step is clean
# ---------------------------------------------------------------------------


def _fixture_jaxpr(builder):
    mesh = meshlib.make_debug_pod_mesh()
    fn = builder(mesh)
    return jax.make_jaxpr(fn)(jnp.zeros((4, 256), jnp.float32))


def test_purity_fixture_f32_all_gather_fires():
    jxp = _fixture_jaxpr(bad_collective.f32_score_all_gather)
    found = collective_lint.purity_findings(jxp)
    assert found and all(f.rule == "collective-f32-weight"
                         for f in found)
    assert any("all_gather" in f.where for f in found)


def test_purity_fixture_u8_mask_fires():
    jxp = _fixture_jaxpr(bad_collective.u8_mask_all_gather)
    found = collective_lint.purity_findings(jxp)
    assert any(f.rule == "collective-unpacked-mask" for f in found)


def test_purity_fixture_bf16_pmean_fires():
    jxp = _fixture_jaxpr(bad_collective.bf16_mask_pmean)
    found = collective_lint.purity_findings(jxp)
    assert any(f.rule == "collective-f32-weight"
               and "psum" in f.where for f in found)


def test_round_step_clean_and_one_bpp_at_head():
    """Clean-at-HEAD twin + the headline claim on the smoke reference
    arch: the packed fedpm_reg round's collectives carry NOTHING but
    uint32 words, the float sidecar, and scalars — and the accounting
    uplink is exactly 1 bit per mask parameter per cohort."""
    rep = collective_lint.arch_collective_report("internlm2-1.8b",
                                                 "fedpm_reg", C=2)
    assert rep["findings"] == [], [str(f) for f in rep["findings"][:3]]
    m = rep["model"]
    assert m["bpp_wire"] == 1.0
    assert m["uplink_bits"] > 0
    roles = {r["role"] for r in m["sites"]}
    assert roles <= {"uplink", "metric", "sidecar"}
    # the walker reached the shard_map body: pod-axis gathers of words
    assert any(r["prim"].startswith("all_gather")
               and r["dtype"] == "uint32" and r["axes"] == ["pod"]
               for r in m["sites"])


def test_unpacked_baseline_fires_and_costs_more():
    """Liveness: the bf16-psum baseline trips the float rule and its
    accounting wire cost is a multiple of the packed path's 1 Bpp (16
    bits per crossing; the exact bpp scales with the mesh) — the rule
    cannot go dead silently."""
    rep = collective_lint.arch_collective_report(
        "internlm2-1.8b", "fedpm_reg", C=2, packed=False)
    assert any(f.rule == "collective-f32-weight"
               for f in rep["findings"])
    m = rep["model"]
    assert m["bpp_wire"] >= 8.0
    assert any(r["role"] == "mask-unpacked" for r in m["sites"])


def test_unknown_algorithm_rejected():
    with pytest.raises(ValueError, match="fedsgd"):
        comm_model.arch_round_comm_model("internlm2-1.8b", "fedsgd")


# ---------------------------------------------------------------------------
# shard lint: silent replication + declared-vs-lowered
# ---------------------------------------------------------------------------


def test_silent_replication_fires_on_fixture():
    rep = shard_lint.silent_replication_report(
        bad_sharding.BAD_TREE_SHAPES, _StubMesh())
    assert [f.rule for f in rep["findings"]] == \
        ["shard-silent-replication"]
    (f,) = rep["findings"]
    assert "w_odd" in f.where and "129" in f.detail
    # the small odd-shaped norm leaf stays under the noise floor
    assert not any("scale" in g.where for g in rep["findings"])


def test_silent_replication_clean_at_head():
    """Registry smoke trees shard cleanly on a 2x2x2 mesh: every big
    leaf gets at least one axis (no divisibility fallback)."""
    for arch in ("internlm2-1.8b", "deepseek-v2-lite-16b"):
        rep = shard_lint.arch_shard_report(arch, mesh=_StubMesh())
        assert rep["findings"] == [], \
            (arch, [str(f) for f in rep["findings"][:3]])
        assert rep["explanations"]


def test_input_sharding_mismatch_aligns_pruned_args_and_flags_drift():
    """jit prunes unread args (the round step's zeroed opt_m); the
    check aligns declared leaves through _kept_var_idx, then flags the
    leaf whose lowered sharding is not the declared one."""
    class Act:
        def __init__(self, ok):
            self.ok = ok

        def is_equivalent_to(self, d, nd):
            return self.ok

    sds = jax.ShapeDtypeStruct((4, 4), "float32")
    shapes = {"a": sds, "b": sds, "c": sds}
    declared = {k: types.SimpleNamespace(spec=f"P({k})")
                for k in shapes}
    compiled = types.SimpleNamespace(
        input_shardings=([Act(True), Act(False)], {}),
        _executable=types.SimpleNamespace(_kept_var_idx={0, 2}))
    out = shard_lint.input_sharding_mismatches(compiled, declared,
                                               shapes)
    assert [f.where for f in out] == ["c"]
    assert out[0].rule == "shard-spec-mismatch"
    # arity drift with no usable kept-index map is itself a finding
    compiled.input_shardings = ([Act(True)], {})
    compiled._executable = types.SimpleNamespace(_kept_var_idx=None)
    out = shard_lint.input_sharding_mismatches(compiled, declared,
                                               shapes)
    assert len(out) == 1 and "arity drift" in out[0].detail


# ---------------------------------------------------------------------------
# explain_spec (launch/sharding.py): decision trace
# ---------------------------------------------------------------------------


def test_explain_spec_rules_and_skip_recording():
    mesh = _StubMesh()
    ex = shd.explain_spec("step", (), mesh)
    assert ex.rule == "scalar" and ex.spec == P()
    ex = shd.explain_spec("blocks/scale", (4,), mesh)
    assert ex.rule == "replicate-small" and not ex.skipped
    ex = shd.explain_spec("embed", (256, 64), mesh, scan_dims=0)
    assert ex.rule == "embed" and ex.spec == P("data", "model")
    ex = shd.explain_spec("blocks/w_q", (3, 64, 128), mesh)
    assert ex.rule == "generic" and ex.spec == P(None, "data", "model")
    assert ex.skipped == ()
    ex = shd.explain_spec("blocks/w_up", (3, 4, 64, 128), mesh)
    assert ex.rule == "moe-expert"
    assert ex.spec == P(None, "model", "data", None)
    # the fallback leaf: every try recorded, nothing sharded
    ex = shd.explain_spec("blocks/w_odd", (3, 129, 257), mesh)
    assert ex.rule == "generic" and ex.spec == P(None, None, None)
    assert len(ex.skipped) == 2
    assert any("129" in s for s in ex.skipped)


def test_param_spec_is_explain_spec():
    mesh = _StubMesh(pod=2, data=4, model=2)
    cases = [("embed", (256, 64), 0), ("blocks/w_q", (3, 64, 128), 1),
             ("blocks/w_up", (3, 4, 64, 128), 1),
             ("final_norm", (64,), 0), ("blocks/bias", (3, 512), 1)]
    for path, shape, sd in cases:
        assert shd.param_spec(path, shape, mesh, scan_dims=sd) == \
            shd.explain_spec(path, shape, mesh, scan_dims=sd).spec


# ---------------------------------------------------------------------------
# BENCH_comm.json: baseline sanity + differ logic
# ---------------------------------------------------------------------------


def _load_check_comm():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_comm
    finally:
        sys.path.pop(0)
    return check_comm


def test_bench_comm_baseline_committed_and_pure():
    doc = json.loads((REPO / "BENCH_comm.json").read_text())
    assert set(doc["algos"]) == set(plans.MASK_ALGOS)
    for algo, tab in doc["algos"].items():
        assert tab["bpp_wire"] <= 1.0, (algo, tab["bpp_wire"])
    assert doc["unpacked_contrast"]["purity_findings"] > 0
    v = doc["validation"]
    assert v["ok"] and v["rel_err"] <= v["tolerance"]


def test_check_comm_detects_drift():
    check_comm = _load_check_comm()
    base = json.loads((REPO / "BENCH_comm.json").read_text())
    assert check_comm.diff(copy.deepcopy(base), base) == []
    fresh = copy.deepcopy(base)
    fresh["algos"]["fedpm_reg"]["uplink_bits"] += 32
    fresh["algos"]["fedpm_reg"]["sites"][0]["prim"] = "ppermute"
    errs = check_comm.diff(fresh, base)
    assert any("uplink_bits" in e for e in errs)
    assert any("site set drifted" in e for e in errs)
    fresh = copy.deepcopy(base)
    fresh["unpacked_contrast"]["purity_findings"] = 0
    assert any("dead" in e for e in check_comm.diff(fresh, base))


# ---------------------------------------------------------------------------
# acceptance: forced 8-device mesh, static vs measured within 2%
# ---------------------------------------------------------------------------


_FORCED_COMM_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
from repro.analysis import collective_lint, comm_model, shard_lint
from repro.configs import get_config
from repro.core import masking
from repro.launch import mesh as meshlib
from repro.launch import plans
from repro.launch import sharding as shd
from repro.launch import steps as steplib
from repro.models import build_model

mesh = meshlib.make_debug_pod_mesh()
assert mesh.size == 8 and mesh.shape["pod"] == 2, mesh
api = build_model(get_config("internlm2-1.8b", smoke=True))
C = 2
scfg = steplib.StepConfig(packed_masks=True,
                          **plans.MASK_ALGOS["fedpm_reg"])
jxp, shapes, sh = comm_model.trace_round_jaxpr(api, scfg, mesh, C,
                                               codec="bitpack")
purity = collective_lint.round_purity_findings(jxp, shapes, sh, mesh)
assert purity == [], [str(f) for f in purity[:3]]
model = comm_model.round_comm_model(jxp, shapes, sh, mesh, scfg)
assert model["bpp_wire"] <= 1.0 + 1e-9, model["bpp_wire"]
assert model["mesh"]["n_devices"] == 8

state = steplib.init_fed_state(jax.random.PRNGKey(scfg.seed), api,
                               masking.MaskSpec(), C)
step = jax.jit(
    steplib.make_round_step(api, scfg, mesh=mesh, state_sh=sh,
                            codec="bitpack"),
    in_shardings=(sh,), out_shardings=(sh, shd.replicated(mesh)))
compiled = step.lower(state).compile()
mism = shard_lint.input_sharding_mismatches(compiled, sh, shapes,
                                            label="state/")
assert mism == [], [str(f) for f in mism[:3]]
_, metrics = compiled(state)
measured = float(metrics["bits_measured"])
static = float(model["uplink_bits"])
rel = abs(static - measured) / measured
assert rel < 0.02, (static, measured, rel)
assert float(model["downlink_bits"]) == float(metrics["downlink_bits"])
print("COMM_OK", int(static), int(measured), model["bpp_wire"])
"""


def test_wire_claim_on_forced_8dev_mesh():
    """Acceptance: on a REAL forced (2, 2, 2) mesh the static uplink
    prediction for the packed fedpm_reg round agrees with the
    CommLedger's measured bits within 2%, the purity lint finds zero
    float/unpacked crossings, and the declared shardings are the ones
    the executable ingests."""
    env = {"PYTHONPATH": str(REPO / "src"),
           "PATH": "/usr/bin:/bin:/usr/local/bin",
           "HOME": "/tmp"}
    out = subprocess.run([sys.executable, "-c", _FORCED_COMM_SCRIPT],
                         capture_output=True, text=True, timeout=540,
                         env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COMM_OK" in out.stdout
