"""Tier-1 smoke for benchmarks/roofline.py: analyze + to_markdown over
a canned dryrun-style results dict (real registry arch/shape/mesh
keys), so the CI bench job catches schema drift between the dryrun
artifacts and the roofline reader."""
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from benchmarks import roofline  # noqa: E402


def _canned_results():
    """Two cells in the exact shape lower_cell writes: one healthy
    train cell with both steps, one failed cell to skip."""
    step = lambda f, b, cb: {
        "flops": f, "bytes_accessed": b,
        "collective_bytes": {"all-gather": cb, "total": cb},
        "memory": {"argument_size": 1 << 30, "output_size": 1 << 28,
                   "temp_size": 1 << 29, "generated_code_size": 1 << 20},
    }
    return {
        "internlm2-1.8b|train_4k|pod16x16": {
            "ok": True,
            "stream_cover": {"ok": True, "n_leaves": 7, "n_streams": 14},
            "train_step": step(2.5e12, 1.0e11, 2.0e9),
            "round_step": step(1.0e9, 5.0e9, 3.0e8),
        },
        "qwen2-7b|prefill_32k|pod2x16x16": {
            "ok": False, "error": "OOM",
        },
    }


def test_analyze_rows_and_terms():
    rows = roofline.analyze(_canned_results())
    # the failed cell is skipped; the ok cell yields one row per step
    assert {(r["arch"], r["step"]) for r in rows} == {
        ("internlm2-1.8b", "train_step"),
        ("internlm2-1.8b", "round_step")}
    by_step = {r["step"]: r for r in rows}
    tr = by_step["train_step"]
    assert tr["chips"] == roofline.CHIPS["pod16x16"]
    assert tr["t_compute"] == pytest.approx(2.5e12 / roofline.PEAK_FLOPS)
    assert tr["t_memory"] == pytest.approx(1.0e11 / roofline.HBM_BW)
    assert tr["t_collective"] == pytest.approx(2.0e9 / roofline.LINK_BW)
    assert tr["dominant"] in ("compute", "memory", "collective")
    # 6*N*T model FLOPs anchor is positive for train, zero for round
    assert tr["model_flops"] > 0
    assert by_step["round_step"]["model_flops"] == 0.0


def test_to_markdown_renders_every_row():
    rows = roofline.analyze(_canned_results())
    md = roofline.to_markdown(rows)
    lines = md.splitlines()
    assert lines[0].startswith("| arch |")
    assert len(lines) == 2 + len(rows)
    assert all("**" in ln for ln in lines[2:])   # dominant term marked
    for r in rows:
        assert r["arch"] in md and r["step"] in md


def test_model_flops_formulas():
    f_train = roofline.model_flops("internlm2-1.8b", "train_4k",
                                   "train_step")
    f_pref = roofline.model_flops("internlm2-1.8b", "prefill_32k",
                                  "prefill_step")
    assert f_train > 0 and f_pref > 0
    assert roofline.model_flops("internlm2-1.8b", "train_4k",
                                "round_step") == 0.0
    assert roofline.scan_trip_count("internlm2-1.8b") >= 1
