"""Tier-1 tests for the repro.analysis static-analysis subsystem:
every rule is demonstrated by a committed failing fixture (or an
in-test corrupted structure) AND shown clean on the repo at HEAD."""
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_lint, source_lint, stream_cover
from repro.core import masking
from repro.kernels import ops, ref

REPO = pathlib.Path(__file__).resolve().parents[1]
FIX = pathlib.Path(__file__).parent / "analysis_fixtures"
SRC = REPO / "src" / "repro"


# ---------------------------------------------------------------------------
# jaxpr engine
# ---------------------------------------------------------------------------


def _operands(M=128, K=128, N=128):
    x = jnp.zeros((M, K), jnp.bfloat16)
    w = jnp.zeros((K, N), jnp.bfloat16)
    s = jnp.zeros((K, N), jnp.float32)
    g = jnp.zeros((M, N), jnp.bfloat16)
    return x, w, s, g


def test_weight_f32_rule_fires_on_naive_not_on_fused():
    """The promoted counter: the jnp oracle materializes weight-shaped
    f32 temporaries, the fused kernel path defines none — and the
    compat wrapper agrees with the rule-based walker."""
    x, w, s, _ = _operands()
    K, N = w.shape
    naive_jx = jax.make_jaxpr(
        lambda x, w, s: ref.masked_matmul(x, w, s, 0))(x, w, s)
    fused_jx = jax.make_jaxpr(
        lambda x, w, s: ops.masked_dense(x, w, s, 0))(x, w, s)
    rule = jaxpr_lint.weight_f32_temporaries((K, N))
    naive_f = jaxpr_lint.lint_jaxpr(naive_jx, [rule])
    assert naive_f and all(f.rule == "weight-f32-temporary"
                           for f in naive_f)
    assert jaxpr_lint.lint_jaxpr(fused_jx, [rule]) == []
    # the compat counter is the same rule through the same walker
    assert jaxpr_lint.count_weight_f32_defs_jaxpr(
        naive_jx, (K, N)) == len(naive_f)
    assert jaxpr_lint.count_weight_f32_defs_jaxpr(
        fused_jx, (K, N)) == 0


def test_mask_materialization_rule():
    """materialize_leaf defines a weight-shaped bool mask; the fused
    fwd+bwd never does."""
    x, w, s, g = _operands()
    K, N = w.shape
    leaf = masking.MaskedLeaf.build(w, s, 7)
    rule = jaxpr_lint.mask_materialization((K, N))
    mat_jx = jax.make_jaxpr(masking.materialize_leaf)(leaf)
    found = jaxpr_lint.lint_jaxpr(mat_jx, [rule])
    assert found and all(f.rule == "mask-materialization"
                         for f in found)

    def fused(x, w, s, g):
        y, vjp = jax.vjp(lambda x_, s_: ops.masked_dense(x_, w, s_, 0),
                         x, s)
        return y, vjp(g)

    fused_jx = jax.make_jaxpr(fused)(x, w, s, g)
    assert jaxpr_lint.lint_jaxpr(fused_jx, [rule]) == []


def test_dtype_promotion_rule_bf16_upcast():
    x, w, _, _ = _operands()
    K, N = w.shape
    rule = jaxpr_lint.DtypePromotionRule([(K, N)])
    up_jx = jax.make_jaxpr(
        lambda w: w.astype(jnp.float32) * 2.0)(w)
    found = jaxpr_lint.lint_jaxpr(up_jx, [rule])
    assert any("bf16->f32" in f.detail for f in found)
    # a downcast (f32 -> bf16) at the same shape is fine
    down_jx = jax.make_jaxpr(
        lambda s: s.astype(jnp.bfloat16))(jnp.zeros((K, N), jnp.float32))
    assert jaxpr_lint.lint_jaxpr(down_jx, [rule]) == []


def test_dtype_promotion_rule_f64():
    from jax.experimental import enable_x64
    with enable_x64():
        jx = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) + 1.0)(jnp.ones((4,)))
    found = jaxpr_lint.lint_jaxpr(
        jx, [jaxpr_lint.DtypePromotionRule()])
    assert any("f64" in f.detail for f in found)


def test_donation_alias_rule():
    inner = jax.jit(lambda x: x * 2.0, donate_argnums=0)

    def bad(x):
        return inner(x) + x          # x read AFTER its buffer is donated

    def good(x):
        return inner(x) + 1.0

    rule = jaxpr_lint.DonationAliasRule()
    x = jnp.ones((8, 8))
    bad_f = jaxpr_lint.lint_jaxpr(jax.make_jaxpr(bad)(x), [rule])
    assert any(f.rule == "donation-alias" for f in bad_f)
    assert jaxpr_lint.lint_jaxpr(jax.make_jaxpr(good)(x), [rule]) == []


def test_walker_descends_into_scan():
    """Leaf defs inside lax.scan bodies are visited (the walker must
    not stop at the call wrapper)."""
    def body(c, _):
        return c, (c.astype(jnp.float32) ** 2)

    w = jnp.zeros((128, 128), jnp.bfloat16)
    jx = jax.make_jaxpr(
        lambda w: jax.lax.scan(body, w, jnp.arange(3)))(w)
    found = jaxpr_lint.lint_jaxpr(
        jx, [jaxpr_lint.weight_f32_temporaries((128, 128))])
    assert found


# ---------------------------------------------------------------------------
# stream engine
# ---------------------------------------------------------------------------


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def test_stream_cover_clean_tree():
    tree = {"a": masking.MaskedLeaf.build(_sds(3, 4, 8), None, 5),
            "b": masking.MaskedLeaf.build(_sds(16, 8), None, 9),
            "c": None}
    ivs = stream_cover.collect_intervals(tree)
    assert len(ivs) == 4                     # 3 stacked blocks + 1
    assert stream_cover.check_intervals(ivs) == []


def test_stream_overlap_detected():
    leaf = masking.MaskedLeaf.build(_sds(3, 4, 8), None, 5)
    leaf.off = jnp.zeros_like(leaf.off)      # every block reads [0, 32)
    found = stream_cover.check_intervals(
        stream_cover.collect_intervals({"a": leaf}))
    assert any(f.rule == "stream-overlap" for f in found)


def test_stream_gap_detected():
    leaf = masking.MaskedLeaf.build(_sds(2, 4, 8), None, 5)
    leaf.off = leaf.off * jnp.uint32(2)      # hole between the blocks
    found = stream_cover.check_intervals(
        stream_cover.collect_intervals({"a": leaf}))
    assert any(f.rule == "stream-gap" for f in found)


def test_stream_seed_collision_across_leaves():
    tree = {"a": masking.MaskedLeaf.build(_sds(4, 8), None, 5),
            "b": masking.MaskedLeaf.build(_sds(4, 8), None, 5)}
    found = stream_cover.check_intervals(
        stream_cover.collect_intervals(tree))
    assert any(f.rule == "stream-overlap" and "seed" in f.detail
               for f in found)


def test_state_stream_report_flags_collision_sweep():
    """The (shard, cohort) sweep itself catches collisions: same
    (step, dev, cohort, run_seed) coordinates for every leaf index
    can't happen through mask_stream_seed, so corrupt the report's
    inputs instead — two devs that alias to one id."""
    from repro.analysis import model_check
    _, state, _ = model_check.model_step_setup(
        model_check.MODEL_CHECK_CFG, C=2, S=16)
    rep = stream_cover.state_stream_report(state, devs=(0, 0),
                                           cohorts=range(2))
    assert any(f.rule == "stream-overlap" for f in rep["findings"])
    clean = stream_cover.state_stream_report(state, devs=(0, 1),
                                             cohorts=range(2))
    assert clean["findings"] == []
    assert clean["n_streams"] == clean["n_leaves"] * 4


def test_stream_gate_multi_shard_grouped_moe():
    """Acceptance: the coverage gate over the deepseek-style MoE smoke
    config — grouped (E, K, N) expert leaves — swept across 8 shard
    ids x 2 cohorts (mask_stream_seed is pure; no devices needed)."""
    rep = stream_cover.arch_stream_report(
        "deepseek-v2-lite-16b", smoke=True, C=2, devs=range(8))
    assert rep["findings"] == []
    assert rep["n_leaves"] > 0
    assert rep["n_intervals"] > rep["n_leaves"]   # stacked/grouped
    assert rep["n_streams"] == rep["n_leaves"] * 8 * 2


_FORCED_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
from repro.analysis import stream_cover
from repro.configs import get_config
from repro.core import masking
from repro.launch import mesh as meshlib
from repro.launch import steps as steplib
from repro.models import build_model

cfg = get_config("deepseek-v2-lite-16b", smoke=True)
api = build_model(cfg)
mesh = meshlib.make_debug_mesh(4, 2)
assert len(jax.devices()) == 8, jax.devices()
n_dev = 1
for a in mesh.axis_names:
    n_dev *= mesh.shape[a]
state = jax.eval_shape(
    lambda k: steplib.init_fed_state(k, api, masking.MaskSpec(), C=2),
    jax.random.PRNGKey(0))
rep = stream_cover.state_stream_report(
    state, devs=range(n_dev), cohorts=range(2), run_seed=17)
assert rep["findings"] == [], [str(f) for f in rep["findings"][:3]]
assert rep["n_streams"] == rep["n_leaves"] * n_dev * 2
print("STREAM_OK", rep["n_leaves"], rep["n_intervals"],
      rep["n_streams"])
"""


def test_stream_gate_on_forced_multi_device_mesh():
    """Acceptance: the gate passes on a REAL forced 8-device mesh
    (xla_force_host_platform_device_count, the dryrun mechanism) with
    grouped MoE leaves, shard ids enumerated from the mesh axes."""
    env = {"PYTHONPATH": str(REPO / "src"),
           "PATH": "/usr/bin:/bin:/usr/local/bin",
           "HOME": "/tmp"}
    out = subprocess.run([sys.executable, "-c", _FORCED_MESH_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "STREAM_OK" in out.stdout


# ---------------------------------------------------------------------------
# source engine (AST rules): fixtures fire, HEAD is clean
# ---------------------------------------------------------------------------


def test_bare_prngkey_rule_fires_on_fixture():
    found = source_lint.check_bare_prngkey([FIX / "bad_prngkey.py"],
                                           allowlist=frozenset())
    assert any(f.rule == "bare-prngkey" and "PRNGKey(29)" in f.detail
               for f in found)


def test_bare_prngkey_clean_at_head():
    assert source_lint.check_bare_prngkey(
        source_lint.launch_files()) == []


def test_kernel_oracle_rules_fire_on_fixture():
    found = source_lint.check_kernel_oracles(
        FIX / "bad_kernels.py", FIX / "bad_ref.py", FIX / "bad_ops.py")
    rules = {f.rule for f in found}
    assert "missing-oracle" in rules
    assert "missing-ref-bwd-hatch" in rules


def test_kernel_oracles_clean_at_head():
    assert source_lint.check_kernel_oracles(
        SRC / "kernels" / "masked_matmul.py",
        SRC / "kernels" / "ref.py",
        SRC / "kernels" / "ops.py") == []


def test_knob_doc_rule_fires_on_fixture_and_clean_at_head():
    readme = REPO / "README.md"
    found = source_lint.check_knob_docs([FIX / "bad_knob.py"], readme)
    assert any("REPRO_BOGUS_KNOB" in f.detail for f in found)
    # the documented table really exists and the real tree is clean
    assert "REPRO_FORCE_INTERPRET" in source_lint.readme_knobs(readme)
    files = (sorted(SRC.rglob("*.py"))
             + sorted((REPO / "benchmarks").glob("*.py")))
    assert source_lint.check_knob_docs(files, readme) == []


def test_materialize_allowlist_rule():
    found = source_lint.check_materialize_allowlist(
        [FIX / "bad_materialize.py"])
    assert len(found) == 2                   # both sneaky calls
    assert all(f.rule == "materialize-allowlist" for f in found)
    assert source_lint.check_materialize_allowlist(
        sorted(SRC.rglob("*.py"))) == []


def test_source_lint_clean_at_head():
    assert source_lint.run_all(REPO) == []


# ---------------------------------------------------------------------------
# kernels/ops.py backend-cache reset (satellite regression)
# ---------------------------------------------------------------------------


def test_reset_backend_cache_unsticks_env_flip(monkeypatch,
                                               kernel_backend_reset):
    """The bug the satellite fixes: flipping REPRO_FORCE_INTERPRET
    mid-process was silently ignored by the lru_cache; the public
    reset makes the flip take effect."""
    monkeypatch.setattr(ops, "repro_backend", lambda: "tpu")
    monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    ops.reset_backend_cache()
    assert ops._use_interpret() is False
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert ops._use_interpret() is False     # stale: flip ignored
    ops.reset_backend_cache()
    assert ops._use_interpret() is True      # reset applies the flip
