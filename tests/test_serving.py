"""Serving-path tests: tenant-isolation bit-identity through the
multi-tenant continuous-batching engine, freeze-cache LRU semantics
(fixed-seed twins of tests/test_serving_property.py), the
frozen-decode vs fused-training-forward equivalence regression (the
formerly untested `conv1d_step` decode residue), and the
launch/serve.py prefill/decode timing split."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import masking
from repro.models import build_model
from repro.runtime.serve_engine import ServeEngine


def _build(name="internlm2-1.8b", seed=0):
    cfg = get_config(name, smoke=True)
    api = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    mp = masking.init_masked(key, api.init_params(key),
                             masking.MaskSpec())
    return cfg, api, key, mp


def _solo_completion(api, mp, seed, prompt, gen, max_seq, mode="sample"):
    """The reference: the SAME tenant decoded alone in a fresh
    single-slot session."""
    eng = ServeEngine(api, mp, slots=1, cache_capacity=1,
                      max_seq=max_seq)
    eng.register_tenant("solo", seed=seed, mode=mode)
    rid = eng.submit("solo", prompt, gen)
    return eng.run()[rid]


# ---------------------------------------------------------------------------
# Tenant isolation: the bit-identity contract
# ---------------------------------------------------------------------------


def test_tenant_isolation_bit_identity():
    """Interleave decode steps from 3 tenants (distinct mask seeds,
    staggered prompt/generation lengths so admission and completion
    never line up) through the continuous-batching engine: each
    tenant's logits must be BIT-identical to that tenant decoded alone
    in a fresh single-slot session."""
    cfg, api, key, mp = _build()
    prompts = np.asarray(jax.random.randint(key, (3, 10), 0, cfg.vocab))
    lens = [(10, 6), (7, 8), (4, 5)]          # staggered (prompt, gen)
    max_seq = 18

    eng = ServeEngine(api, mp, slots=2, cache_capacity=3,
                      max_seq=max_seq)
    rids = []
    for i, (P, G) in enumerate(lens):
        eng.register_tenant(f"t{i}", seed=100 + i, mode="sample")
        rids.append(eng.submit(f"t{i}", prompts[i, :P], G))
    done = eng.run()
    assert sorted(done) == sorted(rids)

    for i, (P, G) in enumerate(lens):
        solo = _solo_completion(api, mp, 100 + i, prompts[i, :P], G,
                                max_seq)
        got = done[rids[i]]
        assert got.tokens == solo.tokens, f"tenant {i} tokens diverged"
        assert len(got.decode_logits) == G
        for t, (a, b) in enumerate(zip(got.decode_logits,
                                       solo.decode_logits)):
            assert np.array_equal(a, b), \
                f"tenant {i} logits differ at decode step {t}"


def test_tenant_isolation_under_cache_thrash():
    """capacity=1 with 3 live tenants forces evictions mid-traffic;
    re-freezing an evicted identity must reproduce the identical tree,
    so isolation stays bit-exact even while the cache thrashes."""
    cfg, api, key, mp = _build(seed=1)
    prompts = np.asarray(jax.random.randint(key, (3, 6), 0, cfg.vocab))
    eng = ServeEngine(api, mp, slots=2, cache_capacity=1, max_seq=12)
    rids = []
    for i in range(3):
        eng.register_tenant(f"t{i}", seed=7 * (i + 1), mode="threshold")
        rids.append(eng.submit(f"t{i}", prompts[i], 4))
    done = eng.run()
    assert eng.cache.evictions >= 1
    assert len(eng.cache) <= 1
    for i in range(3):
        solo = _solo_completion(api, mp, 7 * (i + 1), prompts[i], 4, 12,
                                mode="threshold")
        got = done[rids[i]]
        assert got.tokens == solo.tokens
        assert all(np.array_equal(a, b) for a, b in
                   zip(got.decode_logits, solo.decode_logits))


def test_continuous_batching_mixes_prefill_and_decode():
    """More requests than slots with staggered lengths: the engine
    must admit new requests into freed slots while other slots keep
    decoding (ticks where PREFILL and DECODE phases coexist), and
    every request must complete with exactly its requested tokens."""
    cfg, api, key, mp = _build(seed=2)
    prompts = np.asarray(jax.random.randint(key, (4, 9), 0, cfg.vocab))
    eng = ServeEngine(api, mp, slots=2, cache_capacity=2, max_seq=16)
    lens = [(9, 4), (3, 9), (6, 6), (4, 8)]
    rids = []
    for i, (P, G) in enumerate(lens):
        eng.register_tenant(f"t{i}", seed=i + 1)
        rids.append(eng.submit(f"t{i}", prompts[i, :P], G))
    done = eng.run()
    assert sorted(done) == sorted(rids)
    assert eng.mixed_ticks > 0, \
        "no tick ever interleaved prefill with decode"
    for rid, (P, G) in zip(rids, lens):
        assert len(done[rid].tokens) == G
        assert done[rid].prefill_steps == P - 1
    st = eng.stats()
    assert st["prefill_tokens"] == sum(P - 1 for P, _ in lens)
    assert st["decode_tokens"] == sum(G for _, G in lens)


def test_lockstep_mode_matches_exact_mode():
    """The vmapped lockstep step (one dispatch for all slots) is the
    throughput mode: tokens must agree with the exact per-slot mode
    and logits must be numerically equivalent (batched-dot
    reassociation only)."""
    cfg, api, key, mp = _build(seed=3)
    prompts = np.asarray(jax.random.randint(key, (3, 6), 0, cfg.vocab))

    def run(lockstep):
        eng = ServeEngine(api, mp, slots=2, cache_capacity=3,
                          max_seq=12, lockstep=lockstep)
        rids = []
        for i in range(3):
            eng.register_tenant(f"t{i}", seed=50 + i)
            rids.append(eng.submit(f"t{i}", prompts[i], 5))
        return eng.run(), rids

    exact, rids_e = run(False)
    lock, rids_l = run(True)
    for re_, rl in zip(rids_e, rids_l):
        assert exact[re_].tokens == lock[rl].tokens
        for a, b in zip(exact[re_].decode_logits, lock[rl].decode_logits):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_engine_input_validation():
    cfg, api, key, mp = _build(seed=4)
    eng = ServeEngine(api, mp, slots=1, cache_capacity=1, max_seq=8)
    eng.register_tenant("a", seed=1)
    with pytest.raises(ValueError):
        eng.register_tenant("a", seed=2)       # duplicate name
    with pytest.raises(KeyError):
        eng.submit("ghost", [1, 2], 2)         # unknown tenant
    with pytest.raises(ValueError):
        eng.submit("a", list(range(7)), 4)     # overflows max_seq
    with pytest.raises(ValueError):
        eng.submit("a", [], 2)                 # empty prompt
    with pytest.raises(ValueError):
        masking.FreezeCache(lambda k: k, capacity=0)


# ---------------------------------------------------------------------------
# Freeze-cache LRU semantics (fixed-seed twin of the hypothesis suite)
# ---------------------------------------------------------------------------


def _tiny_mp():
    key = jax.random.PRNGKey(0)
    params_like = {"w_x": jnp.zeros((16, 8)), "bias": jnp.zeros((8,))}
    return masking.init_masked(key, params_like, masking.MaskSpec())


def test_freeze_cache_exact_lru():
    mp = _tiny_mp()
    built = []

    def build(ident):
        built.append(ident.seed)
        return masking.freeze_identity(mp, ident)

    cache = masking.FreezeCache(build, capacity=2)
    ids = [masking.MaskIdentity(seed=s) for s in range(4)]

    cache.get(ids[0])
    cache.get(ids[1])
    cache.get(ids[0])                  # hit: 0 becomes MRU
    assert [i.seed for i in cache.keys()] == [1, 0]
    cache.get(ids[2])                  # evicts 1 (exact LRU), not 0
    assert [i.seed for i in cache.keys()] == [0, 2]
    assert ids[1] not in cache and ids[0] in cache
    assert cache.stats() == {"capacity": 2, "occupancy": 2, "hits": 1,
                             "misses": 3, "evictions": 1}
    # a hit returns a tree bit-identical to a fresh freeze of the
    # same identity (the builder is deterministic)
    hit = cache.get(ids[0])
    fresh = masking.freeze_identity(mp, ids[0])
    for a, b in zip(jax.tree_util.tree_leaves(hit),
                    jax.tree_util.tree_leaves(fresh)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert built == [0, 1, 2]          # hits never rebuild


def test_freeze_identity_distinct_tenants_distinct_masks():
    """Two identities over the SAME shared weights must decode through
    different sub-networks (distinct masks), while equal identities
    are bit-identical."""
    mp = _tiny_mp()
    a = masking.freeze_identity(mp, masking.MaskIdentity(seed=1,
                                                        mode="sample"))
    b = masking.freeze_identity(mp, masking.MaskIdentity(seed=2,
                                                        mode="sample"))
    a2 = masking.freeze_identity(mp, masking.MaskIdentity(seed=1,
                                                         mode="sample"))
    assert not np.array_equal(np.asarray(a["w_x"]), np.asarray(b["w_x"]))
    assert np.array_equal(np.asarray(a["w_x"]), np.asarray(a2["w_x"]))
    # every tenant shares the SAME frozen w: where both masks are on,
    # the effective weights agree
    wa, wb = np.asarray(a["w_x"], np.float32), np.asarray(b["w_x"],
                                                          np.float32)
    both = (wa != 0) & (wb != 0)
    assert both.any()
    assert np.array_equal(wa[both], wb[both])


def test_hbm_accounting_helpers():
    mp = _tiny_mp()
    # one masked (16, 8) bf16 leaf -> 16*8*2 bytes delta; packed mask
    # artifact: ceil(128/32) = 4 words = 16 bytes
    assert masking.masked_delta_bytes(mp) == 16 * 8 * 2
    assert masking.mask_artifact_bytes(mp) == 16


# ---------------------------------------------------------------------------
# Decode vs fused training forward (the frozen-decode residue)
# ---------------------------------------------------------------------------

# one family per decode code path: dense attention, ssm (conv1d_step),
# hybrid (conv1d_step + attention mix)
DECODE_FAMILIES = ("internlm2-1.8b", "mamba2-370m", "recurrentgemma-9b")


@pytest.mark.parametrize("name", DECODE_FAMILIES)
@pytest.mark.parametrize("mode", ("sample", "threshold"))
def test_frozen_decode_matches_fused_training_forward(name, mode):
    """`freeze_for_decode(masked_forward_tree(...))` full-sequence
    decode must match the fused training-path forward on the same
    tokens — decode correctness as a tested property instead of a
    docstring claim (covers the `conv1d_step` frozen-decode
    residue)."""
    cfg, api, key, mp = _build(name, seed=5)
    B, S = 2, 10
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    seed_fn = lambda i: masking.mask_stream_seed(0, 0, i, 0, run_seed=9)

    fused_tree = masking.masked_forward_tree(mp, seed_fn, mode=mode)
    ref_logits = api.forward(fused_tree, {"tokens": tokens})[0]

    frozen = masking.freeze_for_decode(fused_tree)
    cache = api.init_cache(B, S)
    dec = jax.jit(api.decode_step)
    errs = []
    for t in range(S):
        logits, cache = dec(frozen, cache, tokens[:, t],
                            jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(logits - ref_logits[:, t]))))
    # hybrid crosses TWO implementation boundaries (frozen plain
    # matmuls compiled by XLA vs the fused Pallas kernels, whose f32
    # tile accumulation orders differ at bf16 precision) on top of the
    # bf16 ring-buffer KV cache: measured drift reaches ~0.09, while a
    # genuinely wrong mask shows O(1) logit changes.
    tol = 0.15 if cfg.family == "hybrid" else 0.02
    assert max(errs) < tol, f"{name}/{mode}: {errs}"


@pytest.mark.parametrize("name,tol", (("mamba2-370m", None),
                                      ("recurrentgemma-9b", 0.15)))
def test_unfrozen_masked_decode_matches_frozen(name, tol):
    """Decoding straight through the UNFROZEN MaskedLeaf tree (the
    per-token `conv1d_step` -> `effective_weight` materializing
    residue plus fused dense kernels) samples the SAME mask stream as
    `freeze_for_decode`: ssm decode is bit-identical, and the hybrid
    stays within accumulation noise.

    The hybrid is NOT bit-exact: its layer scan compiles the frozen
    path's plain bf16 matmuls into XLA fusions whose accumulation
    order differs from the Pallas kernels' fixed f32 tile loop
    (verified leaf-by-leaf that the masks themselves are identical —
    `materialize_leaf` == fused kernel output outside the scan).  A
    wrong mask would show O(1) logit changes; the measured
    accumulation drift is <= ~0.09."""
    cfg, api, key, mp = _build(name, seed=6)
    B, S = 1, 6
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    seed_fn = lambda i: masking.mask_stream_seed(0, 0, i, 0, run_seed=4)
    tree = masking.masked_forward_tree(mp, seed_fn, mode="sample")
    frozen = masking.freeze_for_decode(tree)

    c1, c2 = api.init_cache(B, S), api.init_cache(B, S)
    for t in range(S):
        l1, c1 = api.decode_step(frozen, c1, tokens[:, t],
                                 jnp.asarray(t, jnp.int32))
        l2, c2 = api.decode_step(tree, c2, tokens[:, t],
                                 jnp.asarray(t, jnp.int32))
        if tol is None:
            assert np.array_equal(np.asarray(l1), np.asarray(l2)), \
                f"{name}: frozen vs unfrozen decode diverged at t={t}"
        else:
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                       atol=tol, rtol=0)


# ---------------------------------------------------------------------------
# launch/serve.py smoke: prefill/decode split + multi-tenant invocation
# ---------------------------------------------------------------------------


def test_serve_main_single_tenant_timing_split(capsys):
    from repro.launch import serve
    serve.main(["--arch", "internlm2-1.8b", "--smoke", "--batch", "2",
                "--prompt-len", "6", "--tokens", "4"])
    out = capsys.readouterr().out
    assert "prefill" in out and "decode" in out
    assert "tok/s" in out


def test_serve_main_multi_tenant(capsys):
    from repro.launch import serve
    serve.main(["--arch", "internlm2-1.8b", "--smoke",
                "--prompt-len", "6", "--tokens", "4", "--tenants", "3",
                "--slots", "2", "--cache-capacity", "2"])
    out = capsys.readouterr().out
    assert "3/3 tenants served" in out
    assert "freeze-cache" in out and "evictions" in out
    assert "resident HBM: 1 x w" in out
