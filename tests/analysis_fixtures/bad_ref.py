"""Fixture ref module for bad_kernels.py: holds no oracle for the
exported kernels."""


def unrelated_helper(x):
    return x
