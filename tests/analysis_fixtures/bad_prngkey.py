"""Fixture: the pre-fix launch/steps.py downlink-quantizer key — a
hard-coded constant PRNGKey folded only with the step counter, so the
stream silently ignores --seed.  The bare-prngkey rule must flag it."""
import jax


def quantizer_key(step):
    return jax.random.fold_in(jax.random.PRNGKey(29), step)
