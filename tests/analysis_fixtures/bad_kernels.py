"""Fixture kernels module: exports a Pallas kernel (and its backward)
with no matching oracle in bad_ref.py and no REPRO_REF_BWD hatch in
bad_ops.py.  The missing-oracle and missing-ref-bwd-hatch rules must
flag both."""
from jax.experimental import pallas as pl


def masked_matmul_new(x, w, s):
    return pl.pallas_call(lambda *refs: None)(x, w, s)


def masked_matmul_new_ds(x, w, s, g):
    return pl.pallas_call(lambda *refs: None)(x, w, s, g)
