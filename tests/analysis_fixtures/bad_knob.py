"""Fixture: reads a REPRO_* env knob that has no row in the README
env-knob table.  The knob-doc rule must flag the read."""
import os


def undocumented_knob() -> bool:
    return os.environ.get("REPRO_BOGUS_KNOB", "") == "1"
