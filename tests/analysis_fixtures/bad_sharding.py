"""Fixture param trees that trip `repro.analysis.shard_lint`'s
``shard-silent-replication`` rule: every dim of the big leaf is
indivisible by every mesh axis size on the debug pod mesh, so
`launch/sharding.py` falls back to full replication — silently, before
`explain_spec` started recording the skipped dims.

`tests/test_collective.py` asserts the rule fires here and stays quiet
on the real registry trees.
"""
import jax

# all dims odd/prime -> no axis of a (2, 2, 2) or (2, 2, 1) debug mesh
# divides them; body is >> the 1024-element noise floor
BAD_TREE_SHAPES = {
    "blocks": {
        # scan dim 3 is fine; (129, 257) replicates with skips
        "w_odd": jax.ShapeDtypeStruct((3, 129, 257), "float32"),
    },
    # deliberately-replicated small leaf: must NOT fire
    "norm": {"scale": jax.ShapeDtypeStruct((3, 7), "float32")},
}
