"""Fixture: a weight-sized materialization outside the allowlist — a
training-path helper quietly materializing every MaskedLeaf.  The
materialize-allowlist rule must flag both calls."""
from repro.core import masking
from repro.models import layers


def sneaky_forward(tree, leaf):
    w = layers.effective_weight(leaf)
    return w, masking.materialize_leaf(tree)
