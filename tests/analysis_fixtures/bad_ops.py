"""Fixture ops module for bad_kernels.py: dispatches the backward
kernel with no REPRO_REF_BWD escape hatch anywhere."""
from tests.analysis_fixtures import bad_kernels


def masked_dense_new_bwd(x, w, s, g):
    return bad_kernels.masked_matmul_new_ds(x, w, s, g)
