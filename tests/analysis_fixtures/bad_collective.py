"""Fixture round-uplink bodies that VIOLATE the collective wire-purity
rules (`repro.analysis.collective_lint`).

Each builder returns a shard-mapped callable whose jaxpr contains
exactly the collective the named rule must flag.  `tests/
test_collective.py` traces each one on the debug pod mesh and asserts
the rule fires — a rule with no firing fixture is a dead gate.
"""
import jax
import jax.numpy as jnp

from repro.launch.steps import _shard_map

P = jax.sharding.PartitionSpec


def f32_score_all_gather(mesh):
    """Ships the raw f32 score tensor across pods.

    Must fire ``collective-f32-weight``: a weight-shaped float operand
    crossing the uplink collective."""
    def body(scores):
        return jax.lax.all_gather(scores, "pod")
    return _shard_map(body, mesh, (P(),), P("pod"))


def u8_mask_all_gather(mesh):
    """Gathers the sampled mask as one byte per parameter (8x the
    packed wire size).

    Must fire ``collective-unpacked-mask``: an integer mask crossing a
    collective without bitpacking."""
    def body(scores):
        mask = (scores > 0).astype(jnp.uint8)
        return jax.lax.all_gather(mask, "pod")
    return _shard_map(body, mesh, (P(),), P("pod"))


def bf16_mask_pmean(mesh):
    """Averages bf16 mask indicators across pods — the pre-bitpack
    baseline aggregation (16 bits per parameter on the wire).

    Must fire ``collective-f32-weight``: a non-sidecar float operand
    in a cross-pod psum."""
    def body(scores):
        mask = (scores > 0).astype(jnp.bfloat16)
        return jax.lax.pmean(mask, "pod")
    return _shard_map(body, mesh, (P(),), P())


ALL = {
    "collective-f32-weight": f32_score_all_gather,
    "collective-unpacked-mask": u8_mask_all_gather,
    "collective-f32-weight/pmean": bf16_mask_pmean,
}
