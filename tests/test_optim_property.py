"""Randomized property sweeps for the optimizer library.

Requires `hypothesis`; skips cleanly when it is absent — a fixed-grid
version lives in test_optim.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.optim import optimizers as optlib


@given(st.integers(1, 500))
@settings(max_examples=10, deadline=None)
def test_warmup_cosine_schedule_monotone_warmup(total):
    sched = optlib.warmup_cosine(1.0, warmup=10, total_steps=total + 10)
    vals = [float(sched(jnp.asarray(s))) for s in range(10)]
    assert all(vals[i] <= vals[i + 1] + 1e-6 for i in range(9))
    assert abs(vals[-1] - 1.0) < 0.12
    end = float(sched(jnp.asarray(total + 9)))
    assert end <= 1.0
