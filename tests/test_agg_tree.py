"""Aggregator-tree gates (repro.runtime.agg_tree).

The hard invariants from docs/DESIGN.md §5:

  * IDENTITY: at zero faults / zero adversaries the tree path commits
    bit-identically to the flat `AsyncRoundEngine` — theta AND the
    measured wire bits (dyadic cohort: equal sizes, power-of-two K).
  * O(params): the pooled root record's size matches
    `analysis.comm_model.tree_root_record_bits` exactly and does not
    depend on how many clients folded.
  * BYZANTINE: density bombs, all-zero uplinks, and forged-checksum
    bit-flips are quarantined BEFORE they enter a fold; the commit
    aggregates exactly the honest cohort.
  * FAILURE DOMAINS: an edge-aggregator crash replays its uncommitted
    fold deterministically; the crashed run's theta equals the
    uncrashed run's bitwise; crash-consistent save/restore continues a
    faulted run event-identically.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.analysis import comm_model
from repro.core import aggregation, masking
from repro.models import cnn
from repro.data import synthetic, partition
from repro.runtime.async_engine import AsyncConfig, AsyncRoundEngine
from repro.runtime.agg_tree import ByzantineFilter, TreeConfig, \
    TreeRoundEngine, TreeTopology
from repro.runtime.fault import FaultInjector
from repro.api import payloads as plds

KEY = jax.random.PRNGKey(0)
CFG = cnn.ConvConfig("t", (8, 8), (16,), n_classes=4, img_size=8)
SPEC = masking.MaskSpec()
# dyadic cohort: 4 EQUAL-size clients so the commit weights are exactly
# 0.25 in f32 and the flat tensordot's partial sums are exact — the
# precondition for the tree-vs-flat bit-identity gate
K, H, B = 4, 2, 8

_NONE = lambda x: x is None


@pytest.fixture(scope="module")
def setup():
    task = synthetic.make_image_task(KEY, n=256, img=8, n_classes=4,
                                     noise=0.3)
    params = cnn.init_params(KEY, CFG)
    apply_fn = lambda p, b: cnn.forward(p, CFG, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    rng = np.random.default_rng(0)
    cidx = partition.partition_iid(rng, np.asarray(task.y), K)
    assert len({len(c) for c in cidx}) == 1, "cohort must be equal-size"
    data = synthetic.federated_batches(KEY, task, cidx, K, H, B)
    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    algo = api.get_algorithm("fedpm_reg", apply_fn, loss_fn, spec=SPEC,
                             local_steps=H)
    return dict(algo=algo, params=params, data=data, sizes=sizes,
                apply_fn=apply_fn, loss_fn=loss_fn)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree, is_leaf=_NONE)


def _assert_trees_equal(a, b):
    for la, lb in zip(_leaves(a), _leaves(b)):
        if la is None:
            assert lb is None
            continue
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_trees_close(a, b, **kw):
    for la, lb in zip(_leaves(a), _leaves(b)):
        if la is None:
            assert lb is None
            continue
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32), **kw)


def _tree_engine(setup, **kw):
    kw.setdefault("tree", TreeConfig(fanout=2))
    return TreeRoundEngine(setup["algo"],
                           setup["algo"].init(KEY, setup["params"]),
                           setup["data"], setup["sizes"], KEY, **kw)


# ---------------------------------------------------------------------------
# unit: the exact-count kernel and its wire form
# ---------------------------------------------------------------------------


def test_mean_from_counts_matches_mean_from_words_dyadic():
    """Pooled integer counts + dyadic weights reproduce the flat
    bit-matrix tensordot BITWISE, under any client->edge grouping."""
    rng = np.random.default_rng(3)
    n, Kc = 70, 4
    bits = rng.integers(0, 2, size=(Kc, n)).astype(np.uint8)
    words = jnp.stack([plds.pack_leaf(jnp.asarray(b)) for b in bits])
    w = jnp.full((Kc,), 0.25, jnp.float32)
    flat = plds.mean_from_words(words, n, w)
    # pool counts over an uneven grouping {0,2} | {1} | {3}
    P = 32 * ((n + 31) // 32)
    groups = [[0, 2], [1], [3]]
    counts = np.zeros((1, P), np.int64)
    for g in groups:
        for i in g:
            counts[0] += np.pad(bits[i], (0, P - n)).astype(np.int64)
    pooled = plds.mean_from_counts(jnp.asarray(counts), n,
                                   jnp.asarray([0.25], jnp.float32))
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(pooled))


def test_pack_counts_roundtrip_and_overflow():
    rng = np.random.default_rng(0)
    for acc_bits in (8, 16, 32):
        c = rng.integers(0, 2 ** acc_bits, size=71).astype(np.int64)
        words = aggregation.pack_counts(c, acc_bits)
        assert words.dtype == np.uint32
        assert 32 * words.size == aggregation.packed_count_bits(
            71, acc_bits)
        back = aggregation.unpack_counts(words, 71, acc_bits)
        np.testing.assert_array_equal(back, c)
    with pytest.raises(OverflowError):
        aggregation.pack_counts(np.asarray([256], np.int64), 8)
    with pytest.raises(OverflowError):
        aggregation.pack_counts(np.asarray([-1], np.int64), 16)


def test_byzantine_filter_zscore_and_trim():
    cfg = TreeConfig(min_cohort=8, z_thresh=4.0, z_floor=0.02,
                     trim_frac=0.25)
    byz = ByzantineFilter(cfg)
    # warm-up: no decisions before min_cohort admitted folds
    assert byz.zscore(0.99) == 0.0
    rng = np.random.default_rng(1)
    for d in rng.normal(0.5, 0.01, size=32):
        byz.admit(float(d))
    assert byz.zscore(0.5) < 1.0
    assert byz.zscore(0.9) > cfg.z_thresh
    # one outlier in a clean cohort: quarantined, not trimmed
    adm, quar, trimmed = byz.screen([0.5, 0.51, 0.9, 0.49])
    assert not trimmed and list(quar) == [2] and adm == [0, 1, 3]
    # half the cohort "anomalous": the statistics are suspect ->
    # trimmed fallback keeps all but the ceil(trim_frac * m) extremes
    adm, quar, trimmed = byz.screen([0.9, 0.5, 0.95, 0.85, 0.92, 0.5])
    assert trimmed
    assert len(quar) == 2                      # ceil(0.25 * 6)
    assert list(sorted(quar)) == [2, 4]        # the two largest z
    assert adm == [0, 1, 3, 5]
    # state round-trips exactly
    byz2 = ByzantineFilter(cfg)
    byz2.load_state(byz.state_dict())
    assert byz2.state_dict() == byz.state_dict()


def test_fedavg_cannot_ride_the_tree(setup):
    algo = api.get_algorithm("fedavg", setup["apply_fn"],
                             setup["loss_fn"], local_steps=H)
    with pytest.raises(ValueError, match="pooled_aggregate"):
        TreeRoundEngine(algo, algo.init(KEY, setup["params"]),
                        setup["data"], setup["sizes"], KEY)


def test_tree_topology_round_mask():
    topo = TreeTopology(8, fanout=2, agg_fault_prob=0.5, seed=3)
    alive = np.ones(8, bool)
    crashed_rounds = [r for r in range(20)
                      if topo.crashed_edges(r).any()]
    assert crashed_rounds, "fault draws must fire at p=0.5"
    r = crashed_rounds[0]
    masked = topo.round_mask(alive, r)
    crashed = topo.crashed_edges(r)
    for c in range(8):
        assert masked[c] == (alive[c] and not crashed[c // 2])
    # all-crash rescue: the lowest edge is adopted, never an empty round
    topo_all = TreeTopology(4, fanout=2, agg_fault_prob=1.0, seed=0)
    assert topo_all.surviving_edges(0) == 1
    assert topo_all.round_mask(np.ones(4, bool), 0).sum() == 2


# ---------------------------------------------------------------------------
# the identity gate: tree == flat at zero faults / zero adversaries
# ---------------------------------------------------------------------------


def test_zero_fault_tree_bit_identical_to_flat(setup):
    """ISSUE gate: with no faults and no adversaries the tree path is
    bit-identical to the flat `AsyncRoundEngine` commit — theta AND the
    measured wire bits — and its event stream stays {fold, commit}."""
    algo, data, sizes = setup["algo"], setup["data"], setup["sizes"]
    flat = AsyncRoundEngine(algo, algo.init(KEY, setup["params"]),
                            data, sizes, KEY)
    tree = _tree_engine(setup)
    for t in range(3):
        cf = flat.tick(data)
        ct = tree.tick(data)
        assert len(cf) == len(ct) == 1
        assert cf[0]["uplink_bits_measured"] \
            == ct[0]["uplink_bits_measured"]
        assert cf[0]["uplink_header_bits"] \
            == ct[0]["uplink_header_bits"]
        assert cf[0]["n_folded"] == ct[0]["n_folded"] == K
        _assert_trees_equal(flat.state.theta, tree.state.theta)
        # float sidecar / weighted metrics pool in a different
        # association order — equal to tolerance, not bitwise
        _assert_trees_close(flat.state.floats, tree.state.floats,
                            rtol=1e-5, atol=1e-6)
        assert ct[0]["uplink_bpp"] == pytest.approx(
            cf[0]["uplink_bpp"], rel=1e-5)
        assert ct[0]["loss"] == pytest.approx(cf[0]["loss"], rel=1e-4)
    assert {e["kind"] for e in flat.events} == {"fold", "commit"}
    assert {e["kind"] for e in tree.events} == {"fold", "commit"}
    assert tree.totals["root_bits_measured"] > 0


def test_root_record_bits_match_static_model(setup):
    """CommLedger-side root traffic == the static `comm_model` table,
    exactly, and per-record size is independent of the folded count."""
    tree = _tree_engine(setup)
    c = tree.tick(setup["data"])[0]
    tmpl = tree._payload_template
    leaf_params = [plds._prod(sh) for sh in tmpl.shapes]
    float_elems = sum(int(f.size) for f in _leaves(tmpl.floats)
                      if f is not None)
    # metric count from a real launch record
    probe = _tree_engine(setup)
    probe._launch(setup["data"], 0)
    n_metrics = len(probe.pending[0].metrics)
    st = comm_model.tree_root_round_bits(
        leaf_params, tree.n_edges, acc_bits=tree.tree.acc_bits,
        n_classes=1, float_elems=float_elems, n_metrics=n_metrics)
    assert st["root_bits"] == c["root_bits_measured"]
    assert st["root_header_bits"] == c["root_header_bits"]


# ---------------------------------------------------------------------------
# Byzantine quarantine
# ---------------------------------------------------------------------------


def _honest_oracle(setup, eng, honest, t=0):
    """Reference aggregate over the honest slice of tick t's launch."""
    algo, sizes = setup["algo"], setup["sizes"]
    state0 = algo.init(KEY, setup["params"])
    key = jax.random.fold_in(KEY, t)
    _, payloads, _ = eng._client_phase(state0, setup["data"], key)
    sel = [plds.slice_payload(payloads, c) for c in honest]
    batched = plds.stack_payloads(sel)
    w = jnp.asarray([float(sizes[c]) for c in honest], jnp.float32)
    wn = w / jnp.sum(w)
    return algo.aggregate(state0, batched, wn,
                          jnp.ones((len(honest),), bool))


@pytest.mark.parametrize("role,reason", [("ones", "density"),
                                         ("zeros", "density"),
                                         ("flip", "decl_mismatch")])
def test_adversary_quarantined_before_fold(setup, role, reason):
    """Density bombs are caught by the absolute bounds, forged-CRC
    bit-flips by the pre-decode popcount declaration; either way the
    commit aggregates exactly the honest cohort."""
    eng = _tree_engine(setup, adversary={1: role},
                       config=AsyncConfig(quorum_frac=0.5))
    commits = eng.tick(setup["data"])
    assert len(commits) == 1
    q = [e for e in eng.events if e["kind"] == "byz_quarantine"]
    assert [(e["client"], e["reason"]) for e in q] == [(1, reason)]
    assert eng.byz_quarantined == {reason: 1}
    honest = [c for c in range(K) if c != 1]
    assert commits[0]["clients"] == honest
    assert commits[0]["n_folded"] == K - 1
    ref = _honest_oracle(setup, eng, honest)
    _assert_trees_close(eng.state.theta, ref.theta,
                        rtol=1e-5, atol=1e-6)
    # the tamper passed CRC verification — the declaration caught it
    assert not any(e["kind"] == "corrupt_reject" for e in eng.events)


def test_flip_without_declaration_would_fold(setup):
    """Sanity on the threat model: the forged-CRC flip is INVISIBLE to
    checksum verification — remove the declaration and it folds.
    (Bitpack codec: a one-bit flip shifts density by 1/n, so no other
    filter stage can catch it either.)"""
    eng = _tree_engine(setup, adversary={1: "flip"}, codec="bitpack",
                       config=AsyncConfig(quorum_frac=0.5))
    eng._launch(setup["data"], 0)
    assert all(e.msg.verify() for e in eng.pending)
    eng._decl.clear()
    eng._deliver(0)
    assert not any(e["kind"] == "byz_quarantine" for e in eng.events)
    assert sum(e["kind"] == "fold" for e in eng.events) == K


# ---------------------------------------------------------------------------
# failure domains: crash, failover, replay, partition
# ---------------------------------------------------------------------------


def _force_edge_faults(eng, schedule):
    """Deterministically override the per-tick aggregator fault draws:
    schedule[t] = (crashed_edges, partitioned_edges)."""
    def fake(t):
        crashed = np.zeros(eng.n_edges, bool)
        parted = np.zeros(eng.n_edges, bool)
        cr, pa = schedule.get(t, ((), ()))
        crashed[list(cr)] = True
        parted[list(pa)] = True
        return crashed, parted
    eng._edge_alive = fake


def _partial_fold_engine(setup, eng):
    """Launch tick 0 and deliver everyone EXCEPT client 3 (delayed one
    tick), leaving an uncommitted partial fold on the edges."""
    eng._launch(setup["data"], 0)
    eng.pending[3].deliver = 1
    eng._deliver(0)
    assert not eng._maybe_commit(0)      # 3 < quorum of 4
    eng.tick_idx = 1


def test_edge_crash_replay_is_lossless(setup):
    """A crash destroys edge 0's buffered partial fold (clients 0, 1);
    replay from the fold log + failover to the sibling reconstructs it
    EXACTLY: the crashed run commits theta bitwise equal to the
    uncrashed run's, and the replayed deliveries are re-metered as real
    wire traffic."""
    mk = lambda: _tree_engine(
        setup, config=AsyncConfig(quorum_frac=1.0, deadline_rounds=10))
    ref, eng = mk(), mk()
    _force_edge_faults(ref, {})
    _force_edge_faults(eng, {1: ((0,), ())})   # edge 0 dies at tick 1
    _partial_fold_engine(setup, ref)
    _partial_fold_engine(setup, eng)
    c_ref = ref.flush()
    c_eng = eng.flush()
    assert not any(e["kind"] == "agg_crash" for e in ref.events)
    crash = [e for e in eng.events if e["kind"] == "agg_crash"]
    assert crash and crash[0]["lost"] == 2     # fanout-2 edge was full
    replays = [e for e in eng.events if e["kind"] == "replay"]
    assert {e["client"] for e in replays} == {0, 1}
    fo = [e for e in eng.events if e["kind"] == "failover"]
    assert {e["client"] for e in fo} == {0, 1}
    # integer count pooling is grouping-invariant: the re-routed fold
    # commits the identical theta
    assert len(c_ref) == len(c_eng) == 1
    _assert_trees_equal(ref.state.theta, eng.state.theta)
    _assert_trees_close(ref.state.floats, eng.state.floats,
                        rtol=1e-5, atol=1e-6)
    assert c_eng[0]["uplink_bits_measured"] \
        > c_ref[0]["uplink_bits_measured"]
    assert c_eng[0]["n_folded"] == c_ref[0]["n_folded"] == K
    assert eng.buffer_ones == ref.buffer_ones == 0


def test_edge_crash_without_failover_requeues(setup):
    eng = _tree_engine(
        setup, tree=TreeConfig(fanout=2, failover=False),
        config=AsyncConfig(quorum_frac=1.0, deadline_rounds=10))
    _force_edge_faults(eng, {0: ((0,), ())})
    eng.tick(setup["data"])
    ua = [e for e in eng.events if e["kind"] == "agg_unavailable"]
    assert {e["client"] for e in ua} == {0, 1}
    assert not any(e["kind"] == "failover" for e in eng.events)
    # the requeued uplinks consumed no wire this tick
    folded_now = [e for e in eng.events if e["kind"] == "fold"]
    assert {e["client"] for e in folded_now} == {2, 3}
    eng._edge_alive = lambda t: (np.zeros(2, bool), np.zeros(2, bool))
    commits = eng.flush()
    assert commits and commits[0]["n_folded"] == K


def test_edge_partition_delays_without_wire(setup):
    """A partitioned edge delays its deliveries one tick; they hit the
    wire exactly once, so the run's totals and committed state match a
    fault-free run bitwise."""
    ref = _tree_engine(
        setup, config=AsyncConfig(quorum_frac=1.0, deadline_rounds=10))
    eng = _tree_engine(
        setup, config=AsyncConfig(quorum_frac=1.0, deadline_rounds=10))
    _force_edge_faults(ref, {})
    _force_edge_faults(eng, {0: ((), (1,))})
    c_ref = ref.tick(setup["data"])
    assert len(c_ref) == 1
    c_eng = eng.tick(setup["data"])
    assert not c_eng                      # folded 2 < quorum 4
    pa = [e for e in eng.events if e["kind"] == "agg_partition"]
    assert {e["client"] for e in pa} == {2, 3}
    c_eng = eng.flush()
    assert c_eng and c_eng[0]["n_folded"] == K
    assert eng.totals["uplink_bits_measured"] \
        == ref.totals["uplink_bits_measured"]
    _assert_trees_equal(ref.state.theta, eng.state.theta)
    _assert_trees_equal(ref.state.floats, eng.state.floats)


def test_faulted_run_is_deterministic(setup):
    def run():
        inj = FaultInjector(K, seed=7, agg_crash_prob=0.3,
                            agg_partition_prob=0.15, corrupt_prob=0.1)
        eng = _tree_engine(
            setup, injector=inj,
            config=AsyncConfig(quorum_frac=0.75, deadline_rounds=2))
        for _ in range(6):
            eng.tick(setup["data"])
        eng.flush()
        return eng
    a, b = run(), run()
    assert a.events == b.events
    _assert_trees_equal(a.state, b.state)
    assert a.totals == b.totals
    assert a.byz.state_dict() == b.byz.state_dict()


# ---------------------------------------------------------------------------
# crash-consistent save / restore
# ---------------------------------------------------------------------------


def _faulted_pair(setup):
    def mk():
        inj = FaultInjector(K, seed=7, agg_crash_prob=0.3,
                            agg_partition_prob=0.15)
        return _tree_engine(
            setup, injector=inj,
            config=AsyncConfig(quorum_frac=0.75, deadline_rounds=2))
    return mk(), mk()


def test_save_restore_continues_identically(setup, tmp_path):
    ref, eng = _faulted_pair(setup)
    for t in range(3):
        ref.tick(setup["data"])
        eng.tick(setup["data"])
    path = os.path.join(tmp_path, "eng")
    eng.save(path)
    _, fresh = _faulted_pair(setup)
    fresh.restore(path)
    assert not fresh._degraded_restore
    assert fresh.byz.state_dict() == eng.byz.state_dict()
    for t in range(3, 6):
        ref.tick(setup["data"])
        fresh.tick(setup["data"])
    ref.flush()
    fresh.flush()
    assert fresh.events == ref.events
    _assert_trees_equal(fresh.state, ref.state)
    assert fresh.totals == ref.totals


def test_corrupt_fold_log_degrades_restore(setup, tmp_path):
    """A tampered fold-log checksum must refuse the buffered state and
    fall back to the degraded theta-only restore (base-engine
    doctrine), clearing the tree accumulators."""
    eng = _tree_engine(
        setup, config=AsyncConfig(quorum_frac=1.0, deadline_rounds=10))
    _partial_fold_engine(setup, eng)     # folds logged, no commit
    assert any(edge.log for edge in eng.edges)
    path = os.path.join(tmp_path, "eng")
    eng.save(path)
    man = json.load(open(path + ".json"))
    logs = man["extra"]["tree"]["edges"][0]["log"]
    assert logs
    logs[0]["checksum"] = (logs[0]["checksum"] + 1) % (1 << 32)
    with open(path + ".json", "w") as f:
        json.dump(man, f)
    fresh = _tree_engine(
        setup, config=AsyncConfig(quorum_frac=1.0, deadline_rounds=10))
    fresh.restore(path)
    assert fresh._degraded_restore
    assert fresh.events[-1]["kind"] == "restore_degraded"
    assert not fresh.pending
    assert all(not e.log and not e.classes for e in fresh.edges)
    _assert_trees_equal(fresh.state, eng.state)
