"""Canonical lowered steps for the production mesh.

Federated mapping at pod scale (docs/DESIGN.md §2): a *cohort* (= FL client
site) is one pod (multi-pod mesh) or the whole pod (single-pod). Inside
a cohort, data-parallel slices share synchronized score updates (the
site's local cluster); ACROSS cohorts the ONLY traffic is the paper's
mask exchange at round boundaries — the slow inter-pod DCN link is
exactly the uplink the paper's 1-bit protocol compresses.

Lowered artifacts per training cell:
  * train_step  — one local mini-batch score update (no cross-pod comm)
  * round_step  — mask sample + (bitpacked) cross-pod aggregation
  * fedavg_step — float baseline: grads all-reduced across everything

Serving cells lower serve_step (one-token decode over a full KV cache).

State layout: scores/floats/opt carry a leading cohort axis C sharded
on "pod"; frozen weights have no cohort axis (same seed everywhere).

train_step runs the FUSED masked-execution path by default: the model
forward consumes `masking.MaskedLeaf` (w, s, seed) bundles and every
maskable leaf runs its fused kernel — `ops.masked_dense` for 2-D
projections, `ops.masked_dense_grouped` for stacked (E, K, N) MoE
expert weights, `ops.masked_conv1d` for depthwise conv kernels — so
the mask and the masked weights never exist in HBM on either pass,
for ANY maskable leaf shape (docs/DESIGN.md §3).
`REPRO_EFF_PATH=1` is the escape hatch: identical hash-stream masks,
but materialized through `masking.hash_effective` (the pre-fusion
reference semantics, for debugging/bisection).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.api import codecs as codecs_lib
from repro.api import payloads as plds
from repro.core import masking, regularizer, aggregation
from repro.core.masking import MaskedParams
from repro.kernels import ref as kref
from repro.launch import sharding as shd

Pytree = Any


def _shard_map(fn, mesh, in_specs, out_specs):
    """jax.shard_map moved out of jax.experimental after 0.4.x and the
    check_rep kwarg was later renamed check_vma; both moves happened in
    different releases, so resolve home and kwarg name independently."""
    import inspect
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    kw = ("check_vma"
          if "check_vma" in inspect.signature(sm).parameters
          else "check_rep")
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: False})


def n_cohorts(mesh) -> int:
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1


@dataclasses.dataclass(frozen=True)
class StepConfig:
    lam: float = 1.0
    lr: float = 0.1
    float_lr: float = 0.01
    momentum: float = 0.9
    chunk_kv: Optional[int] = None   # chunked attention for long seq
    packed_masks: bool = True        # bitpacked cross-pod aggregation
    score_dtype: Any = jnp.float32
    microbatch: int = 1              # grad-accumulation chunks
    optimizer: str = "momentum"      # "momentum" | "adam" (scores)
    adam_eps: float = 1e-8
    downlink_bits: int = 0           # k-bit theta broadcast (0 = f32)
    seed: int = 17                   # run seed mixed into every mask
    #                                  stream (forward AND uplink) —
    #                                  plumbed from --seed in train.py
    mask_mode: str = "sample"        # "sample" (Bernoulli, fedpm*) |
    #                                  "threshold" (FedMask)
    tau: float = 0.5                 # threshold for mask_mode="threshold"


# sentinel "leaf index" for the downlink-quantizer key stream: far above
# any real leaf index, so `mask_stream_seed` cannot hand the quantizer a
# mask stream of the same (step, dev=0, cohort) coordinates
_DOWNLINK_STREAM_LEAF = 1 << 20


# ---------------------------------------------------------------------------
# State construction (shape-only friendly: works under jax.eval_shape)
# ---------------------------------------------------------------------------


def init_fed_state(key, api, spec: masking.MaskSpec, C: int,
                   score_dtype=jnp.float32, optimizer: str = "momentum"):
    params_like = api.init_params(key)
    mp = masking.init_masked(key, params_like, spec,
                             score_dtype=score_dtype)

    def rep(tree):  # add cohort axis
        return jax.tree_util.tree_map(
            lambda x: None if x is None else jnp.broadcast_to(
                x[None], (C,) + x.shape),
            tree, is_leaf=lambda x: x is None)

    scores = rep(mp.scores)
    zeros_like = lambda t: jax.tree_util.tree_map(
        lambda x: None if x is None else jnp.zeros_like(x), t,
        is_leaf=lambda x: x is None)
    state = {
        "scores": scores,
        "floats": rep(mp.floats),
        "weights": mp.weights,
        "opt_m": zeros_like(scores),
        "step": jnp.zeros((), jnp.int32),
    }
    if optimizer == "adam":
        state["opt_v"] = zeros_like(scores)
    return state


def fed_state_shardings(state_shapes, mesh):
    """Shardings for the federated state pytree (cohort axis -> pod)."""
    has_pod = "pod" in mesh.axis_names

    def score_like(tree):
        def one(path, leaf):
            if leaf is None:
                return None
            p = shd._path_str(path)
            # leading cohort axis (+ possibly a layer-stack axis after)
            sd = 1 + (0 if any(t in p.lower() for t in
                               ("embed", "final_norm", "lm_head",
                                "pos_embed")) else 1)
            sd = min(sd, max(len(leaf.shape) - 1, 0))
            ps = shd.param_spec(p, leaf.shape, mesh, scan_dims=sd)
            spec = list(ps) + [None] * (len(leaf.shape) - len(list(ps)))
            if has_pod:
                spec[0] = "pod"
            return jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(*spec))
        return jax.tree_util.tree_map_with_path(
            one, tree, is_leaf=lambda x: x is None)

    out = {
        "scores": score_like(state_shapes["scores"]),
        "floats": score_like(state_shapes["floats"]),
        "weights": shd.tree_param_shardings(state_shapes["weights"], mesh),
        "opt_m": score_like(state_shapes["opt_m"]),
        "step": shd.replicated(mesh),
    }
    if "opt_v" in state_shapes:
        out["opt_v"] = score_like(state_shapes["opt_v"])
    return out


# ---------------------------------------------------------------------------
# train_step: one local mini-batch update (no cross-pod traffic)
# ---------------------------------------------------------------------------


def _eff_path() -> bool:
    """REPRO_EFF_PATH=1 escape hatch (checked at trace time): train
    through materialized effective params (`masking.hash_effective`) —
    bit-identical hash-stream masks, pre-fusion memory behaviour."""
    return os.environ.get("REPRO_EFF_PATH", "") == "1"


def make_train_step(api, cfg: StepConfig):
    """One local mini-batch score update on the fused masked-execution
    path: the forward consumes a `masked_forward_tree` whose maskable
    leaves run the fused kernels (dense / grouped-expert / conv) with
    scores as a first-class grad argument (STE custom-vjp), per-leaf
    seeds derived from
    (cfg.seed, step, leaf, cohort) by the SAME `mask_stream_seed`
    convention the round uplink samples with."""
    def cohort_loss(scores, floats, weights, batch, tick, cohort):
        mp = MaskedParams(weights, scores, floats)
        seed_fn = lambda i: masking.mask_stream_seed(
            tick, 0, i, cohort, run_seed=cfg.seed)
        build = (masking.hash_effective if _eff_path()
                 else masking.masked_forward_tree)
        params = build(mp, seed_fn, mode=cfg.mask_mode, tau=cfg.tau)
        out = api.forward(params, batch, chunk_kv=cfg.chunk_kv)
        loss = api.loss(out, batch)
        reg = regularizer.entropy_proxy(scores)
        return loss + cfg.lam * reg, (loss, reg)

    def train_step(state, batch):
        C = jax.tree_util.tree_leaves(state["scores"])[0].shape[0]

        def one(scores, floats, opt_m, opt_v, batch_c, idx):
            if cfg.microbatch > 1:
                M = cfg.microbatch
                mb = jax.tree_util.tree_map(
                    lambda b: b.reshape((M, b.shape[0] // M)
                                        + b.shape[1:]), batch_c)

                def acc(carry, xs):
                    gs_a, gf_a, loss_a = carry
                    b_i, t_i = xs
                    (tot, (l, r)), (g1, g2) = jax.value_and_grad(
                        cohort_loss, argnums=(0, 1), has_aux=True)(
                            scores, floats, state["weights"], b_i, t_i,
                            idx)
                    add = lambda a, g: None if a is None else a + g
                    gs_a = jax.tree_util.tree_map(
                        add, gs_a, g1, is_leaf=lambda x: x is None)
                    gf_a = jax.tree_util.tree_map(
                        add, gf_a, g2, is_leaf=lambda x: x is None)
                    return (gs_a, gf_a, loss_a + l), None

                zeros = lambda t: jax.tree_util.tree_map(
                    lambda x: None if x is None else
                    jnp.zeros(x.shape, jnp.float32), t,
                    is_leaf=lambda x: x is None)
                # one stream tick per microbatch so accumulation chunks
                # draw distinct masks
                ticks = state["step"] * M + jnp.arange(
                    M, dtype=jnp.int32)
                (gs, gf, loss), _ = jax.lax.scan(
                    acc, (zeros(scores), zeros(floats),
                          jnp.float32(0.0)), (mb, ticks))
                gs = jax.tree_util.tree_map(
                    lambda g: None if g is None else g / M, gs,
                    is_leaf=lambda x: x is None)
                gf = jax.tree_util.tree_map(
                    lambda g: None if g is None else g / M, gf,
                    is_leaf=lambda x: x is None)
                loss = loss / M
                reg = jnp.float32(0.0)
            else:
                (tot, (loss, reg)), (gs, gf) = jax.value_and_grad(
                    cohort_loss, argnums=(0, 1), has_aux=True)(
                        scores, floats, state["weights"], batch_c,
                        state["step"], idx)
            if opt_v is not None:  # adam on scores
                b1, b2 = 0.9, 0.999
                new_m = jax.tree_util.tree_map(
                    lambda m, g: None if m is None else
                    (b1 * m + (1 - b1) * g).astype(m.dtype),
                    opt_m, gs, is_leaf=lambda x: x is None)
                new_v = jax.tree_util.tree_map(
                    lambda v, g: None if v is None else
                    (b2 * v + (1 - b2) * jnp.square(
                        g.astype(jnp.float32))).astype(v.dtype),
                    opt_v, gs, is_leaf=lambda x: x is None)
                t = (state["step"] + 1).astype(jnp.float32)
                bc1 = 1 - b1 ** t
                bc2 = 1 - b2 ** t
                scores = jax.tree_util.tree_map(
                    lambda s, m, v: None if s is None else
                    (s - cfg.lr * (m / bc1) / (jnp.sqrt(v / bc2)
                                               + cfg.adam_eps)
                     ).astype(s.dtype),
                    scores, new_m, new_v, is_leaf=lambda x: x is None)
            else:
                new_v = None
                new_m = jax.tree_util.tree_map(
                    lambda m, g: None if m is None else
                    (cfg.momentum * m + g).astype(m.dtype),
                    opt_m, gs, is_leaf=lambda x: x is None)
                scores = jax.tree_util.tree_map(
                    lambda s, m: None if s is None else
                    (s - cfg.lr * m).astype(s.dtype),
                    scores, new_m, is_leaf=lambda x: x is None)
            floats = jax.tree_util.tree_map(
                lambda f, g: None if f is None else
                (f - cfg.float_lr * g).astype(f.dtype),
                floats, gf, is_leaf=lambda x: x is None)
            return scores, floats, new_m, new_v, loss

        has_v = "opt_v" in state
        opt_v_in = state.get("opt_v")
        if has_v:
            scores, floats, opt_m, opt_v, losses = jax.vmap(one)(
                state["scores"], state["floats"], state["opt_m"],
                opt_v_in, batch, jnp.arange(C))
        else:
            scores, floats, opt_m, opt_v, losses = jax.vmap(
                one, in_axes=(0, 0, 0, None, 0, 0))(
                state["scores"], state["floats"], state["opt_m"],
                None, batch, jnp.arange(C))
        new_state = dict(state, scores=scores, floats=floats, opt_m=opt_m,
                         step=state["step"] + 1)
        if has_v:
            new_state["opt_v"] = opt_v
        return new_state, {"loss": jnp.mean(losses)}

    return train_step


# ---------------------------------------------------------------------------
# round_step: the paper's communication event (cross-pod mask exchange)
# ---------------------------------------------------------------------------


def _mask_stream_seeds(step, dev, leaf_idx: int, C: int,
                       run_seed=0) -> jax.Array:
    """Per-(run, round, shard, leaf, cohort) uint32 seeds for the
    counter-based mask sampler — one thin wrapper over the SHARED
    convention (`masking.mask_stream_seed`) the fused model forward
    derives its per-leaf seeds with, so a leaf's forward mask and its
    uplink `sample_and_pack` words come from one stream family."""
    return masking.mask_stream_seed(step, dev, leaf_idx,
                                    jnp.arange(C, dtype=jnp.uint32),
                                    run_seed=run_seed)


def make_round_step(api, cfg: StepConfig, mesh=None, state_sh=None,
                    codec=None):
    """Cross-pod mask exchange. When `mesh`/`state_sh` are given, the
    aggregation runs under shard_map with an EXPLICIT all_gather of the
    bit-packed uint32 words over the 'pod' axis — the wire carries
    exactly 1 bit/parameter/cohort (vs 16 for the bf16-psum baseline).
    Without a mesh (tests, 1-device), a plain jnp path is used.

    `codec` (name or `repro.api.codecs.Codec`, default the paper's
    arithmetic coder) meters the uplink: metrics carry ``bpp`` (eq. 13
    entropy bound), ``bpp_measured`` (the codec's pooled wire rate) and
    ``bits_measured`` / ``downlink_bits`` round totals for the
    CommLedger.  With ``cfg.downlink_bits > 0`` the post-round theta
    broadcast really goes through the stochastic k-bit quantizer
    (`aggregation.quantize_theta`) before scores are reset from it.
    """
    has_pod = mesh is not None and "pod" in mesh.axis_names
    if codec is None:
        codec = "arithmetic"
    if isinstance(codec, str):
        codec = codecs_lib.get_codec(codec)

    def _round_local(scores, floats, weights, opt_m, step, part=None):
        """Runs per-shard under shard_map (or globally w/o mesh).

        ``part`` is the round's participation vector (f32[C_global],
        1.0 = the cohort's uplink arrived, 0.0 = crashed/cut): the
        aggregation renormalizes the weighted mean over SURVIVORS
        (eq. 8 with dropped nodes renormalized out), and the metering
        only counts bits survivors actually put on the wire.  ``None``
        (a trace-time constant) keeps the original all-cohorts path
        bit-for-bit.

        Per-leaf uplink: the FUSED sample+pack kernel turns each
        cohort's score row straight into bit-packed uint32 words
        (scores -> hash -> Bernoulli -> words in one pass; the uint8
        mask never exists in HBM on the transport path), then the
        packed words ride `jax.lax.all_gather` over the 'pod' axis and
        reduce through `repro.api.payloads.mean_from_words` — the same
        transport code the host-sim round engine uses, so the two paths
        cannot drift.  The unpacked (bf16-psum) path samples the SAME
        counter-based hash streams in pure jnp (`kernels.ref`), so both
        paths see bit-identical masks.
        """
        pod_axis = "pod" if has_pod else None
        if mesh is not None:
            # distinct hash stream per device shard (same seed would
            # give identical bits on every shard)
            dev = jnp.int32(0)
            for a in mesh.axis_names:
                dev = dev * mesh.shape[a] + jax.lax.axis_index(a)
        else:
            dev = jnp.int32(0)

        flat_s, tdef = jax.tree_util.tree_flatten(
            scores, is_leaf=lambda x: x is None)
        # survivor weights: normalized over the GLOBAL participation
        # vector; each shard also needs its local slice (its own
        # cohorts' alive flags) for float folds and metering
        Cl_loc = next((l.shape[0] for l in flat_s if l is not None), 1)
        if part is not None:
            wn_g = part / jnp.maximum(jnp.sum(part), 1.0)
            if pod_axis:
                off = jax.lax.axis_index(pod_axis) * Cl_loc
                alive_l = jax.lax.dynamic_slice(part, (off,), (Cl_loc,))
                wn_l = jax.lax.dynamic_slice(wn_g, (off,), (Cl_loc,))
            else:
                alive_l, wn_l = part, wn_g
        else:
            wn_g = alive_l = wn_l = None
        # metering accumulators: per-cohort one-counts via popcount of
        # the packed words (the uint8 masks where they exist anyway),
        # plus the pooled per-cohort streams for the codec meter
        words_exact = hasattr(codec, "measure_pooled_words")
        theta_flat = []
        ones_parts, word_parts, bit_parts = [], [], []
        n_pool, Cl_any = 0, 1
        for i, sl in enumerate(flat_s):
            if sl is None:
                theta_flat.append(None)
                continue
            Cl = Cl_any = sl.shape[0]
            body = sl.shape[1:]
            flat = sl.reshape(Cl, -1)
            n = flat.shape[1]
            seeds = _mask_stream_seeds(step, dev, i, Cl,
                                       run_seed=cfg.seed)
            if cfg.packed_masks:
                words = aggregation.sample_and_pack_rows(
                    flat, seeds, use_kernel=True,
                    mode=cfg.mask_mode, tau=cfg.tau)       # (Cl, W) u32
                ones_parts.append(jnp.sum(
                    jax.lax.population_count(words),
                    axis=1).astype(jnp.float32))
                if words_exact:
                    word_parts.append(words)
                else:  # codec needs gap structure, not just counts
                    bit_parts.append(jax.vmap(
                        lambda wd: aggregation.unpack_bits(wd, n)
                    )(words))
                if pod_axis:
                    words_all = jax.lax.all_gather(words, pod_axis)
                    words_all = words_all.reshape(-1, words.shape[-1])
                else:
                    words_all = words
                # wn_g rows follow the gather's pod-major cohort order,
                # so the survivor-renormalized weighted mean drops in
                # where the uniform mean was
                theta = plds.mean_from_words(words_all, n,
                                             weights=wn_g)
            else:
                masks2 = (kref.threshold_rows(flat, cfg.tau)
                          if cfg.mask_mode == "threshold"
                          else kref.sample_rows(flat, seeds))
                ones_parts.append(jnp.sum(
                    masks2.astype(jnp.float32), axis=1))
                bit_parts.append(masks2)
                if part is None:
                    b = jnp.mean(masks2.astype(jnp.bfloat16), axis=0)
                    if pod_axis:
                        b = jax.lax.pmean(b, pod_axis)
                    theta = b.astype(jnp.float32)
                else:
                    b = jnp.tensordot(
                        wn_l, masks2.astype(jnp.float32), axes=(0, 0))
                    if pod_axis:
                        b = jax.lax.psum(b, pod_axis)
                    theta = b
            n_pool += n
            theta_flat.append(theta.reshape(body))
        theta = jax.tree_util.tree_unflatten(tdef, theta_flat)
        if cfg.downlink_bits:
            # the orphaned k-bit downlink, live: theta crosses the wire
            # stochastically quantized; the key derives from the run's
            # mask_stream_seed convention at the sentinel downlink slot
            # with dev=0 — every shard uses the same key, so cohorts
            # keep receiving identical broadcasts, and distinct
            # (run_seed, step) pairs quantize under distinct keys
            qkey = jax.random.PRNGKey(masking.mask_stream_seed(
                step, 0, _DOWNLINK_STREAM_LEAF, 0, run_seed=cfg.seed))
            theta = aggregation.dequantize_theta(
                aggregation.quantize_theta(theta, qkey,
                                           bits=cfg.downlink_bits),
                bits=cfg.downlink_bits)
        new_scores = jax.tree_util.tree_map(
            lambda t, s: None if t is None else jnp.broadcast_to(
                masking.logit(t)[None], s.shape).astype(cfg.score_dtype),
            theta, scores, is_leaf=lambda x: x is None)
        if part is not None:
            # survivor-weighted float fold: dead cohorts' local floats
            # contribute zero weight, the psum renormalizes globally
            def _wavg(f):
                if f is None:
                    return None
                s = jnp.tensordot(wn_l, f.astype(jnp.float32),
                                  axes=(0, 0))
                if has_pod:
                    s = jax.lax.psum(s, "pod")
                return jnp.broadcast_to(s[None],
                                        f.shape).astype(f.dtype)
            new_floats = jax.tree_util.tree_map(
                _wavg, floats, is_leaf=lambda x: x is None)
        elif has_pod:
            new_floats = jax.tree_util.tree_map(
                lambda f: None if f is None else
                (jax.lax.pmean(f.astype(jnp.float32), "pod")
                 ).astype(f.dtype),
                floats, is_leaf=lambda x: x is None)
        else:
            new_floats = jax.tree_util.tree_map(
                lambda f: None if f is None else jnp.broadcast_to(
                    jnp.mean(f.astype(jnp.float32), 0)[None],
                    f.shape).astype(f.dtype),
                floats, is_leaf=lambda x: x is None)
        new_opt = jax.tree_util.tree_map(
            lambda m: None if m is None else jnp.zeros_like(m),
            opt_m, is_leaf=lambda x: x is None)
        # local bpp estimate (same value on every device up to shard
        # composition; cheap diagnostic) — the paper's eq. 13 meter,
        # computed from the popcounts so the packed path never
        # re-materializes the uint8 mask the fused kernel avoided
        if n_pool:
            ones_c = sum(ones_parts)                       # (Cl,)
            if part is None:
                p1 = jnp.sum(ones_c) / jnp.float32(n_pool * Cl_any)
            else:  # survivors only: dead cohorts sent nothing
                p1 = (jnp.sum(ones_c * alive_l)
                      / (jnp.float32(n_pool)
                         * jnp.maximum(jnp.sum(alive_l), 1.0)))
            bpp = regularizer.binary_entropy(p1)
        else:
            bpp = jnp.float32(0.0)
        # measured wire bits: pool every leaf's stream per cohort and
        # ask the codec — the same measure_* primitives the host-sim
        # engine meters payloads with.  Popcount-exact codecs (bitpack,
        # arithmetic) meter the packed words directly; others get the
        # unpacked pooled bits.  Each shard codes its own slice-stream;
        # the psum over EVERY mesh axis makes the returned value the
        # exact total of all shards' streams (and genuinely replicated,
        # as the out_spec declares).
        if word_parts:
            pooled = jnp.concatenate(word_parts, axis=1)
            per_cohort = jax.vmap(
                lambda wr: codec.measure_pooled_words(wr, n_pool)
            )(pooled)
        elif bit_parts:
            pooled = jnp.concatenate(bit_parts, axis=1).astype(jnp.uint8)
            per_cohort = jax.vmap(codec.measure_pooled_bits)(pooled)
        else:
            per_cohort = jnp.zeros((1,), jnp.int32)
        per_cohort = per_cohort.astype(jnp.float32)
        if part is not None and per_cohort.shape[0] == Cl_loc:
            per_cohort = per_cohort * alive_l   # dead uplinks: 0 bits
        bits_total = jnp.sum(per_cohort)
        if mesh is not None:
            bits_total = jax.lax.psum(bits_total,
                                      tuple(mesh.axis_names))
        return new_scores, new_floats, new_opt, bpp, bits_total

    def _zero_v(st, out):
        if "opt_v" in st:
            out["opt_v"] = jax.tree_util.tree_map(
                lambda v: None if v is None else jnp.zeros_like(v),
                st["opt_v"], is_leaf=lambda x: x is None)
        return out

    def _comm_totals(state):
        """(cohorts, global mask params) from the static state shapes."""
        C, n = 1, 0
        for s in jax.tree_util.tree_leaves(
                state["scores"], is_leaf=lambda x: x is None):
            if s is None:
                continue
            C = s.shape[0]
            n += s.size // s.shape[0]
        return C, n

    def _comm_metrics(state, bpp, bits_total, n_alive=None):
        """``n_alive`` (traced survivor count) rescales the per-cohort
        denominators; None keeps the full-participation accounting."""
        C, n_glob = _comm_totals(state)
        dl_bpp = float(cfg.downlink_bits) if cfg.downlink_bits else 32.0
        eff = (jnp.float32(C) if n_alive is None
               else jnp.maximum(n_alive, 1.0))
        return {"bpp": bpp,
                "bpp_measured": bits_total / (jnp.float32(n_glob) * eff),
                "bits_measured": bits_total,
                "downlink_bpp": jnp.float32(dl_bpp),
                "downlink_bits": jnp.float32(dl_bpp * n_glob) * eff}

    def _as_part(participation):
        return (None if participation is None
                else jnp.asarray(participation).astype(jnp.float32))

    if mesh is None:
        def round_step(state, participation=None):
            part = _as_part(participation)
            sc, fl, om, bpp, bits_total = _round_local(
                state["scores"], state["floats"], state["weights"],
                state["opt_m"], state["step"], part)
            out = dict(state, scores=sc, floats=fl, opt_m=om,
                       step=state["step"] + 1)
            return _zero_v(state, out), _comm_metrics(
                state, bpp, bits_total,
                None if part is None else jnp.sum(part))
        return round_step

    def specs_of(tree):
        return jax.tree_util.tree_map(
            lambda s: None if s is None else s.spec, tree,
            is_leaf=lambda x: x is None)

    in_specs = (specs_of(state_sh["scores"]), specs_of(state_sh["floats"]),
                specs_of(state_sh["weights"]), specs_of(state_sh["opt_m"]),
                jax.sharding.PartitionSpec())
    out_specs = (specs_of(state_sh["scores"]),
                 specs_of(state_sh["floats"]),
                 specs_of(state_sh["opt_m"]),
                 jax.sharding.PartitionSpec(),
                 jax.sharding.PartitionSpec())
    mapped = _shard_map(_round_local, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    # participation variant: the vector is replicated (every shard
    # slices out its own cohorts); traced separately so the no-fault
    # path stays byte-identical to the original lowering
    mapped_part = _shard_map(
        lambda sc, fl, w, om, st, pt: _round_local(sc, fl, w, om, st,
                                                   pt),
        mesh=mesh, in_specs=in_specs + (jax.sharding.PartitionSpec(),),
        out_specs=out_specs)

    def round_step(state, participation=None):
        part = _as_part(participation)
        if part is None:
            sc, fl, om, bpp, bits_total = mapped(
                state["scores"], state["floats"], state["weights"],
                state["opt_m"], state["step"])
            n_alive = None
        else:
            sc, fl, om, bpp, bits_total = mapped_part(
                state["scores"], state["floats"], state["weights"],
                state["opt_m"], state["step"], part)
            n_alive = jnp.sum(part)
        out = dict(state, scores=sc, floats=fl, opt_m=om,
                   step=state["step"] + 1)
        return _zero_v(state, out), _comm_metrics(state, bpp,
                                                  bits_total, n_alive)

    return round_step


# ---------------------------------------------------------------------------
# fedavg_step: the float reference (32-bit gradient all-reduce)
# ---------------------------------------------------------------------------


def make_fedavg_step(api, cfg: StepConfig):
    def loss_fn(params, batch):
        out = api.forward(params, batch, chunk_kv=cfg.chunk_kv)
        return api.loss(out, batch)

    def fedavg_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        opt_m = jax.tree_util.tree_map(
            lambda m, g: (cfg.momentum * m + g).astype(m.dtype),
            state["opt_m"], grads)
        params = jax.tree_util.tree_map(
            lambda p, m: (p - cfg.lr * m).astype(p.dtype),
            state["params"], opt_m)
        return dict(state, params=params, opt_m=opt_m,
                    step=state["step"] + 1), {"loss": loss}

    return fedavg_step


def init_fedavg_state(key, api):
    params = api.init_params(key)
    return {"params": params,
            "opt_m": jax.tree_util.tree_map(
                lambda x: jnp.zeros_like(x, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def fedavg_state_shardings(state_shapes, mesh):
    return {"params": shd.tree_param_shardings(state_shapes["params"],
                                               mesh),
            "opt_m": shd.tree_param_shardings(state_shapes["opt_m"],
                                              mesh),
            "step": shd.replicated(mesh)}


# ---------------------------------------------------------------------------
# serve_step: one-token decode with full KV cache (deployed artifact)
# ---------------------------------------------------------------------------


def make_serve_step(api):
    def serve_step(params, cache, token, pos):
        return api.decode_step(params, cache, token, pos)
    return serve_step


def make_multi_serve_step(api):
    """Slot-major multi-tenant decode: one vmapped step over B batch
    slots, each carrying ITS OWN params tree (a gather over the
    freeze-cache's materialized trees), KV cache, current token, and
    position — the lockstep execution mode of
    `repro.runtime.serve_engine.ServeEngine`.

    Inputs are stacked with a leading slot axis: params/cache pytrees
    `(B, ...)`, token `(B, 1)` (inner per-slot batch of 1), pos `(B,)`
    — so slots at different sequence positions (prefill vs decode)
    advance in ONE dispatch.  Numerically equivalent to B independent
    `make_serve_step` calls but NOT bit-exact (batched-dot
    reassociation); the engine's default per-slot mode is the
    bit-identity contract (tests/test_serving.py).
    """
    def multi_serve_step(params, caches, tokens, poss):
        return jax.vmap(api.decode_step)(params, caches, tokens, poss)
    return multi_serve_step
