import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
# The two lines above MUST run before ANY other import (jax locks the
# device count on first init).

import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import collective_lint  # noqa: E402
from repro.analysis import comm_model       # noqa: E402
from repro.analysis import shard_lint       # noqa: E402
from repro.analysis import stream_cover     # noqa: E402
from repro.configs import get_config, ARCH_NAMES, SHAPES, LONG_CONTEXT_OK  # noqa: E402
from repro.core import masking  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch import steps as steplib  # noqa: E402

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (no allocation) + shardings
# ---------------------------------------------------------------------------


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg, shape_cfg, mesh, C):
    """(batch_shapes, batch_shardings) with leading cohort axis C."""
    Bc = shape_cfg.global_batch // C
    S = shape_cfg.seq_len
    ns = lambda *spec: jax.sharding.NamedSharding(mesh, P(*spec))
    pod = "pod" if "pod" in mesh.axis_names else None
    shapes = {}
    sh = {}
    if cfg.family == "vlm":
        n_vis = 256
        shapes["tokens"] = sds((C, Bc, S - n_vis), jnp.int32)
        shapes["vis_embeds"] = sds((C, Bc, n_vis, cfg.d_model),
                                   jnp.bfloat16)
        sh["tokens"] = ns(pod, "data", None)
        sh["vis_embeds"] = ns(pod, "data", None, None)
    elif cfg.family == "encdec":
        shapes["tokens"] = sds((C, Bc, S), jnp.int32)
        shapes["frames"] = sds((C, Bc, cfg.enc_seq, cfg.d_model),
                               jnp.bfloat16)
        sh["tokens"] = ns(pod, "data", None)
        sh["frames"] = ns(pod, "data", None, None)
    else:
        shapes["tokens"] = sds((C, Bc, S), jnp.int32)
        sh["tokens"] = ns(pod, "data", None)
    return shapes, sh


def serve_batch_specs(cfg, shape_cfg, mesh, api):
    """decode: (cache, token, pos) shape structs + shardings."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    cache_shapes = jax.eval_shape(lambda: api.init_cache(B, S))
    cache_sh = shd.cache_shardings(cache_shapes, mesh, B)
    client = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    csize = 1
    for a in client:
        csize *= mesh.shape[a]
    tok_spec = P(client) if B % csize == 0 and csize > 1 else P()
    token = sds((B,), jnp.int32)
    pos = sds((), jnp.int32)
    sh = (jax.sharding.NamedSharding(mesh, tok_spec),
          shd.replicated(mesh))
    return cache_shapes, cache_sh, token, pos, sh


def prefill_batch_specs(cfg, shape_cfg, mesh):
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    ns = lambda *spec: jax.sharding.NamedSharding(mesh, P(*spec))
    client = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    shapes, sh = {}, {}
    if cfg.family == "vlm":
        n_vis = 256
        shapes["tokens"] = sds((B, S - n_vis), jnp.int32)
        shapes["vis_embeds"] = sds((B, n_vis, cfg.d_model), jnp.bfloat16)
        sh["tokens"] = ns(client, None)
        sh["vis_embeds"] = ns(client, None, None)
    elif cfg.family == "encdec":
        shapes["tokens"] = sds((B, S), jnp.int32)
        shapes["frames"] = sds((B, cfg.enc_seq, cfg.d_model),
                               jnp.bfloat16)
        sh["tokens"] = ns(client, None)
        sh["frames"] = ns(client, None, None)
    else:
        shapes["tokens"] = sds((B, S), jnp.int32)
        sh["tokens"] = ns(client, None)
    return shapes, sh


# ---------------------------------------------------------------------------
# HLO collective-byte accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8}

_TYPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|"
                      r"s64|u64)\[([0-9,]*)\]")
_KIND_RE = re.compile(r"\b(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind OPERAND bytes, parsed from compiled HLO.

    Operand types are often printed as bare %names, so operand size is
    derived from the RESULT type: all-gather result = operand *
    group_size; reduce-scatter result = operand / group_size; others are
    operand-sized. Async ops are counted once (at -start).
    """
    out = {}
    for line in hlo_text.splitlines():
        km = _KIND_RE.search(line)
        if km is None:
            continue
        if "-done(" in line:
            continue
        kind = km.group(1)
        # result type(s): everything left of the op name
        head = line[:km.start()]
        total = 0
        for tm in _TYPE_RE.finditer(head):
            dt, dims = tm.group(1), tm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        gm = _GROUPS_RE.search(line)
        gsize = int(gm.group(2)) if gm else 1
        if kind == "all-gather":
            total = total // max(gsize, 1)       # operand = result/group
        elif kind == "reduce-scatter":
            total = total * gsize                # operand = result*group
        out[kind] = out.get(kind, 0) + total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               step_kind: str = "auto", packed: bool = True,
               keep_hlo: bool = False, cfg_patch: dict | None = None):
    """Returns a result dict (memory, cost, collective bytes)."""
    import dataclasses
    cfg = get_config(arch)
    shape_cfg = SHAPES[shape_name]
    chunk_kv = 512 if shape_cfg.seq_len >= 32768 else None
    microbatch = 1
    tp_only = False
    if cfg_patch:
        cfg_patch = dict(cfg_patch)
        chunk_kv = cfg_patch.pop("chunk_kv", chunk_kv)  # StepConfig
        microbatch = cfg_patch.pop("microbatch", 1)     # StepConfig
        tp_only = cfg_patch.pop("tp_only", False)       # sharding mode
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    api = build_model(cfg)
    C = steplib.n_cohorts(mesh)
    spec = masking.MaskSpec()
    scfg = steplib.StepConfig(chunk_kv=chunk_kv, packed_masks=packed,
                              microbatch=microbatch)
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    results = {}
    # jax>=0.6 spells the context manager jax.set_mesh; on older
    # wheels Mesh is itself a context manager
    set_mesh = getattr(jax, "set_mesh", lambda m: m)
    with set_mesh(mesh):
        if shape_cfg.kind == "train":
            state_shapes = jax.eval_shape(
                lambda k: steplib.init_fed_state(k, api, spec, C), key)
            # ROADMAP gate: the per-shard mask streams must tile the
            # global hash stream exactly — zero overlaps, zero gaps,
            # no (leaf, shard, cohort) seed collisions across the
            # whole forced mesh
            n_dev = 1
            for a in mesh.axis_names:
                n_dev *= mesh.shape[a]
            cover = stream_cover.state_stream_report(
                state_shapes, devs=range(n_dev), cohorts=range(C),
                run_seed=scfg.seed)
            if cover["findings"]:
                raise AssertionError(
                    "mask-stream coverage violated: "
                    + "; ".join(str(f) for f in cover["findings"][:5]))
            results["stream_cover"] = {
                "ok": True, "n_leaves": cover["n_leaves"],
                "n_streams": cover["n_streams"]}
            state_sh = steplib.fed_state_shardings(state_shapes, mesh)
            batch_shapes, batch_sh = train_batch_specs(cfg, shape_cfg,
                                                       mesh, C)
            if step_kind in ("auto", "train"):
                fn = steplib.make_train_step(api, scfg)
                lowered = jax.jit(
                    fn, in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, shd.replicated(mesh)),
                ).lower(state_shapes, batch_shapes)
                results["train_step"] = _analyze(lowered, keep_hlo)
            if step_kind in ("auto", "round"):
                fn = steplib.make_round_step(api, scfg, mesh=mesh,
                                             state_sh=state_sh)
                # ROADMAP gate: wire purity — on the packed uplink,
                # nothing but uint32 mask words, the float-sidecar
                # pmean, and O(1) scalar metrics may cross a round
                # collective (repro.analysis.collective_lint)
                jxp = jax.make_jaxpr(fn)(state_shapes)
                purity = collective_lint.round_purity_findings(
                    jxp, state_shapes, state_sh, mesh)
                if packed and purity:
                    raise AssertionError(
                        "collective wire purity violated: "
                        + "; ".join(str(f) for f in purity[:5]))
                compiled = jax.jit(
                    fn, in_shardings=(state_sh,),
                    out_shardings=(state_sh, shd.replicated(mesh)),
                ).lower(state_shapes).compile()
                results["round_step"] = _analyze_compiled(compiled,
                                                          keep_hlo)
                model = comm_model.round_comm_model(
                    jxp, state_shapes, state_sh, mesh, scfg)
                results["round_step"]["comm_model"] = {
                    k: model[k] for k in
                    ("bpp_wire", "uplink_bits", "downlink_bits",
                     "n_sites", "ring_bytes_per_axis")}
                # ROADMAP gate: the shardings the launcher declares
                # must be the shardings the executable ingests — a
                # drift is an unmetered per-step reshard
                mism = shard_lint.input_sharding_mismatches(
                    compiled, state_sh, state_shapes, label="state/")
                if mism:
                    raise AssertionError(
                        "declared-vs-lowered sharding drift: "
                        + "; ".join(str(f) for f in mism[:5]))
                results["round_step"]["shard_lint"] = {"ok": True}
        elif shape_cfg.kind == "prefill":
            params_shapes = jax.eval_shape(api.init_params, key)
            params_sh = shd.tree_param_shardings(params_shapes, mesh,
                                                 tp_only=tp_only)
            batch_shapes, batch_sh = prefill_batch_specs(cfg, shape_cfg,
                                                         mesh)

            def prefill(params, batch):
                out = api.forward(params, batch, chunk_kv=chunk_kv)
                return out[0][:, -1]

            lowered = jax.jit(
                prefill, in_shardings=(params_sh, batch_sh),
            ).lower(params_shapes, batch_shapes)
            results["prefill_step"] = _analyze(lowered, keep_hlo)
        else:  # decode
            params_shapes = jax.eval_shape(api.init_params, key)
            params_sh = shd.tree_param_shardings(params_shapes, mesh,
                                                 tp_only=tp_only)
            cache_shapes, cache_sh, token, pos, (tok_sh, pos_sh) = \
                serve_batch_specs(cfg, shape_cfg, mesh, api)
            fn = steplib.make_serve_step(api)
            lowered = jax.jit(
                fn, in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
            ).lower(params_shapes, cache_shapes, token, pos)
            results["serve_step"] = _analyze(lowered, keep_hlo)

    for r in results.values():
        r["lower_compile_s"] = round(time.time() - t0, 1)
    return results


def _analyze(lowered, keep_hlo=False):
    return _analyze_compiled(lowered.compile(), keep_hlo)


def _analyze_compiled(compiled, keep_hlo=False):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # older jax: one dict per program
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    out = {
        "flops": float(cost.get("flops", -1)) if cost else -1,
        "bytes_accessed": float(cost.get("bytes accessed", -1))
        if cost else -1,
        "collective_bytes": coll,
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
    }
    if keep_hlo:
        out["hlo"] = hlo
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def iter_cells(archs, shapes):
    for a in archs:
        for s in shapes:
            if s == "long_500k" and a not in LONG_CONTEXT_OK:
                continue
            yield a, s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--unpacked", action="store_true",
                    help="bf16 psum mask aggregation (baseline)")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    shapes = (list(SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    n_ok = n_fail = 0
    for arch, shape in iter_cells(archs, shapes):
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            cell = f"{arch}|{shape}|{mesh_name}"
            if cell in results and results[cell].get("ok"):
                continue
            t0 = time.time()
            try:
                r = lower_cell(arch, shape, mp,
                               packed=not args.unpacked)
                results[cell] = {"ok": True, **r}
                n_ok += 1
                print(f"[OK]   {cell}  ({time.time() - t0:.0f}s)",
                      flush=True)
            except Exception as e:
                results[cell] = {"ok": False, "error": repr(e),
                                 "traceback": traceback.format_exc()}
                n_fail += 1
                print(f"[FAIL] {cell}: {e}", flush=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"done: {n_ok} ok, {n_fail} failed -> {args.out}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
