"""Pod-scale launch plans: registry names -> lowered production steps.

The host-sim registry (`repro.api`) resolves an algorithm name to a
`FedAlgorithm`; at pod scale the same name resolves — through
`api.get_launch_plan` — to a `LaunchPlan` bundling the lowered state,
train step, round step, and batch layout for `repro.launch.train`.
Importing this module populates the launch side of the registry, so the
launcher has no per-algorithm if/else: adding an algorithm here makes
`--algo <name>` work.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import api
from repro.core import masking
from repro.launch import steps as steplib


@dataclasses.dataclass
class LaunchPlan:
    """Everything the launcher needs, resolved from one registry name."""
    name: str
    state: Any
    step_fn: Callable                 # (state, batch) -> (state, metrics)
    round_fn: Optional[Callable]      # (state) -> (state, metrics) | None
    make_batch: Callable              # (key, tokens, batch, seq) -> batch


def _cohort_batch(cohorts: int):
    def make_batch(key, toks, batch, seq):
        idx = jax.random.randint(key, (cohorts, batch), 0,
                                 toks.shape[0] - seq - 1)
        return {"tokens": jax.vmap(jax.vmap(
            lambda i: jax.lax.dynamic_slice(toks, (i,), (seq,))))(idx)}
    return make_batch


def _flat_batch(key, toks, batch, seq):
    idx = jax.random.randint(key, (batch,), 0, toks.shape[0] - seq - 1)
    return {"tokens": jax.vmap(
        lambda i: jax.lax.dynamic_slice(toks, (i,), (seq,)))(idx)}


def _mask_plan(name, *, force_lam=None, mask_mode=None):
    """Mask-training plans (fedpm_reg / fedpm / fedmask): cohort-axis
    state, fused masked-execution train step, bitpacked round.  `codec`
    picks the wire codec the round step meters uplinks with (`--codec`
    in `repro.launch.train`); `mask_mode="threshold"` is the FedMask
    variant — the forward differentiates through the fused threshold
    kernels and the uplink packs the deterministic mask."""
    def plan(model_api, scfg: steplib.StepConfig, *, key, cohorts,
             spec=None, optimizer="momentum", codec=None) -> LaunchPlan:
        if force_lam is not None:
            scfg = dataclasses.replace(scfg, lam=force_lam)
        if mask_mode is not None:
            scfg = dataclasses.replace(scfg, mask_mode=mask_mode)
        spec = masking.MaskSpec() if spec is None else spec
        state = steplib.init_fed_state(key, model_api, spec, C=cohorts,
                                       optimizer=optimizer)
        return LaunchPlan(
            name=name, state=state,
            step_fn=jax.jit(steplib.make_train_step(model_api, scfg)),
            round_fn=jax.jit(steplib.make_round_step(model_api, scfg,
                                                     codec=codec)),
            make_batch=_cohort_batch(cohorts))
    return plan


def _fedavg_plan(model_api, scfg: steplib.StepConfig, *, key, cohorts,
                 spec=None, optimizer="momentum",
                 codec=None) -> LaunchPlan:
    state = steplib.init_fedavg_state(key, model_api)
    return LaunchPlan(
        name="fedavg", state=state,
        step_fn=jax.jit(steplib.make_fedavg_step(model_api, scfg)),
        round_fn=None, make_batch=_flat_batch)


# per-algorithm StepConfig overrides for the mask-round algorithms —
# the single source both the launch registrations below and the
# analysis engines (repro.analysis.comm_model / collective_lint) build
# their round-step configs from, so the linted jaxpr is the launched
# jaxpr
MASK_ALGOS = {
    "fedpm_reg": {},
    "fedpm": {"lam": 0.0},
    "fedmask": {"lam": 0.0, "mask_mode": "threshold"},
}

for _name, _kw in MASK_ALGOS.items():
    api.register_launch(_name, _mask_plan(
        _name, force_lam=_kw.get("lam"),
        mask_mode=_kw.get("mask_mode")))
api.register_launch("fedavg", _fedavg_plan)
