"""Sharding rules: pytree-path + shape -> PartitionSpec.

Strategy (docs/DESIGN.md §2):
  * weights/scores/optimizer state: last dim -> "model" (TP), the
    second-to-last -> "data" (FSDP-style). Leading stack axes (layer /
    group / expert scan dims) are never sharded — except MoE expert
    axes, which go to "model" (EP) when the feature dims are too small
    to make TP worthwhile (deepseek-v2 experts: d_ff 1408/1536).
  * activations/batch: batch dim -> ("pod", "data"); long-context
    decode (batch 1) shards the KV-cache sequence dim instead (SP).
  * norms/scalars: replicated.

The rules are heuristic but DETERMINISTIC and shape-validated: a dim is
only sharded if divisible by the mesh axis size; otherwise the next
candidate dim is tried — so every (arch x mesh) lowers cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

Pytree = Any


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


@dataclasses.dataclass(frozen=True)
class SpecExplanation:
    """Why `param_spec` chose (or declined) a sharding for one leaf.

    ``rule`` names the decision branch that produced the spec;
    ``skipped`` records every dim a branch TRIED to shard but could not
    (divisibility / size), so a fully-replicated big leaf is
    distinguishable from a deliberately replicated norm — the
    silent-replication fallback used to be invisible, now
    `repro.analysis.shard_lint` reports it."""
    path: str
    shape: tuple
    spec: P
    rule: str            # replicate-small | embed | moe-expert |
    #                      generic | scalar
    skipped: tuple       # human-readable per-dim skip reasons


def explain_spec(path: str, shape, mesh, *,
                 scan_dims: int = 1) -> SpecExplanation:
    """`param_spec` with its decision trace (same spec, bit for bit)."""
    nd = len(shape)
    dmodel = _axis_size(mesh, "model")
    ddata = _axis_size(mesh, "data")
    spec = [None] * nd
    skipped: list = []

    def done(rule):
        return SpecExplanation(path, tuple(shape), P(*spec), rule,
                               tuple(skipped))

    def try_dim(dim, axis, size):
        if shape[dim] % size == 0:
            spec[dim] = axis
            return True
        skipped.append(f"dim {dim % nd - nd} ({shape[dim]}) % "
                       f"{axis} ({size}) != 0")
        return False

    if nd == 0:
        return done("scalar")
    lp = path.lower()
    # scalars / 1D / norms / small: replicate
    if nd <= scan_dims or all(s == 1 for s in shape):
        return done("replicate-small")

    # embeddings: (V, D) with no scan dim
    if "embed" in lp or "lm_head" in lp:
        try_dim(-2, "data", ddata)
        try_dim(-1, "model", dmodel)
        return done("embed")

    # MoE stacked experts: (..., E, d_in, d_out) — expert axis -> model.
    # (Tried F-on-data co-sharding for the block-dispatch einsum chain:
    # REFUTED — bytes +18%, collective +31%; see §Perf-log. Kept d_in.)
    if ("w_up" in lp or "w_gate" in lp or "w_down" in lp) and \
            nd - scan_dims == 3:
        if try_dim(nd - 3, "model", dmodel):
            try_dim(-2, "data", ddata)
            return done("moe-expert")
        # expert axis indivisible: fall through to the generic rule

    # generic 2D body: last -> model, second-to-last -> data
    try_dim(-1, "model", dmodel)
    if nd - scan_dims >= 2:
        try_dim(-2, "data", ddata)
    # 1D body (biases): shard on model if large & divisible
    if nd - scan_dims == 1 and shape[-1] % dmodel == 0 \
            and shape[-1] >= 4 * dmodel:
        spec[-1] = "model"
    return done("generic")


def param_spec(path: str, shape, mesh, *, scan_dims: int = 1) -> P:
    """PartitionSpec for a parameter-like leaf.

    scan_dims: number of leading stacked axes (layers/groups) to skip.
    """
    return explain_spec(path, shape, mesh, scan_dims=scan_dims).spec


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def tree_param_shardings(tree: Pytree, mesh, scan_dims_fn=None,
                         tp_only: bool = False) -> Pytree:
    """NamedSharding pytree for a parameter tree (works on
    ShapeDtypeStruct trees too). None leaves stay None.

    tp_only=True drops the FSDP ("data") dims — the inference layout:
    weights have no optimizer state, so the HBM saved by FSDP is small
    while its per-layer all-gathers dominate prefill (§Roofline). Used
    by the serving path / §Perf prefill iteration."""
    def one(path, leaf):
        if leaf is None:
            return None
        p = _path_str(path)
        sd = scan_dims_fn(p, leaf) if scan_dims_fn else _default_scan_dims(p)
        sd = min(sd, max(len(leaf.shape) - 1, 0))
        ps = param_spec(p, leaf.shape, mesh, scan_dims=sd)
        if tp_only:
            ps = P(*[None if a == "data" else a for a in ps])
        return NamedSharding(mesh, ps)
    return jax.tree_util.tree_map_with_path(
        one, tree, is_leaf=lambda x: x is None)


def _default_scan_dims(path: str) -> int:
    lp = path.lower()
    if "groups" in lp:          # hybrid: (n_groups, ...)
        return 1
    if "embed" in lp or "final_norm" in lp or "lm_head" in lp \
            or "pos_embed" in lp:
        return 0
    return 1                    # stacked layers


def batch_spec(mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes)


def batch_shardings(batch_tree: Pytree, mesh) -> Pytree:
    bs = batch_spec(mesh)

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        spec = [None] * nd
        if leaf.shape[0] % int(np.prod([mesh.shape[a] for a in bs[0]])
                               if bs[0] else 1) == 0 and bs[0]:
            spec[0] = bs[0]
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(cache_tree: Pytree, mesh, batch: int) -> Pytree:
    """KV caches: (L, B, S, heads, hd) — batch -> client axes when
    divisible, else sequence -> "data" (SP for batch-1 long context);
    heads -> "model" when divisible, else seq -> model."""
    client = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    csize = int(np.prod([mesh.shape[a] for a in client])) if client else 1
    dmodel = _axis_size(mesh, "model")

    def one(path, leaf):
        p = _path_str(path).lower()
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        if nd >= 2 and shape[1] % csize == 0 and csize > 1:
            spec[1] = client
            # heads or seq on model
            if nd >= 4 and shape[3] % dmodel == 0:
                spec[3] = "model"
            elif nd >= 3 and shape[2] % dmodel == 0:
                spec[2] = "model"
        elif nd >= 3:
            # batch too small: shard seq across data (+ model if needed)
            if shape[2] % (csize * dmodel) == 0 and csize > 1:
                spec[2] = client + ("model",)
            elif shape[2] % dmodel == 0:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
