"""Production training launcher.

    python -m repro.launch.train --arch internlm2-1.8b --smoke \
        --steps 100 --round-every 10 --ckpt-dir /tmp/ckpt

On real hardware the same entry point runs the production mesh; on this
container use --smoke (reduced config, 1 device). Handles:
  * checkpoint/restart (atomic, async)
  * round-boundary mask exchange (the paper's protocol)
  * elastic re-entry: --cohorts may differ across restarts; theta is
    mesh-agnostic so the run continues
  * any registered algorithm with a launch plan via --algo (e.g.
    fedavg, the 32-Bpp reference); names resolve through repro.api —
    there is no per-algorithm dispatch here
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api as fedapi
from repro.api import codecs as codecs_lib
from repro.configs import get_config
from repro.models import build_model
from repro.data import synthetic
from repro.launch import steps as steplib
from repro.launch import plans as planlib  # noqa: F401  (registers plans)
from repro.launch import mesh as meshlib
from repro.runtime import elastic, fault
from repro import ckpt as ckptlib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--algo", default="fedpm_reg",
                    choices=list(fedapi.launchable()))
    ap.add_argument("--codec", default="arithmetic",
                    choices=[c for c in codecs_lib.available()
                             if c != "float32"],
                    help="wire codec metering the mask uplink")
    ap.add_argument("--downlink-bits", type=int, default=8,
                    help="k-bit stochastic theta broadcast "
                         "(0 = raw float32 downlink)")
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=17,
                    help="run seed for every mask stream (forward and "
                         "uplink) — two runs with the same seed sample "
                         "bit-identical masks")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--round-every", type=int, default=10)
    ap.add_argument("--cohorts", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--score-opt", default="momentum",
                    choices=["momentum", "adam"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--fail-prob", type=float, default=0.0,
                    help="per-round iid cohort failure probability; "
                         "the round aggregation renormalizes over "
                         "survivors")
    ap.add_argument("--pod-size", type=int, default=0,
                    help="cohorts per failure domain (0 = independent "
                         "failures); whole pods drop together")
    ap.add_argument("--pod-outage-prob", type=float, default=0.0,
                    help="per-round correlated pod outage probability")
    ap.add_argument("--quorum-frac", type=float, default=1.0,
                    help="straggler cut: keep the fastest fraction of "
                         "surviving cohorts each round (1.0 = wait "
                         "for everyone)")
    ap.add_argument("--tree-fanout", type=int, default=0,
                    help="cohorts per edge aggregator (0 = flat "
                         "aggregation); with a tree, each round's root "
                         "traffic is one O(params) pooled fold record "
                         "per surviving edge (runtime/agg_tree.py)")
    ap.add_argument("--agg-fault-prob", type=float, default=0.0,
                    help="per-round edge-aggregator crash probability "
                         "(requires --tree-fanout); cohorts of a "
                         "crashed edge miss the barrier round")
    args = ap.parse_args(argv)
    if args.agg_fault_prob > 0 and args.tree_fanout <= 0:
        ap.error("--agg-fault-prob requires --tree-fanout > 0")

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    scfg = steplib.StepConfig(lam=args.lam, lr=args.lr,
                              optimizer=args.score_opt,
                              downlink_bits=args.downlink_bits,
                              seed=args.seed)

    plan = fedapi.get_launch_plan(args.algo)(
        api, scfg, key=key, cohorts=args.cohorts,
        optimizer=args.score_opt, codec=args.codec)
    state, step_fn, round_fn = plan.state, plan.step_fn, plan.round_fn

    # hierarchical aggregator tree (runtime/agg_tree.py): the barrier
    # round has no retransmit window, so edge faults collapse to
    # participation masking, and the edge -> root hop is metered from
    # the static cost model — one O(params) pooled record per
    # surviving edge, independent of the cohort count
    topo, tree_edge_bits = None, 0
    if args.tree_fanout > 0:
        from repro.analysis import comm_model
        from repro.runtime import agg_tree
        if not (isinstance(state, dict) and "scores" in state):
            ap.error(f"--tree-fanout: algo '{args.algo}' carries no "
                     "mask scores to pool at an edge")
        _leaves = lambda t: (
            l for l in jax.tree_util.tree_leaves(
                t, is_leaf=lambda x: x is None) if l is not None)
        leaf_params = [int(np.prod(l.shape[1:]))
                       for l in _leaves(state["scores"])]
        float_elems = sum(int(np.prod(l.shape[1:]))
                          for l in _leaves(state.get("floats")))
        topo = agg_tree.TreeTopology(args.cohorts, args.tree_fanout,
                                     agg_fault_prob=args.agg_fault_prob,
                                     seed=args.seed)
        rec = comm_model.tree_root_record_bits(
            leaf_params, acc_bits=topo.cfg.acc_bits, n_classes=1,
            float_elems=float_elems, n_metrics=0)
        tree_edge_bits = rec["wire_bits"] + rec["sidecar_bits"]
        print(f"tree: {topo.n_edges} edge(s) at fanout "
              f"{args.tree_fanout}, root record "
              f"{tree_edge_bits}b/edge (static)")

    start = 0
    saver = None
    if args.ckpt_dir:
        saver = ckptlib.AsyncCheckpointer(args.ckpt_dir, keep=2)
        if ckptlib.latest_step(args.ckpt_dir) is not None:
            try:
                state, start = ckptlib.restore_checkpoint(args.ckpt_dir,
                                                          state)
                print(f"resumed at step {start}")
            except (KeyError, ValueError):
                # structure mismatch (elastic resize / optimizer
                # switch): carry the learned theta/float signal over,
                # rebuild the rest (runtime/elastic.py)
                state, start = elastic.restore_theta_only(
                    args.ckpt_dir, state)
                print(f"structure mismatch: theta-only partial "
                      f"restore at step {start}")

    toks = synthetic.make_lm_stream(key, 500_000, cfg.vocab)
    faulty = (args.fail_prob > 0 or args.pod_outage_prob > 0
              or args.quorum_frac < 1.0)
    sim = (fault.FaultSimulator(args.cohorts, fail_prob=args.fail_prob,
                                pod_size=args.pod_size,
                                pod_outage_prob=args.pod_outage_prob,
                                seed=args.seed)
           if faulty else None)
    policy = (fault.StragglerPolicy(quorum_frac=args.quorum_frac)
              if args.quorum_frac < 1.0 else None)
    # the ledger must survive restarts or cumulative MB under-reports;
    # it rides next to the checkpoints as a tiny json sidecar
    ledger = fedapi.CommLedger()
    ledger_path = (os.path.join(args.ckpt_dir, "comm_ledger.json")
                   if args.ckpt_dir else None)
    if start > 0 and ledger_path and os.path.exists(ledger_path):
        with open(ledger_path) as f:
            ledger = fedapi.CommLedger(**json.load(f))
        print(f"resumed ledger: {ledger.total_mb:.2f}MB over "
              f"{ledger.rounds} rounds")

    t0 = time.time()
    for step in range(start, args.steps):
        kd = jax.random.fold_in(key, step)
        batch = plan.make_batch(kd, toks, args.batch, args.seq)
        state, m = step_fn(state, batch)
        if round_fn is not None and (step + 1) % args.round_every == 0:
            # draws are keyed by (seed, round index), NOT a mutable
            # generator cursor: a resumed run replays the identical
            # fault sequence from any restart point
            round_idx = (step + 1) // args.round_every
            alive = (sim.sample_round(policy, round_idx=round_idx)
                     if sim is not None else None)
            if topo is not None:
                base = (np.asarray(alive, bool) if alive is not None
                        else np.ones(args.cohorts, bool))
                masked = topo.round_mask(base, round_idx)
                # rescue: a round never folds an empty cohort — if
                # aggregator faults orphan every surviving client,
                # the root adopts them directly this round
                alive = masked if masked.any() else base
            # survivor-renormalized aggregation: the participation
            # vector gates which cohorts' masks the round folds
            state, rm = (round_fn(state) if alive is None
                         else round_fn(state, jnp.asarray(alive)))
            upd = {"uplink_bits_measured": rm["bits_measured"],
                   "downlink_bits": rm["downlink_bits"]}
            if topo is not None:
                upd["root_bits_measured"] = float(
                    topo.surviving_edges(round_idx) * tree_edge_bits)
            ledger.update(upd)
            msg = (f"step {step+1}: loss={float(m['loss']):.3f} "
                   f"uplink={float(rm['bpp']):.3f}Bpp "
                   f"(wire {float(rm['bpp_measured']):.3f}Bpp "
                   f"{args.codec}) cum={ledger.total_mb:.2f}MB")
            if alive is not None:
                msg += f" alive={alive.sum()}/{args.cohorts}"
            if topo is not None:
                msg += (f" edges={topo.surviving_edges(round_idx)}"
                        f"/{topo.n_edges} root={ledger.root_mb:.3f}MB")
            print(msg + f" ({time.time()-t0:.0f}s)", flush=True)
            if saver:
                saver.save(step + 1, state)
                os.makedirs(args.ckpt_dir, exist_ok=True)
                tmp = ledger_path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump({"uplink_bits": ledger.uplink_bits,
                               "downlink_bits": ledger.downlink_bits,
                               "root_bits": ledger.root_bits,
                               "rounds": ledger.rounds}, f)
                os.replace(tmp, ledger_path)
        elif (step + 1) % 10 == 0:
            print(f"step {step+1}: loss={float(m['loss']):.3f}",
                  flush=True)
    if saver:
        saver.close()
    if ledger.rounds:
        msg = (f"comm: {ledger.rounds} rounds, "
               f"up={ledger.uplink_mb:.2f}MB "
               f"down={ledger.downlink_mb:.2f}MB "
               f"total={ledger.total_mb:.2f}MB")
        if ledger.root_bits:
            msg += f" root={ledger.root_mb:.3f}MB"
        print(msg)
    print("done")


if __name__ == "__main__":
    main()
