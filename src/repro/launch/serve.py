"""Serving launcher: batched decode from a (seed, mask) artifact or a
fresh random sub-network.

Serving deliberately runs the REFERENCE path (docs/DESIGN.md §3): the
deployed mask is static, so the prefill phase freezes the masked tree
ONCE (`masking.freeze_for_decode` on a threshold-mode forward tree —
the same deterministic mask a FedMask artifact ships) and every decode
step reuses the materialized params — decode is KV-cache-bound, and
the per-token loops (`conv1d_step`, attention projections) therefore
do ZERO mask resampling in steady state.  The fused (w, s, seed) path
is the *training* hot path (`launch.steps.make_train_step`).

    python -m repro.launch.serve --arch gemma3-4b --smoke --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import masking
from repro.models import build_model
from repro.launch import steps as steplib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    # default 0 = the behaviour before --seed existed (PRNGKey(0)
    # network), so unflagged invocations stay reproducible
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    # --seed picks the frozen random network (the artifact's RNG seed);
    # the deployed threshold mask is deterministic given the scores
    key = jax.random.PRNGKey(args.seed)
    spec = masking.MaskSpec()

    params_like = api.init_params(key)
    mp = masking.init_masked(key, params_like, spec)
    # prefill: freeze the static serving mask ONCE — decode steps then
    # consume plain arrays and never re-derive effective weights
    seed_fn = lambda i: masking.mask_stream_seed(0, 0, i, 0,
                                                 run_seed=args.seed)
    tree = masking.masked_forward_tree(mp, seed_fn, mode="threshold")
    eff = masking.freeze_for_decode(tree)

    B = args.batch
    S = args.prompt_len + args.tokens
    serve = jax.jit(steplib.make_serve_step(api))
    cache = api.init_cache(B, S)
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    tok = prompt[:, 0]
    t0 = time.time()
    for t in range(S - 1):
        logits, cache = serve(eff, cache, tok, jnp.asarray(t, jnp.int32))
        tok = (prompt[:, t + 1] if t + 1 < args.prompt_len
               else jnp.argmax(logits, -1).astype(jnp.int32))
    dt = time.time() - t0
    print(f"{args.arch}: {B} requests x {args.tokens} new tokens "
          f"in {dt:.2f}s ({B * args.tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
