"""Serving launcher: single-tenant batched decode or the multi-tenant
continuous-batching engine over one shared frozen weight copy.

Serving deliberately consumes the REFERENCE path (docs/DESIGN.md §3):
a deployed mask is static, so each tenant's masked tree is frozen ONCE
(`masking.freeze_identity` — the threshold-mode deterministic mask a
FedMask artifact ships) and every decode step reuses the materialized
params, doing ZERO mask resampling in steady state.  The fused
(w, s, seed) path is the *training* hot path
(`launch.steps.make_train_step`).

Single tenant (the original demo, timing fixed: warmup step off the
clock, `time.perf_counter`, prefill and decode tok/s reported
separately):

    python -m repro.launch.serve --arch gemma3-4b --smoke --tokens 16

Multi-tenant (the `repro.runtime.serve_engine.ServeEngine` engine:
per-slot mask identity, bounded LRU freeze-cache, prefill/decode
continuous batching — resident weight HBM stays ONE shared `w` while
tenants grow past the cache capacity):

    python -m repro.launch.serve --arch gemma3-4b --smoke \
        --tenants 4 --slots 2 --cache-capacity 2 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import masking
from repro.models import build_model
from repro.launch import steps as steplib


def _serve_single(args, cfg, api, key, mp):
    """The original single-tenant batched greedy decode, timing fixed:
    jit compilation happens in a warmup step OFF the clock, timing uses
    `time.perf_counter`, and prefill vs decode tok/s are reported
    separately."""
    ident = masking.MaskIdentity(seed=args.seed, mode="threshold")
    eff = masking.freeze_identity(mp, ident)

    B = args.batch
    P = args.prompt_len
    S = P + args.tokens
    serve = jax.jit(steplib.make_serve_step(api))
    cache = api.init_cache(B, S)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)

    # warmup: one step on a scratch cache so the first TIMED step is
    # compile-free (t0 used to include the whole jit compile)
    scratch = api.init_cache(B, S)
    out = serve(eff, scratch, prompt[:, 0], jnp.asarray(0, jnp.int32))
    jax.block_until_ready(out[0])

    tok = prompt[:, 0]
    prefill_s = decode_s = 0.0
    for t in range(S - 1):
        t0 = time.perf_counter()
        logits, cache = serve(eff, cache, tok, jnp.asarray(t, jnp.int32))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        if t + 1 < P:
            prefill_s += dt
            tok = prompt[:, t + 1]
        else:
            decode_s += dt
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pre_tok = B * (P - 1)
    dec_tok = B * args.tokens
    print(f"{cfg.name}: {B} requests, prefill {pre_tok} tok in "
          f"{prefill_s:.3f}s ({pre_tok / max(prefill_s, 1e-9):.1f} tok/s), "
          f"decode {dec_tok} tok in {decode_s:.3f}s "
          f"({dec_tok / max(decode_s, 1e-9):.1f} tok/s)")


def _serve_multi(args, cfg, api, key, mp):
    """Multi-tenant continuous batching: every tenant is a mask
    identity over the SAME `mp.weights`; the engine's freeze-cache
    bounds resident materialized trees to --cache-capacity."""
    from repro.runtime.serve_engine import ServeEngine

    eng = ServeEngine(api, mp, slots=args.slots,
                      cache_capacity=args.cache_capacity,
                      max_seq=args.prompt_len + args.tokens,
                      lockstep=args.lockstep)
    prompts = jax.random.randint(
        key, (args.tenants, args.prompt_len), 0, cfg.vocab)
    import numpy as np
    prompts = np.asarray(prompts)
    for i in range(args.tenants):
        eng.register_tenant(f"tenant{i}", seed=args.seed + i)
        eng.submit(f"tenant{i}", prompts[i], args.tokens)
    done = eng.run()
    st = eng.stats()
    print(f"{cfg.name}: {len(done)}/{args.tenants} tenants served on "
          f"{args.slots} slots (freeze-cache {st['occupancy']}/"
          f"{st['capacity']}, {st['hits']} hits / {st['misses']} misses"
          f" / {st['evictions']} evictions)")
    print(f"  prefill {st['prefill_tokens']} tok "
          f"({st['prefill_tok_s']:.1f} tok/s), "
          f"decode {st['decode_tokens']} tok "
          f"({st['decode_tok_s']:.1f} tok/s)")
    print(f"  resident HBM: 1 x w ({st['weight_bytes']} B) + "
          f"{st['resident_tree_count']} x delta "
          f"({st['delta_bytes_per_tree']} B) = {st['resident_bytes']} B "
          f"for {st['tenants']} tenants "
          f"(mask artifact {st['mask_artifact_bytes']} B/tenant)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    # default 0 = the behaviour before --seed existed (PRNGKey(0)
    # network), so unflagged invocations stay reproducible
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=1,
                    help=">1 drives the multi-tenant engine: one "
                         "request per tenant, distinct mask seeds")
    ap.add_argument("--slots", type=int, default=2,
                    help="concurrent batch slots (multi-tenant)")
    ap.add_argument("--cache-capacity", type=int, default=2,
                    help="freeze-cache bound on resident trees")
    ap.add_argument("--lockstep", action="store_true",
                    help="one vmapped step for all slots per tick "
                         "(throughput mode; not bit-exact)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    # --seed picks the frozen random network (the artifact's RNG seed);
    # the deployed threshold mask is deterministic given the scores
    key = jax.random.PRNGKey(args.seed)
    mp = masking.init_masked(key, api.init_params(key),
                             masking.MaskSpec())
    if args.tenants > 1:
        _serve_multi(args, cfg, api, key, mp)
    else:
        _serve_single(args, cfg, api, key, mp)


if __name__ == "__main__":
    main()
