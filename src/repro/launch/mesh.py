"""Production mesh definitions.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

Federated mapping: client cohorts ride ("pod", "data"); tensor/expert
parallel rides "model". Defined as FUNCTIONS so importing this module
never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; smoke tests see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_debug_pod_mesh(n_pod: int = 0, n_data: int = 0,
                        n_model: int = 0):
    """Smallest mesh with ALL THREE production axes — the pod axis is
    what makes the round step's cross-cohort collectives appear, so the
    comm-model/collective-lint gates trace on this mesh (a "data",
    "model" debug mesh has no uplink at all).  With no arguments, picks
    the largest of (2,2,2) / (2,2,1) / (2,1,1) / (1,1,1) that fits the
    available devices (CI forces 8 via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    if not (n_pod and n_data and n_model):
        n = len(jax.devices())
        n_pod, n_data, n_model = ((2, 2, 2) if n >= 8 else
                                  (2, 2, 1) if n >= 4 else
                                  (2, 1, 1) if n >= 2 else (1, 1, 1))
    return jax.make_mesh((n_pod, n_data, n_model),
                         ("pod", "data", "model"))


def client_axes(mesh) -> tuple:
    """Mesh axes that carry federated clients (the 'uplink' axes)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a == "model")
