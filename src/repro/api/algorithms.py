"""The six federated algorithms, expressed in the `FedAlgorithm`
protocol with typed payloads in both directions.

  name         payload          codec       downlink            reference
  -----------  ---------------  ----------  ------------------  ------------
  fedpm_reg    BitpackedMasks   arithmetic  ProbBroadcast k=8   the paper
  fedpm        BitpackedMasks   arithmetic  ProbBroadcast k=8   FedPM
  fedmask      BitpackedMasks   arithmetic  FloatBroadcast      Li et al.
  topk         BitpackedMasks   arithmetic  FloatBroadcast      top-k [4]
  mv_signsgd   SignVotes        signpack    FloatBroadcast      [12]
  fedavg       FloatDeltas      float32     FloatBroadcast      [1]

Each is a factory `f(apply_fn, loss_fn, *, spec=None, **hp)` registered
under its name; resolve with `repro.api.get_algorithm`.  Every factory
takes ``codec=`` to swap the wire codec; the fedpm family takes
``downlink_bits=`` for the k-bit theta broadcast (clients genuinely
train from the dequantized copy).  The `fedpm*` rows reuse
`repro.core.federated.make_client_update` (the paper-faithful local
step), so the host-sim engine and this API cannot diverge.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.api import payloads as plds
from repro.api.protocol import FedAlgorithm, PayloadSpec
from repro.api.registry import register
from repro.core import federated, masking, regularizer
from repro.optim import optimizers as optlib

Pytree = Any

_NONE = lambda x: x is None


def _default_spec(spec):
    return masking.MaskSpec() if spec is None else spec


# ---------------------------------------------------------------------------
# FedPM family: the paper's method (lam > 0) and the FedPM reference
# ---------------------------------------------------------------------------


MASK_SPEC = PayloadSpec(
    plds.BitpackedMasks, nominal_bpp=None,
    description="bitpacked binary masks; entropy-coded <= 1 Bpp",
    default_codec="arithmetic")


def _prob_downlink(bits: int):
    """Server -> clients: theta over the real k-bit quantized wire
    (`ProbBroadcast`); clients train from the dequantized copy."""
    def downlink(state, key):
        pay = plds.ProbBroadcast.from_theta(state.theta, key, bits=bits,
                                            floats=state.floats)
        return pay, state._replace(theta=pay.to_theta())
    return downlink


def _float_downlink(select):
    """Server -> clients: raw float broadcast (lossless, 32 Bpp)."""
    def downlink(state, key):
        return plds.FloatBroadcast.from_tree(select(state)), state
    return downlink


def _fedpm_family(name, apply_fn, loss_fn, *, spec=None, cfg=None,
                  lam=1.0, local_steps=3, lr=0.1, float_lr=0.01,
                  optimizer="sgd", bayesian=False, train_floats=True,
                  codec=None, downlink_bits=8):
    spec = _default_spec(spec)
    if cfg is None:
        cfg = federated.FedConfig(
            lam=lam, local_steps=local_steps, lr=lr, float_lr=float_lr,
            optimizer=optimizer, bayesian=bayesian,
            train_floats=train_floats)
    local = federated.make_client_update(apply_fn, loss_fn, cfg)

    def init(key, params_like):
        return federated.init_server(key, params_like, spec)

    def client_update(state, data, key):
        mask, floats, metrics = local(state.weights, state.floats,
                                      state.theta, data, key)
        metrics.pop("uplink_bpp", None)  # the transport layer owns this
        return plds.BitpackedMasks.from_masks(mask, floats), metrics

    def aggregate(state, payloads, wn, participation):
        q = plds.batched_packed_mean(payloads, wn)
        if cfg.bayesian:
            k = jnp.sum(participation.astype(jnp.float32))
            theta = jax.tree_util.tree_map(
                lambda t: None if t is None else
                (1.0 + t * k) / (2.0 + k), q, is_leaf=_NONE)
        else:
            theta = q
        floats = plds.batched_float_mean(payloads.floats, wn)
        return federated.ServerState(
            theta=theta, floats=floats, weights=state.weights,
            seed=state.seed, round=state.round + 1)

    def pooled_aggregate(state, q, floats, k):
        # same transition as `aggregate` given q = weighted mask mean
        # (the aggregator tree already reduced the pooled counts)
        if cfg.bayesian:
            k = jnp.asarray(k, jnp.float32)
            theta = jax.tree_util.tree_map(
                lambda t: None if t is None else
                (1.0 + t * k) / (2.0 + k), q, is_leaf=_NONE)
        else:
            theta = q
        return federated.ServerState(
            theta=theta, floats=floats, weights=state.weights,
            seed=state.seed, round=state.round + 1)

    def eval_params(state, key):
        scores = masking.scores_from_theta(state.theta)
        mp = masking.MaskedParams(state.weights, scores, state.floats)
        return masking.sample_effective(mp, key, mode="sample")

    return FedAlgorithm(name, init=init, client_update=client_update,
                        aggregate=aggregate, eval_params=eval_params,
                        payload_spec=MASK_SPEC, codec=codec,
                        downlink=_prob_downlink(downlink_bits),
                        pooled_aggregate=pooled_aggregate)


@register("fedpm_reg", payload_spec=MASK_SPEC,
          description="regularized FedPM (the paper; lam > 0)")
def fedpm_reg(apply_fn, loss_fn, *, spec=None, lam=1.0, **kw):
    return _fedpm_family("fedpm_reg", apply_fn, loss_fn, spec=spec,
                         lam=lam, **kw)


@register("fedpm", payload_spec=MASK_SPEC,
          description="FedPM reference (no regularizer)")
def fedpm(apply_fn, loss_fn, *, spec=None, **kw):
    kw.pop("lam", None)
    return _fedpm_family("fedpm", apply_fn, loss_fn, spec=spec, lam=0.0,
                         **kw)


# ---------------------------------------------------------------------------
# FedMask — deterministic STE-threshold masking [7]
# ---------------------------------------------------------------------------


class MaskState(NamedTuple):
    scores: Pytree
    floats: Pytree
    weights: Pytree
    round: jax.Array


def _mask_init(spec):
    def init(key, params_like):
        mp = masking.init_masked(key, params_like, spec)
        return MaskState(mp.scores, mp.floats, mp.weights,
                         jnp.zeros((), jnp.int32))
    return init


def _mask_aggregate(state, payloads, wn, participation):
    theta = plds.batched_packed_mean(payloads, wn)
    scores = masking.scores_from_theta(theta)
    return MaskState(scores, state.floats, state.weights,
                     state.round + 1)


def _mask_pooled_aggregate(state, q, floats, k):
    # `_mask_aggregate` given the already-reduced mask mean; payload
    # floats are ignored on this family, exactly as in the flat path
    scores = masking.scores_from_theta(q)
    return MaskState(scores, state.floats, state.weights,
                     state.round + 1)


_SCORE_DOWNLINK = _float_downlink(
    lambda s: {"scores": s.scores, "floats": s.floats})


@register("fedmask", payload_spec=MASK_SPEC,
          description="deterministic STE-threshold masks")
def fedmask(apply_fn, loss_fn, *, spec=None, tau=0.5, lr=0.1,
            local_steps=3, codec=None):
    """Forward uses m = 1[sigmoid(s) > tau] with STE; the uplink is the
    thresholded mask (the biased-update baseline, paper footnote 3)."""
    spec = _default_spec(spec)
    opt = optlib.momentum(lr)

    def client_update(state, data, key):
        ostate = opt.init(state.scores)

        def loss_of(sc, batch):
            eff = masking.sample_effective(
                masking.MaskedParams(state.weights, sc, state.floats),
                key, mode="threshold", tau=tau)
            return loss_fn(apply_fn(eff, batch), batch)

        def step(carry, batch):
            sc, os = carry
            loss, g = jax.value_and_grad(loss_of)(sc, batch)
            upd, os = opt.update(g, os, sc)
            return (optlib.apply_updates(sc, upd), os), loss

        (sc, _), losses = jax.lax.scan(step, (state.scores, ostate),
                                       data)
        mask = jax.tree_util.tree_map(
            lambda s: None if s is None else
            (jax.nn.sigmoid(s) > tau).astype(jnp.uint8),
            sc, is_leaf=_NONE)
        metrics = {"loss": losses[-1],
                   "sparsity": regularizer.sparsity(mask)}
        return plds.BitpackedMasks.from_masks(mask), metrics

    def eval_params(state, key):
        mp = masking.MaskedParams(state.weights, state.scores,
                                  state.floats)
        return masking.sample_effective(mp, key, mode="threshold",
                                        tau=tau)

    return FedAlgorithm("fedmask", init=_mask_init(spec),
                        client_update=client_update,
                        aggregate=_mask_aggregate,
                        eval_params=eval_params, payload_spec=MASK_SPEC,
                        codec=codec, downlink=_SCORE_DOWNLINK,
                        pooled_aggregate=_mask_pooled_aggregate)


# ---------------------------------------------------------------------------
# Top-k over scores — deterministic sparse mask [4]
# ---------------------------------------------------------------------------


@register("topk", payload_spec=MASK_SPEC,
          description="top-k% scores -> 1, rest pruned")
def topk(apply_fn, loss_fn, *, spec=None, k_frac=0.3, lr=0.1,
         local_steps=3, codec=None):
    """Train scores like FedPM (stochastic STE), but the uplink mask
    sets the global top k% of scores to 1 and prunes the rest."""
    spec = _default_spec(spec)
    opt = optlib.momentum(lr)

    def _topk_mask(scores):
        flat = [s.reshape(-1) for s in jax.tree_util.tree_leaves(scores)
                if s is not None]
        kth = jnp.quantile(jnp.concatenate(flat), 1.0 - k_frac)
        return jax.tree_util.tree_map(
            lambda s: None if s is None else
            (s >= kth).astype(jnp.uint8),
            scores, is_leaf=_NONE)

    def client_update(state, data, key):
        ostate = opt.init(state.scores)

        def loss_of(sc, batch, k):
            eff = masking.sample_effective(
                masking.MaskedParams(state.weights, sc, state.floats),
                k, mode="sample")
            return loss_fn(apply_fn(eff, batch), batch)

        def step(carry, xs):
            sc, os = carry
            batch, k = xs
            loss, g = jax.value_and_grad(loss_of)(sc, batch, k)
            upd, os = opt.update(g, os, sc)
            return (optlib.apply_updates(sc, upd), os), loss

        h = jax.tree_util.tree_leaves(data)[0].shape[0]
        keys = jax.random.split(key, h)
        (sc, _), losses = jax.lax.scan(step, (state.scores, ostate),
                                       (data, keys))
        mask = _topk_mask(sc)
        metrics = {"loss": losses[-1],
                   "sparsity": regularizer.sparsity(mask)}
        return plds.BitpackedMasks.from_masks(mask), metrics

    def eval_params(state, key):
        mp = masking.MaskedParams(state.weights, state.scores,
                                  state.floats)
        return masking.sample_effective(mp, key, mode="threshold")

    return FedAlgorithm("topk", init=_mask_init(spec),
                        client_update=client_update,
                        aggregate=_mask_aggregate,
                        eval_params=eval_params, payload_spec=MASK_SPEC,
                        codec=codec, downlink=_SCORE_DOWNLINK,
                        pooled_aggregate=_mask_pooled_aggregate)


# ---------------------------------------------------------------------------
# MV-SignSGD — majority-vote sign compression (1 Bpp, float model) [12]
# ---------------------------------------------------------------------------


SIGN_SPEC = PayloadSpec(plds.SignVotes, nominal_bpp=1.0,
                        description="bitpacked gradient signs, 1 Bpp",
                        default_codec="signpack")


class FloatState(NamedTuple):
    params: Pytree
    round: jax.Array


def _float_init(key, params_like):
    return FloatState(params_like, jnp.zeros((), jnp.int32))


@register("mv_signsgd", payload_spec=SIGN_SPEC,
          description="majority-vote sign compression")
def mv_signsgd(apply_fn, loss_fn, *, spec=None, lr=1e-3, local_steps=3,
               codec=None):
    def client_update(state, data, key):
        # accumulate grad over local batches, send elementwise sign
        def step(g_acc, batch):
            loss, g = jax.value_and_grad(
                lambda pp: loss_fn(apply_fn(pp, batch), batch))(
                    state.params)
            return jax.tree_util.tree_map(jnp.add, g_acc, g), loss

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), state.params)
        g, losses = jax.lax.scan(step, g0, data)
        # 1-bit wire has no zero symbol: break exact-zero gradients
        # (dead units) with an unbiased coin so the majority vote has
        # zero expected drift instead of a systematic -1.
        leaves, treedef = jax.tree_util.tree_flatten(g)
        keys = jax.random.split(jax.random.fold_in(key, 1),
                                max(len(leaves), 1))
        signs = jax.tree_util.tree_unflatten(treedef, [
            jnp.where(gl == 0.0,
                      jax.random.rademacher(kl, gl.shape, jnp.float32),
                      jnp.sign(gl))
            for gl, kl in zip(leaves, keys)])
        metrics = {"loss": losses[-1], "sparsity": jnp.float32(0.0)}
        return plds.SignVotes.from_signs(signs), metrics

    def aggregate(state, payloads, wn, participation):
        # majority vote: >half the weighted sign bits positive -> +1
        q = plds.batched_packed_mean(payloads, wn)
        params = jax.tree_util.tree_map(
            lambda p, qi: (p - lr * jnp.sign(2.0 * qi - 1.0)
                           ).astype(p.dtype),
            state.params, q)
        return FloatState(params, state.round + 1)

    def pooled_aggregate(state, q, floats, k):
        # `aggregate` given the already-reduced vote fraction
        params = jax.tree_util.tree_map(
            lambda p, qi: (p - lr * jnp.sign(2.0 * qi - 1.0)
                           ).astype(p.dtype),
            state.params, q)
        return FloatState(params, state.round + 1)

    return FedAlgorithm("mv_signsgd", init=_float_init,
                        client_update=client_update, aggregate=aggregate,
                        eval_params=lambda s, k: s.params,
                        payload_spec=SIGN_SPEC, codec=codec,
                        downlink=_float_downlink(lambda s: s.params),
                        pooled_aggregate=pooled_aggregate)


# ---------------------------------------------------------------------------
# FedAvg — the float reference (32 Bpp uplink) [1]
# ---------------------------------------------------------------------------


FLOAT_SPEC = PayloadSpec(plds.FloatDeltas, nominal_bpp=32.0,
                         description="raw float32 deltas, 32 Bpp",
                         default_codec="float32")


@register("fedavg", payload_spec=FLOAT_SPEC,
          description="float weight averaging (32-Bpp reference)")
def fedavg(apply_fn, loss_fn, *, spec=None, lr=0.05, local_steps=3,
           codec=None):
    opt = optlib.momentum(lr)

    def client_update(state, data, key):
        ostate = opt.init(state.params)

        def step(carry, batch):
            p, os = carry
            loss, g = jax.value_and_grad(
                lambda pp: loss_fn(apply_fn(pp, batch), batch))(p)
            upd, os = opt.update(g, os, p)
            return (optlib.apply_updates(p, upd), os), loss

        (p, _), losses = jax.lax.scan(step, (state.params, ostate), data)
        delta = jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            p, state.params)
        metrics = {"loss": losses[-1], "sparsity": jnp.float32(0.0)}
        return plds.FloatDeltas.from_tree(delta), metrics

    def aggregate(state, payloads, wn, participation):
        mean_delta = plds.batched_float_mean(payloads.values, wn)
        params = jax.tree_util.tree_map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
            state.params, mean_delta)
        return FloatState(params, state.round + 1)

    return FedAlgorithm("fedavg", init=_float_init,
                        client_update=client_update, aggregate=aggregate,
                        eval_params=lambda s, k: s.params,
                        payload_spec=FLOAT_SPEC, codec=codec,
                        downlink=_float_downlink(lambda s: s.params))
