"""String-keyed algorithm registry.

    from repro import api
    algo = api.get_algorithm("fedpm_reg", apply_fn, loss_fn,
                             spec=masking.MaskSpec(), lam=1.0)
    state = algo.init(key, params_like)
    state, metrics = algo.round(state, data, participation, sizes, key)

Factories have the uniform signature

    factory(apply_fn, loss_fn, *, spec=None, **hyperparams) -> FedAlgorithm

so sweeps iterate `api.available()` without per-algorithm dispatch.
Every factory also accepts ``codec=`` (a `repro.api.codecs` name or
instance) to override the payload spec's default wire codec — e.g.
``get_algorithm("fedpm_reg", ..., codec="golomb")`` — and the mask
family accepts ``downlink_bits=`` for the k-bit theta broadcast.  The
pod-scale launcher resolves the same names to lowered launch plans
(`register_launch` / `get_launch_plan`, populated by
`repro.launch.plans`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.api.protocol import FedAlgorithm, PayloadSpec


@dataclasses.dataclass(frozen=True)
class AlgorithmEntry:
    name: str
    factory: Callable                  # host-sim FedAlgorithm factory
    payload_spec: PayloadSpec
    description: str = ""


_REGISTRY: Dict[str, AlgorithmEntry] = {}
_LAUNCH: Dict[str, Callable] = {}


def register(name: str, *, payload_spec: PayloadSpec,
             description: str = ""):
    """Decorator: register a host-sim algorithm factory under `name`."""
    def deco(factory: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = AlgorithmEntry(name, factory, payload_spec,
                                         description)
        return factory
    return deco


def get_entry(name: str) -> AlgorithmEntry:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown algorithm {name!r}; available: "
            f"{', '.join(available())}")
    return _REGISTRY[name]


def get_algorithm(name: str, apply_fn: Callable, loss_fn: Callable,
                  **kwargs) -> FedAlgorithm:
    """Build the named algorithm for a model (`apply_fn`, `loss_fn`).

    kwargs are algorithm hyperparameters (`spec`, `lam`, `lr`, ...);
    every factory accepts `spec=None` even if it ignores masking.
    """
    return get_entry(name).factory(apply_fn, loss_fn, **kwargs)


def available() -> tuple:
    return tuple(sorted(_REGISTRY))


def register_launch(name: str, plan_factory: Callable) -> None:
    """Attach a pod-scale launch plan factory to a registered name."""
    get_entry(name)  # must name a known algorithm
    _LAUNCH[name] = plan_factory


def get_launch_plan(name: str) -> Callable:
    get_entry(name)
    if name not in _LAUNCH:
        raise KeyError(
            f"algorithm {name!r} has no pod-scale launch plan "
            f"(launchable: {', '.join(launchable()) or 'none'}; import "
            f"repro.launch.plans to populate)")
    return _LAUNCH[name]


def launchable() -> tuple:
    return tuple(sorted(_LAUNCH))
