"""Pluggable wire codecs — REAL serialization, measured on the wire.

The payload layer (`repro.api.payloads`) fixes *what* a client
transmits; this module fixes *how it is coded into words* and what that
costs, exactly.  A `Codec` turns one `UplinkPayload` into a
`WireMessage` carrying genuinely serialized uint32 words plus the exact
bit count, and back:

    msg     = codec.encode(payload)          # host-side, real bytes
    payload = codec.decode(msg)              # lossless inverse
    bits    = codec.measure_bits(payload)    # traced twin of encode's
                                             # size, usable under jit/vmap

`encode`/`decode` run on the host (numpy): variable-length codes cannot
produce shape-polymorphic arrays under `jax.jit`.  `measure_bits` is the
jit-safe mirror — the same size formula evaluated with jnp ops (popcount
over the packed words, no Python loops) — so the round engine reports
``uplink_bits_measured`` without leaving the compiled step.  For the
fixed-rate codecs (`Bitpack32`, `SignPack`, `Float32Raw`, `GolombRice`)
the mirror is bit-exact; for `ArithmeticBernoulli` the encoder pads its
stream to the measured target, so ``msg.wire_bits`` still equals the
traced value (float-ulp differences can move it by at most one word).

Binary codecs pool every mask leaf into ONE bitstream with ONE header:
the eq. 13 entropy bound is computed over the pooled bits, so pooling is
what lets a real coder approach it without per-leaf header overhead.

    codec                wire format                       rate
    -------------------  --------------------------------  -------------
    bitpack   Bitpack32  concatenated bits, 32->1 words    1 Bpp aligned
    golomb    GolombRice run-length Rice codes of 1-gaps   << 1 sparse
    arithmetic Arithmetic Bernoulli arithmetic coding       ~H(p) + eps
    signpack  SignPack   sign bits, 32->1 words            1 Bpp aligned
    float32   Float32Raw raw IEEE words                    dtype width

`CommLedger` accumulates measured two-way traffic across rounds — the
SpaFL-style total communication budget the benchmarks plot against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation

Pytree = Any

WORD_BITS = 32

_NONE = lambda x: x is None


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def word_align(bits):
    """Round a bit count up to a whole number of uint32 words (works on
    Python ints and traced int32 scalars alike)."""
    return (bits + (WORD_BITS - 1)) // WORD_BITS * WORD_BITS


_word_align = word_align


def _flatten_opt(tree):
    """Flatten keeping None leaves in place (None-aware pytrees)."""
    return jax.tree_util.tree_flatten(tree, is_leaf=_NONE)


# ---------------------------------------------------------------------------
# Host-side bit IO (numpy).  Bit order matches aggregation.pack_bits:
# bit i of word w is stream position 32*w + i (little-endian in-word).
# ---------------------------------------------------------------------------


class _BitWriter:
    def __init__(self):
        self.words: List[int] = []
        self.pos = 0

    def write_bit(self, b: int) -> None:
        w, o = divmod(self.pos, WORD_BITS)
        if w == len(self.words):
            self.words.append(0)
        if b:
            self.words[w] |= 1 << o
        self.pos += 1

    def write(self, value: int, nbits: int) -> None:
        for i in range(nbits):
            self.write_bit((value >> i) & 1)

    def to_array(self, pad_to_bits: Optional[int] = None) -> np.ndarray:
        total = self.pos if pad_to_bits is None else pad_to_bits
        if total < self.pos:
            raise ValueError(
                f"stream is {self.pos} bits, cannot pad to {total}")
        nw = (total + WORD_BITS - 1) // WORD_BITS
        arr = np.zeros((nw,), np.uint32)
        arr[: len(self.words)] = np.asarray(self.words, np.uint64).astype(
            np.uint32)
        return arr


class _BitReader:
    def __init__(self, words: np.ndarray):
        self.words = np.asarray(words, np.uint32)
        self.pos = 0
        self.limit = self.words.size * WORD_BITS

    def read_bit(self) -> int:
        if self.pos >= self.limit:       # zero padding past the stream
            return 0
        w, o = divmod(self.pos, WORD_BITS)
        self.pos += 1
        return (int(self.words[w]) >> o) & 1

    def read(self, nbits: int) -> int:
        v = 0
        for i in range(nbits):
            v |= self.read_bit() << i
        return v


def _np_unpack(words: np.ndarray, n: int) -> np.ndarray:
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, None] >> shifts) & np.uint32(1)
    return bits.reshape(-1)[:n].astype(np.uint8)


def _np_pack(bits: np.ndarray) -> np.ndarray:
    pad = (-bits.size) % WORD_BITS
    if pad:
        bits = np.concatenate([bits, np.zeros((pad,), bits.dtype)])
    bits = bits.astype(np.uint32).reshape(-1, WORD_BITS)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return (bits << shifts).sum(axis=1, dtype=np.uint64).astype(np.uint32)


# ---------------------------------------------------------------------------
# WireMessage
# ---------------------------------------------------------------------------


# per-message integrity header: one uint32 CRC32 over words + sidecar.
# Metered SEPARATELY from the mask payload (`header_bits`, like the
# float sidecar) so `wire_bits` — and with it the measured mask Bpp,
# the CommLedger feed, and `analysis.comm_model`'s static collective
# tables — stay exactly what the codec puts on the mask stream.
HEADER_BITS = WORD_BITS


class ChecksumError(ValueError):
    """A WireMessage failed its integrity check (corrupted in transit).

    The async engine (`repro.runtime.async_engine`) catches this at the
    transport seam and schedules a bounded retransmit instead of
    folding garbage into the round buffer."""


@dataclasses.dataclass
class WireMessage:
    """One client's serialized transmission.

    words:    the coded streams (np.uint32 arrays) — the paper's metered
              payload (masks / signs / floats).
    sidecar:  raw float side-channel (norm/bias leaves FedAvg'd alongside
              bitpacked masks), serialized as uint32 views.  Counted in
              the ledger, excluded from the mask Bpp metric — matching
              the paper's reporting.
    meta:     static decode metadata (treedefs, shapes, dtypes, headers).
    checksum: CRC32 over words + sidecar, stamped at encode time
              (`aggregation.words_checksum`).  `verify()` recomputes it
              on arrival; a mismatch means in-transit corruption and the
              receiver must reject the message (`ChecksumError` from
              `decode`).  Costs `HEADER_BITS` on the wire, reported via
              `header_bits` next to — never inside — `wire_bits`.
    """
    codec: str
    payload_cls: type
    words: List[np.ndarray]
    sidecar: List[np.ndarray]
    meta: Dict[str, Any]
    word_bits: int = WORD_BITS
    checksum: Optional[int] = None

    def __post_init__(self):
        if self.checksum is None:
            self.checksum = self.compute_checksum()

    def compute_checksum(self) -> int:
        return aggregation.words_checksum(
            list(self.words) + list(self.sidecar))

    def verify(self) -> bool:
        """True iff the streams still match the stamped checksum."""
        return self.checksum == self.compute_checksum()

    def verify_or_raise(self) -> None:
        if not self.verify():
            raise ChecksumError(
                f"WireMessage({self.codec}) checksum mismatch: "
                f"header {self.checksum:#010x} != stream "
                f"{self.compute_checksum():#010x}")

    @property
    def wire_bits(self) -> int:
        return sum(int(w.size) for w in self.words) * self.word_bits

    @property
    def sidecar_bits(self) -> int:
        return sum(int(w.size) for w in self.sidecar) * self.word_bits

    @property
    def header_bits(self) -> int:
        return HEADER_BITS

    @property
    def total_bits(self) -> int:
        return self.wire_bits + self.sidecar_bits + self.header_bits


# ---------------------------------------------------------------------------
# Sidecar float (de)serialization — shared by every codec
# ---------------------------------------------------------------------------


def _encode_float_tree(tree):
    leaves, treedef = _flatten_opt(tree)
    arrays, shapes, dtypes = [], [], []
    for l in leaves:
        if l is None:
            shapes.append(None)
            dtypes.append(None)
            continue
        a = np.asarray(l)
        shapes.append(a.shape)
        dtypes.append(a.dtype.str)
        raw = a.tobytes()
        raw += b"\x00" * ((-len(raw)) % 4)
        arrays.append(np.frombuffer(raw, np.uint32).copy())
    return arrays, {"treedef": treedef, "shapes": tuple(shapes),
                    "dtypes": tuple(dtypes)}


def _decode_float_tree(arrays, meta):
    it = iter(arrays)
    leaves = []
    for sh, dt in zip(meta["shapes"], meta["dtypes"]):
        if sh is None:
            leaves.append(None)
            continue
        raw = next(it).tobytes()
        nbytes = _prod(sh) * np.dtype(dt).itemsize
        leaves.append(jnp.asarray(
            np.frombuffer(raw[:nbytes], dt).reshape(sh)))
    return jax.tree_util.tree_unflatten(meta["treedef"], leaves)


def float_tree_bits(tree) -> int:
    """Static serialized size of a float pytree (word-aligned/leaf)."""
    tot = 0
    for l in jax.tree_util.tree_leaves(tree, is_leaf=_NONE):
        if l is None:
            continue
        tot += _word_align(l.size * l.dtype.itemsize * 8)
    return tot


# ---------------------------------------------------------------------------
# Codec protocol
# ---------------------------------------------------------------------------


class Codec:
    """encode/decode are host-side and lossless; measure_bits is the
    traced (jit/vmap-safe) size of encode's output for the same
    payload."""

    name: str = "abstract"

    def accepts(self, payload_cls: type) -> bool:
        raise NotImplementedError

    def encode(self, payload) -> WireMessage:
        raise NotImplementedError

    def decode(self, msg: WireMessage):
        raise NotImplementedError

    def measure_bits(self, payload) -> jax.Array:
        """Coded wire bits (int32 scalar), excluding the float sidecar."""
        raise NotImplementedError

    def sidecar_bits(self, payload) -> int:
        """Static bits of the float side-channel riding along."""
        floats = getattr(payload, "floats", None)
        return float_tree_bits(floats) if floats is not None else 0

    def __repr__(self):
        return f"{type(self).__name__}({self.name!r})"


# ---------------------------------------------------------------------------
# Packed binary codecs (BitpackedMasks / SignVotes)
# ---------------------------------------------------------------------------


def _pooled_bits_np(payload):
    """Host: concatenate every non-None leaf's bits (padding dropped)."""
    leaves, treedef = _flatten_opt(payload.words)
    chunks, it = [], iter(payload.shapes)
    for w in leaves:
        if w is None:
            continue
        chunks.append(_np_unpack(np.asarray(w), _prod(next(it))))
    bits = (np.concatenate(chunks) if chunks
            else np.zeros((0,), np.uint8))
    return bits, treedef, [w is None for w in leaves]


def _packed_meta(payload, treedef, none_mask):
    floats = getattr(payload, "floats", None)
    side_arrays, fmeta = _encode_float_tree(floats)
    return side_arrays, {
        "words_treedef": treedef,
        "none_mask": tuple(none_mask),
        "shapes": payload.shapes,
        "has_floats": hasattr(payload, "floats"),
        "floats_meta": fmeta,
    }


def _rebuild_packed(payload_cls, bits: np.ndarray, msg: WireMessage):
    """Split pooled bits back into per-leaf packed words and rebuild the
    payload object (the exact form `UplinkPayload` puts on the uplink)."""
    meta = msg.meta
    shapes_it = iter(meta["shapes"])
    leaves, off = [], 0
    for is_none in meta["none_mask"]:
        if is_none:
            leaves.append(None)
            continue
        n = _prod(next(shapes_it))
        leaves.append(jnp.asarray(_np_pack(bits[off:off + n])))
        off += n
    words = jax.tree_util.tree_unflatten(meta["words_treedef"], leaves)
    if meta["has_floats"]:
        floats = _decode_float_tree(msg.sidecar, meta["floats_meta"])
        return payload_cls(words, floats, meta["shapes"])
    return payload_cls(words, meta["shapes"])


def _payload_n(payload) -> int:
    return sum(_prod(sh) for sh in payload.shapes)


def _popcount_total(payload) -> jax.Array:
    """Pooled ones count straight from the packed words (the
    Pallas-friendly path: `lax.population_count` on uint32 words — the
    same primitive `repro.kernels.bitpack` lowers; zero unpacking).
    Padding bits are zeros by construction and never inflate the count.
    """
    ones = jnp.int32(0)
    for w in jax.tree_util.tree_leaves(payload.words, is_leaf=_NONE):
        if w is None:
            continue
        ones = ones + jnp.sum(
            jax.lax.population_count(w).astype(jnp.int32))
    return ones


def _pooled_bits_traced(payload) -> jax.Array:
    """Traced concatenation of every leaf's bits, padding dropped."""
    chunks, it = [], iter(payload.shapes)
    for w in jax.tree_util.tree_leaves(payload.words, is_leaf=_NONE):
        if w is None:
            continue
        chunks.append(aggregation.unpack_bits(w, _prod(next(it))))
    if not chunks:
        return jnp.zeros((0,), jnp.uint8)
    return jnp.concatenate(chunks)


class _PackedCodec(Codec):
    def accepts(self, payload_cls: type) -> bool:
        from repro.api import payloads as plds
        return issubclass(payload_cls,
                          (plds.BitpackedMasks, plds.SignVotes))

    def measure_pooled_bits(self, bits: jax.Array) -> jax.Array:
        """Traced wire size for ONE client's pooled {0,1} vector — the
        primitive the pod-scale round step vmaps over cohorts."""
        raise NotImplementedError

    def measure_bits(self, payload) -> jax.Array:
        return self.measure_pooled_bits(_pooled_bits_traced(payload))


class Bitpack32(_PackedCodec):
    """The paper's artifact format: pooled bits, 32 -> 1 uint32 words.

    Exactly `align32(n)` bits — the word-aligned 1 Bpp reference every
    entropy coder is measured against.
    """

    name = "bitpack"

    def encode(self, payload) -> WireMessage:
        bits, treedef, none_mask = _pooled_bits_np(payload)
        side, meta = _packed_meta(payload, treedef, none_mask)
        return WireMessage(self.name, type(payload), [_np_pack(bits)],
                           side, meta)

    def decode(self, msg: WireMessage):
        msg.verify_or_raise()
        n = sum(_prod(sh) for sh in msg.meta["shapes"])
        bits = _np_unpack(msg.words[0], n)
        return _rebuild_packed(msg.payload_cls, bits, msg)

    def measure_pooled_bits(self, bits: jax.Array) -> jax.Array:
        return jnp.int32(_word_align(bits.shape[0]))

    def measure_pooled_words(self, words: jax.Array,
                             n: int) -> jax.Array:
        """Size from the bit-packed words directly (word-aligned size
        depends only on n) — lets the pod round step meter the fused
        sample+pack output without unpacking the mask."""
        return jnp.int32(_word_align(n))

    def measure_bits(self, payload) -> jax.Array:
        return jnp.int32(_word_align(_payload_n(payload)))


class SignPack(Bitpack32):
    """Bitpack32 with sign semantics (+1 -> 1, -1 -> 0): MV-SignSGD's
    1-bit wire.  Identical word layout; named separately so the sign
    payloads advertise their own default."""

    name = "signpack"


def _rice_k(n, ones):
    """Rice parameter from the integer mean gap — pure integer compare
    chain so numpy and traced jnp agree bit-for-bit."""
    gbar = (n - ones) // jnp.maximum(ones, 1) if hasattr(ones, "dtype") \
        else (n - ones) // max(ones, 1)
    if hasattr(gbar, "dtype"):
        thresh = jnp.asarray(2 ** np.arange(1, 16), jnp.int32)
        return jnp.sum((gbar >= thresh).astype(jnp.int32))
    return int(sum(1 for t in 2 ** np.arange(1, 16) if gbar >= t))


class GolombRice(_PackedCodec):
    """Run-length coding of the gaps between ones, Rice(2^k) per gap.

    Stream: 32-bit header [k:5 | ones:27], then per one-bit the gap g to
    the previous one as unary(g >> k) + k literal low bits.  Trailing
    zeros are implicit (the decoder knows n and the ones count).  The
    codec of choice for very sparse regularized masks where even the
    arithmetic coder's tables are overkill.
    """

    name = "golomb"

    _MAX_ONES = (1 << 27) - 1

    def encode(self, payload) -> WireMessage:
        bits, treedef, none_mask = _pooled_bits_np(payload)
        side, meta = _packed_meta(payload, treedef, none_mask)
        n, ones = bits.size, int(bits.sum())
        if ones > self._MAX_ONES:
            raise ValueError(f"GolombRice supports < 2^27 ones per "
                             f"payload, got {ones}")
        k = _rice_k(n, ones)
        wr = _BitWriter()
        wr.write(k | (ones << 5), 32)
        pos = np.flatnonzero(bits)
        gaps = np.diff(pos, prepend=-1) - 1
        for g in gaps:
            g = int(g)
            for _ in range(g >> k):
                wr.write_bit(1)
            wr.write_bit(0)
            wr.write(g & ((1 << k) - 1), k)
        return WireMessage(self.name, type(payload),
                           [wr.to_array(_word_align(wr.pos))], side, meta)

    def decode(self, msg: WireMessage):
        msg.verify_or_raise()
        n = sum(_prod(sh) for sh in msg.meta["shapes"])
        rd = _BitReader(msg.words[0])
        header = rd.read(32)
        k, ones = header & 31, header >> 5
        bits = np.zeros((n,), np.uint8)
        pos = -1
        for _ in range(ones):
            q = 0
            while rd.read_bit():
                q += 1
            g = (q << k) | rd.read(k)
            pos += g + 1
            bits[pos] = 1
        return _rebuild_packed(msg.payload_cls, bits, msg)

    def measure_pooled_bits(self, bits: jax.Array) -> jax.Array:
        bits = bits.astype(jnp.int32)
        n = bits.shape[0]
        if n == 0:
            return jnp.int32(WORD_BITS)
        ones = jnp.sum(bits)
        k = _rice_k(jnp.int32(n), ones)
        pos = jnp.arange(n, dtype=jnp.int32)
        marked = jnp.where(bits == 1, pos, -1)
        last = jax.lax.associative_scan(jnp.maximum, marked)
        prev = jnp.concatenate(
            [jnp.full((1,), -1, jnp.int32), last[:-1]])
        gaps = jnp.where(bits == 1, pos - prev - 1, 0)
        per = jnp.where(bits == 1, (gaps >> k) + 1 + k, 0)
        return _word_align(jnp.int32(32) + jnp.sum(per))

    def measure_pooled_words(self, words: jax.Array,
                             n: int) -> jax.Array:
        """Bit-exact `measure_pooled_bits`, straight off the packed
        uint32 words: a `lax.scan` carries the zero-run between words
        while a 32-lane prev-one scan recovers each gap inside one —
        the n-length mask is never materialized, so the metrics path
        honors the same no-unpacked-mask rule the wire does (padding
        bits beyond n are zero and only ever extend the final, unused
        run)."""
        if n == 0:
            return jnp.int32(WORD_BITS)
        ones = jnp.sum(
            jax.lax.population_count(words).astype(jnp.int32))
        k = _rice_k(jnp.int32(n), ones)
        lanes = jnp.arange(WORD_BITS, dtype=jnp.int32)
        ulanes = lanes.astype(jnp.uint32)

        def word_body(carry, w):
            run, acc = carry     # zeros since the previous one, bits
            bit = ((w.astype(jnp.uint32) >> ulanes)
                   & jnp.uint32(1)).astype(jnp.int32)
            marked = jnp.where(bit == 1, lanes, -1)
            last = jax.lax.associative_scan(jnp.maximum, marked)
            prev = jnp.concatenate(
                [jnp.full((1,), -1, jnp.int32), last[:-1]])
            gap = jnp.where(prev < 0, lanes + run, lanes - prev - 1)
            acc = acc + jnp.sum(
                jnp.where(bit == 1, (gap >> k) + 1 + k, 0))
            run = jnp.where(last[-1] < 0, run + WORD_BITS,
                            WORD_BITS - 1 - last[-1])
            return (run, acc), None

        (_, total), _ = jax.lax.scan(
            word_body, (jnp.int32(0), jnp.int32(0)),
            words.reshape(-1))
        return _word_align(jnp.int32(32) + total)


class ArithmeticBernoulli(_PackedCodec):
    """Bernoulli-prior binary arithmetic coding of the pooled bits —
    the coder that actually realizes the paper's sub-1-Bpp uplink.

    Stream: 32-bit header [p1 scaled to 16 bits | reserved], then a
    CACM87-style carry-free arithmetic code of the n bits under the
    static prior p1.  The size formula (and thus `measure_bits`) is
    `align32(32 + ceil(n*H(p1q)) + slack)` with a small fixed slack for
    coder termination and finite-precision rounding; the encoder pads
    its stream to that target, so measured == wire exactly, and the
    whole thing sits within a few words of the eq. 13 entropy bound.
    `measure_bits` needs only a popcount over the packed words.
    """

    name = "arithmetic"

    _PSCALE = 1 << 16
    _HALF = 1 << 31
    _QTR = 1 << 30

    @classmethod
    def _p1_scaled(cls, ones, n):
        """Quantized prior, identical formula for np and jnp inputs
        (IEEE f32 divide/multiply/round are exact matches)."""
        if hasattr(ones, "dtype") and not isinstance(ones, np.ndarray):
            p = ones.astype(jnp.float32) / jnp.float32(n)
            s = jnp.round(p * jnp.float32(cls._PSCALE))
            return jnp.clip(s.astype(jnp.int32), 1, cls._PSCALE - 1)
        p = np.float32(ones) / np.float32(n)
        s = np.round(p * np.float32(cls._PSCALE))
        return int(np.clip(np.int64(s), 1, cls._PSCALE - 1))

    @classmethod
    def _target_bits(cls, ones, n, p1c):
        """Shared size formula: ideal Bernoulli code length + header +
        termination/rounding slack, word-aligned."""
        if hasattr(p1c, "dtype") and not isinstance(p1c, np.ndarray):
            lg = jnp.log2
            f32 = lambda x: jnp.asarray(x, jnp.float32)
            ceil, i32 = jnp.ceil, lambda x: x.astype(jnp.int32)
        else:
            lg = np.log2
            f32 = np.float32
            ceil, i32 = np.ceil, lambda x: int(x)
        p1 = f32(p1c) / f32(cls._PSCALE)
        ideal = -(f32(ones) * lg(p1) + f32(n - ones) * lg(1 - p1))
        slack = 48 + (n >> 13)
        return _word_align(i32(ceil(ideal)) + 32 + slack)

    def encode(self, payload) -> WireMessage:
        bits, treedef, none_mask = _pooled_bits_np(payload)
        side, meta = _packed_meta(payload, treedef, none_mask)
        n, ones = bits.size, int(bits.sum())
        wr = _BitWriter()
        if n == 0:
            return WireMessage(self.name, type(payload),
                               [wr.to_array(0)], side, meta)
        p1c = self._p1_scaled(ones, n)
        target = int(self._target_bits(ones, n, p1c))
        wr.write(p1c, 32)
        self._ac_encode(bits, p1c, wr)
        if wr.pos > target:  # the slack term guarantees this never fires
            raise RuntimeError(
                f"arithmetic stream {wr.pos}b exceeded target {target}b")
        return WireMessage(self.name, type(payload),
                           [wr.to_array(target)], side, meta)

    def decode(self, msg: WireMessage):
        msg.verify_or_raise()
        n = sum(_prod(sh) for sh in msg.meta["shapes"])
        if n == 0:
            return _rebuild_packed(msg.payload_cls,
                                   np.zeros((0,), np.uint8), msg)
        rd = _BitReader(msg.words[0])
        p1c = rd.read(32) & (self._PSCALE - 1)
        bits = self._ac_decode(rd, n, p1c)
        return _rebuild_packed(msg.payload_cls, bits, msg)

    def measure_pooled_bits(self, bits: jax.Array) -> jax.Array:
        n = bits.shape[0]
        if n == 0:
            return jnp.int32(0)
        return self._measure_from_counts(
            jnp.sum(bits.astype(jnp.int32)), n)

    def measure_pooled_words(self, words: jax.Array,
                             n: int) -> jax.Array:
        """Size from bit-packed uint32 words (padding bits zero) and
        the true bit count n: the formula needs only (ones, n), so a
        popcount replaces unpacking the mask (per-leaf word padding in
        a pooled stream changes neither count)."""
        if n == 0:
            return jnp.int32(0)
        ones = jnp.sum(
            jax.lax.population_count(words).astype(jnp.int32))
        return self._measure_from_counts(ones, n)

    def measure_bits(self, payload) -> jax.Array:
        n = _payload_n(payload)
        if n == 0:
            return jnp.int32(0)
        return self._measure_from_counts(_popcount_total(payload), n)

    def _measure_from_counts(self, ones, n) -> jax.Array:
        p1c = self._p1_scaled(ones, n)
        return self._target_bits(ones, jnp.int32(n), p1c)

    # -- CACM87 carry-free coder ------------------------------------------

    @classmethod
    def _ac_encode(cls, bits: np.ndarray, p1c: int,
                   wr: _BitWriter) -> None:
        HALF, QTR = cls._HALF, cls._QTR
        p0c = cls._PSCALE - p1c
        lo, hi, pending = 0, (1 << 32) - 1, 0

        def out(b):
            nonlocal pending
            wr.write_bit(b)
            while pending:
                wr.write_bit(1 - b)
                pending -= 1

        for b in bits.tolist():
            span = hi - lo + 1
            split = lo + ((span * p0c) >> 16) - 1
            if b:
                lo = split + 1
            else:
                hi = split
            while True:
                if hi < HALF:
                    out(0)
                elif lo >= HALF:
                    out(1)
                    lo -= HALF
                    hi -= HALF
                elif lo >= QTR and hi < 3 * QTR:
                    pending += 1
                    lo -= QTR
                    hi -= QTR
                else:
                    break
                lo <<= 1
                hi = (hi << 1) | 1
        pending += 1
        out(0 if lo < QTR else 1)

    @classmethod
    def _ac_decode(cls, rd: _BitReader, n: int, p1c: int) -> np.ndarray:
        HALF, QTR = cls._HALF, cls._QTR
        p0c = cls._PSCALE - p1c
        lo, hi = 0, (1 << 32) - 1
        code = 0
        for _ in range(32):
            code = (code << 1) | rd.read_bit()
        bits = np.zeros((n,), np.uint8)
        for i in range(n):
            span = hi - lo + 1
            split = lo + ((span * p0c) >> 16) - 1
            if code <= split:
                hi = split
            else:
                bits[i] = 1
                lo = split + 1
            while True:
                if hi < HALF:
                    pass
                elif lo >= HALF:
                    lo -= HALF
                    hi -= HALF
                    code -= HALF
                elif lo >= QTR and hi < 3 * QTR:
                    lo -= QTR
                    hi -= QTR
                    code -= QTR
                else:
                    break
                lo <<= 1
                hi = (hi << 1) | 1
                code = (code << 1) | rd.read_bit()
        return bits


# ---------------------------------------------------------------------------
# Float codec (FloatDeltas)
# ---------------------------------------------------------------------------


class Float32Raw(Codec):
    """Raw IEEE words — the uncompressed reference the paper divides by.
    Works for any float dtype; the wire is the dtype's own width."""

    name = "float32"

    def accepts(self, payload_cls: type) -> bool:
        from repro.api import payloads as plds
        return issubclass(payload_cls, plds.FloatDeltas)

    def encode(self, payload) -> WireMessage:
        arrays, fmeta = _encode_float_tree(payload.values)
        meta = {"floats_meta": fmeta, "shapes": payload.shapes,
                "bits": payload.bits}
        return WireMessage(self.name, type(payload), arrays, [], meta)

    def decode(self, msg: WireMessage):
        msg.verify_or_raise()
        values = _decode_float_tree(msg.words, msg.meta["floats_meta"])
        return msg.payload_cls(values, msg.meta["shapes"],
                               msg.meta["bits"])

    def measure_bits(self, payload) -> jax.Array:
        tot = 0
        for sh, b in zip(payload.shapes, payload.bits):
            tot += _word_align(_prod(sh) * b)
        # f32, not int32: 32 Bpp on a >=67M-param model overflows int32
        return jnp.float32(tot)

    def sidecar_bits(self, payload) -> int:
        return 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


CODECS: Dict[str, Codec] = {
    c.name: c for c in (Bitpack32(), GolombRice(), ArithmeticBernoulli(),
                        SignPack(), Float32Raw())
}


def available() -> tuple:
    return tuple(sorted(CODECS))


def get_codec(name: str) -> Codec:
    if name not in CODECS:
        raise KeyError(f"unknown codec {name!r}; available: "
                       f"{', '.join(available())}")
    return CODECS[name]


def default_for(payload_cls: type) -> str:
    from repro.api import payloads as plds
    if issubclass(payload_cls, plds.SignVotes):
        return "signpack"
    if issubclass(payload_cls, plds.BitpackedMasks):
        return "arithmetic"
    return "float32"


def resolve(codec, payload_spec) -> Codec:
    """None -> the spec's default; str -> registry; Codec -> itself.
    Validates the codec can serialize the spec's payload class."""
    if codec is None:
        codec = getattr(payload_spec, "default_codec", None) \
            or default_for(payload_spec.cls)
    if isinstance(codec, str):
        codec = get_codec(codec)
    if not codec.accepts(payload_spec.cls):
        raise ValueError(
            f"codec {codec.name!r} cannot serialize "
            f"{payload_spec.cls.__name__} payloads")
    return codec


# ---------------------------------------------------------------------------
# CommLedger — cumulative two-way traffic over a whole run
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CommLedger:
    """Accumulates measured wire bits across rounds, both directions.

    Fed with the round-engine metrics (`uplink_bits_measured`,
    `downlink_bits`); the benchmarks plot accuracy against
    `total_mb` — communication as the paper's x-axis, not rounds.
    MB here is 1e6 bytes.
    """

    uplink_bits: float = 0.0
    downlink_bits: float = 0.0
    rounds: int = 0
    # aggregator-tree root traffic (pooled fold records crossing the
    # edge -> root hop); O(params) per round, see runtime/agg_tree.py
    root_bits: float = 0.0

    def update(self, metrics: Dict[str, Any]) -> "CommLedger":
        self.uplink_bits += float(metrics.get("uplink_bits_measured",
                                              0.0))
        self.downlink_bits += float(metrics.get("downlink_bits", 0.0))
        self.root_bits += float(metrics.get("root_bits_measured", 0.0))
        self.rounds += 1
        return self

    @property
    def uplink_mb(self) -> float:
        return self.uplink_bits / 8e6

    @property
    def downlink_mb(self) -> float:
        return self.downlink_bits / 8e6

    @property
    def total_mb(self) -> float:
        return self.uplink_mb + self.downlink_mb

    @property
    def root_mb(self) -> float:
        return self.root_bits / 8e6

    def as_dict(self) -> Dict[str, float]:
        return {"rounds": self.rounds,
                "cumulative_uplink_mb": self.uplink_mb,
                "cumulative_downlink_mb": self.downlink_mb,
                "cumulative_root_mb": self.root_mb,
                "cumulative_total_mb": self.total_mb}
