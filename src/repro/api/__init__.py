"""`repro.api` — the unified federated-learning surface.

One protocol (`FedAlgorithm`: init / client_update / aggregate /
eval_params + payload_spec), one registry (`register` /
`get_algorithm`), typed payloads in BOTH directions (`BitpackedMasks`,
`SignVotes`, `FloatDeltas` up; `ProbBroadcast`, `FloatBroadcast` down),
and pluggable wire codecs (`repro.api.codecs`: `bitpack`, `golomb`,
`arithmetic`, `signpack`, `float32`) whose REAL serialized size is the
single source of truth for the measured communication metrics.  The
`CommLedger` accumulates two-way wire bytes across a whole run.
Host-sim sweeps, the benchmarks, the examples, and the pod-scale
launcher all resolve algorithms here.
"""
from repro.api.codecs import (  # noqa: F401
    ArithmeticBernoulli, Bitpack32, Codec, CommLedger, Float32Raw,
    GolombRice, SignPack, WireMessage, get_codec, resolve as
    resolve_codec)
from repro.api.codecs import available as available_codecs  # noqa: F401
from repro.api.payloads import (  # noqa: F401
    BitpackedMasks, DownlinkPayload, FloatBroadcast, FloatDeltas,
    ProbBroadcast, SignVotes, UplinkPayload, batched_float_mean,
    batched_packed_mean, mean_from_words, pack_leaf)
from repro.api.protocol import (  # noqa: F401
    FedAlgorithm, PayloadSpec, SupportsFedAlgorithm, client_view,
    evaluate, run_round)
from repro.api.registry import (  # noqa: F401
    AlgorithmEntry, available, get_algorithm, get_entry,
    get_launch_plan, launchable, register, register_launch)
from repro.api import algorithms  # noqa: F401  (registers the six)
