"""`repro.api` — the unified federated-learning surface.

One protocol (`FedAlgorithm`: init / client_update / aggregate /
eval_params + payload_spec), one registry (`register` /
`get_algorithm`), and typed uplink payloads (`BitpackedMasks`,
`SignVotes`, `FloatDeltas`) whose serialized size is the single source
of truth for `uplink_bpp`.  Host-sim sweeps, the benchmarks, the
examples, and the pod-scale launcher all resolve algorithms here.
"""
from repro.api.payloads import (  # noqa: F401
    BitpackedMasks, FloatDeltas, SignVotes, UplinkPayload,
    batched_float_mean, batched_packed_mean, mean_from_words, pack_leaf)
from repro.api.protocol import (  # noqa: F401
    FedAlgorithm, PayloadSpec, SupportsFedAlgorithm, evaluate, run_round)
from repro.api.registry import (  # noqa: F401
    AlgorithmEntry, available, get_algorithm, get_entry,
    get_launch_plan, launchable, register, register_launch)
from repro.api import algorithms  # noqa: F401  (registers the six)
