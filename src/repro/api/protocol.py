"""The `FedAlgorithm` protocol and the shared round engine.

Every federated algorithm in the repo — the paper's regularized FedPM,
the FedPM reference, and all Sec.-IV baselines — is expressed as four
functions plus a payload spec:

    init(key, params_like)              -> state
    client_update(state, data, key)     -> (UplinkPayload, metrics)
    aggregate(state, payloads, wn, participation) -> state
    eval_params(state, key)             -> effective model params

`client_update` is written for ONE client; `run_round` vmaps it over
the cohort, weights the client metrics by |D_i| x participation
(eq. 8 with dropped nodes renormalized out), and — crucially — computes
``uplink_bpp`` once, from the typed payloads, in the transport layer.
Algorithms cannot report a communication cost their payload doesn't
serialize.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class PayloadSpec:
    """Static description of what an algorithm's clients transmit."""
    cls: type                      # UplinkPayload subclass
    nominal_bpp: Optional[float]   # None => data-dependent (entropy-coded)
    description: str = ""


@runtime_checkable
class SupportsFedAlgorithm(Protocol):
    """Structural protocol — anything with these attributes plugs into
    `run_round` / the registry (duck-typed; `FedAlgorithm` below is the
    standard concrete carrier)."""
    name: str
    payload_spec: PayloadSpec

    def init(self, key, params_like): ...
    def client_update(self, state, data, key): ...
    def aggregate(self, state, payloads, wn, participation): ...
    def eval_params(self, state, key): ...


def run_round(algo: "FedAlgorithm", state, data, participation, sizes,
              key):
    """One federated round, algorithm-agnostic.

    data: pytree with leading axes [K, H, ...] (client x local step);
    participation: bool[K]; sizes: f32[K] (|D_i|).
    Returns (new_state, metrics) with `uplink_bpp` derived from the
    payloads' serialized form.
    """
    n_clients = participation.shape[0]
    keys = jax.random.split(key, n_clients)
    payloads, metrics = jax.vmap(
        algo.client_update, in_axes=(None, 0, 0))(state, data, keys)

    w = sizes * participation.astype(jnp.float32)
    wn = w / jnp.maximum(jnp.sum(w), 1e-9)

    new_state = algo.aggregate(state, payloads, wn, participation)

    out = {k: jnp.sum(v * wn) if getattr(v, "ndim", 0) == 1 else v
           for k, v in metrics.items()}
    # Transport-layer accounting: one formula for every algorithm.
    bpps = jax.vmap(lambda p: p.bpp())(payloads)
    out["uplink_bpp"] = jnp.sum(bpps * wn)
    return new_state, out


class FedAlgorithm:
    """Concrete carrier for the protocol, plus a jitted `round`.

    `round(state, data, participation, sizes, key)` keeps the legacy
    host-sim signature so existing sweeps/tests drive any algorithm
    uniformly.
    """

    def __init__(self, name: str, *, init: Callable,
                 client_update: Callable, aggregate: Callable,
                 eval_params: Callable, payload_spec: PayloadSpec):
        self.name = name
        self.init = init
        self.client_update = client_update
        self.aggregate = aggregate
        self.eval_params = eval_params
        self.payload_spec = payload_spec
        self._round = jax.jit(
            lambda state, data, part, sizes, key: run_round(
                self, state, data, part, sizes, key))

    def round(self, state, data, participation, sizes, key):
        return self._round(state, data, participation, sizes, key)

    def __repr__(self):
        return (f"FedAlgorithm({self.name!r}, "
                f"payload={self.payload_spec.cls.__name__})")


def evaluate(algo: FedAlgorithm, state, batch, apply_fn: Callable,
             metric_fn: Callable, key, n_samples: int = 1):
    """Mean metric over `n_samples` sampled effective networks."""
    total = 0.0
    for i in range(n_samples):
        eff = algo.eval_params(state, jax.random.fold_in(key, i))
        total = total + metric_fn(apply_fn(eff, batch), batch)
    return total / n_samples
