"""The `FedAlgorithm` protocol and the shared round engine.

Every federated algorithm in the repo — the paper's regularized FedPM,
the FedPM reference, and all Sec.-IV baselines — is expressed as four
functions plus a payload spec:

    init(key, params_like)              -> state
    client_update(state, data, key)     -> (UplinkPayload, metrics)
    aggregate(state, payloads, wn, participation) -> state
    eval_params(state, key)             -> effective model params

`client_update` is written for ONE client; `run_round` vmaps it over
the cohort, weights the client metrics by |D_i| x participation
(eq. 8 with dropped nodes renormalized out), and — crucially — performs
ALL communication accounting in the transport layer:

  * the server broadcast goes through the algorithm's `downlink`
    (`ProbBroadcast` quantizes theta to k bits on the real wire;
    clients see the dequantized copy), reported as ``downlink_bpp`` /
    ``downlink_bits``;
  * every uplink payload is metered by the round's `Codec`
    (`repro.api.codecs`): ``uplink_bpp`` stays the eq. 13 entropy lower
    bound, ``uplink_bpp_measured`` / ``uplink_bits_measured`` are what
    the codec actually puts on the wire.

Algorithms cannot report a communication cost their payload doesn't
serialize.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.api import codecs as codecs_lib

Pytree = Any


@dataclasses.dataclass(frozen=True)
class PayloadSpec:
    """Static description of what an algorithm's clients transmit."""
    cls: type                      # UplinkPayload subclass
    nominal_bpp: Optional[float]   # None => data-dependent (entropy-coded)
    description: str = ""
    default_codec: Optional[str] = None  # repro.api.codecs name


@runtime_checkable
class SupportsFedAlgorithm(Protocol):
    """Structural protocol — anything with these attributes plugs into
    `run_round` / the registry (duck-typed; `FedAlgorithm` below is the
    standard concrete carrier)."""
    name: str
    payload_spec: PayloadSpec

    def init(self, key, params_like): ...
    def client_update(self, state, data, key): ...
    def aggregate(self, state, payloads, wn, participation): ...
    def eval_params(self, state, key): ...


def client_view(algo, state, key):
    """What the clients receive this round: the state after the server
    broadcast went over the (possibly quantized) downlink wire.
    Returns (downlink_payload | None, client_state)."""
    downlink = getattr(algo, "downlink", None)
    if downlink is None:
        return None, state
    return downlink(state, jax.random.fold_in(key, 0x0d0e))


def run_round(algo: "FedAlgorithm", state, data, participation, sizes,
              key, codec=None):
    """One federated round, algorithm-agnostic.

    data: pytree with leading axes [K, H, ...] (client x local step);
    participation: bool[K]; sizes: f32[K] (|D_i|).
    Returns (new_state, metrics).  All communication metrics come from
    the transport layer: `uplink_bpp` (entropy bound) and
    `uplink_bpp_measured` / `uplink_bits_measured` (the codec's real
    wire size) from the typed payloads, `downlink_bpp` /
    `downlink_bits` from the server broadcast.
    """
    if codec is None:
        codec = getattr(algo, "codec", None)
    n_clients = participation.shape[0]
    pf = participation.astype(jnp.float32)
    n_part = jnp.sum(pf)

    # -- downlink: server -> clients over the real broadcast wire -------
    dl_payload, client_state = client_view(algo, state, key)

    keys = jax.random.split(key, n_clients)
    payloads, metrics = jax.vmap(
        algo.client_update, in_axes=(None, 0, 0))(client_state, data,
                                                  keys)

    w = sizes * pf
    wn = w / jnp.maximum(jnp.sum(w), 1e-9)

    new_state = algo.aggregate(state, payloads, wn, participation)

    out = {k: jnp.sum(v * wn) if getattr(v, "ndim", 0) == 1 else v
           for k, v in metrics.items()}
    # Transport-layer accounting: one formula for every algorithm.
    bpps = jax.vmap(lambda p: p.bpp())(payloads)
    out["uplink_bpp"] = jnp.sum(bpps * wn)
    if codec is not None:
        n_params = max(payloads.num_params(), 1)
        bits, side = jax.vmap(lambda p: (
            codec.measure_bits(p),
            jnp.int32(codec.sidecar_bits(p))))(payloads)
        bits = bits.astype(jnp.float32)
        side = side.astype(jnp.float32)
        out["uplink_bpp_measured"] = jnp.sum(bits * wn) / n_params
        out["uplink_bits_measured"] = jnp.sum((bits + side) * pf)
    if dl_payload is not None:
        out["downlink_bpp"] = dl_payload.bpp()
        out["downlink_bits"] = jnp.float32(
            dl_payload.wire_bits() + dl_payload.sidecar_bits()) * n_part
    else:
        out["downlink_bpp"] = jnp.float32(0.0)
        out["downlink_bits"] = jnp.float32(0.0)
    return new_state, out


class FedAlgorithm:
    """Concrete carrier for the protocol, plus a jitted `round`.

    `round(state, data, participation, sizes, key)` keeps the legacy
    host-sim signature so existing sweeps/tests drive any algorithm
    uniformly.  The old state is DONATED to the round step (the buffers
    are reused in place where the backend supports it — at pod scale
    this halves peak state memory), so callers must not touch a state
    pytree after passing it to `round`; use the returned one.

    `codec` (name or `repro.api.codecs.Codec`) picks the wire codec the
    round engine meters uplinks with; defaults to the payload spec's
    `default_codec`.  `downlink` is the per-round server broadcast:
    fn(state, key) -> (DownlinkPayload, client_state).

    `pooled_aggregate` (optional) is the hierarchical-aggregation seam:
    ``fn(state, q, floats, k) -> state`` where ``q`` is the
    weighted-mean mask tree an aggregator tree already reduced from
    pooled popcount records (`payloads.mean_from_counts`), ``floats``
    the pooled float sidecar and ``k`` the number of folded clients.
    It must implement the SAME state transition as `aggregate` given
    ``q = batched_packed_mean(payloads, wn)`` — the tree engine's
    zero-fault bit-identity gate holds the two to each other.
    Algorithms whose payload has no packed words (e.g. fedavg) leave it
    None and cannot ride the tree.
    """

    def __init__(self, name: str, *, init: Callable,
                 client_update: Callable, aggregate: Callable,
                 eval_params: Callable, payload_spec: PayloadSpec,
                 codec=None, downlink: Optional[Callable] = None,
                 pooled_aggregate: Optional[Callable] = None):
        self.name = name
        # The state must own its buffers: `round` donates them, and an
        # init that aliases the caller's params template (float leaves
        # commonly do) would otherwise delete the caller's arrays.
        self.init = lambda key, params_like: jax.tree_util.tree_map(
            lambda x: None if x is None else jnp.array(x),
            init(key, params_like), is_leaf=lambda x: x is None)
        self.client_update = client_update
        self.aggregate = aggregate
        self.eval_params = eval_params
        self.payload_spec = payload_spec
        self.codec = codecs_lib.resolve(codec, payload_spec)
        self.downlink = downlink
        self.pooled_aggregate = pooled_aggregate
        self._round = jax.jit(
            lambda state, data, part, sizes, key: run_round(
                self, state, data, part, sizes, key),
            donate_argnums=0)

    def round(self, state, data, participation, sizes, key):
        with warnings.catch_warnings():
            # CPU backends don't implement donation; the per-lowering
            # warning is expected there and only for THIS call site
            warnings.filterwarnings(
                "ignore",
                message="Some donated buffers were not usable")
            return self._round(state, data, participation, sizes, key)

    def __repr__(self):
        return (f"FedAlgorithm({self.name!r}, "
                f"payload={self.payload_spec.cls.__name__}, "
                f"codec={self.codec.name!r})")


def evaluate(algo: FedAlgorithm, state, batch, apply_fn: Callable,
             metric_fn: Callable, key, n_samples: int = 1):
    """Mean metric over `n_samples` sampled effective networks."""
    total = 0.0
    for i in range(n_samples):
        eff = algo.eval_params(state, jax.random.fold_in(key, i))
        total = total + metric_fn(apply_fn(eff, batch), batch)
    return total / n_samples
