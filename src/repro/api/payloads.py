"""Typed uplink payloads — the ONE place communication cost is accounted.

Every federated algorithm's client sends exactly one `UplinkPayload` per
round.  The payload type fixes both the wire format and the reported
``uplink_bpp`` (bits per parameter), so per-algorithm metric code cannot
drift from what is actually serialized:

  * ``BitpackedMasks`` — binary masks packed 32->1 into uint32 words
    (the paper's artifact).  Reported Bpp is the empirical entropy of
    the transmitted bits (eq. 13): what an ideal entropy coder achieves
    on this exact payload, always <= 1.
  * ``SignVotes``      — bitpacked sign bits (MV-SignSGD): exactly
    1 bit per parameter.
  * ``FloatDeltas``    — raw float tensors (FedAvg & friends): the
    dtype width, 32 Bpp for float32.

Payloads are registered pytrees, so they flow through ``jax.jit`` /
``jax.vmap`` unchanged; static shape metadata rides in the treedef.  The
round engine (`repro.api.protocol.run_round`) vmaps `client_update` over
clients and derives the round's ``uplink_bpp`` from the batched payload
— algorithms never report their own communication cost.  The actual
wire format (and the measured Bpp next to the entropy bound) is the
codec's job: see `repro.api.codecs`.

The server's broadcast is typed too (`DownlinkPayload`): `ProbBroadcast`
is the stochastic k-bit theta quantization on the real downlink wire,
`FloatBroadcast` the raw float reference.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.api import codecs as codecs_lib
from repro.core import aggregation, masking, regularizer

Pytree = Any

_NONE = lambda x: x is None


def _leaf_shapes(tree: Pytree) -> tuple:
    """Static (hashable) shapes of the non-None leaves, flatten order."""
    return tuple(tuple(l.shape) for l in jax.tree_util.tree_leaves(
        tree, is_leaf=_NONE) if l is not None)


def _float_bits(tree: Pytree) -> tuple:
    return tuple(l.dtype.itemsize * 8 for l in jax.tree_util.tree_leaves(
        tree, is_leaf=_NONE) if l is not None)


def pack_leaf(m: jax.Array) -> jax.Array:
    """Bitpack one {0,1} leaf into a flat uint32 word vector."""
    flat, _ = aggregation.pad_to_words(m.reshape(-1))
    return aggregation.pack_bits(flat)


def mean_from_words(words: jax.Array, n: int,
                    weights: Optional[jax.Array] = None) -> jax.Array:
    """Weighted mean of K bitpacked clients: (K, W) uint32 -> (n,) f32.

    This is THE aggregation kernel for binary uplinks (eq. 8): both the
    host-sim engine and the pod-scale round step (after its all_gather
    of the packed words) reduce through here, so the two execution paths
    cannot drift.  ``weights`` defaults to the uniform mean.
    """
    bits = jax.vmap(lambda w: aggregation.unpack_bits(w, n))(words)
    bits = bits.astype(jnp.float32)
    if weights is None:
        return jnp.mean(bits, axis=0)
    return jnp.tensordot(weights, bits, axes=(0, 0))


def mean_from_counts(counts: jax.Array, n: int,
                     weights: jax.Array) -> jax.Array:
    """Weighted mean from pooled per-bit counts: (C, P) integer counts
    + (C,) per-client class weights -> (n,) f32.

    ``counts[c][p]`` is how many clients of weight class c set bit p
    (P covers the padded word domain; positions past n are dropped).
    With every client in class c carrying normalized weight
    ``weights[c]``, eq. 8's weighted mean collapses to
    ``sum_c weights[c] * counts[c]`` — the O(params)-per-class twin of
    `mean_from_words` the aggregator tree's root reduces through.
    Because pooled counts are exact integers, a dyadic weight vector
    (equal sizes, power-of-two cohort) makes this bit-identical to the
    flat `mean_from_words` path under ANY client-to-edge grouping.
    """
    c = jnp.asarray(counts).astype(jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    return jnp.tensordot(w, c, axes=(0, 0))[:n]


def _popcount_sum(words: jax.Array) -> jax.Array:
    return jnp.sum(jax.lax.population_count(words).astype(jnp.float32))


class UplinkPayload:
    """Interface every payload implements (one client's uplink).

    ``num_params`` / ``wire_bits`` are static Python ints; ``bpp`` is a
    traced scalar (it may depend on the transmitted values).  Methods
    assume an UNBATCHED (single-client) payload; the round engine vmaps
    them over the client axis.
    """

    def num_params(self) -> int:
        raise NotImplementedError

    def wire_bits(self) -> int:
        """Exact serialized size in bits (word-aligned where packed)."""
        raise NotImplementedError

    def bpp(self) -> jax.Array:
        """Reported uplink bits/parameter (entropy-coded where binary)."""
        raise NotImplementedError


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitpackedMasks(UplinkPayload):
    """Binary masks, 32 bits -> one uint32 word per leaf.

    words:  pytree mirroring the mask tree; uint32[W] leaves for masked
            params, None where the model keeps float leaves.
    floats: optional float sidecar (norms/biases FedAvg'd alongside the
            masks; not counted in the paper's mask Bpp metric).
    shapes: static original leaf shapes (flatten order) for unpacking.
    """
    words: Pytree
    floats: Pytree
    shapes: tuple

    def tree_flatten(self):
        return (self.words, self.floats), self.shapes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @classmethod
    def from_masks(cls, masks: Pytree, floats: Pytree = None
                   ) -> "BitpackedMasks":
        words = jax.tree_util.tree_map(
            lambda m: None if m is None else pack_leaf(m),
            masks, is_leaf=_NONE)
        return cls(words, floats, _leaf_shapes(masks))

    def to_masks(self) -> Pytree:
        it = iter(self.shapes)
        return jax.tree_util.tree_map(
            lambda w: None if w is None else aggregation.unpack_bits(
                w, _prod(sh := next(it))).reshape(sh),
            self.words, is_leaf=_NONE)

    def num_params(self) -> int:
        return sum(_prod(sh) for sh in self.shapes)

    def wire_bits(self) -> int:
        return sum(32 * ((_prod(sh) + 31) // 32) for sh in self.shapes)

    def bpp(self) -> jax.Array:
        """Empirical entropy of the transmitted bits (eq. 13).

        Padding bits are zeros and never reach ``ones``; ``n`` counts
        real parameters only, so this matches the unpacked-mask entropy
        exactly.
        """
        ones = jnp.float32(0.0)
        for w in jax.tree_util.tree_leaves(self.words, is_leaf=_NONE):
            if w is not None:
                ones = ones + _popcount_sum(w)
        n = self.num_params()
        if n == 0:
            return jnp.float32(0.0)
        return regularizer.binary_entropy(ones / jnp.float32(n))

    def as_path_dict(self) -> dict:
        """{path: (uint32 words, original shape)} — the artifact layout
        `repro.ckpt.save_artifact` persists."""
        out, it = {}, iter(self.shapes)
        for path, w in masking.leaves_with_paths(self.words):
            if w is None:
                continue
            out[path] = (w, next(it))
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SignVotes(UplinkPayload):
    """Bitpacked gradient signs (MV-SignSGD): exactly 1 bit/param.

    The wire has no zero symbol: a sign of exactly 0 serializes as -1.
    Senders with meaningful zero gradients must tie-break before
    packing (the registered `mv_signsgd` flips an unbiased coin) or
    the missing symbol becomes a systematic negative vote.
    """
    words: Pytree
    shapes: tuple

    def tree_flatten(self):
        return (self.words,), self.shapes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    @classmethod
    def from_signs(cls, signs: Pytree) -> "SignVotes":
        words = jax.tree_util.tree_map(
            lambda s: None if s is None else pack_leaf(
                (s > 0).astype(jnp.uint8)),
            signs, is_leaf=_NONE)
        return cls(words, _leaf_shapes(signs))

    def to_signs(self) -> Pytree:
        it = iter(self.shapes)
        return jax.tree_util.tree_map(
            lambda w: None if w is None else
            (2.0 * aggregation.unpack_bits(
                w, _prod(sh := next(it))).astype(jnp.float32)
             - 1.0).reshape(sh),
            self.words, is_leaf=_NONE)

    def num_params(self) -> int:
        return sum(_prod(sh) for sh in self.shapes)

    def wire_bits(self) -> int:
        return sum(32 * ((_prod(sh) + 31) // 32) for sh in self.shapes)

    def bpp(self) -> jax.Array:
        return jnp.float32(0.0) if self.num_params() == 0 \
            else jnp.float32(1.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FloatDeltas(UplinkPayload):
    """Raw float tensors (deltas or full params): the dtype width on the
    wire — 32 Bpp for float32, the reference the paper compresses."""
    values: Pytree
    shapes: tuple
    bits: tuple   # static per-leaf dtype widths, flatten order

    def tree_flatten(self):
        return (self.values,), (self.shapes, self.bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1])

    @classmethod
    def from_tree(cls, values: Pytree) -> "FloatDeltas":
        return cls(values, _leaf_shapes(values), _float_bits(values))

    def num_params(self) -> int:
        return sum(_prod(sh) for sh in self.shapes)

    def wire_bits(self) -> int:
        return sum(_prod(sh) * b for sh, b in zip(self.shapes, self.bits))

    def bpp(self) -> jax.Array:
        n = self.num_params()
        if n == 0:
            return jnp.float32(0.0)
        return jnp.float32(self.wire_bits() / n)


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------
# Downlink payloads — what the SERVER broadcasts each round.
#
# The paper meters the uplink only; SpaFL-style two-way budgets need the
# broadcast counted too.  `run_round` asks the algorithm for one
# `DownlinkPayload` per round, reports `downlink_bpp`, and feeds the
# total (wire x participating clients) into the CommLedger.
# ---------------------------------------------------------------------------


class DownlinkPayload:
    """Interface for one round's server broadcast."""

    def num_params(self) -> int:
        raise NotImplementedError

    def wire_bits(self) -> int:
        """Exact serialized size in bits (word-aligned where packed)."""
        raise NotImplementedError

    def sidecar_bits(self) -> int:
        """Float side-channel bits riding along (norms/biases)."""
        return 0

    def bpp(self) -> jax.Array:
        n = self.num_params()
        if n == 0:
            return jnp.float32(0.0)
        return jnp.float32(self.wire_bits() / n)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ProbBroadcast(DownlinkPayload):
    """Stochastic k-bit quantization of the server's probability mask —
    `aggregation.quantize_theta` put on the actual downlink wire.

    q:      uint8/uint16 leaves in [0, 2^bits - 1] (None for float
            leaves), an unbiased estimator of theta.
    floats: the FedAvg'd float leaves broadcast alongside (sidecar).
    bits:   static quantization width.
    """
    q: Pytree
    floats: Pytree
    bits: int

    def tree_flatten(self):
        return (self.q, self.floats), self.bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @classmethod
    def from_theta(cls, theta: Pytree, key, bits: int = 8,
                   floats: Pytree = None) -> "ProbBroadcast":
        return cls(aggregation.quantize_theta(theta, key, bits=bits),
                   floats, bits)

    def to_theta(self) -> Pytree:
        """What the clients actually receive (dequantized)."""
        return aggregation.dequantize_theta(self.q, bits=self.bits)

    def num_params(self) -> int:
        return sum(l.size for l in jax.tree_util.tree_leaves(
            self.q, is_leaf=_NONE) if l is not None)

    def wire_bits(self) -> int:
        tot = 0
        for l in jax.tree_util.tree_leaves(self.q, is_leaf=_NONE):
            if l is None:
                continue
            tot += codecs_lib.word_align(l.size * self.bits)
        return tot

    def sidecar_bits(self) -> int:
        return codecs_lib.float_tree_bits(self.floats)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FloatBroadcast(DownlinkPayload):
    """Raw float broadcast (server params / scores): the dtype width on
    the wire — the 32-Bpp downlink reference."""
    values: Pytree
    shapes: tuple
    bits: tuple

    def tree_flatten(self):
        return (self.values,), (self.shapes, self.bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1])

    @classmethod
    def from_tree(cls, values: Pytree) -> "FloatBroadcast":
        return cls(values, _leaf_shapes(values), _float_bits(values))

    def num_params(self) -> int:
        return sum(_prod(sh) for sh in self.shapes)

    def wire_bits(self) -> int:
        return sum(_prod(sh) * b for sh, b in zip(self.shapes, self.bits))


def batched_packed_mean(payload, weights: jax.Array) -> Pytree:
    """Weighted mean of K clients' bits, straight from the packed words
    (eq. 8).  Works for any packed payload exposing `words`/`shapes`
    (`BitpackedMasks` -> theta, `SignVotes` -> vote fraction).
    `payload` is engine-batched: every words leaf carries a leading K
    axis."""
    it = iter(payload.shapes)
    return jax.tree_util.tree_map(
        lambda w: None if w is None else mean_from_words(
            w, _prod(sh := next(it)), weights).reshape(sh),
        payload.words, is_leaf=_NONE)


def batched_float_mean(tree: Pytree, weights: jax.Array) -> Pytree:
    """Weighted mean over the leading K axis, dtype-preserving."""
    return jax.tree_util.tree_map(
        lambda f: None if f is None else jnp.tensordot(
            weights, f.astype(jnp.float32), axes=(0, 0)).astype(f.dtype),
        tree, is_leaf=_NONE)


def stack_payloads(payloads):
    """Stack unbatched same-structure payloads into one engine-batched
    payload (every leaf gains a leading B axis) — the inverse of slicing
    a vmapped `client_update` output per client.

    This is how the buffered-async engine's commit turns its arrival
    buffer (decoded `WireMessage`s accumulated across the quorum window)
    back into the batched form `FedAlgorithm.aggregate` consumes, so
    buffered commits reduce through the SAME `batched_packed_mean` /
    `mean_from_words` code path as the synchronous barrier round.
    """
    if not payloads:
        raise ValueError("stack_payloads needs at least one payload")
    return jax.tree_util.tree_map(
        lambda *ls: jnp.stack([jnp.asarray(l) for l in ls]), *payloads)


def slice_payload(payload, i: int):
    """Client i's unbatched payload out of an engine-batched one."""
    return jax.tree_util.tree_map(lambda l: l[i], payload)
