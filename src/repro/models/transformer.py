"""Decoder-only transformer family: dense (gemma3/internlm2/deepseek-7b/
qwen2), MoE with MLA (deepseek-v2-*), and VLM backbone (qwen2-vl).

Layers are *stacked* (leading L axis) and applied with jax.lax.scan so the
HLO stays one-block-sized — essential for 60-layer dry-run compiles.
Per-layer heterogeneity (gemma3 5:1 local:global attention, per-layer
RoPE theta) rides the scan as per-layer scalar arrays.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Pytree = Any
NEG_BIG = 1 << 30


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ArchConfig, moe: bool):
    ks = jax.random.split(key, 4)
    p = {"attn_norm": L.rms_norm_init(cfg.d_model),
         "ffn_norm": L.rms_norm_init(cfg.d_model)}
    if cfg.kv_lora_rank:
        p["attn"] = L.mla_init(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.kv_lora_rank, cfg.q_lora_rank,
                               cfg.qk_nope_dim, cfg.qk_rope_dim,
                               cfg.v_head_dim)
    else:
        p["attn"] = L.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.hd, cfg.qkv_bias)
    if moe:
        p["moe"] = L.moe_init(ks[1], cfg.d_model, cfg.moe_d_ff,
                              cfg.n_experts, cfg.n_shared_experts)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(key: jax.Array, cfg: ArchConfig) -> Pytree:
    ks = jax.random.split(key, 5)
    n_moe = cfg.n_layers - cfg.first_dense_layers if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe

    params = {
        "embed": {"table": L.embed_init(ks[0], (cfg.vocab, cfg.d_model))},
        "final_norm": L.rms_norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"table": L.embed_init(
            ks[1], (cfg.vocab, cfg.d_model))}

    if n_dense:
        dk = jax.random.split(ks[2], n_dense)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe=False))(dk)
    if n_moe:
        mk = jax.random.split(ks[3], n_moe)
        params["moe_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe=True))(mk)
    return params


# ---------------------------------------------------------------------------
# Per-layer attention pattern (gemma3 local:global etc.)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ArchConfig, n: int):
    """(window[i], theta[i]) arrays for layers 0..n-1."""
    wins, thetas = [], []
    for i in range(n):
        is_global = (cfg.global_every == 0
                     or (i + 1) % (cfg.global_every + 1) == 0)
        if cfg.sliding_window and not is_global:
            wins.append(cfg.sliding_window)
            thetas.append(cfg.rope_theta)
        else:
            wins.append(NEG_BIG)  # effectively full attention
            thetas.append(cfg.rope_theta_global or cfg.rope_theta)
    return (jnp.asarray(wins, jnp.int32), jnp.asarray(thetas, jnp.float32))


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _block(cfg: ArchConfig, moe: bool, x, lp, positions, window, theta,
           chunk_kv, mrope_positions):
    h = L.rms_norm(lp["attn_norm"], x)
    if cfg.kv_lora_rank:
        attn_out, kv = L.mla_apply(
            lp["attn"], h, positions, cfg.n_heads, cfg.kv_lora_rank,
            cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
            rope_theta=cfg.rope_theta, chunk_kv=chunk_kv)
    else:
        attn_out, kv = L.gqa_apply(
            lp["attn"], h, positions, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            window=window, causal=True, rope_theta=theta,
            chunk_kv=chunk_kv, mrope_positions=mrope_positions,
            mrope_sections=cfg.mrope_sections if mrope_positions is not None
            else None)
    x = x + attn_out
    h = L.rms_norm(lp["ffn_norm"], x)
    if moe:
        ffn_out, aux = L.moe_apply(lp["moe"], h, cfg.n_experts, cfg.top_k,
                                   cfg.capacity_factor,
                                   block_dispatch=cfg.moe_block_dispatch)
    else:
        ffn_out, aux = L.mlp_apply(lp["mlp"], h, cfg.act), 0.0
    return x + ffn_out, kv, aux


def forward(params: Pytree, cfg: ArchConfig, tokens: jax.Array,
            vis_embeds: Optional[jax.Array] = None,
            chunk_kv: Optional[int] = None,
            collect_cache: bool = False):
    """tokens: (B, S_text). vis_embeds: (B, S_vis, D) stub patch embeds
    (VLM); they are prepended, total S = S_vis + S_text.

    Returns (logits, aux_loss) or (logits, aux_loss, cache).
    """
    x = L.embed_lookup(params["embed"]["table"], tokens)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    mrope_positions = None
    if vis_embeds is not None:
        x = jnp.concatenate([vis_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        S_vis = vis_embeds.shape[1]
        side = max(int(S_vis ** 0.5), 1)
        # vision: t=0, (h, w) grid; text: t advances from side
        t = jnp.concatenate([jnp.zeros((S_vis,), jnp.int32),
                             side + jnp.arange(S - S_vis)])
        hpos = jnp.concatenate([jnp.arange(S_vis) // side,
                                side + jnp.arange(S - S_vis)])
        wpos = jnp.concatenate([jnp.arange(S_vis) % side,
                                side + jnp.arange(S - S_vis)])
        mrope_positions = jnp.broadcast_to(
            jnp.stack([t, hpos, wpos])[:, None, :],
            (3, B, S)).astype(jnp.int32)
        # positions for masking still linear
    B, S, _ = x.shape
    positions = jnp.arange(S)

    aux_total = jnp.float32(0.0)
    caches = {}

    def run_stack(x, stacked, n, moe, aux_total):
        wins, thetas = layer_windows(cfg, cfg.n_layers)
        off = 0 if not moe else cfg.first_dense_layers
        wins = jax.lax.dynamic_slice_in_dim(wins, off, n)
        thetas = jax.lax.dynamic_slice_in_dim(thetas, off, n)

        def body(carry, xs):
            x, aux = carry
            lp, w, th = xs
            blk = _block
            if cfg.remat:
                blk = jax.checkpoint(
                    _block, static_argnums=(0, 1, 7),
                    policy=jax.checkpoint_policies.nothing_saveable)
            x, kv, a = blk(cfg, moe, x, lp, positions, w, th,
                           chunk_kv, mrope_positions)
            ys = kv if collect_cache else None
            return (x, aux + a), ys

        (x, aux_total), kvs = jax.lax.scan(
            body, (x, aux_total), (stacked, wins, thetas),
            unroll=cfg.scan_unroll)
        return x, aux_total, kvs

    if "layers" in params:
        n_dense = jax.tree_util.tree_leaves(
            params["layers"])[0].shape[0]
        x, aux_total, kvs = run_stack(x, params["layers"], n_dense,
                                      False, aux_total)
        if collect_cache:
            caches["dense"] = kvs
    if "moe_layers" in params:
        n_moe = jax.tree_util.tree_leaves(
            params["moe_layers"])[0].shape[0]
        x, aux_total, kvs = run_stack(x, params["moe_layers"], n_moe,
                                      True, aux_total)
        if collect_cache:
            caches["moe"] = kvs

    x = L.rms_norm(params["final_norm"], x)
    head = params.get("lm_head", params["embed"])["table"]
    logits = L.unembed(head, x)
    if cfg.logit_sharding:
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.PartitionSpec(*cfg.logit_sharding))
    if collect_cache:
        return logits, aux_total, caches
    return logits, aux_total


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(outputs, batch):
    """Next-token CE. outputs = (logits, aux); batch['tokens'] (B, S)."""
    logits, aux = outputs[0], outputs[1]
    tokens = batch["tokens"]
    S_txt = tokens.shape[1]
    logits = logits[:, -S_txt:]  # VLM: score only the text tail
    lg = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    # logsumexp form: only (B, S) temporaries besides the logits
    lse = jax.nn.logsumexp(lg, axis=-1)
    at = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = lse - at
    return jnp.mean(nll) + 0.01 * aux


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------


def _local_global_split(cfg: ArchConfig):
    """gemma3-style pattern: 1 global per (global_every + 1) layers.
    Returns (plen, n_groups, n_tail): groups of plen = global_every
    local + 1 global; tail layers are all local."""
    plen = cfg.global_every + 1
    n_groups = cfg.n_layers // plen
    return plen, n_groups, cfg.n_layers - n_groups * plen


def init_cache_windowed(cfg: ArchConfig, batch: int, max_seq: int,
                        dtype=jnp.bfloat16) -> Pytree:
    """Ring-buffer caches (size = sliding_window) for local layers; full
    caches only for the global layers. For gemma3-4b @ 500k this cuts
    cache bytes ~5.6x (28 local layers hold 1024 keys instead of 524288)
    — docs/DESIGN.md §7."""
    W = min(cfg.sliding_window, max_seq)
    plen, n_groups, n_tail = _local_global_split(cfg)
    n_loc = plen - 1
    kv = lambda *shape: jnp.zeros(shape, dtype)
    cache = {
        "loc_k": kv(n_groups, n_loc, batch, W, cfg.n_kv_heads, cfg.hd),
        "loc_v": kv(n_groups, n_loc, batch, W, cfg.n_kv_heads, cfg.hd),
        "loc_pos": jnp.full((n_groups, n_loc, W), -NEG_BIG, jnp.int32),
        "glob_k": kv(n_groups, batch, max_seq, cfg.n_kv_heads, cfg.hd),
        "glob_v": kv(n_groups, batch, max_seq, cfg.n_kv_heads, cfg.hd),
    }
    if n_tail:
        cache["tail_k"] = kv(n_tail, batch, W, cfg.n_kv_heads, cfg.hd)
        cache["tail_v"] = kv(n_tail, batch, W, cfg.n_kv_heads, cfg.hd)
        cache["tail_pos"] = jnp.full((n_tail, W), -NEG_BIG, jnp.int32)
    return cache


def decode_step_windowed(params: Pytree, cfg: ArchConfig, cache: Pytree,
                         token: jax.Array, pos: jax.Array):
    """One-token decode with ring-buffer local caches (gemma3 pattern).
    Layers are re-grouped (global_every local + 1 global) x n_groups +
    a local tail; parameters are reshaped views of the (L, ...) stacks."""
    B = token.shape[0]
    W = cache["loc_k"].shape[3]
    plen, n_groups, n_tail = _local_global_split(cfg)
    n_loc = plen - 1
    x = L.embed_lookup(params["embed"]["table"], token[:, None])
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = pos[None]
    theta_l = cfg.rope_theta
    theta_g = cfg.rope_theta_global or cfg.rope_theta

    stacked = params["layers"]
    grouped = jax.tree_util.tree_map(
        lambda a: a[: n_groups * plen].reshape(
            (n_groups, plen) + a.shape[1:]), stacked)
    tail = jax.tree_util.tree_map(lambda a: a[n_groups * plen:], stacked)

    def attn_ring(lp, h, kc, vc, kpos, theta):
        slot = pos % W
        k_new = L.masked_dense_apply(h, lp["attn"]["w_k"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        v_new = L.masked_dense_apply(h, lp["attn"]["w_v"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        k_new = L.apply_rope(k_new, positions, theta)
        kc = jax.lax.dynamic_update_slice(
            kc, k_new.astype(kc.dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v_new.astype(vc.dtype), (0, slot, 0, 0))
        kpos = jax.lax.dynamic_update_slice(kpos, pos[None], (slot,))
        out, _ = L.gqa_apply(lp["attn"], h, positions, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd,
                             window=cfg.sliding_window, causal=True,
                             rope_theta=theta, kv_override=(kc, vc),
                             k_positions=kpos)
        return out, kc, vc, kpos

    def attn_full(lp, h, kc, vc, theta):
        k_new = L.masked_dense_apply(h, lp["attn"]["w_k"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        v_new = L.masked_dense_apply(h, lp["attn"]["w_v"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        k_new = L.apply_rope(k_new, positions, theta)
        kc = jax.lax.dynamic_update_slice(
            kc, k_new.astype(kc.dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v_new.astype(vc.dtype), (0, pos, 0, 0))
        out, _ = L.gqa_apply(lp["attn"], h, positions, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd, window=None,
                             causal=True, rope_theta=theta,
                             kv_override=(kc, vc),
                             k_positions=jnp.arange(kc.shape[1]))
        return out, kc, vc

    def ffn(lp, x):
        h = L.rms_norm(lp["ffn_norm"], x)
        return x + L.mlp_apply(lp["mlp"], h, cfg.act)

    def group_body(x, xs):
        gp, lk, lv, lpos, gk, gv = xs
        nlk, nlv, nlpos = [], [], []
        for i in range(plen):
            lp = jax.tree_util.tree_map(lambda a: a[i], gp)
            h = L.rms_norm(lp["attn_norm"], x)
            if i < n_loc:
                out, k2, v2, p2 = attn_ring(lp, h, lk[i], lv[i],
                                            lpos[i], theta_l)
                nlk.append(k2)
                nlv.append(v2)
                nlpos.append(p2)
            else:
                out, gk, gv = attn_full(lp, h, gk, gv, theta_g)
            x = ffn(lp, x + out)
        return x, (jnp.stack(nlk), jnp.stack(nlv), jnp.stack(nlpos),
                   gk, gv)

    x, (lks, lvs, lposs, gks, gvs) = jax.lax.scan(
        group_body, x, (grouped, cache["loc_k"], cache["loc_v"],
                        cache["loc_pos"], cache["glob_k"],
                        cache["glob_v"]))
    new_cache = dict(cache, loc_k=lks, loc_v=lvs, loc_pos=lposs,
                     glob_k=gks, glob_v=gvs)

    if n_tail:
        def tail_body(x, xs):
            lp, kc, vc, kpos = xs
            h = L.rms_norm(lp["attn_norm"], x)
            out, k2, v2, p2 = attn_ring(lp, h, kc, vc, kpos, theta_l)
            x = ffn(lp, x + out)
            return x, (k2, v2, p2)

        x, (tk, tv, tp) = jax.lax.scan(
            tail_body, x, (tail, cache["tail_k"], cache["tail_v"],
                           cache["tail_pos"]))
        new_cache.update(tail_k=tk, tail_v=tv, tail_pos=tp)

    x = L.rms_norm(params["final_norm"], x)
    head = params.get("lm_head", params["embed"])["table"]
    logits = L.unembed(head, x)[:, 0]
    return logits, new_cache


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Pytree:
    n_moe = cfg.n_layers - cfg.first_dense_layers if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    if cfg.kv_lora_rank:
        mk = lambda n: {
            "c_kv": jnp.zeros((n, batch, max_seq, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((n, batch, max_seq, 1, cfg.qk_rope_dim),
                                dtype)}
    else:
        mk = lambda n: {
            "k": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dtype),
            "v": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dtype)}
    out = {}
    if n_dense:
        out["dense"] = mk(n_dense)
    if n_moe:
        out["moe"] = mk(n_moe)
    return out


def decode_step(params: Pytree, cfg: ArchConfig, cache: Pytree,
                token: jax.Array, pos: jax.Array):
    """One-token decode. token: (B,) int32; pos: scalar int32 (current
    position; cache holds keys for positions < pos... <= pos after write).

    Returns (logits (B, V), new_cache).
    """
    B = token.shape[0]
    x = L.embed_lookup(params["embed"]["table"], token[:, None])
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = pos[None]  # (1,)

    wins, thetas = layer_windows(cfg, cfg.n_layers)
    new_cache = {}

    def attn_gqa(lp, h, lc, w, th):
        # project new kv, write into cache at pos, attend over cache
        k_new = L.masked_dense_apply(h, lp["attn"]["w_k"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        v_new = L.masked_dense_apply(h, lp["attn"]["w_v"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        if "bias_k" in lp["attn"]:
            k_new = k_new + lp["attn"]["bias_k"].reshape(
                cfg.n_kv_heads, cfg.hd).astype(k_new.dtype)
            v_new = v_new + lp["attn"]["bias_v"].reshape(
                cfg.n_kv_heads, cfg.hd).astype(v_new.dtype)
        k_new = L.apply_rope(k_new, positions, th)
        kc = jax.lax.dynamic_update_slice(
            lc["k"], k_new.astype(lc["k"].dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            lc["v"], v_new.astype(lc["v"].dtype), (0, pos, 0, 0))
        S_max = kc.shape[1]
        k_pos = jnp.arange(S_max)
        out, _ = L.gqa_apply(
            lp["attn"], h, positions, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            window=w, causal=True, rope_theta=th,
            kv_override=(kc, vc), k_positions=k_pos)
        return out, {"k": kc, "v": vc}

    def attn_mla(lp, h, lc):
        dkv = L.masked_dense_apply(h, lp["attn"]["w_dkv"])
        c_kv_new = L.rms_norm({"scale": lp["attn"]["kv_norm_scale"]},
                              dkv[..., :cfg.kv_lora_rank])
        k_rope_new = L.apply_rope(
            dkv[..., cfg.kv_lora_rank:][:, :, None, :], positions,
            cfg.rope_theta)
        ckv = jax.lax.dynamic_update_slice(
            lc["c_kv"], c_kv_new.astype(lc["c_kv"].dtype), (0, pos, 0))
        krp = jax.lax.dynamic_update_slice(
            lc["k_rope"], k_rope_new.astype(lc["k_rope"].dtype),
            (0, pos, 0, 0))
        out, _ = L.mla_apply(
            lp["attn"], h, positions, cfg.n_heads, cfg.kv_lora_rank,
            cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
            rope_theta=cfg.rope_theta, cache_kv=(ckv, krp))
        return out, {"c_kv": ckv, "k_rope": krp}

    def run_stack(x, stacked, cache_part, moe, offset):
        n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        w = jax.lax.dynamic_slice_in_dim(wins, offset, n)
        th = jax.lax.dynamic_slice_in_dim(thetas, offset, n)

        def body(x, xs):
            lp, lc, wi, thi = xs
            h = L.rms_norm(lp["attn_norm"], x)
            if cfg.kv_lora_rank:
                attn_out, nc = attn_mla(lp, h, lc)
            else:
                attn_out, nc = attn_gqa(lp, h, lc, wi, thi)
            x = x + attn_out
            h = L.rms_norm(lp["ffn_norm"], x)
            if moe:
                ffn_out, _ = L.moe_apply(lp["moe"], h, cfg.n_experts,
                                         cfg.top_k, cfg.capacity_factor)
            else:
                ffn_out = L.mlp_apply(lp["mlp"], h, cfg.act)
            return x + ffn_out, nc

        return jax.lax.scan(body, x, (stacked, cache_part, w, th),
                            unroll=cfg.scan_unroll)

    off = 0
    if "layers" in params:
        x, nc = run_stack(x, params["layers"], cache["dense"], False, 0)
        new_cache["dense"] = nc
        off = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
    if "moe_layers" in params:
        x, nc = run_stack(x, params["moe_layers"], cache["moe"], True, off)
        new_cache["moe"] = nc

    x = L.rms_norm(params["final_norm"], x)
    head = params.get("lm_head", params["embed"])["table"]
    logits = L.unembed(head, x)[:, 0]
    return logits, new_cache
