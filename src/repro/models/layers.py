"""Shared neural-net layers for the model zoo (pure JAX, pytree params).

Conventions:
  * activations x: (B, S, D); params are nested dicts of jnp arrays.
  * maskable tensors get names WITHOUT the MaskSpec float patterns
    ("w_*"); norms/biases/routers carry "scale"/"bias"/"router" so the
    paper's technique skips them (docs/DESIGN.md §Arch-applicability).
  * every layer has init(key, cfg...) -> params and apply(params, x, ...).
  * every maskable projection is consumed through a per-leaf dispatch:
    `masked_dense_apply` (2-D dense weights), `masked_grouped_apply`
    (stacked (E, K, N) MoE expert weights), `masked_conv1d_apply`
    (depthwise (W, C) conv kernels) or `masked_conv2d_apply` (CNN
    (kh, kw, ci, co) kernels).  A leaf may be a plain array (float
    training, or effective params materialized by
    `masking.sample_effective` / `masking.hash_effective`) OR a
    `masking.MaskedLeaf` (w, s, seed) bundle, in which case the fused
    Pallas kernels run — no mask or masked-weight tensor ever exists
    in HBM for ANY maskable leaf shape (docs/DESIGN.md §3).
    `effective_weight` (the materializing fallback) survives only on
    the per-token decode path (`conv1d_step`), where
    `masking.freeze_for_decode` materializes once per session anyway.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import masking
from repro.core.masking import MaskedLeaf
from repro.kernels import ops

Pytree = Any

DEFAULT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Masked execution dispatch: plain array | MaskedLeaf (w, s, seed)
# ---------------------------------------------------------------------------


def masked_dense_apply(x: jax.Array, p) -> jax.Array:
    """y = x @ w_eff for a plain weight array or a `MaskedLeaf`.

    Plain array: the ordinary matmul (float baselines, materialized
    effective params).  MaskedLeaf: the fused masked-dense kernel —
    the Bernoulli (or FedMask-threshold) mask is regenerated per tile
    from the leaf's hash-stream coordinates on BOTH passes, with scores
    a first-class grad argument through the STE custom-vjp.
    """
    if isinstance(p, MaskedLeaf):
        if p.mode == "threshold":
            return ops.masked_dense_threshold(x, p.w, p.s, p.tau)
        return ops.masked_dense(x, p.w, p.s, p.seed, p.off)
    return x @ p


def masked_grouped_apply(x: jax.Array, p) -> jax.Array:
    """y[e] = x[e] @ w_eff[e] for a stacked (E, K, N) weight (MoE
    expert einsums; x: (E, ..., K)).

    Plain array: the batched einsum (float baselines, materialized
    effective params).  MaskedLeaf: ONE grouped Pallas launch for all
    E groups — per-group `seed`/`off` stream coordinates make each
    expert's mask exactly its slice of the leaf's flat uplink stream,
    and the stacked m⊙w never exists in HBM on either pass."""
    if isinstance(p, MaskedLeaf):
        if p.mode == "threshold":
            return ops.masked_dense_grouped_threshold(x, p.w, p.s, p.tau)
        return ops.masked_dense_grouped(x, p.w, p.s, p.seed, p.off)
    shape = x.shape
    y = jnp.einsum("ecd,edf->ecf", x.reshape(shape[0], -1, shape[-1]),
                   p)
    return y.reshape(shape[:-1] + (p.shape[-1],))


def masked_conv1d_apply(x: jax.Array, p) -> jax.Array:
    """Depthwise causal conv y[b,s,c] = Σ_t x[b,s+t-(W-1),c]·w_eff[t,c]
    for a (W, C) kernel leaf, f32 output (bias/cast stay with the
    caller).  Both branches run the SAME Pallas tap loop
    (`ops.masked_conv1d` / `ops.conv1d_plain`), so fused and
    materialized-reference convs are bit-identical — and neither
    builds the old (B, S, W, C) stacked-views tensor."""
    if isinstance(p, MaskedLeaf):
        if p.mode == "threshold":
            return ops.masked_conv1d_threshold(x, p.w, p.s, p.tau)
        return ops.masked_conv1d(x, p.w, p.s, p.seed, p.off)
    return ops.conv1d_plain(x, p)


def masked_conv2d_apply(x: jax.Array, p) -> jax.Array:
    """2-D SAME conv for a (kh, kw, ci, co) kernel leaf (the paper's
    Conv4/6/10 CNNs).  x: (B, H, W, ci) -> (B, H, W, co).

    Plain array: `lax.conv_general_dilated`.  MaskedLeaf: im2col ONCE
    to (B·H·W, kh·kw·ci) and run ONE fused `ops.masked_dense` launch —
    the (kh·kw·ci, co) row-major reshape of the leaf is contiguous
    with its flat hash stream (idx = row·co + col == the leaf's flat
    index), so the single launch at the leaf's base offset samples the
    identical mask as the uplink `sample_and_pack` stream, m⊙w never
    exists in HBM, and the activations are padded/read once rather
    than once per tap."""
    if not isinstance(p, MaskedLeaf):
        return jax.lax.conv_general_dilated(
            x, p.astype(x.dtype), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    kh, kw, ci, co = p.w.shape
    B, H, Wd, _ = x.shape
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw),
                     (0, 0)))
    cols = jnp.concatenate(
        [xp[:, dy:dy + H, dx:dx + Wd, :]
         for dy in range(kh) for dx in range(kw)],
        axis=-1).reshape(-1, kh * kw * ci)
    blk = MaskedLeaf(p.w.reshape(kh * kw * ci, co),
                     p.s.reshape(kh * kw * ci, co),
                     p.seed[0, 0], p.off[0, 0], p.mode, p.tau)
    return masked_dense_apply(cols, blk).reshape(B, H, Wd, co)


def effective_weight(p) -> jax.Array:
    """Effective weight tensor m * w from the SAME hash stream as the
    fused kernels (one weight-sized temporary).

    Since the grouped/conv kernels landed this survives ONLY on the
    per-token decode path (`conv1d_step`) — decode sessions should
    materialize once up front via `masking.freeze_for_decode`, making
    this a no-op pass-through (docs/DESIGN.md §3)."""
    if isinstance(p, MaskedLeaf):
        return masking.materialize_leaf(p)
    return p

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype=DEFAULT_DTYPE, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=DEFAULT_DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layer_norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] \
        + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta=10000.0, dtype=jnp.float32):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=dtype)
                            / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., S, H, Hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections=(16, 24, 24), theta=10000.0):
    """Qwen2-VL M-RoPE: positions3 (3, ..., S) for (t, h, w); the rotary
    dim is partitioned into `sections` (halved freq indices), each section
    rotated by its own position stream."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (half,)
    # build per-frequency position selector
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)  # (half,)
    # positions3: (3, B, S) -> (B, S, half) gathering by sec_id
    pos = jnp.take(positions3, sec_id, axis=0)          # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)  # (B, S, half)
    ang = pos * freqs                                   # (B, S, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, chunked online-softmax)
# ---------------------------------------------------------------------------


def gqa_init(key, d_model, n_heads, n_kv, head_dim, qkv_bias=False,
             dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "w_k": dense_init(ks[1], (d_model, n_kv * head_dim), dtype),
        "w_v": dense_init(ks[2], (d_model, n_kv * head_dim), dtype),
        "w_o": dense_init(ks[3], (n_heads * head_dim, d_model), dtype,
                          fan_in=n_heads * head_dim),
    }
    if qkv_bias:
        p["bias_q"] = jnp.zeros((n_heads * head_dim,), jnp.float32)
        p["bias_k"] = jnp.zeros((n_kv * head_dim,), jnp.float32)
        p["bias_v"] = jnp.zeros((n_kv * head_dim,), jnp.float32)
    return p


def _attn_scores_mask(q_pos, k_pos, window: int | None, causal=True):
    """(Sq, Sk) additive mask. window=None -> full (causal)."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = (diff >= 0) if causal else jnp.ones_like(diff, bool)
    if window is not None:
        ok = ok & (diff < window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention_core(q, k, v, q_pos, k_pos, window=None, causal=True,
                   chunk_kv: int | None = None, soft_cap: float | None = None):
    """q: (B, Sq, H, Hd); k: (B, Sk, Kv, Hd); v: (B, Sk, Kv, Dv).
    GQA by head repetition; Dv may differ from Hd (MLA).

    chunk_kv: if set, run online-softmax over KV chunks (flash-style
    memory behaviour: never materializes the (Sq, Sk) matrix). This is
    the memory path for 32k prefill / 500k contexts.
    """
    B, Sq, H, Hd = q.shape
    Kv = k.shape[2]
    Dv = v.shape[-1]
    rep = H // Kv
    scale = 1.0 / math.sqrt(Hd)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Kv, rep, Hd)

    if chunk_kv is None:
        s = jnp.einsum("bqgrh,bkgh->bgrqk", qf, k.astype(jnp.float32))
        if soft_cap is not None:
            s = jnp.tanh(s / soft_cap) * soft_cap
        s = s + _attn_scores_mask(q_pos, k_pos, window, causal)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqk,bkgh->bqgrh", p, v.astype(jnp.float32))
        return o.reshape(B, Sq, H, Dv).astype(q.dtype)

    # online softmax over kv chunks
    Sk = k.shape[1]
    n_chunks = (Sk + chunk_kv - 1) // chunk_kv
    pad = n_chunks * chunk_kv - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, (0, pad), constant_values=-(10 ** 9))
    kc = kp.reshape(B, n_chunks, chunk_kv, Kv, Hd)
    vc = vp.reshape(B, n_chunks, chunk_kv, Kv, Dv)
    pc = kpos.reshape(n_chunks, chunk_kv)

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, pci = xs
        s = jnp.einsum("bqgrh,bkgh->bgrqk", qf, kci.astype(jnp.float32))
        if soft_cap is not None:
            s = jnp.tanh(s / soft_cap) * soft_cap
        s = s + _attn_scores_mask(q_pos, pci, window, causal)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrqk,bkgh->bgrqh", p, vci.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Kv, rep, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Kv, rep, Sq), jnp.float32)
    a0 = jnp.zeros((B, Kv, rep, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.moveaxis(o, -2, 1).reshape(B, Sq, H, Dv)
    return o.astype(q.dtype)


def gqa_apply(p, x, positions, n_heads, n_kv, head_dim, *, window=None,
              causal=True, rope_theta=10000.0, chunk_kv=None,
              mrope_positions=None, mrope_sections=None,
              kv_override=None, k_positions=None, use_rope=True):
    """Full GQA block (no norm).

    positions: (S,) or (B, S) query positions (also key positions for
    self-attention without override).
    kv_override: (k, v) tensors — cross-attention or cached decode; keys
    are assumed already roped. k_positions gives their positions (default
    arange).
    Returns (out, (k, v)) so callers can populate KV caches.
    """
    B, S, D = x.shape
    q = masked_dense_apply(x, p["w_q"]).reshape(B, S, n_heads, head_dim)
    if "bias_q" in p:
        q = q + p["bias_q"].reshape(n_heads, head_dim).astype(q.dtype)
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections, rope_theta)
    elif use_rope:
        q = apply_rope(q, positions, rope_theta)

    if kv_override is not None:
        k, v = kv_override
        k_pos = (k_positions if k_positions is not None
                 else jnp.arange(k.shape[1]))
    else:
        k = masked_dense_apply(x, p["w_k"]).reshape(B, S, n_kv, head_dim)
        v = masked_dense_apply(x, p["w_v"]).reshape(B, S, n_kv, head_dim)
        if "bias_k" in p:
            k = k + p["bias_k"].reshape(n_kv, head_dim).astype(k.dtype)
            v = v + p["bias_v"].reshape(n_kv, head_dim).astype(v.dtype)
        if mrope_positions is not None:
            k = apply_mrope(k, mrope_positions, mrope_sections, rope_theta)
        elif use_rope:
            k = apply_rope(k, positions, rope_theta)
        k_pos = positions

    o = attention_core(q, k, v, positions, k_pos,
                       window=window, causal=causal, chunk_kv=chunk_kv)
    return masked_dense_apply(
        o.reshape(B, S, n_heads * head_dim), p["w_o"]), (k, v)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------


def mla_init(key, d_model, n_heads, kv_lora, q_lora, qk_nope, qk_rope,
             v_head, dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 8)
    p = {
        # KV compression: d -> kv_lora (+ decoupled rope key)
        "w_dkv": dense_init(ks[0], (d_model, kv_lora + qk_rope), dtype),
        "kv_norm_scale": jnp.ones((kv_lora,), jnp.float32),
        "w_uk": dense_init(ks[1], (kv_lora, n_heads * qk_nope), dtype),
        "w_uv": dense_init(ks[2], (kv_lora, n_heads * v_head), dtype),
        "w_o": dense_init(ks[3], (n_heads * v_head, d_model), dtype,
                          fan_in=n_heads * v_head),
    }
    if q_lora:
        p["w_dq"] = dense_init(ks[4], (d_model, q_lora), dtype)
        p["q_norm_scale"] = jnp.ones((q_lora,), jnp.float32)
        p["w_uq"] = dense_init(ks[5], (q_lora, n_heads * (qk_nope + qk_rope)),
                               dtype)
    else:
        p["w_q"] = dense_init(ks[6], (d_model, n_heads * (qk_nope + qk_rope)),
                              dtype)
    return p


def mla_apply(p, x, positions, n_heads, kv_lora, qk_nope, qk_rope, v_head,
              rope_theta=10000.0, chunk_kv=None, cache_kv=None):
    """MLA forward. cache_kv: (c_kv, k_rope) prefilled tensors for decode
    (the compressed-KV cache — MLA's memory saving)."""
    B, S, D = x.shape
    if "w_dq" in p:
        cq = rms_norm({"scale": p["q_norm_scale"]},
                      masked_dense_apply(x, p["w_dq"]))
        q = masked_dense_apply(cq, p["w_uq"]).reshape(
            B, S, n_heads, qk_nope + qk_rope)
    else:
        q = masked_dense_apply(x, p["w_q"]).reshape(
            B, S, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    dkv = masked_dense_apply(x, p["w_dkv"])
    c_kv = rms_norm({"scale": p["kv_norm_scale"]}, dkv[..., :kv_lora])
    k_rope_new = apply_rope(dkv[..., kv_lora:][:, :, None, :], positions,
                            rope_theta)  # (B,S,1,qk_rope)

    if cache_kv is not None:
        c_kv_all, k_rope_all = cache_kv
        k_pos = jnp.arange(c_kv_all.shape[1])
        q_pos = positions
    else:
        c_kv_all, k_rope_all = c_kv, k_rope_new
        k_pos = positions
        q_pos = positions

    k_nope = masked_dense_apply(c_kv_all, p["w_uk"]).reshape(
        B, -1, n_heads, qk_nope)
    v = masked_dense_apply(c_kv_all, p["w_uv"]).reshape(
        B, -1, n_heads, v_head)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(
            k_rope_all, k_nope.shape[:3] + (qk_rope,))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attention_core(qfull, k, v, q_pos, k_pos, window=None, causal=True,
                       chunk_kv=chunk_kv)
    # o has head_dim v_head? attention_core keeps q's Hd; v dims differ.
    return masked_dense_apply(o.reshape(B, S, -1), p["w_o"]), \
        (c_kv, k_rope_new)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, dtype=DEFAULT_DTYPE, gated=True,
             act="silu"):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype, fan_in=d_ff)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_apply(p, x, act="silu"):
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
         "gelu_tanh": functools.partial(jax.nn.gelu, approximate=True),
         "relu": jax.nn.relu}[act]
    up = masked_dense_apply(x, p["w_up"])
    if "w_gate" in p:
        up = a(masked_dense_apply(x, p["w_gate"])) * up
    else:
        up = a(up)
    return masked_dense_apply(up, p["w_down"])


# ---------------------------------------------------------------------------
# MoE (capacity-based dispatch, EP-shardable on the expert axis)
# ---------------------------------------------------------------------------


def moe_init(key, d_model, moe_d_ff, n_experts, n_shared, dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 5)
    p = {
        "router_w": dense_init(ks[0], (d_model, n_experts), jnp.float32),
        # stacked expert weights: (E, ...) — EP shards axis 0
        "w_up": dense_init(ks[1], (n_experts, d_model, moe_d_ff), dtype),
        "w_gate": dense_init(ks[2], (n_experts, d_model, moe_d_ff), dtype),
        "w_down": dense_init(ks[3], (n_experts, moe_d_ff, d_model), dtype,
                             fan_in=moe_d_ff),
    }
    if n_shared:
        p["shared"] = mlp_init(ks[4], d_model, moe_d_ff * n_shared, dtype)
    return p


def moe_apply(p, x, n_experts, top_k, capacity_factor=1.25,
              router_noise=0.0, key=None, block_dispatch=0):
    """GShard-style capacity dispatch. x: (B, S, D) -> (B, S, D).

    Dispatch/combine are einsums so GSPMD shards them (tokens on data,
    experts on model). Dropped tokens (over capacity) fall through on the
    residual path (plus shared experts for DeepSeek-V2).

    block_dispatch=G > 0: tokens are split into G blocks, each with its
    own (G x smaller) expert capacity, and dispatched independently.
    The (T, E, C) dispatch tensor shrinks Gx — the one-hot dispatch
    einsums cost O(T * E * C * D) = O(T^2 * top_k * cf * D / G), so
    block-local dispatch cuts the dominant non-useful FLOPs by G while
    matching real per-device capacity semantics (docs/DESIGN.md §7).
    """
    B, S, D = x.shape
    if block_dispatch and B * S % block_dispatch == 0 \
            and B * S // block_dispatch >= 8:
        G = block_dispatch
        xt = x.reshape(G, (B * S) // G, 1, D)
        y, aux = jax.vmap(
            lambda xb: moe_apply(p, xb, n_experts, top_k,
                                 capacity_factor, 0.0, None, 0))(xt)
        return y.reshape(B, S, D), jnp.mean(aux)
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32) @ p["router_w"])
    if router_noise > 0 and key is not None:
        logits = logits + router_noise * jax.random.normal(
            key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    gval, gidx = jax.lax.top_k(probs, top_k)                # (T, k)
    gval = gval / jnp.maximum(jnp.sum(gval, -1, keepdims=True), 1e-9)

    cap = max(int(T * top_k * capacity_factor / n_experts), 4)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gidx, n_experts, dtype=jnp.float32)  # (T,k,E)
    flat = onehot.reshape(T * top_k, n_experts)
    pos_in_e = (jnp.cumsum(flat, axis=0) - flat).reshape(
        T, top_k, n_experts)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)               # (T, k)
    keep = pos < cap
    gval = gval * keep

    # dispatch tensor (T, E, C)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) \
        * keep[..., None]                                    # (T,k,C)
    disp = jnp.einsum("tke,tkc->tec", onehot, pos_oh)        # (T,E,C)
    xe = jnp.einsum("tec,td->ecd", disp, xt.astype(jnp.float32))
    # xe stays f32 through the expert stack: the chain then carries NO
    # intermediate bf16 rounding, so the fused (Pallas) and plain
    # (einsum) branches of masked_grouped_apply are bit-identical —
    # XLA's excess-precision pass would elide a bf16 round-trip on the
    # einsum branch but not on a physical pallas output buffer

    # stacked (E, ., .) expert weights ride the GROUPED fused kernels:
    # one pallas_call per projection covers all E experts (per-expert
    # seed/off = expert's slice of the leaf's hash stream), so the
    # stacked m⊙w is never materialized — plain arrays (float
    # baselines, REPRO_EFF_PATH) take the batched einsum
    h = jax.nn.silu(masked_grouped_apply(xe, p["w_gate"])) \
        * masked_grouped_apply(xe, p["w_up"])
    ye = masked_grouped_apply(h, p["w_down"])                # (E,C,D)

    comb = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh,
                      gval.astype(jnp.float32))
    y = jnp.einsum("tec,ecd->td", comb, ye.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, D)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x)

    # aux load-balancing loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(onehot.sum(1), axis=0)
    aux = n_experts * jnp.sum(me * ce)
    return y, aux


# ---------------------------------------------------------------------------
# Causal temporal conv (mamba2 / recurrentgemma frontends)
# ---------------------------------------------------------------------------


def conv1d_init(key, width, channels, dtype=DEFAULT_DTYPE):
    return {"w_conv": dense_init(key, (width, channels), dtype,
                                 fan_in=width),
            "bias_conv": jnp.zeros((channels,), jnp.float32)}


def conv1d_causal(p, x):
    """Depthwise causal conv. x: (B, S, C); kernel (W, C).

    Dispatches through `masked_conv1d_apply`: MaskedLeaf kernels run
    the fused masked tap loop, plain kernels the mask-free twin — both
    one Pallas pass, with no (B, S, W, C) stacked-views temporary."""
    out = masked_conv1d_apply(x, p["w_conv"])
    return (out + p["bias_conv"]).astype(x.dtype)


def conv1d_step(p, buf, x_t):
    """Single decode step with rolling buffer. buf: (B, W-1, C).

    Decode-path note: `effective_weight` re-materializes m⊙w from a
    MaskedLeaf EVERY step — decode sessions must freeze the mask once
    at prefill (`masking.freeze_for_decode`, see `launch/serve.py`), so
    steady-state decode sees a plain array here and does zero mask
    resampling."""
    w_conv = effective_weight(p["w_conv"])
    W = w_conv.shape[0]
    full = jnp.concatenate([buf, x_t[:, None]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                     w_conv.astype(jnp.float32)) + p["bias_conv"]
    return full[:, 1:], out.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(table, x):
    return x.astype(jnp.float32) @ table.astype(jnp.float32).T
