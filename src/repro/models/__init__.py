"""Model zoo dispatcher: family -> (init, forward, loss, cache, decode).

Forward contract (docs/DESIGN.md §3): `forward(params, batch, ...)`
accepts a params pytree whose maskable leaves are EITHER plain arrays
(float training, or effective params materialized by
`masking.sample_effective` / `masking.hash_effective` — the reference
path) OR `masking.MaskedLeaf` (w, s, seed) bundles built by
`masking.masked_forward_tree` — the fused execution path, where every
maskable leaf runs its fused kernel directly (`ops.masked_dense` for
2-D projections, `ops.masked_dense_grouped` for stacked MoE expert
weights, `ops.masked_conv1d` for depthwise conv kernels) and the
Bernoulli mask never exists in HBM.  Model code never branches on the
path: the `layers.masked_*_apply` dispatchers decide per leaf, so the
same forward serves float baselines, masked training, and serving
(which freezes the tree once via `masking.freeze_for_decode`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer, ssm, hybrid, encdec, cnn  # noqa: F401


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init_params: Callable
    forward: Callable            # (params, batch, chunk_kv=None) -> (logits, aux)
    loss: Callable               # (outputs, batch) -> scalar
    init_cache: Optional[Callable]
    decode_step: Callable        # (params, cache, token, pos) -> (logits, cache)


def build_model(cfg: ArchConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer

        def fwd(params, batch, chunk_kv=None):
            return mod.forward(params, cfg, batch["tokens"],
                               vis_embeds=batch.get("vis_embeds"),
                               chunk_kv=chunk_kv)

        windowed = (cfg.window_kv_cache and cfg.sliding_window
                    and cfg.global_every > 0)

        def dec(params, cache, token, pos):
            if windowed:
                return mod.decode_step_windowed(params, cfg, cache,
                                                token, pos)
            return mod.decode_step(params, cfg, cache, token, pos)

        def mk_cache(b, s):
            if windowed:
                return mod.init_cache_windowed(cfg, b, s)
            return mod.init_cache(cfg, b, s)

        return ModelApi(cfg, lambda k: mod.init_params(k, cfg), fwd,
                        transformer.lm_loss, mk_cache, dec)

    if cfg.family == "ssm":
        def fwd(params, batch, chunk_kv=None):
            return ssm.forward(params, cfg, batch["tokens"],
                               chunk_kv=chunk_kv)

        def dec(params, cache, token, pos):
            return ssm.decode_step(params, cfg, cache, token, pos)

        return ModelApi(cfg, lambda k: ssm.init_params(k, cfg), fwd,
                        transformer.lm_loss,
                        lambda b, s: ssm.init_cache(cfg, b, s), dec)

    if cfg.family == "hybrid":
        def fwd(params, batch, chunk_kv=None):
            return hybrid.forward(params, cfg, batch["tokens"],
                                  chunk_kv=chunk_kv)

        def dec(params, cache, token, pos):
            return hybrid.decode_step(params, cfg, cache, token, pos)

        return ModelApi(cfg, lambda k: hybrid.init_params(k, cfg), fwd,
                        transformer.lm_loss,
                        lambda b, s: hybrid.init_cache(cfg, b, s), dec)

    if cfg.family == "encdec":
        def fwd(params, batch, chunk_kv=None):
            return encdec.forward(params, cfg, batch["tokens"],
                                  frames=batch.get("frames"),
                                  chunk_kv=chunk_kv)

        def dec(params, cache, token, pos):
            return encdec.decode_step(params, cfg, cache, token, pos)

        return ModelApi(cfg, lambda k: encdec.init_params(k, cfg), fwd,
                        transformer.lm_loss,
                        lambda b, s: encdec.init_cache(cfg, b, s), dec)

    raise ValueError(f"unknown family {cfg.family}")
