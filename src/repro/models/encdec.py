"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings (B, enc_seq, D). LayerNorm +
non-gated GELU MLP + learned positions, per the original architecture.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Pytree = Any


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": L.layer_norm_init(cfg.d_model),
        "attn": L.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                           cfg.n_kv_heads, cfg.hd),
        "ffn_norm": L.layer_norm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": L.layer_norm_init(cfg.d_model),
        "attn": L.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                           cfg.n_kv_heads, cfg.hd),
        "cross_norm": L.layer_norm_init(cfg.d_model),
        "cross": L.gqa_init(ks[1], cfg.d_model, cfg.n_heads,
                            cfg.n_kv_heads, cfg.hd),
        "ffn_norm": L.layer_norm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False),
    }


def init_params(key, cfg: ArchConfig) -> Pytree:
    ks = jax.random.split(key, 6)
    return {
        "embed": {"table": L.embed_init(ks[0], (cfg.vocab, cfg.d_model))},
        "pos_embed_float": L.embed_init(ks[1], (40960, cfg.d_model)),
        "enc_pos_embed_float": L.embed_init(ks[2], (cfg.enc_seq,
                                                    cfg.d_model)),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(
            jax.random.split(ks[3], cfg.enc_layers)),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(
            jax.random.split(ks[4], cfg.n_layers)),
        "enc_final_norm": L.layer_norm_init(cfg.d_model),
        "final_norm": L.layer_norm_init(cfg.d_model),
    }


def encode(params, cfg: ArchConfig, frames, chunk_kv=None):
    """frames: (B, enc_seq, D) stubbed frontend embeddings."""
    S = frames.shape[1]
    x = frames + params["enc_pos_embed_float"][:S].astype(frames.dtype)
    positions = jnp.arange(S)

    def body(x, lp):
        h = L.layer_norm(lp["attn_norm"], x)
        out, _ = L.gqa_apply(lp["attn"], h, positions, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd, causal=False,
                             use_rope=False, chunk_kv=chunk_kv)
        x = x + out
        h = L.layer_norm(lp["ffn_norm"], x)
        return x + L.mlp_apply(lp["mlp"], h, "gelu"), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=cfg.scan_unroll)
    return L.layer_norm(params["enc_final_norm"], x)


def _dec_block(cfg, lp, x, enc_out, positions, chunk_kv,
               self_kv=None, self_kpos=None):
    h = L.layer_norm(lp["attn_norm"], x)
    out, kv = L.gqa_apply(lp["attn"], h, positions, cfg.n_heads,
                          cfg.n_kv_heads, cfg.hd, causal=True,
                          use_rope=False, chunk_kv=chunk_kv,
                          kv_override=self_kv, k_positions=self_kpos)
    x = x + out
    h = L.layer_norm(lp["cross_norm"], x)
    B, S_enc = enc_out.shape[0], enc_out.shape[1]
    k = L.masked_dense_apply(enc_out, lp["cross"]["w_k"]).reshape(
        B, S_enc, cfg.n_kv_heads, cfg.hd)
    v = L.masked_dense_apply(enc_out, lp["cross"]["w_v"]).reshape(
        B, S_enc, cfg.n_kv_heads, cfg.hd)
    out, _ = L.gqa_apply(lp["cross"], h, positions, cfg.n_heads,
                         cfg.n_kv_heads, cfg.hd, causal=False,
                         use_rope=False, kv_override=(k, v),
                         k_positions=jnp.arange(S_enc))
    x = x + out
    h = L.layer_norm(lp["ffn_norm"], x)
    return x + L.mlp_apply(lp["mlp"], h, "gelu"), kv


def forward(params, cfg: ArchConfig, tokens, frames=None, chunk_kv=None,
            **_):
    """tokens: (B, S_dec); frames: (B, enc_seq, D)."""
    if frames is None:
        frames = jnp.zeros((tokens.shape[0], cfg.enc_seq, cfg.d_model),
                           jnp.bfloat16)
    enc_out = encode(params, cfg, frames, chunk_kv)
    S = tokens.shape[1]
    x = L.embed_lookup(params["embed"]["table"], tokens)
    x = x + params["pos_embed_float"][:S].astype(x.dtype)
    positions = jnp.arange(S)

    def body(x, lp):
        x, _ = _dec_block(cfg, lp, x, enc_out, positions, chunk_kv)
        return x, None

    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=cfg.scan_unroll)
    x = L.layer_norm(params["final_norm"], x)
    return L.unembed(params["embed"]["table"], x), jnp.float32(0.0)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Pytree:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    enc = (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "ck": jnp.zeros(enc, dtype), "cv": jnp.zeros(enc, dtype)}


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    """One decoder token; cross-KV precomputed in cache (from encode)."""
    B = token.shape[0]
    x = L.embed_lookup(params["embed"]["table"], token[:, None])
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_embed_float"], pos, 1, 0).astype(x.dtype)
    positions = pos[None]

    def body(x, xs):
        lp, lc = xs
        h = L.layer_norm(lp["attn_norm"], x)
        k_new = L.masked_dense_apply(h, lp["attn"]["w_k"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        v_new = L.masked_dense_apply(h, lp["attn"]["w_v"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.hd)
        kc = jax.lax.dynamic_update_slice(lc["k"],
                                          k_new.astype(lc["k"].dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(lc["v"],
                                          v_new.astype(lc["v"].dtype),
                                          (0, pos, 0, 0))
        out, _ = L.gqa_apply(lp["attn"], h, positions, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd, causal=True,
                             use_rope=False, kv_override=(kc, vc),
                             k_positions=jnp.arange(kc.shape[1]))
        x = x + out
        h = L.layer_norm(lp["cross_norm"], x)
        out, _ = L.gqa_apply(lp["cross"], h, positions, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd, causal=False,
                             use_rope=False, kv_override=(lc["ck"],
                                                          lc["cv"]),
                             k_positions=jnp.arange(cfg.enc_seq))
        x = x + out
        h = L.layer_norm(lp["ffn_norm"], x)
        x = x + L.mlp_apply(lp["mlp"], h, "gelu")
        return x, {"k": kc, "v": vc, "ck": lc["ck"], "cv": lc["cv"]}

    x, nc = jax.lax.scan(body, x, (params["dec_layers"], cache),
                         unroll=cfg.scan_unroll)
    x = L.layer_norm(params["final_norm"], x)
    logits = L.unembed(params["embed"]["table"], x)[:, 0]
    return logits, nc
