"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD algorithm for training (quadratic-within-chunk, linear across
chunks) and the recurrent form for decode. Attention-free: long_500k is
the showcase shape (constant-memory state).

Parameter naming: maskable tensors are w_*; the dynamical-system params
(A_log, dt bias, D) stay float — Bernoulli-masking a decay rate destroys
stability (docs/DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Pytree = Any


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_headdim
    return d_in, n_heads


def _layer_init(key, cfg: ArchConfig):
    d, N, G = cfg.d_model, cfg.ssm_state, cfg.ssm_ngroups
    d_in, nh = _dims(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = d_in + 2 * G * N
    return {
        "norm": L.rms_norm_init(d),
        # fused input projection: [z, x, B, C, dt]
        "w_in": L.dense_init(ks[0], (d, 2 * d_in + 2 * G * N + nh)),
        "conv": L.conv1d_init(ks[1], cfg.conv_width, conv_ch),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm_scale": jnp.ones((d_in,), jnp.float32),
        "w_out": L.dense_init(ks[2], (d_in, d), fan_in=d_in),
    }


def init_params(key, cfg: ArchConfig) -> Pytree:
    ks = jax.random.split(key, 3)
    lk = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": {"table": L.embed_init(ks[1], (cfg.vocab, cfg.d_model))},
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(lk),
        "final_norm": L.rms_norm_init(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# SSD chunked scan (training)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int = 256):
    """SSD: y_t = C_t^T sum_{s<=t} (prod_{r=s+1..t} exp(A dt_r)) dt_s B_s x_s

    x: (B, S, H, P); dt: (B, S, H); A: (H,) (negative);
    Bm, Cm: (B, S, G, N). Heads map to groups by H // G repetition.
    Returns y: (B, S, H, P), final_state: (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N)

    dA = dtc * A  # (B, nc, c, H)  negative
    dA_cs = jnp.cumsum(dA, axis=2)

    # intra-chunk (quadratic within chunk)
    # L[b,n,h,i,j] = exp(dA_cs_i - dA_cs_j) for i >= j
    diff = dA_cs[..., :, None, :] - dA_cs[..., None, :, :]  # (B,nc,c,c,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :,
                                                    None]
    # zero OFF-mask diffs BEFORE exp: exp(+big)*0 -> NaN in the vjp
    diff = jnp.where(mask, diff, 0.0)
    Ldec = jnp.where(mask, jnp.exp(diff), 0.0)
    # scores: C_i . B_j  (group-shared)
    CB = jnp.einsum("bucgs,bukgs->buckg", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))  # (B,nc,c,c,G)
    CB = jnp.repeat(CB, rep, axis=-1)  # (B,nc,c,c,H)
    W = CB * Ldec
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_intra = jnp.einsum("buckh,bukhp->buchp", W, xdt)

    # chunk-final states: state_n = sum_j exp(dA_cs_last - dA_cs_j) dt_j B_j x_j
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,nc,c,H)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,c,H,N)
    states = jnp.einsum("buch,buchs,buchp->buhps",
                        decay_to_end, Bh.astype(jnp.float32), xdt)

    # inter-chunk recurrence over nc (sequential, cheap)
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (B, nc, H)

    def body(carry, xs):
        st_prev = carry                      # (B, H, P, N)
        st_new, dec = xs                     # (B,H,P,N), (B,H)
        st = st_prev * dec[..., None, None] + st_new
        return st, st_prev

    st0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final_state, init_states = jax.lax.scan(
        body, st0, (jnp.moveaxis(states, 1, 0),
                    jnp.moveaxis(chunk_decay, 1, 0)))
    init_states = jnp.moveaxis(init_states, 0, 1)  # (B,nc,H,P,N)

    # contribution of carried-in state: y += C_i exp(dA_cs_i) state_in
    Ch = jnp.repeat(Cc, rep, axis=3)  # (B,nc,c,H,N)
    y_inter = jnp.einsum("buchs,buch,buhps->buchp",
                         Ch.astype(jnp.float32), jnp.exp(dA_cs),
                         init_states)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, final_state


def _mix(cfg: ArchConfig, lp, x, chunk=256):
    """One mamba2 mixer on (B, S, D)."""
    d_in, nh = _dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    B_, S, D = x.shape
    zxbcdt = L.masked_dense_apply(x, lp["w_in"])
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N],
        axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(L.conv1d_causal(lp["conv"], conv_in))
    xs = conv_out[..., :d_in].reshape(B_, S, nh, P)
    Bm = conv_out[..., d_in:d_in + G * N].reshape(B_, S, G, N)
    Cm = conv_out[..., d_in + G * N:].reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, chunk=min(chunk, S))
    y = y + xs.astype(jnp.float32) * lp["D"][..., None]
    y = y.reshape(B_, S, d_in)
    y = L.rms_norm({"scale": lp["gate_norm_scale"]},
                   y.astype(x.dtype) * jax.nn.silu(z))
    return L.masked_dense_apply(y, lp["w_out"])


def forward(params, cfg: ArchConfig, tokens, chunk_kv=None, **_):
    x = L.embed_lookup(params["embed"]["table"], tokens)

    def body(x, lp):
        def blk(x, lp):
            h = L.rms_norm(lp["norm"], x)
            return x + _mix(cfg, lp, h)
        if cfg.remat:
            blk = jax.checkpoint(
                blk, policy=jax.checkpoint_policies.nothing_saveable)
        return blk(x, lp), None

    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=cfg.scan_unroll)
    x = L.rms_norm(params["final_norm"], x)
    logits = L.unembed(params["embed"]["table"], x)
    return logits, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Recurrent decode (constant memory — the long_500k path)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Pytree:
    d_in, nh = _dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    conv_ch = d_in + 2 * G * N
    return {
        "ssm_state": jnp.zeros((cfg.n_layers, batch, nh, P, N),
                               jnp.float32),
        "conv_buf": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1,
                               conv_ch), dtype),
    }


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    d_in, nh = _dims(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    B_ = token.shape[0]
    x = L.embed_lookup(params["embed"]["table"], token)  # (B, D)

    def body(x, xs):
        lp, st, buf = xs
        h = L.rms_norm(lp["norm"], x[:, None])[:, 0]
        zxbcdt = L.masked_dense_apply(h, lp["w_in"])
        z, xin, Bm, Cm, dt = jnp.split(
            zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N,
                     2 * d_in + 2 * G * N], axis=-1)
        conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
        buf, conv_out = L.conv1d_step(lp["conv"], buf, conv_in)
        conv_out = jax.nn.silu(conv_out)
        xin = conv_out[..., :d_in].reshape(B_, nh, P)
        Bm = conv_out[..., d_in:d_in + G * N].reshape(B_, G, N)
        Cm = conv_out[..., d_in + G * N:].reshape(B_, G, N)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["A_log"])
        dA = jnp.exp(dt * A)  # (B, nh)
        rep = nh // G
        Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,nh,N)
        Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
        st = st * dA[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt, Bh, xin.astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", st, Ch)
        y = y + xin.astype(jnp.float32) * lp["D"][..., None]
        y = y.reshape(B_, d_in)
        y = L.rms_norm({"scale": lp["gate_norm_scale"]},
                       y.astype(x.dtype) * jax.nn.silu(z))
        return x + L.masked_dense_apply(y, lp["w_out"]), (st, buf)

    x, (sts, bufs) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm_state"], cache["conv_buf"]),
        unroll=cfg.scan_unroll)
    x = L.rms_norm(params["final_norm"], x[:, None])[:, 0]
    logits = L.unembed(params["embed"]["table"], x)
    return logits, {"ssm_state": sts, "conv_buf": bufs}
