"""The paper's own experiment models: Conv4 / Conv6 / Conv10 feed-forward
CNNs (as in Zhou et al. [9] / Ramanujan et al. [4]), for MNIST/CIFAR-
style (B, H, W, C) inputs. These are the faithful-reproduction models;
every conv and dense kernel is maskable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    name: str
    conv_planes: Tuple[int, ...]   # channels per conv layer; pool after each pair
    dense_sizes: Tuple[int, ...]
    n_classes: int = 10
    in_channels: int = 3
    img_size: int = 32


CONV4 = ConvConfig("conv4", (64, 64, 128, 128), (256, 256))
CONV6 = ConvConfig("conv6", (64, 64, 128, 128, 256, 256), (256, 256))
CONV10 = ConvConfig("conv10",
                    (64, 64, 128, 128, 256, 256, 512, 512, 512, 512),
                    (256, 256))


def init_params(key, cfg: ConvConfig) -> Pytree:
    params = {"convs": [], "denses": []}
    ks = jax.random.split(key, len(cfg.conv_planes) + len(cfg.dense_sizes)
                          + 1)
    cin = cfg.in_channels
    for i, cout in enumerate(cfg.conv_planes):
        fan_in = 3 * 3 * cin
        params["convs"].append({
            "w_conv": L.dense_init(ks[i], (3, 3, cin, cout),
                                   fan_in=fan_in),
            "bias": jnp.zeros((cout,), jnp.float32)})
        cin = cout
    side = cfg.img_size // (2 ** (len(cfg.conv_planes) // 2))
    din = side * side * cin
    for j, dout in enumerate(cfg.dense_sizes + (cfg.n_classes,)):
        k = ks[len(cfg.conv_planes) + j]
        params["denses"].append({
            "w_dense": L.dense_init(k, (din, dout), fan_in=din),
            "bias": jnp.zeros((dout,), jnp.float32)})
        din = dout
    return params


def forward(params, cfg: ConvConfig, images):
    """images: (B, H, W, C) -> logits (B, n_classes)."""
    x = images.astype(jnp.float32)
    for i, cp in enumerate(params["convs"]):
        # (3, 3, cin, cout) kernels: MaskedLeaf -> one fused
        # masked_dense per tap (off = tap_idx*ci*co slices of the
        # leaf's hash stream), plain arrays -> lax conv
        x = L.masked_conv2d_apply(x, cp["w_conv"])
        x = jax.nn.relu(x + cp["bias"])
        if i % 2 == 1:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
    x = x.reshape(x.shape[0], -1)
    for j, dp in enumerate(params["denses"]):
        x = L.masked_dense_apply(x, dp["w_dense"]) + dp["bias"]
        if j < len(params["denses"]) - 1:
            x = jax.nn.relu(x)
    return x


def ce_loss(logits, batch):
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, batch["labels"][:, None], axis=-1)
    return jnp.mean(nll)


def accuracy(logits, batch):
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                    .astype(jnp.float32))
