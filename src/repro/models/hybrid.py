"""RecurrentGemma (Griffin, arXiv:2402.19427): RG-LRU recurrent blocks
interleaved with local (sliding-window, MQA) attention at a 2:1 ratio.

Layout: the layer list is grouped as repeats of cfg.block_pattern
("rec","rec","attn"); full groups ride one lax.scan, the remainder rides
a second rec-only scan. The RG-LRU temporal mix uses an associative scan
(log-depth on TPU) for training and an O(1)-state recurrence for decode
— this is the long_500k path.

Float (non-masked) params: recurrence decay `a_param` (Lambda), conv
bias, gate biases, norms — masking a decay destroys stability
(docs/DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Pytree = Any

_C = 8.0  # RG-LRU decay sharpness constant (Griffin paper)


def _lru_width(cfg):
    return cfg.lru_width or cfg.d_model


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _rec_block_init(key, cfg: ArchConfig):
    d, w = cfg.d_model, _lru_width(cfg)
    ks = jax.random.split(key, 6)
    # Lambda init so a^c in [0.9, 0.999] (Griffin)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1
    return {
        "norm": L.rms_norm_init(d),
        "w_x": L.dense_init(ks[0], (d, w)),
        "w_y": L.dense_init(ks[1], (d, w)),
        "conv": L.conv1d_init(ks[2], cfg.conv_width, w),
        "w_rg": L.dense_init(ks[3], (w, w)),   # recurrence gate
        "w_ri": L.dense_init(ks[4], (w, w)),   # input gate
        "bias_rg": jnp.zeros((w,), jnp.float32),
        "bias_ri": jnp.zeros((w,), jnp.float32),
        "a_param": a_param,
        "w_out": L.dense_init(key, (w, d), fan_in=w),
        "mlp_norm": L.rms_norm_init(d),
        "mlp": L.mlp_init(key, d, cfg.d_ff, act=cfg.act),
    }


def _attn_block_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    return {
        "norm": L.rms_norm_init(cfg.d_model),
        "attn": L.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                           cfg.n_kv_heads, cfg.hd),
        "mlp_norm": L.rms_norm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, act=cfg.act),
    }


def _group_counts(cfg: ArchConfig):
    plen = len(cfg.block_pattern)
    n_groups = cfg.n_layers // plen
    n_tail = cfg.n_layers - n_groups * plen  # leading-pattern remainder
    return n_groups, n_tail


def init_params(key, cfg: ArchConfig) -> Pytree:
    ks = jax.random.split(key, 4)
    n_groups, n_tail = _group_counts(cfg)

    def group_init(k):
        gks = jax.random.split(k, len(cfg.block_pattern))
        return {f"b{i}_{kind}": (_rec_block_init(gk, cfg) if kind == "rec"
                                 else _attn_block_init(gk, cfg))
                for i, (kind, gk) in enumerate(zip(cfg.block_pattern, gks))}

    params = {
        "embed": {"table": L.embed_init(ks[0], (cfg.vocab, cfg.d_model))},
        "groups": jax.vmap(group_init)(jax.random.split(ks[1], n_groups)),
        "final_norm": L.rms_norm_init(cfg.d_model),
    }
    if n_tail:
        tails = []
        tk = jax.random.split(ks[2], n_tail)
        for i in range(n_tail):
            kind = cfg.block_pattern[i]
            tails.append(_rec_block_init(tk[i], cfg) if kind == "rec"
                         else _attn_block_init(tk[i], cfg))
        params["tail"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *tails) if all(
                cfg.block_pattern[i] == cfg.block_pattern[0]
                for i in range(n_tail)) else tails
    return params


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def rg_lru_scan(u, r, i, a_param):
    """h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * u_t), associative scan.

    u, r, i: (B, S, W) float32. Returns h (B, S, W) and final h.
    """
    log_a = -_C * jax.nn.softplus(a_param) * r          # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    A, Bv = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return Bv, Bv[:, -1]


def _rec_mix(cfg, lp, x):
    """RG-LRU mixer on (B, S, D) -> (B, S, D)."""
    w = _lru_width(cfg)
    gate = jax.nn.gelu(
        L.masked_dense_apply(x, lp["w_y"]).astype(jnp.float32))
    u = L.masked_dense_apply(x, lp["w_x"])
    u = L.conv1d_causal(lp["conv"], u).astype(jnp.float32)
    r = jax.nn.sigmoid(L.masked_dense_apply(u, lp["w_rg"])
                       .astype(jnp.float32) + lp["bias_rg"])
    i = jax.nn.sigmoid(L.masked_dense_apply(u, lp["w_ri"])
                       .astype(jnp.float32) + lp["bias_ri"])
    h, _ = rg_lru_scan(u, r, i, lp["a_param"])
    return L.masked_dense_apply((h * gate).astype(x.dtype),
                                lp["w_out"])


def _rec_step(cfg, lp, x_t, h_prev, conv_buf):
    """One decode step. x_t: (B, D); h_prev: (B, W)."""
    gate = jax.nn.gelu(
        L.masked_dense_apply(x_t, lp["w_y"]).astype(jnp.float32))
    u = L.masked_dense_apply(x_t, lp["w_x"])
    conv_buf, u = L.conv1d_step(lp["conv"], conv_buf, u)
    u = u.astype(jnp.float32)
    r = jax.nn.sigmoid(L.masked_dense_apply(u, lp["w_rg"])
                       .astype(jnp.float32) + lp["bias_rg"])
    i = jax.nn.sigmoid(L.masked_dense_apply(u, lp["w_ri"])
                       .astype(jnp.float32) + lp["bias_ri"])
    log_a = -_C * jax.nn.softplus(lp["a_param"]) * r
    a = jnp.exp(log_a)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) \
        * (i * u)
    return L.masked_dense_apply((h * gate).astype(x_t.dtype),
                                lp["w_out"]), h, conv_buf


def _block_fwd(cfg, kind, lp, x, positions, chunk_kv):
    h = L.rms_norm(lp["norm"], x)
    if kind == "rec":
        x = x + _rec_mix(cfg, lp, h)
    else:
        out, _ = L.gqa_apply(lp["attn"], h, positions, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd,
                             window=cfg.sliding_window, causal=True,
                             rope_theta=cfg.rope_theta, chunk_kv=chunk_kv)
        x = x + out
    h = L.rms_norm(lp["mlp_norm"], x)
    return x + L.mlp_apply(lp["mlp"], h, cfg.act)


def forward(params, cfg: ArchConfig, tokens, chunk_kv=None, **_):
    x = L.embed_lookup(params["embed"]["table"], tokens)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.arange(tokens.shape[1])

    def group_body(x, gp):
        def blk(x, gp):
            for i, kind in enumerate(cfg.block_pattern):
                x = _block_fwd(cfg, kind, gp[f"b{i}_{kind}"], x,
                               positions, chunk_kv)
            return x
        if cfg.remat:
            blk = jax.checkpoint(
                blk, policy=jax.checkpoint_policies.nothing_saveable)
        return blk(x, gp), None

    x, _ = jax.lax.scan(group_body, x, params["groups"],
                        unroll=cfg.scan_unroll)

    if "tail" in params:
        def tail_body(x, lp):
            return _block_fwd(cfg, cfg.block_pattern[0], lp, x,
                              positions, chunk_kv), None
        x, _ = jax.lax.scan(tail_body, x, params["tail"])

    x = L.rms_norm(params["final_norm"], x)
    return L.unembed(params["embed"]["table"], x), jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Decode: O(1) recurrent state + ring-buffer local-attention cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Pytree:
    n_groups, n_tail = _group_counts(cfg)
    w = _lru_width(cfg)
    W = min(cfg.sliding_window or max_seq, max_seq)
    n_rec_per_group = cfg.block_pattern.count("rec")
    n_attn_per_group = len(cfg.block_pattern) - n_rec_per_group
    cache = {
        "h": jnp.zeros((n_groups, n_rec_per_group, batch, w), jnp.float32),
        "conv": jnp.zeros((n_groups, n_rec_per_group, batch,
                           cfg.conv_width - 1, w), dtype),
        # ring buffer for local attention: only `window` keys retained
        "k": jnp.zeros((n_groups, n_attn_per_group, batch, W,
                        cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((n_groups, n_attn_per_group, batch, W,
                        cfg.n_kv_heads, cfg.hd), dtype),
        "k_pos": jnp.full((n_groups, n_attn_per_group, W), -NEG_POS,
                          jnp.int32),
    }
    if n_tail:
        cache["tail_h"] = jnp.zeros((n_tail, batch, w), jnp.float32)
        cache["tail_conv"] = jnp.zeros((n_tail, batch, cfg.conv_width - 1,
                                        w), dtype)
    return cache


NEG_POS = 1 << 30


def _attn_step_ring(cfg, lp, x_t, kc, vc, kpos, pos):
    """Decode attention with a ring-buffer window cache.

    x_t: (B, D); kc/vc: (B, W, Kv, Hd); kpos: (W,) positions stored.
    """
    B = x_t.shape[0]
    W = kc.shape[1]
    h = x_t[:, None]  # (B,1,D)
    slot = pos % W
    k_new = L.masked_dense_apply(h, lp["attn"]["w_k"]).reshape(
        B, 1, cfg.n_kv_heads, cfg.hd)
    v_new = L.masked_dense_apply(h, lp["attn"]["w_v"]).reshape(
        B, 1, cfg.n_kv_heads, cfg.hd)
    k_new = L.apply_rope(k_new, pos[None], cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice(kc, k_new.astype(kc.dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v_new.astype(vc.dtype),
                                      (0, slot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(kpos, pos[None], (slot,))
    out, _ = L.gqa_apply(lp["attn"], h, pos[None], cfg.n_heads,
                         cfg.n_kv_heads, cfg.hd,
                         window=cfg.sliding_window, causal=True,
                         rope_theta=cfg.rope_theta,
                         kv_override=(kc, vc), k_positions=kpos)
    return out[:, 0], kc, vc, kpos


def decode_step(params, cfg: ArchConfig, cache, token, pos):
    x = L.embed_lookup(params["embed"]["table"], token)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    rec_ids = [i for i, k in enumerate(cfg.block_pattern) if k == "rec"]
    attn_ids = [i for i, k in enumerate(cfg.block_pattern) if k == "attn"]

    def group_body(x, xs):
        gp, h_st, conv_st, kc, vc, kpos = xs
        new_h, new_conv, new_k, new_v, new_kp = [], [], [], [], []
        ri = ai = 0
        for i, kind in enumerate(cfg.block_pattern):
            lp = gp[f"b{i}_{kind}"]
            hin = L.rms_norm(lp["norm"], x[:, None])[:, 0]
            if kind == "rec":
                out, hh, cb = _rec_step(cfg, lp, hin, h_st[ri],
                                        conv_st[ri])
                new_h.append(hh)
                new_conv.append(cb)
                ri += 1
            else:
                out, k2, v2, kp2 = _attn_step_ring(cfg, lp, hin, kc[ai],
                                                   vc[ai], kpos[ai], pos)
                new_k.append(k2)
                new_v.append(v2)
                new_kp.append(kp2)
                ai += 1
            x = x + out
            hmlp = L.rms_norm(lp["mlp_norm"], x[:, None])[:, 0]
            x = x + L.mlp_apply(lp["mlp"], hmlp, cfg.act)
        st = (jnp.stack(new_h), jnp.stack(new_conv), jnp.stack(new_k),
              jnp.stack(new_v), jnp.stack(new_kp))
        return x, st

    x, (hs, convs, ks, vs, kps) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["h"], cache["conv"], cache["k"],
         cache["v"], cache["k_pos"]), unroll=cfg.scan_unroll)
    new_cache = dict(cache, h=hs, conv=convs, k=ks, v=vs, k_pos=kps)

    if "tail" in params:
        def tail_body(x, xs):
            lp, h_st, conv_st = xs
            hin = L.rms_norm(lp["norm"], x[:, None])[:, 0]
            out, hh, cb = _rec_step(cfg, lp, hin, h_st, conv_st)
            x = x + out
            hmlp = L.rms_norm(lp["mlp_norm"], x[:, None])[:, 0]
            return x + L.mlp_apply(lp["mlp"], hmlp, cfg.act), (hh, cb)

        x, (th, tc) = jax.lax.scan(
            tail_body, x, (params["tail"], cache["tail_h"],
                           cache["tail_conv"]))
        new_cache["tail_h"], new_cache["tail_conv"] = th, tc

    x = L.rms_norm(params["final_norm"], x[:, None])[:, 0]
    logits = L.unembed(params["embed"]["table"], x)
    return logits, new_cache
