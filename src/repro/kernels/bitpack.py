"""Bit-pack / unpack Pallas kernels for the 1-Bpp mask uplink.

pack:   (W, 32) {0,1} -> (W,) uint32   (little-endian bit order)
unpack: (W,) uint32   -> (W, 32) uint8

TPU adaptation: GPU implementations use warp ballots; on TPU we pack by
a vectorized shift-OR across the 32-lane minor axis. Blocks are (512,
32): the sublane axis carries words (multiple of 8) while the 32-bit
lanes hold the bits — Mosaic relayouts this to native tiling. The packed
uplink then rides jax.lax.all_gather at 1/16 the bytes of a bf16 psum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(m_ref, o_ref):
    bits = m_ref[...].astype(jnp.uint32)                   # (bw, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 1)
    o_ref[...] = jnp.sum(bits << shifts, axis=1).astype(jnp.uint32)


def _unpack_kernel(w_ref, o_ref):
    words = w_ref[...].astype(jnp.uint32)                  # (bw,)
    shifts = jax.lax.broadcasted_iota(
        jnp.uint32, (words.shape[0], 32), 1)
    o_ref[...] = ((words[:, None] >> shifts)
                  & jnp.uint32(1)).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bw", "interpret"))
def pack_bits(mask_flat: jax.Array, *, bw: int = 512,
              interpret: bool = False) -> jax.Array:
    """mask_flat: (n,) with n % 32 == 0, values in {0,1}. -> (n//32,)
    uint32."""
    assert mask_flat.ndim == 1 and mask_flat.size % 32 == 0
    W = mask_flat.size // 32
    bw_ = min(bw, W)
    while W % bw_:
        bw_ //= 2
    m2 = mask_flat.reshape(W, 32)
    return pl.pallas_call(
        _pack_kernel,
        grid=(W // bw_,),
        in_specs=[pl.BlockSpec((bw_, 32), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bw_,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((W,), jnp.uint32),
        interpret=interpret,
    )(m2)


@functools.partial(jax.jit, static_argnames=("n", "bw", "interpret"))
def unpack_bits(words: jax.Array, n: int, *, bw: int = 512,
                interpret: bool = False) -> jax.Array:
    """words: (W,) uint32 -> (n,) uint8 (n <= 32*W)."""
    W = words.size
    bw_ = min(bw, W)
    while W % bw_:
        bw_ //= 2
    bits = pl.pallas_call(
        _unpack_kernel,
        grid=(W // bw_,),
        in_specs=[pl.BlockSpec((bw_,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bw_, 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, 32), jnp.uint8),
        interpret=interpret,
    )(words)
    return bits.reshape(-1)[:n]
