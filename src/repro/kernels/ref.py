"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hash_uniform(idx: jax.Array, seed) -> jax.Array:
    """Must match masked_matmul._hash_uniform exactly."""
    s = jnp.asarray(seed, jnp.uint32) + jnp.uint32(1)
    s = (s ^ (s >> 16)) * jnp.uint32(0x45D9F3B5)
    s = s ^ (s >> 11)
    x = idx.astype(jnp.uint32) + jnp.uint32(0x9E3779B9) * s
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ s ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def masked_matmul(x, w, s, seed, off=0):
    wm = sample_mask(s, seed, off).astype(jnp.float32) \
        * w.astype(jnp.float32)
    return (x.astype(jnp.float32) @ wm).astype(x.dtype)


def sample_mask(s, seed, off=0):
    """The mask the fused kernel implicitly uses (for uplink packing).
    `off` shifts the flat hash index (layer-stacked leaves)."""
    K, N = s.shape
    idx = (jnp.asarray(off, jnp.uint32)
           + jnp.arange(K, dtype=jnp.uint32)[:, None] * jnp.uint32(N)
           + jnp.arange(N, dtype=jnp.uint32)[None, :])
    u = hash_uniform(idx, seed)
    return (u < jax.nn.sigmoid(s.astype(jnp.float32))).astype(jnp.uint8)


def threshold_mask(s, tau=0.5):
    """The deterministic FedMask mask m = 1[sigmoid(s) > tau]."""
    return (jax.nn.sigmoid(s.astype(jnp.float32))
            > jnp.asarray(tau, jnp.float32)).astype(jnp.uint8)


def masked_matmul_dx(g, w, s, seed, off=0):
    """Oracle for kernels.masked_matmul_dx: dx = g @ (m ⊙ w)ᵀ with the
    mask regenerated from the same hash stream as the forward."""
    m = sample_mask(s, seed, off).astype(jnp.float32)
    wm = m * w.astype(jnp.float32)
    return (g.astype(jnp.float32) @ wm.T).astype(g.dtype)


def masked_matmul_ds(x, g, w, s):
    """Oracle for kernels.masked_matmul_ds: the STE score gradient
    ds = (xᵀ@g) ⊙ w ⊙ σ(s)(1−σ(s))."""
    xg = x.astype(jnp.float32).T @ g.astype(jnp.float32)
    sig = jax.nn.sigmoid(s.astype(jnp.float32))
    return (xg * w.astype(jnp.float32) * sig * (1.0 - sig)).astype(
        s.dtype)


def masked_dense_bwd(x, w, s, seed, g, off=0):
    """The naive (3-temporary) STE backward — ops._bwd's fallback math
    and the benchmark baseline: materializes the mask, the masked
    weights, and xᵀ@g at weight size."""
    K, N = w.shape
    x2 = x.reshape(-1, K)
    g2 = g.reshape(-1, N)
    m = sample_mask(s, seed, off).astype(jnp.float32)
    wf = w.astype(jnp.float32)
    wm = (m * wf).astype(x.dtype)
    dx = (g2 @ wm.T).reshape(x.shape).astype(x.dtype)
    xg = x2.astype(jnp.float32).T @ g2.astype(jnp.float32)
    sig = jax.nn.sigmoid(s.astype(jnp.float32))
    ds = (xg * wf * sig * (1.0 - sig)).astype(s.dtype)
    return dx, ds


def _grouped_mask(s, seeds, offs, mode="sample", tau=0.5):
    if mode == "threshold":
        return jax.vmap(lambda se: threshold_mask(se, tau))(s)
    return jax.vmap(sample_mask)(s, jnp.asarray(seeds, jnp.uint32),
                                 jnp.asarray(offs, jnp.uint32))


def masked_matmul_grouped(x, w, s, seeds, offs, mode="sample", tau=0.5):
    """Oracle for kernels.masked_matmul_grouped: y[e] = x[e] @ (m[e]⊙w[e])
    with group e's mask drawn at flat offset offs[e] of seeds[e]'s
    stream (offs[e] = e*K*N makes the E masks one stacked-leaf stream)."""
    wm = _grouped_mask(s, seeds, offs, mode, tau).astype(jnp.float32) \
        * w.astype(jnp.float32)
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.float32),
                      wm).astype(x.dtype)


def masked_matmul_grouped_dx(g, w, s, seeds, offs, mode="sample",
                             tau=0.5):
    """Oracle for kernels.masked_matmul_grouped_dx:
    dx[e] = g[e] @ (m[e] ⊙ w[e])ᵀ, same per-group streams."""
    wm = _grouped_mask(s, seeds, offs, mode, tau).astype(jnp.float32) \
        * w.astype(jnp.float32)
    return jnp.einsum("emn,ekn->emk", g.astype(jnp.float32),
                      wm).astype(g.dtype)


def masked_matmul_grouped_ds(x, g, w, s):
    """Oracle for kernels.masked_matmul_grouped_ds:
    ds[e] = (x[e]ᵀ@g[e]) ⊙ w[e] ⊙ σ(s[e])(1−σ(s[e]))."""
    xg = jnp.einsum("emk,emn->ekn", x.astype(jnp.float32),
                    g.astype(jnp.float32))
    sig = jax.nn.sigmoid(s.astype(jnp.float32))
    return (xg * w.astype(jnp.float32) * sig * (1.0 - sig)).astype(
        s.dtype)


def masked_dense_grouped_bwd(x, w, s, seeds, offs, g, mode="sample",
                             tau=0.5):
    """The naive grouped STE backward (REPRO_REF_BWD=1 and the
    benchmark baseline): materializes the stacked mask, m⊙w and xᵀ@g
    at full (E, K, N) size."""
    dx = masked_matmul_grouped_dx(g, w, s, seeds, offs, mode, tau)
    ds = masked_matmul_grouped_ds(x, g, w, s)
    return dx, ds


def masked_conv1d(x, w, s, seed, off=0, mode="sample", tau=0.5):
    """Oracle for kernels.masked_conv1d: depthwise causal conv with the
    hash-stream masked (W, C) kernel, accumulated tap-by-tap in the
    SAME order as the Pallas kernel (bit-identical f32 sums).
    x: (B, S, C) unpadded; returns f32 (B, S, C)."""
    Wt = w.shape[0]
    S = x.shape[1]
    m = (threshold_mask(s, tau) if mode == "threshold"
         else sample_mask(s, seed, off))
    wm = (m.astype(w.dtype) * w).astype(jnp.float32)
    xp = jnp.pad(x, ((0, 0), (Wt - 1, 0), (0, 0)))
    out = xp[:, 0:S].astype(jnp.float32) * wm[0]
    for t in range(1, Wt):
        out = out + xp[:, t:t + S].astype(jnp.float32) * wm[t]
    return out


def masked_conv1d_bwd(x, w, s, seed, g, off=0, mode="sample", tau=0.5):
    """Naive STE backward of the masked depthwise causal conv
    (REPRO_REF_BWD=1 escape hatch): dx is the flipped-tap correlation
    of g with m⊙w, ds = (xᵀ★g) ⊙ w ⊙ σ'(s) at kernel size."""
    Wt = w.shape[0]
    S = x.shape[1]
    m = (threshold_mask(s, tau) if mode == "threshold"
         else sample_mask(s, seed, off))
    wm = (m.astype(w.dtype) * w).astype(jnp.float32)
    gp = jnp.pad(g, ((0, 0), (0, Wt - 1), (0, 0))).astype(jnp.float32)
    dx = gp[:, 0:S] * wm[Wt - 1]
    for u in range(1, Wt):
        dx = dx + gp[:, u:u + S] * wm[Wt - 1 - u]
    xp = jnp.pad(x, ((0, 0), (Wt - 1, 0), (0, 0))).astype(jnp.float32)
    gf = g.astype(jnp.float32)
    xg = jnp.stack([jnp.sum(xp[:, t:t + S] * gf, axis=(0, 1))
                    for t in range(Wt)])
    sig = jax.nn.sigmoid(s.astype(jnp.float32))
    ds = (xg * w.astype(jnp.float32) * sig * (1.0 - sig)).astype(s.dtype)
    return dx.astype(x.dtype), ds


def sample_rows(s2, seeds):
    """(C, n) score rows + (C,) seeds -> (C, n) uint8 Bernoulli masks.

    Row c is sampled from the flat-index hash stream with seeds[c] —
    bit-identical to what kernels.sample_and_pack packs."""
    _, n = s2.shape
    idx = jnp.arange(n, dtype=jnp.uint32)

    def one(row, seed):
        u = hash_uniform(idx, seed)
        return (u < jax.nn.sigmoid(row.astype(jnp.float32))).astype(
            jnp.uint8)

    return jax.vmap(one)(s2, jnp.asarray(seeds, jnp.uint32))


def threshold_rows(s2, tau=0.5):
    """(C, n) score rows -> (C, n) uint8 deterministic FedMask masks."""
    return (jax.nn.sigmoid(s2.astype(jnp.float32))
            > jnp.asarray(tau, jnp.float32)).astype(jnp.uint8)


def sample_and_pack(s2, seeds, mode="sample", tau=0.5):
    """Oracle for kernels.sample_and_pack: the two-pass sample-then-pack
    it fuses.  (C, n) scores -> (C, ceil(n/32)) uint32 words."""
    m = (threshold_rows(s2, tau) if mode == "threshold"
         else sample_rows(s2, seeds))
    n = m.shape[1]
    pad = (-n) % 32
    if pad:
        m = jnp.pad(m, ((0, 0), (0, pad)))
    return jax.vmap(pack_bits)(m)


def pack_bits(mask_flat):
    bits = mask_flat.astype(jnp.uint32).reshape(-1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=1).astype(jnp.uint32)


def unpack_bits(words, n):
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(jnp.uint8)
