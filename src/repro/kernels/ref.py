"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hash_uniform(idx: jax.Array, seed) -> jax.Array:
    """Must match masked_matmul._hash_uniform exactly."""
    x = idx.astype(jnp.uint32) + jnp.uint32(0x9E3779B9) * (
        jnp.asarray(seed, jnp.uint32) + jnp.uint32(1))
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def masked_matmul(x, w, s, seed):
    K, N = w.shape
    idx = (jnp.arange(K, dtype=jnp.uint32)[:, None] * jnp.uint32(N)
           + jnp.arange(N, dtype=jnp.uint32)[None, :])
    u = hash_uniform(idx, seed)
    theta = jax.nn.sigmoid(s.astype(jnp.float32))
    m = (u < theta)
    wm = jnp.where(m, w.astype(jnp.float32), 0.0)
    return (x.astype(jnp.float32) @ wm).astype(x.dtype)


def sample_mask(s, seed):
    """The mask the fused kernel implicitly uses (for uplink packing)."""
    K, N = s.shape
    idx = (jnp.arange(K, dtype=jnp.uint32)[:, None] * jnp.uint32(N)
           + jnp.arange(N, dtype=jnp.uint32)[None, :])
    u = hash_uniform(idx, seed)
    return (u < jax.nn.sigmoid(s.astype(jnp.float32))).astype(jnp.uint8)


def pack_bits(mask_flat):
    bits = mask_flat.astype(jnp.uint32).reshape(-1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=1).astype(jnp.uint32)


def unpack_bits(words, n):
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(jnp.uint8)
