"""Fused masked matmul Pallas kernel — the mask-training hot spot.

Computes   y = x @ (m ⊙ w),   m = 1[u < sigmoid(s)],  u = hash(seed, idx)

in ONE pass: tiles of `w` and `s` stream HBM->VMEM once per (k, n) tile,
the Bernoulli mask is formed in VMEM/VREGs from a counter-based hash
(no RNG state, no mask tensor in HBM), the gated tile feeds the MXU.

Naive XLA: materialize sigmoid(s) (f32), u (f32), m*w (bf16) — three
extra weight-sized HBM tensors per step. This kernel eliminates all
three; the weight-HBM traffic drops ~3x and the masked weights never
exist in memory (DESIGN.md §2.1).

The hash is xorshift-multiply (splitmix-like) over the *global* element
index, so the sampled mask is identical regardless of tiling — ref.py
reproduces it with pure jnp for the allclose oracle.

Block shapes default to (128, 512, 512) — MXU-aligned (multiples of
128) and VMEM-safe: bm*bk + 2*bk*bn + bm*bn tiles ≈ 128*512*4B +
2*512*512*(2+4)B + 128*512*4B ≈ 1.9 MB « 16 MB v5e VMEM, leaving room
for double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hash_uniform(idx: jax.Array, seed) -> jax.Array:
    """Counter-based uniform in [0,1): splitmix32-style avalanche of the
    global element index. uint32 ops only (TPU-friendly)."""
    x = idx.astype(jnp.uint32) + jnp.uint32(0x9E3779B9) * (
        jnp.asarray(seed, jnp.uint32) + jnp.uint32(1))
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    # 24-bit mantissa -> [0, 1)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _kernel(x_ref, w_ref, s_ref, seed_ref, o_ref, acc_ref, *,
            bk: int, bn: int, n_total: int, nk: int):
    k_i = pl.program_id(2)

    @pl.when(k_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global element indices of this (bk, bn) tile of w/s
    n_i = pl.program_id(1)
    row0 = k_i * bk
    col0 = n_i * bn
    rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
    cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
    idx = rows * jnp.uint32(n_total) + cols

    u = _hash_uniform(idx, seed_ref[0])
    theta = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))
    m = (u < theta)
    wm = jnp.where(m, w_ref[...].astype(jnp.float32), 0.0)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), wm,
                            preferred_element_type=jnp.float32)

    @pl.when(k_i == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "interpret"))
def masked_matmul(x: jax.Array, w: jax.Array, s: jax.Array,
                  seed: jax.Array, *, bm: int = 128, bn: int = 512,
                  bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (M, K) bf16/f32; w, s: (K, N); seed: scalar uint32.
    Returns (M, N) in x.dtype."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and s.shape == (K, N)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nm, nn, nk = M // bm_, N // bn_, K // bk_

    grid = (nm, nn, nk)
    kernel = functools.partial(_kernel, bk=bk_, bn=bn_, n_total=N, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, w, s, jnp.asarray(seed, jnp.uint32).reshape(1))
