"""Fused masked matmul Pallas kernels — the mask-training hot spot.

Forward:   y  = x @ (m ⊙ w),   m = 1[u < sigmoid(s)],  u = hash(seed, idx)

in ONE pass: tiles of `w` and `s` stream HBM->VMEM once per (k, n) tile,
the Bernoulli mask is formed in VMEM/VREGs from a counter-based hash
(no RNG state, no mask tensor in HBM), the gated tile feeds the MXU.

Backward (STE, see ops.py): two more kernels with the same property —

  masked_matmul_dx:  dx = g @ (m ⊙ w)ᵀ     mask regenerated per tile
                                            from the SAME hash stream,
                                            bit-identical to the forward
  masked_matmul_ds:  ds = (xᵀ@g) ⊙ w ⊙ σ(s)(1−σ(s))
                                            the (K,N)-sized xᵀ@g product
                                            and the sigmoid never leave
                                            VMEM

and a fused uplink sampler —

  sample_and_pack:   scores -> hash -> Bernoulli -> packed uint32 words
                     in one pass (replaces sample-then-pack_bits, which
                     materialized the full uint8 mask in HBM).

Naive XLA: materialize sigmoid(s) (f32), u (f32), m*w (bf16) — three
extra weight-sized HBM tensors per step, and the backward repeats all
three plus xᵀ@g. These kernels eliminate every weight-sized temporary;
benchmarks/kernels_bench.py asserts the structural win by counting
weight-shaped f32 definitions in the lowered HLO.

The hash is xorshift-multiply (splitmix-like) over the *global* element
index, so the sampled mask is identical regardless of tiling — ref.py
reproduces it with pure jnp for the allclose oracle.  `n_logical` lets a
caller zero-pad operands to MXU alignment while keeping the hash indexed
by the LOGICAL column count, so padded and unpadded launches sample
bit-identical masks (padding columns carry w == 0 and contribute
nothing).  The `off` operand shifts the flat hash index: a layer-stacked
(L, K, N) leaf sampled through per-layer kernel launches with
off = l*K*N draws exactly the bits `sample_and_pack` packs for the full
flattened leaf — the model-forward masks and the uplink stream are one
stream (docs/DESIGN.md §3).

`mode="threshold"` swaps the Bernoulli draw for the deterministic
FedMask predicate m = 1[sigmoid(s) > tau] (tau rides as a runtime
scalar operand, so no retrace per tau); the hash/seed/off operands are
ignored in that mode.

Block shapes default to (128, 512, 512) — MXU-aligned (multiples of
128) and VMEM-safe: bm*bk + 2*bk*bn + bm*bn tiles ≈ 128*512*4B +
2*512*512*(2+4)B + 128*512*4B ≈ 1.9 MB « 16 MB v5e VMEM, leaving room
for double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hash_uniform(idx: jax.Array, seed) -> jax.Array:
    """Counter-based uniform in [0,1): splitmix32-style avalanche of the
    global element index. uint32 ops only (TPU-friendly).

    The seed is avalanched separately and injected a second time in the
    middle of the pipeline, so two seeds never yield index-shifted
    copies of one stream (a purely additive seed would: stream offsets
    only ~8M apart would overlap for >8M-element leaves)."""
    s = jnp.asarray(seed, jnp.uint32) + jnp.uint32(1)
    s = (s ^ (s >> 16)) * jnp.uint32(0x45D9F3B5)
    s = s ^ (s >> 11)
    x = idx.astype(jnp.uint32) + jnp.uint32(0x9E3779B9) * s
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ s ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    # 24-bit mantissa -> [0, 1)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _tile_mask(s_ref, seed_ref, off_ref, tau_ref, *, row0, col0,
               bk: int, bn: int, n_total: int, mode: str):
    """Bernoulli (hash-stream) or threshold mask for one (bk, bn) tile."""
    theta = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))
    if mode == "threshold":
        return theta > tau_ref[0]
    rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
    cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
    idx = off_ref[0] + rows * jnp.uint32(n_total) + cols
    return _hash_uniform(idx, seed_ref[0]) < theta


def _kernel(x_ref, w_ref, s_ref, seed_ref, off_ref, tau_ref, o_ref,
            acc_ref, *, bk: int, bn: int, n_total: int, nk: int,
            mode: str):
    k_i = pl.program_id(2)

    @pl.when(k_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global element indices of this (bk, bn) tile of w/s
    n_i = pl.program_id(1)
    m = _tile_mask(s_ref, seed_ref, off_ref, tau_ref,
                   row0=k_i * jnp.uint32(bk), col0=n_i * jnp.uint32(bn),
                   bk=bk, bn=bn, n_total=n_total, mode=mode)
    wm = jnp.where(m, w_ref[...].astype(jnp.float32), 0.0)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), wm,
                            preferred_element_type=jnp.float32)

    @pl.when(k_i == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _scalar_operands(seed, off, tau):
    return (jnp.asarray(seed, jnp.uint32).reshape(1),
            jnp.asarray(off, jnp.uint32).reshape(1),
            jnp.asarray(tau, jnp.float32).reshape(1))


_SCALAR_SPECS = [pl.BlockSpec((1,), lambda i, j, k: (0,))] * 3


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "n_logical", "interpret",
                                             "mode"))
def masked_matmul(x: jax.Array, w: jax.Array, s: jax.Array,
                  seed: jax.Array, off: jax.Array = 0, *, bm: int = 128,
                  bn: int = 512, bk: int = 512,
                  n_logical: int | None = None, interpret: bool = False,
                  mode: str = "sample", tau: jax.Array = 0.5
                  ) -> jax.Array:
    """x: (M, K) bf16/f32; w, s: (K, N); seed/off: scalar uint32.
    Returns (M, N) in x.dtype.  `n_logical` overrides the column count
    used for the hash index (for zero-padded launches); `off` shifts the
    flat hash index (layer-stacked leaves).  `mode="threshold"` uses the
    deterministic m = 1[sigmoid(s) > tau] mask instead of the hash."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and s.shape == (K, N)
    n_total = N if n_logical is None else n_logical
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nm, nn, nk = M // bm_, N // bn_, K // bk_

    grid = (nm, nn, nk)
    kernel = functools.partial(_kernel, bk=bk_, bn=bn_, n_total=n_total,
                               nk=nk, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ] + _SCALAR_SPECS,
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, w, s, *_scalar_operands(seed, off, tau))


# ---------------------------------------------------------------------------
# Fused STE backward: dx = g @ (m*w)^T, mask regenerated per (k, n) tile
# ---------------------------------------------------------------------------


def _dx_kernel(g_ref, w_ref, s_ref, seed_ref, off_ref, tau_ref, o_ref,
               acc_ref, *, bk: int, bn: int, n_total: int, nn: int,
               mode: str):
    n_i = pl.program_id(2)

    @pl.when(n_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global element indices of this (bk, bn) tile of w/s — the same
    # row-major flat index the forward kernel hashes, so the regenerated
    # mask is bit-identical to the forward sample
    k_i = pl.program_id(1)
    m = _tile_mask(s_ref, seed_ref, off_ref, tau_ref,
                   row0=k_i * jnp.uint32(bk), col0=n_i * jnp.uint32(bn),
                   bk=bk, bn=bn, n_total=n_total, mode=mode)
    wm = jnp.where(m, w_ref[...].astype(jnp.float32), 0.0)   # (bk, bn)
    # contract over the n axis: (bm, bn) x (bk, bn) -> (bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...].astype(jnp.float32), wm,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n_i == nn - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "n_logical", "interpret",
                                             "mode"))
def masked_matmul_dx(g: jax.Array, w: jax.Array, s: jax.Array,
                     seed: jax.Array, off: jax.Array = 0, *,
                     bm: int = 128, bn: int = 512, bk: int = 512,
                     n_logical: int | None = None,
                     interpret: bool = False, mode: str = "sample",
                     tau: jax.Array = 0.5) -> jax.Array:
    """g: (M, N) upstream cotangent; w, s: (K, N).  Returns
    dx = g @ (m ⊙ w)ᵀ : (M, K) in g.dtype.

    The transposed access pattern gets its own grid/BlockSpec layout
    (accumulation runs over the n axis, innermost), not a reuse of the
    forward grid.  `off`/`mode`/`tau` as in `masked_matmul` — the
    regenerated mask is bit-identical to the forward's.
    """
    M, N = g.shape
    K, N2 = w.shape
    assert N == N2 and s.shape == (K, N)
    n_total = N if n_logical is None else n_logical
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nm, nk, nn = M // bm_, K // bk_, N // bn_

    grid = (nm, nk, nn)
    kernel = functools.partial(_dx_kernel, bk=bk_, bn=bn_,
                               n_total=n_total, nn=nn, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, k, n: (i, n)),
            pl.BlockSpec((bk_, bn_), lambda i, k, n: (k, n)),
            pl.BlockSpec((bk_, bn_), lambda i, k, n: (k, n)),
        ] + _SCALAR_SPECS,
        out_specs=pl.BlockSpec((bm_, bk_), lambda i, k, n: (i, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bk_), jnp.float32)],
        interpret=interpret,
    )(g, w, s, *_scalar_operands(seed, off, tau))


# ---------------------------------------------------------------------------
# Fused STE backward: ds = (x^T @ g) * w * sigmoid'(s), single pass
# ---------------------------------------------------------------------------


def _ds_kernel(x_ref, g_ref, w_ref, s_ref, o_ref, acc_ref, *, nm: int):
    m_i = pl.program_id(2)

    @pl.when(m_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # contract over the batch axis: (bm, bk) x (bm, bn) -> (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), g_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(m_i == nm - 1)
    def _():
        # elementwise epilogue in VMEM: neither x^T@g nor the sigmoid
        # ever exist at weight size in HBM
        sig = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))
        o_ref[...] = (acc_ref[...] * w_ref[...].astype(jnp.float32)
                      * sig * (1.0 - sig)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "interpret"))
def masked_matmul_ds(x: jax.Array, g: jax.Array, w: jax.Array,
                     s: jax.Array, *, bm: int = 128, bn: int = 512,
                     bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (M, K); g: (M, N); w, s: (K, N).  Returns the STE score
    gradient ds = (xᵀ@g) ⊙ w ⊙ σ(s)(1−σ(s)) : (K, N) in s.dtype."""
    M, K = x.shape
    M2, N = g.shape
    assert M == M2 and w.shape == (K, N) and s.shape == (K, N)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nk, nn, nm = K // bk_, N // bn_, M // bm_

    grid = (nk, nn, nm)
    kernel = functools.partial(_ds_kernel, nm=nm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda k, n, m: (m, k)),
            pl.BlockSpec((bm_, bn_), lambda k, n, m: (m, n)),
            pl.BlockSpec((bk_, bn_), lambda k, n, m: (k, n)),
            pl.BlockSpec((bk_, bn_), lambda k, n, m: (k, n)),
        ],
        out_specs=pl.BlockSpec((bk_, bn_), lambda k, n, m: (k, n)),
        out_shape=jax.ShapeDtypeStruct((K, N), s.dtype),
        scratch_shapes=[pltpu.VMEM((bk_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, g, w, s)


# ---------------------------------------------------------------------------
# Fused uplink sampler: scores -> Bernoulli bits -> packed uint32 words
# ---------------------------------------------------------------------------


def _sap_kernel(s_ref, seed_ref, o_ref, *, bw: int, n_total: int,
                mode: str, tau: float):
    i = pl.program_id(1)
    # word/lane coordinates of this (1, bw, 32) tile; bit j of word wi
    # carries flat element wi*32 + j (little-endian, matching pack_bits)
    words = i * bw + jax.lax.broadcasted_iota(jnp.uint32, (1, bw, 32), 1)
    lanes = jax.lax.broadcasted_iota(jnp.uint32, (1, bw, 32), 2)
    idx = (words * jnp.uint32(32) + lanes).astype(jnp.uint32)

    theta = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))
    if mode == "threshold":
        m = theta > jnp.float32(tau)
    else:
        m = _hash_uniform(idx, seed_ref[0]) < theta
    # padding bits (idx >= n_total) are forced to zero so the packed
    # words match pack_bits(pad_to_words(mask)) exactly
    m = m & (idx < jnp.uint32(n_total))
    bits = m.astype(jnp.uint32) << lanes
    o_ref[...] = jnp.sum(bits, axis=2).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bw", "interpret", "mode",
                                             "tau"))
def sample_and_pack(s: jax.Array, seeds: jax.Array, *, bw: int = 256,
                    interpret: bool = False, mode: str = "sample",
                    tau: float = 0.5) -> jax.Array:
    """s: (C, n) score rows; seeds: (C,) uint32 per-row stream seeds.
    Returns (C, W) uint32 with W = ceil(n/32): the bit-packed Bernoulli
    mask m = 1[hash_u(idx) < sigmoid(s)] of every row, sampled and
    packed in one pass (bits past n are zero, as pad_to_words pads).
    `mode="threshold"` packs the deterministic FedMask mask
    m = 1[sigmoid(s) > tau] instead (seeds are ignored)."""
    C, n = s.shape
    assert seeds.shape == (C,), (seeds.shape, C)
    W = (n + 31) // 32
    # prefer a block that divides W exactly: real leaves (dims multiples
    # of 8) give highly composite W, so no score-sized pad copy is made;
    # only degenerate W (no divisor >= 8) falls back to rounding W up,
    # where the jnp.pad copy is cheaper than a near-unit-block grid
    b = min(bw, W)
    while W % b:
        b //= 2
    if b >= 8 or b == W:
        bw_, Wp = b, W
    else:
        bw_ = min(bw, W)
        Wp = -(-W // bw_) * bw_
    pad = Wp * 32 - n
    sp = jnp.pad(s, ((0, 0), (0, pad))) if pad else s
    s3 = sp.reshape(C, Wp, 32)
    kernel = functools.partial(_sap_kernel, bw=bw_, n_total=n,
                               mode=mode, tau=tau)
    out = pl.pallas_call(
        kernel,
        grid=(C, Wp // bw_),
        in_specs=[
            pl.BlockSpec((1, bw_, 32), lambda c, i: (c, i, 0)),
            pl.BlockSpec((1,), lambda c, i: (c,)),
        ],
        out_specs=pl.BlockSpec((1, bw_), lambda c, i: (c, i)),
        out_shape=jax.ShapeDtypeStruct((C, Wp), jnp.uint32),
        interpret=interpret,
    )(s3, jnp.asarray(seeds, jnp.uint32))
    return out[:, :W]
