"""Fused masked matmul Pallas kernels — the mask-training hot spot.

Forward:   y  = x @ (m ⊙ w),   m = 1[u < sigmoid(s)],  u = hash(seed, idx)

in ONE pass: tiles of `w` and `s` stream HBM->VMEM once per (k, n) tile,
the Bernoulli mask is formed in VMEM/VREGs from a counter-based hash
(no RNG state, no mask tensor in HBM), the gated tile feeds the MXU.

Backward (STE, see ops.py): two more kernels with the same property —

  masked_matmul_dx:  dx = g @ (m ⊙ w)ᵀ     mask regenerated per tile
                                            from the SAME hash stream,
                                            bit-identical to the forward
  masked_matmul_ds:  ds = (xᵀ@g) ⊙ w ⊙ σ(s)(1−σ(s))
                                            the (K,N)-sized xᵀ@g product
                                            and the sigmoid never leave
                                            VMEM

and a fused uplink sampler —

  sample_and_pack:   scores -> hash -> Bernoulli -> packed uint32 words
                     in one pass (replaces sample-then-pack_bits, which
                     materialized the full uint8 mask in HBM).

The GROUPED family extends the same discipline to stacked (E, K, N)
leaves (MoE expert weights): `masked_matmul_grouped` (+ dx/ds) runs one
pallas_call for all E groups — the expert index rides the grid and each
group carries its own `seed`/`off` scalar operands, so group e's mask
is drawn at flat offset e*K*N of the leaf's uplink stream.  The CONV
family (`masked_conv1d`, `masked_conv1d_ds`) covers the depthwise
causal (W, C) kernel leaves (mamba2 / recurrentgemma frontends), where
the W-tap reduction is elementwise per channel and unrolled in-kernel;
`mode="plain"` is the mask-free twin the reference path runs on
pre-materialized weights, keeping both paths instruction-identical
(bit-equal f32 sums under FMA fusion).

Naive XLA: materialize sigmoid(s) (f32), u (f32), m*w (bf16) — three
extra weight-sized HBM tensors per step, and the backward repeats all
three plus xᵀ@g. These kernels eliminate every weight-sized temporary;
benchmarks/kernels_bench.py asserts the structural win by counting
weight-shaped f32 definitions in the lowered HLO.

The hash is xorshift-multiply (splitmix-like) over the *global* element
index, so the sampled mask is identical regardless of tiling — ref.py
reproduces it with pure jnp for the allclose oracle.  `n_logical` lets a
caller zero-pad operands to MXU alignment while keeping the hash indexed
by the LOGICAL column count, so padded and unpadded launches sample
bit-identical masks (padding columns carry w == 0 and contribute
nothing).  The `off` operand shifts the flat hash index: a layer-stacked
(L, K, N) leaf sampled through per-layer kernel launches with
off = l*K*N draws exactly the bits `sample_and_pack` packs for the full
flattened leaf — the model-forward masks and the uplink stream are one
stream (docs/DESIGN.md §3).

`mode="threshold"` swaps the Bernoulli draw for the deterministic
FedMask predicate m = 1[sigmoid(s) > tau] (tau rides as a runtime
scalar operand, so no retrace per tau); the hash/seed/off operands are
ignored in that mode.

Block shapes default to (128, 512, 512) — MXU-aligned (multiples of
128) and VMEM-safe: bm*bk + 2*bk*bn + bm*bn tiles ≈ 128*512*4B +
2*512*512*(2+4)B + 128*512*4B ≈ 1.9 MB « 16 MB v5e VMEM, leaving room
for double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hash_uniform(idx: jax.Array, seed) -> jax.Array:
    """Counter-based uniform in [0,1): splitmix32-style avalanche of the
    global element index. uint32 ops only (TPU-friendly).

    The seed is avalanched separately and injected a second time in the
    middle of the pipeline, so two seeds never yield index-shifted
    copies of one stream (a purely additive seed would: stream offsets
    only ~8M apart would overlap for >8M-element leaves)."""
    s = jnp.asarray(seed, jnp.uint32) + jnp.uint32(1)
    s = (s ^ (s >> 16)) * jnp.uint32(0x45D9F3B5)
    s = s ^ (s >> 11)
    x = idx.astype(jnp.uint32) + jnp.uint32(0x9E3779B9) * s
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ s ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    # 24-bit mantissa -> [0, 1)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _tile_mask_vals(s_tile, seed, off, tau, *, row0, col0,
                    n_total: int, mode: str):
    """Bernoulli (hash-stream) or threshold mask for one 2-D score tile
    (the value-level core shared by the dense, grouped, and conv
    kernels; `seed`/`off`/`tau` are scalars already read from refs)."""
    theta = jax.nn.sigmoid(s_tile.astype(jnp.float32))
    if mode == "threshold":
        return theta > tau
    bk, bn = s_tile.shape
    rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
    cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
    idx = off + rows * jnp.uint32(n_total) + cols
    return _hash_uniform(idx, seed) < theta


def _tile_mask(s_ref, seed_ref, off_ref, tau_ref, *, row0, col0,
               bk: int, bn: int, n_total: int, mode: str):
    """Bernoulli (hash-stream) or threshold mask for one (bk, bn) tile."""
    del bk, bn  # implied by the ref block shape
    return _tile_mask_vals(s_ref[...], seed_ref[0], off_ref[0],
                           tau_ref[0], row0=row0, col0=col0,
                           n_total=n_total, mode=mode)


def _kernel(x_ref, w_ref, s_ref, seed_ref, off_ref, tau_ref, o_ref,
            acc_ref, *, bk: int, bn: int, n_total: int, nk: int,
            mode: str):
    k_i = pl.program_id(2)

    @pl.when(k_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global element indices of this (bk, bn) tile of w/s
    n_i = pl.program_id(1)
    m = _tile_mask(s_ref, seed_ref, off_ref, tau_ref,
                   row0=k_i * jnp.uint32(bk), col0=n_i * jnp.uint32(bn),
                   bk=bk, bn=bn, n_total=n_total, mode=mode)
    wm = jnp.where(m, w_ref[...].astype(jnp.float32), 0.0)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), wm,
                            preferred_element_type=jnp.float32)

    @pl.when(k_i == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _scalar_operands(seed, off, tau):
    return (jnp.asarray(seed, jnp.uint32).reshape(1),
            jnp.asarray(off, jnp.uint32).reshape(1),
            jnp.asarray(tau, jnp.float32).reshape(1))


_SCALAR_SPECS = [pl.BlockSpec((1,), lambda i, j, k: (0,))] * 3


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "n_logical", "interpret",
                                             "mode"))
def masked_matmul(x: jax.Array, w: jax.Array, s: jax.Array,
                  seed: jax.Array, off: jax.Array = 0, *, bm: int = 128,
                  bn: int = 512, bk: int = 512,
                  n_logical: int | None = None, interpret: bool = False,
                  mode: str = "sample", tau: jax.Array = 0.5
                  ) -> jax.Array:
    """x: (M, K) bf16/f32; w, s: (K, N); seed/off: scalar uint32.
    Returns (M, N) in x.dtype.  `n_logical` overrides the column count
    used for the hash index (for zero-padded launches); `off` shifts the
    flat hash index (layer-stacked leaves).  `mode="threshold"` uses the
    deterministic m = 1[sigmoid(s) > tau] mask instead of the hash."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and s.shape == (K, N)
    n_total = N if n_logical is None else n_logical
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nm, nn, nk = M // bm_, N // bn_, K // bk_

    grid = (nm, nn, nk)
    kernel = functools.partial(_kernel, bk=bk_, bn=bn_, n_total=n_total,
                               nk=nk, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ] + _SCALAR_SPECS,
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, w, s, *_scalar_operands(seed, off, tau))


# ---------------------------------------------------------------------------
# Fused STE backward: dx = g @ (m*w)^T, mask regenerated per (k, n) tile
# ---------------------------------------------------------------------------


def _dx_kernel(g_ref, w_ref, s_ref, seed_ref, off_ref, tau_ref, o_ref,
               acc_ref, *, bk: int, bn: int, n_total: int, nn: int,
               mode: str):
    n_i = pl.program_id(2)

    @pl.when(n_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global element indices of this (bk, bn) tile of w/s — the same
    # row-major flat index the forward kernel hashes, so the regenerated
    # mask is bit-identical to the forward sample
    k_i = pl.program_id(1)
    m = _tile_mask(s_ref, seed_ref, off_ref, tau_ref,
                   row0=k_i * jnp.uint32(bk), col0=n_i * jnp.uint32(bn),
                   bk=bk, bn=bn, n_total=n_total, mode=mode)
    wm = jnp.where(m, w_ref[...].astype(jnp.float32), 0.0)   # (bk, bn)
    # contract over the n axis: (bm, bn) x (bk, bn) -> (bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...].astype(jnp.float32), wm,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n_i == nn - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "n_logical", "interpret",
                                             "mode"))
def masked_matmul_dx(g: jax.Array, w: jax.Array, s: jax.Array,
                     seed: jax.Array, off: jax.Array = 0, *,
                     bm: int = 128, bn: int = 512, bk: int = 512,
                     n_logical: int | None = None,
                     interpret: bool = False, mode: str = "sample",
                     tau: jax.Array = 0.5) -> jax.Array:
    """g: (M, N) upstream cotangent; w, s: (K, N).  Returns
    dx = g @ (m ⊙ w)ᵀ : (M, K) in g.dtype.

    The transposed access pattern gets its own grid/BlockSpec layout
    (accumulation runs over the n axis, innermost), not a reuse of the
    forward grid.  `off`/`mode`/`tau` as in `masked_matmul` — the
    regenerated mask is bit-identical to the forward's.
    """
    M, N = g.shape
    K, N2 = w.shape
    assert N == N2 and s.shape == (K, N)
    n_total = N if n_logical is None else n_logical
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nm, nk, nn = M // bm_, K // bk_, N // bn_

    grid = (nm, nk, nn)
    kernel = functools.partial(_dx_kernel, bk=bk_, bn=bn_,
                               n_total=n_total, nn=nn, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, k, n: (i, n)),
            pl.BlockSpec((bk_, bn_), lambda i, k, n: (k, n)),
            pl.BlockSpec((bk_, bn_), lambda i, k, n: (k, n)),
        ] + _SCALAR_SPECS,
        out_specs=pl.BlockSpec((bm_, bk_), lambda i, k, n: (i, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bk_), jnp.float32)],
        interpret=interpret,
    )(g, w, s, *_scalar_operands(seed, off, tau))


# ---------------------------------------------------------------------------
# Fused STE backward: ds = (x^T @ g) * w * sigmoid'(s), single pass
# ---------------------------------------------------------------------------


def _ds_kernel(x_ref, g_ref, w_ref, s_ref, o_ref, acc_ref, *, nm: int):
    m_i = pl.program_id(2)

    @pl.when(m_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # contract over the batch axis: (bm, bk) x (bm, bn) -> (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), g_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(m_i == nm - 1)
    def _():
        # elementwise epilogue in VMEM: neither x^T@g nor the sigmoid
        # ever exist at weight size in HBM
        sig = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))
        o_ref[...] = (acc_ref[...] * w_ref[...].astype(jnp.float32)
                      * sig * (1.0 - sig)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "interpret"))
def masked_matmul_ds(x: jax.Array, g: jax.Array, w: jax.Array,
                     s: jax.Array, *, bm: int = 128, bn: int = 512,
                     bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (M, K); g: (M, N); w, s: (K, N).  Returns the STE score
    gradient ds = (xᵀ@g) ⊙ w ⊙ σ(s)(1−σ(s)) : (K, N) in s.dtype."""
    M, K = x.shape
    M2, N = g.shape
    assert M == M2 and w.shape == (K, N) and s.shape == (K, N)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nk, nn, nm = K // bk_, N // bn_, M // bm_

    grid = (nk, nn, nm)
    kernel = functools.partial(_ds_kernel, nm=nm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda k, n, m: (m, k)),
            pl.BlockSpec((bm_, bn_), lambda k, n, m: (m, n)),
            pl.BlockSpec((bk_, bn_), lambda k, n, m: (k, n)),
            pl.BlockSpec((bk_, bn_), lambda k, n, m: (k, n)),
        ],
        out_specs=pl.BlockSpec((bk_, bn_), lambda k, n, m: (k, n)),
        out_shape=jax.ShapeDtypeStruct((K, N), s.dtype),
        scratch_shapes=[pltpu.VMEM((bk_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, g, w, s)


# ---------------------------------------------------------------------------
# Fused uplink sampler: scores -> Bernoulli bits -> packed uint32 words
# ---------------------------------------------------------------------------


def _sap_kernel(s_ref, seed_ref, o_ref, *, bw: int, n_total: int,
                mode: str, tau: float):
    i = pl.program_id(1)
    # word/lane coordinates of this (1, bw, 32) tile; bit j of word wi
    # carries flat element wi*32 + j (little-endian, matching pack_bits)
    words = i * bw + jax.lax.broadcasted_iota(jnp.uint32, (1, bw, 32), 1)
    lanes = jax.lax.broadcasted_iota(jnp.uint32, (1, bw, 32), 2)
    idx = (words * jnp.uint32(32) + lanes).astype(jnp.uint32)

    theta = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))
    if mode == "threshold":
        m = theta > jnp.float32(tau)
    else:
        m = _hash_uniform(idx, seed_ref[0]) < theta
    # padding bits (idx >= n_total) are forced to zero so the packed
    # words match pack_bits(pad_to_words(mask)) exactly
    m = m & (idx < jnp.uint32(n_total))
    bits = m.astype(jnp.uint32) << lanes
    o_ref[...] = jnp.sum(bits, axis=2).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bw", "interpret", "mode",
                                             "tau"))
def sample_and_pack(s: jax.Array, seeds: jax.Array, *, bw: int = 256,
                    interpret: bool = False, mode: str = "sample",
                    tau: float = 0.5) -> jax.Array:
    """s: (C, n) score rows; seeds: (C,) uint32 per-row stream seeds.
    Returns (C, W) uint32 with W = ceil(n/32): the bit-packed Bernoulli
    mask m = 1[hash_u(idx) < sigmoid(s)] of every row, sampled and
    packed in one pass (bits past n are zero, as pad_to_words pads).
    `mode="threshold"` packs the deterministic FedMask mask
    m = 1[sigmoid(s) > tau] instead (seeds are ignored)."""
    C, n = s.shape
    assert seeds.shape == (C,), (seeds.shape, C)
    W = (n + 31) // 32
    # prefer a block that divides W exactly: real leaves (dims multiples
    # of 8) give highly composite W, so no score-sized pad copy is made;
    # only degenerate W (no divisor >= 8) falls back to rounding W up,
    # where the jnp.pad copy is cheaper than a near-unit-block grid
    b = min(bw, W)
    while W % b:
        b //= 2
    if b >= 8 or b == W:
        bw_, Wp = b, W
    else:
        bw_ = min(bw, W)
        Wp = -(-W // bw_) * bw_
    pad = Wp * 32 - n
    sp = jnp.pad(s, ((0, 0), (0, pad))) if pad else s
    s3 = sp.reshape(C, Wp, 32)
    kernel = functools.partial(_sap_kernel, bw=bw_, n_total=n,
                               mode=mode, tau=tau)
    out = pl.pallas_call(
        kernel,
        grid=(C, Wp // bw_),
        in_specs=[
            pl.BlockSpec((1, bw_, 32), lambda c, i: (c, i, 0)),
            pl.BlockSpec((1,), lambda c, i: (c,)),
        ],
        out_specs=pl.BlockSpec((1, bw_), lambda c, i: (c, i)),
        out_shape=jax.ShapeDtypeStruct((C, Wp), jnp.uint32),
        interpret=interpret,
    )(s3, jnp.asarray(seeds, jnp.uint32))
    return out[:, :W]


# ---------------------------------------------------------------------------
# Grouped masked matmul: y[e] = x[e] @ (m[e] ⊙ w[e]) for stacked weights
# ---------------------------------------------------------------------------
#
# The group/expert index rides the grid (leading axis, block size 1) and
# each group carries its OWN `seed`/`off` scalar operand, so group e's
# mask is exactly its slice of the stacked leaf's flat hash stream
# (off[e] = e*K*N under the `MaskedLeaf.build` convention).  This is how
# MoE expert einsums ride the zero-weight-temporary invariant: one
# pallas_call for all E experts, no (E, K, N) m⊙w tensor in HBM.


def _grp_operands(seeds, offs, tau):
    return (jnp.asarray(seeds, jnp.uint32).reshape(-1),
            jnp.asarray(offs, jnp.uint32).reshape(-1),
            jnp.asarray(tau, jnp.float32).reshape(1))


def _g_kernel(x_ref, w_ref, s_ref, seed_ref, off_ref, tau_ref, o_ref,
              acc_ref, *, bk: int, bn: int, n_total: int, nk: int,
              mode: str):
    k_i = pl.program_id(3)

    @pl.when(k_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_i = pl.program_id(2)
    m = _tile_mask_vals(s_ref[0], seed_ref[0], off_ref[0], tau_ref[0],
                        row0=k_i * jnp.uint32(bk),
                        col0=n_i * jnp.uint32(bn),
                        n_total=n_total, mode=mode)
    wm = jnp.where(m, w_ref[0].astype(jnp.float32), 0.0)
    acc_ref[...] += jnp.dot(x_ref[0].astype(jnp.float32), wm,
                            preferred_element_type=jnp.float32)

    @pl.when(k_i == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "n_logical", "interpret",
                                             "mode"))
def masked_matmul_grouped(x: jax.Array, w: jax.Array, s: jax.Array,
                          seeds: jax.Array, offs: jax.Array, *,
                          bm: int = 128, bn: int = 512, bk: int = 512,
                          n_logical: int | None = None,
                          interpret: bool = False, mode: str = "sample",
                          tau: jax.Array = 0.5) -> jax.Array:
    """x: (E, M, K); w, s: (E, K, N); seeds, offs: (E,) uint32 per-group
    hash-stream coordinates.  Returns (E, M, N) in x.dtype: one
    pallas_call computing y[e] = x[e] @ (m[e] ⊙ w[e]) with group e's
    mask drawn at flat index offs[e] + row*n_total + col — exactly the
    slice `sample_and_pack` packs for the stacked leaf when
    offs[e] = e*K*N.  `mode="threshold"` as in `masked_matmul`."""
    E, M, K = x.shape
    assert w.shape == (E, K, s.shape[-1]) and s.shape == w.shape, \
        (x.shape, w.shape, s.shape)
    N = w.shape[-1]
    n_total = N if n_logical is None else n_logical
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nm, nn, nk = M // bm_, N // bn_, K // bk_

    kernel = functools.partial(_g_kernel, bk=bk_, bn=bn_,
                               n_total=n_total, nk=nk, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(E, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk_, bn_), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bk_, bn_), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1,), lambda e, i, j, k: (e,)),
            pl.BlockSpec((1,), lambda e, i, j, k: (e,)),
            pl.BlockSpec((1,), lambda e, i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, w, s, *_grp_operands(seeds, offs, tau))


def _g_dx_kernel(g_ref, w_ref, s_ref, seed_ref, off_ref, tau_ref, o_ref,
                 acc_ref, *, bk: int, bn: int, n_total: int, nn: int,
                 mode: str):
    n_i = pl.program_id(3)

    @pl.when(n_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_i = pl.program_id(2)
    m = _tile_mask_vals(s_ref[0], seed_ref[0], off_ref[0], tau_ref[0],
                        row0=k_i * jnp.uint32(bk),
                        col0=n_i * jnp.uint32(bn),
                        n_total=n_total, mode=mode)
    wm = jnp.where(m, w_ref[0].astype(jnp.float32), 0.0)   # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[0].astype(jnp.float32), wm,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n_i == nn - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "n_logical", "interpret",
                                             "mode"))
def masked_matmul_grouped_dx(g: jax.Array, w: jax.Array, s: jax.Array,
                             seeds: jax.Array, offs: jax.Array, *,
                             bm: int = 128, bn: int = 512,
                             bk: int = 512, n_logical: int | None = None,
                             interpret: bool = False,
                             mode: str = "sample",
                             tau: jax.Array = 0.5) -> jax.Array:
    """g: (E, M, N) upstream cotangent; w, s: (E, K, N).  Returns
    dx[e] = g[e] @ (m[e] ⊙ w[e])ᵀ : (E, M, K) in g.dtype, masks
    bit-identical to the grouped forward's (same per-group stream)."""
    E, M, N = g.shape
    K = w.shape[1]
    assert w.shape == (E, K, N) and s.shape == (E, K, N)
    n_total = N if n_logical is None else n_logical
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nm, nk, nn = M // bm_, K // bk_, N // bn_

    kernel = functools.partial(_g_dx_kernel, bk=bk_, bn=bn_,
                               n_total=n_total, nn=nn, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(E, nm, nk, nn),
        in_specs=[
            pl.BlockSpec((1, bm_, bn_), lambda e, i, k, n: (e, i, n)),
            pl.BlockSpec((1, bk_, bn_), lambda e, i, k, n: (e, k, n)),
            pl.BlockSpec((1, bk_, bn_), lambda e, i, k, n: (e, k, n)),
            pl.BlockSpec((1,), lambda e, i, k, n: (e,)),
            pl.BlockSpec((1,), lambda e, i, k, n: (e,)),
            pl.BlockSpec((1,), lambda e, i, k, n: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bk_), lambda e, i, k, n: (e, i, k)),
        out_shape=jax.ShapeDtypeStruct((E, M, K), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bk_), jnp.float32)],
        interpret=interpret,
    )(g, w, s, *_grp_operands(seeds, offs, tau))


def _g_ds_kernel(x_ref, g_ref, w_ref, s_ref, o_ref, acc_ref, *,
                 nm: int):
    m_i = pl.program_id(3)

    @pl.when(m_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), g_ref[0].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(m_i == nm - 1)
    def _():
        sig = jax.nn.sigmoid(s_ref[0].astype(jnp.float32))
        o_ref[...] = (acc_ref[...] * w_ref[0].astype(jnp.float32)
                      * sig * (1.0 - sig)).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "interpret"))
def masked_matmul_grouped_ds(x: jax.Array, g: jax.Array, w: jax.Array,
                             s: jax.Array, *, bm: int = 128,
                             bn: int = 512, bk: int = 512,
                             interpret: bool = False) -> jax.Array:
    """x: (E, M, K); g: (E, M, N); w, s: (E, K, N).  Returns the STE
    score gradient ds[e] = (x[e]ᵀ@g[e]) ⊙ w[e] ⊙ σ(s[e])(1−σ(s[e])) :
    (E, K, N) in s.dtype, epilogue fused in VMEM per group."""
    E, M, K = x.shape
    N = g.shape[-1]
    assert g.shape == (E, M, N) and w.shape == (E, K, N) \
        and s.shape == (E, K, N)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, \
        (M, N, K, bm_, bn_, bk_)
    nk, nn, nm = K // bk_, N // bn_, M // bm_

    kernel = functools.partial(_g_ds_kernel, nm=nm)
    return pl.pallas_call(
        kernel,
        grid=(E, nk, nn, nm),
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda e, k, n, m: (e, m, k)),
            pl.BlockSpec((1, bm_, bn_), lambda e, k, n, m: (e, m, n)),
            pl.BlockSpec((1, bk_, bn_), lambda e, k, n, m: (e, k, n)),
            pl.BlockSpec((1, bk_, bn_), lambda e, k, n, m: (e, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bk_, bn_), lambda e, k, n, m: (e, k, n)),
        out_shape=jax.ShapeDtypeStruct((E, K, N), s.dtype),
        scratch_shapes=[pltpu.VMEM((bk_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, g, w, s)


# ---------------------------------------------------------------------------
# Masked depthwise causal conv: the (W, C) kernel leaf, fully fused
# ---------------------------------------------------------------------------
#
# A depthwise conv is elementwise per channel, so it cannot ride the
# matmul kernels; this kernel family extends the same hash-stream
# discipline to it.  The W-tap reduction is unrolled in-kernel over a
# (S, bc) activation tile (W is 4ish), the (W, bc) mask tile is drawn
# from flat index off + w_row*n_total + col — the leaf's uplink stream —
# and neither the mask nor m⊙w ever exists in HBM.  The `flip` variant
# reverses the tap order, which turns the forward correlation into the
# dL/dx transposed correlation with the SAME regenerated mask.


def _conv_kernel(x_ref, w_ref, s_ref, seed_ref, off_ref, tau_ref, o_ref,
                 *, Wt: int, S: int, n_total: int, mode: str,
                 flip: bool):
    if mode == "plain":
        # mask-free twin for pre-materialized weights (the reference
        # path): the SAME tap loop, so fused and reference convs are
        # instruction-identical (bit-equal f32 sums under FMA fusion)
        wm = w_ref[...].astype(jnp.float32)                 # (Wt, bc)
    else:
        j = pl.program_id(1)
        bc = w_ref.shape[-1]
        m = _tile_mask_vals(s_ref[...], seed_ref[0], off_ref[0],
                            tau_ref[0], row0=jnp.uint32(0),
                            col0=j * jnp.uint32(bc),
                            n_total=n_total, mode=mode)
        wm = jnp.where(m, w_ref[...].astype(jnp.float32), 0.0)
    acc = None
    for t in range(Wt):
        row = Wt - 1 - t if flip else t
        term = x_ref[0, t:t + S, :].astype(jnp.float32) \
            * wm[row][None, :]
        acc = term if acc is None else acc + term
    o_ref[...] = acc.astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("bc", "n_logical",
                                             "interpret", "mode",
                                             "flip"))
def masked_conv1d(x_pad: jax.Array, w: jax.Array, s: jax.Array,
                  seed: jax.Array, off: jax.Array = 0, *, bc: int = 128,
                  n_logical: int | None = None, interpret: bool = False,
                  mode: str = "sample", tau: jax.Array = 0.5,
                  flip: bool = False) -> jax.Array:
    """x_pad: (B, S + W - 1, C) causally padded input; w, s: (W, C)
    depthwise kernel/scores.  Returns f32 (B, S, C):
    y[b,s,c] = Σ_t x_pad[b,s+t,c] · (m ⊙ w)[t,c], the mask drawn at
    flat index off + t*n_total + c (the leaf's uplink stream).
    `flip=True` reverses the tap order (wm[W-1-t] at shift t) — the
    dL/dx correlation of the causal conv, same mask."""
    B, Sp, C = x_pad.shape
    Wt, C2 = w.shape
    assert C == C2 and s.shape == (Wt, C)
    S = Sp - Wt + 1
    n_total = C if n_logical is None else n_logical
    bc_ = min(bc, C)
    assert C % bc_ == 0, (C, bc_)
    kernel = functools.partial(_conv_kernel, Wt=Wt, S=S,
                               n_total=n_total, mode=mode, flip=flip)
    return pl.pallas_call(
        kernel,
        grid=(B, C // bc_),
        in_specs=[
            pl.BlockSpec((1, Sp, bc_), lambda b, j: (b, 0, j)),
            pl.BlockSpec((Wt, bc_), lambda b, j: (0, j)),
            pl.BlockSpec((Wt, bc_), lambda b, j: (0, j)),
        ] + [pl.BlockSpec((1,), lambda b, j: (0,))] * 3,
        out_specs=pl.BlockSpec((1, S, bc_), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), jnp.float32),
        interpret=interpret,
    )(x_pad, w, s, *_scalar_operands(seed, off, tau))


def _conv_ds_kernel(x_ref, g_ref, w_ref, s_ref, o_ref, acc_ref, *,
                    Wt: int, S: int, nb: int, epilogue: str):
    b_i = pl.program_id(1)

    @pl.when(b_i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gv = g_ref[0].astype(jnp.float32)                  # (S, bc)
    acc_ref[...] += jnp.concatenate(
        [jnp.sum(x_ref[0, t:t + S, :].astype(jnp.float32) * gv,
                 axis=0, keepdims=True) for t in range(Wt)], axis=0)

    @pl.when(b_i == nb - 1)
    def _():
        if epilogue == "dw":
            # raw xᵀ★g: the weight gradient of the PLAIN conv (float
            # baselines training the materialized kernel directly)
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)
        else:
            sig = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))
            o_ref[...] = (acc_ref[...] * w_ref[...].astype(jnp.float32)
                          * sig * (1.0 - sig)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "interpret",
                                             "epilogue"))
def masked_conv1d_ds(x_pad: jax.Array, g: jax.Array, w: jax.Array,
                     s: jax.Array, *, bc: int = 128,
                     interpret: bool = False,
                     epilogue: str = "ste") -> jax.Array:
    """x_pad: (B, S + W - 1, C); g: (B, S, C) cotangent; w, s: (W, C).
    Returns the STE score gradient
    ds[t,c] = (Σ_{b,s} x_pad[b,s+t,c] g[b,s,c]) ⊙ w ⊙ σ(s)(1−σ(s)) :
    (W, C) in s.dtype — the xᵀg correlation and the sigmoid epilogue
    never leave VMEM.  `epilogue="dw"` skips the STE epilogue and
    returns the raw correlation (the plain conv's weight gradient)."""
    B, Sp, C = x_pad.shape
    Wt, C2 = w.shape
    S = Sp - Wt + 1
    assert C == C2 and s.shape == (Wt, C) and g.shape == (B, S, C)
    bc_ = min(bc, C)
    assert C % bc_ == 0, (C, bc_)
    kernel = functools.partial(_conv_ds_kernel, Wt=Wt, S=S, nb=B,
                               epilogue=epilogue)
    return pl.pallas_call(
        kernel,
        grid=(C // bc_, B),
        in_specs=[
            pl.BlockSpec((1, Sp, bc_), lambda j, b: (b, 0, j)),
            pl.BlockSpec((1, S, bc_), lambda j, b: (b, 0, j)),
            pl.BlockSpec((Wt, bc_), lambda j, b: (0, j)),
            pl.BlockSpec((Wt, bc_), lambda j, b: (0, j)),
        ],
        out_specs=pl.BlockSpec((Wt, bc_), lambda j, b: (0, j)),
        out_shape=jax.ShapeDtypeStruct((Wt, C), s.dtype),
        scratch_shapes=[pltpu.VMEM((Wt, bc_), jnp.float32)],
        interpret=interpret,
    )(x_pad, g, w, s)
