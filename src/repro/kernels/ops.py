"""Jit'd public wrappers around the Pallas kernels.

`masked_dense` is the drop-in for the mask-training forward on a Dense
layer, with the STE custom-vjp.  Forward AND backward run fused:

    y     = x @ (m*w)                        [masked_matmul]
    dL/dx = g @ (m*w)^T                      [masked_matmul_dx]
    dL/ds = (x^T @ g) * w * sigmoid'(s)      [masked_matmul_ds]

The mask is never materialized in HBM on either pass: the backward
regenerates it per tile from the same counter-based hash stream as the
forward (bit-identical — asserted in tests/test_kernels.py).  The `off`
argument shifts the flat hash index so a layer-stacked (L, K, N) leaf
executed as L per-layer launches (off = l*K*N) samples exactly the
stream `sample_and_pack` packs for the flattened leaf — this is how the
model zoo's `MaskedLeaf` execution path (repro.models.layers) and the
uplink share one stream (docs/DESIGN.md §3).

`masked_dense_threshold` is the deterministic FedMask twin: the mask is
m = 1[sigmoid(s) > tau] (no hash), same STE backward, same fusion.

`masked_dense_grouped` (+ `_threshold`) is the stacked-leaf twin for
(E, K, N) MoE expert weights: ONE grouped pallas_call per projection
covers all E experts with per-group seed/off stream coordinates
(offs[e] = e*K*N under the `MaskedLeaf.build` convention), so the
stacked m⊙w never exists in HBM either.  `masked_conv1d`
(+ `_threshold`) covers the depthwise causal (W, C) conv kernel leaves,
and `conv1d_plain` is its mask-free twin for pre-materialized weights —
the reference path runs it so fused and materialized convs are
instruction-identical (bit-equal), and neither builds the old
(B, S, W, C) stacked-views tensor.

MXU-unaligned shapes are zero-padded up to lane (128) alignment before
the kernel launch instead of silently falling back to the jnp reference:
the hash is indexed by the LOGICAL column count (`n_logical`), so the
padded launch samples exactly the same mask, and padded columns carry
w == 0 so they contribute nothing.

`sample_and_pack` fuses the per-round uplink sampling with the 32->1
bitpack (scores -> hash -> Bernoulli -> uint32 words in one pass).

Environment knobs (documented in README "Execution paths"):
  * REPRO_REF_BWD=1        — naive jnp STE backward (debug baseline)
  * REPRO_FORCE_INTERPRET=1 — pin Pallas interpret mode (CI determinism)
  * REPRO_EFF_PATH=1       — read by repro.launch.steps: train through
    materialized effective params instead of the fused kernels

On non-TPU backends (this CPU container) the wrappers call the kernels
in interpret mode — selected once per process by `_use_interpret()`.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import masked_matmul as _mm
from repro.kernels import bitpack as _bp
from repro.kernels import ref


def repro_backend() -> str:
    return jax.default_backend()


@functools.lru_cache(maxsize=1)
def _use_interpret() -> bool:
    """Cached per process: `jax.default_backend()` walks the backend
    registry, which is pure overhead when re-queried inside every jit
    trace.  `REPRO_FORCE_INTERPRET=1` pins interpret mode regardless of
    backend (CI determinism)."""
    if os.environ.get("REPRO_FORCE_INTERPRET", "") == "1":
        return True
    return repro_backend() != "tpu"


def reset_backend_cache() -> None:
    """Drop the cached `_use_interpret()` decision so a mid-process
    flip of `REPRO_FORCE_INTERPRET` (or a swapped backend) takes
    effect — without this the flip is silently ignored for the rest of
    the process.  Call it from any test/bench fixture that toggles the
    knob (tests/conftest.py `kernel_backend_reset`,
    benchmarks/kernels_bench.py main)."""
    _use_interpret.cache_clear()


def pack_bits(mask_flat: jax.Array) -> jax.Array:
    if mask_flat.size % 32:
        pad = 32 - mask_flat.size % 32
        mask_flat = jnp.concatenate(
            [mask_flat, jnp.zeros((pad,), mask_flat.dtype)])
    return _bp.pack_bits(mask_flat, interpret=_use_interpret())


def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    return _bp.unpack_bits(words, n, interpret=_use_interpret())


def sample_and_pack(scores: jax.Array, seeds: jax.Array,
                    mode: str = "sample", tau: float = 0.5) -> jax.Array:
    """Fused uplink sampler: (C, n) score rows + (C,) uint32 seeds ->
    (C, ceil(n/32)) uint32 words of m ~ Bern(sigmoid(scores)).

    One kernel pass replaces the sample-then-pack_bits two-pass; the
    full uint8 mask never exists in HBM.  `ref.sample_rows` /
    `ref.sample_and_pack` are the bit-exact jnp oracles.
    `mode="threshold"` packs m = 1[sigmoid(scores) > tau] (FedMask).
    """
    return _mm.sample_and_pack(scores, seeds, interpret=_use_interpret(),
                               mode=mode, tau=tau)


# ---------------------------------------------------------------------------
# Padding to MXU alignment (keeps the hash indexed by logical shape)
# ---------------------------------------------------------------------------


def _round_up(d: int, m: int) -> int:
    return -(-d // m) * m


def _block_for(dp: int) -> int:
    """Largest MXU-friendly block (multiple of 128, <= 512) dividing the
    padded dim."""
    for b in (512, 256, 128):
        if dp % b == 0:
            return b
    raise AssertionError(dp)  # dp is always a multiple of 128


def _pad2(a: jax.Array, r: int, c: int) -> jax.Array:
    pr, pc = r - a.shape[0], c - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _fused_fwd(x, w, s, seed, off, tau, mode):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    M = x2.shape[0]
    K, N = w.shape
    Mp, Kp, Np = (_round_up(M, 128), _round_up(K, 128),
                  _round_up(N, 128))
    y = _mm.masked_matmul(
        _pad2(x2, Mp, Kp), _pad2(w, Kp, Np), _pad2(s, Kp, Np), seed,
        off, bm=128, bn=_block_for(Np), bk=_block_for(Kp), n_logical=N,
        interpret=_use_interpret(), mode=mode, tau=tau)[:M, :N]
    return y.reshape(shape[:-1] + (N,))


def _fused_bwd(x, w, s, seed, off, tau, mode, g):
    K, N = w.shape
    if os.environ.get("REPRO_REF_BWD", "") == "1":
        if mode == "threshold":
            m = ref.threshold_mask(s, tau).astype(jnp.float32)
            wf = w.astype(jnp.float32)
            g2 = g.reshape(-1, N)
            dx = (g2 @ (m * wf).T).reshape(x.shape).astype(x.dtype)
            ds = ref.masked_matmul_ds(x.reshape(-1, K), g2, w, s)
            return dx, ds
        return ref.masked_dense_bwd(x, w, s, seed, g, off)
    x2 = x.reshape(-1, K)
    g2 = g.reshape(-1, N)
    M = x2.shape[0]
    Mp, Kp, Np = (_round_up(M, 128), _round_up(K, 128),
                  _round_up(N, 128))
    bn, bk = _block_for(Np), _block_for(Kp)
    interp = _use_interpret()
    xp, gp = _pad2(x2, Mp, Kp), _pad2(g2, Mp, Np)
    wp, sp = _pad2(w, Kp, Np), _pad2(s, Kp, Np)
    dx = _mm.masked_matmul_dx(gp, wp, sp, seed, off, bm=128, bn=bn,
                              bk=bk, n_logical=N, interpret=interp,
                              mode=mode, tau=tau)[:M, :K]
    ds = _mm.masked_matmul_ds(xp, gp, wp, sp, bm=128, bn=bn, bk=bk,
                              interpret=interp)[:K, :N]
    return (dx.reshape(x.shape).astype(x.dtype), ds.astype(s.dtype))


@jax.custom_vjp
def _masked_dense(x, w, s, seed, off):
    return _fused_fwd(x, w, s, seed, off, 0.5, "sample")


def _md_fwd(x, w, s, seed, off):
    return _masked_dense(x, w, s, seed, off), (x, w, s, seed, off)


def _md_bwd(res, g):
    x, w, s, seed, off = res
    dx, ds = _fused_bwd(x, w, s, seed, off, 0.5, "sample", g)
    return dx, None, ds, None, None


_masked_dense.defvjp(_md_fwd, _md_bwd)


@jax.custom_vjp
def _masked_dense_thr(x, w, s, tau):
    return _fused_fwd(x, w, s, 0, 0, tau, "threshold")


def _mdt_fwd(x, w, s, tau):
    return _masked_dense_thr(x, w, s, tau), (x, w, s, tau)


def _mdt_bwd(res, g):
    x, w, s, tau = res
    dx, ds = _fused_bwd(x, w, s, 0, 0, tau, "threshold", g)
    return dx, None, ds, None


_masked_dense_thr.defvjp(_mdt_fwd, _mdt_bwd)


def masked_dense(x, w, s, seed, off=0):
    """y = x @ (bern(sigmoid(s); seed) * w), STE backward. x: (..., K).

    `off` shifts the flat hash index: per-layer launches over a stacked
    (L, K, N) leaf pass off = l*K*N so the L masks together are exactly
    the leaf's flat `sample_and_pack` stream under the same seed.
    """
    return _masked_dense(x, w, s, jnp.asarray(seed, jnp.uint32),
                         jnp.asarray(off, jnp.uint32))


def masked_dense_threshold(x, w, s, tau=0.5):
    """y = x @ (1[sigmoid(s) > tau] * w), STE backward (FedMask mode).

    Deterministic twin of `masked_dense`: no hash stream, same fused
    kernels and the same ds epilogue (STE passes d m/d theta := 1
    through the threshold exactly as through the Bernoulli sample).
    """
    return _masked_dense_thr(x, w, s, jnp.asarray(tau, jnp.float32))


# ---------------------------------------------------------------------------
# Grouped masked dense: stacked (E, K, N) weights, one kernel launch
# ---------------------------------------------------------------------------


def _pad3(a: jax.Array, m: int, k: int) -> jax.Array:
    pm, pk = m - a.shape[1], k - a.shape[2]
    if pm == 0 and pk == 0:
        return a
    return jnp.pad(a, ((0, 0), (0, pm), (0, pk)))


def _grp_fused_fwd(x, w, s, seeds, offs, tau, mode):
    shape = x.shape
    E = shape[0]
    x3 = x.reshape(E, -1, shape[-1])
    M = x3.shape[1]
    K, N = w.shape[-2:]
    Mp, Kp, Np = (_round_up(M, 128), _round_up(K, 128),
                  _round_up(N, 128))
    y = _mm.masked_matmul_grouped(
        _pad3(x3, Mp, Kp), _pad3(w, Kp, Np), _pad3(s, Kp, Np), seeds,
        offs, bm=128, bn=_block_for(Np), bk=_block_for(Kp), n_logical=N,
        interpret=_use_interpret(), mode=mode, tau=tau)[:, :M, :N]
    return y.reshape(shape[:-1] + (N,))


def _grp_fused_bwd(x, w, s, seeds, offs, tau, mode, g):
    E = x.shape[0]
    K, N = w.shape[-2:]
    if os.environ.get("REPRO_REF_BWD", "") == "1":
        x3 = x.reshape(E, -1, K)
        g3 = g.reshape(E, -1, N)
        dx, ds = ref.masked_dense_grouped_bwd(x3, w, s, seeds, offs, g3,
                                              mode, tau)
        return dx.reshape(x.shape).astype(x.dtype), ds
    x3 = x.reshape(E, -1, K)
    g3 = g.reshape(E, -1, N)
    M = x3.shape[1]
    Mp, Kp, Np = (_round_up(M, 128), _round_up(K, 128),
                  _round_up(N, 128))
    bn, bk = _block_for(Np), _block_for(Kp)
    interp = _use_interpret()
    xp, gp = _pad3(x3, Mp, Kp), _pad3(g3, Mp, Np)
    wp, sp = _pad3(w, Kp, Np), _pad3(s, Kp, Np)
    dx = _mm.masked_matmul_grouped_dx(
        gp, wp, sp, seeds, offs, bm=128, bn=bn, bk=bk, n_logical=N,
        interpret=interp, mode=mode, tau=tau)[:, :M, :K]
    ds = _mm.masked_matmul_grouped_ds(
        xp, gp, wp, sp, bm=128, bn=bn, bk=bk, interpret=interp)[:, :K, :N]
    return (dx.reshape(x.shape).astype(x.dtype), ds.astype(s.dtype))


@jax.custom_vjp
def _masked_dense_grouped(x, w, s, seeds, offs):
    return _grp_fused_fwd(x, w, s, seeds, offs, 0.5, "sample")


def _mdg_fwd(x, w, s, seeds, offs):
    return (_masked_dense_grouped(x, w, s, seeds, offs),
            (x, w, s, seeds, offs))


def _mdg_bwd(res, g):
    x, w, s, seeds, offs = res
    dx, ds = _grp_fused_bwd(x, w, s, seeds, offs, 0.5, "sample", g)
    return dx, None, ds, None, None


_masked_dense_grouped.defvjp(_mdg_fwd, _mdg_bwd)


@jax.custom_vjp
def _masked_dense_grouped_thr(x, w, s, tau):
    E = x.shape[0]
    zeros = jnp.zeros((E,), jnp.uint32)
    return _grp_fused_fwd(x, w, s, zeros, zeros, tau, "threshold")


def _mdgt_fwd(x, w, s, tau):
    return _masked_dense_grouped_thr(x, w, s, tau), (x, w, s, tau)


def _mdgt_bwd(res, g):
    x, w, s, tau = res
    E = x.shape[0]
    zeros = jnp.zeros((E,), jnp.uint32)
    dx, ds = _grp_fused_bwd(x, w, s, zeros, zeros, tau, "threshold", g)
    return dx, None, ds, None


_masked_dense_grouped_thr.defvjp(_mdgt_fwd, _mdgt_bwd)


def masked_dense_grouped(x, w, s, seeds, offs=None):
    """y[e] = x[e] @ (bern(sigmoid(s[e]); seeds[e], offs[e]) * w[e]) for
    stacked (E, K, N) weights, STE backward.  x: (E, ..., K).

    One `pallas_call` covers all E groups (the expert index rides the
    grid) with per-group `seeds`/`offs` stream coordinates: under the
    `MaskedLeaf.build` convention (offs[e] = e*K*N, one seed) the E
    masks together are exactly the stacked leaf's flat
    `sample_and_pack` stream.  MXU-unaligned M/K/N are zero-padded with
    the hash indexed by the logical column count, as in `masked_dense`.
    """
    E = x.shape[0]
    seeds = jnp.broadcast_to(jnp.asarray(seeds, jnp.uint32), (E,))
    if offs is None:
        K, N = w.shape[-2:]
        offs = jnp.arange(E, dtype=jnp.uint32) * jnp.uint32(K * N)
    offs = jnp.broadcast_to(jnp.asarray(offs, jnp.uint32), (E,))
    return _masked_dense_grouped(x, w, s, seeds, offs)


def masked_dense_grouped_threshold(x, w, s, tau=0.5):
    """y[e] = x[e] @ (1[sigmoid(s[e]) > tau] * w[e]) for stacked
    (E, K, N) weights, STE backward (FedMask mode; no hash stream)."""
    return _masked_dense_grouped_thr(x, w, s,
                                     jnp.asarray(tau, jnp.float32))


# ---------------------------------------------------------------------------
# Masked depthwise causal conv: the (W, C) kernel leaf, fully fused
# ---------------------------------------------------------------------------


def _conv_pads(w):
    Wt, C = w.shape
    Cp = _round_up(C, 128)
    return Wt, C, Cp, min(_block_for(Cp), 128)


def _conv_fused_fwd(x, w, s, seed, off, tau, mode):
    B, S, C = x.shape
    Wt, _, Cp, bc = _conv_pads(w)
    xp = jnp.pad(x, ((0, 0), (Wt - 1, 0), (0, Cp - C)))
    wp, sp = _pad2(w, Wt, Cp), _pad2(s, Wt, Cp)
    y = _mm.masked_conv1d(xp, wp, sp, seed, off, bc=bc, n_logical=C,
                          interpret=_use_interpret(), mode=mode,
                          tau=tau)
    return y[:, :, :C]


def _conv_fused_bwd(x, w, s, seed, off, tau, mode, g):
    if os.environ.get("REPRO_REF_BWD", "") == "1":
        return ref.masked_conv1d_bwd(x, w, s, seed, g, off, mode, tau)
    B, S, C = x.shape
    Wt, _, Cp, bc = _conv_pads(w)
    interp = _use_interpret()
    wp, sp = _pad2(w, Wt, Cp), _pad2(s, Wt, Cp)
    # dL/dx: correlation of g with the flipped masked taps — the same
    # kernel with trailing (instead of leading) zero padding
    gp = jnp.pad(g, ((0, 0), (0, Wt - 1), (0, Cp - C)))
    dx = _mm.masked_conv1d(gp, wp, sp, seed, off, bc=bc, n_logical=C,
                           interpret=interp, mode=mode, tau=tau,
                           flip=True)[:, :, :C]
    xp = jnp.pad(x, ((0, 0), (Wt - 1, 0), (0, Cp - C)))
    gp2 = jnp.pad(g, ((0, 0), (0, 0), (0, Cp - C)))
    ds = _mm.masked_conv1d_ds(xp, gp2, wp, sp, bc=bc,
                              interpret=interp)[:, :C]
    return dx.astype(x.dtype), ds.astype(s.dtype)


@jax.custom_vjp
def _masked_conv1d(x, w, s, seed, off):
    return _conv_fused_fwd(x, w, s, seed, off, 0.5, "sample")


def _mc_fwd(x, w, s, seed, off):
    return _masked_conv1d(x, w, s, seed, off), (x, w, s, seed, off)


def _mc_bwd(res, g):
    x, w, s, seed, off = res
    dx, ds = _conv_fused_bwd(x, w, s, seed, off, 0.5, "sample", g)
    return dx, None, ds, None, None


_masked_conv1d.defvjp(_mc_fwd, _mc_bwd)


@jax.custom_vjp
def _masked_conv1d_thr(x, w, s, tau):
    return _conv_fused_fwd(x, w, s, 0, 0, tau, "threshold")


def _mct_fwd(x, w, s, tau):
    return _masked_conv1d_thr(x, w, s, tau), (x, w, s, tau)


def _mct_bwd(res, g):
    x, w, s, tau = res
    dx, ds = _conv_fused_bwd(x, w, s, 0, 0, tau, "threshold", g)
    return dx, None, ds, None


_masked_conv1d_thr.defvjp(_mct_fwd, _mct_bwd)


def masked_conv1d(x, w, s, seed, off=0):
    """Depthwise causal conv through the masked (W, C) kernel leaf:
    y[b,s,c] = Σ_t x[b, s+t-(W-1), c] · (m ⊙ w)[t,c], STE backward.
    x: (B, S, C); returns f32 (B, S, C) (bias/cast stay with the
    caller).  The mask is drawn at flat index off + t*C + c — the
    leaf's uplink `sample_and_pack` stream — and is regenerated
    per-tile on both passes; m⊙w never exists in HBM."""
    return _masked_conv1d(x, w, s, jnp.asarray(seed, jnp.uint32),
                          jnp.asarray(off, jnp.uint32))


def masked_conv1d_threshold(x, w, s, tau=0.5):
    """Deterministic FedMask twin of `masked_conv1d`:
    m = 1[sigmoid(s) > tau], same fused kernels and STE backward."""
    return _masked_conv1d_thr(x, w, s, jnp.asarray(tau, jnp.float32))


@jax.custom_vjp
def conv1d_plain(x, w):
    """Depthwise causal conv with a PLAIN (pre-materialized) (W, C)
    kernel, through the same Pallas tap loop as `masked_conv1d` — so
    the reference path (effective params) and the fused masked path
    are instruction-identical and their f32 sums bit-equal.  Replaces
    the old (B, S, W, C) stacked-shifted-views einsum (a W× activation
    blowup).  x: (B, S, C); returns f32 (B, S, C).

    Float baselines also land here, which on non-TPU backends means
    interpret-mode emulation — a deliberate trade: depthwise convs are
    a sliver of model FLOPs (W ≈ 4 taps vs d² matmuls), non-TPU runs
    are smoke-scale, and the payoff is that the fused-vs-materialized
    path equivalence stays bit-exact on every backend."""
    B, S, C = x.shape
    Wt, _, Cp, bc = _conv_pads(w)
    xp = jnp.pad(x, ((0, 0), (Wt - 1, 0), (0, Cp - C)))
    wp = _pad2(w, Wt, Cp)
    # wp doubles as the (unread) score operand: plain mode never
    # touches s_ref, so no extra weight-sized tensor is shipped
    return _mm.masked_conv1d(xp, wp, wp, 0, 0, bc=bc, n_logical=C,
                             interpret=_use_interpret(),
                             mode="plain")[:, :, :C]


def _cp_fwd(x, w):
    return conv1d_plain(x, w), (x, w)


def _cp_bwd(res, g):
    x, w = res
    B, S, C = x.shape
    Wt, _, Cp, bc = _conv_pads(w)
    interp = _use_interpret()
    wp = _pad2(w, Wt, Cp)
    gp = jnp.pad(g, ((0, 0), (0, Wt - 1), (0, Cp - C)))
    dx = _mm.masked_conv1d(gp, wp, wp, 0, 0, bc=bc, n_logical=C,
                           interpret=interp, mode="plain",
                           flip=True)[:, :, :C]
    xp = jnp.pad(x, ((0, 0), (Wt - 1, 0), (0, Cp - C)))
    gp2 = jnp.pad(g, ((0, 0), (0, 0), (0, Cp - C)))
    dw = _mm.masked_conv1d_ds(xp, gp2, wp, wp, bc=bc, interpret=interp,
                              epilogue="dw")[:, :C]
    return dx.astype(x.dtype), dw.astype(w.dtype)


conv1d_plain.defvjp(_cp_fwd, _cp_bwd)
