"""Jit'd public wrappers around the Pallas kernels.

`masked_dense` is the drop-in for the mask-training forward on a Dense
layer, with the STE custom-vjp.  Forward AND backward run fused:

    y     = x @ (m*w)                        [masked_matmul]
    dL/dx = g @ (m*w)^T                      [masked_matmul_dx]
    dL/ds = (x^T @ g) * w * sigmoid'(s)      [masked_matmul_ds]

The mask is never materialized in HBM on either pass: the backward
regenerates it per tile from the same counter-based hash stream as the
forward (bit-identical — asserted in tests/test_kernels.py).  The `off`
argument shifts the flat hash index so a layer-stacked (L, K, N) leaf
executed as L per-layer launches (off = l*K*N) samples exactly the
stream `sample_and_pack` packs for the flattened leaf — this is how the
model zoo's `MaskedLeaf` execution path (repro.models.layers) and the
uplink share one stream (docs/DESIGN.md §3).

`masked_dense_threshold` is the deterministic FedMask twin: the mask is
m = 1[sigmoid(s) > tau] (no hash), same STE backward, same fusion.

MXU-unaligned shapes are zero-padded up to lane (128) alignment before
the kernel launch instead of silently falling back to the jnp reference:
the hash is indexed by the LOGICAL column count (`n_logical`), so the
padded launch samples exactly the same mask, and padded columns carry
w == 0 so they contribute nothing.

`sample_and_pack` fuses the per-round uplink sampling with the 32->1
bitpack (scores -> hash -> Bernoulli -> uint32 words in one pass).

Environment knobs (documented in README "Execution paths"):
  * REPRO_REF_BWD=1        — naive jnp STE backward (debug baseline)
  * REPRO_FORCE_INTERPRET=1 — pin Pallas interpret mode (CI determinism)
  * REPRO_EFF_PATH=1       — read by repro.launch.steps: train through
    materialized effective params instead of the fused kernels

On non-TPU backends (this CPU container) the wrappers call the kernels
in interpret mode — selected once per process by `_use_interpret()`.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import masked_matmul as _mm
from repro.kernels import bitpack as _bp
from repro.kernels import ref


def repro_backend() -> str:
    return jax.default_backend()


@functools.lru_cache(maxsize=1)
def _use_interpret() -> bool:
    """Cached per process: `jax.default_backend()` walks the backend
    registry, which is pure overhead when re-queried inside every jit
    trace.  `REPRO_FORCE_INTERPRET=1` pins interpret mode regardless of
    backend (CI determinism)."""
    if os.environ.get("REPRO_FORCE_INTERPRET", "") == "1":
        return True
    return repro_backend() != "tpu"


def pack_bits(mask_flat: jax.Array) -> jax.Array:
    if mask_flat.size % 32:
        pad = 32 - mask_flat.size % 32
        mask_flat = jnp.concatenate(
            [mask_flat, jnp.zeros((pad,), mask_flat.dtype)])
    return _bp.pack_bits(mask_flat, interpret=_use_interpret())


def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    return _bp.unpack_bits(words, n, interpret=_use_interpret())


def sample_and_pack(scores: jax.Array, seeds: jax.Array,
                    mode: str = "sample", tau: float = 0.5) -> jax.Array:
    """Fused uplink sampler: (C, n) score rows + (C,) uint32 seeds ->
    (C, ceil(n/32)) uint32 words of m ~ Bern(sigmoid(scores)).

    One kernel pass replaces the sample-then-pack_bits two-pass; the
    full uint8 mask never exists in HBM.  `ref.sample_rows` /
    `ref.sample_and_pack` are the bit-exact jnp oracles.
    `mode="threshold"` packs m = 1[sigmoid(scores) > tau] (FedMask).
    """
    return _mm.sample_and_pack(scores, seeds, interpret=_use_interpret(),
                               mode=mode, tau=tau)


# ---------------------------------------------------------------------------
# Padding to MXU alignment (keeps the hash indexed by logical shape)
# ---------------------------------------------------------------------------


def _round_up(d: int, m: int) -> int:
    return -(-d // m) * m


def _block_for(dp: int) -> int:
    """Largest MXU-friendly block (multiple of 128, <= 512) dividing the
    padded dim."""
    for b in (512, 256, 128):
        if dp % b == 0:
            return b
    raise AssertionError(dp)  # dp is always a multiple of 128


def _pad2(a: jax.Array, r: int, c: int) -> jax.Array:
    pr, pc = r - a.shape[0], c - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _fused_fwd(x, w, s, seed, off, tau, mode):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    M = x2.shape[0]
    K, N = w.shape
    Mp, Kp, Np = (_round_up(M, 128), _round_up(K, 128),
                  _round_up(N, 128))
    y = _mm.masked_matmul(
        _pad2(x2, Mp, Kp), _pad2(w, Kp, Np), _pad2(s, Kp, Np), seed,
        off, bm=128, bn=_block_for(Np), bk=_block_for(Kp), n_logical=N,
        interpret=_use_interpret(), mode=mode, tau=tau)[:M, :N]
    return y.reshape(shape[:-1] + (N,))


def _fused_bwd(x, w, s, seed, off, tau, mode, g):
    K, N = w.shape
    if os.environ.get("REPRO_REF_BWD", "") == "1":
        if mode == "threshold":
            m = ref.threshold_mask(s, tau).astype(jnp.float32)
            wf = w.astype(jnp.float32)
            g2 = g.reshape(-1, N)
            dx = (g2 @ (m * wf).T).reshape(x.shape).astype(x.dtype)
            ds = ref.masked_matmul_ds(x.reshape(-1, K), g2, w, s)
            return dx, ds
        return ref.masked_dense_bwd(x, w, s, seed, g, off)
    x2 = x.reshape(-1, K)
    g2 = g.reshape(-1, N)
    M = x2.shape[0]
    Mp, Kp, Np = (_round_up(M, 128), _round_up(K, 128),
                  _round_up(N, 128))
    bn, bk = _block_for(Np), _block_for(Kp)
    interp = _use_interpret()
    xp, gp = _pad2(x2, Mp, Kp), _pad2(g2, Mp, Np)
    wp, sp = _pad2(w, Kp, Np), _pad2(s, Kp, Np)
    dx = _mm.masked_matmul_dx(gp, wp, sp, seed, off, bm=128, bn=bn,
                              bk=bk, n_logical=N, interpret=interp,
                              mode=mode, tau=tau)[:M, :K]
    ds = _mm.masked_matmul_ds(xp, gp, wp, sp, bm=128, bn=bn, bk=bk,
                              interpret=interp)[:K, :N]
    return (dx.reshape(x.shape).astype(x.dtype), ds.astype(s.dtype))


@jax.custom_vjp
def _masked_dense(x, w, s, seed, off):
    return _fused_fwd(x, w, s, seed, off, 0.5, "sample")


def _md_fwd(x, w, s, seed, off):
    return _masked_dense(x, w, s, seed, off), (x, w, s, seed, off)


def _md_bwd(res, g):
    x, w, s, seed, off = res
    dx, ds = _fused_bwd(x, w, s, seed, off, 0.5, "sample", g)
    return dx, None, ds, None, None


_masked_dense.defvjp(_md_fwd, _md_bwd)


@jax.custom_vjp
def _masked_dense_thr(x, w, s, tau):
    return _fused_fwd(x, w, s, 0, 0, tau, "threshold")


def _mdt_fwd(x, w, s, tau):
    return _masked_dense_thr(x, w, s, tau), (x, w, s, tau)


def _mdt_bwd(res, g):
    x, w, s, tau = res
    dx, ds = _fused_bwd(x, w, s, 0, 0, tau, "threshold", g)
    return dx, None, ds, None


_masked_dense_thr.defvjp(_mdt_fwd, _mdt_bwd)


def masked_dense(x, w, s, seed, off=0):
    """y = x @ (bern(sigmoid(s); seed) * w), STE backward. x: (..., K).

    `off` shifts the flat hash index: per-layer launches over a stacked
    (L, K, N) leaf pass off = l*K*N so the L masks together are exactly
    the leaf's flat `sample_and_pack` stream under the same seed.
    """
    return _masked_dense(x, w, s, jnp.asarray(seed, jnp.uint32),
                         jnp.asarray(off, jnp.uint32))


def masked_dense_threshold(x, w, s, tau=0.5):
    """y = x @ (1[sigmoid(s) > tau] * w), STE backward (FedMask mode).

    Deterministic twin of `masked_dense`: no hash stream, same fused
    kernels and the same ds epilogue (STE passes d m/d theta := 1
    through the threshold exactly as through the Bernoulli sample).
    """
    return _masked_dense_thr(x, w, s, jnp.asarray(tau, jnp.float32))
