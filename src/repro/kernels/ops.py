"""Jit'd public wrappers around the Pallas kernels.

`masked_dense` is the drop-in for the mask-training forward on a Dense
layer, with the STE custom-vjp: forward uses the fused kernel (never
materializes the masked weights); backward recomputes the mask cheaply
(elementwise) and routes gradients to x and to the scores via STE:

    dL/dx = g @ (m*w)^T
    dL/ds = (x^T @ g) * w * sigmoid'(s)      [STE through the sample]

On non-TPU backends (this CPU container) the wrappers call the kernels
in interpret mode or fall back to ref.py — selected by `repro_backend()`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import masked_matmul as _mm
from repro.kernels import bitpack as _bp
from repro.kernels import ref


def repro_backend() -> str:
    return jax.default_backend()


def _use_interpret() -> bool:
    return repro_backend() != "tpu"


def pack_bits(mask_flat: jax.Array) -> jax.Array:
    if mask_flat.size % 32:
        pad = 32 - mask_flat.size % 32
        mask_flat = jnp.concatenate(
            [mask_flat, jnp.zeros((pad,), mask_flat.dtype)])
    return _bp.pack_bits(mask_flat, interpret=_use_interpret())


def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    return _bp.unpack_bits(words, n, interpret=_use_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def masked_dense(x, w, s, seed):
    """y = x @ (bern(sigmoid(s); seed) * w), STE backward. x: (..., K)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    M = x2.shape[0]
    if M % 128 == 0 and w.shape[0] % 512 == 0 and w.shape[1] % 512 == 0:
        y = _mm.masked_matmul(x2, w, s, seed, interpret=_use_interpret())
    else:
        y = ref.masked_matmul(x2, w, s, seed)
    return y.reshape(shape[:-1] + (w.shape[1],))


def _fwd(x, w, s, seed):
    return masked_dense(x, w, s, seed), (x, w, s, seed)


def _bwd(res, g):
    x, w, s, seed = res
    K, N = w.shape
    x2 = x.reshape(-1, K)
    g2 = g.reshape(-1, N)
    m = ref.sample_mask(s, seed).astype(jnp.float32)
    wf = w.astype(jnp.float32)
    wm = (m * wf).astype(x.dtype)
    dx = (g2 @ wm.T).reshape(x.shape).astype(x.dtype)
    xg = (x2.astype(jnp.float32).T @ g2.astype(jnp.float32))
    sig = jax.nn.sigmoid(s.astype(jnp.float32))
    ds = (xg * wf * sig * (1.0 - sig)).astype(s.dtype)
    return dx, None, ds, None


masked_dense.defvjp(_fwd, _bwd)
