from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, momentum, adam, adamw, clip_by_global_norm, chain,
    scale_by_schedule, cosine_schedule, warmup_cosine,
)
