"""Minimal optax-style optimizer library (optax is not available offline).

An Optimizer is a pair of pure functions:
    init(params)           -> state
    update(grads, state, params) -> (updates, state)
Apply with `apply_updates`. All transforms are pytree-generic and
None-leaf tolerant (masked trees carry None for non-applicable leaves).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def _map(f, *trees):
    return jax.tree_util.tree_map(
        lambda *xs: None if xs[0] is None else f(*xs), *trees,
        is_leaf=lambda x: x is None)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple]


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return _map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------


def sgd(lr: float) -> Optimizer:
    return Optimizer(
        init=lambda p: (),
        update=lambda g, s, p=None: (_map(lambda x: -lr * x, g), s))


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False
             ) -> Optimizer:
    def init(p):
        return _map(jnp.zeros_like, p)

    def update(g, m, p=None):
        m = _map(lambda mi, gi: beta * mi + gi, m, g)
        if nesterov:
            upd = _map(lambda mi, gi: -lr * (beta * mi + gi), m, g)
        else:
            upd = _map(lambda mi: -lr * mi, m)
        return upd, m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: Pytree
    nu: Pytree


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         ) -> Optimizer:
    def init(p):
        return AdamState(jnp.zeros((), jnp.int32),
                         _map(lambda x: jnp.zeros_like(x, jnp.float32), p),
                         _map(lambda x: jnp.zeros_like(x, jnp.float32), p))

    def update(g, st, p=None):
        c = st.count + 1
        mu = _map(lambda m, gi: b1 * m + (1 - b1) * gi.astype(jnp.float32),
                  st.mu, g)
        nu = _map(lambda v, gi: b2 * v + (1 - b2)
                  * jnp.square(gi.astype(jnp.float32)), st.nu, g)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        upd = _map(lambda m, v: -lr * (m / bc1)
                   / (jnp.sqrt(v / bc2) + eps), mu, nu)
        return upd, AdamState(c, mu, nu)

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    base = adam(lr, b1, b2, eps)

    def update(g, st, p):
        upd, st = base.update(g, st, p)
        upd = _map(lambda u, pi: u - lr * weight_decay
                   * pi.astype(jnp.float32), upd, p)
        return upd, st

    return Optimizer(base.init, update)


# ---------------------------------------------------------------------------
# Gradient transforms / schedules
# ---------------------------------------------------------------------------


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def update(g, s, p=None):
        sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                 for x in jax.tree_util.tree_leaves(g) if x is not None)
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return _map(lambda x: x * scale, g), s

    return Optimizer(lambda p: (), update)


def chain(*opts: Optimizer) -> Optimizer:
    def init(p):
        return tuple(o.init(p) for o in opts)

    def update(g, states, p=None):
        new_states = []
        for o, s in zip(opts, states):
            g, s = o.update(g, s, p)
            new_states.append(s)
        return g, tuple(new_states)

    return Optimizer(init, update)


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        return base_lr * (min_frac + (1 - min_frac)
                          * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return fn


def warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                  min_frac: float = 0.05):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        return jnp.where(s < warmup, base_lr * (s + 1) / warmup,
                         cos(s - warmup))
    return fn


def scale_by_schedule(opt_fn: Callable[[float], Optimizer],
                      schedule: Callable) -> Optimizer:
    """Wrap an lr->Optimizer factory with a schedule on a step counter."""
    unit = opt_fn(1.0)

    class SchedState(NamedTuple):
        count: jax.Array
        inner: Any

    def init(p):
        return SchedState(jnp.zeros((), jnp.int32), unit.init(p))

    def update(g, st, p=None):
        upd, inner = unit.update(g, st.inner, p)
        lr = schedule(st.count)
        upd = _map(lambda u: u * lr, upd)
        return upd, SchedState(st.count + 1, inner)

    return Optimizer(init, update)
