"""Synthetic-but-learnable datasets (the container is offline: no real
MNIST/CIFAR). Two generators:

* `make_image_task` — class-conditional Gaussian-prototype images with
  structured noise; a ConvN can overfit it and the FL sparsity/Bpp
  dynamics the paper studies are fully exercised. Difficulty knobs
  (prototype distance, noise) emulate MNIST-easy vs CIFAR-hard regimes.
* `make_lm_stream` — Zipf-sampled token stream with short-range Markov
  structure for LM smoke training.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ImageTask:
    x: jnp.ndarray        # (N, H, W, C) float32
    y: jnp.ndarray        # (N,) int32
    n_classes: int


def make_image_task(key, n: int = 4096, img: int = 32, channels: int = 3,
                    n_classes: int = 10, proto_scale: float = 1.0,
                    noise: float = 0.6) -> ImageTask:
    """Class prototypes are low-frequency random fields; samples =
    prototype + per-sample noise. Harder with lower proto_scale / higher
    noise."""
    kp, kn, kl = jax.random.split(key, 3)
    # low-frequency prototypes: upsample 8x8 random fields
    small = jax.random.normal(kp, (n_classes, 8, 8, channels)) * proto_scale
    protos = jax.image.resize(small, (n_classes, img, img, channels),
                              "bilinear")
    labels = jax.random.randint(kl, (n,), 0, n_classes)
    xs = protos[labels] + noise * jax.random.normal(
        kn, (n, img, img, channels))
    return ImageTask(xs.astype(jnp.float32), labels.astype(jnp.int32),
                     n_classes)


def make_lm_stream(key, n_tokens: int, vocab: int, order: int = 1,
                   alpha: float = 1.2):
    """Zipf unigram + deterministic bigram drift: next ~ (prev*7+z) mod V
    mixed with fresh Zipf draws. Predictable enough for loss to fall."""
    kz, km = jax.random.split(key)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = ranks ** (-alpha)
    probs = probs / jnp.sum(probs)
    z = jax.random.choice(kz, vocab, (n_tokens,), p=probs)
    mix = jax.random.bernoulli(km, 0.5, (n_tokens,))

    def step(prev, xs):
        zi, mi = xs
        nxt = jnp.where(mi, (prev * 7 + 3) % vocab, zi)
        return nxt, nxt

    _, toks = jax.lax.scan(step, jnp.int32(0),
                           (z.astype(jnp.int32), mix))
    return toks


def federated_batches(key, task: ImageTask, client_idx, n_clients: int,
                      local_steps: int, batch_size: int):
    """Build the (K, H, B, ...) round tensor the vmapped client expects.

    client_idx: list of per-client index arrays (from partition.*).
    Clients with fewer samples than H*B sample with replacement.
    """
    xs, ys = [], []
    keys = jax.random.split(key, n_clients)
    need = local_steps * batch_size
    for i in range(n_clients):
        idx = client_idx[i]
        pick = jax.random.choice(keys[i], idx.shape[0], (need,),
                                 replace=idx.shape[0] < need)
        sel = idx[pick]
        xs.append(task.x[sel].reshape(local_steps, batch_size,
                                      *task.x.shape[1:]))
        ys.append(task.y[sel].reshape(local_steps, batch_size))
    return {"images": jnp.stack(xs), "labels": jnp.stack(ys)}
