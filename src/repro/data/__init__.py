from repro.data.synthetic import (  # noqa: F401
    make_image_task, make_lm_stream, federated_batches,
)
from repro.data.partition import partition_iid, partition_by_class  # noqa
