"""Federated dataset partitioners (paper Sec. IV).

* IID: even random split across K devices.
* by-class: each device gets a random subset of c classes (the paper's
  non-IID setting, c in {2, 4}).
* Dirichlet(alpha): label-distribution skew (beyond-paper, standard in
  the FL literature).
"""
from __future__ import annotations

import numpy as np


def partition_iid(rng: np.random.Generator, labels: np.ndarray, k: int):
    idx = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(idx, k)]


def partition_by_class(rng: np.random.Generator, labels: np.ndarray,
                       k: int, c: int):
    """Each client is assigned c random classes; the pool of each class
    is split evenly among the clients that hold it."""
    n_classes = int(labels.max()) + 1
    holders: dict[int, list[int]] = {cl: [] for cl in range(n_classes)}
    assign = []
    for i in range(k):
        classes = rng.choice(n_classes, size=c, replace=False)
        assign.append(classes)
        for cl in classes:
            holders[int(cl)].append(i)
    out: list[list[int]] = [[] for _ in range(k)]
    for cl in range(n_classes):
        pool = np.where(labels == cl)[0]
        rng.shuffle(pool)
        hs = holders[cl] or [int(rng.integers(k))]
        for j, chunk in enumerate(np.array_split(pool, len(hs))):
            out[hs[j]].extend(chunk.tolist())
    return [np.sort(np.asarray(ix, np.int64)) for ix in out]


def partition_dirichlet(rng: np.random.Generator, labels: np.ndarray,
                        k: int, alpha: float = 0.5):
    n_classes = int(labels.max()) + 1
    out: list[list[int]] = [[] for _ in range(k)]
    for cl in range(n_classes):
        pool = np.where(labels == cl)[0]
        rng.shuffle(pool)
        props = rng.dirichlet([alpha] * k)
        cuts = (np.cumsum(props) * len(pool)).astype(int)[:-1]
        for i, chunk in enumerate(np.split(pool, cuts)):
            out[i].extend(chunk.tolist())
    return [np.sort(np.asarray(ix, np.int64)) for ix in out]
