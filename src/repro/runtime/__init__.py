from repro.runtime.fault import (  # noqa: F401
    FaultSimulator, StragglerPolicy, participation_vector,
)
from repro.runtime.elastic import reshard_server, cohort_plan  # noqa
