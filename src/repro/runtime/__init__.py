from repro.runtime.fault import (  # noqa: F401
    FaultSimulator, StragglerPolicy, FaultInjector,
    participation_vector, counter_uniform, counter_normal,
)
from repro.runtime.elastic import (  # noqa: F401
    reshard_server, cohort_plan, restore_theta_only,
)
from repro.runtime.async_engine import (  # noqa: F401
    AsyncConfig, AsyncRoundEngine,
)
from repro.runtime.serve_engine import (  # noqa: F401
    Completion, Request, ServeEngine,
)
