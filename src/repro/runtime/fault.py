"""Fault tolerance & straggler mitigation for 1000+ node federated runs.

The paper's protocol is naturally elastic: a round aggregates whatever
masks arrive, with the weighted mean renormalized over survivors
(federated.make_round_fn handles the renormalization). This module
produces per-round participation vectors from failure/straggler models,
so the SAME mechanism covers:

  * node crash           -> client missing this round
  * network partition    -> whole cohort missing
  * straggler            -> client past deadline, cut by policy
  * elastic scale-down   -> trailing clients permanently removed
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based cohort cut: keep the first `quorum_frac` arrivals,
    drop the rest (they are simply absent from the weighted mean).
    `overprovision` asks the selector for K' > K clients so the expected
    number of arrivals still meets the target cohort size."""
    quorum_frac: float = 0.8
    overprovision: float = 1.25

    def cut(self, rng: np.random.Generator, latencies: np.ndarray
            ) -> np.ndarray:
        k = len(latencies)
        keep = max(int(round(k * self.quorum_frac)), 1)
        order = np.argsort(latencies)
        mask = np.zeros(k, bool)
        mask[order[:keep]] = True
        return mask


@dataclasses.dataclass
class FaultSimulator:
    """Per-round iid failures + heavy-tailed latencies (lognormal) +
    optional correlated pod-level outages."""
    n_clients: int
    fail_prob: float = 0.05
    pod_size: int = 0            # >0: clients grouped into pods
    pod_outage_prob: float = 0.0
    latency_sigma: float = 0.5
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    def sample_round(self, policy: Optional[StragglerPolicy] = None
                     ) -> np.ndarray:
        alive = self.rng.random(self.n_clients) >= self.fail_prob
        if self.pod_size and self.pod_outage_prob > 0:
            n_pods = (self.n_clients + self.pod_size - 1) // self.pod_size
            pod_down = self.rng.random(n_pods) < self.pod_outage_prob
            for p in np.where(pod_down)[0]:
                alive[p * self.pod_size:(p + 1) * self.pod_size] = False
        if policy is not None:
            lat = self.rng.lognormal(0.0, self.latency_sigma,
                                     self.n_clients)
            lat[~alive] = np.inf
            alive &= policy.cut(self.rng, lat)
        if not alive.any():      # server never stalls: keep one survivor
            alive[self.rng.integers(self.n_clients)] = True
        return alive


def participation_vector(sim: Optional[FaultSimulator], n_clients: int,
                         policy: Optional[StragglerPolicy] = None):
    import jax.numpy as jnp
    if sim is None:
        return jnp.ones((n_clients,), bool)
    return jnp.asarray(sim.sample_round(policy))
