"""Fault tolerance & straggler mitigation for 1000+ node federated runs.

The paper's protocol is naturally elastic: a round aggregates whatever
masks arrive, with the weighted mean renormalized over survivors
(federated.make_round_fn and launch.steps.make_round_step both handle
the renormalization). This module produces per-round participation
vectors and transport-seam fault injections from failure/straggler
models, so the SAME mechanism covers:

  * node crash           -> client missing this round
  * network partition    -> whole cohort missing
  * straggler            -> client past deadline, cut by policy
  * corrupted uplink     -> checksum fails, bounded retransmit, then cut
  * elastic scale-down   -> trailing clients permanently removed

Every draw is RESTART-DETERMINISTIC: failures derive from
``(seed, round, client, stream)`` through a splitmix64 counter hash —
there is no mutable ``np.random.Generator`` whose state a coordinator
crash would lose.  Replaying round r after a restore produces the
identical fault sequence (docs/DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# stream ids for the counter hash — one per independent failure process
_S_ALIVE = 1
_S_POD = 2
_S_LAT_A = 3
_S_LAT_B = 4
_S_RESCUE = 5
_S_CRASH = 6
_S_PART = 7
_S_DELAY = 8
_S_DELAY_N = 9
_S_CORRUPT = 10
_S_BITFLIP = 11
_S_AGG_CRASH = 12
_S_AGG_PART = 13


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — the counter-hash core (vectorized u64)."""
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15))
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
    return x


def counter_uniform(seed: int, round_idx: int, stream: int,
                    n: int) -> np.ndarray:
    """n uniforms in [0, 1) from (seed, round, stream, 0..n-1) — pure
    counter mode, no carried state.  The restart-determinism primitive:
    the same coordinates always reproduce the same draw."""
    with np.errstate(over="ignore"):
        base = (np.uint64(np.uint64(seed) & np.uint64(0xFFFFFFFF))
                * np.uint64(0xD1342543DE82EF95)
                ^ np.uint64(round_idx) * np.uint64(0xAF251AF3B0F025B5)
                ^ np.uint64(stream) * np.uint64(0x9E3779B97F4A7C15))
        ctr = base + np.arange(n, dtype=np.uint64)
    h = _splitmix64(ctr)
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def counter_normal(seed: int, round_idx: int, stream_a: int,
                   stream_b: int, n: int) -> np.ndarray:
    """Standard normals via Box-Muller over two counter streams."""
    u1 = np.maximum(counter_uniform(seed, round_idx, stream_a, n),
                    1e-12)
    u2 = counter_uniform(seed, round_idx, stream_b, n)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based cohort cut: keep the first `quorum_frac` arrivals,
    drop the rest (they are simply absent from the weighted mean).
    `overprovision` asks the selector for K' > K clients so the expected
    number of arrivals still meets the target cohort size."""
    quorum_frac: float = 0.8
    overprovision: float = 1.25

    def cut(self, latencies: np.ndarray) -> np.ndarray:
        k = len(latencies)
        keep = max(int(round(k * self.quorum_frac)), 1)
        order = np.argsort(latencies)
        mask = np.zeros(k, bool)
        mask[order[:keep]] = True
        return mask


@dataclasses.dataclass
class FaultSimulator:
    """Per-round iid failures + heavy-tailed latencies (lognormal) +
    optional correlated pod-level outages.

    Draws are keyed by (seed, round): `sample_round(round_idx=r)` is a
    pure function, and the internal `cursor` only provides the default
    round index for callers that sample sequentially.  On restart, set
    ``cursor`` to the resumed round (or pass ``round_idx``) and the
    fault sequence replays identically.
    """
    n_clients: int
    fail_prob: float = 0.05
    pod_size: int = 0            # >0: clients grouped into pods
    pod_outage_prob: float = 0.0
    latency_sigma: float = 0.5
    seed: int = 0
    cursor: int = 0              # next round index for cursor-mode calls

    def latencies(self, round_idx: int) -> np.ndarray:
        """Lognormal per-client round latencies for round `round_idx`."""
        z = counter_normal(self.seed, round_idx, _S_LAT_A, _S_LAT_B,
                           self.n_clients)
        return np.exp(self.latency_sigma * z)

    def sample_round(self, policy: Optional[StragglerPolicy] = None,
                     round_idx: Optional[int] = None) -> np.ndarray:
        r = int(self.cursor if round_idx is None else round_idx)
        if round_idx is None:
            self.cursor = r + 1
        u = counter_uniform(self.seed, r, _S_ALIVE, self.n_clients)
        alive = u >= self.fail_prob
        if self.pod_size and self.pod_outage_prob > 0:
            n_pods = (self.n_clients + self.pod_size - 1) // self.pod_size
            pod_down = counter_uniform(self.seed, r, _S_POD,
                                       n_pods) < self.pod_outage_prob
            for p in np.where(pod_down)[0]:
                alive[p * self.pod_size:(p + 1) * self.pod_size] = False
        if policy is not None:
            lat = self.latencies(r)
            lat[~alive] = np.inf
            alive &= policy.cut(lat)
        if not alive.any():      # server never stalls: keep one survivor
            pick = counter_uniform(self.seed, r, _S_RESCUE, 1)[0]
            alive[int(pick * self.n_clients)] = True
        return alive


def participation_vector(sim: Optional[FaultSimulator], n_clients: int,
                         policy: Optional[StragglerPolicy] = None,
                         round_idx: Optional[int] = None):
    import jax.numpy as jnp
    if sim is None:
        return jnp.ones((n_clients,), bool)
    return jnp.asarray(sim.sample_round(policy, round_idx=round_idx))


# ---------------------------------------------------------------------------
# Transport-seam injection (the async engine's chaos source)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultInjector:
    """Deterministic fault injection at the TRANSPORT seam, for the
    buffered-async engine (`repro.runtime.async_engine`):

      * crash      — the uplink is never sent (client died mid-round)
      * partition  — a whole pod's uplinks are dropped (correlated)
      * straggler  — delivery is delayed whole rounds past the deadline
      * corrupt    — the packed words are bit-flipped in transit; the
                     receiver's `WireMessage` checksum rejects them and
                     the client retransmits with backoff, up to
                     `max_retries`, after which it is cut from the round

    Every decision is a pure function of (seed, round, client[, try]):
    a coordinator restart replays the identical fault sequence.
    """
    n_clients: int
    seed: int = 0
    crash_prob: float = 0.0
    pod_size: int = 0
    partition_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_rounds_max: int = 2   # uniform 1..max extra rounds late
    corrupt_prob: float = 0.0       # per delivery attempt
    max_retries: int = 2
    backoff_rounds: float = 0.5     # extra delay per retransmit
    agg_crash_prob: float = 0.0     # per-tick edge-aggregator crash
    agg_partition_prob: float = 0.0  # per-tick edge-aggregator partition

    def dropped(self, round_idx: int) -> np.ndarray:
        """bool[n_clients]: uplink never arrives (crash or partition)."""
        u = counter_uniform(self.seed, round_idx, _S_CRASH,
                            self.n_clients)
        out = u < self.crash_prob
        if self.pod_size and self.partition_prob > 0:
            n_pods = (self.n_clients + self.pod_size - 1) // self.pod_size
            down = counter_uniform(self.seed, round_idx, _S_PART,
                                   n_pods) < self.partition_prob
            for p in np.where(down)[0]:
                out[p * self.pod_size:(p + 1) * self.pod_size] = True
        return out

    def delay_rounds(self, round_idx: int) -> np.ndarray:
        """int[n_clients]: whole rounds each delivery lands late
        (0 = within this round's deadline)."""
        u = counter_uniform(self.seed, round_idx, _S_DELAY,
                            self.n_clients)
        extra = counter_uniform(self.seed, round_idx, _S_DELAY_N,
                                self.n_clients)
        late = u < self.straggler_prob
        k = 1 + (extra * self.straggler_rounds_max).astype(np.int64)
        return np.where(late, np.minimum(k, self.straggler_rounds_max),
                        0).astype(np.int64)

    def agg_crashed(self, round_idx: int, n_aggs: int) -> np.ndarray:
        """bool[n_aggs]: edge aggregator crashes this tick, losing its
        uncommitted partial fold (an aggregator-level failure domain)."""
        u = counter_uniform(self.seed, round_idx, _S_AGG_CRASH, n_aggs)
        return u < self.agg_crash_prob

    def agg_partitioned(self, round_idx: int, n_aggs: int) -> np.ndarray:
        """bool[n_aggs]: edge aggregator unreachable this tick —
        deliveries destined for it are delayed one tick, not lost."""
        u = counter_uniform(self.seed, round_idx, _S_AGG_PART, n_aggs)
        return u < self.agg_partition_prob

    def corrupt_attempt(self, round_idx: int, client: int,
                        attempt: int) -> bool:
        """Does transmission attempt `attempt` arrive corrupted?"""
        u = counter_uniform(
            self.seed, round_idx, _S_CORRUPT,
            (client + 1) * (self.max_retries + 2))[
                (client + 1) * (self.max_retries + 2) - 1 - attempt]
        return bool(u < self.corrupt_prob)

    def corrupt_words(self, words, round_idx: int, client: int,
                      attempt: int):
        """Flip one deterministic bit in the serialized word streams —
        what a corrupted-in-transit message looks like on arrival."""
        out = [np.array(w, np.uint32, copy=True) for w in words]
        total = sum(int(w.size) for w in out)
        if total == 0:
            return out
        u = counter_uniform(self.seed, round_idx, _S_BITFLIP,
                            self.n_clients * (self.max_retries + 2))
        pick = int(u[client * (self.max_retries + 2) + attempt]
                   * total * 32)
        w_idx, bit = divmod(pick, 32)
        for arr in out:
            if w_idx < arr.size:
                arr[w_idx] ^= np.uint32(1 << bit)
                break
            w_idx -= arr.size
        return out
