"""Mask-native multi-tenant serving engine: continuous batching over
ONE shared frozen weight copy.

The paper's serving asset: a deployed tenant is a 1-bit mask over the
SAME frozen random network `w` — a sub-network identity
(`masking.MaskIdentity`), ~32x smaller on the wire than a float
adapter and ZERO extra weight copies at rest.  This engine cashes that
in:

  * one `MaskedParams` (one `w` in HBM) is shared by every tenant;
  * per-tenant decode trees are materialized ONCE by
    `masking.freeze_identity` and held in a bounded
    `masking.FreezeCache` (exact LRU), so resident HBM is
    ``1 x w + min(tenants, capacity) x masked-leaf deltas`` — never
    ``tenants x w`` — no matter how many tenants rotate through
    (docs/DESIGN.md §3);
  * a continuous-batching scheduler drives ``slots`` concurrent
    requests: every engine tick advances EACH active slot by one
    token, so newly admitted requests PREFILL (consume their next
    prompt token) while resident slots keep DECODING, and a freed
    slot admits the next queued request on the same tick — token-level
    continuous batching with prefill/decode disaggregated in the
    accounting (`prefill_s` / `decode_s` are separate clocks);
  * slot execution is the bit-identity contract: by default every
    slot steps through the SAME jitted single-request `serve_step`
    (`launch.steps.make_serve_step`), so a tenant's logits are
    bit-identical to that tenant decoded alone in a fresh single-slot
    session REGARDLESS of what traffic shares the engine
    (tests/test_serving.py).  ``lockstep=True`` instead gathers the
    resident trees into a stacked slot-major batch and runs ONE
    vmapped step for all slots per tick
    (`launch.steps.make_multi_serve_step`) — fewer dispatches, but
    batched-dot reassociation makes it numerically equivalent rather
    than bit-exact, so it is opt-in.

Timing discipline (the `launch/serve.py` fix, satellite of this PR):
compilation is forced OFF the clock by a warmup step at first admit,
and all timing uses `time.perf_counter` with prefill and decode
accumulated separately.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masking
from repro.core.masking import FreezeCache, MaskedParams, MaskIdentity
from repro.launch import steps as steplib

Pytree = Any


@dataclasses.dataclass
class Request:
    """One generation request bound to a tenant identity."""
    rid: int
    tenant: str
    prompt: np.ndarray           # (P,) int32 prompt token ids
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    """Finished request: generated ids plus the decode-step logits
    that produced them (``decode_logits[i]`` -> ``tokens[i]``), for
    the bit-identity harness."""
    rid: int
    tenant: str
    prompt: np.ndarray
    tokens: List[int]
    decode_logits: List[np.ndarray]
    prefill_steps: int
    decode_steps: int


class _Slot:
    """One batch slot: its own KV cache + the tenant's frozen tree."""
    __slots__ = ("req", "tree", "cache", "pos", "t", "tokens",
                 "logits", "last_token")

    def __init__(self):
        self.req: Optional[Request] = None
        self.tree = None
        self.cache = None
        self.pos = 0           # next cache write position
        self.t = 0             # tokens consumed so far (prompt + gen)
        self.tokens: List[int] = []
        self.logits: List[np.ndarray] = []
        self.last_token = 0

    @property
    def active(self) -> bool:
        return self.req is not None

    @property
    def prefilling(self) -> bool:
        # the step consuming the LAST prompt token emits the logits
        # that start generation, so it already counts as decode work
        return self.active and self.t < len(self.req.prompt) - 1

    def free(self):
        self.req = None
        self.tree = None
        self.cache = None
        self.tokens = []
        self.logits = []


class ServeEngine:
    """Continuous-batching scheduler over one shared frozen `w`.

    Parameters
    ----------
    api:            `repro.models.ModelApi` for the served arch.
    mp:             shared `MaskedParams` — ONE frozen weight copy; every
                    tenant is a mask identity over it.
    slots:          concurrent batch slots (in-flight requests).
    cache_capacity: bound on resident materialized trees (exact LRU).
    max_seq:        per-slot KV-cache length (>= prompt + generated).
    lockstep:       False -> per-slot jitted single-request steps (the
                    bit-identity contract); True -> one vmapped step
                    for all slots per tick (throughput mode).
    """

    def __init__(self, api, mp: MaskedParams, *, slots: int = 4,
                 cache_capacity: int = 2, max_seq: int = 64,
                 lockstep: bool = False):
        if slots < 1:
            raise ValueError(f"need >= 1 slot, got {slots}")
        self.api = api
        self.mp = mp
        self.max_seq = int(max_seq)
        self.lockstep = bool(lockstep)
        self._tenants: Dict[str, MaskIdentity] = {}
        self._scores: Dict[MaskIdentity, Pytree] = {}
        self.cache = FreezeCache(self._freeze, cache_capacity)
        self._step = jax.jit(steplib.make_serve_step(api))
        self._vstep = jax.jit(steplib.make_multi_serve_step(api))
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: collections.deque = collections.deque()
        self.completions: Dict[int, Completion] = {}
        self._next_rid = 0
        self._warm = False
        # lockstep device state: slot-major stacked trees/caches
        self._stacked_tree = None
        self._stacked_cache = None
        # stats
        self.ticks = 0
        self.mixed_ticks = 0       # ticks with prefill AND decode slots
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.prefill_tokens = 0
        self.decode_tokens = 0

    # -- tenants ------------------------------------------------------------

    def register_tenant(self, name: str,
                        ident: Optional[MaskIdentity] = None, *,
                        seed: Optional[int] = None,
                        mode: str = "threshold", tau: float = 0.5,
                        scores: Optional[Pytree] = None) -> MaskIdentity:
        """Bind ``name`` to a mask identity (built from ``seed`` when
        not given explicitly).  ``scores`` optionally carries the
        tenant's personal score tree over the shared `w`; distinct
        score trees need distinct identities (use `MaskIdentity.tag`)."""
        if ident is None:
            if seed is None:
                raise ValueError("register_tenant needs ident= or seed=")
            ident = MaskIdentity(seed=int(seed), mode=mode, tau=tau,
                                 tag=name if scores is not None else "")
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if scores is not None and ident in self._scores \
                and self._scores[ident] is not scores:
            raise ValueError(
                f"identity {ident} already bound to a different score "
                "tree; disambiguate with MaskIdentity.tag")
        self._tenants[name] = ident
        if scores is not None:
            self._scores[ident] = scores
        return ident

    def _freeze(self, ident: MaskIdentity) -> Pytree:
        return masking.freeze_identity(self.mp, ident,
                                       scores=self._scores.get(ident))

    # -- requests -----------------------------------------------------------

    def submit(self, tenant: str, prompt, max_new_tokens: int) -> int:
        """Queue one request; returns the request id."""
        if tenant not in self._tenants:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {sorted(self._tenants)}")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if prompt.size + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_seq ({self.max_seq})")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, tenant, prompt,
                                  int(max_new_tokens)))
        return rid

    # -- scheduling ---------------------------------------------------------

    def _admit(self, i: int, req: Request):
        slot = self.slots[i]
        slot.req = req
        slot.tree = self.cache.get(self._tenants[req.tenant])
        slot.cache = self.api.init_cache(1, self.max_seq)
        slot.pos = 0
        slot.t = 0
        slot.tokens = []
        slot.logits = []
        slot.last_token = int(req.prompt[0])
        if self.lockstep:
            self._scatter_slot(i, slot)
        if not self._warm:
            # compile OFF the clock: one throwaway step on a scratch
            # cache (same shapes/dtypes as every later call)
            scratch = self.api.init_cache(1, self.max_seq)
            tok = jnp.asarray([slot.last_token], jnp.int32)
            if self.lockstep:
                B = len(self.slots)
                out = self._vstep(
                    self._stacked_tree, self._stacked_cache,
                    jnp.zeros((B, 1), jnp.int32),
                    jnp.zeros((B,), jnp.int32))
            else:
                out = self._step(slot.tree, scratch, tok,
                                 jnp.asarray(0, jnp.int32))
            jax.block_until_ready(out[0])
            self._warm = True

    def _scatter_slot(self, i: int, slot: _Slot):
        """Gather the slot's cached tree/cache into the stacked
        slot-major device state (lockstep mode)."""
        if self._stacked_tree is None:
            B = len(self.slots)
            self._stacked_tree = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (B,) + a.shape),
                slot.tree)
            self._stacked_cache = jax.tree_util.tree_map(
                lambda c: jnp.broadcast_to(c[None],
                                           (B,) + c.shape).copy(),
                slot.cache)
            return
        self._stacked_tree = jax.tree_util.tree_map(
            lambda b, t: b.at[i].set(t), self._stacked_tree, slot.tree)
        self._stacked_cache = jax.tree_util.tree_map(
            lambda b, c: b.at[i].set(c), self._stacked_cache, slot.cache)

    def step(self) -> bool:
        """One engine tick: admit queued requests into free slots, then
        advance every active slot by one token.  Returns False when
        idle (no active slot and empty queue)."""
        for i, slot in enumerate(self.slots):
            if not slot.active and self.queue:
                self._admit(i, self.queue.popleft())
        phases = [slot.prefilling for slot in self.slots if slot.active]
        if not phases:
            return False
        if any(phases) and not all(phases):
            self.mixed_ticks += 1
        if self.lockstep:
            self._tick_lockstep()
        else:
            for slot in self.slots:
                if slot.active:
                    self._advance_exact(slot)
        self.ticks += 1
        return True

    def run(self) -> Dict[int, Completion]:
        """Drive ticks until queue and slots drain; returns
        completions by request id."""
        while self.step():
            pass
        return self.completions

    # -- exact (per-slot) execution ----------------------------------------

    def _advance_exact(self, slot: _Slot):
        tok = jnp.asarray([slot.last_token], jnp.int32)
        t0 = time.perf_counter()
        logits, slot.cache = self._step(slot.tree, slot.cache, tok,
                                        jnp.asarray(slot.pos, jnp.int32))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self._consume(slot, np.asarray(logits[0]), dt)

    # -- lockstep (vmapped) execution --------------------------------------

    def _tick_lockstep(self):
        B = len(self.slots)
        toks = np.zeros((B, 1), np.int32)
        poss = np.zeros((B,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.active:
                toks[i, 0] = slot.last_token
                poss[i] = slot.pos
        t0 = time.perf_counter()
        logits, self._stacked_cache = self._vstep(
            self._stacked_tree, self._stacked_cache,
            jnp.asarray(toks), jnp.asarray(poss))
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        lg = np.asarray(logits)
        active = [s for s in self.slots if s.active]
        share = dt / max(len(active), 1)
        for i, slot in enumerate(self.slots):
            if slot.active:
                self._consume(slot, lg[i, 0], share)

    # -- shared per-token bookkeeping --------------------------------------

    def _consume(self, slot: _Slot, logits_row: np.ndarray, dt: float):
        req = slot.req
        P = len(req.prompt)
        if slot.t < P - 1:
            # prefill: logits discarded, next input is the next prompt
            # token
            self.prefill_s += dt
            self.prefill_tokens += 1
            slot.t += 1
            slot.pos += 1
            slot.last_token = int(req.prompt[slot.t])
            return
        # decode: these logits produce the next generated token
        self.decode_s += dt
        self.decode_tokens += 1
        nxt = int(np.argmax(logits_row))
        slot.logits.append(logits_row)
        slot.tokens.append(nxt)
        slot.t += 1
        slot.pos += 1
        slot.last_token = nxt
        if len(slot.tokens) >= req.max_new_tokens:
            self.completions[req.rid] = Completion(
                rid=req.rid, tenant=req.tenant, prompt=req.prompt,
                tokens=slot.tokens, decode_logits=slot.logits,
                prefill_steps=P - 1, decode_steps=len(slot.tokens))
            slot.free()

    # -- accounting ---------------------------------------------------------

    def hbm_report(self) -> dict:
        """Resident-HBM decomposition: ONE shared `w` + at most
        ``capacity`` masked-leaf deltas, independent of tenant count."""
        delta = masking.masked_delta_bytes(self.mp)
        occ = len(self.cache)
        return {
            "weight_bytes": delta,
            "delta_bytes_per_tree": delta,
            "resident_tree_count": occ,
            "resident_bytes": delta + occ * delta,
            "mask_artifact_bytes": masking.mask_artifact_bytes(self.mp),
            "tenants": len(self._tenants),
        }

    def stats(self) -> dict:
        out = {"ticks": self.ticks, "mixed_ticks": self.mixed_ticks,
               "prefill_s": self.prefill_s, "decode_s": self.decode_s,
               "prefill_tokens": self.prefill_tokens,
               "decode_tokens": self.decode_tokens,
               "prefill_tok_s": (self.prefill_tokens / self.prefill_s
                                 if self.prefill_s > 0 else 0.0),
               "decode_tok_s": (self.decode_tokens / self.decode_s
                                if self.decode_s > 0 else 0.0)}
        out.update(self.cache.stats())
        out.update(self.hbm_report())
        return out
