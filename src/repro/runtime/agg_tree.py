"""Hierarchical aggregator tree: Byzantine-filtered edge folds,
aggregator failure domains, and O(params) root traffic.

The flat buffered-async engine (`runtime.async_engine`) delivers every
client's `WireMessage` straight to the coordinator, so per-commit root
traffic is O(clients x params).  This module layers a fanout-configurable
aggregator tree on the SAME primitives:

  * clients uplink through the live `runtime.fault.FaultInjector`
    transport exactly as today (CRC32 verify, bounded retransmit,
    staleness discard) — but each lands at its EDGE aggregator
    (``client // fanout``), not at the root;
  * the edge folds verified arrivals into exact integer per-bit-position
    count accumulators (`aggregation.fold_bit_counts` semantics, one
    accumulator per (|D_i|, trained-from-version) weight class), plus
    pooled float-sidecar / metric / entropy sums;
  * at commit every edge forwards ONE `PooledFoldRecord` upstream —
    fixed-width packed counts (`aggregation.pack_counts`), weight-class
    headers, client count, and a fold checksum.  Root traffic per round
    is O(params) * n_edges, INDEPENDENT of the client count
    (`analysis.comm_model.tree_root_record_bits` is the static twin the
    benchmarks cross-validate against);
  * the root deserializes the records (the serialization is
    load-bearing — accumulators never travel as live objects), merges
    classes in exact integer arithmetic, recomputes staleness discounts
    against the CURRENT version, and hands the reduced mask mean to the
    algorithm's `pooled_aggregate` seam
    (`payloads.mean_from_counts` — eq. 8 over pooled counts).

Bit-identity: integer count pooling is associative and lossless, so at
zero faults / zero adversaries the tree commit is bit-identical to the
flat engine's theta AND measured wire bits whenever the commit weights
are dyadic (equal sizes, power-of-two cohort) — tests/test_agg_tree.py
gates this against `AsyncRoundEngine` directly.

Failure domains: each edge aggregator can crash or partition
(`FaultInjector.agg_crashed` / `agg_partitioned` counter streams).  A
crash destroys the edge's uncommitted partial fold; its already-verified
arrivals are REPLAYED from the edge's fold log (the client-side
retransmit queue keeps messages until commit) and re-routed to the next
alive sibling (failover) or retried next tick (quarantine-and-replay).
Replays are re-metered as real wire traffic and re-use their original
attempt index, so the counter-hashed fault draws — and therefore a
restored run — stay deterministic.  A partitioned edge delays its
deliveries one tick without consuming the wire.

Byzantine filter (at the edge, before anything enters a fold):

  1. DECLARATION check, pre-decode: the launch-time popcount of the
     encoded stream (a 32-bit commitment metered as ``decl_bits``) is
     compared against the arrived words.  A transit tamper that forges
     the CRC cannot forge the commitment, and corrupt streams never
     reach the decoder.
  2. Absolute mask-density bounds: all-ones density bombs and all-zero
     uplinks are quarantined outright.
  3. Popcount z-score against running Welford statistics (std floored,
     warm-up cohort) — drifting poisoners.
  4. Trimmed-fold fallback: if the z-filter would quarantine more than
     ``trim_frac`` of a tick's arrivals the statistics themselves are
     suspect; only the most extreme ``trim_frac`` are quarantined and
     the rest fold.

Crash consistency: `save`/`restore` extend the base engine's
`ckpt.save_bundle` path with the per-edge fold logs (pristine verified
messages + checksums), the declaration map, and the filter statistics;
restore REFOLDS the logs into fresh accumulators, so the fold state has
one source of truth and a checksum mismatch degrades exactly like the
base engine (`_restore_degraded`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import codecs as codecs_lib
from repro.api import payloads as plds
from repro.core import aggregation
from repro.runtime.async_engine import AsyncConfig, AsyncRoundEngine, \
    _InFlight
from repro.runtime import fault as faultlib

Pytree = Any

_NONE = lambda x: x is None

# one uint32 popcount commitment per launched uplink (the Byzantine
# filter's pre-decode declaration), metered next to the CRC header
DECL_BITS = 32
# per weight class on the edge -> root wire: size (f32) + version + count
CLASS_HEADER_BITS = 96


def _unpack_bits_np(words) -> np.ndarray:
    """Host unpack of uint32 words to a {0,1} uint8 vector, length
    32 * n_words, matching `aggregation.pack_bits` order (bit j of word
    i is position 32*i + j)."""
    a = np.ascontiguousarray(np.asarray(words, np.uint32).astype("<u4"))
    return np.unpackbits(a.view(np.uint8), bitorder="little")


def _wire_popcount(words) -> int:
    """Total ones over a WireMessage's coded streams (host-side)."""
    tot = 0
    for w in words:
        a = np.ascontiguousarray(np.asarray(w, np.uint32).astype("<u4"))
        tot += int(np.unpackbits(a.view(np.uint8)).sum())
    return tot


def _payload_popcount(payload) -> int:
    tot = 0
    for w in jax.tree_util.tree_leaves(getattr(payload, "words", ()),
                                       is_leaf=_NONE):
        if w is not None:
            tot += _wire_popcount([jax.device_get(w)])
    return tot


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Aggregator-tree topology + Byzantine filter policy.

    fanout:       clients per edge aggregator (edge = client // fanout).
    acc_bits:     packed count field width on the edge -> root wire
                  (8/16/32); an edge may fold at most 2^acc_bits - 1
                  clients per class before `pack_counts` hard-errors.
    min_density / max_density: absolute per-client mask-density bounds
                  (all-zero and density-bomb quarantine).
    z_thresh:     quarantine when |density - mean| / std exceeds this
                  (0 disables the statistical filter).
    z_floor:      std floor so a converged cohort cannot divide by ~0.
    min_cohort:   Welford warm-up: no z decisions before this many
                  admitted folds.
    trim_frac:    trimmed-fold fallback: if the z-filter flags more than
                  this fraction of a tick's arrivals, quarantine only
                  the most extreme ceil(trim_frac * m) and fold the rest.
    failover:     re-parent a crashed edge's deliveries to the next
                  alive sibling this tick (else they retry next tick).
    """
    fanout: int = 32
    acc_bits: int = 16
    min_density: float = 0.01
    max_density: float = 0.99
    z_thresh: float = 6.0
    z_floor: float = 0.02
    min_cohort: int = 8
    trim_frac: float = 0.25
    failover: bool = True

    def n_edges(self, n_clients: int) -> int:
        return max(1, -(-n_clients // self.fanout))

    def edge_of(self, client: int) -> int:
        return client // self.fanout


# ---------------------------------------------------------------------------
# Byzantine filter (standalone, unit-testable)
# ---------------------------------------------------------------------------


class ByzantineFilter:
    """Density z-score screen with trimmed-fold fallback.

    Keeps running Welford statistics over ADMITTED mask densities (one
    shared population across edges — the filters synchronize through
    commits).  Deterministic: plain float arithmetic, state survives
    save/restore exactly."""

    def __init__(self, cfg: TreeConfig):
        self.cfg = cfg
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0

    def zscore(self, density: float) -> float:
        if self.n < self.cfg.min_cohort or self.cfg.z_thresh <= 0:
            return 0.0
        std = max(math.sqrt(self.m2 / self.n), self.cfg.z_floor)
        return abs(density - self.mean) / std

    def admit(self, density: float) -> None:
        self.n += 1
        d = density - self.mean
        self.mean += d / self.n
        self.m2 += d * (density - self.mean)

    def screen(self, densities: List[float]
               ) -> Tuple[List[int], Dict[int, float], bool]:
        """(admitted indices, {quarantined index: z}, trimmed?) for one
        tick's arrival cohort.  Does NOT update the statistics — the
        caller admits survivors (skipping replayed entries)."""
        m = len(densities)
        flags = [(self.zscore(d), i) for i, d in enumerate(densities)]
        flags = [(z, i) for z, i in flags if z > self.cfg.z_thresh]
        cap = max(1, int(np.ceil(self.cfg.trim_frac * m)))
        trimmed = len(flags) > cap
        if trimmed:
            flags.sort(key=lambda t: (-t[0], t[1]))
            flags = flags[:cap]
        quarantined = {i: z for z, i in flags}
        admitted = [i for i in range(m) if i not in quarantined]
        return admitted, quarantined, trimmed

    def state_dict(self) -> dict:
        return {"n": int(self.n), "mean": float(self.mean),
                "m2": float(self.m2)}

    def load_state(self, d: dict) -> None:
        self.n = int(d["n"])
        self.mean = float(d["mean"])
        self.m2 = float(d["m2"])


# ---------------------------------------------------------------------------
# Edge fold state + the pooled wire record
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ClassAcc:
    """One edge's running fold for one (|D_i|, version) weight class."""
    size: float
    version: int
    count: int
    counts: List[np.ndarray]        # int64[P] per word leaf (exact)
    fsums: List[np.ndarray]         # f32 per float leaf
    msums: Dict[str, float]
    bpp_sum: float
    clients: List[Tuple[int, int]]  # (client, round) in fold order


@dataclasses.dataclass
class _Edge:
    classes: Dict[Tuple[float, int], _ClassAcc]
    log: List[_InFlight]            # pristine verified messages


@dataclasses.dataclass
class ClassFold:
    """One weight class inside a `PooledFoldRecord` (wire form)."""
    size: float
    version: int
    count: int
    count_words: List[np.ndarray]   # `aggregation.pack_counts` streams
    float_sums: List[np.ndarray]
    metric_sums: Dict[str, float]
    bpp_sum: float


@dataclasses.dataclass
class PooledFoldRecord:
    """The ONE record an edge forwards upstream per commit.

    Wire accounting mirrors `WireMessage`: `wire_bits` is the packed
    count payload + per-class headers, `sidecar_bits` the pooled float
    sums / metric sums / entropy sum, `header_bits` the CRC32 fold
    checksum.  All of it is O(params) — nothing scales with the number
    of folded clients."""
    edge: int
    acc_bits: int
    classes: List[ClassFold]
    checksum: Optional[int] = None

    def __post_init__(self):
        if self.checksum is None:
            self.checksum = self.compute_checksum()

    def compute_checksum(self) -> int:
        streams = []
        for cf in self.classes:
            streams.extend(cf.count_words)
        return aggregation.words_checksum(streams)

    def verify(self) -> bool:
        return self.checksum == self.compute_checksum()

    @property
    def wire_bits(self) -> int:
        tot = 0
        for cf in self.classes:
            tot += sum(32 * int(w.size) for w in cf.count_words)
            tot += CLASS_HEADER_BITS
        return tot

    @property
    def sidecar_bits(self) -> int:
        tot = 0
        for cf in self.classes:
            tot += 32 * (sum(int(f.size) for f in cf.float_sums)
                         + len(cf.metric_sums) + 1)
        return tot

    @property
    def header_bits(self) -> int:
        return codecs_lib.HEADER_BITS

    @classmethod
    def from_edge(cls, edge_id: int, edge: _Edge, acc_bits: int
                  ) -> "PooledFoldRecord":
        folds = []
        for key in sorted(edge.classes):
            a = edge.classes[key]
            folds.append(ClassFold(
                size=float(a.size), version=int(a.version),
                count=int(a.count),
                count_words=[aggregation.pack_counts(c, acc_bits)
                             for c in a.counts],
                float_sums=[np.asarray(f, np.float32) for f in a.fsums],
                metric_sums=dict(a.msums), bpp_sum=float(a.bpp_sum)))
        return cls(edge=edge_id, acc_bits=acc_bits, classes=folds)


# ---------------------------------------------------------------------------
# The tree engine
# ---------------------------------------------------------------------------


class TreeRoundEngine(AsyncRoundEngine):
    """`AsyncRoundEngine` with a fanout-configurable aggregator layer
    between the transport and the commit.

    Drop-in: same tick/flush/save/restore surface, same commit metric
    dict (plus ``root_bits_measured`` / ``edges`` / ``seq``).  Requires
    an algorithm with the `pooled_aggregate` seam (packed payloads);
    float-delta algorithms cannot ride the tree.

    ``adversary`` maps client -> role for the Byzantine tests / drills:
    ``"ones"`` / ``"zeros"`` are malicious clients that encode a
    self-consistent density bomb (caught by the density bounds),
    ``"flip"`` is a transit tamper that flips one coded bit and forges
    the CRC (caught by the pre-decode declaration check)."""

    def __init__(self, algo, state, data_like, sizes, key,
                 config: Optional[AsyncConfig] = None,
                 injector=None, codec=None,
                 tree: Optional[TreeConfig] = None,
                 adversary: Optional[Dict[int, str]] = None):
        super().__init__(algo, state, data_like, sizes, key,
                         config=config, injector=injector, codec=codec)
        if getattr(algo, "pooled_aggregate", None) is None:
            raise ValueError(
                f"algorithm {algo.name!r} has no pooled_aggregate seam; "
                "only packed-payload algorithms can ride the aggregator "
                "tree")
        self.tree = tree or TreeConfig()
        self.n_edges = self.tree.n_edges(self.n_clients)
        self.adversary = dict(adversary or {})
        self.byz = ByzantineFilter(self.tree)

        for k in ("root_bits_measured", "root_header_bits", "decl_bits"):
            self.totals[k] = 0.0
            self._since_commit[k] = 0.0

        # static payload geometry: per word leaf the padded bit-position
        # count P and the true parameter count n; per float leaf the
        # shape/dtype — everything the edge accumulators and the root
        # rebuild need
        tmpl = self._payload_template
        wleaves, self._words_def = jax.tree_util.tree_flatten(
            tmpl.words, is_leaf=_NONE)
        self._words_none = tuple(w is None for w in wleaves)
        self._leaf_P = tuple(int(w.size) * 32 for w in wleaves
                             if w is not None)
        self._leaf_n = tuple(plds._prod(sh) for sh in tmpl.shapes)
        self._leaf_shapes = tmpl.shapes
        self._has_floats = hasattr(tmpl, "floats")
        floats = getattr(tmpl, "floats", None)
        fleaves, self._floats_def = jax.tree_util.tree_flatten(
            floats, is_leaf=_NONE)
        self._floats_none = tuple(f is None for f in fleaves)
        self._float_shapes = tuple(tuple(f.shape) for f in fleaves
                                   if f is not None)
        self._float_dtypes = tuple(f.dtype for f in fleaves
                                   if f is not None)

        self._reset_tree_state()
        self._root_phase = jax.jit(self._root_phase_fn)

    def _reset_tree_state(self):
        self.edges = [_Edge(classes={}, log=[])
                      for _ in range(self.n_edges)]
        self._decl: Dict[Tuple[int, int], int] = {}
        self._replayed: set = set()
        self.byz = ByzantineFilter(self.tree)
        self.byz_quarantined: Dict[str, int] = {}

    # -- launch: adversary mutation + popcount declaration ---------------

    def _bomb_message(self, role: str) -> codecs_lib.WireMessage:
        """A malicious client's self-consistent uplink: every mask bit
        set (``ones``) or cleared (``zeros``), encoded through the real
        codec with a valid CRC — only the density bounds can catch it."""
        bit = 1 if role == "ones" else 0
        tmpl = self._payload_template
        it = iter(tmpl.shapes)
        words = jax.tree_util.tree_map(
            lambda w: None if w is None else plds.pack_leaf(
                jnp.full(next(it), bit, jnp.uint8)),
            tmpl.words, is_leaf=_NONE)
        if self._has_floats:
            payload = self._payload_cls(words, tmpl.floats, tmpl.shapes)
        else:
            payload = self._payload_cls(words, tmpl.shapes)
        return self.codec.encode(payload)

    def _launch(self, data, t: int, key=None):
        n0 = len(self.pending)
        super()._launch(data, t, key)
        for e in self.pending[n0:]:
            role = self.adversary.get(e.client)
            if role in ("ones", "zeros"):
                e.msg = self._bomb_message(role)
                self._event("adversary", client=e.client, round=t,
                            role=role)
            # the client commits to its stream's popcount at launch;
            # the edge checks the commitment before decoding
            self._decl[(e.round, e.client)] = _wire_popcount(e.msg.words)
            self._since_commit["decl_bits"] += DECL_BITS
            self.totals["decl_bits"] += DECL_BITS
            if role == "flip":
                # transit tamper AFTER the declaration: flip one coded
                # bit and restamp (forge) the CRC so verify() passes
                tampered = [np.asarray(w, np.uint32).copy()
                            for w in e.msg.words]
                tampered[0][0] ^= np.uint32(1)
                e.msg = dataclasses.replace(e.msg, words=tampered,
                                            checksum=None)
                self._event("adversary", client=e.client, round=t,
                            role=role)

    # -- deliver: failure domains -> transport -> Byzantine screen -------

    def _edge_alive(self, t: int):
        inj = self.injector
        if inj is None:
            z = np.zeros(self.n_edges, bool)
            return z, z
        return (inj.agg_crashed(t, self.n_edges),
                inj.agg_partitioned(t, self.n_edges))

    def _failover_target(self, home: int, crashed: np.ndarray
                         ) -> Optional[int]:
        if not self.tree.failover:
            return None
        for step in range(1, self.n_edges):
            sib = (home + step) % self.n_edges
            if not crashed[sib]:
                return sib
        return None

    def _crash_edge(self, eid: int, t: int):
        """Failure domain: the edge's uncommitted partial fold is gone.
        Replay its logged (already-verified) arrivals from the
        client-side retransmit queue — same attempt index, so the
        counter-hashed corrupt draw repeats its non-corrupting outcome
        and the replay is deterministic; the retransmission is metered
        as real wire traffic on redelivery."""
        edge = self.edges[eid]
        lost = sum(a.count for a in edge.classes.values())
        # the lost fold's popcount leaves the running buffer total too —
        # the replayed arrivals re-add it when they re-fold
        self.buffer_ones -= sum(int(c.sum())
                                for a in edge.classes.values()
                                for c in a.counts)
        self._event("agg_crash", edge=eid, lost=lost)
        for le in edge.log:
            self._event("replay", client=le.client, round=le.round,
                        edge=eid, attempt=le.attempt)
            self._replayed.add((le.round, le.client, le.attempt))
            self.pending.append(dataclasses.replace(le, deliver=t))
        edge.classes = {}
        edge.log = []

    def _deliver(self, t: int):
        inj = self.injector
        crashed, parted = self._edge_alive(t)
        for eid in np.flatnonzero(crashed):
            self._crash_edge(int(eid), t)
        still: List[_InFlight] = []
        arrivals: List[Tuple[_InFlight, int]] = []
        for e in self.pending:
            if e.deliver > t:
                still.append(e)
                continue
            home = self.tree.edge_of(e.client) % self.n_edges
            target = home
            if crashed[home]:
                sib = self._failover_target(home, crashed)
                if sib is None:
                    self._event("agg_unavailable", client=e.client,
                                round=e.round, edge=home,
                                attempt=e.attempt)
                    still.append(dataclasses.replace(e, deliver=t + 1))
                    continue
                self._event("failover", client=e.client, round=e.round,
                            edge=home, to=int(sib), attempt=e.attempt)
                target = int(sib)
            if parted[target]:
                self._event("agg_partition", client=e.client,
                            round=e.round, edge=int(target),
                            attempt=e.attempt)
                still.append(dataclasses.replace(e, deliver=t + 1))
                continue
            msg = e.msg
            if inj is not None and inj.corrupt_attempt(
                    e.round, e.client, e.attempt):
                msg = dataclasses.replace(
                    e.msg, words=inj.corrupt_words(
                        e.msg.words, e.round, e.client, e.attempt))
            abits = float(msg.wire_bits + msg.sidecar_bits)
            self._since_commit["uplink_bits_measured"] += abits
            self.totals["uplink_bits_measured"] += abits
            self._since_commit["uplink_header_bits"] += msg.header_bits
            self.totals["uplink_header_bits"] += msg.header_bits
            if not msg.verify():
                if e.attempt >= (inj.max_retries if inj else 0):
                    self._event("cut", client=e.client, round=e.round,
                                attempts=e.attempt + 1)
                    continue
                backoff = max(1, int(np.ceil(
                    inj.backoff_rounds * (e.attempt + 1))))
                self._event("corrupt_reject", client=e.client,
                            round=e.round, attempt=e.attempt,
                            retry_at=t + backoff)
                still.append(dataclasses.replace(
                    e, attempt=e.attempt + 1, deliver=t + backoff))
                continue
            staleness = self.version - e.version
            if staleness > self.config.max_staleness:
                self._event("stale_drop", client=e.client,
                            round=e.round, staleness=staleness,
                            attempt=e.attempt)
                continue
            # declaration check BEFORE decode: a forged CRC cannot forge
            # the launch-time popcount commitment, and corrupt streams
            # never reach the decoder
            decl = self._decl.get((e.round, e.client))
            if decl is not None and _wire_popcount(msg.words) != decl:
                self._quarantine(e, int(target), "decl_mismatch")
                continue
            arrivals.append((e, int(target)))
        self.pending = still
        self._screen_and_fold(t, arrivals)

    def _quarantine(self, e: _InFlight, edge: int, reason: str,
                    **kw):
        self.byz_quarantined[reason] = \
            self.byz_quarantined.get(reason, 0) + 1
        self._event("byz_quarantine", client=e.client, round=e.round,
                    edge=edge, reason=reason, attempt=e.attempt, **kw)

    def _screen_and_fold(self, t: int, arrivals):
        """Byzantine screen over one tick's verified arrivals, then fold
        the survivors into their edges' class accumulators."""
        if not arrivals:
            return
        cand = []
        for e, target in arrivals:
            payload = self.codec.decode(e.msg)
            n = max(payload.num_params(), 1)
            ones = _payload_popcount(payload)
            density = ones / n
            if density < self.tree.min_density \
                    or density > self.tree.max_density:
                self._quarantine(e, target, "density",
                                 density=round(density, 6))
                continue
            cand.append((e, target, payload, density, ones))
        if not cand:
            return
        admitted, quarantined, trimmed = self.byz.screen(
            [c[3] for c in cand])
        if trimmed:
            self._event("trimmed_fold", flagged=len(quarantined),
                        cohort=len(cand))
        for i, z in sorted(quarantined.items()):
            e, target = cand[i][0], cand[i][1]
            self._quarantine(e, target, "zscore", z=round(z, 4))
        for i in admitted:
            e, target, payload, density, ones = cand[i]
            rkey = (e.round, e.client, e.attempt)
            if rkey in self._replayed:
                self._replayed.discard(rkey)  # stats already counted
            else:
                self.byz.admit(density)
            self._accumulate(target, e, payload)
            self.buffer_ones += ones
            self._event("fold", client=e.client, round=e.round,
                        staleness=self.version - e.version, ones=ones,
                        attempt=e.attempt, edge=target)

    def _accumulate(self, eid: int, e: _InFlight, payload) -> None:
        """Exact integer fold of one verified payload into the edge's
        class accumulator (and its replay log).  Pure accumulation — no
        events, no metering — so the restore path can refold logs
        byte-identically."""
        edge = self.edges[eid]
        key = (float(e.size), int(e.version))
        acc = edge.classes.get(key)
        if acc is None:
            acc = _ClassAcc(
                size=float(e.size), version=int(e.version), count=0,
                counts=[np.zeros((p,), np.int64) for p in self._leaf_P],
                fsums=[np.zeros(sh, np.float32)
                       for sh in self._float_shapes],
                msums={k: 0.0 for k in e.metrics}, bpp_sum=0.0,
                clients=[])
            edge.classes[key] = acc
        wl = [w for w in jax.tree_util.tree_leaves(
            payload.words, is_leaf=_NONE) if w is not None]
        for i, w in enumerate(wl):
            acc.counts[i] += _unpack_bits_np(
                jax.device_get(w)).astype(np.int64)
        if self._has_floats:
            fl = [f for f in jax.tree_util.tree_leaves(
                payload.floats, is_leaf=_NONE) if f is not None]
            for i, f in enumerate(fl):
                acc.fsums[i] += np.asarray(jax.device_get(f), np.float32)
        for k, v in e.metrics.items():
            acc.msums[k] = acc.msums.get(k, 0.0) + float(v)
        acc.bpp_sum += float(payload.bpp())
        acc.count += 1
        acc.clients.append((int(e.client), int(e.round)))
        edge.log.append(dataclasses.replace(e))

    # -- commit: pooled records cross the edge -> root hop ---------------

    def _folded_total(self) -> int:
        return sum(a.count for edge in self.edges
                   for a in edge.classes.values())

    def _maybe_commit(self, t: int, force: bool = False) -> List[dict]:
        # prune whole classes the fold outlived (class granularity: the
        # staleness of every member is identical by construction)
        for edge in self.edges:
            for key in sorted(edge.classes):
                size, ver = key
                if self.version - ver <= self.config.max_staleness:
                    continue
                acc = edge.classes.pop(key)
                for c, r in acc.clients:
                    self._event("stale_drop", client=c, round=r,
                                staleness=self.version - ver)
                edge.log = [le for le in edge.log
                            if (float(le.size), int(le.version)) != key]
        folded = self._folded_total()
        if folded == 0:
            return []
        deadline = (t - self.last_commit_tick
                    >= self.config.deadline_rounds)
        if folded < self.quorum and not (force or deadline):
            return []
        return [self._commit(t, forced=force or deadline)]

    def _root_phase_fn(self, state, counts, fsums, msums, bpps, sizes,
                       stal, kcounts):
        """Jitted root reduction: staleness-discounted per-client class
        weights, theta via `mean_from_counts` (eq. 8 over pooled exact
        counts), pooled float/metric/entropy means, then the algorithm's
        `pooled_aggregate` transition."""
        disc = jnp.asarray(aggregation.staleness_weight(
            jnp.asarray(stal, jnp.float32), self.config.staleness_alpha),
            jnp.float32)
        sizes = jnp.asarray(sizes, jnp.float32)
        w = jnp.where(disc == 1.0, sizes, sizes * disc)
        tot = jnp.sum(jnp.asarray(kcounts, jnp.float32) * w)
        wn = w / jnp.maximum(tot, 1e-9)
        it = iter(range(len(self._leaf_n)))
        qleaves = []
        for none in self._words_none:
            if none:
                qleaves.append(None)
                continue
            i = next(it)
            qleaves.append(plds.mean_from_counts(
                counts[i], self._leaf_n[i], wn
            ).reshape(self._leaf_shapes[i]))
        q = jax.tree_util.tree_unflatten(self._words_def, qleaves)
        fleaves, fi = [], 0
        for none in self._floats_none:
            if none:
                fleaves.append(None)
                continue
            fleaves.append(jnp.tensordot(
                wn, jnp.asarray(fsums[fi], jnp.float32), axes=(0, 0)
            ).astype(self._float_dtypes[fi]))
            fi += 1
        floats = jax.tree_util.tree_unflatten(self._floats_def, fleaves)
        k = jnp.sum(jnp.asarray(kcounts, jnp.float32))
        new_state = self.algo.pooled_aggregate(state, q, floats, k)
        up_bpp = jnp.sum(wn * jnp.asarray(bpps, jnp.float32))
        mmeans = {mk: jnp.sum(wn * jnp.asarray(mv, jnp.float32))
                  for mk, mv in msums.items()}
        return new_state, up_bpp, mmeans

    def _commit(self, t: int, forced: bool = False) -> dict:
        # 1. every edge serializes its pooled fold — the ONLY bytes that
        # cross the edge -> root hop, metered into root_bits_measured
        records: List[PooledFoldRecord] = []
        clients: List[int] = []
        for eid, edge in enumerate(self.edges):
            if not edge.classes:
                continue
            for acc in edge.classes.values():
                clients.extend(c for c, _ in acc.clients)
            rec = PooledFoldRecord.from_edge(eid, edge, self.tree.acc_bits)
            rbits = float(rec.wire_bits + rec.sidecar_bits)
            self._since_commit["root_bits_measured"] += rbits
            self.totals["root_bits_measured"] += rbits
            self._since_commit["root_header_bits"] += rec.header_bits
            self.totals["root_header_bits"] += rec.header_bits
            records.append(rec)
        # 2. root: verify + DESERIALIZE the records (the packed wire
        # form is load-bearing), merge classes in exact integers
        merged: Dict[Tuple[float, int], dict] = {}
        for rec in records:
            if not rec.verify():
                raise codecs_lib.ChecksumError(
                    f"edge {rec.edge} pooled fold failed its checksum")
            for cf in rec.classes:
                counts = [aggregation.unpack_counts(wd, p, rec.acc_bits)
                          for wd, p in zip(cf.count_words, self._leaf_P)]
                key = (float(cf.size), int(cf.version))
                m = merged.get(key)
                if m is None:
                    merged[key] = {
                        "count": int(cf.count), "counts": counts,
                        "fsums": [f.copy() for f in cf.float_sums],
                        "msums": dict(cf.metric_sums),
                        "bpp": float(cf.bpp_sum)}
                    continue
                m["count"] += int(cf.count)
                for i, c in enumerate(counts):
                    m["counts"][i] = m["counts"][i] + c
                for i, f in enumerate(cf.float_sums):
                    m["fsums"][i] = m["fsums"][i] + f
                for mk, mv in cf.metric_sums.items():
                    m["msums"][mk] = m["msums"].get(mk, 0.0) + mv
                m["bpp"] += float(cf.bpp_sum)
        keys = sorted(merged)
        sizes = np.asarray([k[0] for k in keys], np.float32)
        stal = np.asarray([self.version - k[1] for k in keys],
                          np.float32)
        kcounts = np.asarray([merged[k]["count"] for k in keys],
                             np.float32)
        counts = [np.stack([merged[k]["counts"][i] for k in keys])
                  for i in range(len(self._leaf_P))]
        fsums = [np.stack([merged[k]["fsums"][i] for k in keys])
                 for i in range(len(self._float_shapes))]
        mkeys = sorted(merged[keys[0]]["msums"])
        msums = {mk: np.asarray([merged[k]["msums"][mk] for k in keys],
                                np.float32) for mk in mkeys}
        bpps = np.asarray([merged[k]["bpp"] for k in keys], np.float32)
        new_state, up_bpp, mmeans = self._root_phase(
            self.state, counts, fsums, msums, bpps, sizes, stal,
            kcounts)
        self.state = new_state
        B = int(kcounts.sum())
        stal_max = int(max(self.version - k[1] for k in keys))
        self.version += 1
        self.last_commit_tick = t
        self.totals["commits"] += 1
        out = {"uplink_bpp": float(up_bpp),
               "downlink_bpp": self._last_downlink_bpp,
               "n_folded": B,
               "version": self.version,
               "tick": t,
               "forced": bool(forced),
               "staleness_max": stal_max,
               "clients": sorted(clients),
               "edges": len(records)}
        out.update({k: self._since_commit[k] for k in self._since_commit})
        for mk in mkeys:
            out[mk] = float(mmeans[mk])
        self._since_commit = {k: 0.0 for k in self._since_commit}
        for edge in self.edges:
            edge.classes = {}
            edge.log = []
        self.buffer_ones = 0
        live = {(e.round, e.client) for e in self.pending}
        self._decl = {k: v for k, v in self._decl.items() if k in live}
        self._event("commit", version=self.version, folded=B,
                    forced=bool(forced), edges=len(records))
        out["seq"] = self.events[-1]["seq"]
        return out

    # -- crash-consistent checkpointing ----------------------------------

    def _save_payload(self):
        arrays, extra = super()._save_payload()
        edges_meta = []
        for eid, edge in enumerate(self.edges):
            log_meta = []
            for i, le in enumerate(edge.log):
                for j, w in enumerate(le.msg.words):
                    arrays[f"elog{eid}_{i}/w{j}"] = w
                for j, s in enumerate(le.msg.sidecar):
                    arrays[f"elog{eid}_{i}/s{j}"] = s
                log_meta.append({
                    "client": le.client, "version": le.version,
                    "round": le.round, "deliver": le.deliver,
                    "attempt": le.attempt, "size": le.size,
                    "metrics": le.metrics,
                    "checksum": int(le.msg.checksum),
                    "n_words": len(le.msg.words),
                    "n_side": len(le.msg.sidecar)})
            edges_meta.append({"log": log_meta})
        extra["tree"] = {
            "decl": [[int(r), int(c), int(o)]
                     for (r, c), o in sorted(self._decl.items())],
            "filter": self.byz.state_dict(),
            "quarantined": dict(self.byz_quarantined),
            "replayed": sorted([list(k) for k in self._replayed]),
            "edges": edges_meta,
        }
        return arrays, extra

    def _load_payload(self, arrays, extra):
        super()._load_payload(arrays, extra)
        self._reset_tree_state()
        te = extra.get("tree")
        if te is None or self._degraded_restore:
            return self
        self._decl = {(int(r), int(c)): int(o)
                      for r, c, o in te["decl"]}
        self.byz.load_state(te["filter"])
        self.byz_quarantined = {k: int(v)
                                for k, v in te["quarantined"].items()}
        self._replayed = {tuple(int(x) for x in k)
                          for k in te["replayed"]}
        for eid, em in enumerate(te["edges"]):
            for i, meta in enumerate(em["log"]):
                words = [np.asarray(arrays[f"elog{eid}_{i}/w{j}"],
                                    np.uint32)
                         for j in range(int(meta["n_words"]))]
                side = [np.asarray(arrays[f"elog{eid}_{i}/s{j}"],
                                   np.uint32)
                        for j in range(int(meta["n_side"]))]
                msg = codecs_lib.WireMessage(
                    self.codec.name, self._payload_cls, words, side,
                    self._wire_meta, checksum=int(meta["checksum"]))
                # the fold log is state: a corrupt entry degrades the
                # restore exactly like a corrupt buffer entry would
                if not msg.verify():
                    return self._restore_degraded(meta, i)
                le = _InFlight(
                    client=int(meta["client"]),
                    version=int(meta["version"]),
                    round=int(meta["round"]),
                    deliver=int(meta["deliver"]),
                    attempt=int(meta["attempt"]),
                    size=float(meta["size"]), msg=msg,
                    metrics=dict(meta["metrics"]))
                # refold: the logs are the single source of truth for
                # the edge accumulators — deterministic reconstruction
                self._accumulate(eid, le, self.codec.decode(msg))
        return self

    def _restore_degraded(self, meta, slot):
        self._reset_tree_state()
        return super()._restore_degraded(meta, slot)


# ---------------------------------------------------------------------------
# Barrier-path topology shim for launch/train.py
# ---------------------------------------------------------------------------


class TreeTopology:
    """Static client -> edge map + aggregator fault draws for the
    SYNCHRONOUS train loop.

    The barrier round has no retransmit window, so failure-domain
    semantics collapse: every client homed on a crashed edge misses the
    round (failover cannot beat the barrier), and if every edge crashed
    the lowest-id edge is rescued so the round never degenerates to an
    empty cohort.  Root traffic is metered statically
    (`analysis.comm_model.tree_root_record_bits` x surviving edges) —
    the jitted round step has no host seam for per-cohort words."""

    def __init__(self, n_clients: int, fanout: int,
                 agg_fault_prob: float = 0.0, seed: int = 0):
        self.cfg = TreeConfig(fanout=max(1, fanout))
        self.n_clients = n_clients
        self.n_edges = self.cfg.n_edges(n_clients)
        self.agg_fault_prob = float(agg_fault_prob)
        self.seed = seed

    def crashed_edges(self, round_idx: int) -> np.ndarray:
        u = faultlib.counter_uniform(self.seed, round_idx,
                                     faultlib._S_AGG_CRASH, self.n_edges)
        crashed = u < self.agg_fault_prob
        if crashed.all():
            crashed = crashed.copy()
            crashed[0] = False      # rescue: the root adopts one edge
        return crashed

    def surviving_edges(self, round_idx: int) -> int:
        return int((~self.crashed_edges(round_idx)).sum())

    def round_mask(self, alive: np.ndarray, round_idx: int
                   ) -> np.ndarray:
        """Participation after aggregator faults: clients of crashed
        edges miss the barrier regardless of client-level liveness."""
        crashed = self.crashed_edges(round_idx)
        out = np.asarray(alive, bool).copy()
        for c in np.flatnonzero(out):
            if crashed[self.cfg.edge_of(int(c)) % self.n_edges]:
                out[c] = False
        return out


# ---------------------------------------------------------------------------
# CLI driver: the chaos-smoke target (tools/chaos_smoke.py --tree)
# ---------------------------------------------------------------------------


def _build_engine(args):
    from repro import api
    from repro.core import masking
    from repro.models import cnn
    from repro.data import synthetic, partition

    key = jax.random.PRNGKey(args.seed)
    cfg = cnn.ConvConfig("t", (8, 8), (16,), n_classes=4, img_size=8)
    task = synthetic.make_image_task(key, n=24 * args.clients, img=8,
                                     n_classes=4, noise=0.3)
    params = cnn.init_params(key, cfg)
    apply_fn = lambda p, b: cnn.forward(p, cfg, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    rng = np.random.default_rng(args.seed)
    cidx = partition.partition_iid(rng, np.asarray(task.y),
                                   args.clients)
    data = synthetic.federated_batches(key, task, cidx, args.clients,
                                       2, 8)
    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    algo = api.get_algorithm("fedpm_reg", apply_fn, loss_fn,
                             spec=masking.MaskSpec(), local_steps=2)
    inj = faultlib.FaultInjector(
        args.clients, seed=args.seed,
        agg_crash_prob=args.agg_fault_prob,
        agg_partition_prob=args.agg_fault_prob * 0.5)
    eng = TreeRoundEngine(
        algo, algo.init(key, params), data, sizes, key,
        config=AsyncConfig(quorum_frac=args.quorum_frac,
                           deadline_rounds=args.deadline),
        injector=inj, tree=TreeConfig(fanout=args.fanout))
    return eng, data


def _main(argv=None):
    import argparse
    import os
    import time

    from repro.ckpt import checkpoint as ckptlib

    ap = argparse.ArgumentParser(
        description="aggregator-tree chaos driver: tick a "
                    "TreeRoundEngine with per-tick crash-consistent "
                    "saves (the SIGKILL target of chaos_smoke --tree)")
    ap.add_argument("--ticks", type=int, default=8)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--fanout", type=int, default=2)
    ap.add_argument("--agg-fault-prob", type=float, default=0.0)
    ap.add_argument("--quorum-frac", type=float, default=1.0)
    ap.add_argument("--deadline", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--marker", default="",
                    help="file to create after the first commit is "
                         "durably saved (the kill signal)")
    ap.add_argument("--tick-sleep", type=float, default=0.0,
                    help="widen the kill window (never affects results)")
    args = ap.parse_args(argv)

    eng, data = _build_engine(args)
    bundle = os.path.join(args.ckpt_dir, "engine")
    if ckptlib.bundle_exists(bundle):
        eng.restore(bundle)
        print(f"resumed at tick {eng.tick_idx} (version {eng.version}, "
              f"seq {eng._event_seq})", flush=True)
    for _ in range(eng.tick_idx, args.ticks):
        commits = eng.tick(data)
        eng.save(bundle)     # durable BEFORE the commit is announced
        for c in commits:
            print(f"commit v={c['version']} seq={c['seq']} "
                  f"tick={c['tick']}", flush=True)
        if args.marker and commits and not os.path.exists(args.marker):
            with open(args.marker, "w") as f:
                f.write(str(commits[-1]["version"]))
        if args.tick_sleep:
            time.sleep(args.tick_sleep)
    for c in eng.flush():
        eng.save(bundle)
        print(f"commit v={c['version']} seq={c['seq']} "
              f"tick={c['tick']}", flush=True)
    eng.save(bundle)
    digest = AsyncRoundEngine._payload_checksum(eng.state)
    print(f"theta digest {digest:08x} version {eng.version}",
          flush=True)
    print("done", flush=True)


if __name__ == "__main__":
    _main()
