"""Buffered-async round engine: quorum commits, staleness-weighted
mask folds, live transport faults, crash-consistent resume.

The synchronous engine (`repro.api.protocol.run_round`) is a barrier:
a round waits for every cohort's uplink before aggregating.  At 1000+
clients the barrier is the tail-latency product of the whole fleet, so
this module replaces it with a FedBuff-style buffer:

  * every tick the server LAUNCHES the current cohort (same downlink
    wire, same vmapped `client_update`, same per-round key schedule as
    `run_round` — bit-identical client phase);
  * each client's payload is ENCODED to a real `WireMessage` (packed
    uint32 mask words + float sidecar + CRC32 header) and handed to the
    transport, where `runtime.fault.FaultInjector` may crash it, drop
    its pod, delay it whole rounds, or flip bits in transit;
  * arrivals FOLD into the round buffer as they land: the checksum is
    verified first (corrupt uplinks are rejected and retransmitted with
    bounded backoff, then cut), the decoded words join the buffer and
    a running popcount accumulator (`aggregation.fold_popcount`) tracks
    the live ones-count without re-touching buffered words;
  * the round COMMITS when the buffer reaches quorum (or a deadline
    forces it): fold weights are `aggregation.staleness_weights` —
    |D_i| discounted by ``(1+s)^-alpha`` and renormalized over the
    buffer — and the reduction goes through `payloads.stack_payloads`
    into the algorithm's own `aggregate`, i.e. the SAME
    `batched_packed_mean` / `mean_from_words` kernel as the barrier
    path.  With zero faults and ``quorum_frac=1`` every commit is
    bit-identical to `run_round` (tests/test_async_engine.py gates
    this, wire bits included).

Crash consistency: `save()` writes the full engine — server state,
buffered payloads, in-flight messages, tick/version counters, comm
totals — through `ckpt.save_bundle` (tmp + os.replace, manifest last).
Fault draws are pure functions of (seed, round, client, attempt)
(`runtime.fault`), so a restored engine REPLAYS the identical fault
sequence; there is no RNG state to lose, only the tick cursor, which
the bundle carries.

Accounting: `uplink_bits_measured` counts every delivered attempt's
``wire_bits + sidecar_bits`` (rejected attempts consumed the wire too);
the CRC32 header is metered separately as ``uplink_header_bits`` so the
mask Bpp metric, the CommLedger feed, and `analysis.comm_model`'s
static tables keep meaning exactly what the codec put on the mask
stream.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import codecs as codecs_lib
from repro.api import payloads as plds
from repro.api import protocol
from repro.core import aggregation
from repro.ckpt import checkpoint as ckptlib
from repro.runtime.fault import FaultInjector

Pytree = Any

_NONE = lambda x: x is None


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Commit policy for the buffered-async engine.

    quorum_frac:     commit once ceil(quorum_frac * n_clients) uplinks
                     are buffered (1.0 = the synchronous barrier).
    deadline_rounds: force-commit a non-empty buffer after this many
                     ticks without a commit (no quorum starvation).
    max_staleness:   arrivals trained against a theta more than this
                     many commits old are discarded, not folded.
    staleness_alpha: discount exponent of ``(1 + s)^-alpha``.
    """
    quorum_frac: float = 1.0
    deadline_rounds: int = 4
    max_staleness: int = 4
    staleness_alpha: float = 0.5

    @property
    def alpha(self) -> float:
        return self.staleness_alpha

    def quorum_count(self, n_clients: int) -> int:
        k = int(np.ceil(self.quorum_frac * n_clients))
        return min(max(k, 1), n_clients)


@dataclasses.dataclass
class _InFlight:
    """One uplink on the wire (client -> server, not yet accepted)."""
    client: int
    version: int          # server commit count the client trained from
    round: int            # tick the client was launched at
    deliver: int          # tick the current attempt lands
    attempt: int          # 0 = first transmission
    size: float           # |D_i|
    msg: codecs_lib.WireMessage
    metrics: Dict[str, float]


@dataclasses.dataclass
class _Buffered:
    """One verified arrival waiting in the round buffer."""
    client: int
    version: int
    round: int
    size: float
    payload: Any
    metrics: Dict[str, float]


class AsyncRoundEngine:
    """Host-sim buffered-async server around one `FedAlgorithm`.

    Drive it one tick at a time::

        eng = AsyncRoundEngine(algo, state, data_like, sizes, key,
                               config=AsyncConfig(quorum_frac=0.8),
                               injector=FaultInjector(K, crash_prob=.3))
        for t in range(T):
            commits = eng.tick(data_t)      # 0 or 1 commits per tick
        eng.flush()                         # fold any tail arrivals

    ``data_like`` is one TICK's client batch pytree (leading axes
    [K, H, ...]) — shapes only; it seeds the payload/wire templates the
    checkpoint restore path rebuilds messages with.
    """

    def __init__(self, algo, state, data_like, sizes, key,
                 config: Optional[AsyncConfig] = None,
                 injector: Optional[FaultInjector] = None,
                 codec=None):
        self.algo = algo
        self.state = state
        self.config = config or AsyncConfig()
        self.injector = injector
        self.codec = (algo.codec if codec is None
                      else codecs_lib.get_codec(codec)
                      if isinstance(codec, str) else codec)
        self.sizes = np.asarray(jax.device_get(sizes), np.float32)
        self.n_clients = int(self.sizes.shape[0])
        self.key = key

        self.tick_idx = 0
        self.version = 0            # commits so far = theta generation
        self.last_commit_tick = 0
        self.buffer: List[_Buffered] = []
        self.pending: List[_InFlight] = []
        self.events: List[dict] = []
        self._event_seq = 0         # monotone event ordering cursor
        self.buffer_ones = 0        # running popcount over the buffer
        self.totals = {"uplink_bits_measured": 0.0,
                       "uplink_header_bits": 0.0,
                       "downlink_bits": 0.0, "commits": 0}
        self._since_commit = {"uplink_bits_measured": 0.0,
                              "uplink_header_bits": 0.0,
                              "downlink_bits": 0.0}
        self._last_downlink_bpp = 0.0

        # -- traced phases (split at an INTEGER boundary: the packed
        # uint32 words cross between them, so the jit split cannot
        # perturb float results vs run_round's single jit) ------------
        def client_phase(state_, data, key_):
            dl, client_state = protocol.client_view(self.algo, state_,
                                                    key_)
            keys = jax.random.split(key_, self.n_clients)
            payloads, metrics = jax.vmap(
                self.algo.client_update,
                in_axes=(None, 0, 0))(client_state, data, keys)
            return dl, payloads, metrics

        self._client_phase = jax.jit(client_phase)

        def agg_phase(state_, batched, sizes_, staleness, part):
            wn = aggregation.staleness_weights(
                sizes_, staleness, self.config.staleness_alpha)
            new_state = self.algo.aggregate(state_, batched, wn, part)
            bpps = jax.vmap(lambda p: p.bpp())(batched)
            return new_state, jnp.sum(bpps * wn), wn

        self._agg_phase = jax.jit(agg_phase)

        # -- payload / wire templates (shapes are static per algo):
        # the restore path unflattens bundle arrays with this treedef
        # and rebuilds WireMessages with this meta -------------------
        pshape = jax.eval_shape(
            lambda s, d, k: self.algo.client_update(s, d, k)[0],
            state, jax.tree_util.tree_map(lambda x: x[0], data_like),
            key)
        template = jax.tree_util.tree_map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype), pshape)
        tleaves, tdef = jax.tree_util.tree_flatten(template,
                                                   is_leaf=_NONE)
        self._payload_template = template
        self._payload_treedef = tdef
        self._payload_none = tuple(l is None for l in tleaves)
        tmsg = self.codec.encode(template)
        self._wire_meta = tmsg.meta
        self._payload_cls = tmsg.payload_cls
        self._degraded_restore = False

    # -- policy shorthands ------------------------------------------------

    @property
    def quorum(self) -> int:
        return self.config.quorum_count(self.n_clients)

    def _event(self, kind: str, **kw):
        """Append an event record.  Every record carries a monotone
        ``seq`` (total order over the engine's whole life, survives
        save/restore) so a crash-restart consumer can assert
        exactly-once semantics instead of matching on event counts;
        per-delivery events additionally carry the transmission
        ``attempt`` for (round, client) idempotency keys."""
        self.events.append(dict(kind=kind, seq=self._event_seq,
                                tick=self.tick_idx, **kw))
        self._event_seq += 1

    # -- tick: launch -> deliver -> maybe commit --------------------------

    def tick(self, data, key=None) -> List[dict]:
        """One engine tick.  Returns the (possibly empty) list of
        commit metric dicts produced this tick."""
        t = self.tick_idx
        self._launch(data, t, key)
        self._deliver(t)
        out = self._maybe_commit(t)
        self.tick_idx = t + 1
        return out

    def flush(self) -> List[dict]:
        """Drain the wire (advancing ticks, no new launches) and
        force-commit whatever ends up buffered — end-of-training tail
        collection.  Bounded: retries are capped, so pending empties."""
        out: List[dict] = []
        for _ in range(100_000):
            t = self.tick_idx
            self._deliver(t)
            if not self.pending:
                out.extend(self._maybe_commit(t, force=True))
                return out
            out.extend(self._maybe_commit(t))
            self.tick_idx = t + 1
        raise RuntimeError("flush did not drain the pending queue")

    def _launch(self, data, t: int, key=None):
        if key is None:
            key = jax.random.fold_in(self.key, t)
        dl, payloads, metrics = self._client_phase(self.state, data,
                                                   key)
        if dl is not None:
            self._last_downlink_bpp = float(dl.bpp())
            dbits = float(dl.wire_bits() + dl.sidecar_bits()
                          ) * self.n_clients
            self._since_commit["downlink_bits"] += dbits
            self.totals["downlink_bits"] += dbits
        inj = self.injector
        dropped = (inj.dropped(t) if inj is not None
                   else np.zeros(self.n_clients, bool))
        delays = (inj.delay_rounds(t) if inj is not None
                  else np.zeros(self.n_clients, np.int64))
        host_metrics = {k: np.asarray(jax.device_get(v))
                        for k, v in metrics.items()}
        for c in range(self.n_clients):
            if dropped[c]:
                self._event("drop", client=c, round=t)
                continue
            msg = self.codec.encode(plds.slice_payload(payloads, c))
            if int(delays[c]) > 0:
                self._event("straggle", client=c, round=t,
                            late=int(delays[c]))
            self.pending.append(_InFlight(
                client=c, version=self.version, round=t,
                deliver=t + int(delays[c]), attempt=0,
                size=float(self.sizes[c]), msg=msg,
                metrics={k: float(v[c]) if getattr(v, "ndim", 0)
                         else float(v)
                         for k, v in host_metrics.items()}))

    def _deliver(self, t: int):
        inj = self.injector
        still: List[_InFlight] = []
        for e in self.pending:
            if e.deliver > t:
                still.append(e)
                continue
            msg = e.msg
            if inj is not None and inj.corrupt_attempt(
                    e.round, e.client, e.attempt):
                msg = dataclasses.replace(
                    e.msg, words=inj.corrupt_words(
                        e.msg.words, e.round, e.client, e.attempt))
            # the delivery consumed the wire whether or not it verifies
            abits = float(msg.wire_bits + msg.sidecar_bits)
            self._since_commit["uplink_bits_measured"] += abits
            self.totals["uplink_bits_measured"] += abits
            self._since_commit["uplink_header_bits"] += msg.header_bits
            self.totals["uplink_header_bits"] += msg.header_bits
            if not msg.verify():
                if e.attempt >= (inj.max_retries if inj else 0):
                    self._event("cut", client=e.client, round=e.round,
                                attempts=e.attempt + 1)
                    continue
                backoff = max(1, int(np.ceil(
                    inj.backoff_rounds * (e.attempt + 1))))
                self._event("corrupt_reject", client=e.client,
                            round=e.round, attempt=e.attempt,
                            retry_at=t + backoff)
                still.append(dataclasses.replace(
                    e, attempt=e.attempt + 1, deliver=t + backoff))
                continue
            staleness = self.version - e.version
            if staleness > self.config.max_staleness:
                self._event("stale_drop", client=e.client,
                            round=e.round, staleness=staleness,
                            attempt=e.attempt)
                continue
            payload = self.codec.decode(msg)
            acc = self.buffer_ones
            for w in jax.tree_util.tree_leaves(
                    getattr(payload, "words", ()), is_leaf=_NONE):
                if w is not None:
                    acc = aggregation.fold_popcount(acc, w)
            ones = acc - self.buffer_ones
            self.buffer_ones = acc
            self.buffer.append(_Buffered(
                client=e.client, version=e.version, round=e.round,
                size=e.size, payload=payload, metrics=e.metrics))
            self._event("fold", client=e.client, round=e.round,
                        staleness=staleness, ones=ones,
                        attempt=e.attempt)
        self.pending = still

    def _maybe_commit(self, t: int, force: bool = False) -> List[dict]:
        # prune anything the buffer outlived
        fresh: List[_Buffered] = []
        for e in self.buffer:
            if self.version - e.version <= self.config.max_staleness:
                fresh.append(e)
            else:
                self._event("stale_drop", client=e.client,
                            round=e.round,
                            staleness=self.version - e.version)
        self.buffer = fresh
        if not self.buffer:
            return []
        deadline = (t - self.last_commit_tick
                    >= self.config.deadline_rounds)
        if len(self.buffer) < self.quorum and not (force or deadline):
            return []
        return [self._commit(t, forced=force or deadline)]

    def _commit(self, t: int, forced: bool = False) -> dict:
        entries, self.buffer = self.buffer, []
        self.buffer_ones = 0
        B = len(entries)
        batched = plds.stack_payloads([e.payload for e in entries])
        sizes = jnp.asarray([e.size for e in entries], jnp.float32)
        stal = jnp.asarray([self.version - e.version for e in entries],
                           jnp.float32)
        part = jnp.ones((B,), bool)
        self.state, up_bpp, wn = self._agg_phase(
            self.state, batched, sizes, stal, part)
        stal_max = int(max(self.version - e.version for e in entries))
        self.version += 1
        self.last_commit_tick = t
        self.totals["commits"] += 1
        out = {"uplink_bpp": float(up_bpp),
               "downlink_bpp": self._last_downlink_bpp,
               "n_folded": B,
               "version": self.version,
               "tick": t,
               "forced": bool(forced),
               "staleness_max": stal_max,
               "clients": [e.client for e in entries]}
        out.update({k: self._since_commit[k] for k in self._since_commit})
        for k in entries[0].metrics:
            vals = jnp.asarray([e.metrics[k] for e in entries],
                               jnp.float32)
            out[k] = float(jnp.sum(vals * wn))
        self._since_commit = {k: 0.0 for k in self._since_commit}
        self._event("commit", version=self.version, folded=B,
                    forced=bool(forced))
        return out

    # -- crash-consistent checkpointing -----------------------------------

    @staticmethod
    def _payload_checksum(payload) -> int:
        """`aggregation.words_checksum` over a buffered payload's raw
        leaf bytes (uint32 words AND float sidecar alike) — the
        integrity tag `restore` re-verifies before trusting a saved
        buffer entry."""
        leaves = []
        for l in jax.tree_util.tree_leaves(payload, is_leaf=_NONE):
            if l is None:
                continue
            b = np.ascontiguousarray(np.asarray(jax.device_get(l)))
            leaves.append(np.frombuffer(b.tobytes(), dtype=np.uint8))
        return aggregation.words_checksum(leaves)

    def save(self, path: str) -> str:
        """Atomically persist the WHOLE engine: server state, buffered
        payloads, in-flight wire messages, counters, comm totals.  A
        coordinator killed right after `save` resumes byte-identically
        (`restore`), and because every fault draw is a counter hash of
        (seed, round, client, attempt), the replayed fault sequence is
        identical too."""
        arrays, extra = self._save_payload()
        return ckptlib.save_bundle(path, arrays, extra)

    def _save_payload(self):
        """(arrays, extra) the bundle persists — subclasses extend."""
        arrays: Dict[str, Any] = {}
        sleaves, _ = jax.tree_util.tree_flatten(self.state,
                                                is_leaf=_NONE)
        for j, l in enumerate(sleaves):
            arrays[f"state/{j}"] = l
        for i, e in enumerate(self.buffer):
            leaves = jax.tree_util.tree_flatten(e.payload,
                                                is_leaf=_NONE)[0]
            for j, l in enumerate(leaves):
                arrays[f"buf{i}/{j}"] = l
        for i, e in enumerate(self.pending):
            for j, w in enumerate(e.msg.words):
                arrays[f"pend{i}/w{j}"] = w
            for j, w in enumerate(e.msg.sidecar):
                arrays[f"pend{i}/s{j}"] = w
        extra = {
            "tick": self.tick_idx, "version": self.version,
            "last_commit_tick": self.last_commit_tick,
            "buffer_ones": self.buffer_ones,
            "totals": self.totals,
            "since_commit": self._since_commit,
            "last_downlink_bpp": self._last_downlink_bpp,
            "events": self.events,
            "event_seq": self._event_seq,
            "buffer": [{"client": e.client, "version": e.version,
                        "round": e.round, "size": e.size,
                        "metrics": e.metrics,
                        "checksum": self._payload_checksum(e.payload)}
                       for e in self.buffer],
            "pending": [{"client": e.client, "version": e.version,
                         "round": e.round, "deliver": e.deliver,
                         "attempt": e.attempt, "size": e.size,
                         "metrics": e.metrics,
                         "checksum": e.msg.checksum,
                         "n_words": len(e.msg.words),
                         "n_side": len(e.msg.sidecar)}
                        for e in self.pending],
        }
        return arrays, extra

    def restore(self, path: str) -> "AsyncRoundEngine":
        """Inverse of `save` onto a freshly constructed engine (same
        algo / sizes / key / config / injector).

        Every buffered payload is re-verified against the checksum
        `save` stored for it (`aggregation.words_checksum` over the raw
        leaf bytes).  On ANY mismatch the engine refuses to resume from
        the silently-corrupt buffer and falls back to the degraded
        theta-only path (`runtime.elastic.restore_theta_only`'s bundle
        twin): server state + counters survive, the buffer and in-flight
        queue are dropped, and the cut clients simply re-enter at their
        next launch — the same elasticity the protocol already has."""
        arrays, extra = ckptlib.load_bundle(path)
        return self._load_payload(arrays, extra)

    def _load_payload(self, arrays, extra) -> "AsyncRoundEngine":
        self._degraded_restore = False
        sdef = jax.tree_util.tree_structure(self.state, is_leaf=_NONE)
        nstate = sdef.num_leaves
        self.state = jax.tree_util.tree_unflatten(
            sdef, [arrays.get(f"state/{j}") for j in range(nstate)])
        self.tick_idx = int(extra["tick"])
        self.version = int(extra["version"])
        self.last_commit_tick = int(extra["last_commit_tick"])
        self.buffer_ones = int(extra["buffer_ones"])
        self.totals = dict(extra["totals"])
        self._since_commit = dict(extra["since_commit"])
        self._last_downlink_bpp = float(extra["last_downlink_bpp"])
        self.events = list(extra["events"])
        self._event_seq = int(extra.get("event_seq", len(self.events)))
        nleaf = len(self._payload_none)
        self.buffer = []
        for i, meta in enumerate(extra["buffer"]):
            leaves = [None if self._payload_none[j]
                      else arrays[f"buf{i}/{j}"] for j in range(nleaf)]
            payload = jax.tree_util.tree_unflatten(
                self._payload_treedef, leaves)
            stored = meta.get("checksum")
            if stored is not None and \
                    self._payload_checksum(payload) != int(stored):
                return self._restore_degraded(meta, i)
            self.buffer.append(_Buffered(
                client=int(meta["client"]),
                version=int(meta["version"]),
                round=int(meta["round"]), size=float(meta["size"]),
                payload=payload, metrics=dict(meta["metrics"])))
        self.pending = []
        for i, meta in enumerate(extra["pending"]):
            words = [np.asarray(arrays[f"pend{i}/w{j}"], np.uint32)
                     for j in range(int(meta["n_words"]))]
            side = [np.asarray(arrays[f"pend{i}/s{j}"], np.uint32)
                    for j in range(int(meta["n_side"]))]
            msg = codecs_lib.WireMessage(
                self.codec.name, self._payload_cls, words, side,
                self._wire_meta, checksum=int(meta["checksum"]))
            self.pending.append(_InFlight(
                client=int(meta["client"]),
                version=int(meta["version"]),
                round=int(meta["round"]),
                deliver=int(meta["deliver"]),
                attempt=int(meta["attempt"]), size=float(meta["size"]),
                msg=msg, metrics=dict(meta["metrics"])))
        return self

    def _restore_degraded(self, meta: dict, slot: int
                          ) -> "AsyncRoundEngine":
        """Checksum-mismatch fallback: keep the restored server state
        and counters (theta is what matters — `elastic` doctrine), but
        refuse the buffered payloads and in-flight queue wholesale.
        Dropped contributors re-enter at their next launch; staleness
        weighting absorbs the lost partial round."""
        self.buffer = []
        self.pending = []
        self.buffer_ones = 0
        self._degraded_restore = True
        self._event("restore_degraded", client=int(meta["client"]),
                    round=int(meta["round"]), slot=int(slot))
        return self
