"""Elastic scaling: resume a federated run on a different mesh / cohort
count.

Because the paper's global state is only (theta, seed, float leaves) —
no per-client optimizer floats — re-entry after a resize is trivial:
new cohorts re-derive local scores from theta (eq. 4). This module
re-shards the restored host arrays onto the new mesh and re-plans the
client->mesh-slice cohort assignment.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

Pytree = Any


def reshard_server(host_tree: Pytree, shardings: Pytree) -> Pytree:
    """Place host (numpy) arrays onto devices per `shardings` (a pytree
    of jax.sharding.NamedSharding matching host_tree).  Works across mesh
    shapes because the source is host-global."""
    def place(x, s):
        if x is None:
            return None
        return jax.device_put(x, s)
    return jax.tree_util.tree_map(place, host_tree, shardings,
                                  is_leaf=lambda x: x is None)


def cohort_plan(n_clients: int, n_slices: int) -> list[np.ndarray]:
    """Assign K logical clients to mesh data-slices (cohorts). On resize
    (n_slices changes) the plan is recomputed; no state migrates because
    clients are stateless between rounds."""
    return [np.arange(i, n_clients, n_slices) for i in range(n_slices)]


def scale_event_log():
    """Tiny helper used by launch/train.py to record resize events."""
    events = []

    def record(step: int, old: int, new: int, reason: str = ""):
        events.append({"step": int(step), "from": int(old),
                       "to": int(new), "reason": reason})
        return events
    return record, events
