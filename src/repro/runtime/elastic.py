"""Elastic scaling: resume a federated run on a different mesh / cohort
count.

Because the paper's global state is only (theta, seed, float leaves) —
no per-client optimizer floats — re-entry after a resize is trivial:
new cohorts re-derive local scores from theta (eq. 4). This module
re-shards the restored host arrays onto the new mesh and re-plans the
client->mesh-slice cohort assignment.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as _ckpt

Pytree = Any


def reshard_server(host_tree: Pytree, shardings: Pytree) -> Pytree:
    """Place host (numpy) arrays onto devices per `shardings` (a pytree
    of jax.sharding.NamedSharding matching host_tree).  Works across mesh
    shapes because the source is host-global."""
    def place(x, s):
        if x is None:
            return None
        return jax.device_put(x, s)
    return jax.tree_util.tree_map(place, host_tree, shardings,
                                  is_leaf=lambda x: x is None)


def cohort_plan(n_clients: int, n_slices: int) -> list[np.ndarray]:
    """Assign K logical clients to mesh data-slices (cohorts). On resize
    (n_slices changes) the plan is recomputed; no state migrates because
    clients are stateless between rounds."""
    return [np.arange(i, n_clients, n_slices) for i in range(n_slices)]


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _fit_cohort(arr: np.ndarray, like) -> np.ndarray:
    """Fit a checkpointed ``(C_old, ...)`` leaf onto a ``(C_new, ...)``
    slot: average over the old cohort axis, broadcast to the new one.
    Valid because theta/float leaves are cohort-replicated right after a
    round commit (every cohort holds the aggregated value), and mid-round
    divergence is exactly what the next round's mean would fold anyway."""
    arr = np.asarray(arr)
    like_shape = tuple(like.shape)
    if arr.shape == like_shape:
        return arr
    if arr.ndim >= 1 and arr.shape[1:] == like_shape[1:]:
        m = np.mean(arr.astype(np.float32), axis=0, keepdims=True)
        return np.broadcast_to(m, like_shape).astype(arr.dtype).copy()
    raise ValueError(
        f"cannot fit checkpoint leaf {arr.shape} onto {like_shape}")


def restore_theta_only(ckpt_dir: str, state_like: Pytree,
                       step: Optional[int] = None) -> tuple[Pytree, int]:
    """Partial restore when the full structure no longer matches (cohort
    resize, optimizer switch, algorithm variant): carry over ONLY the
    learned signal — score/float leaves, which are mesh/cohort-agnostic
    (see module docstring) — and rebuild everything else from
    `state_like`:

      * scores/floats   <- checkpoint, cohort axis refit via `_fit_cohort`
      * opt_m / opt_v   <- zeros (optimizer restarts cleanly)
      * weights         <- kept from `state_like` (seed-regenerated,
                           identical across restarts by construction)
      * step            <- the checkpoint manifest's step

    Returns ``(state, step)`` like `ckpt.restore_checkpoint`."""
    raw, manifest = _ckpt.load_raw(ckpt_dir, step)
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        state_like, is_leaf=lambda x: x is None)
    leaves = []
    for path, leaf in paths_leaves:
        key = _path_key(path)
        if leaf is None:
            leaves.append(None)
            continue
        top = key.split("/", 1)[0]
        if top in ("scores", "floats") and raw.get(key) is not None:
            leaves.append(_fit_cohort(raw[key], leaf))
        elif top in ("opt_m", "opt_v"):
            leaves.append(np.zeros(tuple(leaf.shape),
                                   np.asarray(leaf).dtype))
        elif key == "step":
            leaves.append(np.asarray(manifest["step"],
                                     np.asarray(leaf).dtype))
        else:
            leaves.append(leaf)
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            int(manifest["step"]))


def scale_event_log():
    """Tiny helper used by launch/train.py to record resize events."""
    events = []

    def record(step: int, old: int, new: int, reason: str = ""):
        events.append({"step": int(step), "from": int(old),
                       "to": int(new), "reason": reason})
        return events
    return record, events
