"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent :
1 attention, MQA. [arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"), lru_width=4096,
    sliding_window=2048, conv_width=4, act="gelu_tanh",
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid", n_layers=5, d_model=64,
    n_heads=4, n_kv_heads=1, d_ff=128, vocab=256, head_dim=16,
    block_pattern=("rec", "rec", "attn"), lru_width=64,
    sliding_window=8, conv_width=4, act="gelu_tanh", tie_embeddings=True,
)
