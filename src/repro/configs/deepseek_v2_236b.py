"""deepseek-v2-236b [moe] — MLA kv_lora=512 q_lora=1536, 2 shared +
160 routed experts top-6. [arXiv:2405.04434; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=12288, vocab=102400,
    kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    first_dense_layers=1,
)

SMOKE = ArchConfig(
    name="dsv2-236b-smoke", family="moe", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16,
    n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=32,
    first_dense_layers=1,
)
