"""whisper-medium [audio] — enc-dec, conv frontend stubbed to
precomputed frame embeddings. [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865, head_dim=64,
    enc_layers=24, enc_seq=1500, norm="layer", act="gelu",
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
    enc_layers=2, enc_seq=32, norm="layer", act="gelu",
)
