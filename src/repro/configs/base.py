"""Unified architecture config schema for the model zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention pattern
    sliding_window: Optional[int] = None    # local-attn window size
    global_every: int = 0       # gemma3: 1 global layer per this many (0=all global)
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0          # gemma3 global layers (0 = same)
    qkv_bias: bool = False
    attn_soft_cap: Optional[float] = None

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0          # routed experts (0 = dense FFN)
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    conv_width: int = 4

    # hybrid (recurrentgemma)
    block_pattern: Tuple[str, ...] = ()     # e.g. ("rec", "rec", "attn")
    lru_width: int = 0

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500         # frame count after conv frontend (stub)

    # vlm
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # misc
    scan_unroll: int = 1    # lax.scan unroll for layer stacks (roofline)
    remat: bool = False     # activation-checkpoint each layer block
    moe_block_dispatch: int = 0  # >0: G-block-local MoE dispatch (perf)
    window_kv_cache: bool = False  # ring-buffer cache for local layers
    logit_sharding: tuple = ()   # with_sharding_constraint spec for logits
    act: str = "silu"
    norm: str = "rms"           # rms | layer
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Approximate total parameter count N (for 6ND roofline math)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            din = self.ssm_expand * d
            nh = din // self.ssm_headdim
            per = d * (2 * din + 2 * self.ssm_ngroups * self.ssm_state
                       + nh) + din * d + din  # in_proj(z,x,B,C,dt)+out
            return emb + L * per
        hd = self.hd
        if self.kv_lora_rank:  # MLA
            qk = self.qk_nope_dim + self.qk_rope_dim
            attn = d * (self.kv_lora_rank + self.qk_rope_dim)
            attn += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_head_dim)
            if self.q_lora_rank:
                attn += d * self.q_lora_rank \
                    + self.q_lora_rank * self.n_heads * qk
            else:
                attn += d * self.n_heads * qk
            attn += self.n_heads * self.v_head_dim * d
        else:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
        dense_ffn = 3 * d * self.d_ff
        if self.n_experts:
            moe_ffn = 3 * d * self.moe_d_ff * (
                self.n_experts + self.n_shared_experts)
            n_moe = L - self.first_dense_layers
            ffn_total = (self.first_dense_layers * dense_ffn
                         + n_moe * moe_ffn)
        else:
            ffn_total = L * dense_ffn
        n_attn_layers = L
        if self.block_pattern:
            # hybrid: recurrent blocks replace attention
            n_rec = round(L * self.block_pattern.count("rec")
                          / len(self.block_pattern))
            n_attn_layers = L - n_rec
            lru = self.lru_width or d
            rec = d * lru * 3 + lru * d + 2 * lru  # gates+in/out proj
            ffn_total += 0  # ffn in every block already counted
            return emb + n_attn_layers * attn + n_rec * rec + ffn_total
        if self.family == "encdec":
            # enc self-attn + dec self-attn + dec cross-attn
            return emb + (self.enc_layers + L) * (attn + dense_ffn) \
                + L * attn
        return emb + n_attn_layers * attn + ffn_total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        total = self.param_count()
        all_moe = 3 * d * self.moe_d_ff * self.n_experts \
            * (L - self.first_dense_layers)
        act_moe = 3 * d * self.moe_d_ff * self.top_k \
            * (L - self.first_dense_layers)
        return total - all_moe + act_moe


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic memory path); see docs/DESIGN.md
LONG_CONTEXT_OK = {"mamba2-370m", "recurrentgemma-9b", "gemma3-4b"}
