"""Config registry: --arch <id> resolution."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, \
    LONG_CONTEXT_OK  # noqa: F401

from repro.configs import (  # noqa: F401
    gemma3_4b, internlm2_1_8b, deepseek_7b, qwen2_7b,
    deepseek_v2_lite_16b, deepseek_v2_236b, whisper_medium, mamba2_370m,
    qwen2_vl_2b, recurrentgemma_9b,
)

_REGISTRY = {
    m.CONFIG.name: m for m in (
        gemma3_4b, internlm2_1_8b, deepseek_7b, qwen2_7b,
        deepseek_v2_lite_16b, deepseek_v2_236b, whisper_medium,
        mamba2_370m, qwen2_vl_2b, recurrentgemma_9b)
}

ARCH_NAMES = tuple(_REGISTRY)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = _REGISTRY[name]
    return mod.SMOKE if smoke else mod.CONFIG
