"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision frontend
stubbed to precomputed patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936, head_dim=128,
    qkv_bias=True, mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    qkv_bias=True, mrope_sections=(2, 3, 3), rope_theta=1_000_000.0,
)
