"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
    n_heads=8, n_kv_heads=4, d_ff=10240, vocab=262144, head_dim=256,
    sliding_window=1024, global_every=5, rope_theta=10000.0,
    rope_theta_global=1_000_000.0, act="gelu_tanh",
)

SMOKE = ArchConfig(
    name="gemma3-smoke", family="dense", n_layers=6, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    sliding_window=8, global_every=5, rope_theta=10000.0,
    rope_theta_global=1_000_000.0, act="gelu_tanh",
)
