"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
    conv_width=4, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_ngroups=1,
    conv_width=4, tie_embeddings=True,
)
