# The paper's primary contribution: regularized sparse random-network
# federated training (FedPM + entropy-proxy regularizer).
from repro.core import masking, regularizer, aggregation, federated  # noqa
from repro.core.masking import MaskSpec, MaskedParams  # noqa: F401
from repro.core.federated import FedConfig, ServerState  # noqa: F401
