"""Server-side aggregation (eq. 8) and the bit-packed mask collectives.

Two transport paths for the uplink inside a TPU mesh:

  * ``psum_bf16``  — m cast to bf16, ``jax.lax.psum`` over client axes.
    Simple, but moves 16 bits/parameter on the wire.
  * ``packed_allgather`` — m bit-packed 32->1 into uint32 (Pallas kernel
    on TPU, jnp fallback elsewhere), ``all_gather`` of the packed words,
    then unpack+weighted-mean locally. Moves ~1 bit/parameter/client on
    each link — the paper's 1 Bpp uplink, TPU-native.

Bayesian (Beta-prior) aggregation from FedPM is included as an option.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

# ---------------------------------------------------------------------------
# Bit packing — the public serialization trio (pure-jnp reference; the
# Pallas variant lives in repro.kernels.bitpack):
#
#     flat, pad = pad_to_words(mask.reshape(-1))   # zero-pad to 32k bits
#     words     = pack_bits(flat)                  # uint32 words, 32->1
#     mask_back = unpack_bits(words, mask.size)    # lossless inverse
#
# `repro.api.payloads` builds every `BitpackedMasks` uplink through
# these, and `federated.final_artifact` serializes the deployable
# artifact with them.
# ---------------------------------------------------------------------------


def pack_bits(mask_flat: jax.Array) -> jax.Array:
    """Pack a flat {0,1} uint8/float vector into uint32 words (little-end).

    Length must be a multiple of 32 (pad with `pad_to_words` first).
    """
    assert mask_flat.ndim == 1 and mask_flat.size % 32 == 0
    bits = mask_flat.astype(jnp.uint32).reshape(-1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=1).astype(jnp.uint32)


def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    """Inverse of pack_bits -> uint8 vector of length n (padding bits
    beyond n are dropped)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(jnp.uint8)


def pad_to_words(x: jax.Array, word_bits: int = 32):
    """Flatten and zero-pad `x` to a multiple of `word_bits` entries.

    Returns (flat_padded, pad_count).  Zero padding is what makes
    `unpack_bits(pack_bits(...), n)` an exact round trip and keeps
    entropy accounting honest (pad bits are never counted as params).
    """
    pad = (-x.size) % word_bits
    x = x.reshape(-1)
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), dtype=x.dtype)])
    return x, pad


# Backwards-compatible alias (pre-1.0 private name).
_pad32 = pad_to_words


# ---------------------------------------------------------------------------
# Host-side (simulation) aggregation: list of client masks -> theta
# ---------------------------------------------------------------------------


def aggregate_masks(masks: Sequence[Pytree],
                    weights: Sequence[float] | None = None) -> Pytree:
    """eq. (8): theta(t+1) = sum_i |D_i| m̂_i / sum_k |D_k|.

    `masks` is a list of client mask pytrees (uint8 leaves / None).
    """
    if weights is None:
        weights = [1.0] * len(masks)
    wsum = float(sum(weights))
    ws = [w / wsum for w in weights]

    def one(*ms):
        if ms[0] is None:
            return None
        acc = jnp.zeros(ms[0].shape, jnp.float32)
        for w, m in zip(ws, ms):
            acc = acc + w * m.astype(jnp.float32)
        return acc

    return jax.tree_util.tree_map(one, *masks,
                                  is_leaf=lambda x: x is None)


def aggregate_bayesian(masks: Sequence[Pytree], alpha0: float = 1.0,
                       beta0: float = 1.0) -> Pytree:
    """FedPM's Bayesian aggregation: Beta(alpha0+ones, beta0+zeros) mean.

    Slightly better-calibrated theta for small cohorts (beyond-paper
    option; the paper itself uses the weighted arithmetic mean).
    """
    k = len(masks)

    def one(*ms):
        if ms[0] is None:
            return None
        ones = jnp.zeros(ms[0].shape, jnp.float32)
        for m in ms:
            ones = ones + m.astype(jnp.float32)
        return (alpha0 + ones) / (alpha0 + beta0 + k)

    return jax.tree_util.tree_map(one, *masks,
                                  is_leaf=lambda x: x is None)


def aggregate_floats(float_trees: Sequence[Pytree],
                     weights: Sequence[float] | None = None) -> Pytree:
    """FedAvg for the non-masked float leaves (norms, biases...)."""
    if weights is None:
        weights = [1.0] * len(float_trees)
    wsum = float(sum(weights))
    ws = [w / wsum for w in weights]

    def one(*fs):
        if fs[0] is None:
            return None
        acc = jnp.zeros(fs[0].shape, jnp.float32)
        for w, f in zip(ws, fs):
            acc = acc + w * f.astype(jnp.float32)
        return acc.astype(fs[0].dtype)

    return jax.tree_util.tree_map(one, *float_trees,
                                  is_leaf=lambda x: x is None)


def sample_and_pack_rows(flat_scores: jax.Array, seeds: jax.Array,
                         use_kernel: bool = False, mode: str = "sample",
                         tau: float = 0.5) -> jax.Array:
    """Fused per-cohort uplink sampling + 32->1 bitpack.

    (C, n) score rows + (C,) uint32 seeds -> (C, ceil(n/32)) uint32
    words of m ~ Bern(sigmoid(scores)), where row c draws from the
    counter-based hash stream seeded by seeds[c] (the same stream the
    fused masked-matmul kernels regenerate).  With ``use_kernel`` the
    one-pass Pallas kernel runs (scores -> hash -> Bernoulli -> words;
    no uint8 mask in HBM); otherwise the pure-jnp two-pass reference —
    the two are bit-identical.  ``mode="threshold"`` packs the
    deterministic FedMask mask 1[sigmoid(s) > tau] (seeds ignored).
    """
    if use_kernel:
        from repro.kernels import ops as _kops
        return _kops.sample_and_pack(flat_scores, seeds, mode=mode,
                                     tau=tau)
    from repro.kernels import ref as _kref
    return _kref.sample_and_pack(flat_scores, seeds, mode=mode, tau=tau)


# ---------------------------------------------------------------------------
# Buffered-async aggregation support (repro.runtime.async_engine):
# staleness-discounted survivor weights + the wire-integrity checksum
# ---------------------------------------------------------------------------


def staleness_weight(staleness, alpha: float = 1.0):
    """FedBuff-style polynomial staleness discount ``(1 + s)^-alpha``.

    ``staleness`` counts COMMITS between the theta a client trained
    against and the round its mask is folded into; s = 0 (in-time)
    gives weight 1.0 exactly, so the zero-fault async engine reduces to
    the synchronous weighted mean bit-for-bit.  Works on Python floats
    and np/jnp arrays alike.
    """
    if hasattr(staleness, "dtype"):
        one = np.float32(1.0) if isinstance(staleness, np.ndarray) \
            else jnp.float32(1.0)
        return (one + staleness) ** (-alpha)
    return float((1.0 + staleness) ** (-alpha))


def staleness_weights(sizes, staleness, alpha: float = 1.0):
    """Normalized fold weights for a commit buffer: |D_i| discounted by
    per-entry staleness, renormalized over the buffer — the SAME
    formula `repro.api.protocol.run_round` applies to its participation
    vector (`w = sizes * pf; wn = w / max(sum(w), 1e-9)`), so a buffer
    of all-fresh arrivals aggregates identically to a synchronous
    round."""
    sizes = jnp.asarray(sizes, jnp.float32)
    disc = jnp.asarray(staleness_weight(
        jnp.asarray(staleness, jnp.float32), alpha), jnp.float32)
    # s == 0 must contribute exactly `sizes` (discount is exactly 1.0)
    w = jnp.where(disc == 1.0, sizes, sizes * disc)
    return w / jnp.maximum(jnp.sum(w), 1e-9)


def fold_popcount(acc_ones, words) -> int:
    """Running popcount fold: add one arrival's packed-word one-counts
    to a host accumulator (the async engine's live buffer statistic).
    The device does the popcount; the running sum lives in an unbounded
    Python int so the fold is exact at any scale — bits are integers,
    no float accumulation order issues."""
    ones = jnp.sum(jax.lax.population_count(
        jnp.asarray(words, jnp.uint32)).astype(jnp.int32))
    return int(acc_ones) + int(ones)


def fold_bit_counts(acc, words):
    """Per-bit-position count fold: add one (or a batch of) client's
    packed uint32 words into an integer per-parameter count accumulator
    — the edge aggregator's O(params) pooled state.

    ``acc`` is int32[P] over the padded word domain (P = 32 * n_words);
    ``words`` is uint32[W] (one client) or uint32[B, W] (a chunk of B
    clients, summed in one pass).  Counts are exact integers, so the
    fold is associative and lossless: ANY grouping of clients into
    edges produces the identical accumulator — the property the
    aggregator tree's bit-identity gate rests on.
    """
    w = jnp.asarray(words, jnp.uint32)
    if w.ndim == 1:
        w = w[None, :]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((w[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    return jnp.asarray(acc, jnp.int32) + bits.reshape(w.shape[0], -1
                                                      ).sum(axis=0)


_COUNT_DTYPES = {8: "<u1", 16: "<u2", 32: "<u4"}


def pack_counts(counts, acc_bits: int = 16) -> np.ndarray:
    """Fixed-width serialization of a count accumulator into uint32
    words (host-side, little-endian): each per-bit count rides in an
    ``acc_bits``-wide field, so the record size depends ONLY on the
    parameter count and the field width — never on how many clients
    were folded.  ``acc_bits`` must be 8, 16 or 32; a count that does
    not fit the field is a hard error (silent truncation would forge
    the fold)."""
    if acc_bits not in _COUNT_DTYPES:
        raise ValueError(f"acc_bits must be one of 8/16/32, "
                         f"got {acc_bits}")
    c = np.asarray(counts).reshape(-1)
    if c.size and (int(c.max()) >> acc_bits or int(c.min()) < 0):
        raise OverflowError(
            f"count {int(c.max())} does not fit {acc_bits}-bit "
            f"accumulator field")
    per = 32 // acc_bits
    pad = (-c.size) % per
    c = c.astype(np.uint64)
    if pad:
        c = np.concatenate([c, np.zeros((pad,), np.uint64)])
    return np.ascontiguousarray(
        c.astype(_COUNT_DTYPES[acc_bits])).view("<u4").astype(np.uint32)


def unpack_counts(words, n: int, acc_bits: int = 16) -> np.ndarray:
    """Inverse of `pack_counts`: uint32 word stream -> int64[n] counts
    (padding fields beyond n are dropped)."""
    if acc_bits not in _COUNT_DTYPES:
        raise ValueError(f"acc_bits must be one of 8/16/32, "
                         f"got {acc_bits}")
    w = np.ascontiguousarray(np.asarray(words, np.uint32).astype("<u4"))
    return w.view(_COUNT_DTYPES[acc_bits])[:n].astype(np.int64)


def packed_count_bits(n_positions: int, acc_bits: int = 16) -> int:
    """Exact serialized size in bits of one `pack_counts` stream over
    ``n_positions`` count fields (word-aligned)."""
    per = 32 // acc_bits
    return 32 * ((n_positions + per - 1) // per)


def words_checksum(arrays) -> int:
    """CRC32 checksum over serialized uint32 word streams — the
    per-message integrity header `repro.api.codecs.WireMessage` carries
    (host-side: the wire is host bytes).  `arrays` is a sequence of
    uint32 numpy arrays; the checksum covers their concatenated
    little-endian bytes, so any single bit flip in transit changes it.
    """
    import zlib
    h = 0
    for a in arrays:
        b = np.ascontiguousarray(
            np.asarray(a, np.uint32).astype("<u4")).tobytes()
        h = zlib.crc32(b, h)
    return int(h & 0xFFFFFFFF)


# ---------------------------------------------------------------------------
# In-mesh collectives (used under shard_map over client axes)
# ---------------------------------------------------------------------------


def mask_mean_psum(mask: Pytree, axis_names) -> Pytree:
    """bf16 psum path: theta = mean over client axes. 16 bits/param."""
    names = (axis_names if isinstance(axis_names, (tuple, list))
             else (axis_names,))

    def one(m):
        if m is None:
            return None
        s = jax.lax.psum(m.astype(jnp.bfloat16), names)
        k = 1
        for a in names:
            k *= jax.lax.axis_size(a)
        return s.astype(jnp.float32) / k

    return jax.tree_util.tree_map(one, mask, is_leaf=lambda x: x is None)


def mask_mean_packed(mask: Pytree, axis_names, use_kernel: bool = False
                     ) -> Pytree:
    """Bit-packed path: pack 32 mask bits -> uint32, all_gather packed
    words over client axes, unpack + mean locally. ~1 bit/param/client on
    the wire (vs 16 for bf16 psum).
    """
    names = (axis_names if isinstance(axis_names, (tuple, list))
             else (axis_names,))

    if use_kernel:
        from repro.kernels import ops as _kops
        _pack = _kops.pack_bits
    else:
        _pack = pack_bits

    def one(m):
        if m is None:
            return None
        shape = m.shape
        flat, _ = pad_to_words(m.reshape(-1))
        words = _pack(flat)
        gathered = words
        for a in names:
            gathered = jax.lax.all_gather(gathered, a)
        gathered = gathered.reshape(-1, words.size)
        k = gathered.shape[0]
        # popcount-style unpack-mean: accumulate per-bit sums
        shifts = jnp.arange(32, dtype=jnp.uint32)
        bits = ((gathered[:, :, None] >> shifts) & jnp.uint32(1))
        mean = jnp.mean(bits.astype(jnp.float32), axis=0)
        return mean.reshape(-1)[:m.size].reshape(shape)

    return jax.tree_util.tree_map(one, mask, is_leaf=lambda x: x is None)


def uplink_bits(mask: Pytree, packed: bool = True) -> int:
    """Static accounting: bits a client sends for this mask pytree."""
    n = sum(m.size for m in jax.tree_util.tree_leaves(mask)
            if m is not None)
    if packed:
        return ((n + 31) // 32) * 32
    return n * 16  # bf16 transport


# ---------------------------------------------------------------------------
# Downlink compression (beyond-paper): stochastic k-bit theta broadcast
# ---------------------------------------------------------------------------


def quantize_theta(theta: Pytree, key, bits: int = 8) -> Pytree:
    """Unbiased stochastic quantization of the server's probability mask
    for the downlink broadcast (the paper counts UL masks only; with
    8-bit DL the full round costs ~(1 UL + 8/rounds DL) bits/param).

    Returns uint8/uint16 leaves in [0, 2^bits - 1].
    """
    levels = (1 << bits) - 1
    dtype = jnp.uint8 if bits <= 8 else jnp.uint16
    leaves = [t for t in jax.tree_util.tree_leaves(
        theta, is_leaf=lambda x: x is None)]
    n = sum(1 for t in leaves if t is not None)
    keys = jax.random.split(key, max(n, 1))
    it = iter(range(n))

    def one(t):
        if t is None:
            return None
        k = keys[next(it)]
        x = jnp.clip(t.astype(jnp.float32), 0.0, 1.0) * levels
        lo = jnp.floor(x)
        up = jax.random.uniform(k, t.shape) < (x - lo)  # stochastic
        return (lo + up).astype(dtype)

    return jax.tree_util.tree_map(one, theta,
                                  is_leaf=lambda x: x is None)


def dequantize_theta(q: Pytree, bits: int = 8) -> Pytree:
    levels = (1 << bits) - 1
    return jax.tree_util.tree_map(
        lambda t: None if t is None else
        t.astype(jnp.float32) / levels,
        q, is_leaf=lambda x: x is None)
