"""Core mask-training primitives (the paper's technique).

The paper trains *scores* ``s`` over a frozen random network ``w_init``:

    theta = sigmoid(s)                 # probability mask, eq. (4) inverse
    m ~ Bernoulli(theta)               # sampled sub-network selector
    y(x) = f(x; m * w_init)            # eq. (1)

Gradients reach ``s`` through the non-differentiable sample via a
straight-through estimator (STE): d m / d theta := 1.

Everything here is pytree-generic: a model is any pytree of parameter
leaves; which leaves are maskable is decided by a `MaskSpec` predicate so
norm scales / biases / routers can stay float (see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any

# ---------------------------------------------------------------------------
# Frozen random weights (the "SEED, not weights" artifact)
# ---------------------------------------------------------------------------


def signed_constant_init(key: jax.Array, shape, fan_in: int, dtype=jnp.float32):
    """Paper §IV: weights ~ Uniform{-c, +c} with c = std of Kaiming Normal.

    Kaiming Normal std for fan_in is sqrt(2 / fan_in).
    """
    c = jnp.sqrt(jnp.asarray(2.0 / max(fan_in, 1), dtype=dtype))
    sign = jax.random.rademacher(key, shape, dtype=dtype)
    return sign * c


def score_init(key: jax.Array, shape, dtype=jnp.float32, p0: float = 0.5,
               jitter: float = 0.0):
    """Initial scores such that sigmoid(s) ~= p0 (paper: theta ~ U[0,1]).

    With jitter > 0, theta ~ U[p0-jitter, p0+jitter] via logit sampling.
    The paper samples the *global* initial theta from U[0,1]; we default
    to exactly that when p0=0.5, jitter=0.5.
    """
    if jitter > 0:
        u = jax.random.uniform(key, shape, dtype=dtype,
                               minval=max(p0 - jitter, 1e-4),
                               maxval=min(p0 + jitter, 1 - 1e-4))
        return jnp.log(u) - jnp.log1p(-u)  # logit
    p = jnp.asarray(min(max(p0, 1e-4), 1 - 1e-4), dtype=dtype)
    return jnp.full(shape, jnp.log(p) - jnp.log1p(-p), dtype=dtype)


# ---------------------------------------------------------------------------
# STE Bernoulli sampling
# ---------------------------------------------------------------------------


@jax.custom_vjp
def ste_bernoulli(theta: jax.Array, u: jax.Array) -> jax.Array:
    """m = 1[u < theta], straight-through: dm/dtheta := 1.

    ``u`` is uniform noise with theta's shape (passed in so the caller
    controls the RNG stream; keeps this function re-traceable under scan).
    """
    return (u < theta).astype(theta.dtype)


def _ste_fwd(theta, u):
    return ste_bernoulli(theta, u), None


def _ste_bwd(_, g):
    return (g, None)


ste_bernoulli.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def ste_threshold(theta: jax.Array, tau: float) -> jax.Array:
    """Deterministic mask m = 1[theta > tau] with STE (FedMask-style)."""
    return (theta > tau).astype(theta.dtype)


def _stet_fwd(theta, tau):
    return ste_threshold(theta, tau), None


def _stet_bwd(_, g):
    return (g, None)


ste_threshold.defvjp(_stet_fwd, _stet_bwd)


def sigmoid(s):
    return jax.nn.sigmoid(s)


def logit(theta, eps=1e-6):
    theta = jnp.clip(theta, eps, 1.0 - eps)
    return jnp.log(theta) - jnp.log1p(-theta)


# ---------------------------------------------------------------------------
# MaskSpec: which leaves of a model are masked
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Decides per-leaf (by pytree path) whether the paper's technique
    applies. Default: mask every >=2D tensor except paths matching
    `float_patterns` (norms, biases, routers, recurrence params...).
    """
    float_patterns: tuple = ("norm", "bias", "scale", "router", "a_param",
                             "dt", "A_log", "D", "embed_float")
    mask_embeddings: bool = False
    min_ndim: int = 2

    def is_masked(self, path: str, leaf: jax.Array) -> bool:
        lp = path.lower()
        if any(p in lp for p in self.float_patterns):
            return False
        if not self.mask_embeddings and ("embed" in lp or "unembed" in lp
                                         or "lm_head" in lp):
            return False
        if getattr(leaf, "ndim", 0) < self.min_ndim:
            return False
        return True


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def leaves_with_paths(tree: Pytree):
    return [( _path_str(p), l) for p, l in
            jax.tree_util.tree_flatten_with_path(tree)[0]]


# ---------------------------------------------------------------------------
# MaskedState: (frozen weights, scores) pytrees
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaskedParams:
    """Pytree wrapper for a model under mask-training.

    weights: frozen random values (regenerable from `seed`).
    scores:  trainable logits; None-shaped (0-size) where spec says float.
    floats:  trainable float leaves (norms, biases, ...) — FedAvg'd.
    """
    weights: Pytree
    scores: Pytree
    floats: Pytree

    def tree_flatten(self):
        return (self.weights, self.scores, self.floats), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def split_params(params: Pytree, spec: MaskSpec):
    """Split a plain param pytree into (maskable, float) by spec.

    Returns boolean pytree `is_masked` mirroring params.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    treedef = flat[1]
    decisions = [spec.is_masked(_path_str(p), l) for p, l in flat[0]]
    return jax.tree_util.tree_unflatten(treedef, decisions)


def init_masked(key: jax.Array, params_like: Pytree, spec: MaskSpec,
                fan_in_fn: Callable = None, score_dtype=jnp.float32,
                weight_dtype=jnp.bfloat16) -> MaskedParams:
    """Build MaskedParams from a template pytree (shapes/dtypes).

    For maskable leaves: weights <- signed-constant init, scores <- logit
    of U[0,1] (paper's theta init).  Float leaves keep the template value.
    """
    is_masked = split_params(params_like, spec)
    flat, treedef = jax.tree_util.tree_flatten(params_like)
    flat_mask, _ = jax.tree_util.tree_flatten(is_masked)
    n = len(flat)
    keys = jax.random.split(key, 2 * n)

    weights, scores, floats = [], [], []
    for i, (leaf, masked) in enumerate(zip(flat, flat_mask)):
        if masked:
            fan_in = leaf.shape[0] if leaf.ndim >= 2 else leaf.size
            if fan_in_fn is not None:
                fan_in = fan_in_fn(leaf)
            weights.append(signed_constant_init(keys[2 * i], leaf.shape,
                                                fan_in, weight_dtype))
            scores.append(score_init(keys[2 * i + 1], leaf.shape,
                                     score_dtype, p0=0.5, jitter=0.5))
            floats.append(None)
        else:
            weights.append(None)
            scores.append(None)
            floats.append(leaf)

    mk = lambda lst: jax.tree_util.tree_unflatten(treedef, lst)
    return MaskedParams(mk(weights), mk(scores), mk(floats))


def sample_effective(mp: MaskedParams, key: jax.Array,
                     mode: str = "sample", tau: float = 0.5) -> Pytree:
    """Materialize effective params: m * w for masked leaves, floats as-is.

    mode: "sample"    -> m ~ Bern(sigmoid(s)) with STE (training, paper)
          "threshold" -> m = 1[sigmoid(s) > tau]        (eval / FedMask)
          "expected"  -> m = sigmoid(s)                  (mean network)
    """
    flat_w, treedef = jax.tree_util.tree_flatten(
        mp.weights, is_leaf=lambda x: x is None)
    flat_s, _ = jax.tree_util.tree_flatten(
        mp.scores, is_leaf=lambda x: x is None)
    flat_f, _ = jax.tree_util.tree_flatten(
        mp.floats, is_leaf=lambda x: x is None)

    n_masked = sum(1 for w in flat_w if w is not None)
    keys = jax.random.split(key, max(n_masked, 1))
    out, ki = [], 0
    for w, s, f in zip(flat_w, flat_s, flat_f):
        if w is None:
            out.append(f)
            continue
        theta = sigmoid(s.astype(jnp.float32))
        if mode == "sample":
            u = jax.random.uniform(keys[ki], s.shape, dtype=jnp.float32)
            m = ste_bernoulli(theta, u)
        elif mode == "threshold":
            m = ste_threshold(theta, tau)
        elif mode == "expected":
            m = theta
        else:
            raise ValueError(mode)
        ki += 1
        out.append((m.astype(w.dtype) * w))
    return jax.tree_util.tree_unflatten(treedef, out)


def final_mask(mp: MaskedParams, key: jax.Array) -> Pytree:
    """Sample the per-round uplink mask m̂_i ~ Bern(θ̂_i)  (eq. before (8)).

    Returns a pytree with uint8 {0,1} leaves for masked params, None else.
    """
    def one(s, k):
        if s is None:
            return None
        u = jax.random.uniform(k, s.shape, dtype=jnp.float32)
        return (u < sigmoid(s.astype(jnp.float32))).astype(jnp.uint8)

    flat_s, treedef = jax.tree_util.tree_flatten(
        mp.scores, is_leaf=lambda x: x is None)
    keys = jax.random.split(key, max(len(flat_s), 1))
    out = [one(s, k) for s, k in zip(flat_s, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def scores_from_theta(theta_tree: Pytree) -> Pytree:
    """Client-side round start: s = logit(theta)  (eq. 4)."""
    return jax.tree_util.tree_map(
        lambda t: None if t is None else logit(t.astype(jnp.float32)),
        theta_tree, is_leaf=lambda x: x is None)


def count_params(tree: Pytree) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(tree)
               if l is not None)
