"""Core mask-training primitives (the paper's technique).

The paper trains *scores* ``s`` over a frozen random network ``w_init``:

    theta = sigmoid(s)                 # probability mask, eq. (4) inverse
    m ~ Bernoulli(theta)               # sampled sub-network selector
    y(x) = f(x; m * w_init)            # eq. (1)

Gradients reach ``s`` through the non-differentiable sample via a
straight-through estimator (STE): d m / d theta := 1.

Everything here is pytree-generic: a model is any pytree of parameter
leaves; which leaves are maskable is decided by a `MaskSpec` predicate so
norm scales / biases / routers can stay float (see docs/DESIGN.md
§Arch-applicability).

Two execution paths consume these primitives (docs/DESIGN.md §3):

  * the FUSED path — `masked_forward_tree` merges (weights, scores,
    floats) into one params pytree whose maskable leaves are
    `MaskedLeaf` bundles; the model zoo routes those through the Pallas
    kernels (`repro.models.layers.masked_dense_apply`), regenerating
    the mask per tile from the counter-based hash stream.
  * the REFERENCE path — `sample_effective` (PRNG draw; serving, eval,
    the host-sim engine) and `hash_effective` (the materialized twin of
    the fused path: identical hash-stream masks, effective params at
    full weight size).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _kref

Pytree = Any

# ---------------------------------------------------------------------------
# Frozen random weights (the "SEED, not weights" artifact)
# ---------------------------------------------------------------------------


def signed_constant_init(key: jax.Array, shape, fan_in: int, dtype=jnp.float32):
    """Paper §IV: weights ~ Uniform{-c, +c} with c = std of Kaiming Normal.

    Kaiming Normal std for fan_in is sqrt(2 / fan_in).
    """
    c = jnp.sqrt(jnp.asarray(2.0 / max(fan_in, 1), dtype=dtype))
    sign = jax.random.rademacher(key, shape, dtype=dtype)
    return sign * c


def score_init(key: jax.Array, shape, dtype=jnp.float32, p0: float = 0.5,
               jitter: float = 0.0):
    """Initial scores such that sigmoid(s) ~= p0 (paper: theta ~ U[0,1]).

    With jitter > 0, theta ~ U[p0-jitter, p0+jitter] via logit sampling.
    The paper samples the *global* initial theta from U[0,1]; we default
    to exactly that when p0=0.5, jitter=0.5.
    """
    if jitter > 0:
        u = jax.random.uniform(key, shape, dtype=dtype,
                               minval=max(p0 - jitter, 1e-4),
                               maxval=min(p0 + jitter, 1 - 1e-4))
        return jnp.log(u) - jnp.log1p(-u)  # logit
    p = jnp.asarray(min(max(p0, 1e-4), 1 - 1e-4), dtype=dtype)
    return jnp.full(shape, jnp.log(p) - jnp.log1p(-p), dtype=dtype)


# ---------------------------------------------------------------------------
# STE Bernoulli sampling
# ---------------------------------------------------------------------------


@jax.custom_vjp
def ste_bernoulli(theta: jax.Array, u: jax.Array) -> jax.Array:
    """m = 1[u < theta], straight-through: dm/dtheta := 1.

    ``u`` is uniform noise with theta's shape (passed in so the caller
    controls the RNG stream; keeps this function re-traceable under scan).
    """
    return (u < theta).astype(theta.dtype)


def _ste_fwd(theta, u):
    return ste_bernoulli(theta, u), None


def _ste_bwd(_, g):
    return (g, None)


ste_bernoulli.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def ste_threshold(theta: jax.Array, tau: float) -> jax.Array:
    """Deterministic mask m = 1[theta > tau] with STE (FedMask-style)."""
    return (theta > tau).astype(theta.dtype)


def _stet_fwd(theta, tau):
    return ste_threshold(theta, tau), None


def _stet_bwd(_, g):
    return (g, None)


ste_threshold.defvjp(_stet_fwd, _stet_bwd)


def sigmoid(s):
    return jax.nn.sigmoid(s)


def logit(theta, eps=1e-6):
    theta = jnp.clip(theta, eps, 1.0 - eps)
    return jnp.log(theta) - jnp.log1p(-theta)


# ---------------------------------------------------------------------------
# MaskSpec: which leaves of a model are masked
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Decides per-leaf (by pytree path) whether the paper's technique
    applies. Default: mask every >=2D tensor except paths matching
    `float_patterns` (norms, biases, routers, recurrence params...).
    """
    float_patterns: tuple = ("norm", "bias", "scale", "router", "a_param",
                             "dt", "A_log", "D", "embed_float")
    mask_embeddings: bool = False
    min_ndim: int = 2

    def is_masked(self, path: str, leaf: jax.Array) -> bool:
        lp = path.lower()
        parts = lp.split("/")
        for p in self.float_patterns:
            pl = p.lower()
            # substring for descriptive patterns; single-letter patterns
            # ("D") must match a whole path component — patterns are
            # matched case-insensitively (the dynamics params A_log / D
            # are float: masking a decay rate destroys stability,
            # docs/DESIGN.md §Arch-applicability)
            if (len(pl) > 1 and pl in lp) or pl in parts:
                return False
        if not self.mask_embeddings and ("embed" in lp or "unembed" in lp
                                         or "lm_head" in lp):
            return False
        if getattr(leaf, "ndim", 0) < self.min_ndim:
            return False
        return True


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def leaves_with_paths(tree: Pytree):
    return [( _path_str(p), l) for p, l in
            jax.tree_util.tree_flatten_with_path(tree)[0]]


# ---------------------------------------------------------------------------
# MaskedState: (frozen weights, scores) pytrees
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaskedParams:
    """Pytree wrapper for a model under mask-training.

    weights: frozen random values (regenerable from `seed`).
    scores:  trainable logits; None-shaped (0-size) where spec says float.
    floats:  trainable float leaves (norms, biases, ...) — FedAvg'd.
    """
    weights: Pytree
    scores: Pytree
    floats: Pytree

    def tree_flatten(self):
        return (self.weights, self.scores, self.floats), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def split_params(params: Pytree, spec: MaskSpec):
    """Split a plain param pytree into (maskable, float) by spec.

    Returns boolean pytree `is_masked` mirroring params.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    treedef = flat[1]
    decisions = [spec.is_masked(_path_str(p), l) for p, l in flat[0]]
    return jax.tree_util.tree_unflatten(treedef, decisions)


def init_masked(key: jax.Array, params_like: Pytree, spec: MaskSpec,
                fan_in_fn: Callable = None, score_dtype=jnp.float32,
                weight_dtype=jnp.bfloat16) -> MaskedParams:
    """Build MaskedParams from a template pytree (shapes/dtypes).

    For maskable leaves: weights <- signed-constant init, scores <- logit
    of U[0,1] (paper's theta init).  Float leaves keep the template value.
    """
    is_masked = split_params(params_like, spec)
    flat, treedef = jax.tree_util.tree_flatten(params_like)
    flat_mask, _ = jax.tree_util.tree_flatten(is_masked)
    n = len(flat)
    keys = jax.random.split(key, 2 * n)

    weights, scores, floats = [], [], []
    for i, (leaf, masked) in enumerate(zip(flat, flat_mask)):
        if masked:
            fan_in = leaf.shape[0] if leaf.ndim >= 2 else leaf.size
            if fan_in_fn is not None:
                fan_in = fan_in_fn(leaf)
            weights.append(signed_constant_init(keys[2 * i], leaf.shape,
                                                fan_in, weight_dtype))
            scores.append(score_init(keys[2 * i + 1], leaf.shape,
                                     score_dtype, p0=0.5, jitter=0.5))
            floats.append(None)
        else:
            weights.append(None)
            scores.append(None)
            floats.append(leaf)

    mk = lambda lst: jax.tree_util.tree_unflatten(treedef, lst)
    return MaskedParams(mk(weights), mk(scores), mk(floats))


def sample_effective(mp: MaskedParams, key: jax.Array,
                     mode: str = "sample", tau: float = 0.5) -> Pytree:
    """Materialize effective params: m * w for masked leaves, floats as-is.

    mode: "sample"    -> m ~ Bern(sigmoid(s)) with STE (training, paper)
          "threshold" -> m = 1[sigmoid(s) > tau]        (eval / FedMask)
          "expected"  -> m = sigmoid(s)                  (mean network)
    """
    flat_w, treedef = jax.tree_util.tree_flatten(
        mp.weights, is_leaf=lambda x: x is None)
    flat_s, _ = jax.tree_util.tree_flatten(
        mp.scores, is_leaf=lambda x: x is None)
    flat_f, _ = jax.tree_util.tree_flatten(
        mp.floats, is_leaf=lambda x: x is None)

    n_masked = sum(1 for w in flat_w if w is not None)
    keys = jax.random.split(key, max(n_masked, 1))
    out, ki = [], 0
    for w, s, f in zip(flat_w, flat_s, flat_f):
        if w is None:
            out.append(f)
            continue
        theta = sigmoid(s.astype(jnp.float32))
        if mode == "sample":
            u = jax.random.uniform(keys[ki], s.shape, dtype=jnp.float32)
            m = ste_bernoulli(theta, u)
        elif mode == "threshold":
            m = ste_threshold(theta, tau)
        elif mode == "expected":
            m = theta
        else:
            raise ValueError(mode)
        ki += 1
        out.append((m.astype(w.dtype) * w))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Masked execution: the (w, s, seed) convention shared with the uplink
# ---------------------------------------------------------------------------


def mask_stream_seed(step, dev, leaf_idx: int, cohort, run_seed=0):
    """The deterministic (run, step, shard, leaf, cohort) -> uint32 seed
    convention for the counter-based mask sampler.

    ONE implementation serves both consumers: the per-round uplink
    (`launch.steps` -> `aggregation.sample_and_pack_rows`) and the
    fused model forward (`masked_forward_tree`), so a leaf's forward
    mask under seed sigma is bit-identical to the words
    `sample_and_pack` packs for that leaf under the same sigma.

    The sampler (`kernels.masked_matmul._hash_uniform`) turns each seed
    into a disjoint slice of one avalanche stream, so distinct seeds
    give decorrelated Bernoulli draws; mixing with large odd constants
    keeps the tuple -> seed map collision-free in practice.  `cohort`
    may be a scalar or an array (vectorized over cohorts).
    """
    base = (jnp.asarray(step, jnp.uint32) * jnp.uint32(0x9E3779B9)
            ^ (jnp.asarray(dev, jnp.uint32) + jnp.uint32(1))
            * jnp.uint32(0x85EBCA6B)
            ^ jnp.uint32(leaf_idx * 0xC2B2AE35 & 0xFFFFFFFF)
            ^ jnp.asarray(run_seed, jnp.uint32) * jnp.uint32(0x7FEB352D))
    return base + jnp.asarray(cohort, jnp.uint32) * jnp.uint32(0x01000193)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaskedLeaf:
    """One maskable tensor on the fused execution path: frozen random
    weights `w`, trainable score logits `s`, and the hash-stream
    coordinates (`seed`, `off`) that make its sampled mask a slice of
    the leaf's flat uplink stream.

    For a leaf of shape lead + (K, N), `seed` and `off` have shape
    `lead`: every trailing 2-D block samples at flat hash index
    off[block] = block_idx * K * N — under `jax.lax.scan` over a
    layer-stacked (L, K, N) leaf the slices stay self-describing, and
    for a stacked (E, K, N) expert leaf the per-expert (E,)-shaped
    `seed`/`off` feed ONE grouped kernel launch
    (`ops.masked_dense_grouped`) covering all experts.  `mode`/`tau`
    are static aux data ("sample" for the Bernoulli draw, "threshold"
    for FedMask).
    """
    w: Any
    s: Any
    seed: Any
    off: Any
    mode: str = "sample"
    tau: float = 0.5

    def tree_flatten(self):
        return ((self.w, self.s, self.seed, self.off),
                (self.mode, self.tau))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @classmethod
    def build(cls, w, s, seed, mode: str = "sample", tau: float = 0.5):
        """Bundle a maskable leaf with its stream coordinates.  `seed`
        is a scalar; it is broadcast over the leading (layer-stack /
        expert / kernel-tap) axes with per-block flat-index offsets."""
        lead = w.shape[:-2]
        K, N = w.shape[-2:]
        nblk = 1
        for d in lead:
            nblk *= d
        off = (jnp.arange(nblk, dtype=jnp.uint32)
               * jnp.uint32(K * N)).reshape(lead)
        seed = jnp.broadcast_to(jnp.asarray(seed, jnp.uint32), lead)
        return cls(w, s, seed, off, mode, tau)


def materialize_leaf(leaf: MaskedLeaf) -> jax.Array:
    """Effective weights m * w for one MaskedLeaf, masks bit-identical
    to the fused kernels' (same hash stream, same offsets), STE grads.

    The materializing fallback — one weight-sized temporary.  Since
    the grouped-expert and conv kernels landed, no training-path
    consumer needs it: it backs `hash_effective` (the REPRO_EFF_PATH=1
    twin), `freeze_for_decode` (one-time prefill materialization), and
    the per-token decode loop's `layers.effective_weight`.
    """
    K, N = leaf.w.shape[-2:]
    theta = sigmoid(leaf.s.astype(jnp.float32))
    if leaf.mode == "threshold":
        m = ste_threshold(theta, leaf.tau)
    else:
        idx = (leaf.off[..., None, None]
               + jnp.arange(K * N, dtype=jnp.uint32).reshape(K, N))
        u = _kref.hash_uniform(idx, leaf.seed[..., None, None])
        m = ste_bernoulli(theta, u)
    return m.astype(leaf.w.dtype) * leaf.w


def masked_forward_tree(mp: MaskedParams, seed_fn: Callable,
                        mode: str = "sample", tau: float = 0.5) -> Pytree:
    """Merge MaskedParams into ONE params pytree for `api.forward`:
    maskable leaves become `MaskedLeaf` bundles (the fused execution
    path), float leaves pass through unchanged.

    `seed_fn(leaf_idx) -> uint32 scalar` supplies the per-leaf stream
    seed; leaf indices enumerate the flattened tree (None leaves
    included), matching the uplink's enumeration in
    `launch.steps.make_round_step` exactly.
    """
    flat_w, treedef = jax.tree_util.tree_flatten(
        mp.weights, is_leaf=lambda x: x is None)
    flat_s, _ = jax.tree_util.tree_flatten(
        mp.scores, is_leaf=lambda x: x is None)
    flat_f, _ = jax.tree_util.tree_flatten(
        mp.floats, is_leaf=lambda x: x is None)
    out = []
    for i, (w, s, f) in enumerate(zip(flat_w, flat_s, flat_f)):
        if w is None:
            out.append(f)
            continue
        out.append(MaskedLeaf.build(w, s, seed_fn(i), mode, tau))
    return jax.tree_util.tree_unflatten(treedef, out)


def hash_effective(mp: MaskedParams, seed_fn: Callable,
                   mode: str = "sample", tau: float = 0.5) -> Pytree:
    """Materialized twin of `masked_forward_tree`: effective params
    m * w with the SAME hash-stream masks as the fused kernels (the
    REPRO_EFF_PATH=1 escape hatch and the path-equivalence oracle).
    """
    return freeze_for_decode(masked_forward_tree(mp, seed_fn, mode, tau))


def freeze_for_decode(tree: Pytree) -> Pytree:
    """Materialize every `MaskedLeaf` of a forward tree ONCE for a
    decode session: the deployed mask is static, so effective params
    are computed a single time at prefill and every subsequent
    `decode_step` / `conv1d_step` consumes plain arrays — zero mask
    resampling in steady-state decode (docs/DESIGN.md §3; used by
    `launch/serve.py`).  Float leaves pass through unchanged."""
    return jax.tree_util.tree_map(
        lambda p: materialize_leaf(p) if isinstance(p, MaskedLeaf)
        else p,
        tree, is_leaf=lambda x: x is None or isinstance(x, MaskedLeaf))


# ---------------------------------------------------------------------------
# Serving: per-tenant mask identities + the bounded freeze-cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MaskIdentity:
    """Hashable identity of one tenant's sub-network for serving.

    A deployed tenant differs from every other tenant ONLY by its mask
    — the frozen random `w` is shared — so the identity is exactly the
    mask-stream coordinates that regenerate the mask:

      seed:   the artifact's run seed (`mask_stream_seed(..., run_seed)`)
      mode:   "threshold" (the FedMask-style deployed artifact,
              `launch/serve.py`'s convention) or "sample"
      tau:    threshold for mode="threshold"
      cohort: stream cohort coordinate (0 = the single-artifact default)
      tag:    disambiguator for tenants that carry a per-tenant score
              tree over the shared `w` (two identities with equal
              coordinates but distinct scores MUST differ in `tag`,
              or the freeze-cache would alias them)

    `MaskIdentity` is the freeze-cache key (`FreezeCache`) and the
    per-slot identity of the serving engine
    (`repro.runtime.serve_engine.ServeEngine`).
    """
    seed: int
    mode: str = "threshold"
    tau: float = 0.5
    cohort: int = 0
    tag: str = ""


def freeze_identity(mp: MaskedParams, ident: MaskIdentity,
                    scores: Optional[Pytree] = None) -> Pytree:
    """The per-slot freeze API: materialize the decode tree for ONE
    tenant identity over the shared `MaskedParams`.

    Builds the threshold/sample forward tree at the identity's stream
    coordinates (step=0, dev=0 — the serving convention of
    `launch/serve.py`) and freezes it once via `freeze_for_decode`.
    ``scores`` optionally substitutes a per-tenant score tree (a
    personalized artifact) over the SAME shared weights; the frozen
    result is a plain-array params pytree ready for
    `api.decode_step` — zero mask resampling afterwards.
    """
    if scores is not None:
        mp = MaskedParams(mp.weights, scores, mp.floats)
    seed_fn = lambda i: mask_stream_seed(0, 0, i, ident.cohort,
                                         run_seed=ident.seed)
    return freeze_for_decode(masked_forward_tree(
        mp, seed_fn, mode=ident.mode, tau=ident.tau))


class FreezeCache:
    """Bounded LRU cache of materialized decode trees.

    Serving keeps ONE copy of the frozen random weights and at most
    ``capacity`` materialized per-tenant trees, so resident HBM is
    ``1 x w + capacity x masked-leaf deltas`` regardless of how many
    tenants rotate through the engine (docs/DESIGN.md §3).

    Semantics (property-tested in tests/test_serving_property.py):

      * ``get(key)`` returns the cached tree on a hit (moving the key
        to most-recently-used) or builds one via ``build_fn(key)`` on
        a miss, evicting the exact least-recently-used entry when
        occupancy would exceed ``capacity``;
      * occupancy NEVER exceeds ``capacity``;
      * a hit is bit-identical to a fresh build of the same key (the
        builder is deterministic: the mask stream is a pure function
        of the identity).

    ``hits`` / ``misses`` / ``evictions`` counters feed the serving
    benchmark (`benchmarks/serve_bench.py`).
    """

    def __init__(self, build_fn: Callable[[Any], Pytree], capacity: int):
        if capacity < 1:
            raise ValueError(f"FreezeCache capacity must be >= 1, "
                             f"got {capacity}")
        self._build = build_fn
        self.capacity = int(capacity)
        self._store = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key) -> Pytree:
        if key in self._store:
            self._store.move_to_end(key)
            self.hits += 1
            return self._store[key]
        self.misses += 1
        tree = self._build(key)
        self._store[key] = tree
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1
        return tree

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key) -> bool:
        return key in self._store

    def keys(self):
        """Resident keys in LRU -> MRU order (eviction order)."""
        return list(self._store.keys())

    def stats(self) -> dict:
        return {"capacity": self.capacity, "occupancy": len(self._store),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


def masked_delta_bytes(mp: MaskedParams) -> int:
    """Bytes of ONE materialized per-tenant tree's masked leaves (the
    per-cache-entry HBM delta: m ⊙ w at w's dtype; float leaves and
    the shared `w` are counted once, engine-wide)."""
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(mp.weights)
               if l is not None)


def mask_artifact_bytes(mp: MaskedParams) -> int:
    """Wire size of one tenant's packed 1-bit mask artifact (uint32
    word-aligned per leaf) — what a tenant costs to SHIP, vs
    `masked_delta_bytes` (what a resident tenant costs in HBM)."""
    return sum(4 * ((l.size + 31) // 32)
               for l in jax.tree_util.tree_leaves(mp.scores)
               if l is not None)


def final_mask(mp: MaskedParams, key: jax.Array) -> Pytree:
    """Sample the per-round uplink mask m̂_i ~ Bern(θ̂_i)  (eq. before (8)).

    Returns a pytree with uint8 {0,1} leaves for masked params, None else.
    """
    def one(s, k):
        if s is None:
            return None
        u = jax.random.uniform(k, s.shape, dtype=jnp.float32)
        return (u < sigmoid(s.astype(jnp.float32))).astype(jnp.uint8)

    flat_s, treedef = jax.tree_util.tree_flatten(
        mp.scores, is_leaf=lambda x: x is None)
    keys = jax.random.split(key, max(len(flat_s), 1))
    out = [one(s, k) for s, k in zip(flat_s, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def scores_from_theta(theta_tree: Pytree) -> Pytree:
    """Client-side round start: s = logit(theta)  (eq. 4)."""
    return jax.tree_util.tree_map(
        lambda t: None if t is None else logit(t.astype(jnp.float32)),
        theta_tree, is_leaf=lambda x: x is None)


def count_params(tree: Pytree) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(tree)
               if l is not None)
