"""Federated round orchestration (host-simulation, the paper-faithful path).

Protocol per round t (Sec. II of the paper):
  1. Server holds global probability mask theta(t) (+ float leaves).
  2. Each participating client i: s_i <- logit(theta(t))            (eq. 4)
  3. H local mini-batch steps on scores with STE + entropy-proxy reg
     (eqs. 6, 7, 12).
  4. Sample uplink mask  m̂_i ~ Bern(sigmoid(s_i)).
  5. Server: theta(t+1) = weighted mean of masks                    (eq. 8)

Clients are vmapped: `client_data` carries a leading K axis. Partial
participation / node failure / stragglers are a per-round boolean vector:
missing clients are renormalized out of the mean — this IS the fault
model at 1000-node scale (see docs/DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import masking, regularizer
from repro.optim import optimizers as optlib

Pytree = Any


class ServerState(NamedTuple):
    theta: Pytree      # global probability mask (None for float leaves)
    floats: Pytree     # FedAvg'd float leaves (None for masked leaves)
    weights: Pytree    # frozen random weights (regenerable from seed)
    seed: jax.Array    # the init seed (the only weight "payload")
    round: jax.Array


@dataclasses.dataclass(frozen=True)
class FedConfig:
    lam: float = 1.0            # regularization strength lambda
    local_steps: int = 3        # H: local mini-batch iterations per round
    lr: float = 0.1             # score learning rate
    float_lr: float = 0.01      # lr for non-masked float leaves
    optimizer: str = "sgd"      # "sgd" | "momentum" | "adam"
    bayesian: bool = False      # FedPM beta aggregation
    train_floats: bool = True


def init_server(key: jax.Array, params_like: Pytree,
                spec: masking.MaskSpec) -> ServerState:
    seed = jax.random.key_data(key)[..., -1].astype(jnp.uint32)
    mp = masking.init_masked(key, params_like, spec)
    theta = jax.tree_util.tree_map(
        lambda s: None if s is None else jax.nn.sigmoid(
            s.astype(jnp.float32)),
        mp.scores, is_leaf=lambda x: x is None)
    # init_masked keeps the template's float leaves verbatim; copy them
    # so the round step (which donates its input state) can never delete
    # the caller's own params arrays
    floats = jax.tree_util.tree_map(
        lambda f: None if f is None else jnp.array(f), mp.floats,
        is_leaf=lambda x: x is None)
    return ServerState(theta=theta, floats=floats, weights=mp.weights,
                       seed=seed, round=jnp.zeros((), jnp.int32))


def _make_opt(name: str, lr: float) -> optlib.Optimizer:
    if name == "sgd":
        return optlib.sgd(lr)
    if name == "momentum":
        return optlib.momentum(lr)
    if name == "adam":
        return optlib.adam(lr)
    raise ValueError(name)


def make_client_update(apply_fn: Callable, loss_fn: Callable,
                       cfg: FedConfig):
    """Build the jittable single-client local-update function.

    apply_fn(effective_params, batch) -> model outputs
    loss_fn(outputs, batch) -> scalar data loss (e.g. mean CE)

    Returns fn(weights, floats, theta, data, key) ->
        (mask_uint8_tree, new_floats, metrics)
    where `data` is a pytree with leading axis = cfg.local_steps
    (one mini-batch per local iteration).
    """
    opt = _make_opt(cfg.optimizer, cfg.lr)
    fopt = _make_opt(cfg.optimizer, cfg.float_lr)

    def local_loss(scores, floats, weights, batch, key):
        mp = masking.MaskedParams(weights, scores, floats)
        eff = masking.sample_effective(mp, key, mode="sample")
        out = apply_fn(eff, batch)
        data_loss = loss_fn(out, batch)
        reg = regularizer.entropy_proxy(scores)
        return data_loss + cfg.lam * reg, (data_loss, reg)

    def client(weights, floats, theta, data, key):
        scores = masking.scores_from_theta(theta)  # eq. (4)
        ostate = opt.init(scores)
        fstate = fopt.init(floats)

        def step(carry, xs):
            scores, floats, ostate, fstate = carry
            batch, k = xs
            (loss, (dl, reg)), grads = jax.value_and_grad(
                local_loss, argnums=(0, 1), has_aux=True)(
                    scores, floats, weights, batch, k)
            gs, gf = grads
            upd, ostate = opt.update(gs, ostate, scores)
            scores = optlib.apply_updates(scores, upd)
            if cfg.train_floats:
                updf, fstate = fopt.update(gf, fstate, floats)
                floats = optlib.apply_updates(floats, updf)
            return (scores, floats, ostate, fstate), (loss, dl, reg)

        keys = jax.random.split(key, cfg.local_steps + 1)
        (scores, floats, _, _), (losses, dls, regs) = jax.lax.scan(
            step, (scores, floats, ostate, fstate),
            (data, keys[:cfg.local_steps]))

        mask = masking.final_mask(
            masking.MaskedParams(weights, scores, floats), keys[-1])
        metrics = {
            "loss": losses[-1], "data_loss": dls[-1], "reg": regs[-1],
            "uplink_bpp": regularizer.empirical_entropy(mask),
            "sparsity": regularizer.sparsity(mask),
        }
        return mask, floats, metrics

    return client


def make_round_fn(apply_fn: Callable, loss_fn: Callable, cfg: FedConfig,
                  n_clients: int = None):
    """Build the jitted full-round function over K vmapped clients.

    round_fn(server: ServerState, data: pytree[K, H, ...],
             participation: bool[K], sizes: f32[K], key)
        -> (ServerState, metrics)

    Thin wrapper over the unified `repro.api` engine: the per-client
    local step is `make_client_update` above, the uplink is a
    `BitpackedMasks` payload, and aggregation + `uplink_bpp` accounting
    run in `repro.api.protocol.run_round` — the same code path every
    registered algorithm uses.  `n_clients` is kept for signature
    compatibility; the cohort size now comes from `participation`.
    """
    from repro.api import algorithms as _algos  # deferred: api -> core

    algo = _algos._fedpm_family(
        "fedpm_reg" if cfg.lam > 0 else "fedpm",
        apply_fn, loss_fn, cfg=cfg)
    return algo.round


def make_eval_fn(apply_fn: Callable, metric_fn: Callable,
                 mode: str = "sample", n_samples: int = 1):
    """Global-model evaluation: sample (or threshold) masks from theta.

    metric_fn(outputs, batch) -> scalar (e.g. accuracy).
    """
    def eval_fn(server: ServerState, batch, key):
        scores = masking.scores_from_theta(server.theta)
        mp = masking.MaskedParams(server.weights, scores, server.floats)

        def one(k):
            eff = masking.sample_effective(mp, k, mode=mode)
            return metric_fn(apply_fn(eff, batch), batch)

        keys = jax.random.split(key, n_samples)
        return jnp.mean(jax.vmap(one)(keys))

    return jax.jit(eval_fn)


def final_artifact(server: ServerState, key: jax.Array):
    """The deployable artifact: (seed, one bitpacked mask per leaf).

    Total size ~ n/8 bytes + 4 — the paper's "SEED + binary mask" claim.
    The masks are serialized as a `repro.api.payloads.BitpackedMasks`
    payload (the same type clients put on the uplink), through the
    public `aggregation.pad_to_words`/`pack_bits` pair.
    """
    from repro.api import payloads as _plds  # deferred: api -> core

    scores = masking.scores_from_theta(server.theta)
    mask = masking.final_mask(
        masking.MaskedParams(server.weights, scores, server.floats), key)
    payload = _plds.BitpackedMasks.from_masks(mask, server.floats)
    return {"seed": server.seed, "masks": payload.as_path_dict(),
            "floats": server.floats}
