"""Entropy-proxy regularizer (the paper's contribution, eq. 10-12) and the
empirical Bpp/entropy meter (eq. 13)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def entropy_proxy(scores: Pytree) -> jax.Array:
    """(1/n) * sum_j sigmoid(s_j)  over every masked leaf — eq. (12)'s
    regularization term without lambda. Minimizing it maximizes p_0,
    driving the transmitted-mask entropy down.
    """
    tot, n = jnp.float32(0.0), 0
    for s in jax.tree_util.tree_leaves(scores):
        if s is None:
            continue
        tot = tot + jnp.sum(jax.nn.sigmoid(s.astype(jnp.float32)))
        n += s.size
    if n == 0:
        return jnp.float32(0.0)
    return tot / jnp.float32(n)


def binary_entropy(p: jax.Array, eps: float = 1e-7) -> jax.Array:
    """H(p) in bits. eps is float32-safe (1 - 1e-7 != 1 in f32)."""
    p = jnp.clip(p.astype(jnp.float32), eps, 1.0 - eps)
    return -(p * jnp.log2(p) + (1 - p) * jnp.log2(1 - p))


def empirical_entropy(mask: Pytree) -> jax.Array:
    """Ĥ of one client's transmitted binary mask — eq. (13) inner term.

    This is the average achievable bits-per-parameter under an ideal
    entropy coder, the paper's reported communication metric.
    """
    ones, n = jnp.float32(0.0), 0
    for m in jax.tree_util.tree_leaves(mask):
        if m is None:
            continue
        ones = ones + jnp.sum(m.astype(jnp.float32))
        n += m.size
    if n == 0:
        return jnp.float32(0.0)
    p1 = ones / jnp.float32(n)
    return binary_entropy(p1)


def sparsity(mask: Pytree) -> jax.Array:
    """Fraction of zeros in the transmitted mask."""
    ones, n = jnp.float32(0.0), 0
    for m in jax.tree_util.tree_leaves(mask):
        if m is None:
            continue
        ones = ones + jnp.sum(m.astype(jnp.float32))
        n += m.size
    if n == 0:
        return jnp.float32(0.0)
    return 1.0 - ones / jnp.float32(n)


def theta_entropy(scores: Pytree) -> jax.Array:
    """Expected transmitted entropy E[Ĥ] = mean_j H(sigmoid(s_j)) — a
    differentiable upper-bound companion to eq. (13), reported in logs."""
    tot, n = jnp.float32(0.0), 0
    for s in jax.tree_util.tree_leaves(scores):
        if s is None:
            continue
        tot = tot + jnp.sum(binary_entropy(jax.nn.sigmoid(
            s.astype(jnp.float32))))
        n += s.size
    if n == 0:
        return jnp.float32(0.0)
    return tot / jnp.float32(n)
