"""Baseline federated algorithms the paper compares against (Sec. IV).

All share one interface so benchmarks sweep them uniformly:

    algo.init(key, params_like)                      -> state
    algo.round(state, data[K,H,...], part, sizes, k) -> (state, metrics)
    algo.eval_params(state, key)                     -> effective params

metrics always include `uplink_bpp` (bits per parameter actually needed
on the uplink for this algorithm, using the paper's entropy accounting
where the payload is binary, or the float width otherwise).

  * FedPM            == repro.core.federated with cfg.lam = 0
  * Regularized (ours)== repro.core.federated with cfg.lam > 0
  * FedMask          — deterministic STE-threshold masks        [7]
  * Top-k            — score top-k% -> 1, rest pruned           [4]
  * MV-SignSGD       — majority-vote sign compression           [12]
  * FedAvg           — float weights, the 32-Bpp reference      [1]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import masking, regularizer
from repro.optim import optimizers as optlib

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Algorithm:
    name: str
    init: Callable
    round: Callable
    eval_params: Callable


def _weighted(wn, tree):
    return jax.tree_util.tree_map(
        lambda x: None if x is None else jnp.tensordot(
            wn, x.astype(jnp.float32), axes=(0, 0)).astype(x.dtype),
        tree, is_leaf=lambda x: x is None)


def _part_weights(participation, sizes):
    w = sizes * participation.astype(jnp.float32)
    return w / jnp.maximum(jnp.sum(w), 1e-9)


# ---------------------------------------------------------------------------
# FedAvg — the float reference (32 Bpp uplink)
# ---------------------------------------------------------------------------


def fedavg(apply_fn, loss_fn, lr=0.05, local_steps=3) -> Algorithm:
    opt = optlib.momentum(lr)

    class State(NamedTuple):
        params: Pytree
        round: jax.Array

    def init(key, params_like):
        # standard float training from the given init template
        return State(params_like, jnp.zeros((), jnp.int32))

    def client(params, data, key):
        ostate = opt.init(params)

        def step(carry, batch):
            p, os = carry
            loss, g = jax.value_and_grad(
                lambda pp: loss_fn(apply_fn(pp, batch), batch))(p)
            upd, os = opt.update(g, os, p)
            return (optlib.apply_updates(p, upd), os), loss

        (p, _), losses = jax.lax.scan(step, (params, ostate), data)
        return p, losses[-1]

    vclient = jax.vmap(client, in_axes=(None, 0, 0))

    @jax.jit
    def round_fn(state, data, participation, sizes, key):
        keys = jax.random.split(key, participation.shape[0])
        locals_, losses = vclient(state.params, data, keys)
        wn = _part_weights(participation, sizes)
        params = _weighted(wn, locals_)
        metrics = {"loss": jnp.sum(losses * wn), "uplink_bpp": 32.0,
                   "sparsity": 0.0}
        return State(params, state.round + 1), metrics

    return Algorithm("fedavg", init, round_fn,
                     lambda s, k: s.params)


# ---------------------------------------------------------------------------
# MV-SignSGD — majority-vote sign compression (1 Bpp but float model)
# ---------------------------------------------------------------------------


def mv_signsgd(apply_fn, loss_fn, lr=1e-3, local_steps=3) -> Algorithm:
    class State(NamedTuple):
        params: Pytree
        round: jax.Array

    def init(key, params_like):
        return State(params_like, jnp.zeros((), jnp.int32))

    def client(params, data, key):
        # accumulate grad over local batches, send elementwise sign
        def step(g_acc, batch):
            loss, g = jax.value_and_grad(
                lambda pp: loss_fn(apply_fn(pp, batch), batch))(params)
            return jax.tree_util.tree_map(jnp.add, g_acc, g), loss

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        g, losses = jax.lax.scan(step, g0, data)
        signs = jax.tree_util.tree_map(jnp.sign, g)
        return signs, losses[-1]

    vclient = jax.vmap(client, in_axes=(None, 0, 0))

    @jax.jit
    def round_fn(state, data, participation, sizes, key):
        keys = jax.random.split(key, participation.shape[0])
        signs, losses = vclient(state.params, data, keys)
        wn = _part_weights(participation, sizes)
        # majority vote: sign of the weighted sum of signs
        vote = jax.tree_util.tree_map(
            lambda s: jnp.sign(jnp.tensordot(wn, s, axes=(0, 0))), signs)
        params = jax.tree_util.tree_map(
            lambda p, v: (p - lr * v).astype(p.dtype), state.params, vote)
        metrics = {"loss": jnp.sum(losses * wn), "uplink_bpp": 1.0,
                   "sparsity": 0.0}
        return State(params, state.round + 1), metrics

    return Algorithm("mv_signsgd", init, round_fn,
                     lambda s, k: s.params)


# ---------------------------------------------------------------------------
# Top-k over scores — deterministic sparse mask [4]
# ---------------------------------------------------------------------------


def topk_mask(apply_fn, loss_fn, spec: masking.MaskSpec, k_frac=0.3,
              lr=0.1, local_steps=3) -> Algorithm:
    """Train scores like FedPM (stochastic STE), but the uplink mask sets
    the top k% of scores to 1 and prunes the rest (paper Sec. IV)."""
    opt = optlib.momentum(lr)

    class State(NamedTuple):
        scores: Pytree
        floats: Pytree
        weights: Pytree
        round: jax.Array

    def init(key, params_like):
        mp = masking.init_masked(key, params_like, spec)
        return State(mp.scores, mp.floats, mp.weights,
                     jnp.zeros((), jnp.int32))

    def _topk(scores):
        # global top-k over all masked leaves
        flat = [s.reshape(-1) for s in jax.tree_util.tree_leaves(scores)
                if s is not None]
        allv = jnp.concatenate(flat)
        kth = jnp.quantile(allv, 1.0 - k_frac)
        return jax.tree_util.tree_map(
            lambda s: None if s is None else (s >= kth).astype(jnp.uint8),
            scores, is_leaf=lambda x: x is None)

    def client(weights, floats, scores, data, key):
        ostate = opt.init(scores)

        def loss_of(sc, batch, k):
            eff = masking.sample_effective(
                masking.MaskedParams(weights, sc, floats), k, mode="sample")
            return loss_fn(apply_fn(eff, batch), batch)

        def step(carry, xs):
            sc, os = carry
            batch, k = xs
            loss, g = jax.value_and_grad(loss_of)(sc, batch, k)
            upd, os = opt.update(g, os, sc)
            return (optlib.apply_updates(sc, upd), os), loss

        h = jax.tree_util.tree_leaves(data)[0].shape[0]
        keys = jax.random.split(key, h)
        (sc, _), losses = jax.lax.scan(step, (scores, ostate),
                                       (data, keys))
        return _topk(sc), losses[-1]

    vclient = jax.vmap(client, in_axes=(None, None, None, 0, 0))

    @jax.jit
    def round_fn(state, data, participation, sizes, key):
        keys = jax.random.split(key, participation.shape[0])
        masks, losses = vclient(state.weights, state.floats, state.scores,
                                data, keys)
        wn = _part_weights(participation, sizes)
        theta = jax.tree_util.tree_map(
            lambda m: None if m is None else jnp.tensordot(
                wn, m.astype(jnp.float32), axes=(0, 0)),
            masks, is_leaf=lambda x: x is None)
        scores = masking.scores_from_theta(theta)
        bpp = jax.vmap(regularizer.empirical_entropy)(masks)
        metrics = {"loss": jnp.sum(losses * wn),
                   "uplink_bpp": jnp.sum(bpp * wn),
                   "sparsity": 1.0 - k_frac}
        return State(scores, state.floats, state.weights,
                     state.round + 1), metrics

    def eval_params(state, key):
        mp = masking.MaskedParams(state.weights, state.scores, state.floats)
        return masking.sample_effective(mp, key, mode="threshold")

    return Algorithm("topk", init, round_fn, eval_params)


# ---------------------------------------------------------------------------
# FedMask — deterministic STE-threshold masking [7]
# ---------------------------------------------------------------------------


def fedmask(apply_fn, loss_fn, spec: masking.MaskSpec, tau=0.5,
            lr=0.1, local_steps=3) -> Algorithm:
    """Deterministic variant: forward uses m = 1[sigmoid(s) > tau] with
    STE; uplink is the thresholded mask (the biased-update baseline the
    paper contrasts with, footnote 3)."""
    opt = optlib.momentum(lr)

    class State(NamedTuple):
        scores: Pytree
        floats: Pytree
        weights: Pytree
        round: jax.Array

    def init(key, params_like):
        mp = masking.init_masked(key, params_like, spec)
        return State(mp.scores, mp.floats, mp.weights,
                     jnp.zeros((), jnp.int32))

    def client(weights, floats, scores, data, key):
        ostate = opt.init(scores)

        def loss_of(sc, batch):
            eff = masking.sample_effective(
                masking.MaskedParams(weights, sc, floats), key,
                mode="threshold", tau=tau)
            return loss_fn(apply_fn(eff, batch), batch)

        def step(carry, batch):
            sc, os = carry
            loss, g = jax.value_and_grad(loss_of)(sc, batch)
            upd, os = opt.update(g, os, sc)
            return (optlib.apply_updates(sc, upd), os), loss

        (sc, _), losses = jax.lax.scan(step, (scores, ostate), data)
        mask = jax.tree_util.tree_map(
            lambda s: None if s is None else
            (jax.nn.sigmoid(s) > tau).astype(jnp.uint8),
            sc, is_leaf=lambda x: x is None)
        return mask, losses[-1]

    vclient = jax.vmap(client, in_axes=(None, None, None, 0, 0))

    @jax.jit
    def round_fn(state, data, participation, sizes, key):
        keys = jax.random.split(key, participation.shape[0])
        masks, losses = vclient(state.weights, state.floats, state.scores,
                                data, keys)
        wn = _part_weights(participation, sizes)
        theta = jax.tree_util.tree_map(
            lambda m: None if m is None else jnp.tensordot(
                wn, m.astype(jnp.float32), axes=(0, 0)),
            masks, is_leaf=lambda x: x is None)
        scores = masking.scores_from_theta(theta)
        bpp = jax.vmap(regularizer.empirical_entropy)(masks)
        metrics = {"loss": jnp.sum(losses * wn),
                   "uplink_bpp": jnp.sum(bpp * wn),
                   "sparsity": jax.vmap(regularizer.sparsity)(masks) @ wn}
        return State(scores, state.floats, state.weights,
                     state.round + 1), metrics

    def eval_params(state, key):
        mp = masking.MaskedParams(state.weights, state.scores, state.floats)
        return masking.sample_effective(mp, key, mode="threshold", tau=tau)

    return Algorithm("fedmask", init, round_fn, eval_params)
