"""Baseline federated algorithms the paper compares against (Sec. IV).

This module is now a compatibility shim: the implementations moved to
`repro.api.algorithms`, where every algorithm — including the paper's
`fedpm_reg` — implements the `FedAlgorithm` protocol (init /
client_update / aggregate / eval_params + a typed `UplinkPayload`).
Prefer resolving by name:

    from repro import api
    algo = api.get_algorithm("topk", apply_fn, loss_fn, spec=spec,
                             k_frac=0.3)
    state = algo.init(key, params_like)
    state, metrics = algo.round(state, data, part, sizes, key)

`metrics["uplink_bpp"]` is computed by the transport layer from the
payload's serialized bits — 32 for `FloatDeltas` (FedAvg), exactly 1
for `SignVotes` (MV-SignSGD), and the empirical bit entropy (<= 1) for
`BitpackedMasks` (FedPM / FedMask / Top-k).

  * FedPM             == get_algorithm("fedpm", ...)
  * Regularized (ours)== get_algorithm("fedpm_reg", ...)
  * FedMask           — deterministic STE-threshold masks        [7]
  * Top-k             — score top-k% -> 1, rest pruned           [4]
  * MV-SignSGD        — majority-vote sign compression           [12]
  * FedAvg            — float weights, the 32-Bpp reference      [1]
"""
from __future__ import annotations

from repro.api.protocol import FedAlgorithm as Algorithm  # noqa: F401
from repro import api as _api

from repro.core import masking


def fedavg(apply_fn, loss_fn, lr=0.05, local_steps=3) -> Algorithm:
    return _api.get_algorithm("fedavg", apply_fn, loss_fn, lr=lr,
                              local_steps=local_steps)


def mv_signsgd(apply_fn, loss_fn, lr=1e-3, local_steps=3) -> Algorithm:
    return _api.get_algorithm("mv_signsgd", apply_fn, loss_fn, lr=lr,
                              local_steps=local_steps)


def topk_mask(apply_fn, loss_fn, spec: masking.MaskSpec, k_frac=0.3,
              lr=0.1, local_steps=3) -> Algorithm:
    return _api.get_algorithm("topk", apply_fn, loss_fn, spec=spec,
                              k_frac=k_frac, lr=lr,
                              local_steps=local_steps)


def fedmask(apply_fn, loss_fn, spec: masking.MaskSpec, tau=0.5,
            lr=0.1, local_steps=3) -> Algorithm:
    return _api.get_algorithm("fedmask", apply_fn, loss_fn, spec=spec,
                              tau=tau, lr=lr, local_steps=local_steps)
