"""Mask-stream coverage checker — the "stream race detector".

Given any registry model's federated state (real arrays or
``jax.eval_shape`` structs — only shapes are read), rebuild the exact
hash-stream coordinates the fused forward uses, through the REAL
production builder (`masking.masked_forward_tree`, so this checker
cannot drift from the code it guards), and statically prove:

  * per leaf — every trailing-2D block samples ONE seed and the block
    `off` intervals tile ``[0, flat_size)`` with zero gaps and zero
    overlaps.  A gap means the forward masks are not the flat stream
    `sample_and_pack` packs for the uplink; an overlap means two blocks
    draw correlated masks;
  * globally — no two (leaf, shard, cohort) streams share a seed.
    Every stream's interval set starts at flat index 0, so two equal
    seeds ALWAYS overlap: two sub-networks silently drawing correlated
    masks.  `mask_stream_seed` is a pure function, so the full
    (shard, cohort) grid is enumerated without any devices.

Exposed as the ROADMAP's dryrun-mode gate (`launch/dryrun.py` runs
`state_stream_report` over the forced multi-device mesh) and as the
``stream`` engine of ``tools/repro_lint.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.report import Finding
from repro.core import masking


@dataclasses.dataclass(frozen=True)
class StreamInterval:
    """One trailing-2D block's slice of its owner's flat hash stream."""

    owner: str       # masked-leaf path
    seed: int        # uint32 stream id
    lo: int          # flat start index (the block's `off`)
    hi: int          # flat end index   (off + K*N)
    flat_size: int   # the owning leaf's total flat size


def _path_str(path) -> str:
    return jax.tree_util.keystr(path) or "<root>"


def collect_intervals(tree, owner_prefix: str = "") -> list:
    """Every `MaskedLeaf`'s concrete (seed, off, flat_size) intervals
    from a forward tree built by `masking.masked_forward_tree`.
    Grouped (E, K, N) expert leaves and layer-stacked (L, K, N) leaves
    contribute one interval per trailing-2D block."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None
        or isinstance(x, masking.MaskedLeaf))
    out = []
    for path, leaf in flat:
        if not isinstance(leaf, masking.MaskedLeaf):
            continue
        K, N = leaf.w.shape[-2:]
        blk = int(K) * int(N)
        seeds = np.asarray(leaf.seed, np.uint32).reshape(-1)
        offs = np.asarray(leaf.off, np.uint32).reshape(-1)
        flat_size = blk * seeds.size
        owner = owner_prefix + _path_str(path)
        for sd, off in zip(seeds.tolist(), offs.tolist()):
            out.append(StreamInterval(owner, int(sd), int(off),
                                      int(off) + blk, flat_size))
    return out


def check_intervals(intervals: Sequence[StreamInterval]) -> list:
    """``stream-gap`` / ``stream-overlap`` findings over a set of
    intervals: per-owner tiling of ``[0, flat_size)`` plus cross-owner
    seed collisions."""
    findings = []
    by_owner: dict = {}
    for iv in intervals:
        by_owner.setdefault(iv.owner, []).append(iv)
    for owner, ivs in sorted(by_owner.items()):
        if len({iv.seed for iv in ivs}) > 1:
            findings.append(Finding(
                "stream-gap", owner,
                f"blocks sample {len({iv.seed for iv in ivs})} distinct "
                "seeds — the leaf's flat uplink stream is not covered "
                "by one stream"))
            continue
        cur = 0
        for iv in sorted(ivs, key=lambda i: (i.lo, i.hi)):
            if iv.lo < cur:
                findings.append(Finding(
                    "stream-overlap", owner,
                    f"block [{iv.lo}, {iv.hi}) overlaps the already "
                    f"covered [0, {cur})"))
            elif iv.lo > cur:
                findings.append(Finding(
                    "stream-gap", owner,
                    f"hole [{cur}, {iv.lo}) before the block at "
                    f"{iv.lo}"))
            cur = max(cur, iv.hi)
        if cur != ivs[0].flat_size:
            findings.append(Finding(
                "stream-gap", owner,
                f"blocks cover [0, {cur}) of flat size "
                f"{ivs[0].flat_size}"))
    seed_owners: dict = {}
    for iv in intervals:
        seed_owners.setdefault(iv.seed, set()).add(iv.owner)
    for sd, owners in sorted(seed_owners.items()):
        if len(owners) > 1:
            who = " + ".join(sorted(owners)[:4])
            if len(owners) > 4:
                who += f" + {len(owners) - 4} more"
            findings.append(Finding(
                "stream-overlap", who,
                f"{len(owners)} streams share seed {sd:#010x} — "
                "correlated masks (all streams start at flat index 0)"))
    return findings


def _drop_cohort(tree):
    return jax.tree_util.tree_map(
        lambda l: None if l is None
        else jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
        tree, is_leaf=lambda x: x is None)


def state_stream_report(state, *, step=0, devs=(0,), cohorts=None,
                        run_seed=17, mask_mode: str = "sample",
                        tau: float = 0.5) -> dict:
    """The coverage gate over one federated state (from
    `launch.steps.init_fed_state`, real or `jax.eval_shape`'d).

    Builds the forward tree once through the production
    `masked_forward_tree` (representative shard `devs[0]`, cohort
    `cohorts[0]`) and checks its interval tiling, then sweeps the FULL
    (shard, cohort) grid through `mask_stream_seed` looking for seed
    collisions across distinct (leaf, shard, cohort) streams.

    Returns ``{"n_leaves", "n_intervals", "n_streams", "findings"}``.
    """
    scores = state["scores"]
    C = next(int(l.shape[0]) for l in
             jax.tree_util.tree_leaves(scores) if l is not None)
    if cohorts is None:
        cohorts = range(C)
    devs = [int(d) for d in devs]
    cohorts = [int(c) for c in cohorts]

    mp = masking.MaskedParams(state["weights"], _drop_cohort(scores),
                              _drop_cohort(state["floats"]))
    leaf_ids: list = []

    def seed_fn(i):
        leaf_ids.append(i)
        return masking.mask_stream_seed(step, devs[0], i, cohorts[0],
                                        run_seed=run_seed)

    tree = masking.masked_forward_tree(mp, seed_fn, mode=mask_mode,
                                       tau=tau)
    intervals = collect_intervals(tree)
    findings = check_intervals(intervals)

    # full (shard, cohort) sweep — one broadcasted seed matrix per leaf
    dv = np.asarray(devs, np.uint32)[:, None]
    ch = np.asarray(cohorts, np.uint32)[None, :]
    mats = [np.asarray(masking.mask_stream_seed(step, dv, i, ch,
                                                run_seed=run_seed),
                       np.uint32)
            for i in leaf_ids]
    seeds_all = (np.stack(mats) if mats
                 else np.zeros((0, 1, 1), np.uint32))  # (L, D, C)
    uniq, counts = np.unique(seeds_all.reshape(-1), return_counts=True)
    for sd in uniq[counts > 1].tolist():
        locs = np.argwhere(seeds_all == sd)
        who = ", ".join(
            f"leaf{leaf_ids[l]}/dev{devs[d]}/cohort{cohorts[c]}"
            for l, d, c in locs[:4].tolist())
        findings.append(Finding(
            "stream-overlap", who,
            f"{len(locs)} (leaf, shard, cohort) streams share seed "
            f"{sd:#010x}"))

    return {"n_leaves": len(leaf_ids),
            "n_intervals": len(intervals),
            "n_streams": int(seeds_all.size),
            "findings": findings}


def arch_stream_report(arch: str, *, smoke: bool = True, C: int = 2,
                       devs=(0,), step=0, run_seed=17) -> dict:
    """`state_stream_report` for a registry config by name — the state
    comes from `jax.eval_shape` of the real `init_fed_state`, so no
    parameters are allocated."""
    from repro.configs import get_config
    from repro.launch import steps as steplib
    from repro.models import build_model

    cfg = get_config(arch, smoke=smoke)
    api = build_model(cfg)
    state = jax.eval_shape(
        lambda k: steplib.init_fed_state(k, api, masking.MaskSpec(),
                                         C=C),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return state_stream_report(state, step=step, devs=devs,
                               run_seed=run_seed)
