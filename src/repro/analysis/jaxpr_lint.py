"""Rule-based closed-jaxpr analyzer guarding the mask-native invariants.

The walker (`lint_jaxpr`) descends into ``scan``/``while``/``cond``/
``custom_vjp``/``pjit`` sub-jaxprs; the ``pallas_call`` equation is
never descended into — its innards live in VMEM, which is the entire
point being proved.  Call-like equations that merely forward inner
results are shown to rules as *call sites* (`check_call`) and recursed
into instead of being treated as defining equations, so a leaf-rule hit
is a real compute/materialization step.

Shipped rules:

  * `weight_f32_temporaries` — weight-shaped f32 defs outside the
    kernel boundary (the original ``count_weight_f32_defs_jaxpr`` from
    ``benchmarks/kernels_bench.py``, promoted here; the bench and the
    tier-1 twin are thin callers of this one traversal);
  * `mask_materialization` — weight-shaped bool/uint8/int8 defs: a
    mask made it into HBM;
  * `DtypePromotionRule` — any f64 value (numerics are f32/bf16 end to
    end), plus weight-shaped bf16→f32 ``convert_element_type`` (an
    upcast that doubles a weight-sized tensor's HBM footprint);
  * `DonationAliasRule` — a donated pjit operand read again after the
    call that consumed its buffer.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.analysis.report import Finding

# pure view/layout primitives: no new value is computed, XLA aliases
# them to the operand (lax.scan feeds per-layer score slices to the
# kernels through squeeze) — not weight-sized HBM traffic
_VIEW_PRIMS = frozenset({"squeeze", "reshape"})


def _subjaxprs(params):
    found = []
    stack = list(params.values())
    while stack:
        p = stack.pop()
        if isinstance(p, jcore.ClosedJaxpr):
            found.append(p.jaxpr)
        elif isinstance(p, jcore.Jaxpr):
            found.append(p)
        elif isinstance(p, (tuple, list)):
            stack.extend(p)
    return found


class JaxprRule:
    """One invariant over the equations of a (closed) jaxpr.

    `check_eqn` sees every defining equation outside pallas_call;
    `check_call` sees every call-like equation (one that carries
    sub-jaxprs) together with its enclosing jaxpr and position, before
    the walker recurses into it.  Both return iterables of `Finding`s.
    """

    name = "abstract"

    def check_eqn(self, eqn):
        return ()

    def check_call(self, eqn, enclosing, idx):
        return ()


def lint_jaxpr(jaxpr, rules: Sequence[JaxprRule]) -> list:
    """Run `rules` over every equation of `jaxpr`, recursively."""
    findings: list = []

    def walk(jx):
        for idx, eqn in enumerate(jx.eqns):
            if eqn.primitive.name == "pallas_call":
                continue
            inner = _subjaxprs(eqn.params)
            if inner:
                for r in rules:
                    findings.extend(r.check_call(eqn, jx, idx))
                for j in inner:
                    walk(j)
                continue  # call wrapper: only inner eqns define values
            for r in rules:
                findings.extend(r.check_eqn(eqn))

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return findings


class ShapedDefRule(JaxprRule):
    """Flag leaf equations defining a value of `shape` with a dtype in
    `dtypes`, excluding `exempt_prims` (view-only by default)."""

    def __init__(self, name, shape, dtypes, exempt_prims=_VIEW_PRIMS):
        self.name = name
        self._shape = tuple(shape)
        self._dtypes = frozenset(jnp.dtype(d) for d in dtypes)
        self._exempt = frozenset(exempt_prims)

    def check_eqn(self, eqn):
        if eqn.primitive.name in self._exempt:
            return ()
        out = []
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if (aval is not None and tuple(aval.shape) == self._shape
                    and aval.dtype in self._dtypes):
                out.append(Finding(
                    self.name, eqn.primitive.name,
                    f"defines {aval.dtype}{list(aval.shape)}"))
        return out


def weight_f32_temporaries(weight_shape, exempt_prims=_VIEW_PRIMS):
    """Weight-shaped f32 values computed outside pallas_call — the
    invariant behind the fused path's zero-HBM-weight-traffic claim."""
    return ShapedDefRule("weight-f32-temporary", weight_shape,
                         (jnp.float32,), exempt_prims)


def mask_materialization(weight_shape):
    """Weight-shaped bool/uint8/int8 defs — a materialized mask.  On
    the fused path masks exist only as per-tile VMEM values inside the
    kernels, never as an HBM tensor."""
    return ShapedDefRule("mask-materialization", weight_shape,
                         (jnp.bool_, jnp.uint8, jnp.int8))


class DtypePromotionRule(JaxprRule):
    """Unexpected dtype promotions on masked paths: any f64 value
    anywhere (the repo's numerics are f32/bf16 end to end), and
    weight-shaped bf16→f32 `convert_element_type` outside pallas_call
    (the materialized reference's ``w.astype(f32)`` — doubles the
    weight tensor's HBM footprint).  With no `weight_shapes` given only
    the f64 check applies."""

    name = "dtype-promotion"

    def __init__(self, weight_shapes=()):
        self._shapes = frozenset(tuple(s) for s in weight_shapes)

    def check_eqn(self, eqn):
        out = []
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            if aval.dtype == jnp.dtype("float64"):
                out.append(Finding(
                    self.name, eqn.primitive.name,
                    f"f64 value of shape {list(aval.shape)}"))
                continue
            if (eqn.primitive.name == "convert_element_type"
                    and tuple(aval.shape) in self._shapes
                    and aval.dtype == jnp.dtype(jnp.float32)):
                src = getattr(eqn.invars[0], "aval", None)
                if src is not None and src.dtype == jnp.dtype(jnp.bfloat16):
                    out.append(Finding(
                        self.name, eqn.primitive.name,
                        f"weight-shaped bf16->f32 upcast "
                        f"{list(aval.shape)}"))
        return out


class DonationAliasRule(JaxprRule):
    """A donated pjit operand must not be read again: donation hands
    the buffer to the callee, so a later use aliases freed memory (XLA
    silently copies instead, defeating the donation)."""

    name = "donation-alias"

    def check_call(self, eqn, enclosing, idx):
        donated = eqn.params.get("donated_invars")
        if not donated or not any(donated):
            return ()
        later_uses = set()
        for later in enclosing.eqns[idx + 1:]:
            for v in later.invars:
                if isinstance(v, jcore.Var):
                    later_uses.add(v)
        for v in enclosing.outvars:
            if isinstance(v, jcore.Var):
                later_uses.add(v)
        out = []
        for flag, v in zip(donated, eqn.invars):
            if flag and isinstance(v, jcore.Var) and v in later_uses:
                aval = getattr(v, "aval", None)
                out.append(Finding(
                    self.name, eqn.primitive.name,
                    f"donated operand ({aval}) is read after the call"))
        return out


def count_weight_f32_defs_jaxpr(jaxpr, weight_shape) -> int:
    """Number of equations (recursively) in a jaxpr defining an f32
    value of `weight_shape` outside any `pallas_call` — the original
    bench counter, now one rule of the shared walker (per-outvar
    counting, `_VIEW_PRIMS` skipped, call wrappers recursed into but
    never counted: semantics unchanged, so BENCH_kernels.json counts
    stay comparable)."""
    return len(lint_jaxpr(jaxpr, [weight_f32_temporaries(weight_shape)]))


def count_weight_f32_defs(fn, args, weight_shape) -> int:
    """`count_weight_f32_defs_jaxpr` of `jax.make_jaxpr(fn)(*args)`."""
    return count_weight_f32_defs_jaxpr(jax.make_jaxpr(fn)(*args),
                                       weight_shape)
