"""Static per-round communication model from the round-step jaxpr.

The CommLedger measures what the codec says a round costs; nothing in
that number proves the COLLECTIVES move the same amount.  This module
closes the gap from the static side: walk the traced round step with
the shared `jaxpr_lint` walker (descending `scan`/`cond`/`pjit`
sub-jaxprs and the `shard_map` body), record every collective operand
as a `CollectiveSite`, classify each site against the state's per-shard
shapes, and sum a predicted per-round wire cost — per collective, per
mesh axis, per algorithm (docs/DESIGN.md §2, §Analysis).

Two cost views per site:

  * accounting bits — operand bits x the number of executing shards;
    for the packed uint32 `all_gather` sites this is EXACTLY the number
    the CommLedger meters under the bitpack codec (every shard's pooled
    word stream, counted once), so the static and dynamic accounting
    can be cross-validated on a real mesh (`benchmarks/comm_bench.py
    --validate`, tolerance 2%);
  * ring bytes — what a ring implementation of the collective sends
    per device along its axis group (all_gather S*(A-1); psum
    2*S*(A-1)/A; reduce_scatter / all_to_all S*(A-1)/A; ppermute S).

The headline derived quantity is ``bpp_wire`` = uplink accounting bits
/ (cohorts x global mask params): the packed round step's masks cross
the pod axis at 1 bit per parameter per cohort plus word-padding slack
(<= 32 bits per leaf per cohort per shard); the bf16-psum baseline
crosses at 16.  That is the paper's <= 1 Bpp claim, read off the jaxpr
instead of asserted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_lint import JaxprRule, lint_jaxpr

# every cross-device data-moving primitive jax can put in a jaxpr (the
# *_invariant names are defensive: newer jax versions split the
# replication-checked variants out)
COLLECTIVE_PRIMS = frozenset({
    "all_gather", "all_gather_invariant",
    "psum", "psum_invariant", "psum2",
    "ppermute", "pbroadcast",
    "all_to_all", "reduce_scatter",
    "pmax", "pmin", "pgather",
})


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One operand of one collective equation in the round jaxpr."""
    prim: str
    axes: tuple          # mesh axis names the collective runs over
    shape: tuple         # per-shard operand shape
    dtype: str
    bits: int            # per-shard operand bits

    @property
    def elems(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1


class _CollectSites(JaxprRule):
    """Walker rule that records collective operands instead of failing."""

    name = "collect-collectives"

    def __init__(self):
        self.sites: list = []

    def check_eqn(self, eqn):
        if eqn.primitive.name not in COLLECTIVE_PRIMS:
            return ()
        axes = eqn.params.get("axes") or eqn.params.get("axis_name") or ()
        if not isinstance(axes, (tuple, list)):
            axes = (axes,)
        axes = tuple(str(a) for a in axes)
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            shape = tuple(int(s) for s in aval.shape)
            nbits = jnp.dtype(aval.dtype).itemsize * 8
            self.sites.append(CollectiveSite(
                prim=eqn.primitive.name, axes=axes, shape=shape,
                dtype=str(aval.dtype),
                bits=int(math.prod(shape)) * nbits if shape else nbits))
        return ()


def collect_collective_sites(jaxpr) -> list:
    """Every `CollectiveSite` in `jaxpr`, sub-jaxprs included."""
    rule = _CollectSites()
    lint_jaxpr(jaxpr, [rule])
    return rule.sites


# ---------------------------------------------------------------------------
# per-shard shape arithmetic (PartitionSpec -> local shapes)
# ---------------------------------------------------------------------------


def shard_shape(shape, spec, mesh) -> tuple:
    """Local (per-device) shape of a global `shape` under `spec`."""
    out = list(int(s) for s in shape)
    for d, entry in enumerate(tuple(spec)):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        k = 1
        for a in names:
            k *= int(mesh.shape[a])
        out[d] //= k
    return tuple(out)


def _leaves_with_specs(tree_shapes, tree_sh):
    nn = lambda x: x is None
    shapes = jax.tree_util.tree_leaves(tree_shapes, is_leaf=nn)
    shs = jax.tree_util.tree_leaves(tree_sh, is_leaf=nn)
    return [(l, s.spec) for l, s in zip(shapes, shs)
            if l is not None and s is not None]


def float_shard_shapes(state_shapes, state_sh, mesh) -> frozenset:
    """Per-shard shapes of the float-sidecar leaves (cohort axis
    included) — the ONLY non-scalar float shapes allowed to cross a
    collective on the packed round path (their FedAvg pmean)."""
    return frozenset(shard_shape(l.shape, spec, mesh)
                     for l, spec in _leaves_with_specs(
                         state_shapes["floats"], state_sh["floats"]))


def mask_shard_sizes(state_shapes, state_sh, mesh) -> frozenset:
    """Per-shard flat mask-stream sizes (cohort axis stripped, and with
    it) for every score leaf — the shapes an unpacked mask or raw score
    tree would have if it crossed a collective."""
    sizes = set()
    for l, spec in _leaves_with_specs(state_shapes["scores"],
                                      state_sh["scores"]):
        sh = shard_shape(l.shape, spec, mesh)
        body = int(math.prod(sh[1:])) if len(sh) > 1 else 1
        sizes.add(body)            # one cohort's stream
        sizes.add(body * sh[0])    # all local cohorts pooled
    return frozenset(sizes)


def mask_totals(state_shapes) -> tuple:
    """(cohorts, global mask params) — mirrors the round step's
    `_comm_totals` on the static shapes."""
    C, n = 1, 0
    for s in jax.tree_util.tree_leaves(state_shapes["scores"],
                                       is_leaf=lambda x: x is None):
        if s is None:
            continue
        C = s.shape[0]
        n += int(math.prod(s.shape[1:]))
    return C, n


# ---------------------------------------------------------------------------
# tracing the round step (shape-only: eval_shape state, no allocation)
# ---------------------------------------------------------------------------


def trace_round_jaxpr(api, scfg, mesh, C: int, codec=None,
                      optimizer: str = "momentum"):
    """(jaxpr, state_shapes, state_sh) of the mesh round step."""
    from repro.core import masking
    from repro.launch import steps as steplib

    state_shapes = jax.eval_shape(
        lambda k: steplib.init_fed_state(k, api, masking.MaskSpec(), C,
                                         optimizer=optimizer),
        jax.random.PRNGKey(0))
    state_sh = steplib.fed_state_shardings(state_shapes, mesh)
    fn = steplib.make_round_step(api, scfg, mesh=mesh, state_sh=state_sh,
                                 codec=codec)
    return jax.make_jaxpr(fn)(state_shapes), state_shapes, state_sh


# ---------------------------------------------------------------------------
# the static cost model
# ---------------------------------------------------------------------------

# ring-algorithm send volume per device for a per-shard payload of S
# bytes over an axis group of size A
def _ring_send_bytes(prim: str, S: float, A: int) -> float:
    if A <= 1:
        return 0.0
    if prim.startswith("all_gather"):
        return S * (A - 1)
    if prim.startswith("psum") or prim in ("pmax", "pmin"):
        return 2.0 * S * (A - 1) / A
    if prim in ("reduce_scatter", "all_to_all"):
        return S * (A - 1) / A
    if prim in ("ppermute", "pbroadcast", "pgather"):
        return float(S)
    return float(S)


def classify_site(site: CollectiveSite, *, float_shapes=frozenset(),
                  mask_sizes=frozenset()) -> str:
    """uplink | metric | sidecar | mask-unpacked | other."""
    if site.shape == ():
        return "metric"
    if site.dtype == "uint32" and site.prim.startswith("all_gather"):
        return "uplink"
    if site.dtype.startswith(("float", "bfloat")):
        if site.shape in float_shapes:
            return "sidecar"
        if site.elems in mask_sizes:
            return "mask-unpacked"   # the bf16-psum baseline's crossing
    return "other"


def round_comm_model(jaxpr, state_shapes, state_sh, mesh, scfg) -> dict:
    """Static per-round cost table for one traced round step.

    ``uplink_bits`` counts every shard's uplink payload once (the
    FL-accounting view the CommLedger meters); for the unpacked
    baseline the bf16 mask psums are counted as the uplink.  Downlink
    mirrors the round step's analytic `_comm_metrics` formula (theta
    broadcast is not a collective in the jaxpr: the post-round state
    carries it)."""
    sites = collect_collective_sites(jaxpr)
    fshapes = float_shard_shapes(state_shapes, state_sh, mesh)
    msizes = mask_shard_sizes(state_shapes, state_sh, mesh)
    n_dev = int(mesh.size)
    C, n_glob = mask_totals(state_shapes)

    rows, uplink_bits = [], 0
    per_axis: dict = {}
    per_kind: dict = {}
    for s in sites:
        A = 1
        for a in s.axes:
            if a in mesh.axis_names:
                A *= int(mesh.shape[a])
        role = classify_site(s, float_shapes=fshapes, mask_sizes=msizes)
        ring = _ring_send_bytes(s.prim, s.bits / 8.0, A)
        rows.append({
            "prim": s.prim, "axes": list(s.axes), "axis_size": A,
            "dtype": s.dtype, "shape": list(s.shape), "role": role,
            "payload_bits_per_shard": s.bits,
            "ring_send_bytes_per_device": round(ring, 1),
        })
        if role in ("uplink", "mask-unpacked"):
            uplink_bits += s.bits * n_dev
        ax = "x".join(s.axes) or "-"
        per_axis[ax] = per_axis.get(ax, 0.0) + ring * n_dev
        per_kind[s.prim] = per_kind.get(s.prim, 0.0) + ring * n_dev

    dl_bpp = float(scfg.downlink_bits) if scfg.downlink_bits else 32.0
    return {
        "mesh": {"shape": [int(mesh.shape[a]) for a in mesh.axis_names],
                 "axes": list(mesh.axis_names), "n_devices": n_dev},
        "cohorts": C,
        "mask_params": n_glob,
        "n_sites": len(rows),
        "sites": rows,
        "uplink_bits": int(uplink_bits),
        "bpp_wire": round(uplink_bits / float(C * n_glob), 4)
        if n_glob else 0.0,
        "downlink_bpp": dl_bpp,
        "downlink_bits": float(dl_bpp * n_glob * C),
        "ring_bytes_per_axis": {k: round(v, 1)
                                for k, v in sorted(per_axis.items())},
        "ring_bytes_per_prim": {k: round(v, 1)
                                for k, v in sorted(per_kind.items())},
    }


def tree_root_record_bits(leaf_params: Sequence[int], *,
                          acc_bits: int = 16, n_classes: int = 1,
                          float_elems: int = 0,
                          n_metrics: int = 0) -> dict:
    """Static wire cost of ONE edge aggregator's `PooledFoldRecord`
    (`runtime.agg_tree`) — the ONLY bytes that cross the edge -> root
    hop per commit.

    ``leaf_params`` are the true mask-leaf parameter counts; each leaf's
    count accumulator covers the word-padded bit domain
    (32 * ceil(n/32) positions) at ``acc_bits`` per position
    (`aggregation.packed_count_bits`).  Every weight class adds its
    packed counts plus a (size, version, count) header; the sidecar is
    the pooled float sums, pooled metric sums, and the entropy sum; the
    record header is the CRC32 fold checksum.  Nothing here depends on
    how many clients folded — that is the O(params) root-traffic claim,
    and `benchmarks/tree_bench.py` cross-validates this table against
    the CommLedger's measured ``root_bits`` exactly."""
    from repro.core import aggregation
    from repro.runtime.agg_tree import CLASS_HEADER_BITS
    from repro.api.codecs import HEADER_BITS

    wire = 0
    for n in leaf_params:
        padded = 32 * ((int(n) + 31) // 32)
        wire += aggregation.packed_count_bits(padded, acc_bits)
    wire = n_classes * (wire + CLASS_HEADER_BITS)
    sidecar = 32 * n_classes * (int(float_elems) + int(n_metrics) + 1)
    return {"wire_bits": int(wire), "sidecar_bits": int(sidecar),
            "header_bits": int(HEADER_BITS),
            "total_bits": int(wire + sidecar + HEADER_BITS)}


def tree_root_round_bits(leaf_params: Sequence[int], n_edges: int, *,
                         acc_bits: int = 16, n_classes: int = 1,
                         float_elems: int = 0,
                         n_metrics: int = 0) -> dict:
    """Per-commit root traffic of the whole aggregator tree: one pooled
    record per edge, O(params) x n_edges, independent of client count."""
    rec = tree_root_record_bits(leaf_params, acc_bits=acc_bits,
                                n_classes=n_classes,
                                float_elems=float_elems,
                                n_metrics=n_metrics)
    return {"n_edges": int(n_edges),
            "record_bits": rec,
            "root_bits": int(n_edges * (rec["wire_bits"]
                                        + rec["sidecar_bits"])),
            "root_header_bits": int(n_edges * rec["header_bits"]),
            "root_total_bits": int(n_edges * rec["total_bits"])}


def arch_round_comm_model(arch: str, algo: str = "fedpm_reg", *,
                          mesh=None, C: Optional[int] = None,
                          smoke: bool = True, codec: str = "bitpack",
                          packed: bool = True,
                          downlink_bits: int = 0) -> dict:
    """Cost model for one (arch, algorithm) registry cell.  Returns the
    `round_comm_model` dict plus the traced artifacts under "_trace"
    (stripped before serialization by the bench)."""
    from repro.configs import get_config
    from repro.launch import mesh as meshlib
    from repro.launch import plans, steps as steplib
    from repro.models import build_model

    if algo not in plans.MASK_ALGOS:
        raise ValueError(f"algorithm {algo!r} has no mask round step "
                         f"(known: {sorted(plans.MASK_ALGOS)})")
    if mesh is None:
        mesh = meshlib.make_debug_pod_mesh()
    if C is None:
        C = max(steplib.n_cohorts(mesh), 1)
    api = build_model(get_config(arch, smoke=smoke))
    scfg = steplib.StepConfig(packed_masks=packed,
                              downlink_bits=downlink_bits,
                              **plans.MASK_ALGOS[algo])
    jxp, state_shapes, state_sh = trace_round_jaxpr(api, scfg, mesh, C,
                                                    codec=codec)
    model = round_comm_model(jxp, state_shapes, state_sh, mesh, scfg)
    model["arch"] = arch
    model["algo"] = algo
    model["codec"] = codec
    model["packed"] = packed
    model["_trace"] = (jxp, state_shapes, state_sh, scfg, mesh)
    return model
