"""AST source lint: repo-specific rules over the ``src/`` tree.

Every rule takes explicit file paths (so the failing fixtures under
``tests/analysis_fixtures/`` can prove each rule fires) and returns
`Finding`s; `run_all` applies the real repo layout.

Rules:

  * ``bare-prngkey`` — no ``jax.random.PRNGKey(<const>)`` under
    ``launch/``: keys must derive from the run seed via the
    `mask_stream_seed` convention (the PRNGKey(17) and PRNGKey(29)
    bug class — a constant key silently decouples a stream from
    ``--seed``).  Allowlist: shape-only keys that feed
    ``jax.eval_shape``.
  * ``missing-oracle`` / ``missing-ref-bwd-hatch`` — every exported
    Pallas kernel in ``kernels/masked_matmul.py`` has a ``ref.py`` jnp
    oracle (same name, or `ORACLE_ALIASES`), and every kernel family
    with a backward has a ``REPRO_REF_BWD`` escape hatch in ``ops.py``.
  * ``knob-doc`` — every ``REPRO_*`` env knob READ in source appears in
    the README env-knob table: the table is the machine-checked source
    of truth.
  * ``materialize-allowlist`` — ``effective_weight`` /
    ``materialize_leaf`` call sites only where a weight-sized
    materialization is the design (the per-token decode residue and
    the one-time prefill freeze).
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.report import Finding

_SRC = pathlib.Path(__file__).resolve().parents[2]      # .../src
REPO_ROOT = _SRC.parent


def _rel(path) -> str:
    p = pathlib.Path(path).resolve()
    try:
        return p.relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return p.as_posix()


def _parse(path):
    return ast.parse(pathlib.Path(path).read_text(),
                     filename=str(path))


def _call_name(func) -> str:
    """Trailing name of a call target: jax.random.PRNGKey -> PRNGKey."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


# ---------------------------------------------------------------------------
# bare-prngkey
# ---------------------------------------------------------------------------

# (repo-relative file, constant) pairs where a constant key is fine:
# shape-only keys whose VALUE never reaches a mask or a quantizer
PRNGKEY_ALLOWLIST = frozenset({
    ("src/repro/launch/dryrun.py", 0),   # feeds jax.eval_shape only
})


def check_bare_prngkey(files, allowlist=PRNGKEY_ALLOWLIST) -> list:
    findings = []
    for path in files:
        rel = _rel(path)
        for node in ast.walk(_parse(path)):
            if not (isinstance(node, ast.Call)
                    and _call_name(node.func) == "PRNGKey"
                    and node.args):
                continue
            a = node.args[0]
            if isinstance(a, ast.Constant) and isinstance(a.value, int):
                if (rel, a.value) in allowlist:
                    continue
                findings.append(Finding(
                    "bare-prngkey", f"{rel}:{node.lineno}",
                    f"jax.random.PRNGKey({a.value}) — derive the key "
                    "from the run seed via the mask_stream_seed "
                    "convention"))
    return findings


def launch_files():
    return sorted((_SRC / "repro" / "launch").glob("*.py"))


# ---------------------------------------------------------------------------
# missing-oracle / missing-ref-bwd-hatch
# ---------------------------------------------------------------------------

ORACLE_ALIASES = {
    # masked_conv1d_ds's jnp oracle lives inside the combined conv
    # backward (dx needs the flipped-tap forward, so ref keeps one fn)
    "masked_conv1d_ds": "masked_conv1d_bwd",
}


def _kernel_family(name: str) -> str:
    if "grouped" in name or "grp" in name:
        return "grouped"
    if "conv" in name:
        return "conv"
    return "dense"


def _pallas_exports(tree) -> list:
    """Public top-level defs whose bodies call ``pl.pallas_call``."""
    out = []
    for node in tree.body:
        if (not isinstance(node, ast.FunctionDef)
                or node.name.startswith("_")):
            continue
        for sub in ast.walk(node):
            if (isinstance(sub, ast.Call)
                    and _call_name(sub.func) == "pallas_call"):
                out.append(node.name)
                break
    return out


def check_kernel_oracles(kernels_path, ref_path, ops_path,
                         aliases=ORACLE_ALIASES) -> list:
    findings = []
    exports = _pallas_exports(_parse(kernels_path))
    ref_names = {n.name for n in _parse(ref_path).body
                 if isinstance(n, ast.FunctionDef)}
    for name in exports:
        oracle = aliases.get(name, name)
        if oracle not in ref_names:
            findings.append(Finding(
                "missing-oracle", f"{_rel(kernels_path)}:{name}",
                f"exported Pallas kernel has no ref.py oracle "
                f"(expected `{oracle}`)"))
    # every kernel family with a backward kernel needs a REPRO_REF_BWD
    # escape hatch in ops.py (route grads through the jnp oracle)
    hatch_fams = set()
    for node in ast.walk(_parse(ops_path)):
        if isinstance(node, ast.FunctionDef):
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Constant)
                        and sub.value == "REPRO_REF_BWD"):
                    hatch_fams.add(_kernel_family(node.name))
                    break
    bwd_fams = {_kernel_family(n) for n in exports
                if n.endswith(("_dx", "_ds"))}
    for fam in sorted(bwd_fams - hatch_fams):
        findings.append(Finding(
            "missing-ref-bwd-hatch", _rel(ops_path),
            f"no REPRO_REF_BWD escape hatch for the `{fam}` backward"))
    return findings


# ---------------------------------------------------------------------------
# knob-doc
# ---------------------------------------------------------------------------


def env_knob_reads(files) -> list:
    """[(knob, "file:line")] for every ``os.environ.get`` /
    ``os.getenv`` / ``os.environ[...]`` READ of a ``REPRO_*`` name."""
    reads = []
    for path in files:
        rel = _rel(path)
        for node in ast.walk(_parse(path)):
            knob = None
            if isinstance(node, ast.Call) and node.args:
                name = _call_name(node.func)
                a = node.args[0]
                named = (isinstance(a, ast.Constant)
                         and isinstance(a.value, str)
                         and a.value.startswith("REPRO_"))
                if named and name == "getenv":
                    knob = a.value
                elif (named and name == "get"
                      and isinstance(node.func, ast.Attribute)):
                    v = node.func.value
                    if ((isinstance(v, ast.Attribute)
                         and v.attr == "environ")
                            or (isinstance(v, ast.Name)
                                and v.id == "environ")):
                        knob = a.value
            elif (isinstance(node, ast.Subscript)
                  and isinstance(node.ctx, ast.Load)
                  and isinstance(node.value, ast.Attribute)
                  and node.value.attr == "environ"
                  and isinstance(node.slice, ast.Constant)
                  and isinstance(node.slice.value, str)
                  and node.slice.value.startswith("REPRO_")):
                knob = node.slice.value
            if knob:
                reads.append((knob, f"{rel}:{node.lineno}"))
    return reads


def readme_knobs(readme_path) -> set:
    """``REPRO_*`` names with a row in the README env-knob table."""
    import re
    row = re.compile(r"^\|\s*`(REPRO_[A-Z0-9_]+)`")
    out = set()
    for line in pathlib.Path(readme_path).read_text().splitlines():
        m = row.match(line.strip())
        if m:
            out.add(m.group(1))
    return out


def check_knob_docs(files, readme_path) -> list:
    documented = readme_knobs(readme_path)
    return [Finding(
        "knob-doc", where,
        f"`{knob}` is read here but has no row in the README "
        "env-knob table (the machine-checked source of truth)")
        for knob, where in env_knob_reads(files)
        if knob not in documented]


# ---------------------------------------------------------------------------
# materialize-allowlist
# ---------------------------------------------------------------------------

MATERIALIZE_CALLS = frozenset({"effective_weight", "materialize_leaf"})

# (repo-relative file, enclosing function, callee): the ONLY places a
# weight-sized materialization is the design (docs/DESIGN.md §3)
MATERIALIZE_ALLOWLIST = frozenset({
    # per-token decode residue: one (W, C) conv tap per step
    ("src/repro/models/layers.py", "conv1d_step", "effective_weight"),
    # the wrapper itself delegates to the core builder
    ("src/repro/models/layers.py", "effective_weight",
     "materialize_leaf"),
    # one-time prefill materialization for serving
    ("src/repro/core/masking.py", "freeze_for_decode",
     "materialize_leaf"),
})


def check_materialize_allowlist(files,
                                allowlist=MATERIALIZE_ALLOWLIST) -> list:
    findings = []
    for path in files:
        rel = _rel(path)

        def visit(node, fname):
            for child in ast.iter_child_nodes(node):
                cf = fname
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    cf = child.name
                if isinstance(child, ast.Call):
                    callee = _call_name(child.func)
                    if (callee in MATERIALIZE_CALLS
                            and (rel, fname, callee) not in allowlist):
                        findings.append(Finding(
                            "materialize-allowlist",
                            f"{rel}:{child.lineno}",
                            f"`{callee}` called outside the allowlist "
                            f"(in `{fname or '<module>'}`) — a "
                            "weight-sized HBM materialization"))
                visit(child, cf)

        visit(_parse(path), "")
    return findings


# ---------------------------------------------------------------------------
# the real repo layout
# ---------------------------------------------------------------------------


def run_all(repo_root=REPO_ROOT) -> list:
    """All rules over the repo: ``launch/`` for bare keys, the kernel
    triple for oracles/hatches, ``src/ + benchmarks/`` for knob reads,
    ``src/`` for materializing calls."""
    repo_root = pathlib.Path(repo_root)
    src = repo_root / "src" / "repro"
    findings = []
    findings += check_bare_prngkey(
        sorted((src / "launch").glob("*.py")))
    findings += check_kernel_oracles(
        src / "kernels" / "masked_matmul.py",
        src / "kernels" / "ref.py",
        src / "kernels" / "ops.py")
    knob_files = (sorted(src.rglob("*.py"))
                  + sorted((repo_root / "benchmarks").glob("*.py")))
    findings += check_knob_docs(knob_files, repo_root / "README.md")
    findings += check_materialize_allowlist(sorted(src.rglob("*.py")))
    return findings
