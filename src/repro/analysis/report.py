"""Shared finding type for the repro.analysis engines.

Every engine (jaxpr_lint, stream_cover, source_lint) reports rule
violations as `Finding`s; `tools/repro_lint.py` stringifies them into
the shared ``FAIL ...`` / ``# repro_lint: ...`` CI convention.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    rule   — kebab-case rule id (e.g. ``weight-f32-temporary``)
    where  — location: ``file:line``, a jaxpr primitive name, or a
             masked-leaf path
    detail — what was actually seen there
    """

    rule: str
    where: str
    detail: str = ""

    def __str__(self) -> str:
        d = f": {self.detail}" if self.detail else ""
        return f"[{self.rule}] {self.where}{d}"
