"""Wire-purity rules over the round step's collectives.

The packed uplink's contract (docs/DESIGN.md §2): the ONLY values that
may cross a collective in the mask round are

  * bit-packed uint32 word streams (the 1 Bpp uplink itself),
  * the float sidecar leaves' FedAvg pmean — per-shard float-tree
    shapes, cohort axis included — and
  * O(1) scalar metrics (the pooled bits_total psum).

Everything else is a leak: an f32 score/weight tree in an `all_gather`
inflates real traffic 32x over the measured codec number; an unpacked
bool/uint8 mask inflates it 8x.  `CollectivePurityRule` enforces the
contract as a strict allowlist over every collective operand the
shared `jaxpr_lint` walker can reach (shard_map bodies and
scan/cond/pjit sub-jaxprs included), so the CommLedger's measured
bits and the wire's actual payload cannot drift apart silently.

Findings carry two rule names:
  * ``collective-f32-weight``   — a non-allowlisted float operand;
  * ``collective-unpacked-mask`` — a mask-sized bool/uint8/int8 (or
    other non-u32 integer) operand.

Demonstrated by `tests/analysis_fixtures/bad_collective.py`; the
clean-at-HEAD twin lives in `tests/test_collective.py` and the dryrun
gate (`launch/dryrun.py` raises on any finding when lowering a round
cell).
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro.analysis import comm_model
from repro.analysis.jaxpr_lint import JaxprRule, lint_jaxpr
from repro.analysis.report import Finding

# collective operands with at most this many non-u32 integer elements
# are treated as O(1) bookkeeping, not a mask stream
_SCALAR_SLACK_ELEMS = 32


class CollectivePurityRule(JaxprRule):
    """Strict allowlist over collective operands (see module doc)."""

    name = "collective-wire-purity"

    def __init__(self, allowed_float_shapes=frozenset(), *,
                 max_small_elems: int = _SCALAR_SLACK_ELEMS):
        self._allowed = frozenset(tuple(s) for s in allowed_float_shapes)
        self._max_small = max_small_elems

    def check_eqn(self, eqn):
        if eqn.primitive.name not in comm_model.COLLECTIVE_PRIMS:
            return ()
        axes = eqn.params.get("axes") or eqn.params.get("axis_name") \
            or ()
        out = []
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            shape = tuple(int(s) for s in aval.shape)
            if shape == ():          # O(1) scalar metrics
                continue
            dt = jnp.dtype(aval.dtype)
            elems = int(math.prod(shape))
            where = f"{eqn.primitive.name}[{','.join(map(str, axes))}]"
            if dt == jnp.dtype(jnp.uint32):
                continue             # packed words
            if jnp.issubdtype(dt, jnp.floating):
                if shape in self._allowed:
                    continue         # float-sidecar pmean
                out.append(Finding(
                    "collective-f32-weight", where,
                    f"{dt}{list(shape)} operand is not a packed word "
                    f"stream, a float-sidecar leaf, or a scalar"))
            elif elems > self._max_small:
                out.append(Finding(
                    "collective-unpacked-mask", where,
                    f"{dt}{list(shape)} operand: unpacked mask-sized "
                    f"integer data on the wire"))
        return out


def purity_findings(jaxpr, allowed_float_shapes=frozenset()) -> list:
    """Run the purity rule over one traced function."""
    return lint_jaxpr(jaxpr,
                      [CollectivePurityRule(allowed_float_shapes)])


def round_purity_findings(jaxpr, state_shapes, state_sh, mesh) -> list:
    """Purity findings for a traced round step: the float allowlist is
    derived from the state's own per-shard float-sidecar shapes."""
    allowed = comm_model.float_shard_shapes(state_shapes, state_sh,
                                            mesh)
    return purity_findings(jaxpr, allowed)


def arch_collective_report(arch: str, algo: str = "fedpm_reg", *,
                           mesh=None, C: Optional[int] = None,
                           smoke: bool = True, codec: str = "bitpack",
                           packed: bool = True) -> dict:
    """Trace one (arch, algorithm) round cell, lint its collectives,
    and return the findings together with the static cost model."""
    model = comm_model.arch_round_comm_model(
        arch, algo, mesh=mesh, C=C, smoke=smoke, codec=codec,
        packed=packed)
    jxp, state_shapes, state_sh, _scfg, mesh_used = model.pop("_trace")
    findings = round_purity_findings(jxp, state_shapes, state_sh,
                                     mesh_used)
    return {"findings": findings, "model": model,
            "n_sites": model["n_sites"]}
