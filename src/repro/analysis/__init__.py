"""repro.analysis — static guards for the mask-native invariants.

Five engines (docs/DESIGN.md §Analysis) behind one CLI
(``tools/repro_lint.py``, the CI ``lint`` job):

  * ``jaxpr_lint``   — rule-based closed-jaxpr walker (weight-shaped
    f32 temporaries, materialized masks, dtype promotions, donated
    buffer reuse); ``benchmarks/kernels_bench.py`` and the tier-1 twin
    in ``tests/test_steps.py`` are thin callers of this traversal.
  * ``stream_cover`` — the mask-stream coverage checker ("stream race
    detector"): every `MaskedLeaf`'s (seed, off, size) intervals must
    tile its flat hash stream exactly, and no two (leaf, shard,
    cohort) streams may share a seed.  Also the dryrun-mode gate.
  * ``source_lint``  — AST rules over the ``src/`` tree (bare
    PRNGKeys, kernel-oracle completeness, env-knob docs, the
    materializing-call allowlist).
  * ``collective_lint`` + ``comm_model`` — wire purity of the round
    step's collectives (only packed uint32 words, float-sidecar
    leaves, and scalar metrics may cross) and the static per-round
    cost model (bytes per collective per mesh axis per algorithm,
    cross-validated against the CommLedger on a real mesh; the
    committed ``BENCH_comm.json`` tables).
  * ``shard_lint``   — `launch/sharding.py` annotations vs reality:
    big leaves the divisibility heuristic silently replicated
    (`sharding.explain_spec` traces), and declared NamedShardings vs
    the compiled executable's actual input shardings.

``model_check`` carries the MXU-aligned whole-model configs the jaxpr
gate runs end-to-end on (import it directly — it pulls the model zoo).
"""
from repro.analysis.comm_model import (CollectiveSite,
                                       collect_collective_sites)
from repro.analysis.jaxpr_lint import (count_weight_f32_defs,
                                       count_weight_f32_defs_jaxpr,
                                       lint_jaxpr)
from repro.analysis.report import Finding

__all__ = ["CollectiveSite", "Finding", "collect_collective_sites",
           "count_weight_f32_defs", "count_weight_f32_defs_jaxpr",
           "lint_jaxpr"]
