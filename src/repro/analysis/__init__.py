"""repro.analysis — static guards for the mask-native invariants.

Three engines (docs/DESIGN.md §Analysis) behind one CLI
(``tools/repro_lint.py``, the CI ``lint`` job):

  * ``jaxpr_lint``   — rule-based closed-jaxpr walker (weight-shaped
    f32 temporaries, materialized masks, dtype promotions, donated
    buffer reuse); ``benchmarks/kernels_bench.py`` and the tier-1 twin
    in ``tests/test_steps.py`` are thin callers of this traversal.
  * ``stream_cover`` — the mask-stream coverage checker ("stream race
    detector"): every `MaskedLeaf`'s (seed, off, size) intervals must
    tile its flat hash stream exactly, and no two (leaf, shard,
    cohort) streams may share a seed.  Also the dryrun-mode gate.
  * ``source_lint``  — AST rules over the ``src/`` tree (bare
    PRNGKeys, kernel-oracle completeness, env-knob docs, the
    materializing-call allowlist).

``model_check`` carries the MXU-aligned whole-model configs the jaxpr
gate runs end-to-end on (import it directly — it pulls the model zoo).
"""
from repro.analysis.jaxpr_lint import (count_weight_f32_defs,
                                       count_weight_f32_defs_jaxpr,
                                       lint_jaxpr)
from repro.analysis.report import Finding

__all__ = ["Finding", "count_weight_f32_defs",
           "count_weight_f32_defs_jaxpr", "lint_jaxpr"]
