"""MXU-aligned whole-model check configs and jaxpr tracing helpers.

The end-to-end gate behind the "fused train step defines zero
weight-shaped f32 temporaries" claim lives here, shared by THREE
consumers — ``benchmarks/kernels_bench.py`` (timing + BENCH JSON), the
tier-1 twin in ``tests/test_steps.py``, and ``tools/repro_lint.py`` —
so there is exactly one traversal and one set of check configs, and
counts stay comparable everywhere.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_lint import count_weight_f32_defs_jaxpr
from repro.configs import ArchConfig
from repro.core import masking
from repro.launch import steps as steplib
from repro.models import build_model

# MXU-aligned model configs: every masked trailing-2D block — incl.
# the STACKED MoE expert (E, K, N) and depthwise conv (W, C) leaves —
# is lane-aligned, so every fused launch is unpadded and the counts
# below are exact.  vocab=320 keeps the (float) unembed cast from
# colliding with any masked block shape; activation dims (B, S, cap)
# are chosen so no 2-D f32 activation collides with a block shape.
MODEL_CHECK_CFG = ArchConfig(
    name="bench-aligned", family="dense", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=2, d_ff=256, vocab=320, head_dim=64)

# deepseek-style MoE: MLA attention (all factors 128-aligned) + 1 dense
# + 1 MoE layer of 2 routed experts (stacked (2, 128, 128) leaves ->
# the GROUPED kernel) + 1 shared expert
MOE_CHECK_CFG = ArchConfig(
    name="bench-moe-aligned", family="moe", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=2, d_ff=256, vocab=320,
    kv_lora_rank=128, q_lora_rank=0, qk_nope_dim=128, qk_rope_dim=128,
    v_head_dim=128, n_experts=2, n_shared_experts=1, top_k=2,
    moe_d_ff=128, first_dense_layers=1)

# recurrentgemma-style hybrid: RG-LRU blocks with a (4, 128) depthwise
# conv kernel leaf (-> the fused conv kernel) + local attention
HYBRID_CHECK_CFG = ArchConfig(
    name="bench-hybrid-aligned", family="hybrid", n_layers=3,
    d_model=128, n_heads=2, n_kv_heads=2, d_ff=256, vocab=320,
    head_dim=64, sliding_window=16, block_pattern=("rec", "rec", "attn"),
    lru_width=128, conv_width=4)

MODEL_CHECK_CFGS = {"dense": (MODEL_CHECK_CFG, 64),
                    "moe": (MOE_CHECK_CFG, 48),
                    "hybrid": (HYBRID_CHECK_CFG, 32)}


def model_step_setup(cfg: ArchConfig = MODEL_CHECK_CFG, C: int = 1,
                     B: int = 2, S: int = 64):
    """(api, fed state, cohort batch) for an aligned check config."""
    api = build_model(cfg)
    state = steplib.init_fed_state(jax.random.PRNGKey(0), api,
                                   masking.MaskSpec(), C=C)
    tokens = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 3) \
        % cfg.vocab
    batch = {"tokens": jnp.broadcast_to(tokens, (C, B, S))}
    return api, state, batch


def masked_block_shapes(state):
    """Distinct trailing-2D block shapes of every masked leaf."""
    return sorted({tuple(l.shape[-2:]) for l in
                   jax.tree_util.tree_leaves(state["scores"])
                   if l is not None})


def masked_leaf_shapes(state):
    """Distinct FULL leaf shapes (C, L[, E], K, N) of the score tree."""
    return sorted({tuple(l.shape) for l in
                   jax.tree_util.tree_leaves(state["scores"])
                   if l is not None})


def trace_model_step(api, state, batch, scfg, eff_path: bool,
                     jit_compile: bool = False):
    """(jaxpr, jitted-executable-or-None) of the train step under the
    chosen execution path.  Lowering happens INSIDE the REPRO_EFF_PATH
    guard — the path is chosen at trace time.  `jit_compile=False`
    (analysis) skips XLA compilation; the bench passes True to time the
    executable."""
    prev = os.environ.get("REPRO_EFF_PATH")
    os.environ["REPRO_EFF_PATH"] = "1" if eff_path else "0"
    try:
        step = steplib.make_train_step(api, scfg)
        compiled = (jax.jit(step).lower(state, batch).compile()
                    if jit_compile else None)
        return jax.make_jaxpr(step)(state, batch), compiled
    finally:
        if prev is None:
            os.environ.pop("REPRO_EFF_PATH", None)
        else:
            os.environ["REPRO_EFF_PATH"] = prev


def model_step_weight_defs(cfg: ArchConfig = MODEL_CHECK_CFG,
                           S: int = 64):
    """The end-to-end invariant on the whole-model train step (jaxpr
    counts only — no XLA compile, no timing; the bench layers those on
    top via `trace_model_step(..., jit_compile=True)`).

    Two granularities:
      * block shapes — the trailing-2D tile one fused launch consumes
        ((K, N) dense blocks, the (K, N) of a stacked (E, K, N) expert
        leaf, the (W, C) of a conv kernel leaf); the FUSED path must
        define ZERO f32 values at any of them outside pallas_call
        (forward and backward).
      * full leaf shapes (C, L[, E], K, N) — where the materialized
        REPRO_EFF_PATH reference pays: hash uniforms, sigmoid(theta),
        the STE mask.  Both paths share the score-sized regularizer /
        optimizer arithmetic at this scale, so the assertion is
        RELATIVE: eff must define strictly more than fused on every
        leaf.
    """
    api, state, batch = model_step_setup(cfg, S=S)
    scfg = steplib.StepConfig(lam=0.1, lr=0.5)
    fused_jx, _ = trace_model_step(api, state, batch, scfg,
                                   eff_path=False)
    eff_jx, _ = trace_model_step(api, state, batch, scfg,
                                 eff_path=True)
    out = {"block_shapes": {}, "leaf_shapes": {}}
    for sh in masked_block_shapes(state):
        out["block_shapes"]["x".join(map(str, sh))] = {
            "eff": count_weight_f32_defs_jaxpr(eff_jx, sh),
            "fused": count_weight_f32_defs_jaxpr(fused_jx, sh)}
    for sh in masked_leaf_shapes(state):
        out["leaf_shapes"]["x".join(map(str, sh))] = {
            "eff": count_weight_f32_defs_jaxpr(eff_jx, sh),
            "fused": count_weight_f32_defs_jaxpr(fused_jx, sh)}
    return out
