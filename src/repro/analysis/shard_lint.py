"""Declared-vs-actual sharding lint for the launch layer.

Two independent checks (docs/DESIGN.md §Analysis):

  * silent replication — `launch/sharding.py`'s heuristics only shard
    a dim when the mesh axis size divides it; when nothing divides, the
    leaf silently replicates and every device stores (and, with
    optimizer state, updates) the full tensor.  `explain_spec` now
    records each skipped dim; this engine flags leaves whose spec came
    out fully replicated WITH at least one recorded skip and a body
    big enough to matter (deliberately replicated norms/scalars record
    no skips and never fire).  Rule name: ``shard-silent-replication``
    (fixture: `tests/analysis_fixtures/bad_sharding.py`).

  * declared vs lowered — the NamedShardings the launch layer declares
    must be the shardings the compiled executable actually ingests;
    `compiled.input_shardings` is compared leaf-by-leaf (rule name:
    ``shard-spec-mismatch``).  A mismatch means jit resharded (or XLA
    overrode) an input behind the launcher's back — an extra
    all-to-all on every step that no ledger meters.
"""
from __future__ import annotations

import math
from typing import Optional

import jax

from repro.analysis.report import Finding
from repro.launch import sharding as shd

# replicated bodies smaller than this are noise, not a capacity problem
_MIN_ELEMS = 1024


def silent_replication_report(tree_shapes, mesh, *, scan_dims_fn=None,
                              min_elems: int = _MIN_ELEMS,
                              label: str = "") -> dict:
    """Explain every leaf's spec; flag big fully-replicated leaves
    whose replication came from divisibility skips, not policy."""
    nn = lambda x: x is None
    findings, explanations = [], []

    def one(path, leaf):
        if leaf is None:
            return
        p = shd._path_str(path)
        sd = (scan_dims_fn(p, leaf) if scan_dims_fn
              else shd._default_scan_dims(p))
        sd = min(sd, max(len(leaf.shape) - 1, 0))
        ex = shd.explain_spec(p, leaf.shape, mesh, scan_dims=sd)
        explanations.append(ex)
        body = leaf.shape[sd:]
        if (ex.skipped and all(e is None for e in tuple(ex.spec))
                and int(math.prod(body)) >= min_elems):
            findings.append(Finding(
                "shard-silent-replication",
                f"{label}{p}",
                f"{list(leaf.shape)} fully replicated by fallback: "
                + "; ".join(ex.skipped)))

    jax.tree_util.tree_map_with_path(
        lambda path, leaf: one(path, leaf), tree_shapes, is_leaf=nn)
    return {"findings": findings, "explanations": explanations}


def input_sharding_mismatches(compiled, declared, shapes_tree,
                              label: str = "") -> list:
    """Compare `compiled.input_shardings` against the declared
    NamedSharding tree for the SAME (single-argument) pytree.  jit
    prunes arguments the step never reads (the round step's opt_m is
    zeroed, not read), so the declared list is aligned through the
    executable's kept-variable indices before comparing."""
    nn = lambda x: x is None
    decl = [x for x in jax.tree_util.tree_leaves(declared, is_leaf=nn)
            if x is not None]
    shapes = [x for x in
              jax.tree_util.tree_leaves(shapes_tree, is_leaf=nn)
              if x is not None]
    paths = [shd._path_str(p) for p, x in
             jax.tree_util.tree_flatten_with_path(
                 shapes_tree, is_leaf=nn)[0]
             if x is not None]
    actual = list(jax.tree_util.tree_leaves(compiled.input_shardings[0]))
    kept = getattr(getattr(compiled, "_executable", None),
                   "_kept_var_idx", None)
    if kept is not None and len(actual) != len(decl):
        idxs = sorted(kept)
        if len(idxs) == len(actual) and (not idxs
                                         or idxs[-1] < len(decl)):
            decl = [decl[i] for i in idxs]
            shapes = [shapes[i] for i in idxs]
            paths = [paths[i] for i in idxs]
    if len(actual) != len(decl):
        return [Finding(
            "shard-spec-mismatch", label or "<args>",
            f"flattened arity drift: {len(decl)} declared vs "
            f"{len(actual)} lowered input shardings")]
    out = []
    for p, d, a, s in zip(paths, decl, actual, shapes):
        if not a.is_equivalent_to(d, len(s.shape)):
            out.append(Finding(
                "shard-spec-mismatch", f"{label}{p}",
                f"declared {d.spec} but the executable ingests {a}"))
    return out


def round_shard_report(api, scfg, mesh, C: int, codec=None) -> dict:
    """Both checks over one round cell: silent replication across the
    federated state, and declared-vs-lowered on the COMPILED round
    step."""
    from repro.core import masking
    from repro.launch import steps as steplib

    state_shapes = jax.eval_shape(
        lambda k: steplib.init_fed_state(k, api, masking.MaskSpec(), C),
        jax.random.PRNGKey(0))
    state_sh = steplib.fed_state_shardings(state_shapes, mesh)
    rep = silent_replication_report(state_shapes["weights"], mesh,
                                    label="weights/")
    fn = steplib.make_round_step(api, scfg, mesh=mesh,
                                 state_sh=state_sh, codec=codec)
    compiled = jax.jit(
        fn, in_shardings=(state_sh,),
        out_shardings=(state_sh, shd.replicated(mesh)),
    ).lower(state_shapes).compile()
    mism = input_sharding_mismatches(compiled, state_sh, state_shapes,
                                     label="state/")
    return {"findings": rep["findings"] + mism,
            "explanations": rep["explanations"],
            "n_leaves": len(rep["explanations"])}


def arch_shard_report(arch: str, algo: str = "fedpm_reg", *,
                      mesh=None, C: Optional[int] = None,
                      smoke: bool = True, codec: str = "bitpack",
                      compile_step: bool = False) -> dict:
    """Registry-level entry: silent-replication over the arch's param
    tree (always) and, with ``compile_step``, the full round-cell
    declared-vs-lowered check."""
    from repro.configs import get_config
    from repro.launch import mesh as meshlib
    from repro.launch import plans, steps as steplib
    from repro.models import build_model

    if mesh is None:
        mesh = meshlib.make_debug_pod_mesh()
    if C is None:
        C = max(steplib.n_cohorts(mesh), 1)
    api = build_model(get_config(arch, smoke=smoke))
    if compile_step:
        scfg = steplib.StepConfig(**plans.MASK_ALGOS[algo])
        return round_shard_report(api, scfg, mesh, C, codec=codec)
    params_shapes = jax.eval_shape(api.init_params,
                                   jax.random.PRNGKey(0))
    return silent_replication_report(params_shapes, mesh,
                                     label=f"{arch}/")
