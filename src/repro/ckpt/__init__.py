from repro.ckpt.checkpoint import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, AsyncCheckpointer,
    save_artifact, load_artifact, load_raw, save_bundle, load_bundle,
    bundle_exists,
)
