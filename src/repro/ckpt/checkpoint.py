"""Fault-tolerant checkpointing (no orbax offline — built from scratch).

Design for 1000+ node operation:
  * atomic writes: tmp file + os.replace, manifest written last; a crash
    mid-write never corrupts the latest checkpoint.
  * layout is pytree-path keyed .npy entries inside one .npz per step +
    a JSON manifest (step, pytree structure, shapes, dtypes).
  * restore is MESH-AGNOSTIC: arrays are loaded on host then re-sharded
    by the caller's in_shardings — elastic re-entry onto a different
    mesh shape (runtime/elastic.py drives this).
  * AsyncCheckpointer ships the device->host copy + serialization to a
    background thread so the train loop never blocks on disk.
  * `save_artifact` stores the paper's deployable artifact:
    (seed, bitpacked masks, float leaves) — n/8 bytes instead of 4n.
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SENTINEL = "__none__"


def _flatten(tree: Pytree):
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree: Pytree,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": int(step), "keys": [], "extra": extra or {},
                "dtypes": {}}
    for k, v in flat.items():
        manifest["keys"].append(k)
        if v is None:
            arrays[k] = np.asarray(_SENTINEL)
            continue
        a = np.asarray(jax.device_get(v))
        if a.dtype.name == "bfloat16":  # npz can't round-trip bf16
            manifest["dtypes"][k] = "bfloat16"
            a = a.view(np.uint16)
        arrays[k] = a
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.npz")
    final = os.path.join(ckpt_dir, f"step_{step}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **{k.replace("/", "|"): v for k, v in arrays.items()})
    os.replace(tmp, final)                     # atomic
    mtmp = os.path.join(ckpt_dir, ".tmp_manifest.json")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, f"manifest_{step}.json"))
    # "latest" pointer last — readers only trust complete checkpoints
    ltmp = os.path.join(ckpt_dir, ".tmp_latest")
    with open(ltmp, "w") as f:
        f.write(str(step))
    os.replace(ltmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def load_raw(ckpt_dir: str, step: Optional[int] = None
             ) -> tuple[dict, dict]:
    """Load one checkpoint's arrays WITHOUT a structure template.

    Returns ``({path_key: np.ndarray | None}, manifest)`` — the raw
    host-side view `runtime/elastic.py` needs for shape-tolerant
    partial restores (the caller matches keys against its own state and
    decides what to do with mismatched cohort/mesh axes)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"step_{step}.npz"),
                   allow_pickle=False)
    with open(os.path.join(ckpt_dir, f"manifest_{step}.json")) as f:
        manifest = json.load(f)
    bf16_keys = set(manifest.get("dtypes", {}))
    out = {}
    for nk in data.files:
        k = nk.replace("|", "/")
        arr = data[nk]
        if arr.dtype.kind in ("U", "V") and k not in bf16_keys:
            out[k] = None
        else:
            if k in bf16_keys:
                import ml_dtypes
                arr = arr.view(np.uint16).astype(np.uint16).view(
                    ml_dtypes.bfloat16)
            out[k] = arr
    return out, manifest


def restore_checkpoint(ckpt_dir: str, tree_like: Pytree,
                       step: Optional[int] = None) -> tuple[Pytree, int]:
    """Restore into the structure of `tree_like` (shapes may be loaded
    onto a different mesh by the caller via device_put + shardings)."""
    out, manifest = load_raw(ckpt_dir, step)
    step = int(manifest["step"])
    flat_like = _flatten(tree_like)
    for k, leaf in flat_like.items():
        if k not in out:
            raise KeyError(f"checkpoint missing leaf {k}")
        got = out[k]
        if (leaf is not None and got is not None
                and hasattr(leaf, "shape")
                and tuple(got.shape) != tuple(leaf.shape)):
            # elastic resize / different arch: let the caller fall
            # back to a partial restore (runtime.elastic)
            raise ValueError(
                f"checkpoint leaf {k} has shape {tuple(got.shape)}, "
                f"expected {tuple(leaf.shape)}")
    # rebuild pytree in tree_like's structure
    paths_leaves = jax.tree_util.tree_flatten_with_path(
        tree_like, is_leaf=lambda x: x is None)
    treedef = paths_leaves[1]
    leaves = []
    for path, _ in paths_leaves[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Background-thread checkpointer: save() returns immediately after
    device_get is enqueued; wait() drains. Keeps at most `keep` latest."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(f[5:-4]) for f in os.listdir(self.ckpt_dir)
            if f.startswith("step_") and f.endswith(".npz"))
        for s in steps[:-self.keep]:
            for name in (f"step_{s}.npz", f"manifest_{s}.json"):
                try:
                    os.remove(os.path.join(self.ckpt_dir, name))
                except OSError:
                    pass

    def save(self, step: int, tree: Pytree, extra: Optional[dict] = None):
        if self._err:
            raise self._err
        host = jax.tree_util.tree_map(
            lambda x: None if x is None else np.asarray(jax.device_get(x)),
            tree, is_leaf=lambda x: x is None)
        self._q.put((int(step), host, extra))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join()


# ---------------------------------------------------------------------------
# Atomic state bundles — flat {key: array} + JSON extra, one file pair.
# The buffered-async round engine checkpoints its aggregation buffer,
# in-flight messages, and fault-RNG cursor through these, so a
# coordinator crash mid-buffer resumes byte-identically (the same
# tmp-file + os.replace discipline as step checkpoints).
# ---------------------------------------------------------------------------


def save_bundle(path: str, arrays: dict, extra: Optional[dict] = None
                ) -> str:
    """Atomically write a flat ``{key: np.ndarray | None}`` dict plus a
    JSON-serializable ``extra`` manifest to ``path``(.npz/.json)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    out, dtypes = {}, {}
    for k, v in arrays.items():
        nk = k.replace("/", "|")
        if v is None:
            out[nk] = np.asarray(_SENTINEL)
            continue
        a = np.asarray(jax.device_get(v))
        if a.dtype.name == "bfloat16":
            dtypes[k] = "bfloat16"
            a = a.view(np.uint16)
        out[nk] = a
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **out)
    os.replace(tmp, path + ".npz")
    # manifest LAST: readers only trust bundles with a manifest
    mtmp = path + ".tmp.json"
    with open(mtmp, "w") as f:
        json.dump({"extra": extra or {}, "dtypes": dtypes}, f)
    os.replace(mtmp, path + ".json")
    return path + ".npz"


def load_bundle(path: str) -> tuple[dict, dict]:
    """Inverse of `save_bundle`: ``({key: array | None}, extra)``."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz", allow_pickle=False)
    bf16_keys = set(manifest.get("dtypes", {}))
    out = {}
    for nk in data.files:
        k = nk.replace("|", "/")
        arr = data[nk]
        if arr.dtype.kind in ("U", "V") and k not in bf16_keys:
            out[k] = None
        else:
            if k in bf16_keys:
                import ml_dtypes
                arr = arr.view(np.uint16).view(ml_dtypes.bfloat16)
            out[k] = arr
    return out, manifest.get("extra", {})


def bundle_exists(path: str) -> bool:
    return os.path.exists(path + ".json") and os.path.exists(
        path + ".npz")


# ---------------------------------------------------------------------------
# Deployable artifact: (seed, bitpacked mask) — the paper's end product
# ---------------------------------------------------------------------------


def save_artifact(path: str, artifact: dict) -> int:
    """artifact from federated.final_artifact(). Returns bytes written."""
    arrays = {"seed": np.asarray(jax.device_get(artifact["seed"]))}
    shapes = {}
    for k, (words, shape) in artifact["masks"].items():
        arrays["mask|" + k.replace("/", "|")] = np.asarray(
            jax.device_get(words))
        shapes[k] = list(shape)
    bf16 = []
    for k, v in _flatten(artifact["floats"]).items():
        if v is not None:
            a = np.asarray(jax.device_get(v))
            if a.dtype.name == "bfloat16":
                a = a.view(np.uint16)
                bf16.append(k)
            arrays["float|" + k.replace("/", "|")] = a
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    with open(path + ".json", "w") as f:
        json.dump({"shapes": shapes, "bf16_floats": bf16}, f)
    return os.path.getsize(path)


def load_artifact(path: str):
    data = np.load(path)
    with open(path + ".json") as f:
        meta = json.load(f)
    shapes = meta.get("shapes", meta)  # tolerate legacy layout
    bf16 = set(meta.get("bf16_floats", []))
    masks = {}
    for k in data.files:
        if k.startswith("mask|"):
            key = k[5:].replace("|", "/")
            masks[key] = (data[k], tuple(shapes[key]))
    floats = {}
    for k in data.files:
        if k.startswith("float|"):
            key = k[6:].replace("|", "/")
            a = data[k]
            if key in bf16:
                import ml_dtypes
                a = a.view(ml_dtypes.bfloat16)
            floats[key] = a
    return {"seed": data["seed"], "masks": masks, "floats": floats}
