"""Serving example: load a (seed, bitpacked-mask) artifact, materialize
the sparse sub-network, and decode with a KV cache under batched
requests — the paper's "SEED + binary mask is the whole model" claim,
live.

    PYTHONPATH=src:. python examples/serve_masked.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import masking, federated
from repro.models import build_model
from repro.launch import steps as steplib


def main():
    cfg = ArchConfig(name="serve-demo", family="dense", n_layers=4,
                     d_model=256, n_heads=4, n_kv_heads=2, d_ff=1024,
                     vocab=4096, head_dim=64)
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    spec = masking.MaskSpec()

    # --- "train side": produce the artifact ---------------------------
    params_like = api.init_params(key)
    server = federated.init_server(key, params_like, spec)
    art = federated.final_artifact(server, key)
    n = sum(int(np.prod(sh)) for _, (w, sh) in art["masks"].items())
    packed_bytes = sum(int(w.size) * 4 for _, (w, sh)
                       in art["masks"].items())
    print(f"artifact: {n} masked params -> {packed_bytes} packed bytes "
          f"({8*packed_bytes/n:.2f} bits/param)")

    # --- "serve side": regenerate weights from the seed, apply mask ---
    from repro.core import aggregation
    mp = masking.init_masked(key, params_like, spec)  # same seed
    flat = {p: l for p, l in masking.leaves_with_paths(mp.weights)}

    def materialize(path, w):
        if w is None or path not in art["masks"]:
            return w
        words, shape = art["masks"][path]
        m = aggregation.unpack_bits(jnp.asarray(words),
                                    int(np.prod(shape))).reshape(shape)
        return (m.astype(w.dtype) * w)

    eff = jax.tree_util.tree_map_with_path(
        lambda p, w: materialize(
            "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in p), w),
        mp.weights, is_leaf=lambda x: x is None)
    # float leaves from the artifact
    eff = jax.tree_util.tree_map(
        lambda e, f: f if e is None else e, eff, mp.floats,
        is_leaf=lambda x: x is None)

    # --- batched decode ------------------------------------------------
    B, prompt_len, gen = 8, 32, 16
    serve = jax.jit(steplib.make_serve_step(api))
    cache = api.init_cache(B, prompt_len + gen)
    prompt = jax.random.randint(key, (B, prompt_len), 0, cfg.vocab)
    # prefill by stepping (simple reference path)
    tok = prompt[:, 0]
    t0 = time.time()
    for t in range(prompt_len + gen - 1):
        logits, cache = serve(eff, cache, tok,
                              jnp.asarray(t, jnp.int32))
        tok = (prompt[:, t + 1] if t + 1 < prompt_len
               else jnp.argmax(logits, -1).astype(jnp.int32))
    dt = time.time() - t0
    print(f"decoded {gen} tokens x {B} requests in {dt:.2f}s "
          f"({B*gen/dt:.1f} tok/s on CPU)")
    print("sample continuation ids:", np.asarray(tok)[:8])


if __name__ == "__main__":
    main()
