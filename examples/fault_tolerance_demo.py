"""Fault-tolerance demo: kill 30% of clients every round + straggler
cuts + a mid-run checkpoint restore, and show training still converges
(the weighted mask mean renormalizes over survivors).

    PYTHONPATH=src:. python examples/fault_tolerance_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masking, federated
from repro.models import cnn
from repro.data import synthetic, partition
from repro.runtime import fault
from repro import ckpt


def main():
    key = jax.random.PRNGKey(0)
    cfg = cnn.ConvConfig("ftdemo", (8, 8), (32,), n_classes=4,
                         img_size=8)
    task = synthetic.make_image_task(key, n=512, img=8, n_classes=4,
                                     noise=0.35)
    K = 8
    cidx = partition.partition_iid(np.random.default_rng(0),
                                   np.asarray(task.y), K)
    params = cnn.init_params(key, cfg)
    spec = masking.MaskSpec()
    server = federated.init_server(key, params, spec)

    apply_fn = lambda p, b: cnn.forward(p, cfg, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    fc = federated.FedConfig(lam=0.5, local_steps=2, lr=0.1,
                             optimizer="adam")
    round_fn = federated.make_round_fn(apply_fn, loss_fn, fc, K)
    eval_fn = federated.make_eval_fn(
        apply_fn, lambda o, b: cnn.accuracy(o, b), n_samples=2)
    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    test = {"images": task.x[:256], "labels": task.y[:256]}

    sim = fault.FaultSimulator(K, fail_prob=0.3, pod_size=4,
                               pod_outage_prob=0.05, seed=7)
    pol = fault.StragglerPolicy(quorum_frac=0.75)
    ck = "/tmp/ft_demo_ckpt"

    for r in range(10):
        kr = jax.random.fold_in(key, r)
        data = synthetic.federated_batches(kr, task, cidx, K, 2, 32)
        alive = fault.participation_vector(sim, K, pol)
        server, m = round_fn(server, data, alive, sizes, kr)
        acc = eval_fn(server, test, kr)
        print(f"round {r}: alive={int(alive.sum())}/{K} "
              f"loss={float(m['loss']):.3f} acc={float(acc):.3f} "
              f"bpp={float(m['uplink_bpp']):.3f}")
        if r == 4:
            ckpt.save_checkpoint(ck, r, server._asdict())
            print("  -- checkpoint saved; simulating coordinator crash"
                  " + restore --")
            restored, step = ckpt.restore_checkpoint(ck,
                                                     server._asdict())
            restored = jax.tree_util.tree_map(
                lambda x: None if x is None else jnp.asarray(x),
                restored, is_leaf=lambda x: x is None)
            server = federated.ServerState(**{
                k: restored[k] for k in server._asdict()})
    print("survived 10 rounds with failures; final accuracy above.")


if __name__ == "__main__":
    main()
