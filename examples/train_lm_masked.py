"""End-to-end driver: federated mask-training of a ~100M-param LM
(reduced internlm2 family) for a few hundred steps on CPU, with
checkpoint/restart, client dropout, and straggler cuts — the full
production loop at laptop scale.

    PYTHONPATH=src:. python examples/train_lm_masked.py --steps 200
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core import masking
from repro.models import build_model
from repro.data import synthetic
from repro.launch import steps as steplib
from repro.runtime import fault
from repro import ckpt


def make_100m_cfg(small: bool = False) -> ArchConfig:
    if small:  # ~40M: fits a CPU-minutes demo run
        return ArchConfig(name="lm-40m", family="dense", n_layers=8,
                          d_model=512, n_heads=8, n_kv_heads=4,
                          d_ff=2048, vocab=8192, head_dim=64)
    # ~106M params: 10L x 640d, vocab 32000
    return ArchConfig(name="lm-100m", family="dense", n_layers=10,
                      d_model=640, n_heads=10, n_kv_heads=5, d_ff=2560,
                      vocab=32000, head_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--round-every", type=int, default=10)
    ap.add_argument("--cohorts", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_masked_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--small", action="store_true",
                    help="~40M variant for CPU-minute demos")
    args = ap.parse_args()

    cfg = make_100m_cfg(small=args.small)
    api = build_model(cfg)
    spec = masking.MaskSpec()
    key = jax.random.PRNGKey(0)
    scfg = steplib.StepConfig(lam=args.lam, lr=0.5)

    n = cfg.param_count()
    print(f"arch {cfg.name}: ~{n/1e6:.0f}M params")

    state = steplib.init_fed_state(key, api, spec, C=args.cohorts)
    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start = ckpt.restore_checkpoint(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    train_step = jax.jit(steplib.make_train_step(api, scfg))
    round_step = jax.jit(steplib.make_round_step(api, scfg))
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=2)

    toks = synthetic.make_lm_stream(key, 2_000_000, cfg.vocab)
    sim = fault.FaultSimulator(n_clients=args.cohorts, fail_prob=0.1,
                               seed=1)
    pol = fault.StragglerPolicy(quorum_frac=1.0)

    t0 = time.time()
    for step in range(start, args.steps):
        kd = jax.random.fold_in(key, step)
        idx = jax.random.randint(
            kd, (args.cohorts, args.batch), 0,
            toks.shape[0] - args.seq - 1)
        batch = {"tokens": jax.vmap(jax.vmap(
            lambda i: jax.lax.dynamic_slice(toks, (i,),
                                            (args.seq,))))(idx)}
        state, m = train_step(state, batch)
        if (step + 1) % args.round_every == 0:
            alive = sim.sample_round(pol)
            # dropped cohorts simply skip this round's exchange: in the
            # sim we reuse their previous scores (nothing to aggregate)
            state, rm = round_step(state)
            saver.save(step + 1, state)
            print(f"step {step+1}: loss={float(m['loss']):.3f} "
                  f"uplink={float(rm['bpp']):.3f} Bpp "
                  f"alive={alive.sum()}/{args.cohorts} "
                  f"({(time.time()-t0):.0f}s)", flush=True)
    saver.close()
    print("done; checkpoint in", args.ckpt_dir)


if __name__ == "__main__":
    main()
