"""Quickstart: federated mask-training (the paper's method) on a tiny
CNN + synthetic task, end to end in ~a CPU minute.

Algorithms are resolved by name from the `repro.api` registry; swap
"fedpm_reg" for any of `repro.api.available()` (fedpm, fedmask, topk,
mv_signsgd, fedavg) and the same loop runs — the round engine computes
`uplink_bpp` from each algorithm's typed payload.

    PYTHONPATH=src:. python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import masking, federated
from repro.models import cnn
from repro.data import synthetic, partition
from repro import ckpt


def main():
    key = jax.random.PRNGKey(0)
    cfg = cnn.ConvConfig("quick", (8, 8), (32,), n_classes=4, img_size=8)
    task = synthetic.make_image_task(key, n=512, img=8, n_classes=4,
                                     noise=0.35)
    K = 4
    cidx = partition.partition_iid(np.random.default_rng(0),
                                   np.asarray(task.y), K)

    params = cnn.init_params(key, cfg)
    apply_fn = lambda p, b: cnn.forward(p, cfg, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    metric_fn = lambda o, b: cnn.accuracy(o, b)

    algo = api.get_algorithm("fedpm_reg", apply_fn, loss_fn,
                             spec=masking.MaskSpec(), lam=1.0,
                             local_steps=2, lr=0.1, optimizer="adam")
    print(f"{algo.name}: {algo.payload_spec.description}")
    server = algo.init(key, params)

    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    part = jnp.ones((K,), bool)
    test = {"images": task.x[:256], "labels": task.y[:256]}

    for r in range(8):
        kr = jax.random.fold_in(key, r)
        data = synthetic.federated_batches(kr, task, cidx, K, 2, 32)
        server, m = algo.round(server, data, part, sizes, kr)
        acc = api.evaluate(algo, server, test, apply_fn, metric_fn, kr,
                           n_samples=2)
        print(f"round {r}: loss={float(m['loss']):.3f} "
              f"uplink={float(m['uplink_bpp']):.3f} Bpp "
              f"sparsity={float(m['sparsity']):.2f} "
              f"acc={float(acc):.3f}")

    # the deployable artifact: a SEED + bit-packed masks (~n/8 bytes)
    art = federated.final_artifact(server, key)
    size = ckpt.save_artifact("/tmp/quickstart_artifact.npz", art)
    n = sum(int(np.prod(sh)) for _, (w, sh) in art["masks"].items())
    print(f"artifact: {size} bytes for {n} masked params "
          f"({8 * size / n:.2f} bits/param incl. float leaves)")


if __name__ == "__main__":
    main()
