"""Quickstart: federated mask-training (the paper's method) on a tiny
CNN + synthetic task, end to end in ~a CPU minute.

Algorithms are resolved by name from the `repro.api` registry; swap
"fedpm_reg" for any of `repro.api.available()` (fedpm, fedmask, topk,
mv_signsgd, fedavg) and the same loop runs.  The round engine performs
all communication accounting: `uplink_bpp` is the eq. 13 entropy bound,
`uplink_bpp_measured` what the chosen wire codec (--codec) actually
costs, and the CommLedger accumulates two-way MB across the run.  At
the end the final mask payload is REALLY serialized through the codec
and decoded back, byte for byte.

    PYTHONPATH=src:. python examples/quickstart.py --codec arithmetic
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.api import codecs
from repro.core import masking, federated
from repro.models import cnn
from repro.data import synthetic, partition
from repro import ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--codec", default=None,
                    choices=[c for c in codecs.available()
                             if c != "float32"],
                    help="wire codec for the mask uplink "
                         "(default: the payload's own, arithmetic)")
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    cfg = cnn.ConvConfig("quick", (8, 8), (32,), n_classes=4, img_size=8)
    task = synthetic.make_image_task(key, n=512, img=8, n_classes=4,
                                     noise=0.35)
    K = 4
    cidx = partition.partition_iid(np.random.default_rng(0),
                                   np.asarray(task.y), K)

    params = cnn.init_params(key, cfg)
    apply_fn = lambda p, b: cnn.forward(p, cfg, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    metric_fn = lambda o, b: cnn.accuracy(o, b)

    algo = api.get_algorithm("fedpm_reg", apply_fn, loss_fn,
                             spec=masking.MaskSpec(), lam=1.0,
                             local_steps=2, lr=0.1, optimizer="adam",
                             codec=args.codec)
    print(f"{algo.name}: {algo.payload_spec.description} "
          f"[codec={algo.codec.name}]")
    server = algo.init(key, params)

    sizes = jnp.asarray([len(c) for c in cidx], jnp.float32)
    part = jnp.ones((K,), bool)
    test = {"images": task.x[:256], "labels": task.y[:256]}
    ledger = api.CommLedger()

    for r in range(args.rounds):
        kr = jax.random.fold_in(key, r)
        data = synthetic.federated_batches(kr, task, cidx, K, 2, 32)
        server, m = algo.round(server, data, part, sizes, kr)
        ledger.update(m)
        acc = api.evaluate(algo, server, test, apply_fn, metric_fn, kr,
                           n_samples=2)
        print(f"round {r}: loss={float(m['loss']):.3f} "
              f"uplink={float(m['uplink_bpp']):.3f} Bpp "
              f"(wire {float(m['uplink_bpp_measured']):.3f}) "
              f"downlink={float(m['downlink_bpp']):.2f} Bpp "
              f"sparsity={float(m['sparsity']):.2f} "
              f"acc={float(acc):.3f} cum={ledger.total_mb:.3f}MB")

    # the deployable artifact: a SEED + bit-packed masks (~n/8 bytes)
    art = federated.final_artifact(server, key)
    size = ckpt.save_artifact("/tmp/quickstart_artifact.npz", art)
    n = sum(int(np.prod(sh)) for _, (w, sh) in art["masks"].items())
    print(f"artifact: {size} bytes for {n} masked params "
          f"({8 * size / n:.2f} bits/param incl. float leaves)")

    # real wire serialization: the final mask payload through the codec
    scores = masking.scores_from_theta(server.theta)
    mask = masking.final_mask(
        masking.MaskedParams(server.weights, scores, server.floats), key)
    payload = api.BitpackedMasks.from_masks(mask)
    msg = algo.codec.encode(payload)
    back = algo.codec.decode(msg)
    exact = all(
        a is None or bool(jnp.all(a == b))
        for a, b in zip(
            jax.tree_util.tree_leaves(payload.to_masks(),
                                      is_leaf=lambda x: x is None),
            jax.tree_util.tree_leaves(back.to_masks(),
                                      is_leaf=lambda x: x is None)))
    print(f"wire[{algo.codec.name}]: {msg.wire_bits // 8} bytes "
          f"({msg.wire_bits / n:.3f} Bpp measured, "
          f"{float(payload.bpp()):.3f} entropy bound), "
          f"decode exact={exact}")
    if not exact:
        raise SystemExit("codec round-trip failed")


if __name__ == "__main__":
    main()
