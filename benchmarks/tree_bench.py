#!/usr/bin/env python
"""Root-traffic scaling of the aggregator tree -> BENCH_tree.json.

The claim (docs/DESIGN.md §5, README Robustness): per commit, the
hierarchical aggregator tree forwards ONE `PooledFoldRecord` per edge,
so the edge -> root hop costs O(params) x n_edges bits — INDEPENDENT of
the client count — while the flat path's root ingests
O(clients x params).

This bench simulates 10^4..10^6 clients uplinking a synthetic
8192-parameter Bernoulli(0.5) mask leaf into a 16-edge tree:

  * every client's packed words are FOLDED into its edge's exact
    integer per-bit-position count accumulator (chunked host
    `np.unpackbits`, the same bit order as `aggregation.pack_bits`);
  * each edge serializes a REAL `PooledFoldRecord`
    (`aggregation.pack_counts` wire form, CRC32 fold checksum) and the
    record's wire+sidecar bits are metered into a real `CommLedger`
    exactly like `TreeRoundEngine._commit` does;
  * the root DESERIALIZES the records (`aggregation.unpack_counts` —
    the packed form is load-bearing) and the bench asserts the pooled
    counts reproduce the client-side popcount total computed through an
    independent byte-popcount path — exactness, not tolerance;
  * the measured ledger bits are cross-checked EXACTLY against the
    static `analysis.comm_model.tree_root_round_bits` table.

CI (the ``lint`` job) validates the committed JSON with
``tools/check_tree.py`` (static recompute + O(params) invariants);
regenerating the baseline:

    PYTHONPATH=src python benchmarks/tree_bench.py --json BENCH_tree.json

Usage:
    PYTHONPATH=src python benchmarks/tree_bench.py \
        [--n-params 8192] [--edges 16] [--clients 10000 100000 1000000] \
        [--acc-bits 16] [--seed 0] [--json BENCH_tree.json]
"""
import argparse
import json
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.analysis import comm_model               # noqa: E402
from repro.api.codecs import CommLedger             # noqa: E402
from repro.core import aggregation                  # noqa: E402
from repro.runtime.agg_tree import PooledFoldRecord, _ClassAcc, \
    _Edge                                           # noqa: E402

# byte-wise popcount lookup: the INDEPENDENT client-side ones total the
# pooled counts must reproduce exactly
_POP8 = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None],
                      axis=1).sum(axis=1).astype(np.int64)

CHUNK = 8192  # clients folded per unpackbits batch


def _client_words(rng: np.random.Generator, n: int, n_words: int
                  ) -> np.ndarray:
    """(n, n_words) uint32 — n clients' packed Bernoulli(0.5) masks."""
    return rng.integers(0, 1 << 32, size=(n, n_words), dtype=np.uint64
                        ).astype(np.uint32)


def fold_edge(rng: np.random.Generator, n_clients: int, n_words: int
              ) -> tuple:
    """Fold one edge's cohort into exact integer bit counts.

    Returns (counts int64[32*n_words], independent popcount total)."""
    P = 32 * n_words
    counts = np.zeros((P,), np.int64)
    total_pop = 0
    done = 0
    while done < n_clients:
        m = min(CHUNK, n_clients - done)
        words = _client_words(rng, m, n_words)
        u8 = np.ascontiguousarray(words.astype("<u4")).view(np.uint8)
        bits = np.unpackbits(u8.reshape(m, -1), axis=1,
                             bitorder="little")
        counts += bits.sum(axis=0, dtype=np.int64)
        total_pop += int(_POP8[u8.reshape(-1)].sum())
        done += m
    return counts, total_pop


def run_row(n_clients: int, n_edges: int, n_words: int, acc_bits: int,
            seed: int) -> dict:
    per_edge = n_clients // n_edges
    assert per_edge * n_edges == n_clients, "client count must split"
    assert per_edge < (1 << acc_bits), \
        f"{per_edge} clients/edge overflows acc_bits={acc_bits}"
    P = 32 * n_words
    ledger = CommLedger()
    pooled = np.zeros((P,), np.int64)
    client_side_pop = 0
    root_count = 0
    for eid in range(n_edges):
        rng = np.random.default_rng([seed, n_clients, eid])
        counts, pop = fold_edge(rng, per_edge, n_words)
        client_side_pop += pop
        # the real wire record, exactly as TreeRoundEngine._commit
        acc = _ClassAcc(size=100.0, version=0, count=per_edge,
                        counts=[counts], fsums=[], msums={},
                        bpp_sum=float(per_edge), clients=[])
        rec = PooledFoldRecord.from_edge(
            eid, _Edge(classes={(100.0, 0): acc}, log=[]), acc_bits)
        assert rec.verify(), "fold checksum must round-trip"
        ledger.update({"root_bits_measured":
                       float(rec.wire_bits + rec.sidecar_bits)})
        # root side: the packed stream is load-bearing — deserialize
        back = aggregation.unpack_counts(rec.classes[0].count_words[0],
                                         P, acc_bits)
        np.testing.assert_array_equal(back, counts)
        pooled += back
        root_count += rec.classes[0].count
    # exactness gate: pooled integer counts == the independent
    # byte-popcount total over every client's words
    assert int(pooled.sum()) == client_side_pop, \
        (int(pooled.sum()), client_side_pop)
    assert root_count == n_clients
    static = comm_model.tree_root_round_bits(
        [P], n_edges, acc_bits=acc_bits, n_classes=1,
        float_elems=0, n_metrics=0)
    measured = int(ledger.root_bits)
    assert measured == static["root_bits"], (measured, static)
    # the flat path: every client's padded words cross to the root
    flat_bits = n_clients * P
    return {
        "clients": n_clients,
        "clients_per_edge": per_edge,
        "root_bits_measured": measured,
        "static_root_bits": static["root_bits"],
        "root_header_bits": static["root_header_bits"],
        "flat_root_bits": flat_bits,
        "flat_over_tree": round(flat_bits / measured, 2),
        "total_popcount": client_side_pop,
        "ledger": ledger.as_dict(),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-params", type=int, default=8192)
    ap.add_argument("--edges", type=int, default=16)
    ap.add_argument("--clients", type=int, nargs="+",
                    default=[10_000, 100_000, 1_000_000])
    ap.add_argument("--acc-bits", type=int, default=16,
                    choices=(8, 16, 32))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    if args.n_params % 32:
        print("FAIL --n-params must be word-aligned")
        return 1
    n_words = args.n_params // 32
    doc = {
        "meta": {
            "n_params": args.n_params, "n_edges": args.edges,
            "acc_bits": args.acc_bits, "seed": args.seed,
            "numpy": np.__version__,
        },
        "static_record": comm_model.tree_root_record_bits(
            [args.n_params], acc_bits=args.acc_bits, n_classes=1,
            float_elems=0, n_metrics=0),
        "rows": [],
    }
    for n in sorted(args.clients):
        row = run_row(n, args.edges, n_words, args.acc_bits, args.seed)
        doc["rows"].append(row)
        print(f"# tree_bench clients={n:>9}: root={row['root_bits_measured']}b "
              f"(static match), flat={row['flat_root_bits']}b, "
              f"flat/tree={row['flat_over_tree']}x")
    roots = {r["root_bits_measured"] for r in doc["rows"]}
    if len(roots) != 1:
        print(f"FAIL root bits varied with client count: {sorted(roots)}")
        return 1
    print(f"# tree_bench: root traffic O(params) — {roots.pop()} bits "
          f"at every client count")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# tree_bench: wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
