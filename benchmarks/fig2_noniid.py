"""Paper Fig. 2: non-IID (c classes/device) accuracy/Bpp trade-off over
lambda, vs Top-k and MV-SignSGD baselines.

Prints CSV: dataset,algo,round,acc,bpp,bpp_measured,cum_up_mb,cum_down_mb
"""
from __future__ import annotations

import sys

from benchmarks import common


def main(rounds: int = 12, k: int = 10, c: int = 2):
    print("dataset,algo,round,acc,bpp,bpp_measured,cum_up_mb,"
          "cum_down_mb")
    out = {}
    for ds in ["mnist-like", "cifar10-like"]:
        setup = common.make_setup(ds, k=k, c=c)
        runs = {}
        for lam in [0.0, 0.1, 0.5, 1.0]:
            name = f"lam={lam}"
            hist, _ = common.run_fedpm_variant(setup, lam, rounds)
            runs[name] = hist
        # baselines resolve through the same registry / round engine
        for name, kw in [("topk", dict(k_frac=0.3)),
                         ("mv_signsgd", {})]:
            hist, _ = common.run_algorithm(setup, name, rounds, **kw)
            runs[name] = hist
        for name, hist in runs.items():
            for r in range(rounds):
                print(f"{ds},{name},{r},{hist['acc'][r]:.4f},"
                      f"{hist['bpp'][r]:.4f},"
                      f"{hist['bpp_measured'][r]:.4f},"
                      f"{hist['cumulative_uplink_mb'][r]:.4f},"
                      f"{hist['cumulative_downlink_mb'][r]:.4f}")
        out[ds] = runs
        for name, hist in runs.items():
            led = hist["ledger"]
            print(f"# {ds:13s} {name:12s} final acc={hist['acc'][-1]:.3f}"
                  f" bpp={hist['bpp'][-1]:.3f}"
                  f" comm={led['cumulative_total_mb']:.3f}MB",
                  file=sys.stderr)
    return out


if __name__ == "__main__":
    rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 12
    main(rounds)
