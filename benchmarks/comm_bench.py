#!/usr/bin/env python
"""Static per-round communication tables -> BENCH_comm.json.

For every mask-round algorithm in the launch registry, trace the pod
round step on the forced 8-device debug pod mesh ((2, 2, 2) x
("pod", "data", "model")), lint its collectives for wire purity
(`repro.analysis.collective_lint` — any finding fails the run), and
serialize the static cost model (`repro.analysis.comm_model`): bytes
per collective per mesh axis, accounting uplink/downlink bits, and the
derived ``bpp_wire``.  A bf16-psum "unpacked" contrast row rides along
(it MUST trip the purity rule — that is recorded, not fatal).

``--validate`` additionally executes one real `fedpm_reg` round under
the bitpack codec and cross-checks the static uplink prediction
against the CommLedger-style ``bits_measured`` metric (tolerance 2% —
the only slack is per-leaf word padding vs pooled alignment), plus the
analytic downlink formula exactly.

CI (the ``lint`` job) regenerates the JSON and diffs it against the
committed baseline via ``tools/check_comm.py``.

Usage:
    PYTHONPATH=src python benchmarks/comm_bench.py \
        [--arch internlm2-1.8b] [--cohorts 2] [--codec bitpack] \
        [--json BENCH_comm.json] [--validate] [--md]
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402

import jax        # noqa: E402

from repro.analysis import collective_lint, comm_model  # noqa: E402
from repro.configs import get_config                     # noqa: E402
from repro.core import masking                           # noqa: E402
from repro.launch import mesh as meshlib                 # noqa: E402
from repro.launch import plans                           # noqa: E402
from repro.launch import sharding as shd                 # noqa: E402
from repro.launch import steps as steplib                # noqa: E402
from repro.models import build_model                     # noqa: E402

TOLERANCE = 0.02


def run_validation(arch: str, mesh, C: int, codec: str) -> dict:
    """One REAL fedpm_reg round: measured wire bits vs the static
    prediction from the same trace."""
    api = build_model(get_config(arch, smoke=True))
    scfg = steplib.StepConfig(packed_masks=True,
                              **plans.MASK_ALGOS["fedpm_reg"])
    jxp, state_shapes, state_sh = comm_model.trace_round_jaxpr(
        api, scfg, mesh, C, codec=codec)
    model = comm_model.round_comm_model(jxp, state_shapes, state_sh,
                                        mesh, scfg)
    state = steplib.init_fed_state(jax.random.PRNGKey(scfg.seed), api,
                                   masking.MaskSpec(), C)
    step = jax.jit(
        steplib.make_round_step(api, scfg, mesh=mesh,
                                state_sh=state_sh, codec=codec),
        in_shardings=(state_sh,),
        out_shardings=(state_sh, shd.replicated(mesh)))
    _, metrics = step(state)
    measured = float(metrics["bits_measured"])
    static = float(model["uplink_bits"])
    rel = abs(static - measured) / max(measured, 1.0)
    dl_static = float(model["downlink_bits"])
    dl_measured = float(metrics["downlink_bits"])
    return {
        "arch": arch, "codec": codec,
        "static_uplink_bits": int(static),
        "measured_uplink_bits": int(measured),
        "rel_err": round(rel, 6),
        "tolerance": TOLERANCE,
        "static_downlink_bits": dl_static,
        "measured_downlink_bits": dl_measured,
        "ok": bool(rel <= TOLERANCE and dl_static == dl_measured),
    }


def to_markdown(doc: dict) -> str:
    """The DESIGN.md §2 wire-cost table: collective -> mesh axis ->
    bytes/round, per algorithm (sites aggregated by kind)."""
    lines = [
        "| algorithm | collective | axes | sites | payload bits/shard "
        "| ring send B/device | role |",
        "|---|---|---|---|---|---|---|",
    ]
    tables = dict(doc["algos"])
    tables["fedpm_reg (unpacked bf16)"] = doc["unpacked_contrast"]
    for algo, tab in tables.items():
        agg = {}
        for r in tab["sites"]:
            key = (r["prim"], "x".join(r["axes"]) or "-", r["role"])
            n, pb, rb = agg.get(key, (0, 0, 0.0))
            agg[key] = (n + 1, pb + r["payload_bits_per_shard"],
                        rb + r["ring_send_bytes_per_device"])
        for (prim, axes, role), (n, pb, rb) in sorted(agg.items()):
            lines.append(f"| {algo} | {prim} | {axes} | {n} | {pb} "
                         f"| {rb:.0f} | {role} |")
        lines.append(f"| {algo} | **total** |  | {tab['n_sites']} "
                     f"| bpp_wire={tab['bpp_wire']} "
                     f"| uplink={tab['uplink_bits']}b | |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--cohorts", type=int, default=2)
    ap.add_argument("--codec", default="bitpack")
    ap.add_argument("--json", default=None,
                    help="write the tables to this path")
    ap.add_argument("--validate", action="store_true",
                    help="execute a real round and cross-check the "
                         "static prediction against measured bits")
    ap.add_argument("--md", action="store_true",
                    help="print the DESIGN.md wire-cost table")
    args = ap.parse_args(argv)

    mesh = meshlib.make_debug_pod_mesh()
    errors = []
    doc = {
        "meta": {
            "arch": args.arch, "smoke": True, "codec": args.codec,
            "cohorts": args.cohorts,
            "mesh": {"shape": [int(mesh.shape[a])
                               for a in mesh.axis_names],
                     "axes": list(mesh.axis_names)},
            "jax": jax.__version__,
        },
        "algos": {},
    }

    for algo in sorted(plans.MASK_ALGOS):
        rep = collective_lint.arch_collective_report(
            args.arch, algo, mesh=mesh, C=args.cohorts,
            codec=args.codec)
        for f in rep["findings"]:
            errors.append(f"{algo}: {f}")
        doc["algos"][algo] = rep["model"]
        print(f"# comm_bench {algo}: {rep['n_sites']} sites, "
              f"bpp_wire={rep['model']['bpp_wire']}, "
              f"{len(rep['findings'])} purity finding(s)")

    contrast = collective_lint.arch_collective_report(
        args.arch, "fedpm_reg", mesh=mesh, C=args.cohorts,
        codec=args.codec, packed=False)
    doc["unpacked_contrast"] = dict(
        contrast["model"],
        purity_findings=len(contrast["findings"]))
    print(f"# comm_bench fedpm_reg(unpacked): "
          f"bpp_wire={contrast['model']['bpp_wire']}, "
          f"{len(contrast['findings'])} purity finding(s) "
          "(impure by construction)")
    if not contrast["findings"]:
        errors.append("unpacked contrast fired zero purity findings "
                      "(rule went dead)")

    if args.validate:
        v = run_validation(args.arch, mesh, args.cohorts, args.codec)
        doc["validation"] = v
        print(f"# comm_bench validate: static={v['static_uplink_bits']}"
              f"b measured={v['measured_uplink_bits']}b "
              f"rel_err={v['rel_err']} (tol {v['tolerance']})")
        if not v["ok"]:
            errors.append(f"static-vs-measured drift: {v}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# comm_bench: wrote {args.json}")
    if args.md:
        print(to_markdown(doc))

    for e in errors:
        print(f"FAIL {e}")
    print(f"# comm_bench: {len(errors) or 'ok'}"
          + ("" if not errors else " failure(s)"))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
