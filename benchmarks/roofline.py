"""Roofline analysis from the dry-run compiled artifacts.

Terms (v5e targets, per DESIGN):
    compute    = HLO_FLOPs_per_chip / 197e12          [s]
    memory     = HLO_bytes_per_chip / 819e9           [s]
    collective = collective_operand_bytes_per_chip / 50e9  [s]

cost_analysis() is PER-PARTITION (verified against a hand-sharded
matmul), so the per-chip terms read off directly. Caveat (documented in
docs/DESIGN.md §7): XLA cost analysis counts a lax.scan body ONCE, so
layer-stacked HLO_FLOPs under-count by ~n_layers for scanned stacks; the
hillclimb cells are re-lowered with scan_unroll=n_layers for exact
numbers, and MODEL_FLOPS = 6*N_active*D provides the analytic anchor
for every cell.

Usage: python -m benchmarks.roofline [dryrun_results.json] [--md]
"""
from __future__ import annotations

import json
import sys

from repro.configs import get_config, SHAPES

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


def model_flops(arch: str, shape_name: str, step: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    if step in ("train_step",):
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_act * tokens
    if step == "prefill_step":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_act * tokens
    if step == "serve_step":
        return 2.0 * n_act * sh.global_batch
    return 0.0  # round_step: communication, not model compute


def scan_trip_count(arch: str) -> int:
    """Approximate scan under-count factor (layers per scan body)."""
    cfg = get_config(arch)
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.block_pattern)
    if cfg.family == "encdec":
        return cfg.n_layers  # enc and dec scans, both ~n_layers
    if cfg.n_experts:
        return cfg.n_layers - cfg.first_dense_layers
    return cfg.n_layers


def analyze(results: dict):
    rows = []
    for cell, v in sorted(results.items()):
        if not v.get("ok"):
            continue
        arch, shape, mesh = cell.split("|")
        chips = CHIPS[mesh]
        for step, d in v.items():
            if step in ("ok",):
                continue
            if not isinstance(d, dict) or "flops" not in d:
                continue
            f = d["flops"]
            b = d["bytes_accessed"]
            cb = d["collective_bytes"].get("total", 0)
            t_c = f / PEAK_FLOPS
            t_m = b / HBM_BW
            t_x = cb / LINK_BW
            dom = max((t_c, "compute"), (t_m, "memory"),
                      (t_x, "collective"))[1]
            mf = model_flops(arch, shape, step)
            hlo_global = f * chips
            trip = scan_trip_count(arch)
            hlo_corrected = hlo_global * trip  # scan-once correction
            ratio = mf / hlo_corrected if hlo_corrected else 0.0
            rows.append(dict(
                arch=arch, shape=shape, mesh=mesh, step=step,
                chips=chips, t_compute=t_c, t_memory=t_m,
                t_collective=t_x, dominant=dom,
                model_flops=mf, hlo_flops_per_chip=f,
                hlo_flops_global_scan_corrected=hlo_corrected,
                useful_ratio=ratio,
                collective_bytes=cb,
                bytes_per_chip=b,
            ))
    return rows


SUGGEST = {
    ("compute",): "increase per-chip arithmetic intensity (bigger local "
                  "batch / fuse mask into matmul kernel)",
    ("memory",): "cut HBM traffic: fused masked matmul (no materialized "
                 "m*w), bf16 scores, remat policy",
    ("collective",): "bitpack the mask exchange / reshard to reduce "
                     "all-gather volume",
}


_MOVE = {
    "compute": "raise arithmetic intensity: fused masked-matmul kernel, "
               "larger per-chip batch, fewer redundant dispatch FLOPs",
    "memory": "cut HBM traffic: remat, microbatching, vocab-sharded "
              "logits, ring KV caches, fused mask (no m*w in HBM)",
    "collective": "cut wire bytes: bitpacked mask exchange, TP-only "
                  "weight sharding for inference (drop FSDP gathers)",
}


def to_markdown(rows):
    out = ["| arch | shape | mesh | step | compute s | memory s | "
           "collective s | dominant | MODEL_FLOPS | useful ratio | "
           "to move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
            f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} "
            f"| {r['t_collective']:.2e} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['useful_ratio']:.2f} "
            f"| {_MOVE[r['dominant']]} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    rows = analyze(results)
    if "--md" in sys.argv:
        print(to_markdown(rows))
        return
    print("arch,shape,mesh,step,t_compute,t_memory,t_collective,"
          "dominant,model_flops,useful_ratio")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['step']},"
              f"{r['t_compute']:.3e},{r['t_memory']:.3e},"
              f"{r['t_collective']:.3e},{r['dominant']},"
              f"{r['model_flops']:.3e},{r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()
