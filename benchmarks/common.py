"""Shared benchmark scaffolding: the paper's experimental grid on the
synthetic tasks (offline container — see docs/DESIGN.md §7), reduced-scale by
default so a full figure reproduces in CPU minutes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import masking
from repro.models import cnn
from repro.data import synthetic, partition

SPEC = masking.MaskSpec()


def make_setup(dataset: str, k: int, c: int | None, seed: int = 0,
               n: int = 1024):
    """dataset in {mnist-like, cifar10-like, cifar100-like}: difficulty
    emulated via prototype scale / noise; ConvN per paper Sec. IV."""
    key = jax.random.PRNGKey(seed)
    if dataset == "mnist-like":
        cfg = cnn.ConvConfig("conv4", (16, 16, 32, 32), (64,),
                             n_classes=10, img_size=16, in_channels=1)
        task = synthetic.make_image_task(key, n=n, img=16, channels=1,
                                         proto_scale=1.4, noise=0.45)
    elif dataset == "cifar10-like":
        cfg = cnn.ConvConfig("conv6", (16, 16, 32, 32, 64, 64), (64,),
                             n_classes=10, img_size=16)
        task = synthetic.make_image_task(key, n=n, img=16,
                                         proto_scale=1.0, noise=0.7)
    elif dataset == "cifar100-like":
        cfg = cnn.ConvConfig("conv10",
                             (16, 16, 32, 32, 64, 64, 64, 64, 64, 64),
                             (64,), n_classes=20, img_size=16)
        task = synthetic.make_image_task(key, n=n, img=16, n_classes=20,
                                         proto_scale=1.0, noise=0.7)
    else:
        raise ValueError(dataset)
    rng = np.random.default_rng(seed)
    labels = np.asarray(task.y)
    if c is None:
        cidx = partition.partition_iid(rng, labels, k)
    else:
        cidx = partition.partition_by_class(rng, labels, k, c)
    params = cnn.init_params(key, cfg)
    apply_fn = lambda p, b: cnn.forward(p, cfg, b["images"])
    loss_fn = lambda out, b: cnn.ce_loss(out, b)
    metric_fn = lambda out, b: cnn.accuracy(out, b)
    test = {"images": task.x[: min(512, n)],
            "labels": task.y[: min(512, n)]}
    return dict(cfg=cfg, task=task, cidx=cidx, params=params,
                apply_fn=apply_fn, loss_fn=loss_fn, metric_fn=metric_fn,
                test=test, k=k)


def run_algorithm(setup, name: str, rounds: int, *, local_steps=3,
                  batch=32, seed=0, participation=None, eval_samples=2,
                  codec=None, **algo_kw):
    """Sweep any registered algorithm by name through the unified
    round engine.  Returns per-round dict lists and the final state:
    `bpp` is the eq. 13 entropy bound, `bpp_measured` the wire rate the
    round's codec actually achieves, and `cumulative_uplink_mb` /
    `cumulative_downlink_mb` the CommLedger trajectory — the paper's
    accuracy-vs-communication x-axis.  The final ledger snapshot rides
    along as `hist["ledger"]`."""
    key = jax.random.PRNGKey(seed)
    algo = api.get_algorithm(name, setup["apply_fn"], setup["loss_fn"],
                             spec=SPEC, local_steps=local_steps,
                             codec=codec, **algo_kw)
    st = algo.init(key, setup["params"])
    sizes = jnp.asarray([len(ci) for ci in setup["cidx"]], jnp.float32)
    ledger = api.CommLedger()
    hist = {"acc": [], "bpp": [], "bpp_measured": [], "sparsity": [],
            "loss": [], "cumulative_uplink_mb": [],
            "cumulative_downlink_mb": []}
    for r in range(rounds):
        kr = jax.random.fold_in(key, r)
        data = synthetic.federated_batches(
            kr, setup["task"], setup["cidx"], setup["k"], local_steps,
            batch)
        part = (jnp.ones((setup["k"],), bool) if participation is None
                else participation(r))
        st, m = algo.round(st, data, part, sizes, kr)
        ledger.update(m)
        hist["bpp"].append(float(m["uplink_bpp"]))
        hist["bpp_measured"].append(float(m["uplink_bpp_measured"]))
        hist["sparsity"].append(float(m.get("sparsity", 0.0)))
        hist["loss"].append(float(m["loss"]))
        hist["cumulative_uplink_mb"].append(ledger.uplink_mb)
        hist["cumulative_downlink_mb"].append(ledger.downlink_mb)
        hist["acc"].append(float(api.evaluate(
            algo, st, setup["test"], setup["apply_fn"],
            setup["metric_fn"], kr, n_samples=eval_samples)))
    hist["ledger"] = ledger.as_dict()
    return hist, st


def run_fedpm_variant(setup, lam: float, rounds: int, local_steps=3,
                      batch=32, lr=0.1, seed=0, participation=None):
    """The paper's method at one lambda (lam=0 == FedPM reference)."""
    return run_algorithm(setup, "fedpm_reg", rounds,
                         local_steps=local_steps, batch=batch, seed=seed,
                         participation=participation, lam=lam, lr=lr,
                         optimizer="adam", float_lr=1e-3)


def run_baseline(setup, algo, rounds: int, local_steps=3, batch=32,
                 seed=0):
    """Legacy entry: sweep an already-constructed FedAlgorithm."""
    key = jax.random.PRNGKey(seed)
    st = algo.init(key, setup["params"])
    sizes = jnp.asarray([len(ci) for ci in setup["cidx"]], jnp.float32)
    part = jnp.ones((setup["k"],), bool)
    hist = {"acc": [], "bpp": [], "loss": []}
    for r in range(rounds):
        kr = jax.random.fold_in(key, 1000 + r)
        data = synthetic.federated_batches(
            kr, setup["task"], setup["cidx"], setup["k"], local_steps,
            batch)
        st, m = algo.round(st, data, part, sizes, kr)
        hist["bpp"].append(float(m["uplink_bpp"]))
        hist["loss"].append(float(m["loss"]))
        eff = algo.eval_params(st, kr)
        out = setup["apply_fn"](eff, setup["test"])
        hist["acc"].append(float(setup["metric_fn"](out, setup["test"])))
    return hist, st


class Timer:
    def __init__(self):
        self.t0 = time.time()

    def us_per(self, calls: int) -> float:
        return (time.time() - self.t0) * 1e6 / max(calls, 1)
