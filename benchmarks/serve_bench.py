"""Multi-tenant serving benchmark: tokens/s vs resident sub-network
count vs bytes, through `repro.runtime.serve_engine.ServeEngine`.

The paper's serving claim (docs/DESIGN.md §3): every tenant is a 1-bit
mask over ONE shared frozen random `w`, so weight HBM stays constant
while the tenant count grows — only the bounded freeze-cache of
materialized trees (<= --cache-capacity deltas) and the ~1 bit/param
mask artifacts scale.  Each row of the sweep serves a different tenant
count through the same engine (staggered prompt/generation lengths so
continuous batching genuinely interleaves prefill and decode) and
records the HBM ledger next to the measured throughput:

    PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
        --json BENCH_serve.json

`tools/check_serve.py` diffs the output against the committed
baseline: the structural invariants (constant weight bytes, bounded
cache occupancy, evictions once tenants exceed capacity) are asserted
on any backend; throughput ratios are gated only on real hardware
(interpret-mode timings are emulation artifacts).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core import masking
from repro.kernels import ops
from repro.models import build_model
from repro.runtime.serve_engine import ServeEngine


def _staggered(i: int, prompt_len: int, tokens: int):
    """Per-tenant (prompt, gen) lengths: stagger by tenant index so
    slots free at different ticks and admission interleaves prefill
    with decode (a uniform fleet finishes in lockstep and never mixes
    phases)."""
    p = max(2, prompt_len - (i % 3))
    g = max(1, tokens - 2 + (i % 3))
    return p, g


def run_sweep(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    mp = masking.init_masked(key, api.init_params(key),
                             masking.MaskSpec())
    max_seq = args.prompt_len + args.tokens + 1
    prompts = np.asarray(jax.random.randint(
        key, (max(args.tenant_counts), args.prompt_len), 0, cfg.vocab))

    rows = []
    for tenants in args.tenant_counts:
        eng = ServeEngine(api, mp, slots=args.slots,
                          cache_capacity=args.cache_capacity,
                          max_seq=max_seq)
        for i in range(tenants):
            p, g = _staggered(i, args.prompt_len, args.tokens)
            eng.register_tenant(f"t{i}", seed=args.seed + i)
            eng.submit(f"t{i}", prompts[i, :p], g)
        done = eng.run()
        st = eng.stats()
        assert len(done) == tenants
        rows.append({
            "tenants": tenants,
            "slots": args.slots,
            "capacity": st["capacity"],
            "occupancy": st["occupancy"],
            "hits": st["hits"],
            "misses": st["misses"],
            "evictions": st["evictions"],
            "mixed_ticks": st["mixed_ticks"],
            "weight_bytes": st["weight_bytes"],
            "delta_bytes_per_tree": st["delta_bytes_per_tree"],
            "resident_bytes": st["resident_bytes"],
            "mask_artifact_bytes": st["mask_artifact_bytes"],
            "prefill_tokens": st["prefill_tokens"],
            "decode_tokens": st["decode_tokens"],
            "prefill_tok_s": st["prefill_tok_s"],
            "decode_tok_s": st["decode_tok_s"],
        })
        print(f"tenants={tenants:2d}  occupancy={st['occupancy']}/"
              f"{st['capacity']}  evictions={st['evictions']:2d}  "
              f"weight={st['weight_bytes']} B  "
              f"resident={st['resident_bytes']} B  "
              f"decode {st['decode_tok_s']:.1f} tok/s")
    return {
        "arch": cfg.name,
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "interpret": bool(ops._use_interpret()),
        "slots": args.slots,
        "cache_capacity": args.cache_capacity,
        "rows": rows,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--cache-capacity", type=int, default=2)
    ap.add_argument("--tenant-counts", type=lambda s: [
        int(x) for x in s.split(",")], default=[1, 2, 4, 6],
        help="tenant counts per row; must cross --cache-capacity so "
             "the sweep shows weight HBM constant past the cache bound")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    result = run_sweep(args)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
