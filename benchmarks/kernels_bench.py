"""Kernel benchmark harness: fused masked-matmul forward/backward and
the fused sample+pack uplink kernel vs their pure-jnp oracles.

Three kinds of output:

  * Timings — median-of-N `time.perf_counter` wall clock (after separate
    warmup calls) for fwd / bwd / sample+pack across a shape zoo drawn
    from the real model configs (`repro.configs`), written to
    ``BENCH_kernels.json`` and printed as CSV.  On CPU the kernels run
    in interpret mode, so the numbers are indicative only.

  * Structural assertions — the memory-term argument that holds on any
    backend: counting weight-shaped (K, N) f32 values defined OUTSIDE
    the pallas_call boundary.  The count runs on the jaxpr (where
    `pallas_call` is a single opaque equation) rather than compiled HLO
    text, because interpret-mode emulation inlines full-size plumbing
    buffers into the compiled module that do not exist on TPU.  Pure
    view/layout equations (squeeze/reshape — how `lax.scan` feeds the
    per-layer score slice to the kernel; XLA aliases them) are not
    counted: the invariant is about weight-sized values COMPUTED
    outside the kernel.  The naive path materializes sigmoid(s), the
    hash uniforms, m*w and x^T@g at weight size; the fused forward AND
    backward must define zero such values.  Compiled-HLO substring
    counts are still reported (informational) for continuity with the
    original forward check.

  * Whole-model step — the same invariant asserted END-TO-END on a
    jitted `launch.steps.make_train_step` for an MXU-aligned config of
    EACH kernel-bearing family: dense transformer (2-D blocks),
    deepseek-style MoE (stacked (E, K, N) expert leaves through the
    GROUPED kernel) and recurrentgemma-style hybrid ((W, C) conv
    leaves through the fused conv kernel): the jaxpr of the full train
    step (forward AND backward, scores as a first-class grad argument)
    defines zero weight-shaped f32 values outside `pallas_call` for
    EVERY masked block shape, while the materialized reference path
    (`REPRO_EFF_PATH=1`) scores > 0 on each leaf — proving the model
    zoo's masked-execution routing delivers the kernel win at the
    training hot path for every maskable leaf shape, not just per
    layer.  Timed fused vs. materialized.

`tools/check_bench.py` diffs a fresh JSON against the committed
baseline (structural counts asserted; fused-vs-ref timing ratios gated
on real hardware, informational under interpret).

Run:  PYTHONPATH=src python benchmarks/kernels_bench.py [--iters N]
      [--warmup N] [--max-dim D] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.analysis import count_weight_f32_defs
from repro.analysis import model_check
from repro.configs import get_config
from repro.kernels import ref, ops
from repro.launch import steps as steplib


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------


def timed(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in us: `warmup` untimed calls first
    (compile + cache effects), then `iters` timed calls, each fully
    blocked on, reported as the median (robust to scheduler noise where
    a mean is not)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


# ---------------------------------------------------------------------------
# Shape zoo: the hot matmuls of the real model configs
# ---------------------------------------------------------------------------

ZOO_ARCHS = ("internlm2-1.8b", "gemma3-4b", "qwen2-7b")


def _shrink(d: int, max_dim: int) -> int:
    """Halve until <= max_dim, then round down to lane (128) alignment
    so interpret-mode (CPU) runs stay tractable; actual dims are
    recorded in the JSON."""
    while d > max_dim:
        d //= 2
    return max(d - d % 128, 128)


def shape_zoo(max_dim: int = 1536, m: int = 256):
    """(label, M, K, N) for the per-layer hot matmuls — the attention
    qkv projection (d_model -> (H + 2*H_kv) * hd) and the FFN up
    projection (d_model -> d_ff) — of each zoo arch, deduplicated."""
    out, seen = [], set()
    for name in ZOO_ARCHS:
        cfg = get_config(name)
        qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
        for tag, k, n in (("qkv", cfg.d_model, qkv),
                          ("ffn_up", cfg.d_model, cfg.d_ff)):
            K, N = _shrink(k, max_dim), _shrink(n, max_dim)
            if (K, N) in seen:
                continue
            seen.add((K, N))
            out.append((f"{name}:{tag}", m, K, N))
    return out


GROUPED_ZOO_ARCHS = ("deepseek-v2-lite-16b", "deepseek-v2-236b")


def grouped_shape_zoo(max_dim: int = 1536, m: int = 128,
                      max_experts: int = 4):
    """(label, E, M, K, N) for the stacked MoE expert matmuls
    (d_model -> moe_d_ff per routed expert) of the MoE zoo archs,
    expert count capped for CPU-interpret tractability."""
    out, seen = [], set()
    for name in GROUPED_ZOO_ARCHS:
        cfg = get_config(name)
        E = min(cfg.n_experts, max_experts)
        K, N = (_shrink(cfg.d_model, max_dim // 2),
                _shrink(cfg.moe_d_ff, max_dim // 2))
        if (E, K, N) in seen:
            continue
        seen.add((E, K, N))
        out.append((f"{name}:moe_up", E, m, K, N))
    return out


# ---------------------------------------------------------------------------
# Structural check: weight-shaped f32 values outside the pallas boundary
# ---------------------------------------------------------------------------


_CHECK_SHAPE = (256, 1024, 1024)  # MXU-aligned so no pad/slice eqns


# the counter itself — the rule-based jaxpr walker — lives in
# repro.analysis (jaxpr_lint.count_weight_f32_defs); this harness, the
# tier-1 twin in tests/test_steps.py and tools/repro_lint.py are thin
# callers of that ONE traversal, so counts stay comparable everywhere


def _check_operands(M, K, N):
    x = jnp.zeros((M, K), jnp.bfloat16)
    w = jnp.zeros((K, N), jnp.bfloat16)
    s = jnp.zeros((K, N), jnp.float32)
    g = jnp.zeros((M, N), jnp.bfloat16)
    return x, w, s, g


def weight_temporaries_fwd():
    """(naive, fused) weight-f32 def counts for the forward."""
    M, K, N = _CHECK_SHAPE
    x, w, s, _ = _check_operands(M, K, N)
    naive = count_weight_f32_defs(
        lambda x, w, s: ref.masked_matmul(x, w, s, 0), (x, w, s), (K, N))
    fused = count_weight_f32_defs(
        lambda x, w, s: ops.masked_dense(x, w, s, 0), (x, w, s), (K, N))
    return naive, fused


def weight_temporaries_bwd():
    """(naive, fused) weight-f32 def counts for the STE backward."""
    M, K, N = _CHECK_SHAPE
    x, w, s, g = _check_operands(M, K, N)

    def fused(x, w, s, g):
        _, vjp = jax.vjp(
            lambda x_, s_: ops.masked_dense(x_, w, s_, 0), x, s)
        return vjp(g)

    def naive(x, w, s, g):
        return ref.masked_dense_bwd(x, w, s, 0, g)

    args = (x, w, s, g)
    return (count_weight_f32_defs(naive, args, (K, N)),
            count_weight_f32_defs(fused, args, (K, N)))


# ---------------------------------------------------------------------------
# Whole-model check: the invariant on a full transformer-block train step
# ---------------------------------------------------------------------------

# the aligned check configs and the tracing/counting helpers live in
# repro.analysis.model_check (shared with the tier-1 twin and
# tools/repro_lint.py); the bench layers TIMING on top of its counts
MODEL_CHECK_CFGS = model_check.MODEL_CHECK_CFGS


def model_step_weight_defs(cfg, iters: int = 0, warmup: int = 1,
                           S: int = 64):
    """`model_check.model_step_weight_defs` counts, plus (iters > 0)
    fused-vs-materialized wall time of the compiled train step."""
    out = model_check.model_step_weight_defs(cfg, S=S)
    if iters:
        api, state, batch = model_check.model_step_setup(cfg, S=S)
        scfg = steplib.StepConfig(lam=0.1, lr=0.5)
        _, fused_fn = model_check.trace_model_step(
            api, state, batch, scfg, eff_path=False, jit_compile=True)
        _, eff_fn = model_check.trace_model_step(
            api, state, batch, scfg, eff_path=True, jit_compile=True)
        out["train_step_us"] = timed(fused_fn, state, batch,
                                     iters=iters, warmup=warmup)
        out["train_step_eff_us"] = timed(eff_fn, state, batch,
                                         iters=iters, warmup=warmup)
    return out


def hbm_weight_tensors_baseline_vs_fused():
    """Compiled-HLO substring counts for the forward (the original,
    informational check; interpret-mode emulation inflates the fused
    number with plumbing buffers that do not exist on TPU — the jaxpr
    counts above are the asserted invariant)."""
    M, K, N = _CHECK_SHAPE
    x, w, s, _ = _check_operands(M, K, N)
    txt_base = jax.jit(
        lambda x, w, s: ref.masked_matmul(x, w, s, 0)
    ).lower(x, w, s).compile().as_text()
    txt_fused = jax.jit(
        lambda x, w, s: ops.masked_dense(x, w, s, 0)
    ).lower(x, w, s).compile().as_text()
    return txt_base.count(f"{K},{N}"), txt_fused.count(f"{K},{N}")


# ---------------------------------------------------------------------------
# Benchmarks
# ---------------------------------------------------------------------------


def bench_shape(label, M, K, N, iters, warmup, key):
    kx, kw, ks, kg = jax.random.split(key, 4)
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(jnp.bfloat16)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    g = jax.random.normal(kg, (M, N), jnp.float32).astype(jnp.bfloat16)

    fwd = jax.jit(lambda x, w, s: ops.masked_dense(x, w, s, 7))
    fwd_ref = jax.jit(lambda x, w, s: ref.masked_matmul(x, w, s, 7))

    # grad step = forward + backward on BOTH sides (jax.vjp re-runs the
    # forward, so the naive baseline gets its forward too — symmetric),
    # 3 weight-sized matmuls total (y, dx, ds)
    def _bwd(x, w, s, g):
        _, vjp = jax.vjp(
            lambda x_, s_: ops.masked_dense(x_, w, s_, 7), x, s)
        return vjp(g)

    bwd = jax.jit(_bwd)

    def _bwd_ref(x, w, s, g):
        y = ref.masked_matmul(x, w, s, 7)
        dx, ds = ref.masked_dense_bwd(x, w, s, 7, g)
        return y, dx, ds

    bwd_ref = jax.jit(_bwd_ref)

    # one cohort row of K*N scores: the per-round uplink sampling
    flat = s.reshape(1, -1)
    seeds = jnp.asarray([7], jnp.uint32)
    sap = jax.jit(lambda f, sd: ops.sample_and_pack(f, sd))
    sap_ref = jax.jit(lambda f, sd: ref.sample_and_pack(f, sd))

    t = dict(
        fwd_us=timed(fwd, x, w, s, iters=iters, warmup=warmup),
        fwd_ref_us=timed(fwd_ref, x, w, s, iters=iters, warmup=warmup),
        bwd_us=timed(bwd, x, w, s, g, iters=iters, warmup=warmup),
        bwd_ref_us=timed(bwd_ref, x, w, s, g, iters=iters,
                         warmup=warmup),
        sample_pack_us=timed(sap, flat, seeds, iters=iters,
                             warmup=warmup),
        sample_pack_ref_us=timed(sap_ref, flat, seeds, iters=iters,
                                 warmup=warmup),
    )
    fwd_flops = 2 * M * K * N
    t["fwd_gflops"] = fwd_flops / t["fwd_us"] / 1e3
    t["bwd_gflops"] = 3 * fwd_flops / t["bwd_us"] / 1e3  # y + dx + ds
    t["sample_pack_gbit_s"] = K * N / t["sample_pack_us"] / 1e3
    return {"name": label, "M": M, "K": K, "N": N, **t}


def bench_grouped_shape(label, E, M, K, N, iters, warmup, key):
    """Fused grouped kernels vs the materializing einsum baseline for
    one stacked (E, K, N) expert shape."""
    kx, kw, ks, kg = jax.random.split(key, 4)
    x = jax.random.normal(kx, (E, M, K), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(kw, (E, K, N), jnp.float32).astype(jnp.bfloat16)
    s = jax.random.normal(ks, (E, K, N), jnp.float32)
    g = jax.random.normal(kg, (E, M, N), jnp.float32).astype(jnp.bfloat16)
    seeds = jnp.full((E,), 7, jnp.uint32)
    offs = jnp.arange(E, dtype=jnp.uint32) * jnp.uint32(K * N)

    fwd = jax.jit(lambda x, w, s: ops.masked_dense_grouped(x, w, s, 7))
    fwd_ref = jax.jit(
        lambda x, w, s: ref.masked_matmul_grouped(x, w, s, seeds, offs))

    def _bwd(x, w, s, g):
        _, vjp = jax.vjp(
            lambda x_, s_: ops.masked_dense_grouped(x_, w, s_, 7), x, s)
        return vjp(g)

    bwd = jax.jit(_bwd)

    def _bwd_ref(x, w, s, g):
        y = ref.masked_matmul_grouped(x, w, s, seeds, offs)
        dx, ds = ref.masked_dense_grouped_bwd(x, w, s, seeds, offs, g)
        return y, dx, ds

    bwd_ref = jax.jit(_bwd_ref)

    t = dict(
        fwd_us=timed(fwd, x, w, s, iters=iters, warmup=warmup),
        fwd_ref_us=timed(fwd_ref, x, w, s, iters=iters, warmup=warmup),
        bwd_us=timed(bwd, x, w, s, g, iters=iters, warmup=warmup),
        bwd_ref_us=timed(bwd_ref, x, w, s, g, iters=iters,
                         warmup=warmup),
    )
    fwd_flops = 2 * E * M * K * N
    t["fwd_gflops"] = fwd_flops / t["fwd_us"] / 1e3
    t["bwd_gflops"] = 3 * fwd_flops / t["bwd_us"] / 1e3
    return {"name": label, "E": E, "M": M, "K": K, "N": N, **t}


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--iters", type=int, default=3,
                   help="timed iterations per benchmark (median taken)")
    p.add_argument("--warmup", type=int, default=1,
                   help="untimed warmup iterations")
    p.add_argument("--max-dim", type=int, default=1536,
                   help="shrink zoo dims to <= this (CPU tractability)")
    p.add_argument("--json", default="BENCH_kernels.json",
                   help="output path for the results JSON")
    args = p.parse_args([] if argv is None else argv)

    # a caller (or test) may have flipped REPRO_FORCE_INTERPRET since
    # the first kernel dispatch — make this run see the current env
    ops.reset_backend_cache()
    interpret = ops._use_interpret()
    results = {
        "backend": ops.repro_backend(),
        "interpret": interpret,
        "iters": args.iters,
        "warmup": args.warmup,
        "check_shape": dict(zip("MKN", _CHECK_SHAPE)),
        "shapes": [],
    }

    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    for label, M, K, N in shape_zoo(max_dim=args.max_dim):
        key, sub = jax.random.split(key)
        row = bench_shape(label, M, K, N, args.iters, args.warmup, sub)
        results["shapes"].append(row)
        for op in ("fwd", "bwd", "sample_pack"):
            d = (f"{row[f'{op}_gflops']:.1f}GFLOP/s"
                 if op != "sample_pack"
                 else f"{row['sample_pack_gbit_s']:.2f}Gbit/s")
            print(f"{label}:{op}_{M}x{K}x{N},{row[f'{op}_us']:.0f},{d}")
            print(f"{label}:{op}_ref_{M}x{K}x{N},"
                  f"{row[f'{op}_ref_us']:.0f},baseline")

    # grouped (E, K, N) expert shapes: the MoE hot matmuls
    results["grouped_shapes"] = []
    for label, E, M, K, N in grouped_shape_zoo(max_dim=args.max_dim):
        key, sub = jax.random.split(key)
        row = bench_grouped_shape(label, E, M, K, N, args.iters,
                                  args.warmup, sub)
        results["grouped_shapes"].append(row)
        for op in ("fwd", "bwd"):
            print(f"{label}:{op}_{E}x{M}x{K}x{N},"
                  f"{row[f'{op}_us']:.0f},"
                  f"{row[f'{op}_gflops']:.1f}GFLOP/s")
            print(f"{label}:{op}_ref_{E}x{M}x{K}x{N},"
                  f"{row[f'{op}_ref_us']:.0f},baseline")

    # structural invariants: no weight-shaped f32 value may be defined
    # outside the pallas_call on either pass
    fwd_naive, fwd_fused = weight_temporaries_fwd()
    bwd_naive, bwd_fused = weight_temporaries_bwd()
    results["weight_f32_defs"] = {
        "fwd_naive": fwd_naive, "fwd_fused": fwd_fused,
        "bwd_naive": bwd_naive, "bwd_fused": bwd_fused,
    }
    print(f"weight_f32_defs_fwd_naive,{fwd_naive},count")
    print(f"weight_f32_defs_fwd_fused,{fwd_fused},count")
    print(f"weight_f32_defs_bwd_naive,{bwd_naive},count")
    print(f"weight_f32_defs_bwd_fused,{bwd_fused},count")
    assert fwd_fused == 0, \
        f"fused forward defines {fwd_fused} weight-f32 temporaries"
    assert bwd_fused == 0, \
        f"fused backward defines {bwd_fused} weight-f32 temporaries"
    assert fwd_naive > 0 and bwd_naive > 0, \
        "naive baseline lost its temporaries — check the counter"

    # compiled-HLO substring counts: under interpret-mode emulation the
    # fused number is inflated by plumbing buffers that do not exist on
    # TPU, so the field is explicitly labeled (the jaxpr counts above
    # are the asserted invariant)
    nb, nf = hbm_weight_tensors_baseline_vs_fused()
    results["hlo_substring_counts"] = {
        "fwd_naive": nb, "fwd_fused": nf,
        "interpret_inflated": bool(interpret)}
    if interpret:
        print(f"hbm_weight_tensors_baseline,{nb},interpret_inflated")
        print(f"hbm_weight_tensors_fused,{nf},interpret_inflated")
    else:
        print(f"hbm_weight_tensors_baseline,{nb},count")
        print(f"hbm_weight_tensors_fused,{nf},count")

    # end-to-end: the invariant on a jitted whole-model train step —
    # forward AND backward — for a dense transformer stack, a
    # deepseek-style MoE (stacked (E, K, N) expert leaves through the
    # GROUPED kernel) and a recurrentgemma-style hybrid (depthwise
    # (W, C) conv leaves through the fused conv kernel): the model
    # zoo's masked-execution routing must leave ZERO weight-shaped f32
    # defs outside pallas_call for every masked block shape, while the
    # materialized REPRO_EFF_PATH reference scores > 0 on each leaf
    results["model_step"] = {}
    for fam, (cfg, S) in MODEL_CHECK_CFGS.items():
        model = model_step_weight_defs(cfg, iters=args.iters,
                                       warmup=args.warmup, S=S)
        results["model_step"][fam] = model
        for sh, cts in model["block_shapes"].items():
            print(f"model_step[{fam}]_block_f32_defs_{sh}_fused,"
                  f"{cts['fused']},count")
            assert cts["fused"] == 0, \
                f"{fam} model step defines {cts['fused']} weight-f32 " \
                f"values for block {sh} outside pallas_call"
        for sh, cts in model["leaf_shapes"].items():
            print(f"model_step[{fam}]_leaf_f32_defs_{sh},"
                  f"{cts['eff']}:{cts['fused']},eff:fused")
            assert cts["eff"] > cts["fused"], \
                f"{fam}: materialized path lost its {sh} temporaries " \
                "— check the counter"
        if "train_step_us" in model:
            print(f"model_train_step[{fam}],"
                  f"{model['train_step_us']:.0f},fused")
            print(f"model_train_step_eff[{fam}],"
                  f"{model['train_step_eff_us']:.0f},materialized")

    assert len(results["shapes"]) >= 3, results["shapes"]
    with open(args.json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"# wrote {args.json}")
    return results


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
