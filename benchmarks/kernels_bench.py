"""Kernel micro-benchmarks: fused masked matmul vs the XLA 3-tensor
baseline (materialize sigmoid/u/m*w), and bitpack throughput.

On CPU these numbers are indicative only (the kernel runs in interpret
mode); the structural win — eliminated HBM tensors — is asserted by
counting materialized weight-sized buffers in the lowered HLO.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref, ops


def hbm_weight_tensors_baseline_vs_fused():
    """Count weight-shaped temporaries in each lowering (the structural
    memory-term argument for the Pallas kernel)."""
    M, K, N = 256, 1024, 1024
    x = jnp.zeros((M, K), jnp.bfloat16)
    w = jnp.zeros((K, N), jnp.bfloat16)
    s = jnp.zeros((K, N), jnp.float32)

    def baseline(x, w, s, seed):
        return ref.masked_matmul(x, w, s, seed)

    txt_base = jax.jit(baseline).lower(x, w, s, 0).compile().as_text()
    n_base = txt_base.count(f"{K},{N}")
    # fused path (interpret mode still shows the pallas call boundary)
    txt_fused = jax.jit(
        lambda x, w, s: ops.masked_dense(x, w, s, 0)
    ).lower(x, w, s).compile().as_text()
    n_fused = txt_fused.count(f"{K},{N}")
    return n_base, n_fused


def timed(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def main():
    print("name,us_per_call,derived")
    M, K, N = 256, 1024, 1024
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(key, (K, N), jnp.float32).astype(jnp.bfloat16)
    s = jax.random.normal(key, (K, N), jnp.float32)

    us = timed(jax.jit(lambda x, w, s: ref.masked_matmul(x, w, s, 7)),
               x, w, s)
    flops = 2 * M * K * N
    print(f"masked_matmul_ref_{M}x{K}x{N},{us:.0f},"
          f"{flops / us * 1e6 / 1e9:.1f}GFLOP/s")

    m = jax.random.bernoulli(key, 0.3, (32 * 65536,)).astype(jnp.uint8)
    us = timed(jax.jit(ref.pack_bits), m)
    print(f"bitpack_ref_2Mbit,{us:.0f},"
          f"{m.size / us * 1e6 / 1e9:.2f}Gbit/s")

    nb, nf = hbm_weight_tensors_baseline_vs_fused()
    print(f"hbm_weight_tensors_baseline,{nb},count")
    print(f"hbm_weight_tensors_fused,{nf},count")


if __name__ == "__main__":
    main()
