"""Kernel benchmark harness: fused masked-matmul forward/backward and
the fused sample+pack uplink kernel vs their pure-jnp oracles.

Two kinds of output:

  * Timings — median-of-N `time.perf_counter` wall clock (after separate
    warmup calls) for fwd / bwd / sample+pack across a shape zoo drawn
    from the real model configs (`repro.configs`), written to
    ``BENCH_kernels.json`` and printed as CSV.  On CPU the kernels run
    in interpret mode, so the numbers are indicative only.

  * Structural assertions — the memory-term argument that holds on any
    backend: counting weight-shaped (K, N) f32 values defined OUTSIDE
    the pallas_call boundary.  The count runs on the jaxpr (where
    `pallas_call` is a single opaque equation) rather than compiled HLO
    text, because interpret-mode emulation inlines full-size plumbing
    buffers into the compiled module that do not exist on TPU.  The
    naive path materializes sigmoid(s), the hash uniforms, m*w and
    x^T@g at weight size; the fused forward AND backward must define
    zero such values.  Compiled-HLO substring counts are still reported
    (informational) for continuity with the original forward check.

Run:  PYTHONPATH=src python benchmarks/kernels_bench.py [--iters N]
      [--warmup N] [--max-dim D] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.configs import get_config
from repro.kernels import ref, ops


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------


def timed(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in us: `warmup` untimed calls first
    (compile + cache effects), then `iters` timed calls, each fully
    blocked on, reported as the median (robust to scheduler noise where
    a mean is not)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


# ---------------------------------------------------------------------------
# Shape zoo: the hot matmuls of the real model configs
# ---------------------------------------------------------------------------

ZOO_ARCHS = ("internlm2-1.8b", "gemma3-4b", "qwen2-7b")


def _shrink(d: int, max_dim: int) -> int:
    """Halve until <= max_dim, then round down to lane (128) alignment
    so interpret-mode (CPU) runs stay tractable; actual dims are
    recorded in the JSON."""
    while d > max_dim:
        d //= 2
    return max(d - d % 128, 128)


def shape_zoo(max_dim: int = 1536, m: int = 256):
    """(label, M, K, N) for the per-layer hot matmuls — the attention
    qkv projection (d_model -> (H + 2*H_kv) * hd) and the FFN up
    projection (d_model -> d_ff) — of each zoo arch, deduplicated."""
    out, seen = [], set()
    for name in ZOO_ARCHS:
        cfg = get_config(name)
        qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
        for tag, k, n in (("qkv", cfg.d_model, qkv),
                          ("ffn_up", cfg.d_model, cfg.d_ff)):
            K, N = _shrink(k, max_dim), _shrink(n, max_dim)
            if (K, N) in seen:
                continue
            seen.add((K, N))
            out.append((f"{name}:{tag}", m, K, N))
    return out


# ---------------------------------------------------------------------------
# Structural check: weight-shaped f32 values outside the pallas boundary
# ---------------------------------------------------------------------------


_CHECK_SHAPE = (256, 1024, 1024)  # MXU-aligned so no pad/slice eqns


def count_weight_f32_defs(fn, args, weight_shape) -> int:
    """Number of jaxpr equations (recursively) defining an f32 value of
    `weight_shape` outside any `pallas_call`.

    Call-like equations that merely forward inner results (pjit,
    custom_vjp, scan, ...) are recursed into instead of counted, so a
    hit is a real weight-sized compute/materialization step; the
    pallas_call equation itself is never descended into — its innards
    live in VMEM, which is the entire point.
    """
    tgt = (tuple(weight_shape), jnp.dtype(jnp.float32))
    n_hits = 0

    def subjaxprs(params):
        found = []
        stack = list(params.values())
        while stack:
            p = stack.pop()
            if isinstance(p, jcore.ClosedJaxpr):
                found.append(p.jaxpr)
            elif isinstance(p, jcore.Jaxpr):
                found.append(p)
            elif isinstance(p, (tuple, list)):
                stack.extend(p)
        return found

    def walk(jaxpr):
        nonlocal n_hits
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                continue
            inner = subjaxprs(eqn.params)
            if inner:
                for j in inner:
                    walk(j)
                continue  # call wrapper: count only the defining eqns
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and (
                        tuple(aval.shape), aval.dtype) == tgt:
                    n_hits += 1

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return n_hits


def _check_operands(M, K, N):
    x = jnp.zeros((M, K), jnp.bfloat16)
    w = jnp.zeros((K, N), jnp.bfloat16)
    s = jnp.zeros((K, N), jnp.float32)
    g = jnp.zeros((M, N), jnp.bfloat16)
    return x, w, s, g


def weight_temporaries_fwd():
    """(naive, fused) weight-f32 def counts for the forward."""
    M, K, N = _CHECK_SHAPE
    x, w, s, _ = _check_operands(M, K, N)
    naive = count_weight_f32_defs(
        lambda x, w, s: ref.masked_matmul(x, w, s, 0), (x, w, s), (K, N))
    fused = count_weight_f32_defs(
        lambda x, w, s: ops.masked_dense(x, w, s, 0), (x, w, s), (K, N))
    return naive, fused


def weight_temporaries_bwd():
    """(naive, fused) weight-f32 def counts for the STE backward."""
    M, K, N = _CHECK_SHAPE
    x, w, s, g = _check_operands(M, K, N)

    def fused(x, w, s, g):
        _, vjp = jax.vjp(
            lambda x_, s_: ops.masked_dense(x_, w, s_, 0), x, s)
        return vjp(g)

    def naive(x, w, s, g):
        return ref.masked_dense_bwd(x, w, s, 0, g)

    args = (x, w, s, g)
    return (count_weight_f32_defs(naive, args, (K, N)),
            count_weight_f32_defs(fused, args, (K, N)))


def hbm_weight_tensors_baseline_vs_fused():
    """Compiled-HLO substring counts for the forward (the original,
    informational check; interpret-mode emulation inflates the fused
    number with plumbing buffers that do not exist on TPU — the jaxpr
    counts above are the asserted invariant)."""
    M, K, N = _CHECK_SHAPE
    x, w, s, _ = _check_operands(M, K, N)
    txt_base = jax.jit(
        lambda x, w, s: ref.masked_matmul(x, w, s, 0)
    ).lower(x, w, s).compile().as_text()
    txt_fused = jax.jit(
        lambda x, w, s: ops.masked_dense(x, w, s, 0)
    ).lower(x, w, s).compile().as_text()
    return txt_base.count(f"{K},{N}"), txt_fused.count(f"{K},{N}")


# ---------------------------------------------------------------------------
# Benchmarks
# ---------------------------------------------------------------------------


def bench_shape(label, M, K, N, iters, warmup, key):
    kx, kw, ks, kg = jax.random.split(key, 4)
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(kw, (K, N), jnp.float32).astype(jnp.bfloat16)
    s = jax.random.normal(ks, (K, N), jnp.float32)
    g = jax.random.normal(kg, (M, N), jnp.float32).astype(jnp.bfloat16)

    fwd = jax.jit(lambda x, w, s: ops.masked_dense(x, w, s, 7))
    fwd_ref = jax.jit(lambda x, w, s: ref.masked_matmul(x, w, s, 7))

    # grad step = forward + backward on BOTH sides (jax.vjp re-runs the
    # forward, so the naive baseline gets its forward too — symmetric),
    # 3 weight-sized matmuls total (y, dx, ds)
    def _bwd(x, w, s, g):
        _, vjp = jax.vjp(
            lambda x_, s_: ops.masked_dense(x_, w, s_, 7), x, s)
        return vjp(g)

    bwd = jax.jit(_bwd)

    def _bwd_ref(x, w, s, g):
        y = ref.masked_matmul(x, w, s, 7)
        dx, ds = ref.masked_dense_bwd(x, w, s, 7, g)
        return y, dx, ds

    bwd_ref = jax.jit(_bwd_ref)

    # one cohort row of K*N scores: the per-round uplink sampling
    flat = s.reshape(1, -1)
    seeds = jnp.asarray([7], jnp.uint32)
    sap = jax.jit(lambda f, sd: ops.sample_and_pack(f, sd))
    sap_ref = jax.jit(lambda f, sd: ref.sample_and_pack(f, sd))

    t = dict(
        fwd_us=timed(fwd, x, w, s, iters=iters, warmup=warmup),
        fwd_ref_us=timed(fwd_ref, x, w, s, iters=iters, warmup=warmup),
        bwd_us=timed(bwd, x, w, s, g, iters=iters, warmup=warmup),
        bwd_ref_us=timed(bwd_ref, x, w, s, g, iters=iters,
                         warmup=warmup),
        sample_pack_us=timed(sap, flat, seeds, iters=iters,
                             warmup=warmup),
        sample_pack_ref_us=timed(sap_ref, flat, seeds, iters=iters,
                                 warmup=warmup),
    )
    fwd_flops = 2 * M * K * N
    t["fwd_gflops"] = fwd_flops / t["fwd_us"] / 1e3
    t["bwd_gflops"] = 3 * fwd_flops / t["bwd_us"] / 1e3  # y + dx + ds
    t["sample_pack_gbit_s"] = K * N / t["sample_pack_us"] / 1e3
    return {"name": label, "M": M, "K": K, "N": N, **t}


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--iters", type=int, default=3,
                   help="timed iterations per benchmark (median taken)")
    p.add_argument("--warmup", type=int, default=1,
                   help="untimed warmup iterations")
    p.add_argument("--max-dim", type=int, default=1536,
                   help="shrink zoo dims to <= this (CPU tractability)")
    p.add_argument("--json", default="BENCH_kernels.json",
                   help="output path for the results JSON")
    args = p.parse_args([] if argv is None else argv)

    interpret = ops._use_interpret()
    results = {
        "backend": ops.repro_backend(),
        "interpret": interpret,
        "iters": args.iters,
        "warmup": args.warmup,
        "check_shape": dict(zip("MKN", _CHECK_SHAPE)),
        "shapes": [],
    }

    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    for label, M, K, N in shape_zoo(max_dim=args.max_dim):
        key, sub = jax.random.split(key)
        row = bench_shape(label, M, K, N, args.iters, args.warmup, sub)
        results["shapes"].append(row)
        for op in ("fwd", "bwd", "sample_pack"):
            d = (f"{row[f'{op}_gflops']:.1f}GFLOP/s"
                 if op != "sample_pack"
                 else f"{row['sample_pack_gbit_s']:.2f}Gbit/s")
            print(f"{label}:{op}_{M}x{K}x{N},{row[f'{op}_us']:.0f},{d}")
            print(f"{label}:{op}_ref_{M}x{K}x{N},"
                  f"{row[f'{op}_ref_us']:.0f},baseline")

    # structural invariants: no weight-shaped f32 value may be defined
    # outside the pallas_call on either pass
    fwd_naive, fwd_fused = weight_temporaries_fwd()
    bwd_naive, bwd_fused = weight_temporaries_bwd()
    results["weight_f32_defs"] = {
        "fwd_naive": fwd_naive, "fwd_fused": fwd_fused,
        "bwd_naive": bwd_naive, "bwd_fused": bwd_fused,
    }
    print(f"weight_f32_defs_fwd_naive,{fwd_naive},count")
    print(f"weight_f32_defs_fwd_fused,{fwd_fused},count")
    print(f"weight_f32_defs_bwd_naive,{bwd_naive},count")
    print(f"weight_f32_defs_bwd_fused,{bwd_fused},count")
    assert fwd_fused == 0, \
        f"fused forward defines {fwd_fused} weight-f32 temporaries"
    assert bwd_fused == 0, \
        f"fused backward defines {bwd_fused} weight-f32 temporaries"
    assert fwd_naive > 0 and bwd_naive > 0, \
        "naive baseline lost its temporaries — check the counter"

    nb, nf = hbm_weight_tensors_baseline_vs_fused()
    results["hlo_substring_counts"] = {"fwd_naive": nb, "fwd_fused": nf}
    print(f"hbm_weight_tensors_baseline,{nb},count")
    print(f"hbm_weight_tensors_fused,{nf},count")

    assert len(results["shapes"]) >= 3, results["shapes"]
    with open(args.json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"# wrote {args.json}")
    return results


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
